"""Make the build-time `compile` package importable when pytest runs from
either the repo root or python/."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
