"""L1 correctness: every Pallas kernel vs the pure-jnp oracle in ref.py.

hypothesis sweeps dimensions (including non-tile-multiples and d < tile),
tile sizes, and value magnitudes; assert_allclose with tolerances that admit
rsqrt-vs-sqrt/div rounding but nothing larger.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adaalter, adagrad, average, common, ref, sgd

TOL = dict(rtol=1e-4, atol=1e-6)

dims = st.sampled_from([1, 7, 255, 256, 257, 1000, 8192, 10000])
tiles = st.sampled_from([256, 1024, 8192])
seeds = st.integers(min_value=0, max_value=2**31 - 1)
scalars = st.floats(min_value=1e-3, max_value=10.0,
                    allow_nan=False, allow_infinity=False)


def _vecs(seed, d, n, scale=1.0, positive=False):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        v = rng.normal(size=d, scale=scale).astype(np.float32)
        if positive:
            v = np.abs(v) + 1.0
        out.append(v)
    return out


class TestAdaAlterKernel:
    @settings(max_examples=25, deadline=None)
    @given(seed=seeds, d=dims, tile=tiles, lr=scalars, denom_add=scalars)
    def test_matches_ref(self, seed, d, tile, lr, denom_add):
        x, g = _vecs(seed, d, 2)
        (b2,) = _vecs(seed + 1, d, 1, positive=True)
        (acc,) = _vecs(seed + 2, d, 1, positive=True)
        gsq = g * g
        y, a = adaalter.adaalter_step(x, b2, acc, g, gsq, denom_add, lr,
                                      tile=tile)
        yr, ar = ref.adaalter_step_ref(x, b2, acc, g, gsq, denom_add, lr)
        np.testing.assert_allclose(y, yr, **TOL)
        np.testing.assert_allclose(a, ar, **TOL)

    def test_update_uses_stale_denominator(self):
        """The defining AdaAlter property: y must NOT depend on gsq."""
        d = 512
        x, g = _vecs(0, d, 2)
        (b2,) = _vecs(1, d, 1, positive=True)
        y1, _ = adaalter.adaalter_step(x, b2, b2, g, g * g, 1.0, 0.5)
        y2, _ = adaalter.adaalter_step(x, b2, b2, g, 100.0 * g * g, 1.0, 0.5)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_accumulator_independent_of_update_inputs(self):
        """acc' = acc + gsq regardless of lr/denom_add."""
        d = 300
        x, g = _vecs(2, d, 2)
        (b2,) = _vecs(3, d, 1, positive=True)
        _, a1 = adaalter.adaalter_step(x, b2, b2, g, g * g, 1.0, 0.5)
        _, a2 = adaalter.adaalter_step(x, b2, b2, g, g * g, 9.0, 0.01)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    @settings(max_examples=10, deadline=None)
    @given(seed=seeds, H=st.integers(min_value=1, max_value=6))
    def test_local_round_matches_ref(self, seed, H):
        d = 257
        rng = np.random.default_rng(seed)
        x = rng.normal(size=d).astype(np.float32)
        b2 = (1.0 + rng.random(d)).astype(np.float32)
        grads = rng.normal(size=(H, d)).astype(np.float32)
        xe, ae = ref.local_adaalter_round_ref(x, b2, grads, 1.0, 0.5)
        xx, aa = x, b2
        for s in range(H):
            xx, aa = adaalter.local_adaalter_step(
                xx, b2, aa, grads[s], s + 1, 1.0, 0.5, tile=256)
        np.testing.assert_allclose(xx, xe, **TOL)
        np.testing.assert_allclose(aa, ae, **TOL)

    def test_zero_grad_is_identity_update(self):
        d = 100
        (x,) = _vecs(4, d, 1)
        (b2,) = _vecs(5, d, 1, positive=True)
        y, a = adaalter.adaalter_step(x, b2, b2, np.zeros(d, np.float32),
                                      np.zeros(d, np.float32), 1.0, 0.5)
        np.testing.assert_allclose(y, x, **TOL)
        np.testing.assert_allclose(a, b2, **TOL)


class TestAdaGradKernel:
    @settings(max_examples=25, deadline=None)
    @given(seed=seeds, d=dims, tile=tiles, lr=scalars, eps2=scalars)
    def test_matches_ref(self, seed, d, tile, lr, eps2):
        x, g = _vecs(seed, d, 2)
        (b2,) = _vecs(seed + 1, d, 1, positive=True)
        gsq = g * g
        y, b = adagrad.adagrad_step(x, b2, g, gsq, eps2, lr, tile=tile)
        yr, br = ref.adagrad_step_ref(x, b2, g, gsq, eps2, lr)
        np.testing.assert_allclose(y, yr, **TOL)
        np.testing.assert_allclose(b, br, **TOL)

    def test_order_differs_from_adaalter(self):
        """AdaGrad accumulates first; with a large gsq the two orders must
        visibly diverge — this is the paper's §4.2 distinction."""
        d = 64
        x, g = _vecs(6, d, 2)
        b2 = np.ones(d, np.float32)
        gsq = 50.0 * np.ones(d, np.float32)
        y_ag, _ = adagrad.adagrad_step(x, b2, g, gsq, 1.0, 0.5)
        y_aa, _ = adaalter.adaalter_step(x, b2, b2, g, gsq, 1.0, 0.5)
        assert np.max(np.abs(np.asarray(y_ag) - np.asarray(y_aa))) > 1e-3


class TestSgdKernels:
    @settings(max_examples=20, deadline=None)
    @given(seed=seeds, d=dims, tile=tiles, lr=scalars)
    def test_sgd_matches_ref(self, seed, d, tile, lr):
        x, g = _vecs(seed, d, 2)
        y = sgd.sgd_step(x, g, lr, tile=tile)
        np.testing.assert_allclose(y, ref.sgd_step_ref(x, g, lr), **TOL)

    @settings(max_examples=20, deadline=None)
    @given(seed=seeds, d=dims, lr=scalars,
           mu=st.floats(min_value=0.0, max_value=0.99))
    def test_momentum_matches_ref(self, seed, d, lr, mu):
        x, m, g = _vecs(seed, d, 3)
        y, mo = sgd.momentum_step(x, m, g, lr, mu)
        yr, mr = ref.momentum_step_ref(x, m, g, lr, mu)
        np.testing.assert_allclose(y, yr, **TOL)
        np.testing.assert_allclose(mo, mr, **TOL)


class TestAverageKernel:
    @settings(max_examples=20, deadline=None)
    @given(seed=seeds, d=dims, n=st.integers(min_value=1, max_value=8),
           tile=tiles)
    def test_matches_ref(self, seed, d, n, tile):
        rng = np.random.default_rng(seed)
        stacked = rng.normal(size=(n, d)).astype(np.float32)
        np.testing.assert_allclose(
            average.average(stacked, tile=tile), ref.average_ref(stacked),
            rtol=1e-4, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=seeds, n=st.integers(min_value=2, max_value=6))
    def test_weighted_uniform_equals_mean(self, seed, n):
        rng = np.random.default_rng(seed)
        stacked = rng.normal(size=(n, 777)).astype(np.float32)
        w = np.full(n, 1.0 / n, np.float32)
        np.testing.assert_allclose(
            average.weighted_average(stacked, w, tile=256),
            ref.average_ref(stacked), rtol=1e-4, atol=1e-5)

    def test_identical_replicas_fixed_point(self):
        v = np.random.default_rng(7).normal(size=1000).astype(np.float32)
        stacked = np.stack([v] * 4)
        np.testing.assert_allclose(average.average(stacked), v, rtol=1e-6)


class TestCommon:
    @settings(max_examples=30, deadline=None)
    @given(d=st.integers(min_value=1, max_value=10**6),
           tile=st.sampled_from([256, 1024, 8192]))
    def test_padded_size(self, d, tile):
        p = common.padded_size(d, tile)
        assert p >= d and p % tile == 0 and p - d < tile

    def test_padded_size_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            common.padded_size(0)

    def test_pad1_roundtrip(self):
        v = np.arange(300, dtype=np.float32)
        padded = common.pad1(v, 256)
        assert padded.shape == (512,)
        np.testing.assert_array_equal(np.asarray(padded[:300]), v)
        assert float(np.sum(np.asarray(padded[300:]))) == 0.0
