"""L2 correctness: transformer LM shapes, flat-vector contract, causality,
gradient sanity, eval/PPL consistency."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.presets import PRESETS

CFG = PRESETS["tiny"].model


def _tokens(rng, cfg, batch):
    return jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch, cfg.seq + 1)), jnp.int32)


class TestParamSpec:
    def test_offsets_are_contiguous(self):
        off = 0
        for name, shape, o in M.param_offsets(CFG):
            assert o == off, name
            off += math.prod(shape)
        assert off == M.num_params(CFG)

    def test_flatten_unflatten_roundtrip(self):
        d = M.num_params(CFG)
        flat = jnp.arange(d, dtype=jnp.float32)
        back = M.flatten(CFG, M.unflatten(CFG, flat))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))

    def test_tied_embeddings_have_no_head(self):
        names = [n for n, _ in M.param_spec(CFG)]
        assert "head" not in names
        cfg2 = M.ModelConfig(vocab=64, dim=16, layers=1, heads=2, seq=8,
                             tie_embeddings=False)
        assert "head" in [n for n, _ in M.param_spec(cfg2)]

    def test_dim_heads_validation(self):
        with pytest.raises(ValueError):
            M.ModelConfig(vocab=16, dim=10, heads=3, layers=1, seq=4)

    @settings(max_examples=10, deadline=None)
    @given(dim=st.sampled_from([16, 32, 64]),
           layers=st.integers(min_value=1, max_value=3),
           vocab=st.sampled_from([32, 100, 256]))
    def test_num_params_formula(self, dim, layers, vocab):
        cfg = M.ModelConfig(vocab=vocab, dim=dim, layers=layers, heads=2,
                            seq=16)
        expected = vocab * dim + 16 * dim + layers * (
            dim + dim * 3 * dim + dim * dim + dim
            + dim * 4 * dim + 4 * dim * dim) + dim
        assert M.num_params(cfg) == expected


class TestForward:
    def test_logits_shape(self):
        rng = np.random.default_rng(0)
        flat = M.init_params(CFG, jax.random.PRNGKey(0))
        toks = _tokens(rng, CFG, 3)
        logits = M.forward(CFG, flat, toks[:, :-1])
        assert logits.shape == (3, CFG.seq, CFG.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_causality(self):
        """Changing a future token must not change past logits."""
        rng = np.random.default_rng(1)
        flat = M.init_params(CFG, jax.random.PRNGKey(0))
        toks = np.asarray(_tokens(rng, CFG, 1))[:, :-1]
        cut = CFG.seq // 2
        toks2 = toks.copy()
        toks2[:, cut:] = (toks2[:, cut:] + 1) % CFG.vocab
        l1 = M.forward(CFG, flat, jnp.asarray(toks))
        l2 = M.forward(CFG, flat, jnp.asarray(toks2))
        np.testing.assert_allclose(np.asarray(l1[:, :cut]),
                                   np.asarray(l2[:, :cut]),
                                   rtol=1e-5, atol=1e-6)
        assert np.max(np.abs(np.asarray(l1[:, cut:]) -
                             np.asarray(l2[:, cut:]))) > 1e-4

    def test_init_deterministic(self):
        a = M.init_params(CFG, jax.random.PRNGKey(42))
        b = M.init_params(CFG, jax.random.PRNGKey(42))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = M.init_params(CFG, jax.random.PRNGKey(43))
        assert np.max(np.abs(np.asarray(a) - np.asarray(c))) > 0


class TestLossGrad:
    def test_initial_loss_near_uniform(self):
        """With 0.02-scale init the LM is ~uniform: loss ≈ ln(V)."""
        rng = np.random.default_rng(2)
        flat = M.init_params(CFG, jax.random.PRNGKey(0))
        loss = M.loss_fn(CFG, flat, _tokens(rng, CFG, 4))
        assert abs(float(loss) - math.log(CFG.vocab)) < 0.3

    def test_grad_shape_and_finite(self):
        rng = np.random.default_rng(3)
        flat = M.init_params(CFG, jax.random.PRNGKey(0))
        loss, g = M.loss_and_grad(CFG, flat, _tokens(rng, CFG, 2))
        assert g.shape == (M.num_params(CFG),)
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.max(jnp.abs(g))) > 0

    def test_grad_matches_finite_difference(self):
        """Directional finite-difference check on a few random directions."""
        cfg = M.ModelConfig(vocab=32, dim=16, layers=1, heads=2, seq=8)
        rng = np.random.default_rng(4)
        flat = M.init_params(cfg, jax.random.PRNGKey(1))
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(2, cfg.seq + 1)), jnp.int32)
        _, g = M.loss_and_grad(cfg, flat, toks)
        f64 = np.asarray(flat, np.float64)
        for seed in range(3):
            v = np.random.default_rng(seed).normal(size=f64.size)
            v /= np.linalg.norm(v)
            h = 1e-3
            lp = float(M.loss_fn(cfg, jnp.asarray(f64 + h * v, jnp.float32), toks))
            lm = float(M.loss_fn(cfg, jnp.asarray(f64 - h * v, jnp.float32), toks))
            fd = (lp - lm) / (2 * h)
            an = float(np.dot(np.asarray(g, np.float64), v))
            assert abs(fd - an) < 5e-3 * max(1.0, abs(an)), (fd, an)

    def test_gradient_descends(self):
        rng = np.random.default_rng(5)
        flat = M.init_params(CFG, jax.random.PRNGKey(0))
        toks = _tokens(rng, CFG, 4)
        l0, g = M.loss_and_grad(CFG, flat, toks)
        l1 = M.loss_fn(CFG, flat - 0.5 * g, toks)
        assert float(l1) < float(l0)


class TestEval:
    def test_eval_consistent_with_loss(self):
        rng = np.random.default_rng(6)
        flat = M.init_params(CFG, jax.random.PRNGKey(0))
        toks = _tokens(rng, CFG, 4)
        sum_nll, count = M.eval_nll(CFG, flat, toks)
        loss = M.loss_fn(CFG, flat, toks)
        assert int(count) == 4 * CFG.seq
        np.testing.assert_allclose(float(sum_nll) / float(count),
                                   float(loss), rtol=1e-5)

    def test_ppl_of_uniform_model_is_vocab(self):
        """A zero-parameter (uniform) model has PPL == vocab size."""
        rng = np.random.default_rng(7)
        flat = jnp.zeros(M.num_params(CFG), jnp.float32)
        sum_nll, count = M.eval_nll(CFG, flat, _tokens(rng, CFG, 2))
        ppl = math.exp(float(sum_nll) / float(count))
        assert abs(ppl - CFG.vocab) / CFG.vocab < 1e-3
