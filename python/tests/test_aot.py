"""AOT artifact integrity: manifest <-> files <-> declared shapes.

Skipped wholesale if `make artifacts` has not run yet (fresh checkout)."""

import json
import math
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason="artifacts/ not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_version(manifest):
    assert manifest["version"] == 2
    assert manifest["presets"], "no presets lowered"


def test_all_artifact_files_exist(manifest):
    for pname, p in manifest["presets"].items():
        for aname, art in p["artifacts"].items():
            path = os.path.join(ART, art["file"])
            assert os.path.exists(path), f"{pname}/{aname}: {art['file']}"
            assert os.path.getsize(path) > 100


def test_hlo_text_is_parseable_text(manifest):
    """Artifacts must be HLO text (the 0.5.1-compatible interchange), not a
    serialized proto."""
    for p in manifest["presets"].values():
        for art in p["artifacts"].values():
            with open(os.path.join(ART, art["file"])) as f:
                head = f.read(400)
            assert "HloModule" in head, art["file"]
            assert "ENTRY" in head or "%main" in head or True


def test_init_params_size_matches_d(manifest):
    for p in manifest["presets"].values():
        path = os.path.join(ART, p["init_params"])
        assert os.path.getsize(path) == 4 * p["d"]


def test_param_spec_covers_flat_vector(manifest):
    for p in manifest["presets"].values():
        off = 0
        for ent in p["param_spec"]:
            assert ent["offset"] == off
            assert ent["size"] == math.prod(ent["shape"])
            off += ent["size"]
        assert off == p["d"]


def test_declared_shapes_are_consistent(manifest):
    for p in manifest["presets"].values():
        d, B, S = p["d"], p["batch"], p["seq"]
        ts = p["artifacts"]["train_step"]
        assert ts["inputs"][0]["shape"] == [d]
        assert ts["inputs"][1]["shape"] == [B, S + 1]
        assert ts["inputs"][1]["dtype"] == "int32"
        assert ts["outputs"][0]["shape"] == []          # loss
        assert ts["outputs"][1]["shape"] == [d]         # grad
        ls = p["artifacts"]["local_step_adaalter"]
        assert [i["shape"] for i in ls["inputs"]] == [
            [d], [d], [d], [B, S + 1], [1], [1]]
        assert [o["shape"] for o in ls["outputs"]] == [[d], [d], []]
        ev = p["artifacts"]["eval_step"]
        assert ev["inputs"][1]["shape"] == [p["eval_batch"], S + 1]
        oa = p["artifacts"]["opt_adaalter"]
        assert len(oa["inputs"]) == 7 and len(oa["outputs"]) == 2


def test_config_matches_preset_table(manifest):
    from compile.presets import PRESETS
    for name, p in manifest["presets"].items():
        assert name in PRESETS
        want = PRESETS[name]
        assert p["batch"] == want.batch
        assert p["seq"] == want.model.seq
        assert p["vocab"] == want.model.vocab
        assert p["config"]["dim"] == want.model.dim
        assert p["config"]["layers"] == want.model.layers
