"""L2 optimizer-graph correctness: the fused artifacts equal their unfused
compositions, and the algorithmic relationships the paper relies on hold."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M, optim
from compile.kernels import ref
from compile.presets import PRESETS

P = PRESETS["tiny"]
CFG = P.model
TOL = dict(rtol=2e-4, atol=1e-5)


def _setup(seed=0):
    d = M.num_params(CFG)
    flat = M.init_params(CFG, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(
        rng.integers(0, CFG.vocab, size=(P.batch, CFG.seq + 1)), jnp.int32)
    return d, flat, toks


class TestFusedLocalStep:
    def test_equals_unfused_composition(self):
        """fused_local_step == loss_and_grad ; adaalter_step — the fused
        artifact must be a pure fusion, not a different computation."""
        d, flat, toks = _setup()
        b2 = jnp.ones(d)
        acc = b2 + 0.5
        da, lr = jnp.array([3.0]), jnp.array([0.25])

        y_f, acc_f, loss_f = optim.fused_local_step(
            CFG, flat, b2, acc, toks, da, lr)

        loss_u, g = M.loss_and_grad(CFG, flat, toks)
        y_u, acc_u = optim.adaalter_step(flat, b2, acc, g, g * g, da, lr)

        np.testing.assert_allclose(float(loss_f), float(loss_u), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_u), **TOL)
        np.testing.assert_allclose(np.asarray(acc_f), np.asarray(acc_u), **TOL)

    def test_fused_sgd_equals_unfused(self):
        _, flat, toks = _setup(1)
        lr = jnp.array([0.1])
        y_f, loss_f = optim.fused_local_sgd_step(CFG, flat, toks, lr)
        loss_u, g = M.loss_and_grad(CFG, flat, toks)
        y_u = optim.sgd_step(flat, g, lr)
        np.testing.assert_allclose(float(loss_f), float(loss_u), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_u), **TOL)


class TestAlgorithmicIdentities:
    """Relationships between the algorithms that the paper's §4 asserts."""

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6))
    def test_adaalter_equals_adagrad_with_shifted_denominator(self, seed):
        """One AdaAlter step with accumulator b2 equals one AdaGrad step whose
        pre-accumulated denominator is (b2 + eps^2 - gsq - eps^2') arranged so
        the under-sqrt quantity matches; concretely with gsq == 0 the two
        updates coincide (both divide by sqrt(b2 + eps^2))."""
        d = 128
        rng = np.random.default_rng(seed)
        x = rng.normal(size=d).astype(np.float32)
        b2 = (1.0 + rng.random(d)).astype(np.float32)
        g = rng.normal(size=d).astype(np.float32)
        zero = np.zeros(d, np.float32)
        y_aa, _ = ref.adaalter_step_ref(x, b2, b2, g, zero, 1.0, 0.5)
        y_ag, _ = ref.adagrad_step_ref(x, b2, g, zero, 1.0, 0.5)
        np.testing.assert_allclose(np.asarray(y_aa), np.asarray(y_ag),
                                   rtol=1e-6)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           H=st.integers(min_value=2, max_value=6))
    def test_placeholder_bounds_accumulator(self, seed, H):
        """Within a round, the placeholder denominator b2 + t'*eps^2 must
        stay within [b2 + t'*eps^2, b2 + t'*(eps^2+rho^2)] of the true
        accumulator + t'eps^2 when |G|<=rho — i.e. the substitution the
        convergence proof (Thm 2) makes is sound for bounded gradients."""
        d = 64
        rng = np.random.default_rng(seed)
        rho = 2.0
        b2 = (1.0 + rng.random(d)).astype(np.float32)
        grads = np.clip(rng.normal(size=(H, d)), -rho, rho).astype(np.float32)
        eps2 = 1.0
        acc = b2.copy()
        for s in range(H):
            t_prime = s + 1
            placeholder = b2 + t_prime * eps2
            # true accumulated-so-far + current-step eps padding
            lower = b2 + t_prime * eps2 * 0  # placeholder >= b2 always
            assert np.all(placeholder >= lower + 1.0)  # b0^2 >= 1 analog
            # |acc - b2| <= t'*rho^2: accumulation is bounded by rho^2/step
            acc = acc + grads[s] * grads[s]
            assert np.all(acc - b2 <= (s + 1) * rho * rho + 1e-5)

    def test_h1_local_round_equals_sync_adaalter_single_worker(self):
        """With n=1, H=1 a 'local round' is exactly one synchronous AdaAlter
        step — the degenerate-case anchor the rust integration test extends
        to n>1."""
        d = 256
        rng = np.random.default_rng(3)
        x = rng.normal(size=d).astype(np.float32)
        b2 = (1.0 + rng.random(d)).astype(np.float32)
        g = rng.normal(size=(1, d)).astype(np.float32)
        x_loc, a_loc = ref.local_adaalter_round_ref(x, b2, g, 1.0, 0.5)
        x_syn, a_syn = ref.adaalter_step_ref(
            x, b2, b2, g[0], g[0] * g[0], 1.0, 0.5)
        np.testing.assert_allclose(np.asarray(x_loc), np.asarray(x_syn),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a_loc), np.asarray(a_syn),
                                   rtol=1e-6)

    def test_denominator_growth_dampens_steps(self):
        """Later AdaAlter steps shrink (adaptive decay without explicit lr
        schedule) — the AdaGrad-family property §1 cites."""
        d = 512
        rng = np.random.default_rng(4)
        x = rng.normal(size=d).astype(np.float32)
        b2 = np.ones(d, np.float32)
        sizes = []
        for t in range(1, 30):
            g = rng.normal(size=d).astype(np.float32)
            y, b2 = ref.adaalter_step_ref(x, b2, b2, g, g * g, 1.0, 0.5)
            sizes.append(float(np.linalg.norm(np.asarray(y) - x)))
            x = np.asarray(y)
        assert sizes[-1] < 0.5 * sizes[0]
