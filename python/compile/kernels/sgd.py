"""Pallas kernels for the SGD baselines (Algorithm 2 of the paper).

Plain and heavy-ball-momentum variants, over the same flat-vector tiling as
the adaptive kernels.  Local SGD (Alg. 2) is plain SGD on each worker plus
the coordinator's H-period parameter averaging — the averaging lives in the
rust comm layer / ``average.py``.
"""

from __future__ import annotations

import jax

from .common import as_scalar_arr, auto_tile, elementwise_call, pad1


def _sgd_kernel(x_ref, g_ref, lr_ref, y_ref):
    y_ref[...] = x_ref[...] - lr_ref[0] * g_ref[...]


def sgd_step(x, g, lr, *, tile: int = 0):
    """y = x - lr * g over flat f32[d]."""
    d = x.shape[0]
    tile = tile or auto_tile(d)
    call = elementwise_call(_sgd_kernel, n_out=1, d=d, tile=tile,
                            n_vec_in=2, n_scalar_in=1)
    y = call(pad1(x, tile), pad1(g, tile), as_scalar_arr(lr))
    return y[:d]


def _momentum_kernel(x_ref, m_ref, g_ref, lr_ref, mu_ref, y_ref, m_out_ref):
    m_new = mu_ref[0] * m_ref[...] + g_ref[...]
    y_ref[...] = x_ref[...] - lr_ref[0] * m_new
    m_out_ref[...] = m_new


def momentum_step(x, m, g, lr, mu, *, tile: int = 0):
    """Heavy-ball: m' = mu*m + g; y = x - lr*m'.  Returns (y, m')."""
    d = x.shape[0]
    tile = tile or auto_tile(d)
    call = elementwise_call(_momentum_kernel, n_out=2, d=d, tile=tile,
                            n_vec_in=3, n_scalar_in=2)
    y, m_out = call(pad1(x, tile), pad1(m, tile), pad1(g, tile),
                    as_scalar_arr(lr), as_scalar_arr(mu))
    return y[:d], m_out[:d]
