"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts).

Modules:
  common   — 1-D VPU tiling helpers shared by all element-wise kernels
  adaalter — fused (local) AdaAlter update, the paper's contribution
  adagrad  — fused AdaGrad baseline (Algorithm 1)
  sgd      — plain / momentum SGD baselines (Algorithm 2)
  average  — n-way synchronisation average (Algorithm 4 lines 11-12)
  ref      — pure-jnp oracles each kernel is pinned against
"""
from . import adaalter, adagrad, average, common, ref, sgd  # noqa: F401
