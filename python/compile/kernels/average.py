"""Pallas kernel for the n-way synchronisation average (Alg. 4 lines 11-12).

Every sync round averages the n workers' parameters y_{k,t} and accumulators
A^2_{k,t}.  The kernel reduces a stacked f32[n, d] across axis 0, tiled along
d: each grid instance loads an (n, TILE) panel into VMEM and emits its column
mean.  For the small n of the paper (<= 8) the panel is tiny (8 * 32 KiB).

The rust coordinator normally performs this average itself (it is a
contiguous SIMD loop and avoids a device round-trip) — this kernel exists so
the whole sync step can also execute on-device, and serves as the oracle
cross-check for the rust implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, TILE, padded_size


def _average_kernel(stack_ref, mean_ref):
    # Mean over the worker axis; multiply by 1/n once instead of dividing.
    n = stack_ref.shape[0]
    s = jnp.sum(stack_ref[...], axis=0)
    mean_ref[...] = s * (1.0 / n)


def average(stacked, *, tile: int = TILE):
    """Mean over axis 0 of f32[n, d] -> f32[d]."""
    n, d = stacked.shape
    p = padded_size(d, tile)
    if p != d:
        stacked = jnp.pad(stacked, ((0, 0), (0, p - d)))
    out = pl.pallas_call(
        _average_kernel,
        grid=(p // tile,),
        in_specs=[pl.BlockSpec((n, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=INTERPRET,
    )(stacked)
    return out[:d]


def weighted_average(stacked, weights, *, tile: int = TILE):
    """Convex combination over axis 0: sum_k w_k * stacked[k].

    Used by the elastic-averaging ablation (DESIGN.md) and for straggler-
    weighted sync experiments; ``weights`` is f32[n] and should sum to 1.
    """
    n, d = stacked.shape
    p = padded_size(d, tile)
    if p != d:
        stacked = jnp.pad(stacked, ((0, 0), (0, p - d)))
    w = jnp.asarray(weights, jnp.float32).reshape(n, 1)

    def kernel(stack_ref, w_ref, out_ref):
        out_ref[...] = jnp.sum(stack_ref[...] * w_ref[...], axis=0)

    out = pl.pallas_call(
        kernel,
        grid=(p // tile,),
        in_specs=[
            pl.BlockSpec((n, tile), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=INTERPRET,
    )(stacked, w)
    return out[:d]
