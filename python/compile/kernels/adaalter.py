"""Pallas kernel for the (local) AdaAlter update — the paper's hot path.

One fused, single-pass, coordinate-wise kernel covers both Algorithm 3
(fully-synchronous AdaAlter) and Algorithm 4 (local AdaAlter):

    y    = x - lr * g * rsqrt(b2_base + denom_add)     # update first
    acc' = acc + gsq                                   # accumulate after

with the runtime scalars:
    denom_add = eps^2        (Alg. 3)  or  t' * eps^2  (Alg. 4, the
                              "placeholder" for yet-to-be-synced G o G)
    lr        = warmed-up learning rate eta_t

Fusion notes (DESIGN.md §Perf, L1): the naive formulation costs one sqrt and
one divide per coordinate; we use a single ``rsqrt`` and a multiply, read 5
streams and write 2, so the kernel is memory-bound (arithmetic intensity
~ 5 flops / 28 bytes).  Tiling is the 1-D VPU scheme from ``common.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import common
from .common import as_scalar_arr, auto_tile, elementwise_call, pad1


def _adaalter_kernel(x_ref, b2_ref, acc_ref, g_ref, gsq_ref,
                     denom_add_ref, lr_ref, y_ref, acc_out_ref):
    """Fused AdaAlter tile body: 5 vector refs in, 2 scalar refs, 2 out."""
    x = x_ref[...]
    g = g_ref[...]
    denom_add = denom_add_ref[0]
    lr = lr_ref[0]
    # rsqrt + mul instead of sqrt + div: one transcendental, no divide unit.
    inv = lax.rsqrt(b2_ref[...] + denom_add)
    y_ref[...] = x - lr * g * inv
    acc_out_ref[...] = acc_ref[...] + gsq_ref[...]


def adaalter_step(x, b2_base, acc, g, gsq, denom_add, lr, *, tile: int = 0):
    """Apply one AdaAlter step over a flat f32[d] state.

    Args:
      x:         f32[d] parameters.
      b2_base:   f32[d] denominator used for the update (last-synced B^2).
      acc:       f32[d] running accumulator A^2 (== b2_base for Alg. 3).
      g:         f32[d] gradient used for the update.
      gsq:       f32[d] term folded into the accumulator.
      denom_add: scalar (python float, 0-d or (1,) array) — eps^2 or t'*eps^2.
      lr:        scalar learning rate.
    Returns:
      (y, acc_out): f32[d], f32[d].
    """
    d = x.shape[0]
    tile = tile or auto_tile(d)
    call = elementwise_call(_adaalter_kernel, n_out=2, d=d, tile=tile,
                            n_vec_in=5, n_scalar_in=2)
    y, acc_out = call(pad1(x, tile), pad1(b2_base, tile), pad1(acc, tile),
                      pad1(g, tile), pad1(gsq, tile),
                      as_scalar_arr(denom_add), as_scalar_arr(lr))
    return y[:d], acc_out[:d]


def local_adaalter_step(x, b2_sync, acc, g, t_prime, eps2, lr, *,
                        tile: int = 0):
    """Algorithm 4 lines 6-7 as a single fused call.

    ``t_prime`` is the local-step index t' = mod(t-1, H) + 1; ``eps2`` the
    numerical-stability constant squared.  ``gsq`` is the local G o G, which
    we compute inline (it fuses into the same pass).
    """
    denom_add = jnp.asarray(t_prime, jnp.float32) * jnp.asarray(eps2, jnp.float32)
    return adaalter_step(x, b2_sync, acc, g, g * g, denom_add, lr, tile=tile)
