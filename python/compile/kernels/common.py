"""Shared tiling helpers for the 1-D element-wise optimizer kernels.

All optimizer state in this project is a FLAT f32[d] vector (see DESIGN.md
"Why a flat parameter vector").  Every Pallas kernel here therefore runs on a
1-D grid: each program instance streams one `TILE`-element block HBM->VMEM,
performs the fused coordinate-wise update, and streams the result back.

TPU mapping (DESIGN.md §Hardware-Adaptation): the natural VPU tile is a
multiple of 8*128 = 1024 lanes; we default to 8192 (= 8 sublane rows of 8
vregs) which keeps the VMEM footprint of the busiest kernel
(5 input tiles + 2 output tiles = 7 * 32 KiB = 224 KiB) far below the
~16 MiB VMEM budget, leaving headroom for double buffering.

On CPU we execute with ``interpret=True`` — Pallas lowers to plain HLO ops so
the rust PJRT CPU client can run the artifact (real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Base element-wise tile: multiple of the 8x128 VPU tile (f32).
TILE = 8192

# Upper bound keeping the busiest kernel's VMEM footprint (7 streams x 4 B x
# tile) within ~14 MiB of the ~16 MiB TPU budget.
MAX_TILE = 512 * 1024

# Always interpret on this image: CPU-only PJRT.  Kept as a module constant so
# a TPU build can flip it in one place.
INTERPRET = True


def auto_tile(d: int, base: int = TILE, cap: int = MAX_TILE) -> int:
    """Pick the element-wise tile for dimension ``d``.

    Perf note (EXPERIMENTS.md §Perf, L1): each grid point of an
    interpret-mode pallas_call lowers to a dynamic-slice / dynamic-update-
    slice round trip, which on CPU-PJRT costs far more than the tile's
    arithmetic — a d=117k update ran 3.8x slower with tile=8192 (15 grid
    points) than with one whole-vector tile. So: cover ``d`` with the
    fewest tiles allowed by the VMEM cap, keeping the 8192-lane alignment
    the VPU wants. Real-TPU builds would instead keep small tiles and rely
    on Mosaic's pipelined grid (see DESIGN.md §Hardware-Adaptation).
    """
    needed = padded_size(d, base)
    return min(needed, cap)


def padded_size(d: int, tile: int = TILE) -> int:
    """Smallest multiple of ``tile`` >= ``d`` (and >= ``tile``)."""
    if d <= 0:
        raise ValueError(f"parameter dimension must be positive, got {d}")
    return ((d + tile - 1) // tile) * tile


def pad1(x: jax.Array, tile: int = TILE) -> jax.Array:
    """Zero-pad a 1-D array up to a tile multiple."""
    d = x.shape[0]
    p = padded_size(d, tile)
    if p == d:
        return x
    return jnp.pad(x, (0, p - d))


def vec_spec(tile: int) -> pl.BlockSpec:
    """BlockSpec for a tiled 1-D vector operand: block i -> elements [i*tile, (i+1)*tile)."""
    return pl.BlockSpec((tile,), lambda i: (i,))


def scalar_spec() -> pl.BlockSpec:
    """BlockSpec for a (1,)-shaped runtime scalar broadcast to every grid point.

    Runtime scalars (learning rate, the t'*eps^2 placeholder) are passed as
    f32[1] inputs so one compiled executable serves every step of training.
    """
    return pl.BlockSpec((1,), lambda i: (0,))


def elementwise_call(kernel, n_out: int, d: int, tile: int, n_vec_in: int,
                     n_scalar_in: int, dtype=jnp.float32):
    """Build a pallas_call for an element-wise kernel over f32[d_padded].

    ``kernel`` receives ``n_vec_in`` vector refs, then ``n_scalar_in`` scalar
    refs, then ``n_out`` output refs (pallas convention: inputs then outputs).
    """
    p = padded_size(d, tile)
    grid = (p // tile,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec_spec(tile)] * n_vec_in + [scalar_spec()] * n_scalar_in,
        out_specs=[vec_spec(tile)] * n_out if n_out > 1 else vec_spec(tile),
        out_shape=(
            [jax.ShapeDtypeStruct((p,), dtype) for _ in range(n_out)]
            if n_out > 1
            else jax.ShapeDtypeStruct((p,), dtype)
        ),
        interpret=INTERPRET,
    )


def as_scalar_arr(v) -> jax.Array:
    """Lift a python/jnp scalar to the f32[1] runtime-scalar convention."""
    return jnp.asarray(v, dtype=jnp.float32).reshape((1,))
