"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal.

Each function here is the textbook transcription of the corresponding line(s)
of Algorithms 1, 3 and 4 of Xie et al. 2019 ("Local AdaAlter"), with no
tiling, padding or fusion tricks.  ``python/tests/test_kernels.py`` sweeps the
Pallas kernels against these with hypothesis-randomised shapes and values, and
the rust unit tests in ``rust/src/optim/`` encode the same recurrences by
hand, so all three implementations (Pallas, jnp, rust) are pinned to each
other.

Conventions (shared with the Pallas kernels and the rust coordinator):
  * all state is flat f32[d];
  * ``denom_add`` is the additive placeholder under the square root:
    eps^2 for fully-synchronous AdaAlter (Alg. 3 line 6) and t' * eps^2 for
    local AdaAlter (Alg. 4 line 6);
  * ``gsq`` is whatever the algorithm says to fold into the accumulator:
    mean_i(G_i o G_i) for Alg. 3 line 7, the local G o G for Alg. 4 line 7,
    and G_avg o G_avg for AdaGrad (Alg. 1 line 6).
"""

from __future__ import annotations

import jax.numpy as jnp


def adaalter_step_ref(x, b2_base, acc, g, gsq, denom_add, lr):
    """One AdaAlter update (Alg. 3 lines 6-7 / Alg. 4 lines 6-7).

    y   = x - lr * g / sqrt(b2_base + denom_add)        (update FIRST ...)
    acc = acc + gsq                                     (... accumulate AFTER)

    ``b2_base`` is the denominator used for the *update* (last synchronised
    B^2 in the local variant), ``acc`` the running accumulator A^2 — for the
    fully synchronous variant the caller passes the same array for both.
    Returns (y, acc_out).
    """
    x = jnp.asarray(x, jnp.float32)
    denom = jnp.sqrt(b2_base + denom_add)
    y = x - lr * g / denom
    acc_out = acc + gsq
    return y, acc_out


def adagrad_step_ref(x, b2, g, gsq, eps2, lr):
    """One distributed-AdaGrad update (Alg. 1 lines 6-7).

    AdaGrad accumulates FIRST, then updates with the fresh denominator:
    b2_out = b2 + gsq ;  y = x - lr * g / sqrt(b2_out + eps^2).
    Returns (y, b2_out).
    """
    b2_out = b2 + gsq
    y = x - lr * g / jnp.sqrt(b2_out + eps2)
    return y, b2_out


def sgd_step_ref(x, g, lr):
    """Vanilla (local) SGD step, Alg. 2 line 5:  y = x - lr * g."""
    return x - lr * g


def momentum_step_ref(x, m, g, lr, mu):
    """Heavy-ball SGD:  m_out = mu*m + g ;  y = x - lr*m_out."""
    m_out = mu * m + g
    return x - lr * m_out, m_out


def average_ref(stacked):
    """n-way synchronisation average (Alg. 4 lines 11-12): mean over axis 0."""
    return jnp.mean(jnp.asarray(stacked, jnp.float32), axis=0)


def local_adaalter_round_ref(x, b2_sync, grads, eps2, lr):
    """A full H-step local round on ONE worker (Alg. 4, no communication).

    ``grads``: [H, d] — the H local stochastic gradients.
    Returns (x_H, a2_H): the parameters and accumulator right before the
    synchronisation step.  Used to cross-check the rust worker loop.
    """
    x = jnp.asarray(x, jnp.float32)
    a2 = jnp.asarray(b2_sync, jnp.float32)
    H = grads.shape[0]
    for s in range(H):
        t_prime = s + 1  # t' = mod(t-1, H) + 1 walks 1..H within a round
        x, a2 = adaalter_step_ref(
            x, b2_sync, a2, grads[s], grads[s] * grads[s],
            t_prime * eps2, lr,
        )
    return x, a2
