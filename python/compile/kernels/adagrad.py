"""Pallas kernel for distributed AdaGrad (Algorithm 1) — the paper's baseline.

AdaGrad accumulates FIRST, then updates with the fresh denominator:

    b2' = b2 + gsq
    y   = x - lr * g * rsqrt(b2' + eps^2)

(contrast with AdaAlter, which updates with the *stale* denominator — the
one-line swap that makes lazy local updates possible).  Same flat-vector
tiling as ``adaalter.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import as_scalar_arr, auto_tile, elementwise_call, pad1


def _adagrad_kernel(x_ref, b2_ref, g_ref, gsq_ref, eps2_ref, lr_ref,
                    y_ref, b2_out_ref):
    """Fused AdaGrad tile body: accumulate-then-update."""
    b2_new = b2_ref[...] + gsq_ref[...]
    inv = lax.rsqrt(b2_new + eps2_ref[0])
    y_ref[...] = x_ref[...] - lr_ref[0] * g_ref[...] * inv
    b2_out_ref[...] = b2_new


def adagrad_step(x, b2, g, gsq, eps2, lr, *, tile: int = 0):
    """Apply one distributed-AdaGrad step over flat f32[d] state.

    In the distributed setting (Alg. 1) the caller passes the *averaged*
    gradient for both ``g`` and ``gsq = g o g`` (line 6 accumulates the
    square of the averaged gradient).  Returns (y, b2_out).
    """
    d = x.shape[0]
    tile = tile or auto_tile(d)
    call = elementwise_call(_adagrad_kernel, n_out=2, d=d, tile=tile,
                            n_vec_in=4, n_scalar_in=2)
    y, b2_out = call(pad1(x, tile), pad1(b2, tile), pad1(g, tile),
                     pad1(gsq, tile), as_scalar_arr(eps2), as_scalar_arr(lr))
    return y[:d], b2_out[:d]
