"""L2 — decoder-only transformer language model over a FLAT parameter vector.

The paper trains LSTM-2048-512 ("Big LSTM") on the 1B Word Benchmark; the
optimizer protocol under study is architecture-agnostic (it is coordinate-wise
over the flat parameter vector), so we substitute a decoder-only transformer
LM of configurable size (DESIGN.md §3).  Everything below is build-time JAX:
``aot.py`` lowers these functions once to HLO text, and the rust coordinator
executes the artifacts via PJRT — Python never runs on the training path.

Flat-vector contract: every function takes ``flat: f32[d]`` and unflattens it
inside the traced graph (XLA fuses the slices/reshapes away), so the rust
side only ever handles contiguous f32 buffers for parameters, gradients and
optimizer state — exactly the shape the paper's coordinate-wise algorithms
want.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters of the transformer LM.

    ``seq`` is the training context length; batches are i32[batch, seq+1]
    token panels (inputs = [:, :-1], targets = [:, 1:]).
    """

    vocab: int = 256
    dim: int = 64
    layers: int = 2
    heads: int = 2
    seq: int = 32
    mlp_mult: int = 4
    tie_embeddings: bool = True

    def __post_init__(self):
        if self.dim % self.heads != 0:
            raise ValueError(
                f"dim {self.dim} not divisible by heads {self.heads}")

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads


# ---------------------------------------------------------------------------
# Parameter spec / flatten / unflatten
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat-vector layout.

    The order is load-bearing: the rust manifest records (name, shape,
    offset) so tools can slice individual tensors out of checkpoints.
    """
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab, cfg.dim)),
        ("pos_emb", (cfg.seq, cfg.dim)),
    ]
    for l in range(cfg.layers):
        m = cfg.mlp_mult * cfg.dim
        spec += [
            (f"l{l}.ln1", (cfg.dim,)),
            (f"l{l}.wqkv", (cfg.dim, 3 * cfg.dim)),
            (f"l{l}.wo", (cfg.dim, cfg.dim)),
            (f"l{l}.ln2", (cfg.dim,)),
            (f"l{l}.w1", (cfg.dim, m)),
            (f"l{l}.w2", (m, cfg.dim)),
        ]
    spec.append(("lnf", (cfg.dim,)))
    if not cfg.tie_embeddings:
        spec.append(("head", (cfg.dim, cfg.vocab)))
    return spec


def num_params(cfg: ModelConfig) -> int:
    """Total flat dimension d."""
    return sum(math.prod(s) for _, s in spec_shapes(cfg))


def spec_shapes(cfg: ModelConfig):
    return param_spec(cfg)


def param_offsets(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], int]]:
    """(name, shape, offset) triples — serialised into the manifest."""
    out, off = [], 0
    for name, shape in param_spec(cfg):
        out.append((name, shape, off))
        off += math.prod(shape)
    return out


def unflatten(cfg: ModelConfig, flat: jax.Array) -> Dict[str, jax.Array]:
    """Slice the flat vector into named tensors (inside the traced graph)."""
    params: Dict[str, jax.Array] = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = math.prod(shape)
        params[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        off += n
    return params


def flatten(cfg: ModelConfig, params: Dict[str, jax.Array]) -> jax.Array:
    """Inverse of :func:`unflatten` (used by tests and init)."""
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_spec(cfg)])


def init_params(cfg: ModelConfig, key: jax.Array) -> jax.Array:
    """Standard transformer init, returned as the flat vector.

    Embeddings/projections ~ N(0, 0.02); output projections of each block
    scaled by 1/sqrt(2*layers) (GPT-2 style); norms = 1.
    """
    params: Dict[str, jax.Array] = {}
    resid_scale = 0.02 / math.sqrt(2 * cfg.layers)
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".ln1", ".ln2")) or name == "lnf":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".wo", ".w2")):
            params[name] = resid_scale * jax.random.normal(sub, shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return flatten(cfg, params)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _attention(cfg: ModelConfig, p: Dict[str, jax.Array], l: int,
               x: jax.Array) -> jax.Array:
    """Causal multi-head self-attention for layer ``l``.  x: [B, S, D]."""
    B, S, D = x.shape
    h, hd = cfg.heads, cfg.head_dim
    qkv = x @ p[f"l{l}.wqkv"]                       # [B, S, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, h, hd).transpose(0, 2, 1, 3)  # [B, h, S, hd]
    k = k.reshape(B, S, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, h, hd).transpose(0, 2, 1, 3)
    scores = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)  # [B, h, S, S]
    mask = jnp.tril(jnp.ones((S, S), jnp.bool_))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ v).transpose(0, 2, 1, 3).reshape(B, S, D)
    return out @ p[f"l{l}.wo"]


def _block(cfg: ModelConfig, p: Dict[str, jax.Array], l: int,
           x: jax.Array) -> jax.Array:
    x = x + _attention(cfg, p, l, _rms_norm(x, p[f"l{l}.ln1"]))
    hmid = _rms_norm(x, p[f"l{l}.ln2"]) @ p[f"l{l}.w1"]
    x = x + jax.nn.gelu(hmid) @ p[f"l{l}.w2"]
    return x


def forward(cfg: ModelConfig, flat: jax.Array, inputs: jax.Array) -> jax.Array:
    """Logits for token inputs i32[B, S] -> f32[B, S, V]."""
    p = unflatten(cfg, flat)
    x = p["tok_emb"][inputs] + p["pos_emb"][None, : inputs.shape[1], :]
    for l in range(cfg.layers):
        x = _block(cfg, p, l, x)
    x = _rms_norm(x, p["lnf"])
    head = p["tok_emb"].T if cfg.tie_embeddings else p["head"]
    return x @ head


# ---------------------------------------------------------------------------
# Loss / grad / eval — the functions aot.py lowers
# ---------------------------------------------------------------------------

def _token_nll(cfg: ModelConfig, flat: jax.Array,
               tokens: jax.Array) -> jax.Array:
    """Per-token negative log-likelihood, f32[B, S]."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat, inputs)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1)[..., 0]
    return logz - tgt_logit


def loss_fn(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array) -> jax.Array:
    """Mean cross-entropy over the B*S predicted tokens (scalar f32)."""
    return jnp.mean(_token_nll(cfg, flat, tokens))


def loss_and_grad(cfg: ModelConfig, flat: jax.Array,
                  tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(loss, grad[d]) — the ``train_step`` artifact body."""
    return jax.value_and_grad(lambda f: loss_fn(cfg, f, tokens))(flat)


def eval_nll(cfg: ModelConfig, flat: jax.Array,
             tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(sum_nll, token_count) — rust accumulates these across eval batches
    and reports PPL = exp(sum_nll / count), the paper's §6.2 metric."""
    nll = _token_nll(cfg, flat, tokens)
    return jnp.sum(nll), jnp.asarray(nll.size, jnp.float32)
