"""Model/workload presets shared by aot.py, the tests and the rust manifest.

The paper's workload is LSTM-2048-512 (~1B params) on the 1B Word Benchmark;
single-CPU-core reproduction scales the model down but keeps every protocol
constant (eps=1, b0=1, eta=0.5, warm-up 600, H in {4,8,12,16}) — DESIGN.md §3.

  tiny      — unit/integration tests and the convergence benches: steps are
              a few ms so 5-seed sweeps finish in minutes.
  small     — the end-to-end example (examples/train_lm.rs): ~0.9M params,
              a few hundred steps on a synthetic corpus.
  base100m  — paper-scale-shaped config (~110M params).  Lowering and
              loading it is exercised; *training* it for hundreds of steps
              is not practical on one CPU core (documented in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from .model import ModelConfig


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    model: ModelConfig
    batch: int          # per-worker batch size
    eval_batch: int


PRESETS: Dict[str, Preset] = {
    "tiny": Preset(
        name="tiny",
        model=ModelConfig(vocab=256, dim=64, layers=2, heads=2, seq=32),
        batch=4,
        eval_batch=8,
    ),
    "small": Preset(
        name="small",
        model=ModelConfig(vocab=2048, dim=128, layers=3, heads=4, seq=64),
        batch=4,
        eval_batch=8,
    ),
    "base100m": Preset(
        name="base100m",
        model=ModelConfig(vocab=32000, dim=768, layers=12, heads=12, seq=128),
        batch=1,
        eval_batch=1,
    ),
}

DEFAULT_PRESETS = ("tiny", "small")
