"""L2 — optimizer-step compute graphs, built on the L1 Pallas kernels.

These are the jax functions ``aot.py`` lowers into the per-preset optimizer
artifacts the rust coordinator calls on its hot path.  Each wraps a kernel
from ``compile.kernels`` so the Pallas body lowers into the same HLO module
(interpret=True -> plain HLO ops the CPU PJRT client can run).

``fused_local_step`` is the perf-pass artifact (EXPERIMENTS.md §Perf): during
the H-1 communication-free local iterations of Algorithm 4, the fwd/bwd and
the AdaAlter update need no rust-side interleaving, so we fuse them into a
single executable — one PJRT dispatch per local step instead of two, and the
gradient never leaves the device buffer.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import model as model_lib
from .kernels import adaalter as k_adaalter
from .kernels import adagrad as k_adagrad
from .kernels import sgd as k_sgd


def adaalter_step(x, b2_base, acc, g, gsq, denom_add, lr):
    """(Local) AdaAlter update — Alg. 3/4 lines 6-7.  Scalars are f32[1]."""
    return k_adaalter.adaalter_step(
        x, b2_base, acc, g, gsq, denom_add[0], lr[0])


def adagrad_step(x, b2, g, gsq, eps2, lr):
    """Distributed AdaGrad update — Alg. 1 lines 6-7."""
    return k_adagrad.adagrad_step(x, b2, g, gsq, eps2[0], lr[0])


def sgd_step(x, g, lr):
    """Local SGD update — Alg. 2 line 5."""
    return k_sgd.sgd_step(x, g, lr[0])


def momentum_step(x, m, g, lr, mu):
    """Heavy-ball baseline."""
    return k_sgd.momentum_step(x, m, g, lr[0], mu[0])


def fused_local_step(cfg: model_lib.ModelConfig, flat, b2_sync, acc, tokens,
                     denom_add, lr) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One full communication-free local iteration of Algorithm 4.

    fwd/bwd on ``tokens`` then the AdaAlter local update, in one graph:

        G      = grad F(x; tokens)
        y      = x - lr * G / sqrt(b2_sync + denom_add)   # denom_add = t'*eps^2
        acc'   = acc + G o G

    Returns (y, acc', loss).
    """
    loss, g = model_lib.loss_and_grad(cfg, flat, tokens)
    y, acc_out = k_adaalter.adaalter_step(
        flat, b2_sync, acc, g, g * g, denom_add[0], lr[0])
    return y, acc_out, loss


def fused_local_sgd_step(cfg: model_lib.ModelConfig, flat, tokens, lr):
    """One communication-free local iteration of vanilla local SGD (Alg. 2)."""
    loss, g = model_lib.loss_and_grad(cfg, flat, tokens)
    return k_sgd.sgd_step(flat, g, lr[0]), loss
