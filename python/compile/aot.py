"""AOT compile path: lower every L2 graph to HLO TEXT + a JSON manifest.

Run once by ``make artifacts``; the rust coordinator then only touches
``artifacts/``.  Interchange is HLO *text*, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per preset we emit:
  {p}_train_step          (flat, tokens)                          -> (loss, grad)
  {p}_local_step_adaalter (flat, b2, acc, tokens, denom_add, lr)  -> (y, acc', loss)
  {p}_local_step_sgd      (flat, tokens, lr)                      -> (y, loss)
  {p}_eval_step           (flat, tokens)                          -> (sum_nll, count)
  {p}_opt_adaalter        (x, b2, acc, g, gsq, denom_add, lr)     -> (y, acc')
  {p}_opt_adagrad         (x, b2, g, gsq, eps2, lr)               -> (y, b2')
  {p}_opt_sgd             (x, g, lr)                              -> (y,)
  {p}_init.f32bin         initial parameters (little-endian f32 raw)
plus ``manifest.json`` describing shapes/dtypes/offsets for the rust loader.

Usage:  python -m compile.aot --out-dir ../artifacts [--presets tiny,small]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import optim
from .presets import DEFAULT_PRESETS, PRESETS, Preset

MANIFEST_VERSION = 2
INIT_SEED = 20191121  # arXiv submission date of the paper; fixed for repro.


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape: Sequence[int], dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io_entry(avals) -> List[dict]:
    return [
        {"shape": [int(s) for s in a.shape], "dtype": str(a.dtype)}
        for a in avals
    ]


def lower_one(name: str, fn: Callable, in_avals: Sequence[jax.ShapeDtypeStruct],
              out_dir: str) -> dict:
    """Lower ``fn`` at the given avals, write ``{name}.hlo.txt``, return the
    manifest entry (file, input/output shapes, HLO size)."""
    t0 = time.time()
    lowered = jax.jit(fn).lower(*in_avals)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(fn, *in_avals)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    dt = time.time() - t0
    print(f"  {fname:44s} {len(text)/1024:9.1f} KiB  ({dt:.1f}s)")
    return {
        "file": fname,
        "inputs": _io_entry(in_avals),
        "outputs": _io_entry(out_avals),
    }


def build_preset(preset: Preset, out_dir: str) -> dict:
    """Lower all artifacts for one preset; return its manifest subtree."""
    cfg = preset.model
    d = model_lib.num_params(cfg)
    B, S = preset.batch, cfg.seq
    print(f"preset {preset.name}: d={d} ({d/1e6:.2f}M params), "
          f"batch={B}, seq={S}, vocab={cfg.vocab}")

    vec = _sds((d,))
    sc = _sds((1,))
    tokens = _sds((B, S + 1), jnp.int32)
    eval_tokens = _sds((preset.eval_batch, S + 1), jnp.int32)

    arts = {}
    p = preset.name
    arts["train_step"] = lower_one(
        f"{p}_train_step",
        lambda f, t: model_lib.loss_and_grad(cfg, f, t),
        [vec, tokens], out_dir)
    arts["local_step_adaalter"] = lower_one(
        f"{p}_local_step_adaalter",
        lambda f, b2, acc, t, da, lr: optim.fused_local_step(
            cfg, f, b2, acc, t, da, lr),
        [vec, vec, vec, tokens, sc, sc], out_dir)
    arts["local_step_sgd"] = lower_one(
        f"{p}_local_step_sgd",
        lambda f, t, lr: optim.fused_local_sgd_step(cfg, f, t, lr),
        [vec, tokens, sc], out_dir)
    arts["eval_step"] = lower_one(
        f"{p}_eval_step",
        lambda f, t: model_lib.eval_nll(cfg, f, t),
        [vec, eval_tokens], out_dir)
    arts["opt_adaalter"] = lower_one(
        f"{p}_opt_adaalter", optim.adaalter_step,
        [vec, vec, vec, vec, vec, sc, sc], out_dir)
    arts["opt_adagrad"] = lower_one(
        f"{p}_opt_adagrad", optim.adagrad_step,
        [vec, vec, vec, vec, sc, sc], out_dir)
    arts["opt_sgd"] = lower_one(
        f"{p}_opt_sgd", optim.sgd_step, [vec, vec, sc], out_dir)

    # Initial parameters: raw little-endian f32, loaded with a single read.
    init = model_lib.init_params(cfg, jax.random.PRNGKey(INIT_SEED))
    init_file = f"{p}_init.f32bin"
    np.asarray(init, dtype="<f4").tofile(os.path.join(out_dir, init_file))
    print(f"  {init_file:44s} {d * 4 / 1024:9.1f} KiB")

    return {
        "config": dataclasses.asdict(cfg),
        "d": d,
        "batch": B,
        "eval_batch": preset.eval_batch,
        "seq": S,
        "vocab": cfg.vocab,
        "init_params": init_file,
        "param_spec": [
            {"name": n, "shape": list(s), "offset": o, "size": math.prod(s)}
            for n, s, o in model_lib.param_offsets(cfg)
        ],
        "artifacts": arts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default=",".join(DEFAULT_PRESETS),
                    help="comma-separated preset names "
                         f"(available: {', '.join(PRESETS)})")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = [n.strip() for n in args.presets.split(",") if n.strip()]
    manifest = {
        "version": MANIFEST_VERSION,
        "init_seed": INIT_SEED,
        "presets": {},
    }
    t0 = time.time()
    for name in names:
        if name not in PRESETS:
            raise SystemExit(f"unknown preset {name!r}; "
                             f"available: {', '.join(PRESETS)}")
        manifest["presets"][name] = build_preset(PRESETS[name], args.out_dir)

    # Merge with a pre-existing manifest so `--presets base100m` extends
    # rather than clobbers the default artifact set.
    mpath = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        if old.get("version") == MANIFEST_VERSION:
            merged = dict(old.get("presets", {}))
            merged.update(manifest["presets"])
            manifest["presets"] = merged
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({time.time() - t0:.1f}s total)")


if __name__ == "__main__":
    main()
