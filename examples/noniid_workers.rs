//! Non-IID robustness study (extension beyond the paper's figures): how the
//! data-heterogeneity knob affects Local AdaAlter at different H.
//!
//! The paper's theory (Thm 2) covers non-IID workers but the evaluation
//! uses a shared corpus; this example measures the interaction the theory
//! predicts: more heterogeneity ⇒ local replicas drift faster ⇒ larger H
//! pays a bigger accuracy price.
//!
//! ```bash
//! cargo run --release --example noniid_workers
//! ```

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, Trainer};
use adaalter::sim::SyntheticProblem;
use adaalter::util::csv::CsvWriter;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dim = 2048;
    let workers = 8;
    let steps = 1200;

    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create(
        "results/noniid_sweep.csv",
        &["skew", "H", "final_suboptimality"],
    )?;

    println!("non-IID skew × H — final suboptimality (synthetic, 8 workers, {steps} steps)");
    println!("{:>6} {:>6} {:>16}", "skew", "H", "suboptimality");
    for &skew in &[0.0f32, 0.5, 1.0, 2.0] {
        for &h in &[1u64, 4, 16, 64] {
            let mut cfg = ExperimentConfig::default();
            cfg.train.workers = workers;
            cfg.train.steps = steps;
            cfg.train.sync_period = SyncPeriod::Every(h);
            cfg.train.backend = Backend::RustMath;
            cfg.train.rust_math_dim = dim;
            cfg.train.log_every = steps;
            cfg.optim.algorithm = Algorithm::LocalAdaAlter;
            cfg.optim.warmup_steps = 50;

            let mut problem = SyntheticProblem::new(dim, workers, cfg.train.seed);
            problem.skew = skew;
            let opt_loss = problem.global_loss(&problem.optimum());
            let p = problem.clone();
            let factory: BackendFactory =
                Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));

            let r = Trainer::new(cfg, factory).run()?;
            let subopt = r.final_eval.unwrap().loss - opt_loss;
            println!("{skew:>6.1} {h:>6} {subopt:>16.6}");
            csv.row(&[skew.to_string(), h.to_string(), format!("{subopt:.6}")])?;
        }
    }
    csv.flush()?;
    println!("wrote results/noniid_sweep.csv");
    println!("\nreading: suboptimality should grow with H, and faster at high skew —");
    println!("the Thm 2 noise term 4η²L²H² scales with the replica-drift magnitude.");
    Ok(())
}
