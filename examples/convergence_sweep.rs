//! Convergence sweep — the Fig. 3(a)/(b) reproduction on the real LM:
//! test PPL vs (virtual) time and vs epochs, for AdaGrad, AdaAlter and
//! Local AdaAlter with several H.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example convergence_sweep            # tiny preset
//! ADAALTER_STEPS=400 ADAALTER_WORKERS=4 \
//!   cargo run --release --example convergence_sweep
//! ```
//!
//! Writes one CSV row per (algorithm, eval point); plotting
//! `ppl` against `virtual_hours` reproduces Fig. 3(a), against `epoch`
//! Fig. 3(b).

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::factory::make_factory;
use adaalter::coordinator::Trainer;
use adaalter::util::csv::CsvWriter;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: u64 = env_or("ADAALTER_STEPS", 200);
    let workers: usize = env_or("ADAALTER_WORKERS", 2);
    let preset: String = env_or("ADAALTER_PRESET", "tiny".to_string());

    let variants: Vec<(Algorithm, SyncPeriod, &str)> = vec![
        (Algorithm::AdaGrad, SyncPeriod::Every(1), "AdaGrad"),
        (Algorithm::AdaAlter, SyncPeriod::Every(1), "AdaAlter"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(4), "Local AdaAlter, H=4"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(8), "Local AdaAlter, H=8"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(16), "Local AdaAlter, H=16"),
    ];

    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create(
        "results/fig3_convergence.csv",
        &["algorithm", "step", "epoch", "virtual_hours", "eval_loss", "test_ppl"],
    )?;

    println!("Fig 3 — test PPL vs time/epochs ({preset} preset, {workers} workers, {steps} steps)");
    for (algo, h, label) in &variants {
        let mut cfg = ExperimentConfig::default();
        cfg.train.preset = preset.clone();
        cfg.train.backend = Backend::Pjrt;
        cfg.train.workers = workers;
        cfg.train.steps = steps;
        cfg.train.steps_per_epoch = (steps / 4).max(1);
        cfg.train.sync_period = *h;
        cfg.train.eval_every = (steps / 8).max(1);
        cfg.train.log_every = steps; // quiet
        cfg.optim.algorithm = *algo;
        cfg.optim.warmup_steps = steps / 5;
        cfg.data.eval_batches = 3;

        let factory = make_factory(&cfg)?;
        let r = Trainer::new(cfg, factory).run()?;
        let last = r.recorder.evals.last().unwrap();
        println!(
            "  {label:<24} final PPL {:>8.3}  virtual {:>7.2} h",
            last.ppl.unwrap(),
            last.virtual_s / 3600.0
        );
        for e in &r.recorder.evals {
            csv.row(&[
                label.to_string(),
                e.step.to_string(),
                format!("{:.3}", e.epoch),
                format!("{:.5}", e.virtual_s / 3600.0),
                format!("{:.5}", e.loss),
                format!("{:.4}", e.ppl.unwrap_or(f64::NAN)),
            ])?;
        }
    }
    csv.flush()?;
    println!("wrote results/fig3_convergence.csv");
    Ok(())
}
