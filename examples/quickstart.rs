//! Quickstart: train with Local AdaAlter on the built-in synthetic non-IID
//! workload — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the public API in ~30 lines: build a config, point the trainer at
//! a gradient backend, run, read the curves.

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, Trainer};
use adaalter::sim::{Charge, SyntheticProblem};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Configure: 8 workers, Local AdaAlter, synchronize every H = 4
    //    steps — the paper's default setting (ε = 1, b₀ = 1, η = 0.5).
    let mut cfg = ExperimentConfig::default();
    cfg.train.workers = 8;
    cfg.train.steps = 800;
    cfg.train.sync_period = SyncPeriod::Every(4);
    cfg.train.backend = Backend::RustMath;
    cfg.train.rust_math_dim = 8192;
    cfg.train.log_every = 100;
    cfg.optim.algorithm = Algorithm::LocalAdaAlter;
    cfg.optim.warmup_steps = 50;

    // 2. A gradient backend per worker: here the built-in ill-conditioned
    //    non-IID least-squares problem (each worker has its own D_i).
    let problem = SyntheticProblem::new(cfg.train.rust_math_dim, cfg.train.workers, cfg.train.seed);
    let optimum = problem.global_loss(&problem.optimum());
    let factory: BackendFactory = Arc::new(move |w| Ok(Box::new(problem.backend(w)) as Box<_>));

    // 3. Train.
    let result = Trainer::new(cfg, factory).run()?;

    // 4. Read the results.
    println!("step   epoch   train-loss");
    for p in &result.recorder.steps {
        println!("{:>5}  {:>6.2}  {:>10.4}", p.step, p.epoch, p.train_loss);
    }
    let final_loss = result.final_eval.unwrap().loss;
    let (syncs, bytes) = result.recorder.comm();
    println!("\nfinal global loss {final_loss:.4} (irreducible optimum {optimum:.4})");
    println!(
        "virtual time {:.1}s  = compute {:.1}s + comm {:.1}s + dataload {:.1}s",
        result.clock.now_s(),
        result.clock.total(Charge::Compute),
        result.clock.total(Charge::Communication),
        result.clock.total(Charge::DataLoad),
    );
    println!(
        "{syncs} sync rounds ({:.1} MiB total) — 2/H = 50% of fully-sync traffic",
        bytes as f64 / (1 << 20) as f64
    );
    Ok(())
}
