//! Communication sweep — regenerates the Fig. 1 / Fig. 2 series from the
//! paper-calibrated cluster model and writes them as CSV.
//!
//! ```bash
//! cargo run --release --example comm_sweep
//! ```
//!
//! Also demonstrates the model beyond the paper: ring-allreduce topology
//! and a commodity-Ethernet calibration, to show where the crossovers move.

use adaalter::comm::netmodel::Topology;
use adaalter::config::SyncPeriod::{Every, Infinite};
use adaalter::sim::{EpochModel, SimAlgo};
use adaalter::util::csv::CsvWriter;

fn algos() -> Vec<SimAlgo> {
    vec![
        SimAlgo::AdaGrad,
        SimAlgo::AdaAlter,
        SimAlgo::LocalAdaAlter(Every(4)),
        SimAlgo::LocalAdaAlter(Every(8)),
        SimAlgo::LocalAdaAlter(Every(12)),
        SimAlgo::LocalAdaAlter(Every(16)),
        SimAlgo::LocalAdaAlter(Infinite),
        SimAlgo::IdealComputeOnly,
    ]
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let m = EpochModel::paper();
    let ns = [1usize, 2, 4, 8];

    std::fs::create_dir_all("results")?;
    let mut f1 = CsvWriter::create(
        "results/fig1_epoch_time.csv",
        &["algorithm", "workers", "epoch_seconds", "compute_s", "dataload_s", "comm_s"],
    )?;
    let mut f2 = CsvWriter::create(
        "results/fig2_throughput.csv",
        &["algorithm", "workers", "samples_per_second"],
    )?;

    println!("Fig 1 — time of an epoch (s) vs workers (paper-calibrated V100 PS)");
    println!("{:<34} {:>9} {:>9} {:>9} {:>9}", "algorithm", "n=1", "n=2", "n=4", "n=8");
    for a in algos() {
        let mut row = format!("{:<34}", a.label());
        for &n in &ns {
            let c = m.iter_cost(a, n);
            let iters = m.iters_per_epoch(n);
            row += &format!(" {:>9.0}", iters * c.total_s());
            f1.row(&[
                a.label(),
                n.to_string(),
                format!("{:.1}", iters * c.total_s()),
                format!("{:.1}", iters * c.compute_s),
                format!("{:.1}", iters * c.dataload_extra_s),
                format!("{:.1}", iters * c.comm_s),
            ])?;
        }
        println!("{row}");
    }

    println!("\nFig 2 — throughput (samples/s) vs workers");
    println!("{:<34} {:>9} {:>9} {:>9} {:>9}", "algorithm", "n=1", "n=2", "n=4", "n=8");
    for a in algos() {
        let mut row = format!("{:<34}", a.label());
        for &n in &ns {
            let tp = m.throughput(a, n);
            row += &format!(" {:>9.0}", tp);
            f2.row(&[a.label(), n.to_string(), format!("{tp:.0}")])?;
        }
        println!("{row}");
    }
    f1.flush()?;
    f2.flush()?;

    // Beyond the paper: what if the cluster used ring all-reduce, or a
    // 25 GbE fabric? (DESIGN.md ablation.)
    let mut ethernet = EpochModel::paper();
    ethernet.calib.net.topology = Topology::RingAllReduce;
    ethernet.calib.net.beta_bytes_per_s = 25e9 / 8.0;
    ethernet.calib.overlap = 0.5;
    ethernet.calib.periodic_overlap = 0.5;
    println!("\nAblation — 25 GbE ring all-reduce (epoch s, n=8):");
    for a in [
        SimAlgo::AdaGrad,
        SimAlgo::LocalAdaAlter(Every(4)),
        SimAlgo::LocalAdaAlter(Every(16)),
    ] {
        println!("  {:<32} {:>10.0}", a.label(), ethernet.epoch_time_s(a, 8));
    }
    let sync = ethernet.epoch_time_s(SimAlgo::AdaGrad, 8);
    let h4 = ethernet.epoch_time_s(SimAlgo::LocalAdaAlter(Every(4)), 8);
    println!(
        "  → on slow fabric the H=4 saving grows to {:.0}% (vs ~30% on NVLink)",
        100.0 * (1.0 - h4 / sync)
    );

    println!("\nwrote results/fig1_epoch_time.csv, results/fig2_throughput.csv");
    Ok(())
}
