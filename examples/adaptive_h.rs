//! Adaptive synchronization periods in action: run the same Local
//! AdaAlter workload under each `[sync]` policy and print the realized-H
//! trajectory — the per-round gaps and trigger reasons the recorder logs
//! (DESIGN.md §5).
//!
//! ```bash
//! cargo run --release --example adaptive_h
//! ```

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, Trainer};
use adaalter::sim::{Charge, SyntheticProblem};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let (workers, dim, steps) = (8usize, 1024usize, 400u64);
    let problem = SyntheticProblem::new(dim, workers, 42);
    let optimum = problem.global_loss(&problem.optimum());

    let policies: [(&str, fn(&mut ExperimentConfig)); 4] = [
        ("fixed", |_| {}),
        ("growing", |c| {
            c.sync.policy = "growing".into();
            c.sync.grow_every = 2;
            c.sync.h_max = 16;
        }),
        ("drift", |c| {
            c.sync.policy = "drift".into();
            c.sync.drift_threshold = 2.0;
            c.sync.h_max = 16;
        }),
        ("time_budget", |c| {
            c.sync.policy = "time_budget".into();
            c.sync.target_comm_fraction = 0.02;
        }),
    ];

    for (name, tweak) in policies {
        let mut cfg = ExperimentConfig::default();
        cfg.train.workers = workers;
        cfg.train.steps = steps;
        cfg.train.sync_period = SyncPeriod::Every(4);
        cfg.train.backend = Backend::RustMath;
        cfg.train.rust_math_dim = dim;
        cfg.train.log_every = steps;
        cfg.optim.algorithm = Algorithm::LocalAdaAlter;
        cfg.optim.warmup_steps = 50;
        tweak(&mut cfg);

        let p = problem.clone();
        let factory: BackendFactory = Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));
        let r = Trainer::new(cfg, factory).run()?;

        let (rounds, bytes) = r.recorder.comm();
        println!("== {name:<12} → {}", r.recorder.sync_policy());
        println!(
            "   {rounds} rounds, {:.1} MiB, comm {:.2}s of {:.1}s virtual, \
             final suboptimality {:.4}",
            bytes as f64 / (1 << 20) as f64,
            r.clock.total(Charge::Communication),
            r.clock.now_s(),
            r.final_eval.unwrap().loss - optimum,
        );
        // The realized-H trajectory: one (gap, reason) per executed round.
        let trail: Vec<String> = r
            .recorder
            .sync_events
            .iter()
            .map(|e| format!("{}@{}", e.gap, e.reason))
            .collect();
        // Compress long trajectories: first 10, ellipsis, last 4.
        if trail.len() > 16 {
            println!(
                "   H trail: {} … {} ({} rounds)",
                trail[..10].join(" "),
                trail[trail.len() - 4..].join(" "),
                trail.len()
            );
        } else {
            println!("   H trail: {}", trail.join(" "));
        }
        println!();
    }
    println!("(gap@reason — \"period\" is a scheduled boundary, \"drift\" an");
    println!(" exceeded drift threshold, \"h_max\" the hard cap, \"budget\" a");
    println!(" time-budget boundary; the fixed policy's gaps are all H)");
    Ok(())
}
