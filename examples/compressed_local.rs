//! Compressed local AdaAlter — the scenario family the collective layer
//! opens: the paper's skip-rounds scheme (2/H) *stacked* with the §1
//! compression baselines (QSGD / top-k), all selected by config.
//!
//! ```bash
//! cargo run --release --example compressed_local
//! ```
//!
//! Every run below is the same algorithm, data and seed; only the `[comm]`
//! and `[net]` sections differ. Bytes are what the configured collective
//! actually billed: model-scale α–β traffic for the simulated transports,
//! exact encoded wire sizes for the compressed ones.

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, Trainer};
use adaalter::sim::SyntheticProblem;

const D: usize = 4096;
const N: usize = 4;
const STEPS: u64 = 400;

fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.train.workers = N;
    c.train.steps = STEPS;
    c.train.sync_period = SyncPeriod::Every(4);
    c.train.backend = Backend::RustMath;
    c.train.rust_math_dim = D;
    c.train.seed = 9;
    c.optim.algorithm = Algorithm::LocalAdaAlter;
    c.optim.warmup_steps = 40;
    c
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let problem = SyntheticProblem::new(D, N, 9);
    let opt_loss = problem.global_loss(&problem.optimum());

    let variants: Vec<(&str, ExperimentConfig)> = vec![
        ("PS dense (paper's setting)", cfg()),
        ("ring all-reduce dense", {
            let mut c = cfg();
            c.net.topology = "allreduce".into();
            c
        }),
        ("QSGD s=15 wire", {
            let mut c = cfg();
            c.comm.transport = "channel".into();
            c.comm.compression = "qsgd".into();
            c.comm.qsgd_levels = 15;
            c
        }),
        ("top-k 5% wire", {
            let mut c = cfg();
            c.comm.transport = "channel".into();
            c.comm.compression = "topk".into();
            c.comm.topk_keep = 0.05;
            c
        }),
    ];

    println!("Local AdaAlter H=4, n={N}, d={D}, {STEPS} steps — transport sweep\n");
    println!(
        "{:<28} {:<22} {:>8} {:>14} {:>14}",
        "variant", "transport", "rounds", "total bytes", "final subopt"
    );
    for (name, c) in variants {
        let p = problem.clone();
        let f: BackendFactory = Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));
        let r = Trainer::new(c, f).run()?;
        let (rounds, bytes) = r.recorder.comm();
        let subopt = r.final_eval.expect("eval").loss - opt_loss;
        println!(
            "{:<28} {:<22} {:>8} {:>14} {:>14.4}",
            name,
            r.recorder.transport(),
            rounds,
            bytes,
            subopt
        );
    }
    println!(
        "\nThe 2/H round reduction and the per-round byte compression are \
         orthogonal: stacking them is one [comm] section away."
    );
    Ok(())
}
