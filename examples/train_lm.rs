//! End-to-end driver: train the real transformer LM through the full
//! three-layer stack (rust coordinator → PJRT → AOT-lowered JAX/Pallas
//! graphs) on the synthetic non-IID corpus, and log the loss/PPL curves.
//!
//! This is the EXPERIMENTS.md §End-to-end run:
//!
//! ```bash
//! make artifacts                      # once (lowers tiny + small presets)
//! cargo run --release --example train_lm                 # small preset
//! ADAALTER_PRESET=tiny ADAALTER_STEPS=100 \
//!   cargo run --release --example train_lm               # quick variant
//! ```
//!
//! Defaults: `small` preset (~0.9M params), 8 workers, Local AdaAlter,
//! H = 4, 300 steps, warm-up 60 — a scaled-down §6.2 configuration.

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::factory::make_factory;
use adaalter::coordinator::Trainer;
use adaalter::sim::Charge;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let preset: String = env_or("ADAALTER_PRESET", "small".to_string());
    let steps: u64 = env_or("ADAALTER_STEPS", 300);
    let workers: usize = env_or("ADAALTER_WORKERS", 8);
    let h: u64 = env_or("ADAALTER_H", 4);

    let mut cfg = ExperimentConfig::default();
    cfg.train.preset = preset.clone();
    cfg.train.backend = Backend::Pjrt;
    cfg.train.workers = workers;
    cfg.train.steps = steps;
    cfg.train.steps_per_epoch = (steps / 3).max(1); // 3 reporting epochs
    cfg.train.sync_period = SyncPeriod::Every(h);
    cfg.train.log_every = (steps / 30).max(1);
    cfg.train.eval_every = (steps / 6).max(1);
    cfg.optim.algorithm = Algorithm::LocalAdaAlter;
    cfg.optim.warmup_steps = steps / 5;
    cfg.data.eval_batches = 4;

    println!(
        "== end-to-end: preset={preset} d-workers={workers} H={h} steps={steps} \
         (η=0.5, ε=1, b₀=1, warm-up {}) ==",
        cfg.optim.warmup_steps
    );

    let factory = make_factory(&cfg)?;
    let t0 = std::time::Instant::now();
    let result = Trainer::new(cfg.clone(), factory).run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nstep    epoch   train-loss      lr    virtual-s");
    for p in &result.recorder.steps {
        println!(
            "{:>5}  {:>6.2}  {:>10.4}  {:>7.4}  {:>9.1}",
            p.step, p.epoch, p.train_loss, p.lr, p.virtual_s
        );
    }
    println!("\nstep    epoch   eval-loss   test-PPL");
    for e in &result.recorder.evals {
        println!(
            "{:>5}  {:>6.2}  {:>9.4}  {:>9.3}",
            e.step,
            e.epoch,
            e.loss,
            e.ppl.unwrap_or(f64::NAN)
        );
    }

    let ev = result.final_eval.unwrap();
    let (syncs, bytes) = result.recorder.comm();
    println!("\n== summary ==");
    println!("final test PPL       {:.3}", ev.ppl.unwrap());
    println!("final eval loss      {:.4}", ev.loss);
    println!(
        "virtual time         {:.1}s (compute {:.1} / comm {:.1} / dataload {:.1})",
        result.clock.now_s(),
        result.clock.total(Charge::Compute),
        result.clock.total(Charge::Communication),
        result.clock.total(Charge::DataLoad)
    );
    println!("sync rounds          {syncs} ({:.1} MiB shipped)", bytes as f64 / (1 << 20) as f64);
    println!("host wall time       {wall:.1}s ({:.1} samples/s)", result.recorder.wall_throughput());

    std::fs::create_dir_all("results")?;
    result.recorder.write_steps_csv("results/train_lm_steps.csv")?;
    result.recorder.write_evals_csv("results/train_lm_evals.csv")?;
    println!("wrote results/train_lm_steps.csv, results/train_lm_evals.csv");
    Ok(())
}
