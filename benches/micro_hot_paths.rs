//! Bench: L3 hot paths — the coordinator-side loops that bound throughput,
//! plus the PJRT dispatch costs. The before/after numbers in
//! EXPERIMENTS.md §Perf come from this harness.
//!
//! Run: `cargo bench --bench micro_hot_paths`
//! Knob: ADAALTER_BENCH_DIM (default 1,048,576 — a 4 MiB vector, ~1M-param
//! model; the paper's 0.83B-param state is 800× this, same loops).

use adaalter::coordinator::aggregate::{average_into, Aggregator};
use adaalter::data::BatchLoader;
use adaalter::optim::{AdaAlter, AdaGrad, LocalAdaAlterWorker, SyncOptimizer};
use adaalter::util::rng::Rng;
use adaalter::util::timing::{bench, black_box, report};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn randn(d: usize, seed: u64, sigma: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    Rng::new(seed).fill_normal(&mut v, sigma);
    v
}

fn main() {
    let d: usize = env_or("ADAALTER_BENCH_DIM", 1 << 20);
    let n_workers = 8usize;
    println!("=== L3 hot paths (d = {d}, {n_workers} workers) ===\n");

    // --- optimizer steps -------------------------------------------------
    let g = randn(d, 1, 0.5);
    let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();

    {
        let mut x = randn(d, 2, 1.0);
        let mut opt = AdaGrad::new(d, 1.0, 1.0);
        let s = bench(4, 12, || {
            opt.step(&mut x, &g, &gsq, 0.1);
            black_box(x[0]);
        });
        // streams: read g, gsq, rw b2, rw x = 6 vectors of 4d bytes
        report("adagrad_step (fused accumulate+update)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(24 * d as u64)));
    }
    {
        let mut x = randn(d, 3, 1.0);
        let mut opt = AdaAlter::new(d, 1.0, 1.0);
        let s = bench(4, 12, || {
            opt.step(&mut x, &g, &gsq, 0.1);
            black_box(x[0]);
        });
        report("adaalter_step (fused update+accumulate)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(24 * d as u64)));
    }
    {
        let mut w = LocalAdaAlterWorker::new(randn(d, 4, 1.0), 1.0, 1.0);
        let s = bench(4, 12, || {
            w.local_step(&g, 0.1);
            black_box(w.x()[0]);
        });
        report("local_adaalter_step (placeholder denom)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(20 * d as u64)));
    }

    // --- aggregation -----------------------------------------------------
    let grads: Vec<Vec<f32>> = (0..n_workers).map(|i| randn(d, 10 + i as u64, 0.5)).collect();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    {
        let mut agg = Aggregator::new(d);
        let s = bench(2, 10, || {
            agg.mean_grads(&refs);
            black_box(agg.avg_g[0]);
        });
        report("mean_grads (8-way)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(4 * (n_workers + 1) as u64 * d as u64)));
    }
    {
        let mut agg = Aggregator::new(d);
        let s = bench(2, 10, || {
            agg.mean_grads_and_squares(&refs);
            black_box(agg.avg_gsq[0]);
        });
        report("mean_grads_and_squares (8-way, 1 pass)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(4 * (n_workers + 2) as u64 * d as u64)));
    }
    {
        let mut out = vec![0.0f32; d];
        let s = bench(2, 10, || {
            average_into(&refs, &mut out);
            black_box(out[0]);
        });
        report("average_into (sync round, 8-way)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(4 * (n_workers + 1) as u64 * d as u64)));
    }

    // --- data pipeline ---------------------------------------------------
    {
        let loader = BatchLoader::new(2048, 8, 4, 8, 64, &Default::default(), 7);
        let mut step = 0u64;
        let s = bench(64, 10, || {
            step += 1;
            black_box(loader.train_batch((step % 8) as usize, step));
        });
        report("train_batch (4×65 tokens, zipf+markov)", &s, &format!("{:.2} Mtok/s", 260.0 * s.per_second() / 1e6));
    }

    // --- PJRT dispatch ---------------------------------------------------
    if adaalter::runtime::artifacts_available("artifacts") {
        use adaalter::coordinator::WorkerBackend;
        use adaalter::runtime::PjrtBackend;
        let mut b = PjrtBackend::new("artifacts", "tiny", 0, 1, &Default::default(), 3).unwrap();
        let x = b.init_params().unwrap();
        let dm = b.dim();
        let mut grad = vec![0.0f32; dm];
        let mut step = 0u64;
        let s = bench(3, 8, || {
            step += 1;
            black_box(b.loss_and_grad(&x, step, &mut grad).unwrap());
        });
        report("pjrt train_step (tiny fwd+bwd, B=4 S=32)", &s, &format!("{:.1} ms", s.median_ns / 1e6));

        let mut xf = x.clone();
        let b2 = vec![1.0f32; dm];
        let mut acc = b2.clone();
        let s = bench(3, 8, || {
            step += 1;
            black_box(
                b.fused_local_adaalter(&mut xf, &b2, &mut acc, 1.0, 0.1, step)
                    .unwrap(),
            );
        });
        report("pjrt fused local step (fwd+bwd+update)", &s, &format!("{:.1} ms", s.median_ns / 1e6));
    } else {
        println!("(artifacts/ not built — skipping PJRT dispatch benches)");
    }
}
