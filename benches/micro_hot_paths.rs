//! Bench: L3 hot paths — the coordinator-side loops that bound throughput,
//! the shared kernels, the execution engine's worker-step scaling, and the
//! PJRT dispatch costs. The before/after numbers in EXPERIMENTS.md §Perf
//! come from this harness; the machine-readable trajectory lands in
//! `BENCH_micro_hot_paths.json` (DESIGN.md §7).
//!
//! Run: `cargo bench --bench micro_hot_paths`
//! Knob: ADAALTER_BENCH_DIM (default 1,048,576 — a 4 MiB vector, ~1M-param
//! model; the paper's 0.83B-param state is 800× this, same loops).

use adaalter::comm::compress::{QsgdEncoded, QsgdQuantizer, SparseGrad, TopKSparsifier};
use adaalter::coordinator::aggregate::{average_into, Aggregator};
use adaalter::coordinator::Executor;
use adaalter::data::BatchLoader;
use adaalter::optim::{AdaAlter, AdaGrad, LocalAdaAlterWorker, SyncOptimizer};
use adaalter::util::kernels;
use adaalter::util::rng::Rng;
use adaalter::util::timing::{bench, black_box, report, BenchSink};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One serial-vs-SIMD kernel pair: bench both forms (bitwise identical by
/// construction, pinned in `util/kernels.rs`), report both rows plus the
/// serial/simd median ratio.
fn simd_pair(
    sink: &mut BenchSink,
    speedups: &mut Vec<(&'static str, f64)>,
    name: &'static str,
    bytes: u64,
    serial_f: &mut dyn FnMut(),
    simd_f: &mut dyn FnMut(),
) {
    let ss = bench(4, 12, serial_f);
    let sv = bench(4, 12, simd_f);
    let ratio = ss.median_ns / sv.median_ns;
    report(
        &format!("{name} serial vs simd"),
        &sv,
        &format!("{ratio:.2}x over serial ({:.1} GB/s)", sv.bandwidth_gbs(bytes)),
    );
    sink.timed(&format!("serial_{name}"), &ss, &[("bytes_per_iter", bytes as f64)]);
    sink.timed(&format!("simd_{name}"), &sv, &[("bytes_per_iter", bytes as f64)]);
    speedups.push((name, ratio));
}

fn randn(d: usize, seed: u64, sigma: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    Rng::new(seed).fill_normal(&mut v, sigma);
    v
}

fn main() {
    let d: usize = env_or("ADAALTER_BENCH_DIM", 1 << 20);
    let n_workers = 8usize;
    let mut sink = BenchSink::new("micro_hot_paths");
    sink.value("config", &[("dim", d as f64), ("workers", n_workers as f64)]);
    println!("=== L3 hot paths (d = {d}, {n_workers} workers) ===\n");

    // --- optimizer steps -------------------------------------------------
    let g = randn(d, 1, 0.5);
    let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();

    {
        let mut x = randn(d, 2, 1.0);
        let mut opt = AdaGrad::new(d, 1.0, 1.0);
        let s = bench(4, 12, || {
            opt.step(&mut x, &g, &gsq, 0.1);
            black_box(x[0]);
        });
        // streams: read g, gsq, rw b2, rw x = 6 vectors of 4d bytes
        let bytes = 24 * d as u64;
        report("adagrad_step (fused accumulate+update)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(bytes)));
        sink.timed("adagrad_step", &s, &[("bytes_per_iter", bytes as f64), ("gb_per_s", s.bandwidth_gbs(bytes))]);
    }
    {
        let mut x = randn(d, 3, 1.0);
        let mut opt = AdaAlter::new(d, 1.0, 1.0);
        let s = bench(4, 12, || {
            opt.step(&mut x, &g, &gsq, 0.1);
            black_box(x[0]);
        });
        let bytes = 24 * d as u64;
        report("adaalter_step (fused update+accumulate)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(bytes)));
        sink.timed("adaalter_step", &s, &[("bytes_per_iter", bytes as f64), ("gb_per_s", s.bandwidth_gbs(bytes))]);
    }
    {
        let mut w = LocalAdaAlterWorker::new(randn(d, 4, 1.0), 1.0, 1.0);
        let s = bench(4, 12, || {
            w.local_step(&g, 0.1);
            black_box(w.x()[0]);
        });
        let bytes = 20 * d as u64;
        report("local_adaalter_step (placeholder denom)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(bytes)));
        sink.timed("local_adaalter_step", &s, &[("bytes_per_iter", bytes as f64), ("gb_per_s", s.bandwidth_gbs(bytes))]);
    }

    // --- execution engine: parallel worker steps -------------------------
    // The tentpole measurement (ISSUE 5): throughput of one cluster-wide
    // local iteration (8 independent worker steps) under the serial
    // engine vs scoped thread pools. Bitwise-identical by construction
    // (pinned in rust/tests/integration_exec.rs); the only thing that may
    // change is wall-clock.
    {
        println!("\n--- execution engine: {n_workers}-worker local steps ---");
        let grads: Vec<Vec<f32>> = (0..n_workers).map(|i| randn(d, 40 + i as u64, 0.5)).collect();
        let mut serial_ns = 0.0f64;
        let mut threads8_ns = 0.0f64;
        for (label, ex) in [
            ("serial", Executor::serial()),
            ("threads(2)", Executor::threads(2)),
            ("threads(4)", Executor::threads(4)),
            ("threads(8)", Executor::threads(8)),
        ] {
            let mut workers: Vec<LocalAdaAlterWorker> = (0..n_workers)
                .map(|i| LocalAdaAlterWorker::new(randn(d, 50 + i as u64, 1.0), 1.0, 1.0))
                .collect();
            let s = bench(2, 8, || {
                ex.for_each(&mut workers, |w, st| {
                    st.local_step(&grads[w], 0.1);
                    black_box(st.x()[0]);
                });
            });
            let steps_s = n_workers as f64 * s.per_second();
            if label == "serial" {
                serial_ns = s.median_ns;
            }
            if label == "threads(8)" {
                threads8_ns = s.median_ns;
            }
            report(
                &format!("engine {label} ({n_workers}x local step)"),
                &s,
                &format!("{steps_s:.0} worker-steps/s"),
            );
            sink.timed(
                &format!("engine_{label}"),
                &s,
                &[("worker_steps_per_s", steps_s)],
            );
        }
        let speedup = serial_ns / threads8_ns;
        println!("engine threads(8) vs serial: {speedup:.2}x worker-step throughput");
        sink.value("engine_speedup", &[("threads8_vs_serial", speedup)]);
    }

    // --- aggregation -----------------------------------------------------
    let grads: Vec<Vec<f32>> = (0..n_workers).map(|i| randn(d, 10 + i as u64, 0.5)).collect();
    let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
    {
        let mut agg = Aggregator::new(d);
        let s = bench(2, 10, || {
            agg.mean_grads(&refs);
            black_box(agg.avg_g[0]);
        });
        let bytes = 4 * (n_workers + 1) as u64 * d as u64;
        report("mean_grads (8-way)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(bytes)));
        sink.timed("mean_grads", &s, &[("bytes_per_iter", bytes as f64), ("gb_per_s", s.bandwidth_gbs(bytes))]);
    }
    {
        let mut agg = Aggregator::new(d);
        let s = bench(2, 10, || {
            agg.mean_grads_and_squares(&refs);
            black_box(agg.avg_gsq[0]);
        });
        let bytes = 4 * (n_workers + 2) as u64 * d as u64;
        report("mean_grads_and_squares (8-way, 1 pass)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(bytes)));
        sink.timed("mean_grads_and_squares", &s, &[("bytes_per_iter", bytes as f64), ("gb_per_s", s.bandwidth_gbs(bytes))]);
    }
    {
        let mut out = vec![0.0f32; d];
        let s = bench(2, 10, || {
            average_into(&refs, &mut out);
            black_box(out[0]);
        });
        let bytes = 4 * (n_workers + 1) as u64 * d as u64;
        report("average_into (sync round, 8-way)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(bytes)));
        sink.timed("average_into", &s, &[("bytes_per_iter", bytes as f64), ("gb_per_s", s.bandwidth_gbs(bytes))]);
    }

    // --- compression codecs (scratch-buffer hot paths) -------------------
    {
        let q = QsgdQuantizer::new(15);
        let mut rng = Rng::new(9);
        let mut enc = QsgdEncoded { norm: 0.0, levels: Vec::new(), s: 15 };
        let mut out = vec![0.0f32; d];
        let s = bench(2, 10, || {
            q.encode_to(&g, &mut rng, &mut enc);
            q.decode(&enc, &mut out);
            black_box(out[0]);
        });
        let wire = q.wire_bytes(d);
        report("qsgd roundtrip s=15 (pooled scratch)", &s, &format!("{wire} wire B"));
        sink.timed("qsgd_roundtrip", &s, &[("wire_bytes", wire as f64)]);
    }
    {
        let mut sp = TopKSparsifier::new(d, 0.01);
        let mut msg = SparseGrad { d, idx: Vec::new(), val: Vec::new() };
        let s = bench(2, 10, || {
            sp.encode_into(&g, &mut msg);
            black_box(msg.idx.len());
        });
        let wire = msg.wire_bytes();
        report("topk encode 1% (pooled scratch)", &s, &format!("{wire} wire B"));
        sink.timed("topk_encode", &s, &[("wire_bytes", wire as f64)]);
    }
    {
        let base = randn(d, 21, 1.0);
        let mut delta = vec![0.0f32; d];
        let mut back = vec![0.0f32; d];
        let s = bench(4, 10, || {
            kernels::delta_encode(&g, &base, &mut delta);
            kernels::delta_decode(&base, &delta, &mut back);
            black_box(back[0]);
        });
        let bytes = 6 * 4 * d as u64;
        report("delta encode+decode (sync-round coding)", &s, &format!("{:.1} GB/s", s.bandwidth_gbs(bytes)));
        sink.timed("delta_roundtrip", &s, &[("bytes_per_iter", bytes as f64), ("gb_per_s", s.bandwidth_gbs(bytes))]);
    }

    // --- serial vs SIMD kernel forms (PR 6 tentpole) ---------------------
    // Same kernels, both implementations called directly (bypassing the
    // `exec.simd` dispatcher so one process measures both). Bitwise
    // identical by construction — including the fixed-tree reductions —
    // so the ratio is pure wall-clock. The reductions are where the lanes
    // pay: the serial form of a sequential f64 accumulator is
    // latency-bound; 8 independent lanes break the carried dependency.
    {
        use adaalter::util::kernels::serial;
        use adaalter::util::simd;
        println!("\n--- serial vs SIMD kernel forms (d = {d}) ---");
        let mut speedups: Vec<(&'static str, f64)> = Vec::new();

        {
            let mut out_a = vec![0.0f32; d];
            let mut out_b = vec![0.0f32; d];
            simd_pair(
                &mut sink,
                &mut speedups,
                "mean_grads",
                4 * (n_workers + 1) as u64 * d as u64,
                &mut || {
                    serial::mean_into(&refs, &mut out_a);
                    black_box(out_a[0]);
                },
                &mut || {
                    simd::mean_into(&refs, &mut out_b);
                    black_box(out_b[0]);
                },
            );
        }
        {
            let (mut ga, mut qa) = (vec![0.0f32; d], vec![0.0f32; d]);
            let (mut gb, mut qb) = (vec![0.0f32; d], vec![0.0f32; d]);
            simd_pair(
                &mut sink,
                &mut speedups,
                "mean_grads_and_squares",
                4 * (n_workers + 2) as u64 * d as u64,
                &mut || {
                    serial::mean_and_squares_into(&refs, &mut ga, &mut qa);
                    black_box(qa[0]);
                },
                &mut || {
                    simd::mean_and_squares_into(&refs, &mut gb, &mut qb);
                    black_box(qb[0]);
                },
            );
        }
        {
            let (mut xa, mut ba) = (randn(d, 70, 1.0), vec![1.0f32; d]);
            let (mut xb, mut bb) = (randn(d, 70, 1.0), vec![1.0f32; d]);
            simd_pair(
                &mut sink,
                &mut speedups,
                "adagrad_step",
                24 * d as u64,
                &mut || {
                    serial::adagrad_step(&mut xa, &mut ba, &g, &gsq, 0.001, 1.0);
                    black_box(xa[0]);
                },
                &mut || {
                    simd::adagrad_step(&mut xb, &mut bb, &g, &gsq, 0.001, 1.0);
                    black_box(xb[0]);
                },
            );
        }
        simd_pair(
            &mut sink,
            &mut speedups,
            "sgd_update_sq",
            4 * d as u64,
            &mut || {
                black_box(serial::sgd_update_sq(&g, 0.1));
            },
            &mut || {
                black_box(simd::sgd_update_sq(&g, 0.1));
            },
        );
        {
            let (mut xa, ba, mut aa) = (randn(d, 71, 1.0), vec![1.0f32; d], vec![1.0f32; d]);
            let (mut xb, bb, mut ab) = (randn(d, 71, 1.0), vec![1.0f32; d], vec![1.0f32; d]);
            simd_pair(
                &mut sink,
                &mut speedups,
                "local_adaalter_step",
                20 * d as u64,
                &mut || {
                    black_box(serial::local_adaalter_step(&mut xa, &ba, &mut aa, &g, 0.001, 1.0));
                },
                &mut || {
                    black_box(simd::local_adaalter_step(&mut xb, &bb, &mut ab, &g, 0.001, 1.0));
                },
            );
        }
        sink.value("simd_speedup", &speedups);
        for (name, ratio) in &speedups {
            println!("simd speedup {name}: {ratio:.2}x");
        }
    }

    // --- bf16 conversions (precision.wire hot path) ----------------------
    {
        use adaalter::util::half;
        let src = randn(d, 80, 1.0);
        let mut wire: Vec<u16> = Vec::new();
        let mut back = vec![0.0f32; d];
        let s = bench(4, 12, || {
            half::encode_into(&src, &mut wire);
            half::decode_into(&wire, &mut back);
            black_box(back[0]);
        });
        let bytes = half::wire_bytes(d);
        report("bf16 encode+decode (wire roundtrip)", &s, &format!("{bytes} wire B"));
        sink.timed("bf16_roundtrip", &s, &[("wire_bytes", bytes as f64)]);
    }

    // --- data pipeline ---------------------------------------------------
    {
        let loader = BatchLoader::new(2048, 8, 4, 8, 64, &Default::default(), 7);
        let mut step = 0u64;
        let s = bench(64, 10, || {
            step += 1;
            black_box(loader.train_batch((step % 8) as usize, step));
        });
        let mtok = 260.0 * s.per_second() / 1e6;
        report("train_batch (4×65 tokens, zipf+markov)", &s, &format!("{mtok:.2} Mtok/s"));
        sink.timed("train_batch", &s, &[("mtok_per_s", mtok)]);
    }

    // --- PJRT dispatch ---------------------------------------------------
    if adaalter::runtime::artifacts_available("artifacts") {
        use adaalter::coordinator::WorkerBackend;
        use adaalter::runtime::PjrtBackend;
        let mut b = PjrtBackend::new("artifacts", "tiny", 0, 1, &Default::default(), 3).unwrap();
        let x = b.init_params().unwrap();
        let dm = b.dim();
        let mut grad = vec![0.0f32; dm];
        let mut step = 0u64;
        let s = bench(3, 8, || {
            step += 1;
            black_box(b.loss_and_grad(&x, step, &mut grad).unwrap());
        });
        report("pjrt train_step (tiny fwd+bwd, B=4 S=32)", &s, &format!("{:.1} ms", s.median_ns / 1e6));
        sink.timed("pjrt_train_step", &s, &[]);

        let mut xf = x.clone();
        let b2 = vec![1.0f32; dm];
        let mut acc = b2.clone();
        let s = bench(3, 8, || {
            step += 1;
            black_box(
                b.fused_local_adaalter(&mut xf, &b2, &mut acc, 1.0, 0.1, step)
                    .unwrap(),
            );
        });
        report("pjrt fused local step (fwd+bwd+update)", &s, &format!("{:.1} ms", s.median_ns / 1e6));
        sink.timed("pjrt_fused_local_step", &s, &[]);
    } else {
        println!("(artifacts/ not built — skipping PJRT dispatch benches)");
    }

    sink.finish();
}
