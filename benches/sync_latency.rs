//! Sync-round latency under the `[comm] pipeline` knob (DESIGN.md
//! §"Pipelined sync rounds"): per-round wall clock with the pipeline off
//! vs depth ∈ {2, 4}, at n ∈ {4, 8} workers over k = 8 leader shards —
//! both through the in-process collective (true per-round p50/p99 over
//! repeated `sync_round` calls) and over real loopback TCP deployments.
//!
//! TCP rounds cannot be sampled individually from outside the leader, so
//! the per-round estimate differences two deployments of the same config
//! (long minus short run, divided by the sync-count delta) — process
//! spawn, handshake and teardown cancel out.
//!
//! Ratcheted metrics: `accounted_minus_booked_bytes` must stay exactly 0
//! per TCP cell (pipelining must not move a byte of accounting), and the
//! `pipeline_speedup_*` rates warn below their conservative baseline
//! floors (wall clock depends on the runner). The `round_*_ns` readings
//! are informational.
//!
//! Run: `cargo bench --bench sync_latency`
//! Knob: ADAALTER_BENCH_DIM (default 262,144 — a 1 MiB vector).

use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use adaalter::comm::{ChannelCollective, Collective};
use adaalter::util::json::Json;
use adaalter::util::rng::Rng;
use adaalter::util::timing::{black_box, BenchSink};

/// The compiled `adaalter` CLI binary under test.
const BIN: &str = env!("CARGO_BIN_EXE_adaalter");

/// Leader shard count for every cell (the ISSUE acceptance shape).
const SHARDS: usize = 8;

fn env_or(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn randn(d: usize, seed: u64) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    Rng::new(seed).fill_normal(&mut v, 1.0);
    v
}

/// (p50, p99) of a sorted-in-place nanosecond sample.
fn percentiles(samples: &mut [f64]) -> (f64, f64) {
    samples.sort_by(|a, b| a.total_cmp(b));
    let p50 = samples[samples.len() / 2];
    let p99 = samples[(samples.len() * 99) / 100];
    (p50, p99)
}

/// True per-round p50/p99 through the in-process sharded collective:
/// time each `sync_round` (x and acc families, exactly what the trainer
/// issues at a Local AdaAlter boundary) individually.
fn inproc_round_ns(n: usize, d: usize, depth: usize, rounds: usize) -> Vec<f64> {
    let mut coll = ChannelCollective::pipelined(n, d, SHARDS, depth);
    let states: Vec<Vec<f32>> = (0..n).map(|w| randn(d, 10 + w as u64)).collect();
    let accs: Vec<Vec<f32>> = (0..n).map(|w| randn(d, 20 + w as u64)).collect();
    let xs: Vec<&[f32]> = states.iter().map(|v| v.as_slice()).collect();
    let acc_refs: Vec<&[f32]> = accs.iter().map(|v| v.as_slice()).collect();
    let mut avg_x = vec![0.0f32; d];
    let mut avg_acc = vec![0.0f32; d];
    // Warm-up: faults in the staging buffers and spins up the executor.
    for _ in 0..3 {
        coll.sync_round(&xs, Some(&acc_refs), &mut avg_x, Some(&mut avg_acc)).unwrap();
    }
    (0..rounds)
        .map(|_| {
            let t0 = Instant::now();
            coll.sync_round(&xs, Some(&acc_refs), &mut avg_x, Some(&mut avg_acc)).unwrap();
            let ns = t0.elapsed().as_nanos() as f64;
            black_box(avg_x[0]);
            ns
        })
        .collect()
}

/// Kill-on-drop child, so one failed role never strands the fleet.
struct Guard(Child);

impl Drop for Guard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Wait for a clean exit with a hard deadline (a deadlock must fail the
/// bench, not hang CI).
fn wait(g: &mut Guard, label: &str) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if let Some(st) = g.0.try_wait().expect("try_wait failed") {
            assert!(st.success(), "{label} failed: {st}");
            return;
        }
        assert!(Instant::now() < deadline, "{label} did not exit within 120s");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// One loopback deployment (H = 1 Local AdaAlter, k = [`SHARDS`],
/// `pipeline = depth`): returns its `net_report.json` and the end-to-end
/// wall time in seconds.
fn deploy(tag: &str, n: usize, d: usize, depth: usize, steps: u64) -> (Json, f64) {
    let dir = std::env::temp_dir().join(format!("adaalter_bench_sl_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let dir = dir.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(format!("{dir}/leader.addr"));
    let _ = std::fs::remove_file(format!("{dir}/net_report.json"));
    let toml = format!(
        "[train]\n\
         workers = {n}\n\
         sync_period = 1\n\
         steps = {steps}\n\
         log_every = 64\n\
         backend = \"rust_math\"\n\
         rust_math_dim = {d}\n\
         [optim]\n\
         algorithm = \"local_adaalter\"\n\
         warmup_steps = 10\n\
         [comm]\n\
         transport = \"tcp\"\n\
         shards = {SHARDS}\n\
         pipeline = {depth}\n\
         [net]\n\
         listen = \"127.0.0.1:0\"\n\
         connect_timeout_s = 60.0\n"
    );
    let cfg = format!("{dir}/cfg.toml");
    std::fs::write(&cfg, toml).expect("write config");

    let t0 = Instant::now();
    let mut leader = Guard(
        Command::new(BIN)
            .args(["train", "--config", &cfg, "--role", "leader"])
            .args(["--port-file", &format!("{dir}/leader.addr")])
            .args(["--out-dir", &dir, "--quiet"])
            .stdout(Stdio::null())
            .spawn()
            .expect("spawn leader"),
    );
    let mut kids: Vec<Guard> = (0..n)
        .map(|w| {
            Guard(
                Command::new(BIN)
                    .args(["train", "--config", &cfg, "--role", "worker"])
                    .args(["--worker-id", &w.to_string()])
                    .args(["--port-file", &format!("{dir}/leader.addr")])
                    .arg("--quiet")
                    .stdout(Stdio::null())
                    .spawn()
                    .expect("spawn worker"),
            )
        })
        .collect();
    for (w, g) in kids.iter_mut().enumerate() {
        wait(g, &format!("worker {w}"));
    }
    wait(&mut leader, "leader");
    let wall = t0.elapsed().as_secs_f64();

    let path = format!("{dir}/net_report.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    (Json::parse(&text).expect("net_report.json parses"), wall)
}

/// Startup-cancelled per-round estimate over loopback TCP, plus the long
/// run's exact accounting drift (must be 0).
fn tcp_round_ns(tag: &str, n: usize, d: usize, depth: usize) -> (f64, f64) {
    let (short_steps, long_steps) = (8u64, 56u64);
    let (rep_s, wall_s) = deploy(&format!("{tag}_s"), n, d, depth, short_steps);
    let (rep_l, wall_l) = deploy(&format!("{tag}_l"), n, d, depth, long_steps);
    let num = |rep: &Json, k: &str| rep.req(k).unwrap().num().unwrap();
    let dsyncs = num(&rep_l, "syncs") - num(&rep_s, "syncs");
    assert!(dsyncs > 0.0, "{tag}: long run must sync more than the short run");
    let round_ns = (wall_l - wall_s).max(0.0) * 1e9 / dsyncs;
    let drift = num(&rep_l, "accounted_bytes") - num(&rep_l, "booked_bytes");
    (round_ns, drift)
}

fn main() {
    let d: usize = env_or("ADAALTER_BENCH_DIM", 1 << 18);
    let rounds = 40usize;
    let mut sink = BenchSink::new("sync_latency");
    sink.value("config", &[("dim", d as f64), ("shards", SHARDS as f64)]);
    println!("=== sync-round latency (d = {d}, k = {SHARDS} shards) ===\n");

    for n in [4usize, 8] {
        // In-process: true per-round samples.
        let mut off = inproc_round_ns(n, d, 0, rounds);
        let (off_p50, off_p99) = percentiles(&mut off);
        sink.value(
            &format!("inproc_n{n}_k{SHARDS}_off"),
            &[("round_p50_ns", off_p50), ("round_p99_ns", off_p99)],
        );
        println!("inproc  n={n} off      p50 {:>10.0} ns  p99 {:>10.0} ns", off_p50, off_p99);
        for depth in [2usize, 4] {
            let mut ns = inproc_round_ns(n, d, depth, rounds);
            let (p50, p99) = percentiles(&mut ns);
            sink.value(
                &format!("inproc_n{n}_k{SHARDS}_d{depth}"),
                &[
                    ("round_p50_ns", p50),
                    ("round_p99_ns", p99),
                    ("pipeline_speedup_p50", off_p50 / p50),
                ],
            );
            println!(
                "inproc  n={n} depth {depth}  p50 {:>10.0} ns  p99 {:>10.0} ns  speedup {:.2}x",
                p50,
                p99,
                off_p50 / p50
            );
        }

        // Loopback TCP: startup-cancelled per-round estimates.
        let (off_ns, off_drift) = tcp_round_ns(&format!("n{n}_off"), n, d, 0);
        sink.value(
            &format!("tcp_n{n}_k{SHARDS}_off"),
            &[("round_est_ns", off_ns), ("accounted_minus_booked_bytes", off_drift)],
        );
        println!("tcp     n={n} off      round {:>10.0} ns", off_ns);
        for depth in [2usize, 4] {
            let (ns, drift) = tcp_round_ns(&format!("n{n}_d{depth}"), n, d, depth);
            sink.value(
                &format!("tcp_n{n}_k{SHARDS}_d{depth}"),
                &[
                    ("round_est_ns", ns),
                    ("accounted_minus_booked_bytes", drift),
                    ("pipeline_speedup_round", off_ns / ns),
                ],
            );
            println!(
                "tcp     n={n} depth {depth}  round {:>10.0} ns  speedup {:.2}x",
                ns,
                off_ns / ns
            );
        }
    }
    sink.finish();
}
