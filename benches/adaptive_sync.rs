//! Bench: fixed vs. **adaptive** synchronization scheduling (DESIGN.md §5)
//! over the fig-3 convergence setup on the synthetic non-IID testbed.
//!
//! The paper fixes H ahead of time; its own cost model makes H the knob
//! trading communication (`2/H`) against convergence. This bench runs the
//! same training budget under every `[sync]` policy and reports the
//! realized rounds/bytes/virtual-time and the final suboptimality — the
//! claim under test being that an adaptive policy reaches
//! fig-3-comparable final loss with *fewer* communication rounds than
//! the paper's fixed H = 4.
//!
//! Run: `cargo bench --bench adaptive_sync`
//! Knobs: ADAALTER_BENCH_STEPS (default 800), ADAALTER_BENCH_WORKERS (8),
//!        ADAALTER_BENCH_DIM (512), ADAALTER_DRIFT_THRESHOLD (2.0).

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, Trainer, WorkerBackend};
use adaalter::sim::{Charge, SyntheticProblem};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Row {
    label: &'static str,
    adaptive: bool,
    rounds: u64,
    mib: f64,
    comm_s: f64,
    total_s: f64,
    subopt: f64,
    mean_h: f64,
    events_ok: bool,
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: u64 = env_or("ADAALTER_BENCH_STEPS", 800);
    let workers: usize = env_or("ADAALTER_BENCH_WORKERS", 8);
    let dim: usize = env_or("ADAALTER_BENCH_DIM", 512);
    let theta: f64 = env_or("ADAALTER_DRIFT_THRESHOLD", 2.0);
    let seed = 42u64;

    let problem = SyntheticProblem::new(dim, workers, seed);
    let opt_loss = problem.global_loss(&problem.optimum());
    let init_loss = problem.global_loss(&problem.backend(0).init_params()?);

    let base = |h: u64| {
        let mut c = ExperimentConfig::default();
        c.train.workers = workers;
        c.train.steps = steps;
        c.train.sync_period = SyncPeriod::Every(h);
        c.train.backend = Backend::RustMath;
        c.train.rust_math_dim = dim;
        c.train.seed = seed;
        c.train.log_every = steps;
        c.optim.algorithm = Algorithm::LocalAdaAlter;
        c.optim.warmup_steps = 50;
        c
    };

    let variants: Vec<(&'static str, bool, ExperimentConfig)> = vec![
        ("fixed H=1", false, base(1)),
        ("fixed H=4", false, base(4)),
        ("fixed H=16", false, base(16)),
        ("growing 4→16", true, {
            let mut c = base(4);
            c.sync.policy = "growing".into();
            c.sync.grow_every = 2;
            c.sync.h_max = 16;
            c
        }),
        ("drift-triggered", true, {
            let mut c = base(4);
            c.sync.policy = "drift".into();
            c.sync.drift_threshold = theta;
            c.sync.h_max = 16;
            c
        }),
        ("time-budget 2%", true, {
            let mut c = base(4);
            c.sync.policy = "time_budget".into();
            c.sync.target_comm_fraction = 0.02;
            c.sync.h_max = 64;
            c
        }),
    ];

    println!("=== Adaptive synchronization scheduling (fig-3 setup, synthetic testbed) ===");
    println!(
        "(n={workers}, d={dim}, {steps} steps; init global loss {init_loss:.2}, \
         irreducible optimum {opt_loss:.2}; virtual time = paper-scale cluster)\n"
    );
    println!(
        "{:<16} {:>7} {:>9} {:>9} {:>9} {:>10} {:>7}",
        "policy", "rounds", "MiB", "comm-s", "total-s", "subopt", "mean-H"
    );

    let mut rows: Vec<Row> = Vec::new();
    for (label, adaptive, cfg) in variants {
        let p = problem.clone();
        let factory: BackendFactory = Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));
        let r = Trainer::new(cfg, factory).run()?;
        let (rounds, bytes) = r.recorder.comm();
        let gaps = r.recorder.realized_h();
        let mean_h = if gaps.is_empty() {
            f64::NAN
        } else {
            gaps.iter().sum::<u64>() as f64 / gaps.len() as f64
        };
        let row = Row {
            label,
            adaptive,
            rounds,
            mib: bytes as f64 / (1 << 20) as f64,
            comm_s: r.clock.total(Charge::Communication),
            total_s: r.clock.now_s(),
            subopt: r.final_eval.unwrap().loss - opt_loss,
            mean_h,
            events_ok: r.recorder.sync_events.len() as u64 == rounds,
        };
        println!(
            "{:<16} {:>7} {:>9.1} {:>9.2} {:>9.1} {:>10.4} {:>7.1}",
            row.label, row.rounds, row.mib, row.comm_s, row.total_s, row.subopt, row.mean_h
        );
        rows.push(row);
    }

    println!("\n=== checks ===");
    let h4 = rows.iter().find(|r| r.label == "fixed H=4").unwrap();
    println!(
        "fixed H=4 (the paper's setting) converges: subopt {:.3} < 1 {}",
        h4.subopt,
        ok(h4.subopt < 1.0)
    );
    // The acceptance claim: some adaptive policy matches the fig-3-level
    // final loss with fewer communication rounds than fixed H=4.
    let loss_bar = (2.0 * h4.subopt).max(1.0);
    let winners: Vec<&Row> = rows
        .iter()
        .filter(|r| r.adaptive && r.rounds < h4.rounds && r.subopt <= loss_bar)
        .collect();
    println!(
        "an adaptive policy beats fixed H=4 on rounds at comparable loss \
         (≤ max(1, 2× fixed)): {} {}",
        winners
            .iter()
            .map(|r| format!("{} ({} vs {} rounds, subopt {:.3})", r.label, r.rounds, h4.rounds, r.subopt))
            .collect::<Vec<_>>()
            .join("; "),
        ok(!winners.is_empty())
    );
    println!(
        "…and finishes no later on the virtual clock {}",
        ok(winners.iter().any(|r| r.total_s <= h4.total_s))
    );
    println!(
        "every policy's recorded sync events equal its comm rounds {}",
        ok(rows.iter().all(|r| r.events_ok))
    );
    let growing = rows.iter().find(|r| r.label == "growing 4→16").unwrap();
    println!(
        "growing policy communicates less than any fixed H ≤ its cap \
         ({} rounds vs H=4's {}) {}",
        growing.rounds,
        h4.rounds,
        ok(growing.rounds < h4.rounds)
    );
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
