//! Bench: the fig-1 "epoch time" story under **realistic conditions** —
//! one slow worker of eight (DESIGN.md §6).
//!
//! The paper's premise is that the synchronous barrier ("blocks the global
//! update until all the workers respond", §2) is the bottleneck; its fix —
//! communicate less often (H) — does *not* help when one worker is simply
//! slow, because every barrier still waits for it. This bench runs the
//! same budget under a deterministic 4×-slowdown of worker 7 and compares:
//!
//! * full-barrier fixed H = 4 (the paper's setting) and H = 16;
//! * an adaptive-H policy (growing 4→16) — still a full barrier;
//! * quorum-7 sync rounds (drop the straggler after the quorum arrives);
//! * backup-worker sync (always drop the slowest arrival).
//!
//! The claim under test: quorum or backup-worker sync recovers ≥ 50% of
//! the straggler-induced wall-clock penalty vs. full-barrier fixed H = 4
//! at comparable final loss, and the same seed reproduces the identical
//! `faults_<tag>.csv` twice.
//!
//! Run: `cargo bench --bench straggler_recovery`
//! Knobs: ADAALTER_BENCH_STEPS (default 800), ADAALTER_BENCH_WORKERS (8),
//!        ADAALTER_BENCH_DIM (512), ADAALTER_SLOW_FACTOR (4.0).

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, Trainer, WorkerBackend};
use adaalter::sim::{Charge, SyntheticProblem};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Row {
    label: &'static str,
    partial: bool,
    rounds: u64,
    mib: f64,
    straggler_s: f64,
    total_s: f64,
    subopt: f64,
    mean_participants: f64,
    events_ok: bool,
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: u64 = env_or("ADAALTER_BENCH_STEPS", 800);
    let workers: usize = env_or("ADAALTER_BENCH_WORKERS", 8);
    let dim: usize = env_or("ADAALTER_BENCH_DIM", 512);
    let slow_factor: f64 = env_or("ADAALTER_SLOW_FACTOR", 4.0);
    let seed = 42u64;

    let problem = SyntheticProblem::new(dim, workers, seed);
    let opt_loss = problem.global_loss(&problem.optimum());
    let init_loss = problem.global_loss(&problem.backend(0).init_params()?);
    let init_sub = init_loss - opt_loss;

    let base = |h: u64, faulted: bool| {
        let mut c = ExperimentConfig::default();
        c.train.workers = workers;
        c.train.steps = steps;
        c.train.sync_period = SyncPeriod::Every(h);
        c.train.backend = Backend::RustMath;
        c.train.rust_math_dim = dim;
        c.train.seed = seed;
        c.train.log_every = steps;
        c.optim.algorithm = Algorithm::LocalAdaAlter;
        c.optim.warmup_steps = 50;
        if faulted {
            c.faults.slow_workers = 1;
            c.faults.slow_factor = slow_factor;
        }
        c
    };

    let variants: Vec<(&'static str, bool, ExperimentConfig)> = vec![
        ("clean H=4", false, base(4, false)),
        ("fault full H=4", false, base(4, true)),
        ("fault full H=16", false, base(16, true)),
        ("fault growing", false, {
            let mut c = base(4, true);
            c.sync.policy = "growing".into();
            c.sync.grow_every = 2;
            c.sync.h_max = 16;
            c
        }),
        ("fault quorum-7", true, {
            let mut c = base(4, true);
            c.train.fused = false;
            c.faults.quorum = workers.saturating_sub(1).max(1);
            c
        }),
        ("fault backup k=1", true, {
            let mut c = base(4, true);
            c.train.fused = false;
            c.faults.drop_slowest = 1;
            c
        }),
    ];

    println!("=== Straggler recovery: partial-participation sync under 1 slow worker (DESIGN.md §6) ===");
    println!(
        "(n={workers}, d={dim}, {steps} steps, worker {} runs {slow_factor}× slow; \
         init subopt {init_sub:.1}, irreducible optimum {opt_loss:.2}; \
         virtual time = paper-scale cluster)\n",
        workers - 1
    );
    println!(
        "{:<16} {:>7} {:>9} {:>11} {:>9} {:>10} {:>7}",
        "variant", "rounds", "MiB", "straggler-s", "total-s", "subopt", "part."
    );

    let mut rows: Vec<Row> = Vec::new();
    for (label, partial, cfg) in variants {
        let p = problem.clone();
        let factory: BackendFactory = Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));
        let r = Trainer::new(cfg, factory).run()?;
        let (rounds, bytes) = r.recorder.comm();
        let ev = &r.recorder.fault_events;
        let mean_participants = if ev.is_empty() {
            f64::NAN
        } else {
            ev.iter().map(|e| e.participants as f64).sum::<f64>() / ev.len() as f64
        };
        let row = Row {
            label,
            partial,
            rounds,
            mib: bytes as f64 / (1 << 20) as f64,
            straggler_s: r.clock.total(Charge::Straggler),
            total_s: r.clock.now_s(),
            subopt: r.final_eval.unwrap().loss - opt_loss,
            mean_participants,
            events_ok: ev.is_empty() || ev.len() as u64 == rounds,
        };
        println!(
            "{:<16} {:>7} {:>9.1} {:>11.1} {:>9.1} {:>10.4} {:>7.2}",
            row.label,
            row.rounds,
            row.mib,
            row.straggler_s,
            row.total_s,
            row.subopt,
            row.mean_participants
        );
        rows.push(row);
    }

    println!("\n=== checks ===");
    let clean = rows.iter().find(|r| r.label == "clean H=4").unwrap();
    let full = rows.iter().find(|r| r.label == "fault full H=4").unwrap();
    let penalty = full.total_s - clean.total_s;
    println!(
        "the slow worker costs the full barrier {penalty:.1}s over the clean run \
         ({:.0}% slower) {}",
        100.0 * penalty / clean.total_s,
        ok(penalty > 0.0)
    );
    let h16 = rows.iter().find(|r| r.label == "fault full H=16").unwrap();
    println!(
        "communicating less (H=16) does NOT fix the straggler \
         (recovers only {:.0}% of the penalty) {}",
        100.0 * (full.total_s - h16.total_s) / penalty,
        ok((full.total_s - h16.total_s) / penalty < 0.5)
    );
    // The acceptance claim: a partial-participation policy recovers ≥ 50%
    // of the straggler-induced wall-clock penalty at comparable loss.
    // "Comparable" = within max(1, 2× the full-barrier subopt, 1% of the
    // initial suboptimality) — dropping one replica's shard shifts the
    // survivors' optimum slightly, which is the price of not waiting.
    let loss_bar = (2.0 * full.subopt).max(1.0).max(0.01 * init_sub);
    let mut best: Option<(&Row, f64)> = None;
    for r in rows.iter().filter(|r| r.partial) {
        let recovery = (full.total_s - r.total_s) / penalty;
        println!(
            "{}: recovers {:.0}% of the penalty, subopt {:.3} (bar {loss_bar:.3}) {}",
            r.label,
            100.0 * recovery,
            r.subopt,
            ok(recovery >= 0.5 && r.subopt <= loss_bar)
        );
        if r.subopt <= loss_bar && best.map_or(true, |(_, b)| recovery > b) {
            best = Some((r, recovery));
        }
    }
    let recovered = best.map_or(0.0, |(_, rec)| rec);
    println!(
        "ACCEPTANCE: quorum or backup-worker sync recovers >= 50% of the \
         straggler penalty at comparable loss {}",
        ok(recovered >= 0.5)
    );
    println!(
        "every fault run logs one participation event per round {}",
        ok(rows.iter().all(|r| r.events_ok))
    );
    println!(
        "partial rounds drop only the straggler (mean participants ≈ n−1) {}",
        ok(rows
            .iter()
            .filter(|r| r.partial)
            .all(|r| (r.mean_participants - (workers as f64 - 1.0)).abs() < 0.5))
    );

    // Determinism: the same seed must reproduce the identical
    // faults_<tag>.csv byte for byte.
    let dir = std::env::temp_dir().join(format!("adaalter_straggler_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let mut csvs: Vec<Vec<u8>> = Vec::new();
    for i in 0..2 {
        let mut c = base(4, true);
        c.train.fused = false;
        c.faults.quorum = workers.saturating_sub(1).max(1);
        let p = problem.clone();
        let factory: BackendFactory = Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));
        let r = Trainer::new(c, factory).run()?;
        let path = dir.join(format!("faults_{i}.csv"));
        r.recorder.write_faults_csv(path.to_str().unwrap())?;
        csvs.push(std::fs::read(&path)?);
    }
    println!(
        "same seed reproduces the identical faults_<tag>.csv twice {}",
        ok(!csvs[0].is_empty() && csvs[0] == csvs[1])
    );
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
