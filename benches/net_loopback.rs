//! Loopback-deployment smoke bench (DESIGN.md §4): spawns a real
//! leader + 2 worker OS processes of the `adaalter` binary over TCP on
//! 127.0.0.1, runs a short Local AdaAlter experiment per wire codec, and
//! records the leader's socket byte counters from `net_report.json`.
//!
//! The ratcheted metric is `accounted_minus_booked_bytes` — the real
//! codec payload bytes that crossed the sockets minus the simulated α–β
//! accounting — which must be exactly 0 for every codec (the same pin
//! `integration_net` asserts per-cell). Wall-clock throughput is
//! reported as a `steps_per_s` rate, which only warns: loopback latency
//! depends on the host.

use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use adaalter::util::json::Json;
use adaalter::util::timing::BenchSink;

/// The compiled `adaalter` CLI binary under test.
const BIN: &str = env!("CARGO_BIN_EXE_adaalter");

/// Kill-on-drop child, so one failed role never strands the fleet.
struct Guard(Child);

impl Drop for Guard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Wait for a clean exit with a hard deadline (a deadlock must fail the
/// bench, not hang CI).
fn wait(g: &mut Guard, label: &str) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if let Some(st) = g.0.try_wait().expect("try_wait failed") {
            assert!(st.success(), "{label} failed: {st}");
            return;
        }
        assert!(Instant::now() < deadline, "{label} did not exit within 120s");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Run one loopback deployment and return its `net_report.json` plus the
/// end-to-end wall time (spawn through last exit) in seconds.
fn deploy(tag: &str, comm: &str, workers: usize, steps: u64) -> (Json, f64) {
    let dir = std::env::temp_dir().join(format!("adaalter_bench_net_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let dir = dir.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(format!("{dir}/leader.addr"));
    let _ = std::fs::remove_file(format!("{dir}/net_report.json"));
    let toml = format!(
        "[train]\n\
         workers = {workers}\n\
         sync_period = 4\n\
         steps = {steps}\n\
         log_every = 8\n\
         backend = \"rust_math\"\n\
         rust_math_dim = 64\n\
         [optim]\n\
         algorithm = \"local_adaalter\"\n\
         warmup_steps = 10\n\
         {comm}\
         [net]\n\
         listen = \"127.0.0.1:0\"\n\
         connect_timeout_s = 60.0\n"
    );
    let cfg = format!("{dir}/cfg.toml");
    std::fs::write(&cfg, toml).expect("write config");

    let t0 = Instant::now();
    let mut leader = Guard(
        Command::new(BIN)
            .args(["train", "--config", &cfg, "--role", "leader"])
            .args(["--port-file", &format!("{dir}/leader.addr")])
            .args(["--out-dir", &dir, "--quiet"])
            .stdout(Stdio::null())
            .spawn()
            .expect("spawn leader"),
    );
    let mut kids: Vec<Guard> = (0..workers)
        .map(|w| {
            Guard(
                Command::new(BIN)
                    .args(["train", "--config", &cfg, "--role", "worker"])
                    .args(["--worker-id", &w.to_string()])
                    .args(["--port-file", &format!("{dir}/leader.addr")])
                    .arg("--quiet")
                    .stdout(Stdio::null())
                    .spawn()
                    .expect("spawn worker"),
            )
        })
        .collect();
    for (w, g) in kids.iter_mut().enumerate() {
        wait(g, &format!("worker {w}"));
    }
    wait(&mut leader, "leader");
    let wall = t0.elapsed().as_secs_f64();

    let path = format!("{dir}/net_report.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    (Json::parse(&text).expect("net_report.json parses"), wall)
}

fn main() {
    let mut sink = BenchSink::new("net_loopback");
    let steps = 24u64;
    for (tag, comm) in [
        ("tcp_f32_laa_h4_w2", "[comm]\ntransport = \"tcp\"\n"),
        (
            "tcp_qsgd_laa_h4_w2",
            "[comm]\ntransport = \"tcp\"\ncompression = \"qsgd\"\nqsgd_levels = 15\n",
        ),
    ] {
        let (rep, wall) = deploy(tag, comm, 2, steps);
        let num = |k: &str| rep.req(k).unwrap().num().unwrap();
        let (booked, accounted, total) =
            (num("booked_bytes"), num("accounted_bytes"), num("total_bytes"));
        println!(
            "{tag:<24} booked {booked:>9.0} B  accounted {accounted:>9.0} B  \
             total {total:>9.0} B  wall {wall:.2}s"
        );
        sink.value(
            tag,
            &[
                ("accounted_minus_booked_bytes", accounted - booked),
                ("booked_bytes", booked),
                ("accounted_bytes", accounted),
                ("total_bytes", total),
                ("steps_per_s", steps as f64 / wall),
            ],
        );
    }
    sink.finish();
}
