//! Bench: regenerate **Figure 2** — throughput (samples/second) vs number
//! of workers, plus the §6.4 scaling observation. Machine-readable rows
//! land in `BENCH_fig2_throughput.json`.
//!
//! Run: `cargo bench --bench fig2_throughput`

use adaalter::config::SyncPeriod::{Every, Infinite};
use adaalter::sim::{EpochModel, SimAlgo};
use adaalter::util::timing::BenchSink;

fn main() {
    let m = EpochModel::paper();
    let ns = [1usize, 2, 4, 8];
    let algos = [
        SimAlgo::AdaGrad,
        SimAlgo::AdaAlter,
        SimAlgo::LocalAdaAlter(Every(4)),
        SimAlgo::LocalAdaAlter(Every(8)),
        SimAlgo::LocalAdaAlter(Every(12)),
        SimAlgo::LocalAdaAlter(Every(16)),
        SimAlgo::LocalAdaAlter(Infinite),
        SimAlgo::IdealComputeOnly,
    ];
    let mut sink = BenchSink::new("fig2_throughput");

    println!("=== Figure 2: throughput (samples/s) vs #workers ===\n");
    println!("{:<34} {:>9} {:>9} {:>9} {:>9}", "algorithm", "n=1", "n=2", "n=4", "n=8");
    for a in &algos {
        let row: Vec<String> =
            ns.iter().map(|&n| format!("{:>9.0}", m.throughput(*a, n))).collect();
        println!("{:<34} {}", a.label(), row.join(" "));
        let metrics: Vec<(String, f64)> = ns
            .iter()
            .map(|&n| (format!("samples_per_s_n{n}"), m.throughput(*a, n)))
            .collect();
        let refs: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        sink.value(&a.label(), &refs);
    }

    println!("\n=== shape checks ===");
    // Ordering at n=8: ideal > H=∞ > H=16 > … > H=4 > fully-sync.
    let mut vals: Vec<f64> = vec![
        m.throughput(SimAlgo::IdealComputeOnly, 8),
        m.throughput(SimAlgo::LocalAdaAlter(Infinite), 8),
        m.throughput(SimAlgo::LocalAdaAlter(Every(16)), 8),
        m.throughput(SimAlgo::LocalAdaAlter(Every(4)), 8),
        m.throughput(SimAlgo::AdaGrad, 8),
    ];
    let sorted = {
        let mut s = vals.clone();
        s.sort_by(|a, b| b.total_cmp(a));
        s
    };
    println!("throughput ordering at n=8 matches Fig. 2 {}", ok(vals == sorted));
    vals.dedup();

    // §6.4: sub-linear 4→8 scaling for everything except the ideal bound.
    for a in [SimAlgo::AdaGrad, SimAlgo::LocalAdaAlter(Every(4)), SimAlgo::LocalAdaAlter(Infinite)] {
        let r = m.throughput(a, 8) / m.throughput(a, 4);
        println!(
            "{:<34} 4→8 worker speedup ×{r:.2} (<2: dataloader bound) {}",
            a.label(),
            ok(r < 1.7)
        );
    }
    let r = m.throughput(SimAlgo::IdealComputeOnly, 8) / m.throughput(SimAlgo::IdealComputeOnly, 4);
    println!("{:<34} 4→8 worker speedup ×{r:.2} (=2: ideal) {}", "Ideal computation-only", ok((r - 2.0).abs() < 1e-9));
    sink.value("scaling_4_to_8", &[("ideal_speedup", r)]);

    sink.finish();
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
