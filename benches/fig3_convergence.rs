//! Bench: regenerate **Figure 3** — test perplexity vs training time (a)
//! and vs epochs (b) — by actually training the LM through the full stack
//! for each algorithm, on the scaled-down testbed.
//!
//! Run: `cargo bench --bench fig3_convergence`
//! Knobs: ADAALTER_BENCH_STEPS (default 120), ADAALTER_BENCH_WORKERS (2).
//!
//! Requires `make artifacts`; prints a skip notice otherwise.

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::factory::make_factory;
use adaalter::coordinator::Trainer;
use adaalter::runtime::artifacts_available;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    if !artifacts_available("artifacts") {
        println!("fig3_convergence: artifacts/ not built (run `make artifacts`); skipping");
        return Ok(());
    }
    let steps: u64 = env_or("ADAALTER_BENCH_STEPS", 120);
    let workers: usize = env_or("ADAALTER_BENCH_WORKERS", 2);

    let variants: Vec<(Algorithm, SyncPeriod, &str)> = vec![
        (Algorithm::AdaGrad, SyncPeriod::Every(1), "AdaGrad"),
        (Algorithm::AdaAlter, SyncPeriod::Every(1), "AdaAlter"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(4), "Local AdaAlter, H=4"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(16), "Local AdaAlter, H=16"),
    ];

    println!("=== Figure 3: test PPL vs virtual time / epochs ===");
    println!("(tiny preset, {workers} workers, {steps} steps; virtual time = paper-scale cluster)\n");

    let mut results = Vec::new();
    for (algo, h, label) in &variants {
        let mut cfg = ExperimentConfig::default();
        cfg.train.preset = "tiny".into();
        cfg.train.backend = Backend::Pjrt;
        cfg.train.workers = workers;
        cfg.train.steps = steps;
        cfg.train.steps_per_epoch = (steps / 4).max(1);
        cfg.train.sync_period = *h;
        cfg.train.eval_every = (steps / 6).max(1);
        cfg.train.log_every = steps;
        cfg.optim.algorithm = *algo;
        cfg.optim.warmup_steps = steps / 5;
        cfg.data.eval_batches = 2;

        let r = Trainer::new(cfg.clone(), make_factory(&cfg)?).run()?;
        println!("{label}:");
        println!("  {:>6} {:>7} {:>12} {:>10}", "step", "epoch", "virtual-h", "test-PPL");
        for e in &r.recorder.evals {
            println!(
                "  {:>6} {:>7.2} {:>12.3} {:>10.3}",
                e.step,
                e.epoch,
                e.virtual_s / 3600.0,
                e.ppl.unwrap_or(f64::NAN)
            );
        }
        let last = r.recorder.evals.last().unwrap();
        results.push((label.to_string(), last.ppl.unwrap(), last.virtual_s));
    }

    println!("\n=== shape checks (paper §6.3.2) ===");
    let find = |name: &str| results.iter().find(|(l, _, _)| l == name).unwrap().clone();
    let adagrad = find("AdaGrad");
    let adaalter = find("AdaAlter");
    let h4 = find("Local AdaAlter, H=4");
    let h16 = find("Local AdaAlter, H=16");

    println!(
        "AdaAlter PPL ≈ AdaGrad PPL ({:.2} vs {:.2}, same #epochs) {}",
        adaalter.1,
        adagrad.1,
        ok((adaalter.1 - adagrad.1).abs() / adagrad.1 < 0.15)
    );
    println!(
        "Local H=4 PPL within 15% of fully-sync ({:.2} vs {:.2}) {}",
        h4.1,
        adagrad.1,
        ok((h4.1 - adagrad.1).abs() / adagrad.1 < 0.15)
    );
    // The time saving is n-dependent (only ~11% at n=2, ~29% at n=8):
    // check the measured ratio against the Fig. 1 analytic model at THIS n.
    let em = adaalter::sim::EpochModel::paper();
    let model_ratio = em.iter_cost(adaalter::sim::SimAlgo::LocalAdaAlter(SyncPeriod::Every(4)), workers).total_s()
        / em.iter_cost(adaalter::sim::SimAlgo::AdaGrad, workers).total_s();
    let measured_ratio = h4.2 / adagrad.2;
    println!(
        "Local H=4 time ratio vs AdaGrad: measured {:.3}, Fig.1 model {:.3} (n={workers}) {}",
        measured_ratio,
        model_ratio,
        ok((measured_ratio - model_ratio).abs() < 0.05)
    );
    println!(
        "H=16 faster than H=4 in time ({:.3} h vs {:.3} h) {}",
        h16.2 / 3600.0,
        h4.2 / 3600.0,
        ok(h16.2 <= h4.2)
    );
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
