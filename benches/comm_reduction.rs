//! Bench: bytes-on-the-wire — Local AdaAlter's 2/H reduction vs the
//! compression baselines the paper's §1 cites (QSGD quantization, top-k
//! sparsification), at equal iteration counts.
//!
//! This is the related-work comparison the paper frames in prose, made
//! quantitative on our substrate: per-iteration average bytes shipped per
//! worker for a d-parameter model, plus achieved convergence of each
//! scheme on the synthetic problem (same step budget, same data).
//!
//! Run: `cargo bench --bench comm_reduction`

use adaalter::comm::{QsgdQuantizer, TopKSparsifier};
use adaalter::coordinator::WorkerBackend;
use adaalter::sim::SyntheticProblem;
use adaalter::util::rng::Rng;

const D: usize = 4096;
const N: usize = 4;
const STEPS: u64 = 600;
const ETA: f32 = 0.4;

/// Fully-sync SGD with a per-gradient transform (identity / qsgd / topk).
fn run_compressed(mode: &str, problem: &SyntheticProblem) -> (f64, u64) {
    let mut backends: Vec<_> = (0..N).map(|w| problem.backend(w)).collect();
    let mut x = backends[0].init_params().unwrap();
    let mut g = vec![0.0f32; D];
    let mut dec = vec![0.0f32; D];
    let mut rng = Rng::new(11);
    let q = QsgdQuantizer::new(4);
    let mut sparsifiers: Vec<_> = (0..N).map(|_| TopKSparsifier::new(D, 0.05)).collect();
    let mut bytes = 0u64;
    let warmup = 40u64;

    // Per-scheme stable learning rates: plain SGD needs lr < 2/L; QSGD's
    // quantization variance is amplified ~sqrt(d)/s (Alistarh et al. Lemma
    // 3.1 — 16x here), so its stable lr is correspondingly smaller. This IS
    // the trade-off the bench documents.
    let lr_scale = match mode {
        "dense" => 0.25,
        "topk" => 0.25,
        "qsgd" => 0.25 / 16.0,
        _ => unreachable!(),
    };
    for t in 1..=STEPS {
        let lr = ETA * (t as f32 / warmup as f32).min(1.0) * lr_scale;
        let mut avg = vec![0.0f32; D];
        for (w, b) in backends.iter_mut().enumerate() {
            b.loss_and_grad(&x, t, &mut g).unwrap();
            match mode {
                "dense" => {
                    bytes += 4 * D as u64;
                    for (a, &v) in avg.iter_mut().zip(&g) {
                        *a += v / N as f32;
                    }
                }
                "qsgd" => {
                    let enc = q.encode(&g, &mut rng);
                    bytes += q.wire_bytes(D);
                    q.decode(&enc, &mut dec);
                    for (a, &v) in avg.iter_mut().zip(&dec) {
                        *a += v / N as f32;
                    }
                }
                "topk" => {
                    let msg = sparsifiers[w].encode(&g);
                    bytes += msg.wire_bytes();
                    for (&i, &v) in msg.idx.iter().zip(&msg.val) {
                        avg[i as usize] += v / N as f32;
                    }
                }
                _ => unreachable!(),
            }
        }
        for (xi, &gi) in x.iter_mut().zip(&avg) {
            *xi -= lr * gi;
        }
    }
    let subopt = problem.global_loss(&x) - problem.global_loss(&problem.optimum());
    (subopt, bytes / STEPS / N as u64)
}

/// Local AdaAlter at period H (the paper's scheme) for the same budget.
fn run_local_adaalter(h: u64, problem: &SyntheticProblem) -> (f64, u64) {
    use adaalter::optim::LocalAdaAlterWorker;
    let mut backends: Vec<_> = (0..N).map(|w| problem.backend(w)).collect();
    let init = backends[0].init_params().unwrap();
    let mut ws: Vec<_> = (0..N)
        .map(|_| LocalAdaAlterWorker::new(init.clone(), 1.0, 1.0))
        .collect();
    let mut g = vec![0.0f32; D];
    let mut bytes = 0u64;
    let warmup = 40u64;
    for t in 1..=STEPS {
        let lr = ETA * (t as f32 / warmup as f32).min(1.0);
        for (w, b) in ws.iter_mut().zip(backends.iter_mut()) {
            b.loss_and_grad(w.x(), t, &mut g).unwrap();
            w.local_step(&g, lr);
        }
        if t % h == 0 {
            // 2 vectors per worker per sync (params + denominators).
            bytes += 2 * 4 * D as u64 * N as u64;
            let mut avg_x = vec![0.0f32; D];
            let mut avg_a = vec![0.0f32; D];
            let xs: Vec<&[f32]> = ws.iter().map(|w| w.x()).collect();
            adaalter::util::math::mean_into(&xs, &mut avg_x);
            let accs: Vec<&[f32]> = ws.iter().map(|w| w.acc()).collect();
            adaalter::util::math::mean_into(&accs, &mut avg_a);
            for w in ws.iter_mut() {
                w.apply_sync(&avg_x, &avg_a);
            }
        }
    }
    let xs: Vec<&[f32]> = ws.iter().map(|w| w.x()).collect();
    let mut avg_x = vec![0.0f32; D];
    adaalter::util::math::mean_into(&xs, &mut avg_x);
    let subopt = problem.global_loss(&avg_x) - problem.global_loss(&problem.optimum());
    (subopt, bytes / STEPS / N as u64)
}

fn main() {
    println!("=== Communication reduction: local AdaAlter vs compression ===");
    println!("(d={D}, n={N}, {STEPS} steps; dense f32 gradient = {} B)\n", 4 * D);
    println!(
        "{:<28} {:>14} {:>12} {:>16}",
        "scheme", "B/iter/worker", "vs dense", "final subopt"
    );
    let problem = SyntheticProblem::new(D, N, 5);
    let mut rows: Vec<(String, u64, f64)> = Vec::new();
    for mode in ["dense", "qsgd", "topk"] {
        let (subopt, bytes) = run_compressed(mode, &problem);
        rows.push((format!("sync SGD + {mode}"), bytes, subopt));
    }
    for h in [4u64, 16] {
        let (subopt, bytes) = run_local_adaalter(h, &problem);
        rows.push((format!("local AdaAlter H={h}"), bytes, subopt));
    }
    let dense = rows[0].1 as f64;
    for (name, bytes, subopt) in &rows {
        println!(
            "{name:<28} {bytes:>14} {:>11.1}x {subopt:>16.4}",
            dense / *bytes as f64
        );
    }

    println!("\n=== checks ===");
    let find = |n: &str| rows.iter().find(|(x, _, _)| x.contains(n)).unwrap().clone();
    let (_, b_h4, s_h4) = find("H=4");
    let (_, b_h16, _) = find("H=16");
    let (_, b_qsgd, _) = find("qsgd");
    println!(
        "local AdaAlter H=4 ships 2/H = 1/2 of dense ({b_h4} vs {} B) {}",
        rows[0].1,
        ok((b_h4 as f64 / dense - 0.5).abs() < 0.05)
    );
    println!(
        "H=16 ships 2/16 = 1/8 of dense {}",
        ok((b_h16 as f64 / dense - 0.125).abs() < 0.02)
    );
    println!(
        "QSGD(s=4) ships ~1/8 of dense (4 bits + norm) {}",
        ok((0.1..0.2).contains(&(b_qsgd as f64 / dense)))
    );
    let (_, _, s_dense) = rows[0].clone();
    println!(
        "local AdaAlter H=4 converges at least as well as dense sync SGD at \
         half the traffic ({s_h4:.2} vs {s_dense:.2}) {}",
        ok(s_h4 <= 1.2 * s_dense)
    );
    let (_, _, s_qsgd) = find("qsgd");
    let init = problem.global_loss(&problem.backend(0).init_params().unwrap())
        - problem.global_loss(&problem.optimum());
    println!(
        "qsgd/topk make progress but pay a variance penalty at equal bytes \
         (qsgd subopt {s_qsgd:.1} < init {init:.1}; needed 16x smaller lr) {}",
        ok(s_qsgd < init)
    );
    println!(
        "\nnote: compression reduces BYTES but still pays a message EVERY \
         iteration (latency-bound at scale); local SGD reduces ROUNDS — \
         the orthogonal axis the paper targets (§1–2)."
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
