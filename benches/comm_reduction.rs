//! Bench: bytes-on-the-wire — Local AdaAlter's 2/H reduction vs the
//! compression baselines the paper's §1 cites (QSGD quantization, top-k
//! sparsification), at equal iteration counts, **through the full trainer**.
//!
//! Every row is one `ExperimentConfig`: the transport (uncompressed
//! parameter server, ring all-reduce, QSGD s=15, top-k 1%, and the bf16
//! half-width wire from `[precision]`) is selected purely by the
//! `[comm]` / `[net]` / `[precision]` sections and the recorded traffic
//! is whatever the configured `Collective` actually billed — model-scale
//! α–β traffic for the simulated transports, exact encoded wire bytes for
//! the compressed ones (bf16 bills exactly 2 B/element, half of dense).
//!
//! Run: `cargo bench --bench comm_reduction`

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, Trainer, WorkerBackend};
use adaalter::sim::SyntheticProblem;

const D: usize = 4096;
const N: usize = 4;
const STEPS: u64 = 480;

struct Row {
    name: String,
    transport: String,
    bytes_per_iter_worker: u64,
    total_bytes: u64,
    subopt: f64,
}

fn base_cfg(algo: Algorithm, h: SyncPeriod) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.train.workers = N;
    c.train.steps = STEPS;
    c.train.sync_period = if algo.is_local() { h } else { SyncPeriod::Every(1) };
    c.train.backend = Backend::RustMath;
    c.train.rust_math_dim = D;
    c.train.seed = 5;
    c.optim.algorithm = algo;
    c.optim.warmup_steps = 40;
    c
}

fn run_row(name: &str, cfg: ExperimentConfig, problem: &SyntheticProblem) -> Row {
    let p = problem.clone();
    let f: BackendFactory = Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));
    let r = Trainer::new(cfg, f).run().expect("bench run failed");
    let opt_loss = problem.global_loss(&problem.optimum());
    let (_, bytes) = r.recorder.comm();
    Row {
        name: name.into(),
        transport: r.recorder.transport().to_string(),
        bytes_per_iter_worker: bytes / STEPS / N as u64,
        total_bytes: bytes,
        subopt: r.final_eval.expect("eval").loss - opt_loss,
    }
}

fn with_comm(mut c: ExperimentConfig, transport: &str, compression: &str) -> ExperimentConfig {
    c.comm.transport = transport.into();
    c.comm.compression = compression.into();
    c
}

fn main() {
    println!("=== Communication reduction: transports selected via ExperimentConfig ===");
    println!("(d={D}, n={N}, {STEPS} steps; dense f32 vector = {} B)\n", 4 * D);
    let problem = SyntheticProblem::new(D, N, 5);

    let mut rows: Vec<Row> = Vec::new();

    // The paper's scheme over the four transports the config can name.
    let la = |h| base_cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h));
    rows.push(run_row("local AdaAlter H=4 / PS dense", la(4), &problem));
    {
        let mut c = la(4);
        c.net.topology = "allreduce".into();
        rows.push(run_row("local AdaAlter H=4 / ring all-reduce", c, &problem));
    }
    {
        let mut c = with_comm(la(4), "channel", "qsgd");
        c.comm.qsgd_levels = 15;
        rows.push(run_row("local AdaAlter H=4 / QSGD s=15", c, &problem));
    }
    {
        let mut c = with_comm(la(4), "channel", "topk");
        c.comm.topk_keep = 0.01;
        rows.push(run_row("local AdaAlter H=4 / top-k 1%", c, &problem));
    }
    {
        // The PR 6 wire format: bf16 payloads (2 B/elem) composed with the
        // same delta coding the lossy codecs use — `[precision]` only.
        let mut c = with_comm(la(4), "channel", "none");
        c.precision.wire = "bf16".into();
        rows.push(run_row("local AdaAlter H=4 / bf16+delta wire", c, &problem));
    }

    // The 2/H sweep against fully-synchronous AdaGrad (the paper's claim).
    rows.push(run_row(
        "sync AdaGrad / PS dense",
        base_cfg(Algorithm::AdaGrad, SyncPeriod::Every(1)),
        &problem,
    ));
    rows.push(run_row("local AdaAlter H=16 / PS dense", la(16), &problem));

    println!(
        "{:<40} {:<22} {:>14} {:>12} {:>14}",
        "scheme", "transport", "B/iter/worker", "vs sync", "final subopt"
    );
    let sync_bytes = rows
        .iter()
        .find(|r| r.name.starts_with("sync AdaGrad"))
        .expect("sync row")
        .total_bytes as f64;
    for r in &rows {
        println!(
            "{:<40} {:<22} {:>14} {:>11.3}x {:>14.4}",
            r.name,
            r.transport,
            r.bytes_per_iter_worker,
            sync_bytes / r.total_bytes as f64,
            r.subopt
        );
    }

    println!("\n=== checks ===");
    let find = |needle: &str| rows.iter().find(|r| r.name.contains(needle)).unwrap();
    let h4 = find("H=4 / PS dense");
    let h16 = find("H=16");
    let ring = find("ring");
    let qsgd = find("QSGD");
    let topk = find("top-k");
    let bf16 = find("bf16");
    let sync = find("sync AdaGrad");

    println!(
        "H=4 ships exactly 2/H = 1/2 of fully-sync traffic ({} vs {}) {}",
        h4.total_bytes,
        sync.total_bytes,
        ok(h4.total_bytes * 2 == sync.total_bytes)
    );
    println!(
        "H=16 ships exactly 2/16 = 1/8 {}",
        ok(h16.total_bytes * 8 == sync.total_bytes)
    );
    println!(
        "ring all-reduce moves 2(n-1)/2n = {}/{} of PS traffic {}",
        N - 1,
        N,
        ok(ring.total_bytes * N as u64 == h4.total_bytes * (N as u64 - 1))
    );
    println!(
        "QSGD s=15 (5-bit codes) cuts H=4 round bytes >4x below dense {}",
        ok(qsgd.total_bytes * 4 < h4.total_bytes)
    );
    println!(
        "top-k 1% cuts them >20x {}",
        ok(topk.total_bytes * 20 < h4.total_bytes)
    );
    println!(
        "bf16 wire halves H=4 round bytes EXACTLY ({} vs {}) {}",
        bf16.total_bytes,
        h4.total_bytes,
        ok(bf16.total_bytes * 2 == h4.total_bytes)
    );
    {
        // Simulated PS round time at this run's payload: one H=4 sync
        // round ships 2 vectors per worker each way — f32 vs bf16.
        let net = adaalter::comm::NetModel::from_config(&Default::default());
        let f32_bytes = net.sync_traffic_bytes(N, 4 * D as u64, 2);
        let t_f32 = net.bytes_time(N, f32_bytes);
        let t_bf16 = net.bytes_time(N, f32_bytes / 2);
        println!(
            "modeled PS round time: f32 {:.1} us vs bf16 {:.1} us ({:.2}x) {}",
            t_f32 * 1e6,
            t_bf16 * 1e6,
            t_f32 / t_bf16,
            ok(t_bf16 < t_f32)
        );
    }
    let init = problem.global_loss(&problem.backend(0).init_params().unwrap())
        - problem.global_loss(&problem.optimum());
    println!(
        "every transport still optimizes (subopt << init {init:.1}) {}",
        ok(rows.iter().all(|r| r.subopt.is_finite() && r.subopt < 0.2 * init))
    );
    println!(
        "\nnote: compression cuts BYTES but still pays a round EVERY sync; \
         local AdaAlter cuts ROUNDS (2/H) — and the config lets you stack \
         the two (compressed local AdaAlter), the scenario family the paper \
         frames only in prose."
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
