//! Bench: regenerate **Table 2** — final test PPL (mean ± std over seeds)
//! and total training time for AdaGrad, AdaAlter, and Local AdaAlter with
//! H ∈ {4, 8, 12, 16}, on the scaled-down testbed.
//!
//! Run: `cargo bench --bench table2_final_ppl`
//! Knobs: ADAALTER_BENCH_STEPS (default 120), ADAALTER_BENCH_SEEDS (2),
//!        ADAALTER_BENCH_WORKERS (2).

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::factory::make_factory;
use adaalter::coordinator::Trainer;
use adaalter::runtime::artifacts_available;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n.max(1.0);
    (mean, var.sqrt())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    if !artifacts_available("artifacts") {
        println!("table2_final_ppl: artifacts/ not built (run `make artifacts`); skipping");
        return Ok(());
    }
    let steps: u64 = env_or("ADAALTER_BENCH_STEPS", 120);
    let seeds: u64 = env_or("ADAALTER_BENCH_SEEDS", 2);
    let workers: usize = env_or("ADAALTER_BENCH_WORKERS", 2);

    let rows: Vec<(Algorithm, SyncPeriod, &str)> = vec![
        (Algorithm::AdaGrad, SyncPeriod::Every(1), "AdaGrad"),
        (Algorithm::AdaAlter, SyncPeriod::Every(1), "AdaAlter"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(4), "Local AdaAlter H=4"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(8), "Local AdaAlter H=8"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(12), "Local AdaAlter H=12"),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(16), "Local AdaAlter H=16"),
    ];

    println!("=== Table 2: final test PPL and (virtual) training time ===");
    println!("({} seeds × {} steps, tiny preset, {} workers)\n", seeds, steps, workers);
    println!("{:<24} {:>18} {:>14}", "Method", "Test PPL", "Time (virt. h)");

    let mut summary = Vec::new();
    for (algo, h, label) in &rows {
        let mut ppls = Vec::new();
        let mut hours = Vec::new();
        for seed in 0..seeds {
            let mut cfg = ExperimentConfig::default();
            cfg.train.preset = "tiny".into();
            cfg.train.backend = Backend::Pjrt;
            cfg.train.workers = workers;
            cfg.train.steps = steps;
            cfg.train.steps_per_epoch = (steps / 4).max(1);
            cfg.train.sync_period = *h;
            cfg.train.seed = 1000 + seed;
            cfg.train.log_every = steps;
            cfg.optim.algorithm = *algo;
            cfg.optim.warmup_steps = steps / 5;
            cfg.data.eval_batches = 2;

            let r = Trainer::new(cfg.clone(), make_factory(&cfg)?).run()?;
            ppls.push(r.final_eval.unwrap().ppl.unwrap());
            hours.push(r.clock.now_s() / 3600.0);
        }
        let (pm, ps) = mean_std(&ppls);
        let (tm, _) = mean_std(&hours);
        println!("{label:<24} {:>11.2} ± {:>4.2} {:>14.3}", pm, ps, tm);
        summary.push((label.to_string(), pm, tm));
    }

    println!("\n=== shape checks (Table 2 structure) ===");
    let t = |name: &str| summary.iter().find(|(l, _, _)| l == name).unwrap().2;
    let p = |name: &str| summary.iter().find(|(l, _, _)| l == name).unwrap().1;
    let mut time_monotone = true;
    for w in ["Local AdaAlter H=4", "Local AdaAlter H=8", "Local AdaAlter H=12", "Local AdaAlter H=16"].windows(2) {
        time_monotone &= t(w[1]) <= t(w[0]) + 1e-9;
    }
    println!("time decreases with H {}", ok(time_monotone));
    println!(
        "all local variants faster than AdaGrad ({:.3} h) {}",
        t("AdaGrad"),
        ok(t("Local AdaAlter H=4") < t("AdaGrad"))
    );
    let ppl_ratio = p("Local AdaAlter H=4") / p("AdaGrad");
    println!(
        "H=4 PPL within 15% of AdaGrad (ratio {ppl_ratio:.3}) {}",
        ok((0.85..1.15).contains(&ppl_ratio))
    );
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
