//! Bench: topology / shard-count scaling of one synchronization round
//! under the α–β cost model (DESIGN.md §3) — the ROADMAP item-2 claim
//! that the single-leader PS incast is the scaling bottleneck, and that
//! both the k-shard parameter server (`comm.shards = k`) and the fan-out
//! tree reduction (`net.topology = "tree"`) remove it.
//!
//! Pure model math (no wall clock): every number replicates the
//! `NetModel` f64 arithmetic exactly, so the `traffic_bytes` metrics are
//! ratcheted bit-exact by `tools/bench_diff.rs` and the `speedup`
//! metrics are conservative warn-only floors.
//!
//! Run: `cargo bench --bench topology_scaling`

use adaalter::comm::{tree_depth, NetModel};
use adaalter::config::NetConfig;
use adaalter::util::timing::BenchSink;

/// A cost model for one (topology, fan-out, shards) cell, at the default
/// calibration (α = 50 µs, β = β_server = 132 GB/s).
fn model(topology: &str, fanout: usize, shards: usize) -> NetModel {
    let cfg = NetConfig { topology: topology.into(), tree_fanout: fanout, ..Default::default() };
    NetModel::from_config(&cfg).with_shards(shards)
}

fn main() {
    // Paper-scale payload: a 33M-parameter f32 vector, shipped twice per
    // round (params + AdaGrad denominators — Alg. 4 lines 11–12).
    let d = 33_000_000u64;
    let payload = 4 * d;
    let vectors = 2u64;
    let ns = [8usize, 32, 64];
    let configs: [(&str, &str, usize, usize); 5] = [
        ("ps_k1", "ps", 2, 1),
        ("ps_k4", "ps", 2, 4),
        ("ps_k8", "ps", 2, 8),
        ("tree_f2", "tree", 2, 1),
        ("tree_f4", "tree", 4, 1),
    ];
    let base = model("ps", 2, 1);
    let mut sink = BenchSink::new("topology_scaling");

    println!("=== sync-round time (s) vs n — α–β model, {d} f32 params × {vectors} vectors ===\n");
    println!("{:<10} {:>12} {:>12} {:>12}", "config", "n=8", "n=32", "n=64");
    for (name, topo, fanout, shards) in configs {
        let m = model(topo, fanout, shards);
        let mut metrics: Vec<(String, f64)> = Vec::new();
        let mut row = String::new();
        for &n in &ns {
            let t = m.sync_time(n, payload, vectors);
            row.push_str(&format!(" {t:>12.5}"));
            metrics.push((format!("traffic_bytes_n{n}"), m.sync_traffic_bytes(n, payload, vectors) as f64));
            metrics.push((format!("round_time_s_n{n}"), t));
            metrics.push((
                format!("speedup_vs_single_leader_n{n}"),
                base.sync_time(n, payload, vectors) / t,
            ));
        }
        println!("{name:<10}{row}");
        let refs: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        sink.value(name, &refs);
    }

    // The tentpole shape claim: by n = 32 the single-leader incast loses
    // to every alternative (tree_f4 may still trail at n = 8 — two deep
    // levels of 4-way serialisation against a mild 8-way incast).
    println!("\n=== shape checks ===");
    for &n in &[32usize, 64] {
        let ps = base.sync_time(n, payload, vectors);
        for (name, topo, fanout, shards) in
            [("ps_k4", "ps", 2, 4), ("ps_k8", "ps", 2, 8), ("tree_f2", "tree", 2, 1), ("tree_f4", "tree", 4, 1)]
        {
            let t = model(topo, fanout, shards).sync_time(n, payload, vectors);
            println!(
                "n={n:<3} {name:<8} {t:>9.5}s vs single-leader {ps:>9.5}s — ×{:.2} {}",
                ps / t,
                ok(t < ps)
            );
            assert!(t < ps, "{name} must beat the single-leader incast at n={n}");
        }
    }
    println!(
        "\ntree depth at n=64: ⌈log₂⌉ = {} levels, ⌈log₄⌉ = {} levels",
        tree_depth(64, 2),
        tree_depth(64, 4)
    );
    sink.finish();
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
