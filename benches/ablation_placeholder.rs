//! Ablation bench: WHY AdaAlter's swapped update order + t'·ε² placeholder
//! matter — the design argument of paper §4.2–4.3, made measurable.
//!
//! Compares three local-update rules on the synthetic non-IID problem
//! (hand-rolled loop, no trainer, so the ablated variant needs no config
//! plumbing):
//!
//!   A. Local AdaAlter (Alg. 4)    — placeholder denominator; B² identical
//!                                   on every worker between syncs.
//!   B. "Naive local AdaGrad"      — each worker accumulates its OWN B²
//!                                   from local gradients (the obvious-but-
//!                                   wrong way to make AdaGrad local);
//!                                   denominators drift apart.
//!   C. Local AdaAlter w/o ε-placeholder — update divides by the stale
//!                                   B²_sync only (denom_add = ε² fixed,
//!                                   not t'·ε²): early steps oversized.
//!
//! Reported: (1) cross-worker denominator spread right before each sync
//! (zero for A by construction — the property Theorem 2's proof uses);
//! (2) final suboptimality at equal step budget.
//!
//! Run: `cargo bench --bench ablation_placeholder`

use adaalter::config::SyncPeriod;
use adaalter::coordinator::{SyncScheduler, WorkerBackend};
use adaalter::sim::SyntheticProblem;
use adaalter::util::math;

const D: usize = 2048;
const N: usize = 8;
const H: u64 = 8;
const STEPS: u64 = 800;
const ETA: f32 = 0.5;
const EPS2: f32 = 1.0;

struct W {
    x: Vec<f32>,
    b2_sync: Vec<f32>,
    acc: Vec<f32>,
}

fn average(fields: Vec<&[f32]>, out: &mut [f32]) {
    math::mean_into(&fields, out);
}

/// Run one variant; returns (mean pre-sync denominator spread, final subopt).
fn run(variant: &str, problem: &SyntheticProblem) -> (f64, f64) {
    let mut backends: Vec<_> = (0..N).map(|w| problem.backend(w)).collect();
    let init = backends[0].init_params().unwrap();
    let mut ws: Vec<W> = (0..N)
        .map(|_| W { x: init.clone(), b2_sync: vec![1.0; D], acc: vec![1.0; D] })
        .collect();
    let mut g = vec![0.0f32; D];
    let mut spread_sum = 0.0f64;
    let mut spreads = 0u64;
    let warmup = 50u64;
    // The library's scheduler owns the sync-period arithmetic (t', the
    // sync predicate) so this bench cannot drift from the trainer.
    let sched = SyncScheduler::new(SyncPeriod::Every(H));

    for t in 1..=STEPS {
        let lr = ETA * (t as f32 / warmup as f32).min(1.0);
        let t_prime = sched.t_prime(t);
        for (w, b) in ws.iter_mut().zip(backends.iter_mut()) {
            b.loss_and_grad(&w.x, t, &mut g).unwrap();
            match variant {
                "adaalter" | "no_placeholder" => {
                    let add = if variant == "adaalter" { t_prime as f32 * EPS2 } else { EPS2 };
                    for j in 0..D {
                        w.x[j] -= lr * g[j] / (w.b2_sync[j] + add).sqrt();
                        w.acc[j] += g[j] * g[j];
                    }
                }
                "naive_adagrad" => {
                    // accumulate-first with the WORKER-LOCAL accumulator —
                    // denominators depend on each worker's own gradients.
                    for j in 0..D {
                        w.acc[j] += g[j] * g[j];
                        w.x[j] -= lr * g[j] / (w.acc[j] + EPS2).sqrt();
                    }
                }
                _ => unreachable!(),
            }
        }
        if sched.is_sync_step(t) {
            // Denominator disagreement right before averaging: the quantity
            // Local AdaAlter keeps at 0 between syncs (b2_sync identical),
            // and naive local AdaGrad lets drift (per-worker acc used).
            let live: Vec<&[f32]> = ws
                .iter()
                .map(|w| {
                    if variant == "naive_adagrad" {
                        w.acc.as_slice()
                    } else {
                        w.b2_sync.as_slice()
                    }
                })
                .collect();
            // Pairwise vs worker 0 — exactly 0 when denominators are
            // identical (averaging against the mean would read float
            // rounding of the 8-way sum as fake drift).
            let spread: f64 = live[1..]
                .iter()
                .map(|v| math::max_abs_diff(v, live[0]) as f64)
                .fold(0.0, f64::max);
            spread_sum += spread;
            spreads += 1;

            // Sync round: average x and acc; install.
            let xs: Vec<&[f32]> = ws.iter().map(|w| w.x.as_slice()).collect();
            let mut avg_x = vec![0.0f32; D];
            average(xs, &mut avg_x);
            let accs: Vec<&[f32]> = ws.iter().map(|w| w.acc.as_slice()).collect();
            let mut avg_acc = vec![0.0f32; D];
            average(accs, &mut avg_acc);
            for w in ws.iter_mut() {
                w.x.copy_from_slice(&avg_x);
                w.acc.copy_from_slice(&avg_acc);
                w.b2_sync.copy_from_slice(&avg_acc);
            }
        }
    }
    let xs: Vec<&[f32]> = ws.iter().map(|w| w.x.as_slice()).collect();
    let mut avg_x = vec![0.0f32; D];
    average(xs, &mut avg_x);
    let subopt = problem.global_loss(&avg_x) - problem.global_loss(&problem.optimum());
    (spread_sum / spreads.max(1) as f64, subopt)
}

fn main() {
    println!("=== Ablation: the placeholder denominator (paper §4.2–4.3) ===");
    println!("(synthetic non-IID, d={D}, n={N}, H={H}, {STEPS} steps)\n");
    println!(
        "{:<28} {:>26} {:>18}",
        "variant", "pre-sync denom spread", "final subopt"
    );
    let problem = SyntheticProblem::new(D, N, 7);
    let mut rows = Vec::new();
    for v in ["adaalter", "naive_adagrad", "no_placeholder"] {
        let (spread, subopt) = run(v, &problem);
        println!("{v:<28} {spread:>26.4} {subopt:>18.6}");
        rows.push((v, spread, subopt));
    }

    println!("\n=== checks ===");
    let get = |name: &str| rows.iter().find(|(v, _, _)| *v == name).unwrap().clone();
    let (_, s_aa, l_aa) = get("adaalter");
    let (_, s_ng, l_ng) = get("naive_adagrad");
    let (_, _, l_np) = get("no_placeholder");
    println!(
        "AdaAlter keeps the update denominator IDENTICAL across workers \
         (spread {s_aa:.1e}) {}",
        ok(s_aa == 0.0)
    );
    println!(
        "naive local AdaGrad denominators drift (spread {s_ng:.3}) {}",
        ok(s_ng > 0.0)
    );
    // NOTE the honest reading: on a smooth quadratic the naive variant can
    // converge fine — its failure mode is the *inconsistent objective*
    // (workers divide by different denominators), which breaks the
    // Theorem 2 analysis and bites under heterogeneity/scale, not here.
    // What we check is exactly what §4.3 claims: consistency, bounded cost.
    println!(
        "all variants converge on the smooth problem (subopt {l_aa:.3} / \
         {l_ng:.3} / {l_np:.3} < 1) {}",
        ok(l_aa < 1.0 && l_ng < 1.0 && l_np < 1.0)
    );
    println!(
        "placeholder damping costs ≤2.5x suboptimality vs its no-placeholder \
         ablation at equal steps ({l_aa:.3} vs {l_np:.3}) — the price of the \
         proof-carrying denominator {}",
        ok(l_aa <= l_np * 2.5)
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
