//! Elastic-membership recovery bench (DESIGN.md §10): how fast a churned
//! fleet returns to parity with an undisturbed one.
//!
//! Three in-process recovery scenarios on the synthetic backend, all
//! driven by seeded `[faults]` plans (pure functions of
//! `(seed, worker, step)`, so every number here is deterministic):
//!
//! * `rejoin_recovery` — a worker crashes and rejoins; recovery-time-to-
//!   parity is the number of steps after re-admission until the churned
//!   run's loss trajectory stays within 0.1% of the uninterrupted run's.
//! * `scaleup_recovery` — the fleet grows from 3 to 4 workers mid-run
//!   (`spawn_workers`); parity is measured against a 4-worker-from-start
//!   run from the admission boundary.
//! * `spot_churn` — spot-instance-style churn: a crash + rejoin *and* a
//!   late spawn in one run; parity is measured after the last admission.
//!
//! The ratcheted metrics are the exact byte counts: the churn-free
//! invariant (`final_x_mismatch_bytes` / `loss_trace_mismatch_bytes`
//! between an autoscale-armed-but-quiet run and the default trainer) and
//! churn replay determinism (`replay_mismatch_bytes` between two runs of
//! the same plan) must all be exactly 0. The `parity_steps` /
//! `parity_rounds` readings are informational, and `steps_per_s` rates
//! only warn — wall clock depends on the host.

use std::sync::Arc;
use std::time::Instant;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, RunResult, Trainer};
use adaalter::sim::SyntheticProblem;
use adaalter::util::timing::BenchSink;

/// Problem dimension: big enough that a sync round moves real vectors,
/// small enough that six runs finish in seconds.
const DIM: usize = 2048;
const H: u64 = 4;

/// The H=4 local-AdaAlter shape every scenario uses, every step logged
/// (parity is read off the loss trace).
fn cfg(workers: usize, steps: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.train.workers = workers;
    c.train.steps = steps;
    c.train.sync_period = SyncPeriod::Every(H);
    c.train.backend = Backend::RustMath;
    c.train.rust_math_dim = DIM;
    c.train.log_every = 1;
    c.train.fused = false; // required by the churn validation rules
    c.optim.algorithm = Algorithm::LocalAdaAlter;
    c.optim.warmup_steps = 10;
    c
}

/// Train `c` on the synthetic backend; returns the result and wall time.
fn run(c: &ExperimentConfig) -> (RunResult, f64) {
    let p = SyntheticProblem::new(c.train.rust_math_dim, c.train.workers, c.train.seed);
    let f: BackendFactory = Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));
    let t0 = Instant::now();
    let r = Trainer::new(c.clone(), f).run().expect("bench run failed");
    (r, t0.elapsed().as_secs_f64())
}

/// Bytes of `a`'s final parameters whose bits differ from `b`'s.
fn final_x_mismatch_bytes(a: &RunResult, b: &RunResult) -> f64 {
    assert_eq!(a.final_x.len(), b.final_x.len(), "dimension mismatch");
    let words = a
        .final_x
        .iter()
        .zip(&b.final_x)
        .filter(|(x, y)| x.to_bits() != y.to_bits())
        .count();
    (4 * words) as f64
}

/// Bytes of `a`'s logged loss trace whose bits differ from `b`'s.
fn loss_trace_mismatch_bytes(a: &RunResult, b: &RunResult) -> f64 {
    assert_eq!(a.recorder.steps.len(), b.recorder.steps.len(), "trace length mismatch");
    let words = a
        .recorder
        .steps
        .iter()
        .zip(&b.recorder.steps)
        .filter(|(p, q)| p.step != q.step || p.train_loss.to_bits() != q.train_loss.to_bits())
        .count();
    (8 * words) as f64
}

/// Recovery-time-to-parity: the number of steps after `from_step` until
/// the churned run `a` stays within `tol` (relative) of the reference `b`
/// for the rest of the run. 0 = immediate parity; capped at the end of
/// the run if the trajectories never lock.
fn parity_steps(a: &RunResult, b: &RunResult, from_step: u64, tol: f64) -> u64 {
    assert_eq!(a.recorder.steps.len(), b.recorder.steps.len(), "trace length mismatch");
    let mut last_bad = from_step;
    for (p, q) in a.recorder.steps.iter().zip(&b.recorder.steps) {
        assert_eq!(p.step, q.step, "step ids diverged");
        if p.step < from_step {
            continue;
        }
        let gap = (p.train_loss - q.train_loss).abs() / q.train_loss.abs().max(1e-12);
        if gap > tol {
            last_bad = p.step;
        }
    }
    last_bad - from_step
}

const PARITY_TOL: f64 = 1e-3;

fn main() {
    let mut sink = BenchSink::new("elastic_churn");

    // --- The standing invariant: armed-but-quiet membership engine ------
    // An autoscale-armed run whose thresholds never trip must be
    // bitwise-identical to the default fault-free trainer.
    {
        let base = cfg(4, 160);
        let mut armed = base.clone();
        armed.faults.autoscale = true;
        armed.faults.autoscale_straggler_s = 1e9;
        armed.faults.autoscale_drift = 1e18;
        let (a, _) = run(&base);
        let (b, wall) = run(&armed);
        let fx = final_x_mismatch_bytes(&a, &b);
        let tr = loss_trace_mismatch_bytes(&a, &b);
        println!(
            "churn_free_invariant     final_x mismatch {fx:>4.0} B  trace mismatch {tr:>4.0} B  \
             wall {wall:.2}s"
        );
        sink.value(
            "churn_free_invariant",
            &[
                ("final_x_mismatch_bytes", fx),
                ("loss_trace_mismatch_bytes", tr),
                ("steps_per_s", 160.0 / wall),
            ],
        );
    }

    // --- Crash + rejoin -------------------------------------------------
    {
        let steps = 240;
        let reference = cfg(4, steps);
        let mut churn = reference.clone();
        churn.faults.crash_worker = 2;
        churn.faults.crash_step = 21;
        churn.faults.rejoin_step = 29;
        let readmit = 32; // first H=4 boundary at or after rejoin_step
        let (r, _) = run(&reference);
        let (c1, wall) = run(&churn);
        let (c2, _) = run(&churn);
        let parity = parity_steps(&c1, &r, readmit, PARITY_TOL);
        let replay = final_x_mismatch_bytes(&c1, &c2) + loss_trace_mismatch_bytes(&c1, &c2);
        println!(
            "rejoin_recovery          parity after {parity:>3} steps \
             ({:>2} rounds)  replay mismatch {replay:.0} B  wall {wall:.2}s",
            parity.div_ceil(H)
        );
        sink.value(
            "rejoin_recovery",
            &[
                ("parity_steps", parity as f64),
                ("parity_rounds", parity.div_ceil(H) as f64),
                ("replay_mismatch_bytes", replay),
                ("steps_per_s", steps as f64 / wall),
            ],
        );
    }

    // --- Scale-up: 3 workers grow to 4 ---------------------------------
    {
        let steps = 240;
        let reference = cfg(4, steps);
        let mut churn = reference.clone();
        churn.faults.spawn_workers = 1;
        churn.faults.spawn_step = 80;
        let admit = 80; // spawn_step is itself an H=4 boundary
        let (r, _) = run(&reference);
        let (c, wall) = run(&churn);
        let parity = parity_steps(&c, &r, admit, PARITY_TOL);
        println!(
            "scaleup_recovery         parity after {parity:>3} steps \
             ({:>2} rounds)  wall {wall:.2}s",
            parity.div_ceil(H)
        );
        sink.value(
            "scaleup_recovery",
            &[
                ("parity_steps", parity as f64),
                ("parity_rounds", parity.div_ceil(H) as f64),
                ("steps_per_s", steps as f64 / wall),
            ],
        );
    }

    // --- Spot-instance-style churn: crash + rejoin + late spawn ---------
    {
        let steps = 240;
        let reference = cfg(5, steps);
        let mut churn = reference.clone();
        churn.faults.crash_worker = 3;
        churn.faults.crash_step = 21;
        churn.faults.rejoin_step = 29;
        churn.faults.spawn_workers = 1; // worker 4 arrives late
        churn.faults.spawn_step = 60;
        let last_admit = 60; // the spawn boundary is the last churn event
        let (r, _) = run(&reference);
        let (c1, wall) = run(&churn);
        let (c2, _) = run(&churn);
        let parity = parity_steps(&c1, &r, last_admit, PARITY_TOL);
        let replay = final_x_mismatch_bytes(&c1, &c2) + loss_trace_mismatch_bytes(&c1, &c2);
        println!(
            "spot_churn               parity after {parity:>3} steps \
             ({:>2} rounds)  replay mismatch {replay:.0} B  wall {wall:.2}s",
            parity.div_ceil(H)
        );
        sink.value(
            "spot_churn",
            &[
                ("parity_steps", parity as f64),
                ("parity_rounds", parity.div_ceil(H) as f64),
                ("replay_mismatch_bytes", replay),
                ("steps_per_s", steps as f64 / wall),
            ],
        );
    }

    sink.finish();
}
