//! Bench: regenerate **Figure 1** — time of one epoch vs number of workers
//! for every algorithm the paper plots, from the paper-calibrated cluster
//! model, and check the headline shape claims programmatically.
//!
//! Run: `cargo bench --bench fig1_epoch_time`

use adaalter::config::SyncPeriod::{Every, Infinite};
use adaalter::sim::{EpochModel, SimAlgo};

fn main() {
    let m = EpochModel::paper();
    let ns = [1usize, 2, 4, 8];
    let algos = [
        SimAlgo::AdaGrad,
        SimAlgo::AdaAlter,
        SimAlgo::LocalAdaAlter(Every(4)),
        SimAlgo::LocalAdaAlter(Every(8)),
        SimAlgo::LocalAdaAlter(Every(12)),
        SimAlgo::LocalAdaAlter(Every(16)),
        SimAlgo::LocalAdaAlter(Infinite),
        SimAlgo::IdealComputeOnly,
    ];

    println!("=== Figure 1: time of an epoch (seconds) vs #workers ===");
    println!("(epoch = 20,000×8×256 samples; paper-calibrated 8×V100 PS model)\n");
    println!("{:<34} {:>9} {:>9} {:>9} {:>9}", "algorithm", "n=1", "n=2", "n=4", "n=8");
    for a in &algos {
        let row: Vec<String> = ns
            .iter()
            .map(|&n| format!("{:>9.0}", m.epoch_time_s(*a, n)))
            .collect();
        println!("{:<34} {}", a.label(), row.join(" "));
    }

    // Shape checks the paper's text commits to (§6.3–6.4).
    println!("\n=== shape checks ===");
    let sync8 = m.epoch_time_s(SimAlgo::AdaGrad, 8);
    let h4_8 = m.epoch_time_s(SimAlgo::LocalAdaAlter(Every(4)), 8);
    let reduction = 100.0 * (1.0 - h4_8 / sync8);
    println!(
        "H=4 cuts epoch time by {reduction:.1}% vs fully-sync AdaGrad at n=8 \
         (paper: ~30%) {}",
        ok(reduction > 25.0 && reduction < 35.0)
    );

    let hinf = m.epoch_time_s(SimAlgo::LocalAdaAlter(Infinite), 8);
    let ideal = m.epoch_time_s(SimAlgo::IdealComputeOnly, 8);
    let gap = 100.0 * (hinf - ideal) / ideal;
    println!(
        "H=∞ sits {gap:.1}% above ideal-compute at n=8 — the §6.4 dataloader \
         bottleneck {}",
        ok(gap > 5.0)
    );

    let gap4 = m.epoch_time_s(SimAlgo::LocalAdaAlter(Infinite), 4)
        - m.epoch_time_s(SimAlgo::IdealComputeOnly, 4);
    println!(
        "…but vanishes at n=4 (loading hidden behind compute) {}",
        ok(gap4.abs() < 2.0 * m.epoch_time_s(SimAlgo::IdealComputeOnly, 4) * 0.01)
    );

    let mut monotone = true;
    for w in [16u64, 12, 8, 4].windows(2) {
        monotone &= m.epoch_time_s(SimAlgo::LocalAdaAlter(Every(w[0])), 8)
            <= m.epoch_time_s(SimAlgo::LocalAdaAlter(Every(w[1])), 8);
    }
    println!("epoch time monotone decreasing in H {}", ok(monotone));
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}
