//! Integration over the PJRT runtime: the AOT artifacts loaded and executed
//! from rust, pinned against the rust-native optimizer implementations.
//!
//! All tests self-skip when `artifacts/` has not been built
//! (`make artifacts`), so a fresh checkout still runs `cargo test`.

mod common;

use std::sync::Arc;

use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::factory::make_factory;
use adaalter::coordinator::{Trainer, WorkerBackend};
use adaalter::optim::{AdaAlter, SyncOptimizer};
use adaalter::runtime::{artifacts_available, Arg, Engine, PjrtBackend};
use adaalter::util::math;
use adaalter::util::rng::Rng;

const ARTIFACTS: &str = "artifacts";
const PRESET: &str = common::LM_PRESET;

fn have_artifacts() -> bool {
    artifacts_available(ARTIFACTS)
}

fn lm_config(algo: Algorithm, h: SyncPeriod, workers: usize, steps: u64) -> ExperimentConfig {
    common::lm_cfg(algo, h, workers, steps)
}

/// The HLO optimizer kernel (Pallas adaalter lowered through XLA) must
/// match the rust AdaAlter implementation coordinate-for-coordinate.
#[test]
fn hlo_opt_adaalter_matches_rust() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::new(ARTIFACTS, PRESET).unwrap();
    let d = engine.preset().d;
    let graph = engine.load_graph("opt_adaalter").unwrap();

    let mut rng = Rng::new(7);
    let mut x = vec![0.0f32; d];
    rng.fill_normal(&mut x, 1.0);
    let mut b2 = vec![0.0f32; d];
    for v in b2.iter_mut() {
        *v = 1.0 + rng.f32();
    }
    let mut g = vec![0.0f32; d];
    rng.fill_normal(&mut g, 0.5);
    let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
    let (denom_add, lr) = ([1.0f32], [0.25f32]);

    // HLO path: (x, b2_base, acc, g, gsq, denom_add, lr) -> (y, acc')
    let outs = graph
        .run(&[
            Arg::F32(&x),
            Arg::F32(&b2),
            Arg::F32(&b2),
            Arg::F32(&g),
            Arg::F32(&gsq),
            Arg::F32(&denom_add),
            Arg::F32(&lr),
        ])
        .unwrap();
    let mut y_hlo = vec![0.0f32; d];
    let mut acc_hlo = vec![0.0f32; d];
    adaalter::runtime::engine::read_f32_into(&outs[0], &mut y_hlo).unwrap();
    adaalter::runtime::engine::read_f32_into(&outs[1], &mut acc_hlo).unwrap();

    // Rust path (eps² == denom_add for the sync case).
    let mut opt = AdaAlter::new(d, 1.0, 1.0);
    // Overwrite the accumulator with our random b2 by stepping from scratch:
    // AdaAlter::new starts at b0² = 1; emulate arbitrary b2 by the identity
    // acc = 1 + (b2 - 1) folded in via one zero-lr step with gsq = b2 - 1.
    let pre_gsq: Vec<f32> = b2.iter().map(|v| v - 1.0).collect();
    let mut x_rs = x.clone();
    opt.step(&mut x_rs, &vec![0.0; d], &pre_gsq, 0.0);
    assert!(math::max_abs_diff(opt.b2(), &b2) < 1e-6);
    opt.step(&mut x_rs, &g, &gsq, 0.25);

    let expected_acc: Vec<f32> = b2.iter().zip(&gsq).map(|(b, q)| b + q).collect();
    assert!(math::max_abs_diff(&y_hlo, &x_rs) < 1e-4, "y mismatch");
    assert!(math::max_abs_diff(&acc_hlo, &expected_acc) < 1e-4, "acc mismatch");
}

/// train_step gradients: loss decreases along the negative gradient
/// (directional sanity of the lowered autodiff graph).
#[test]
fn train_step_gradient_descends() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut b =
        PjrtBackend::new(ARTIFACTS, PRESET, 0, 1, &Default::default(), 3).unwrap();
    let x = b.init_params().unwrap();
    let d = b.dim();
    let mut g = vec![0.0f32; d];
    let loss0 = b.loss_and_grad(&x, 1, &mut g).unwrap();
    assert!(loss0 > 0.0 && loss0.is_finite());
    // One explicit descent step re-evaluated on the SAME batch.
    let mut x2 = x.clone();
    for i in 0..d {
        x2[i] -= 0.5 * g[i];
    }
    let mut scratch = vec![0.0f32; d];
    let loss1 = b.loss_and_grad(&x2, 1, &mut scratch).unwrap();
    assert!(loss1 < loss0, "descent failed: {loss0} -> {loss1}");
}

/// The fused local-step graph must equal grad + rust local update.
#[test]
fn fused_local_step_matches_unfused() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut b =
        PjrtBackend::new(ARTIFACTS, PRESET, 0, 2, &Default::default(), 9).unwrap();
    let d = b.dim();
    let x0 = b.init_params().unwrap();
    let b2 = vec![1.0f32; d];

    // Fused path.
    let mut x_f = x0.clone();
    let mut acc_f = b2.clone();
    let loss_f = b
        .fused_local_adaalter(&mut x_f, &b2, &mut acc_f, 1.0, 0.25, 5)
        .unwrap()
        .expect("fused graph available");

    // Unfused: grad then rust-side local step.
    let mut w = adaalter::optim::LocalAdaAlterWorker::new(x0.clone(), 1.0, 1.0);
    let mut g = vec![0.0f32; d];
    let loss_u = b.loss_and_grad(w.x(), 5, &mut g).unwrap();
    w.local_step(&g, 0.25);

    assert!((loss_f - loss_u).abs() < 1e-4, "loss {loss_f} vs {loss_u}");
    assert!(math::max_abs_diff(&x_f, w.x()) < 1e-4, "x mismatch");
    assert!(math::max_abs_diff(&acc_f, w.acc()) < 1e-4, "acc mismatch");
}

/// Full threaded PJRT training run: loss drops, PPL finite and below the
/// uniform-model bound (= vocab), determinism holds.
#[test]
fn pjrt_training_reduces_loss_and_ppl() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let c = lm_config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 2, 40);
    let f = make_factory(&c).unwrap();
    let r = Trainer::new(c.clone(), f).run().unwrap();
    let ev = r.final_eval.unwrap();
    let ppl = ev.ppl.unwrap();
    assert!(ppl.is_finite() && ppl < 256.0, "PPL {ppl} not below uniform (=vocab)");
    let first = r.recorder.steps.first().unwrap().train_loss;
    let last = r.recorder.steps.last().unwrap().train_loss;
    assert!(last < first, "loss did not drop: {first} -> {last}");
}

/// Fused and unfused trainer paths must produce the same final parameters.
#[test]
fn trainer_fused_equals_unfused() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let c = lm_config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 2, 16);
    let f1 = make_factory(&c).unwrap();
    let mut t1 = Trainer::new(c.clone(), f1);
    t1.allow_fused = true;
    let r1 = t1.run().unwrap();

    let f2 = make_factory(&c).unwrap();
    let mut t2 = Trainer::new(c.clone(), f2);
    t2.allow_fused = false;
    let r2 = t2.run().unwrap();

    let diff = math::max_abs_diff(&r1.final_x, &r2.final_x);
    assert!(diff < 1e-3, "fused vs unfused diverged: {diff}");
}

/// PJRT H=1 local AdaAlter ≡ sync AdaAlter on the real LM (the paper's
/// §4.3 equivalence, through the whole stack).
#[test]
fn pjrt_local_h1_equals_sync_adaalter() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cl = lm_config(Algorithm::LocalAdaAlter, SyncPeriod::Every(1), 2, 12);
    let cs = lm_config(Algorithm::AdaAlter, SyncPeriod::Every(1), 2, 12);
    let rl = Trainer::new(cl.clone(), make_factory(&cl).unwrap()).run().unwrap();
    let rs = Trainer::new(cs.clone(), make_factory(&cs).unwrap()).run().unwrap();
    let diff = math::max_abs_diff(&rl.final_x, &rs.final_x);
    assert!(diff < 2e-3, "H=1 equivalence broken on LM: {diff}");
}

/// Eval PPL of the zero parameter vector equals vocab (uniform predictions)
/// — pins the eval artifact's PPL convention (§6.2).
#[test]
fn eval_ppl_of_uniform_model_is_vocab() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut b =
        PjrtBackend::new(ARTIFACTS, PRESET, 0, 1, &Default::default(), 3).unwrap();
    let zeros = vec![0.0f32; b.dim()];
    let m = b.eval(&zeros).unwrap();
    let ppl = m.ppl.unwrap();
    assert!((ppl - 256.0).abs() / 256.0 < 1e-3, "uniform PPL {ppl}");
}

/// Backend factory builds independent per-worker engines that agree on
/// dim and init.
#[test]
fn factory_workers_agree_on_geometry() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let c = lm_config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 3, 1);
    let f = make_factory(&c).unwrap();
    let b0 = f(0).unwrap();
    let b1 = f(1).unwrap();
    assert_eq!(b0.dim(), b1.dim());
    assert_eq!(b0.init_params().unwrap(), b1.init_params().unwrap());
    let _ = Arc::strong_count(&f);
}
