//! Integration: the deterministic fault & straggler scenario engine with
//! partial-participation sync rounds (DESIGN.md §6), through the full
//! threaded trainer on the synthetic backend.
//!
//! * With `[faults]` absent (or explicitly zeroed) the trainer takes the
//!   exact fault-free code paths — pinned bitwise against the default run.
//! * A quorum round with a crashed worker still converges; stragglers are
//!   dropped deterministically; the same seed reproduces the identical
//!   `faults_<tag>.csv` byte for byte.
//! * Property tests: random fault plans never deadlock the lockstep
//!   protocol (every round terminates, rounds == recorded participation
//!   events), and quorum averaging conserves the survivors' mean exactly.

mod common;

use std::sync::mpsc::channel;
use std::sync::Arc;

use adaalter::comm::{ChannelCollective, Collective, Participation, PartialCollective};
use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod, TomlDoc};
use adaalter::coordinator::worker::{worker_loop, Cmd, Reply, WorkerSpec};
use adaalter::coordinator::Trainer;
use adaalter::sim::{Charge, FaultPlan, SyntheticProblem};
use adaalter::util::{math, prop};

use common::{assert_bitwise_eq, cfg, factory, run, tmpdir, try_run};

/// The H=4 local-AdaAlter shape with one 4×-slow worker and quorum sync.
fn quorum_cfg(workers: usize, steps: u64, quorum: usize) -> ExperimentConfig {
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), workers, steps);
    c.train.fused = false;
    c.faults.slow_workers = 1;
    c.faults.slow_factor = 4.0;
    c.faults.quorum = quorum;
    c
}

/// An explicitly-zeroed `[faults]` section parses to the inactive scenario
/// and an empty plan — the config-surface half of the "absent section ≡
/// seed trainer" guarantee.
#[test]
fn zeroed_faults_section_is_inactive() {
    let doc = TomlDoc::parse(
        "[faults]\nslow_workers = 0\nstall_prob = 0.0\ncrash_worker = -1\n\
         quorum = 0\ndrop_slowest = 0\n",
    )
    .unwrap();
    let c = ExperimentConfig::from_doc(&doc).unwrap();
    assert!(!c.faults.is_active());
    assert!(FaultPlan::from_config(&c).is_empty());
}

/// Engaging the partial engine with a quorum equal to the worker count is
/// a full barrier in disguise: the training data (final x, loss trace,
/// eval) must be bitwise identical to the default fault-free run — the
/// participation layer decides *who*, never *what*.
#[test]
fn quorum_of_all_workers_is_data_identical_to_default() {
    let base = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 48);
    let mut q = base.clone();
    q.train.fused = false; // no-op on rust_math; required by validation
    q.faults.quorum = 4;
    let a = run(base);
    let b = run(q);
    assert_bitwise_eq(&a, &b, "quorum==workers vs default");
    // The fault run additionally logs one participation event per round,
    // with everyone participating and zero barrier wait.
    assert!(a.recorder.fault_events.is_empty());
    assert_eq!(b.recorder.fault_events.len() as u64, b.recorder.comm().0);
    assert!(b
        .recorder
        .fault_events
        .iter()
        .all(|e| e.participants == 4 && e.dropped == 0 && e.wait_s == 0.0));
    assert_eq!(b.clock.total(Charge::Straggler), 0.0);
}

/// Quorum rounds with one crashed worker: the cluster keeps training on
/// the survivors and still makes real progress.
#[test]
fn quorum_round_with_crashed_worker_still_converges() {
    let mut c = quorum_cfg(4, 400, 2);
    c.faults.slow_workers = 0; // crash only
    c.faults.crash_worker = 3;
    c.faults.crash_step = 50;
    let problem = SyntheticProblem::new(c.train.rust_math_dim, c.train.workers, c.train.seed);
    use adaalter::coordinator::WorkerBackend as _;
    let opt_loss = problem.global_loss(&problem.optimum());
    let init_sub =
        problem.global_loss(&problem.backend(0).init_params().unwrap()) - opt_loss;

    let r = run(c);
    let final_sub = r.final_eval.unwrap().loss - opt_loss;
    assert!(final_sub.is_finite());
    assert!(
        final_sub < init_sub * 0.2,
        "crashed-quorum run failed to learn: suboptimality {final_sub} vs initial {init_sub}"
    );
    let events = &r.recorder.fault_events;
    assert_eq!(events.len() as u64, r.recorder.comm().0);
    assert!(events.iter().take(12).all(|e| e.alive == 4), "pre-crash rounds");
    assert!(events.iter().skip(13).all(|e| e.alive == 3), "post-crash rounds");
    // Every round closed with at least the quorum.
    assert!(events.iter().all(|e| e.participants >= 2));
}

/// The acceptance pin: the same seed replays the identical scenario —
/// final parameters bitwise, realized-H trajectory, and the
/// `faults_<tag>.csv` participation log byte for byte — and worker-thread
/// interleavings cannot perturb it (every run spawns fresh threads).
#[test]
fn fault_plan_replay_is_bitwise_reproducible() {
    let make = || {
        let mut c = quorum_cfg(4, 80, 3);
        c.faults.stall_prob = 0.2;
        c.faults.stall_s = 0.05;
        c
    };
    let dir = tmpdir("faults_replay");
    let a = run(make());
    let b = run(make());
    assert_bitwise_eq(&a, &b, "fault replay");
    assert_eq!(a.recorder.realized_h(), b.recorder.realized_h());
    assert_eq!(a.recorder.fault_events.len(), b.recorder.fault_events.len());
    let pa = format!("{dir}/faults_a.csv");
    let pb = format!("{dir}/faults_b.csv");
    a.recorder.write_faults_csv(&pa).unwrap();
    b.recorder.write_faults_csv(&pb).unwrap();
    let ca = std::fs::read(&pa).unwrap();
    let cb = std::fs::read(&pb).unwrap();
    assert!(!ca.is_empty());
    assert_eq!(ca, cb, "faults CSV not byte-identical across replays");
    std::fs::remove_dir_all(&dir).ok();
}

/// Backup-worker (drop-slowest-k) rounds: the permanently slow worker is
/// the dropped one every round, and the barrier never waits for it.
#[test]
fn backup_worker_policy_drops_the_slow_worker() {
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 60);
    c.train.fused = false;
    c.faults.slow_workers = 1;
    c.faults.slow_factor = 4.0;
    c.faults.drop_slowest = 1;
    let r = run(c);
    assert_eq!(r.clock.total(Charge::Straggler), 0.0);
    let events = &r.recorder.fault_events;
    assert_eq!(events.len() as u64, r.recorder.comm().0);
    assert!(events.iter().all(|e| e.participants == 3 && e.dropped == 1));
    assert!(r.recorder.transport().starts_with("partial(drop1"));
    assert!(r.final_eval.unwrap().loss.is_finite());
}

/// Worker-side fault injection, exercised directly against the worker
/// loop: the thread executes steps before its crash step, then answers
/// every further command with the tombstone instead of blocking.
#[test]
fn worker_loop_injects_the_crash_tombstone() {
    let d = 16;
    let p = SyntheticProblem::new(d, 1, 7);
    use adaalter::coordinator::WorkerBackend as _;
    let init = Arc::new(p.backend(0).init_params().unwrap());
    let spec = WorkerSpec {
        worker: 0,
        algorithm: Algorithm::LocalAdaAlter,
        epsilon: 1.0,
        b0: 1.0,
        init,
        allow_fused: false,
        collect_update_sq: false,
        bf16_state: false,
        crash_step: Some(3),
    };
    let factory: adaalter::coordinator::BackendFactory =
        Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>));
    let (cmd_tx, cmd_rx) = channel::<Cmd>();
    let (reply_tx, reply_rx) = channel::<Reply>();
    let join = std::thread::spawn(move || worker_loop(spec, factory, cmd_rx, reply_tx));

    assert!(matches!(reply_rx.recv().unwrap(), Reply::Ready { worker: 0 }));
    for t in 1..=2u64 {
        cmd_tx.send(Cmd::LocalStep { t, lr: 0.1 }).unwrap();
        match reply_rx.recv().unwrap() {
            Reply::StepDone { worker: 0, loss, .. } => assert!(loss.is_finite()),
            other => panic!("expected StepDone at t={t}, got {}", reply_kind(&other)),
        }
    }
    // t = 3: the schedule kills the worker; it must reply Crashed, and
    // keep replying Crashed to later commands rather than deadlocking.
    cmd_tx.send(Cmd::LocalStep { t: 3, lr: 0.1 }).unwrap();
    assert!(matches!(reply_rx.recv().unwrap(), Reply::Crashed { worker: 0, step: 3 }));
    cmd_tx.send(Cmd::CollectState { sx: Vec::new(), sa: Vec::new(), raw: false }).unwrap();
    assert!(matches!(reply_rx.recv().unwrap(), Reply::Crashed { worker: 0, .. }));
    cmd_tx.send(Cmd::Stop).unwrap();
    join.join().unwrap();
}

fn reply_kind(r: &Reply) -> &'static str {
    match r {
        Reply::Grad { .. } => "Grad",
        Reply::StepDone { .. } => "StepDone",
        Reply::State { .. } => "State",
        Reply::Eval { .. } => "Eval",
        Reply::Ready { .. } => "Ready",
        Reply::Crashed { .. } => "Crashed",
        Reply::Left { .. } => "Left",
        Reply::Err { .. } => "Err",
    }
}

/// Random fault plans never deadlock the lockstep protocol: every run
/// terminates (cleanly or with a typed error), every executed round is
/// recorded as exactly one participation event, and parameters stay
/// finite.
#[test]
fn random_fault_plans_never_deadlock() {
    prop::check("fault plans terminate", 20, |g| {
        let workers = g.usize_in(2..5);
        let steps = g.u64_in(16..48);
        let h = *g.choose(&[1u64, 2, 4]);
        let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), workers, steps);
        c.train.seed = g.u64_in(0..1 << 16);
        c.train.fused = false;
        if g.bool() {
            c.faults.slow_workers = g.usize_in(1..workers + 1);
            c.faults.slow_factor = g.f64_in(1.0..6.0);
        }
        if g.bool() {
            c.faults.stall_prob = g.f64_in(0.0..0.5);
            c.faults.stall_s = g.f64_in(0.001..0.1);
        }
        if g.bool() {
            c.faults.crash_worker = g.usize_in(0..workers) as i64;
            c.faults.crash_step = g.u64_in(1..steps + 1);
        }
        // Participation policy: full barrier, quorum, or backup worker —
        // quorum chosen to stay reachable even after the crash.
        match g.usize_in(0..3) {
            1 => c.faults.quorum = g.usize_in(1..workers),
            2 => c.faults.drop_slowest = 1.min(workers - 1),
            _ => {}
        }
        if !c.faults.is_active() {
            c.faults.slow_workers = 1; // keep the fault engine engaged
        }
        let r = try_run(c).map_err(|e| format!("run failed: {e}"))?;
        prop::assert_that(
            r.recorder.fault_events.len() as u64 == r.recorder.comm().0,
            format!(
                "{} participation events for {} rounds",
                r.recorder.fault_events.len(),
                r.recorder.comm().0
            ),
        )?;
        prop::assert_that(
            r.final_x.iter().all(|v| v.is_finite()),
            "non-finite parameters",
        )?;
        prop::assert_that(
            r.recorder.fault_events.iter().all(|e| e.participants + e.dropped == e.alive),
            "participants + dropped != alive",
        )
    });
}

/// Quorum averaging over the k surviving workers conserves their mean
/// exactly: the partial round's output is bit-identical to running the
/// plain lockstep mean over just the participants.
#[test]
fn quorum_averaging_conserves_the_survivor_mean_exactly() {
    prop::check("quorum mean conservation", 100, |g| {
        let n = g.usize_in(2..7);
        let d = g.usize_in(1..33);
        let policy = if g.bool() {
            Participation {
                quorum: g.usize_in(1..n + 1),
                timeout_s: g.f64_in(0.0..2.0),
                drop_slowest: 0,
            }
        } else {
            Participation { quorum: 0, timeout_s: 0.0, drop_slowest: g.usize_in(1..n) }
        };
        let mut pc =
            PartialCollective::new(Box::new(ChannelCollective::new(n, d)), policy);
        let xs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(d..d + 1, -8.0..8.0)).collect();
        let arrivals: Vec<f64> = (0..n).map(|_| g.f64_in(0.0..10.0)).collect();
        let xr: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut avg = vec![0.0f32; d];
        let out = pc
            .sync_round_partial(&xr, None, &arrivals, &mut avg, None)
            .map_err(|e| format!("partial round failed: {e}"))?;
        prop::assert_that(!out.participants.is_empty(), "no participants")?;
        prop::assert_that(
            out.participants.len() + out.dropped.len() == n,
            "selection does not partition the workers",
        )?;
        let survivors: Vec<&[f32]> =
            out.participants.iter().map(|&i| xs[i].as_slice()).collect();
        let mut want = vec![0.0f32; d];
        math::mean_into(&survivors, &mut want);
        prop::assert_that(avg == want, "survivor mean not conserved bitwise")?;
        // Selection is deterministic: replay the same arrivals.
        let (p2, d2, close2) = policy.select(&arrivals).map_err(|e| e.to_string())?;
        prop::assert_that(
            p2 == out.participants && d2 == out.dropped && close2 == out.close_s,
            "selection not deterministic",
        )
    });
}

/// Negative paths through the TOML surface: invalid `[faults]`/`[sync]`/
/// `[comm]` combinations come back as field-named config errors before
/// any thread spawns.
#[test]
fn invalid_fault_configs_error_before_running() {
    // quorum exceeding the cluster, via TOML.
    let doc = TomlDoc::parse(
        "[train]\nworkers = 4\nfused = false\n[faults]\nquorum = 5\n",
    )
    .unwrap();
    let err = ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
    assert!(err.contains("faults.quorum"), "{err}");

    // crash + checkpointing is now a supported combination (the fault
    // plan replays as a pure function of the seed) — but only under the
    // fixed sync policy, where boundaries are known ahead of time.
    let doc = TomlDoc::parse(
        "[train]\ncheckpoint_every = 4\n[faults]\ncrash_worker = 1\ncrash_step = 3\n",
    )
    .unwrap();
    ExperimentConfig::from_doc(&doc).expect("checkpointing under [faults] must validate");
    let doc = TomlDoc::parse(
        "[train]\ncheckpoint_every = 4\n[sync]\npolicy = \"growing\"\n\
         [faults]\ncrash_worker = 1\ncrash_step = 3\n",
    )
    .unwrap();
    let err = ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
    assert!(err.contains("train.checkpoint_every"), "{err}");

    // quorum over the fused device path.
    let doc = TomlDoc::parse("[faults]\nquorum = 2\n").unwrap();
    let err = ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
    assert!(err.contains("train.fused"), "{err}");

    // And the programmatic mirror: resume now composes with a plain
    // scenario (the plan replays from the seed), but the autoscaler's
    // patience counters are not checkpointed — that combination still
    // refuses up front, naming the field.
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 2, 8);
    c.train.fused = false;
    c.faults.autoscale = true;
    let d = c.train.rust_math_dim;
    let f = factory(&c);
    let mut t = Trainer::new(c, f);
    t.resume = Some(adaalter::coordinator::Checkpoint {
        step: 4,
        algorithm: Algorithm::LocalAdaAlter,
        vectors: vec![vec![0.0; d], vec![1.0; d], vec![1.0; d]],
    });
    let err = t.run().err().expect("must fail").to_string();
    assert!(err.contains("faults.autoscale"), "{err}");
}

/// A quorum made unreachable by a crash (programmatic plan, so config
/// validation cannot catch it) fails with a typed protocol error — not a
/// deadlock, not a panic.
#[test]
fn unreachable_quorum_errors_cleanly() {
    let mut c = quorum_cfg(3, 40, 3);
    c.faults.slow_workers = 0;
    let f = factory(&c);
    let mut t = Trainer::new(c, f);
    // The config (quorum == workers) validates; the injected plan then
    // kills a worker, leaving only 2 alive for a quorum of 3.
    t.fault_plan = Some(FaultPlan::none(3).with_crash(1, 5));
    let err = t.run().err().expect("must fail").to_string();
    assert!(err.contains("unreachable"), "{err}");
}
