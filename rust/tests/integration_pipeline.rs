//! Pipelined sync-round pins (`[comm] pipeline`; DESIGN.md §"Pipelined
//! sync rounds"): the software pipeline — parallel leader shard
//! reduction, coalesced vectored writer submission, pooled wire staging
//! buffers — is **scheduling only**. Every depth must reproduce the
//! strictly-serial round bit for bit (final parameters, per-step loss
//! bits, final-eval bits) with the real accounted socket bytes still
//! exactly equal to the booked α–β accounting, over real loopback TCP
//! deployments and through the in-process collectives alike. A clean
//! voluntary `Leave` with coalescing on must not strand queued frames
//! (the flush-on-close drain).
//!
//! CI runs this suite serialized (`--test-threads=1`) in release.

mod common;

use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod, TomlDoc};
use adaalter::coordinator::RunResult;
use adaalter::util::json::Json;

/// One pipelined deployment's experiment TOML: synthetic backend at
/// d = 64, every step logged, `shards`/`pipeline` on the comm section.
/// Lossy codecs keep the dense plan (`comm.shards > 1` requires a
/// lossless payload), so their pipeline exercises the writer coalescing
/// alone.
fn pipe_toml(
    algo: &str,
    h: u64,
    workers: usize,
    steps: u64,
    codec: &str,
    shards: usize,
    pipeline: usize,
) -> String {
    let comm = match codec {
        "f32" => format!("[comm]\ntransport = \"tcp\"\nshards = {shards}\npipeline = {pipeline}\n"),
        "bf16" => format!(
            "[comm]\ntransport = \"tcp\"\nshards = {shards}\npipeline = {pipeline}\n\
             [precision]\nwire = \"bf16\"\n"
        ),
        "qsgd" => {
            assert_eq!(shards, 1, "lossy codecs keep the dense plan");
            format!(
                "[comm]\ntransport = \"tcp\"\ncompression = \"qsgd\"\nqsgd_levels = 15\n\
                 pipeline = {pipeline}\n"
            )
        }
        other => panic!("unknown codec {other}"),
    };
    format!(
        "[train]\n\
         workers = {workers}\n\
         sync_period = {h}\n\
         steps = {steps}\n\
         steps_per_epoch = 50\n\
         log_every = 1\n\
         backend = \"rust_math\"\n\
         rust_math_dim = 64\n\
         [optim]\n\
         algorithm = \"{algo}\"\n\
         warmup_steps = 10\n\
         {comm}\
         [net]\n\
         listen = \"127.0.0.1:0\"\n\
         connect_timeout_s = 60.0\n"
    )
}

/// The strictly-serial in-process oracle for a pipelined networked TOML:
/// same experiment, equivalent in-process transport, `pipeline = 0` —
/// so the pin literally reads "pipelined deployment ≡ unpipelined
/// reference, bitwise".
fn serial_reference(toml: &str, codec: &str) -> RunResult {
    let swap = match codec {
        "f32" => "transport = \"simulated\"",
        _ => "transport = \"channel\"",
    };
    let ref_toml = toml
        .replace("transport = \"tcp\"", swap)
        .replace(&format!("pipeline = {}", pipeline_of(toml)), "pipeline = 0");
    let cfg = ExperimentConfig::from_doc(&TomlDoc::parse(&ref_toml).unwrap()).unwrap();
    common::run(cfg)
}

/// The `pipeline = N` value a [`pipe_toml`] document carries.
fn pipeline_of(toml: &str) -> usize {
    toml.lines()
        .find_map(|l| l.trim().strip_prefix("pipeline = "))
        .expect("pipe_toml always writes a pipeline key")
        .parse()
        .expect("pipeline value parses")
}

fn u64_field(rep: &Json, key: &str) -> u64 {
    rep.req(key).unwrap().num().unwrap() as u64
}

/// The deployment report carries the reference's exact bits, and the
/// real accounted socket payload bytes equal the booked α–β accounting.
fn assert_report_matches(rep: &Json, r: &RunResult, what: &str) {
    let got: Vec<u32> = rep
        .req("final_x_bits")
        .unwrap()
        .arr()
        .unwrap()
        .iter()
        .map(|j| j.num().unwrap() as u32)
        .collect();
    let want: Vec<u32> = r.final_x.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got, want, "{what}: final x diverged");

    let steps = rep.req("steps").unwrap().arr().unwrap();
    assert_eq!(steps.len(), r.recorder.steps.len(), "{what}: trace lengths differ");
    for (row, p) in steps.iter().zip(&r.recorder.steps) {
        let row = row.arr().unwrap();
        assert_eq!(row[0].num().unwrap() as u64, p.step, "{what}: step ids diverged");
        assert_eq!(
            row[1].str().unwrap(),
            format!("{:016x}", p.train_loss.to_bits()),
            "{what}: loss trace diverged at step {}",
            p.step
        );
    }

    let eval = r.final_eval.as_ref().expect("reference has a final eval");
    assert_eq!(
        rep.req("final_eval_loss_bits").unwrap().str().unwrap(),
        format!("{:016x}", eval.loss.to_bits()),
        "{what}: final eval diverged"
    );

    let (syncs, booked) = r.recorder.comm();
    assert_eq!(u64_field(rep, "syncs"), syncs, "{what}: sync counts differ");
    assert_eq!(u64_field(rep, "booked_bytes"), booked, "{what}: booked bytes differ");
    assert_eq!(
        u64_field(rep, "accounted_bytes"),
        booked,
        "{what}: real socket bytes != booked accounting"
    );
    assert!(
        u64_field(rep, "total_bytes") > u64_field(rep, "accounted_bytes"),
        "{what}: total wire traffic must exceed the accounted payloads"
    );
}

/// Run one pipelined deployment fault-free and pin it against the
/// strictly-serial in-process oracle.
fn pin(algo: &str, h: u64, workers: usize, codec: &str, shards: usize, depth: usize, tag: &str) {
    let steps = 36;
    let toml = pipe_toml(algo, h, workers, steps, codec, shards, depth);
    let run = common::run_net(&toml, workers, tag, &[]);
    for (w, st) in run.workers.iter().enumerate() {
        assert!(st.success(), "{tag}: worker {w} failed: {st}");
    }
    assert!(run.leader.success(), "{tag}: leader failed: {}", run.leader);
    let rep = common::net_report(&run.out_dir);
    let reference = serial_reference(&toml, codec);
    assert_report_matches(&rep, &reference, tag);
    std::fs::remove_dir_all(&run.out_dir).ok();
}

// --- Real loopback TCP: pipelined ≡ unpipelined, exactly accounted --------

#[test]
fn tcp_pipelined_f32_sharded_pins_bitwise() {
    // The acceptance shape: 8 leader shards, pipeline depths 2 and 4.
    pin("local_adaalter", 4, 4, "f32", 8, 2, "pipe_f32_laa_h4_w4_d2");
    pin("local_adaalter", 4, 4, "f32", 8, 4, "pipe_f32_laa_h4_w4_d4");
    pin("adagrad", 1, 2, "f32", 4, 4, "pipe_f32_adagrad_w2_d4");
}

#[test]
fn tcp_pipelined_bf16_and_qsgd_pin_bitwise() {
    // bf16: sharded plan + parallel reduction + coalescing writers.
    pin("local_adaalter", 4, 4, "bf16", 4, 2, "pipe_bf16_laa_h4_w4_d2");
    // QSGD: dense plan — the pipeline is pure writer coalescing here,
    // and the per-stream RNG burn order must survive it.
    pin("local_adaalter", 4, 2, "qsgd", 1, 4, "pipe_qsgd_laa_h4_w2_d4");
}

/// Two real deployments of the *same* experiment — coalescing on vs off —
/// must publish byte-identical reports: same bits, same booked bytes,
/// same accounted socket bytes.
#[test]
fn pipelined_deployment_report_equals_unpipelined_deployment() {
    let on = pipe_toml("local_adaalter", 4, 2, 24, "f32", 4, 4);
    let off = on.replace("pipeline = 4", "pipeline = 0");
    let run_on = common::run_net(&on, 2, "pipe_on", &[]);
    let run_off = common::run_net(&off, 2, "pipe_off", &[]);
    assert!(run_on.leader.success() && run_off.leader.success());
    let rep_on = common::net_report(&run_on.out_dir);
    let rep_off = common::net_report(&run_off.out_dir);
    for key in ["final_x_bits", "steps", "final_eval_loss_bits", "syncs", "booked_bytes"] {
        assert_eq!(
            rep_on.req(key).unwrap().dump(),
            rep_off.req(key).unwrap().dump(),
            "deployment reports diverged on {key}"
        );
    }
    // Accounted socket bytes are exact on both sides — coalescing must
    // not change what is billed, only how many syscalls carry it.
    assert_eq!(
        u64_field(&rep_on, "accounted_bytes"),
        u64_field(&rep_off, "accounted_bytes"),
        "accounted bytes diverged between depths"
    );
    std::fs::remove_dir_all(&run_on.out_dir).ok();
    std::fs::remove_dir_all(&run_off.out_dir).ok();
}

// --- In-process: pipeline = off ≡ depth = 1 ≡ depth = 4, all codecs -------

/// `pipeline = 0`, `1` and `4` through the in-process collectives
/// (sharded channel f32, bf16 wire, QSGD) are bitwise-identical — the
/// satellite permutation property made end-to-end: whatever order the
/// executor completes shards in, the round's bits never move.
#[test]
fn pipeline_depth_is_bitwise_invisible_in_process() {
    let shapes: &[(&str, usize)] = &[("f32", 8), ("bf16", 4), ("qsgd", 1)];
    for &(codec, shards) in shapes {
        let mk = |depth: usize| {
            let mut c = common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 40);
            c.comm.transport = "channel".into();
            c.comm.shards = shards;
            c.comm.pipeline = depth;
            match codec {
                "bf16" => c.precision.wire = "bf16".into(),
                "qsgd" => {
                    c.comm.compression = "qsgd".into();
                    c.comm.qsgd_levels = 15;
                }
                _ => {}
            }
            common::run(c)
        };
        let off = mk(0);
        let d1 = mk(1);
        let d4 = mk(4);
        common::assert_bitwise_eq(&off, &d1, &format!("{codec}: off vs depth 1"));
        common::assert_bitwise_eq(&off, &d4, &format!("{codec}: off vs depth 4"));
        let (s0, b0) = off.recorder.comm();
        let (s4, b4) = d4.recorder.comm();
        assert_eq!((s0, b0), (s4, b4), "{codec}: booked accounting moved with depth");
    }
}

// --- Flush-on-close: a clean Leave never strands coalesced frames ---------

fn faults_csv(dir: &str, workers: usize) -> String {
    let path = format!("{dir}/faults_local_adaalter_w{workers}_h4.csv");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

fn csv_column_sum(csv: &str, name: &str) -> f64 {
    let mut lines = csv.lines();
    let header: Vec<&str> = lines.next().expect("csv header").split(',').collect();
    let idx = header
        .iter()
        .position(|h| *h == name)
        .unwrap_or_else(|| panic!("faults csv has no {name:?} column: {header:?}"));
    lines
        .map(|l| {
            l.split(',')
                .nth(idx)
                .unwrap_or_else(|| panic!("short csv row {l:?}"))
                .parse::<f64>()
                .unwrap_or_else(|e| panic!("bad {name} value in {l:?}: {e}"))
        })
        .sum()
}

/// The shutdown-drain regression pin: with coalescing writers on, a
/// worker leaving voluntarily mid-run must still get every queued frame
/// — including the final partial batch — onto the wire before its
/// socket closes. A dropped frame would surface as a crash tombstone
/// (or a hang) instead of the clean leave billed here.
#[test]
fn leave_mid_round_with_pipeline_drains_final_frames() {
    let toml = format!(
        "[train]\n\
         workers = 3\n\
         sync_period = 4\n\
         steps = 400\n\
         steps_per_epoch = 50\n\
         log_every = 50\n\
         fused = false\n\
         backend = \"rust_math\"\n\
         rust_math_dim = 64\n\
         [optim]\n\
         algorithm = \"local_adaalter\"\n\
         warmup_steps = 10\n\
         [comm]\n\
         transport = \"tcp\"\n\
         pipeline = 4\n\
         [faults]\n\
         quorum = 2\n\
         [net]\n\
         listen = \"127.0.0.1:0\"\n\
         connect_timeout_s = 60.0\n"
    );
    let env = vec![(
        2usize,
        adaalter::comm::net::LEAVE_AT_STEP_ENV.to_string(),
        "30".to_string(),
    )];
    let run = common::run_net(&toml, 3, "pipe_leave", &env);
    assert!(run.workers[2].success(), "leaving worker exits clean: {}", run.workers[2]);
    for (w, st) in run.workers.iter().take(2).enumerate() {
        assert!(st.success(), "worker {w} failed: {st}");
    }
    assert!(run.leader.success(), "leader must finish on the remainder: {}", run.leader);
    let csv = faults_csv(&run.out_dir, 3);
    assert_eq!(csv_column_sum(&csv, "leaves"), 1.0, "one voluntary leave billed");
    assert_eq!(csv_column_sum(&csv, "crashes"), 0.0, "a dropped frame would bill a crash");
    std::fs::remove_dir_all(&run.out_dir).ok();
}
