//! Elastic membership & crash recovery (DESIGN.md §10): workers that
//! join, rejoin, and resume — in-process and over real sockets.
//!
//! * Plan-scheduled churn: a crashed worker with a `rejoin_step` is
//!   re-admitted at the next sync boundary via the ordinary
//!   `InstallState` catch-up; `spawn_workers` join a smaller initial
//!   fleet mid-run; both are pure functions of `(seed, worker, step)` and
//!   replay byte-identically.
//! * Telemetry-driven autoscaling: the `[faults] autoscale` policy admits
//!   queued spares on healthy drift and retires persistent stragglers as
//!   voluntary leaves — and with thresholds that never fire, the run is
//!   bitwise-identical to the default fault-free trainer.
//! * Real sockets: a worker process killed mid-run relaunches with
//!   `--rejoin`, is admitted through the late `Join` handshake, and the
//!   run converges to the same final eval as a never-killed quorum run;
//!   a voluntary `Leave` is billed as a leave, not a crash.
//!
//! CI runs this suite serialized (`--test-threads=1`) in release, like
//! the net suite — the multi-process scenarios spawn real OS processes.

mod common;

use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod, TomlDoc};
use adaalter::coordinator::RunResult;
use adaalter::metrics::FaultEvent;
use adaalter::util::prop;

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

/// The H=4 local-AdaAlter shape every in-process elastic scenario uses.
fn elastic_cfg(workers: usize, steps: u64) -> ExperimentConfig {
    let mut c = common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), workers, steps);
    c.train.fused = false; // no-op on rust_math; required by churn validation
    c
}

/// The fault event recorded at round `step`, or a panic naming it.
fn event_at(r: &RunResult, step: u64) -> FaultEvent {
    *r.recorder
        .fault_events
        .iter()
        .find(|e| e.step == step)
        .unwrap_or_else(|| panic!("no fault event at step {step}"))
}

/// Sum a named column of a `faults_<tag>.csv` written by a leader process.
fn csv_column_sum(csv: &str, name: &str) -> f64 {
    let mut lines = csv.lines();
    let header: Vec<&str> = lines.next().expect("csv header").split(',').collect();
    let idx = header
        .iter()
        .position(|h| *h == name)
        .unwrap_or_else(|| panic!("faults csv has no {name:?} column: {header:?}"));
    lines
        .map(|l| {
            l.split(',')
                .nth(idx)
                .unwrap_or_else(|| panic!("short csv row {l:?}"))
                .parse::<f64>()
                .unwrap_or_else(|e| panic!("bad {name} value in {l:?}: {e}"))
        })
        .sum()
}

// ---------------------------------------------------------------------------
// In-process: plan-scheduled churn
// ---------------------------------------------------------------------------

/// A crashed worker with a scheduled rejoin is re-admitted at the first
/// boundary at or after `rejoin_step`, warm-started from the boundary's
/// averaged state, and the fleet is whole again for the rest of the run.
#[test]
fn crashed_worker_rejoins_at_the_next_sync_boundary() {
    let mut c = elastic_cfg(4, 48);
    c.faults.crash_worker = 2;
    c.faults.crash_step = 9;
    c.faults.rejoin_step = 15;
    let r = common::run(c);

    // The crash at t = 9 surfaces in the t = 12 round's accounting...
    let e12 = event_at(&r, 12);
    assert_eq!((e12.alive, e12.crashes, e12.joins), (3, 1, 0), "crash round: {e12:?}");
    // ...and the t = 16 boundary (first with 15 <= t) re-admits worker 2.
    let e16 = event_at(&r, 16);
    assert_eq!((e16.alive, e16.joins, e16.leaves), (3, 1, 0), "rejoin round: {e16:?}");
    // From the next phase on the fleet is whole again, with no churn.
    assert!(r
        .recorder
        .fault_events
        .iter()
        .filter(|e| e.step >= 20)
        .all(|e| e.alive == 4 && e.participants == 4 && e.joins == 0 && e.crashes == 0));
    // Nothing in this scenario is a voluntary departure.
    assert!(r.recorder.fault_events.iter().all(|e| e.leaves == 0));
    assert!(r.final_eval.expect("final eval").loss.is_finite());
}

/// `spawn_workers`: the highest worker id starts absent and joins the
/// live set at the first boundary at or after `spawn_step`.
#[test]
fn spawned_worker_joins_the_initial_fleet_mid_run() {
    let mut c = elastic_cfg(4, 40);
    c.faults.spawn_workers = 1;
    c.faults.spawn_step = 9;
    let r = common::run(c);

    for s in [4u64, 8] {
        let e = event_at(&r, s);
        assert_eq!((e.alive, e.joins), (3, 0), "pre-spawn round {s}: {e:?}");
    }
    let e12 = event_at(&r, 12);
    assert_eq!((e12.alive, e12.joins, e12.crashes), (3, 1, 0), "spawn round: {e12:?}");
    assert!(r
        .recorder
        .fault_events
        .iter()
        .filter(|e| e.step >= 16)
        .all(|e| e.alive == 4 && e.participants == 4 && e.joins == 0));
    assert!(r.final_eval.expect("final eval").loss.is_finite());
}

/// The standing invariant, extended to the membership engine: a
/// `[faults]` table that only arms the autoscaler — with thresholds no
/// round ever trips — is bitwise-identical to the default fault-free run.
#[test]
fn churn_free_autoscale_run_is_bitwise_identical_to_default() {
    let base = common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 48);
    let mut c = base.clone();
    c.train.fused = false; // no-op on rust_math; required by validation
    c.faults.autoscale = true;
    c.faults.autoscale_straggler_s = 1e9; // no round is ever "congested"
    c.faults.autoscale_drift = 1e18; // no round is ever "drifty"
    let a = common::run(base);
    let b = common::run(c);
    common::assert_bitwise_eq(&a, &b, "churn-free autoscale vs default");
    // The armed engine logs one participation event per round — all quiet.
    assert!(a.recorder.fault_events.is_empty());
    assert!(!b.recorder.fault_events.is_empty());
    assert!(b
        .recorder
        .fault_events
        .iter()
        .all(|e| e.crashes == 0 && e.leaves == 0 && e.joins == 0 && e.dropped == 0));
}

/// Seeded churn plans replay byte-identically: two runs of the same
/// config produce bit-equal training data and byte-equal fault CSVs.
#[test]
fn seeded_churn_plans_replay_byte_identically() {
    let dir = common::tmpdir("churn_replay");
    prop::check("churn plans replay", 6, |g| {
        let workers = g.usize_in(3..5);
        let steps = 4 * g.u64_in(6..11); // 24..=40, whole phases
        let mut c = elastic_cfg(workers, steps);
        c.train.seed = g.u64_in(1..1_000_000);
        c.faults.crash_worker = 1;
        c.faults.crash_step = g.u64_in(2..steps);
        if g.usize_in(0..2) == 1 {
            // A rejoin past the end of the run is a permanent crash.
            c.faults.rejoin_step = c.faults.crash_step + g.u64_in(1..12);
        }
        if g.usize_in(0..2) == 1 {
            c.faults.spawn_workers = 1;
            c.faults.spawn_step = g.u64_in(1..steps);
        }
        let a = common::run(c.clone());
        let b = common::run(c);
        common::assert_bitwise_eq(&a, &b, "churn replay");
        let (pa, pb) = (format!("{dir}/a.csv"), format!("{dir}/b.csv"));
        a.recorder.write_faults_csv(&pa).unwrap();
        b.recorder.write_faults_csv(&pb).unwrap();
        prop::assert_that(
            std::fs::read_to_string(&pa).unwrap() == std::fs::read_to_string(&pb).unwrap(),
            "fault CSVs must replay byte-identically",
        )
    });
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// In-process: telemetry-driven autoscaling
// ---------------------------------------------------------------------------

/// Healthy, drifty rounds admit a queued spare (`spawn_step = 0`) after
/// `autoscale_patience` rounds.
#[test]
fn autoscale_admits_a_queued_spare_after_patience() {
    let mut c = elastic_cfg(4, 48);
    c.faults.spawn_workers = 1; // worker 3 is the queued spare
    c.faults.spawn_step = 0;
    c.faults.autoscale = true;
    c.faults.autoscale_drift = 0.0; // every healthy round counts as drifty
    c.faults.autoscale_straggler_s = 1e9; // never congested
    c.faults.autoscale_patience = 2;
    let r = common::run(c);

    let e4 = event_at(&r, 4);
    assert_eq!((e4.alive, e4.joins), (3, 0), "first round: {e4:?}");
    // Two healthy rounds -> the t = 8 boundary admits the spare.
    let e8 = event_at(&r, 8);
    assert_eq!((e8.alive, e8.joins, e8.leaves), (3, 1, 0), "admission round: {e8:?}");
    // The spare pool is exhausted: later Admit votes are no-ops.
    assert!(r
        .recorder
        .fault_events
        .iter()
        .filter(|e| e.step >= 12)
        .all(|e| e.alive == 4 && e.participants == 4 && e.joins == 0));
    assert!(r.final_eval.expect("final eval").loss.is_finite());
}

/// Persistently congested rounds retire the slowest live worker — billed
/// as a voluntary leave, never a crash — and the barrier wait vanishes.
#[test]
fn autoscale_retires_a_persistent_straggler_as_a_leave() {
    let mut c = elastic_cfg(4, 48);
    c.faults.slow_workers = 1; // worker 3 is 4x slow
    c.faults.slow_factor = 4.0;
    c.faults.autoscale = true;
    c.faults.autoscale_straggler_s = 1e-6; // any real wait is congestion
    c.faults.autoscale_drift = 1e18; // never vote Admit
    c.faults.autoscale_patience = 2;
    let r = common::run(c);

    let e4 = event_at(&r, 4);
    assert!(e4.wait_s > 0.0, "full barrier must wait on the slow worker: {e4:?}");
    assert_eq!((e4.alive, e4.leaves), (4, 0), "first round: {e4:?}");
    // Two congested rounds -> the t = 8 boundary drops the straggler.
    let e8 = event_at(&r, 8);
    assert_eq!((e8.alive, e8.leaves, e8.crashes), (4, 1, 0), "drop round: {e8:?}");
    // The survivors run in lockstep: no barrier wait, no more churn.
    assert!(r
        .recorder
        .fault_events
        .iter()
        .filter(|e| e.step >= 12)
        .all(|e| e.alive == 3 && e.participants == 3 && e.wait_s == 0.0 && e.leaves == 0));
    assert!(r.recorder.fault_events.iter().all(|e| e.crashes == 0));
    assert!(r.final_eval.expect("final eval").loss.is_finite());
}

// ---------------------------------------------------------------------------
// Real sockets: kill, relaunch --rejoin, voluntary leave
// ---------------------------------------------------------------------------

/// One networked elastic deployment's experiment TOML: H = 4
/// local-AdaAlter under a quorum of 2, so the run survives the gap
/// between a worker's death and its relaunch.
fn elastic_toml(workers: usize, steps: u64, dim: usize, log_every: u64) -> String {
    format!(
        "[train]\n\
         workers = {workers}\n\
         sync_period = 4\n\
         steps = {steps}\n\
         steps_per_epoch = 50\n\
         log_every = {log_every}\n\
         fused = false\n\
         backend = \"rust_math\"\n\
         rust_math_dim = {dim}\n\
         [optim]\n\
         algorithm = \"local_adaalter\"\n\
         warmup_steps = 10\n\
         [comm]\n\
         transport = \"tcp\"\n\
         [faults]\n\
         quorum = 2\n\
         [net]\n\
         listen = \"127.0.0.1:0\"\n\
         connect_timeout_s = 60.0\n"
    )
}

/// Leader faults CSV for [`elastic_toml`] runs (tag = algo_wN_hH).
fn faults_csv(dir: &str, workers: usize) -> String {
    let path = format!("{dir}/faults_local_adaalter_w{workers}_h4.csv");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// The tentpole, end to end over real TCP: a worker process killed
/// mid-run is relaunched with `--rejoin`, admitted through the late
/// `Join` handshake at a sync boundary, catches up via `InstallState`,
/// and the run converges to the same final eval as an uninterrupted
/// quorum run.
#[test]
fn killed_worker_process_rejoins_over_tcp_and_converges() {
    let dir = common::tmpdir("tcp_rejoin");
    // Enough steps that the relaunch (tens of milliseconds after the
    // kill) lands well inside the run on any host; boundaries come every
    // 4 steps, so admission follows almost immediately.
    let toml = elastic_toml(3, 10_000, 256, 200);
    let cfg_path = common::write_cfg(&dir, &toml);
    let mut leader = common::spawn_leader(&cfg_path, &dir);
    let mut w0 = common::spawn_worker(&cfg_path, &dir, 0, &[]);
    let mut w1 = common::spawn_worker(&cfg_path, &dir, 1, &[]);
    let kill = vec![(adaalter::comm::net::EXIT_AT_STEP_ENV.to_string(), "7".to_string())];
    let mut w2 = common::spawn_worker(&cfg_path, &dir, 2, &kill);

    let limit = std::time::Duration::from_secs(120);
    let st = w2.wait_within(limit);
    assert_eq!(st.code(), Some(3), "worker 2 must die through the kill hook: {st}");

    // Relaunch the same worker id against the live run.
    let mut w2b = common::spawn_worker_with(&cfg_path, &dir, 2, &["--rejoin"], &[]);
    let st = w2b.wait_within(limit);
    assert!(st.success(), "relaunched worker 2 must rejoin and finish: {st}");
    for (g, name) in [(&mut w0, "worker 0"), (&mut w1, "worker 1")] {
        let st = g.wait_within(limit);
        assert!(st.success(), "{name} failed: {st}");
    }
    let st = leader.wait_within(limit);
    assert!(st.success(), "leader failed: {st}");

    // The leader billed exactly one crash and (at least) one admission.
    let csv = faults_csv(&dir, 3);
    assert_eq!(csv_column_sum(&csv, "crashes"), 1.0, "exactly one crash billed");
    assert!(csv_column_sum(&csv, "joins") >= 1.0, "the relaunch must be admitted");
    assert_eq!(csv_column_sum(&csv, "leaves"), 0.0, "nothing left voluntarily");

    // Convergence: same final eval as the uninterrupted quorum run (the
    // crash window perturbs the trajectory, so this is a closeness pin,
    // not a bitwise one).
    let rep = common::net_report(&dir);
    let bits = u64::from_str_radix(
        rep.req("final_eval_loss_bits").unwrap().str().expect("final eval recorded"),
        16,
    )
    .unwrap();
    let got = f64::from_bits(bits);
    let ref_toml = toml.replace("transport = \"tcp\"", "transport = \"simulated\"");
    let ref_cfg = ExperimentConfig::from_doc(&TomlDoc::parse(&ref_toml).unwrap()).unwrap();
    let want = common::run(ref_cfg).final_eval.expect("reference eval").loss;
    assert!(
        (got - want).abs() <= 0.1 * want.abs() + 1e-6,
        "rejoined run must converge with the uninterrupted one: got {got}, want {want}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A voluntary departure over the wire: the worker sends a `Leave` frame
/// and exits cleanly; the leader bills a leave, not a crash, and the
/// quorum run finishes on the remaining fleet.
#[test]
fn voluntary_leave_over_tcp_is_billed_as_leave_not_crash() {
    let toml = elastic_toml(3, 400, 64, 50);
    let env = vec![(
        2usize,
        adaalter::comm::net::LEAVE_AT_STEP_ENV.to_string(),
        "30".to_string(),
    )];
    let run = common::run_net(&toml, 3, "tcp_leave", &env);
    assert!(run.workers[2].success(), "leaving worker exits clean: {}", run.workers[2]);
    for (w, st) in run.workers.iter().take(2).enumerate() {
        assert!(st.success(), "worker {w} failed: {st}");
    }
    assert!(run.leader.success(), "leader must finish on the remainder: {}", run.leader);

    let csv = faults_csv(&run.out_dir, 3);
    assert_eq!(csv_column_sum(&csv, "leaves"), 1.0, "one voluntary leave billed");
    assert_eq!(csv_column_sum(&csv, "crashes"), 0.0, "a leave is not a crash");
    assert_eq!(csv_column_sum(&csv, "joins"), 0.0, "nothing rejoined");
    std::fs::remove_dir_all(&run.out_dir).ok();
}
