//! Integration: checkpoint/resume correctness — a resumed run must be
//! bitwise-equal to an uninterrupted one (training is deterministic, so any
//! divergence is a state-capture bug).

mod common;

use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{Checkpoint, Trainer};

use common::{factory, tmpdir};

fn cfg(algo: Algorithm, h: SyncPeriod, steps: u64, ckpt_every: u64, dir: &str) -> ExperimentConfig {
    let mut c = common::cfg_dim(algo, h, 4, steps, 128, 10);
    c.train.checkpoint_every = ckpt_every;
    c.train.checkpoint_path = format!("{dir}/ck.bin");
    c.out_dir = dir.to_string();
    c
}

fn resume_equals_straight(algo: Algorithm, h: SyncPeriod, mid: u64, total: u64) {
    let dir = tmpdir(algo.name());

    // Straight run to `total`.
    let c_straight = cfg(algo, h, total, 0, &dir);
    let r_straight = Trainer::new(c_straight.clone(), factory(&c_straight)).run().unwrap();

    // First half: run to `mid`, checkpointing at `mid`.
    let c_half = cfg(algo, h, mid, mid, &dir);
    let _ = Trainer::new(c_half.clone(), factory(&c_half)).run().unwrap();
    let ck = Checkpoint::load(format!("{dir}/ck.bin")).unwrap();
    assert_eq!(ck.step, mid);
    assert_eq!(ck.algorithm, algo);

    // Second half: resume to `total`.
    let c_rest = cfg(algo, h, total, 0, &dir);
    let mut t = Trainer::new(c_rest.clone(), factory(&c_rest));
    t.resume = Some(ck);
    let r_resumed = t.run().unwrap();

    let diff = adaalter::util::math::max_abs_diff(&r_straight.final_x, &r_resumed.final_x);
    assert!(
        diff == 0.0,
        "{algo}: resumed run diverged from straight run by {diff}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_exact_adagrad() {
    resume_equals_straight(Algorithm::AdaGrad, SyncPeriod::Every(1), 30, 60);
}

#[test]
fn resume_exact_adaalter() {
    resume_equals_straight(Algorithm::AdaAlter, SyncPeriod::Every(1), 25, 60);
}

#[test]
fn resume_exact_sgd() {
    resume_equals_straight(Algorithm::Sgd, SyncPeriod::Every(1), 30, 60);
}

#[test]
fn resume_exact_local_adaalter_at_sync_boundary() {
    // checkpoint_every must align with H (validated by the config layer);
    // mid = 32 is a sync boundary for H = 4.
    resume_equals_straight(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 32, 64);
}

#[test]
fn resume_exact_local_sgd() {
    resume_equals_straight(Algorithm::LocalSgd, SyncPeriod::Every(4), 32, 64);
}

/// The lifted checkpoint × faults ban, end to end: a run with a crash
/// *and* a scheduled rejoin checkpoints at a boundary mid-scenario, and
/// the resumed run is bitwise-equal to the uninterrupted one. The resume
/// lands inside the crash window (crash 10 ≤ 16 < rejoin 23), so the
/// membership table must be reconstructed from the replayed plan: worker
/// 2 starts the resumed run absent and is re-admitted at the t = 24
/// boundary exactly as the straight run re-admits it.
#[test]
fn resume_under_fault_scenario_equals_uninterrupted() {
    let dir = tmpdir("faulted_resume");
    let faulted = |steps: u64, ck: u64| {
        let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), steps, ck, &dir);
        c.train.fused = false;
        c.faults.crash_worker = 2;
        c.faults.crash_step = 10;
        c.faults.rejoin_step = 23;
        c
    };

    let c_straight = faulted(40, 0);
    let r_straight = Trainer::new(c_straight.clone(), factory(&c_straight)).run().unwrap();

    let c_half = faulted(16, 16);
    let _ = Trainer::new(c_half.clone(), factory(&c_half)).run().unwrap();
    let ck = Checkpoint::load(format!("{dir}/ck.bin")).unwrap();
    assert_eq!(ck.step, 16);

    let c_rest = faulted(40, 0);
    let mut t = Trainer::new(c_rest.clone(), factory(&c_rest));
    t.resume = Some(ck);
    let r_resumed = t.run().unwrap();

    assert_eq!(
        r_straight.final_x, r_resumed.final_x,
        "resumed faulted run diverged from the uninterrupted one"
    );
    assert_eq!(
        r_straight.final_eval.as_ref().unwrap().loss.to_bits(),
        r_resumed.final_eval.as_ref().unwrap().loss.to_bits()
    );
    // Both runs re-admitted worker 2 at the t = 24 boundary.
    let joined = |r: &adaalter::coordinator::RunResult| {
        r.recorder
            .fault_events
            .iter()
            .find(|e| e.joins > 0)
            .map(|e| (e.step, e.joins, e.crashes))
    };
    assert_eq!(joined(&r_straight), Some((24, 1, 0)), "straight-run admission");
    assert_eq!(joined(&r_resumed), Some((24, 1, 0)), "resumed-run admission");
    // The straight run additionally saw the crash itself.
    assert!(r_straight.recorder.fault_events.iter().any(|e| e.crashes == 1));
    assert!(r_resumed.recorder.fault_events.iter().all(|e| e.crashes == 0));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_rejects_misaligned_checkpoint_cadence() {
    let dir = tmpdir("misaligned");
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 64, 6, &dir);
    c.train.checkpoint_every = 6; // not a multiple of H=4
    assert!(c.validate().is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_algorithm_mismatch() {
    let dir = tmpdir("mismatch");
    let c1 = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 10, 10, &dir);
    Trainer::new(c1.clone(), factory(&c1)).run().unwrap();
    let ck = Checkpoint::load(format!("{dir}/ck.bin")).unwrap();

    let c2 = cfg(Algorithm::AdaAlter, SyncPeriod::Every(1), 20, 0, &dir);
    let mut t = Trainer::new(c2.clone(), factory(&c2));
    t.resume = Some(ck);
    let err = t.run().err().expect("must fail").to_string();
    assert!(err.contains("checkpoint is for"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_dimension_mismatch() {
    let dir = tmpdir("dim");
    let c1 = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 10, 10, &dir);
    Trainer::new(c1.clone(), factory(&c1)).run().unwrap();
    let ck = Checkpoint::load(format!("{dir}/ck.bin")).unwrap();

    let mut c2 = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 20, 0, &dir);
    c2.train.rust_math_dim = 256;
    let mut t = Trainer::new(c2.clone(), factory(&c2));
    t.resume = Some(ck);
    let err = t.run().err().expect("must fail").to_string();
    assert!(err.contains("checkpoint d="), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
