//! Integration: checkpoint/resume correctness — a resumed run must be
//! bitwise-equal to an uninterrupted one (training is deterministic, so any
//! divergence is a state-capture bug).

mod common;

use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{Checkpoint, Trainer};

use common::{factory, tmpdir};

fn cfg(algo: Algorithm, h: SyncPeriod, steps: u64, ckpt_every: u64, dir: &str) -> ExperimentConfig {
    let mut c = common::cfg_dim(algo, h, 4, steps, 128, 10);
    c.train.checkpoint_every = ckpt_every;
    c.train.checkpoint_path = format!("{dir}/ck.bin");
    c.out_dir = dir.to_string();
    c
}

fn resume_equals_straight(algo: Algorithm, h: SyncPeriod, mid: u64, total: u64) {
    let dir = tmpdir(algo.name());

    // Straight run to `total`.
    let c_straight = cfg(algo, h, total, 0, &dir);
    let r_straight = Trainer::new(c_straight.clone(), factory(&c_straight)).run().unwrap();

    // First half: run to `mid`, checkpointing at `mid`.
    let c_half = cfg(algo, h, mid, mid, &dir);
    let _ = Trainer::new(c_half.clone(), factory(&c_half)).run().unwrap();
    let ck = Checkpoint::load(format!("{dir}/ck.bin")).unwrap();
    assert_eq!(ck.step, mid);
    assert_eq!(ck.algorithm, algo);

    // Second half: resume to `total`.
    let c_rest = cfg(algo, h, total, 0, &dir);
    let mut t = Trainer::new(c_rest.clone(), factory(&c_rest));
    t.resume = Some(ck);
    let r_resumed = t.run().unwrap();

    let diff = adaalter::util::math::max_abs_diff(&r_straight.final_x, &r_resumed.final_x);
    assert!(
        diff == 0.0,
        "{algo}: resumed run diverged from straight run by {diff}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_exact_adagrad() {
    resume_equals_straight(Algorithm::AdaGrad, SyncPeriod::Every(1), 30, 60);
}

#[test]
fn resume_exact_adaalter() {
    resume_equals_straight(Algorithm::AdaAlter, SyncPeriod::Every(1), 25, 60);
}

#[test]
fn resume_exact_sgd() {
    resume_equals_straight(Algorithm::Sgd, SyncPeriod::Every(1), 30, 60);
}

#[test]
fn resume_exact_local_adaalter_at_sync_boundary() {
    // checkpoint_every must align with H (validated by the config layer);
    // mid = 32 is a sync boundary for H = 4.
    resume_equals_straight(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 32, 64);
}

#[test]
fn resume_exact_local_sgd() {
    resume_equals_straight(Algorithm::LocalSgd, SyncPeriod::Every(4), 32, 64);
}

#[test]
fn config_rejects_misaligned_checkpoint_cadence() {
    let dir = tmpdir("misaligned");
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 64, 6, &dir);
    c.train.checkpoint_every = 6; // not a multiple of H=4
    assert!(c.validate().is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_algorithm_mismatch() {
    let dir = tmpdir("mismatch");
    let c1 = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 10, 10, &dir);
    Trainer::new(c1.clone(), factory(&c1)).run().unwrap();
    let ck = Checkpoint::load(format!("{dir}/ck.bin")).unwrap();

    let c2 = cfg(Algorithm::AdaAlter, SyncPeriod::Every(1), 20, 0, &dir);
    let mut t = Trainer::new(c2.clone(), factory(&c2));
    t.resume = Some(ck);
    let err = t.run().err().expect("must fail").to_string();
    assert!(err.contains("checkpoint is for"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_dimension_mismatch() {
    let dir = tmpdir("dim");
    let c1 = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 10, 10, &dir);
    Trainer::new(c1.clone(), factory(&c1)).run().unwrap();
    let ck = Checkpoint::load(format!("{dir}/ck.bin")).unwrap();

    let mut c2 = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 20, 0, &dir);
    c2.train.rust_math_dim = 256;
    let mut t = Trainer::new(c2.clone(), factory(&c2));
    t.resume = Some(ck);
    let err = t.run().err().expect("must fail").to_string();
    assert!(err.contains("checkpoint d="), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
