//! Integration: the algorithmic equivalences the paper's §4 builds on,
//! exercised through the full threaded trainer (leader + workers +
//! channels), on the synthetic backend.

mod common;

use std::sync::Arc;

use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, Trainer};
use adaalter::sim::SyntheticProblem;
use adaalter::util::math;

use common::run;

fn cfg(algo: Algorithm, h: SyncPeriod, workers: usize, steps: u64) -> ExperimentConfig {
    common::cfg_dim(algo, h, workers, steps, 512, 25)
}

/// Paper §4.3: with H=1, Algorithm 4 must coincide with Algorithm 3 —
/// every worker's placeholder is exactly ε², and sync averaging of the
/// accumulators equals the leader-side mean of squares. This holds across
/// worker counts.
#[test]
fn local_h1_equals_sync_adaalter_across_worker_counts() {
    for workers in [1usize, 2, 5, 8] {
        let local = run(cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(1), workers, 60));
        let sync = run(cfg(Algorithm::AdaAlter, SyncPeriod::Every(1), workers, 60));
        let diff = math::max_abs_diff(&local.final_x, &sync.final_x);
        assert!(diff < 1e-3, "workers={workers}: divergence {diff}");
    }
}

/// Same equivalence for local SGD vs fully-synchronous SGD at H=1
/// (averaging linear updates commutes with the update).
#[test]
fn local_sgd_h1_equals_sync_sgd() {
    let mut a = cfg(Algorithm::LocalSgd, SyncPeriod::Every(1), 4, 60);
    let mut b = cfg(Algorithm::Sgd, SyncPeriod::Every(1), 4, 60);
    a.optim.eta = 0.1;
    b.optim.eta = 0.1;
    let (ra, rb) = (run(a), run(b));
    let diff = math::max_abs_diff(&ra.final_x, &rb.final_x);
    assert!(diff < 1e-3, "divergence {diff}");
}

/// Larger H must not crash, must sync exactly floor(T/H) times, and must
/// still converge to a sane region.
#[test]
fn h_sweep_converges_and_counts_syncs() {
    for h in [2u64, 4, 7, 16] {
        let c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), 4, 160);
        let r = run(c);
        assert_eq!(r.recorder.comm().0, 160 / h, "H={h}");
        let loss = r.final_eval.unwrap().loss;
        assert!(loss.is_finite() && loss < 600.0, "H={h}: loss {loss}");
    }
}

/// The monotone noise story of Theorem 2, measured: with the SAME seed and
/// budget, larger H must not dramatically beat smaller H near the optimum
/// (trade-off direction check on train suboptimality averaged over the
/// final quarter).
#[test]
fn larger_h_is_noisier_near_convergence() {
    let problem = SyntheticProblem::new(512, 4, 42);
    let opt_loss = problem.global_loss(&problem.optimum());
    let mut finals = Vec::new();
    for h in [1u64, 16] {
        let c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), 4, 600);
        let r = run(c);
        finals.push(r.final_eval.unwrap().loss - opt_loss);
    }
    // H=16 ends at least as far from the optimum as H=1 (allow 20% slack
    // for noise).
    assert!(
        finals[1] >= finals[0] * 0.8 - 1e-4,
        "H=16 subopt {} unexpectedly beats H=1 subopt {}",
        finals[1],
        finals[0]
    );
}

/// Worker failure (backend construction error) must surface as an error,
/// not a deadlock.
#[test]
fn worker_failure_propagates() {
    let c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 50);
    let p = SyntheticProblem::new(c.train.rust_math_dim, 4, 1);
    let f: BackendFactory = Arc::new(move |w| {
        if w == 2 {
            Err(adaalter::Error::Data("injected failure".into()))
        } else {
            Ok(Box::new(p.backend(w)) as Box<_>)
        }
    });
    let err = Trainer::new(c, f).run().err().expect("must fail");
    assert!(err.to_string().contains("injected failure"), "{err}");
}

/// Mid-training gradient failure must also surface cleanly.
#[test]
fn mid_training_failure_propagates() {
    use adaalter::coordinator::{EvalMetrics, WorkerBackend};

    struct Flaky {
        inner: adaalter::sim::SyntheticBackend,
        fail_at: u64,
    }
    impl WorkerBackend for Flaky {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn loss_and_grad(
            &mut self,
            x: &[f32],
            step: u64,
            out: &mut [f32],
        ) -> adaalter::Result<f32> {
            if step == self.fail_at {
                return Err(adaalter::Error::Data("flaky gradient".into()));
            }
            self.inner.loss_and_grad(x, step, out)
        }
        fn eval(&mut self, x: &[f32]) -> adaalter::Result<EvalMetrics> {
            self.inner.eval(x)
        }
        fn init_params(&self) -> adaalter::Result<Vec<f32>> {
            self.inner.init_params()
        }
    }

    let c = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 3, 50);
    let p = SyntheticProblem::new(c.train.rust_math_dim, 3, 1);
    let f: BackendFactory = Arc::new(move |w| {
        Ok(Box::new(Flaky { inner: p.backend(w), fail_at: 17 }) as Box<_>)
    });
    let err = Trainer::new(c, f).run().err().expect("must fail");
    assert!(err.to_string().contains("flaky gradient"), "{err}");
}

/// Thread-schedule independence: two runs with the same seed but different
/// worker counts *differ*, same worker count *agree bitwise*.
#[test]
fn determinism_and_seed_sensitivity() {
    let base = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 80);
    let r1 = run(base.clone());
    let r2 = run(base.clone());
    assert_eq!(r1.final_x, r2.final_x);

    let mut seeded = base.clone();
    seeded.train.seed = 43;
    let r3 = run(seeded);
    assert_ne!(r1.final_x, r3.final_x, "seed must matter");
}

/// Warm-up interacts with the accumulator: disabling warm-up with a large
/// η must still produce finite parameters (AdaAlter's stale denominator
/// tolerates it on this smooth problem), and warm-up must not change the
/// late-training trajectory materially.
#[test]
fn warmup_robustness() {
    let mut no_warm = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 200);
    no_warm.optim.warmup_steps = 0;
    let r = run(no_warm);
    assert!(r.final_x.iter().all(|v| v.is_finite()));
    assert!(r.final_eval.unwrap().loss.is_finite());
}
