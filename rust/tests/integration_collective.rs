//! Integration: the pluggable collective layer, through the full threaded
//! trainer on the synthetic backend.
//!
//! * The data plane is transport-invariant: the bare `channel` collective
//!   and the default α–β-charged `simulated` collective produce bitwise
//!   identical parameters and loss traces (the seed trainer's data path,
//!   preserved — its averaging ran the same `math::mean_into` these
//!   collectives run).
//! * The recorded traffic matches `SyncScheduler::comm_fraction` — the
//!   paper's `2/H` claim — exactly, for H ∈ {1, 4, 16}.
//! * Compressed transports (QSGD / top-k) run end-to-end, report *exact*
//!   wire bytes, and are selected purely via `ExperimentConfig`.
//! * Sharding the parameter server (`comm.shards = k`) and switching to
//!   the tree reduction (`net.topology = "tree"`) change only the cost
//!   accounting — the data plane stays bitwise-identical.

mod common;

use adaalter::comm::{NetModel, QsgdQuantizer};
use adaalter::config::{Algorithm, SyncPeriod};
use adaalter::coordinator::{Checkpoint, SyncScheduler, Trainer};
use adaalter::sim::SyntheticProblem;

use common::{assert_bitwise_eq, cfg, factory, run};

/// The ISSUE's equivalence criterion: the in-process ChannelCollective
/// reproduces the (simulated-default) trainer bitwise — same final x and
/// same loss trace — for fully-sync AdaGrad at H=1 and local AdaAlter at
/// H=4. The two transports differ only in cost accounting.
#[test]
fn channel_collective_is_bitwise_identical_to_simulated() {
    for (algo, h) in [
        (Algorithm::AdaGrad, SyncPeriod::Every(1)),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(4)),
    ] {
        let sim_cfg = cfg(algo, h, 4, 40);
        let mut chan_cfg = sim_cfg.clone();
        chan_cfg.comm.transport = "channel".into();
        let a = run(sim_cfg);
        let b = run(chan_cfg);
        assert_bitwise_eq(&a, &b, &format!("{algo} across transports"));
        // What differs is the accounting: channel models zero cost.
        assert!(a.recorder.comm().1 > 0);
        assert_eq!(b.recorder.comm().1, 0);
        assert_eq!(a.recorder.comm().0, b.recorder.comm().0, "round counts must agree");
    }
}

/// Recorded sync bytes must equal rounds × per-round traffic, and the
/// byte ratio against fully-synchronous AdaGrad must be exactly the
/// scheduler's comm_fraction — the paper's 2/H — for H ∈ {1, 4, 16}.
#[test]
fn recorded_bytes_match_comm_fraction() {
    let n = 4usize;
    let steps = 48u64;
    let base = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), n, steps);
    let net = NetModel::from_config(&base.net);
    let d_bytes = 4 * base.train.rust_math_dim as u64;

    let sync_run = run(base);
    let (sync_rounds, sync_bytes) = sync_run.recorder.comm();
    assert_eq!(sync_rounds, steps);
    assert_eq!(sync_bytes, steps * net.sync_traffic_bytes(n, d_bytes, 1));

    for h in [1u64, 4, 16] {
        let c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), n, steps);
        let r = run(c);
        let (rounds, bytes) = r.recorder.comm();
        let sched = SyncScheduler::new(SyncPeriod::Every(h));
        assert_eq!(rounds, sched.syncs_up_to(steps), "H={h}");
        // Exact per-round accounting: 2 vectors (params + denominators),
        // pinned both per-round and through the scheduler's total-vector
        // count (traffic is linear in vectors).
        assert_eq!(
            bytes,
            sched.syncs_up_to(steps) * net.sync_traffic_bytes(n, d_bytes, 2),
            "H={h}"
        );
        assert_eq!(
            bytes,
            sched.vectors_up_to(steps, true) * net.sync_traffic_bytes(n, d_bytes, 1),
            "H={h}"
        );
        // And therefore exactly the paper's 2/H of fully-sync traffic.
        let frac = bytes as f64 / sync_bytes as f64;
        let want = sched.comm_fraction(true);
        assert!(
            (frac - want).abs() < 1e-12,
            "H={h}: measured fraction {frac} vs comm_fraction {want}"
        );
    }
}

/// QSGD-compressed local AdaAlter: selected purely by config, exact wire
/// bytes (4 compressed messages per worker per round: Δx up, ΔA² up, and
/// the two quantized average deltas down), finite training.
#[test]
fn qsgd_sync_rounds_report_exact_bytes() {
    let (n, steps, h) = (4usize, 24u64, 4u64);
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), n, steps);
    c.train.rust_math_dim = 256;
    c.comm.transport = "channel".into();
    c.comm.compression = "qsgd".into();
    c.comm.qsgd_levels = 15;
    let r = run(c);
    assert!(r.final_x.iter().all(|v| v.is_finite()));
    let (rounds, bytes) = r.recorder.comm();
    assert_eq!(rounds, steps / h);
    let per_msg = QsgdQuantizer::new(15).wire_bytes(256);
    let per_round = 4 * n as u64 * per_msg;
    assert_eq!(bytes, rounds * per_round);
    assert_eq!(r.recorder.transport(), "qsgd(s=15)");
}

/// Top-k with 1% keep: constant k per message, so bytes are exactly
/// rounds × 4n × 8k; error-feedback residuals persist across rounds
/// without breaking training.
#[test]
fn topk_sync_rounds_report_exact_bytes() {
    let (n, steps, h, d) = (4usize, 24u64, 4u64, 256usize);
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), n, steps);
    c.train.rust_math_dim = d;
    c.comm.transport = "channel".into();
    c.comm.compression = "topk".into();
    c.comm.topk_keep = 0.01;
    let r = run(c);
    assert!(r.final_x.iter().all(|v| v.is_finite()));
    let (rounds, bytes) = r.recorder.comm();
    assert_eq!(rounds, steps / h);
    let k = ((d as f64) * 0.01).ceil() as u64; // 3 coordinates
    assert_eq!(bytes, rounds * 4 * n as u64 * 8 * k);
    assert!(r.recorder.transport().starts_with("topk"));
}

/// Compression also covers the fully-synchronous gradient-gather path:
/// per iteration, n compressed gradients up + the dense model pull down.
#[test]
fn qsgd_gradient_gather_reports_exact_bytes() {
    let (n, steps, d) = (4usize, 10u64, 128usize);
    let mut c = cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), n, steps);
    c.train.rust_math_dim = d;
    c.comm.transport = "channel".into();
    c.comm.compression = "qsgd".into();
    c.comm.qsgd_levels = 15;
    let r = run(c);
    assert!(r.final_x.iter().all(|v| v.is_finite()));
    let (rounds, bytes) = r.recorder.comm();
    assert_eq!(rounds, steps);
    let per_iter = n as u64 * QsgdQuantizer::new(15).wire_bytes(d) + n as u64 * 4 * d as u64;
    assert_eq!(bytes, steps * per_iter);
}

/// Ring all-reduce is one config key away and changes the traffic model:
/// 2(n−1)·payload per round instead of the PS's 2n·payload.
#[test]
fn ring_allreduce_traffic_selected_by_config() {
    let (n, steps, h) = (4usize, 16u64, 4u64);
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), n, steps);
    c.net.topology = "allreduce".into();
    let net = NetModel::from_config(&c.net);
    let d_bytes = 4 * c.train.rust_math_dim as u64;
    let r = run(c);
    let (rounds, bytes) = r.recorder.comm();
    assert_eq!(rounds, steps / h);
    assert_eq!(bytes, rounds * net.sync_traffic_bytes(n, d_bytes, 2));
    assert_eq!(bytes, rounds * 2 * (n as u64 - 1) * d_bytes * 2);
    assert_eq!(r.recorder.transport(), "simulated(allreduce)");
}

/// The ISSUE's sharding equivalence criterion: `comm.shards = k` range-
/// partitions the parameter server across k shard servers, yet the final
/// parameters, loss trace and final eval are bitwise-identical to the
/// single-leader run — for fully-sync AdaGrad at H=1 and local AdaAlter
/// at H=4 — and the recorded bytes are identical too (the per-shard byte
/// sums equal the dense totals exactly).
#[test]
fn sharded_ps_is_bitwise_identical_to_single_leader() {
    for (algo, h) in [
        (Algorithm::AdaGrad, SyncPeriod::Every(1)),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(4)),
    ] {
        // Dim 64 with k=5 exercises the uneven split (64 = 5·12 + 4).
        let dense_cfg = cfg(algo, h, 4, 40);
        let mut shard_cfg = dense_cfg.clone();
        shard_cfg.comm.shards = 5;
        let a = run(dense_cfg);
        let b = run(shard_cfg);
        assert_bitwise_eq(&a, &b, &format!("{algo} sharded vs single-leader PS"));
        assert_eq!(a.recorder.comm(), b.recorder.comm(), "{algo}: byte accounting drifted");
        assert_eq!(a.recorder.transport(), "simulated(ps)");
        assert_eq!(b.recorder.transport(), "simulated(ps, shards=5)");
    }
}

/// The tree reduction is one config key away, keeps the data plane
/// bitwise-identical (cost model only), and charges the all-reduce
/// traffic total 2(n−1)·payload instead of the PS's 2n·payload.
#[test]
fn tree_topology_traffic_selected_by_config() {
    let (n, steps, h) = (4usize, 16u64, 4u64);
    let base = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), n, steps);
    let mut c = base.clone();
    c.net.topology = "tree".into();
    c.net.tree_fanout = 2;
    let net = NetModel::from_config(&c.net);
    let d_bytes = 4 * c.train.rust_math_dim as u64;
    let a = run(base);
    let r = run(c);
    assert_bitwise_eq(&a, &r, "tree vs ps data plane");
    let (rounds, bytes) = r.recorder.comm();
    assert_eq!(rounds, steps / h);
    assert_eq!(bytes, rounds * net.sync_traffic_bytes(n, d_bytes, 2));
    assert_eq!(bytes, rounds * 2 * (n as u64 - 1) * d_bytes * 2);
    assert_eq!(r.recorder.transport(), "simulated(tree)");
}

/// Resuming over a compressed transport is rejected up front: the
/// delta-compression bases and error-feedback residuals are not part of
/// the checkpoint format, so a resumed run could not be exact.
#[test]
fn resume_rejected_over_compressed_transport() {
    let d = 64;
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 2, 8);
    c.comm.transport = "channel".into();
    c.comm.compression = "qsgd".into();
    let f = factory(&c);
    let mut t = Trainer::new(c, f);
    t.resume = Some(Checkpoint {
        step: 4,
        algorithm: Algorithm::LocalAdaAlter,
        vectors: vec![vec![0.0; d], vec![1.0; d], vec![1.0; d]],
    });
    let err = t.run().err().expect("must fail");
    assert!(err.to_string().contains("compressed"), "{err}");
}

/// The bf16 wire (`precision.wire = "bf16"`) through the full trainer:
/// selected purely by config, reports EXACTLY half the dense f32 traffic
/// of the simulated transport, and still optimizes — bf16 keeps 8
/// mantissa bits, far gentler than QSGD's norm-scaled noise.
#[test]
fn bf16_wire_halves_sync_bytes_end_to_end() {
    let (n, steps, h, d) = (4usize, 300u64, 4u64, 64usize);
    let problem = SyntheticProblem::new(d, n, 42);
    use adaalter::coordinator::WorkerBackend as _;
    let opt_loss = problem.global_loss(&problem.optimum());
    let init_sub = problem.global_loss(&problem.backend(0).init_params().unwrap()) - opt_loss;

    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), n, steps);
    c.comm.transport = "channel".into();
    c.precision.wire = "bf16".into();
    c.precision.state = "bf16".into();
    let net = NetModel::from_config(&c.net);
    let d_bytes = 4 * c.train.rust_math_dim as u64;
    let r = run(c);
    assert_eq!(r.recorder.transport(), "bf16");
    assert!(r.final_x.iter().all(|v| v.is_finite()));
    let (rounds, bytes) = r.recorder.comm();
    assert_eq!(rounds, steps / h);
    // Exactly half of what the dense f32 accounting charges per round.
    assert_eq!(bytes * 2, rounds * net.sync_traffic_bytes(n, d_bytes, 2));
    let final_sub = r.final_eval.unwrap().loss - opt_loss;
    assert!(
        final_sub < init_sub * 0.2,
        "bf16 run failed to learn: suboptimality {final_sub} vs initial {init_sub}"
    );
}

/// Compressed local AdaAlter still optimizes: with moderate compression
/// the final loss must come down substantially from the start.
#[test]
fn compressed_local_adaalter_still_learns() {
    let n = 4usize;
    let problem = SyntheticProblem::new(64, n, 42);
    use adaalter::coordinator::WorkerBackend as _;
    let opt_loss = problem.global_loss(&problem.optimum());
    let init_sub =
        problem.global_loss(&problem.backend(0).init_params().unwrap()) - opt_loss;
    let mut c = cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), n, 300);
    c.comm.transport = "channel".into();
    c.comm.compression = "qsgd".into();
    c.comm.qsgd_levels = 15;
    let r = run(c);
    let final_loss = r.final_eval.unwrap().loss;
    assert!(final_loss.is_finite());
    let final_sub = final_loss - opt_loss;
    assert!(
        final_sub < init_sub * 0.2,
        "compressed run failed to learn: suboptimality {final_sub} vs initial {init_sub}"
    );
}
