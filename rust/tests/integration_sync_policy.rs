//! Integration: the synchronization-policy subsystem (DESIGN.md §5)
//! through the full threaded trainer on the synthetic backend.
//!
//! * `policy = "fixed"` is pinned **bitwise** against the pre-policy
//!   trainer: the virtual clock and the recorded bytes must equal the
//!   closed-form accumulation the seed trainer produced (same charges in
//!   the same order), and a drift policy configured to degenerate to the
//!   fixed schedule must reproduce the fixed run's parameters exactly —
//!   the policy layer only decides *when*, never *what*.
//! * Every policy's recorded comm rounds equal the trainer's actual sync
//!   count (the sync-event log), and adaptive runs stay deterministic.

mod common;

use adaalter::comm::NetModel;
use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::SyncScheduler;
use adaalter::sim::{Calibration, Charge};

use common::run;

fn cfg(h: u64, workers: usize, steps: u64) -> ExperimentConfig {
    common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), workers, steps)
}

/// The acceptance pin: with `[sync] policy = "fixed"` (the default), the
/// virtual clock and the recorded bytes are bitwise-identical to the
/// pre-policy trainer — reproduced here as the exact closed-form charge
/// sequence (same f64 additions in the same order the leader loop makes
/// them: per sync round a communication charge, then per iteration a
/// compute charge).
#[test]
fn fixed_policy_pins_pre_policy_clock_and_bytes() {
    let (h, n, steps) = (4u64, 4usize, 40u64);
    let c = cfg(h, n, steps);
    assert!(c.sync.is_fixed(), "default policy must be fixed");
    let calib = Calibration::paper_v100();
    let net = NetModel::from_config(&c.net);
    let d_bytes = 4 * c.train.rust_math_dim as u64;

    let r = run(c);

    // Replicate the leader loop's charge sequence exactly.
    let per_round =
        (1.0 - calib.periodic_overlap) * net.sync_time(n, calib.vector_bytes(), 2);
    let mut compute = calib.t_compute_s;
    compute *= 1.0 + calib.adaalter_compute_overhead; // local AdaAlter
    let extra = (calib.dataload_s(n) - compute).max(0.0);
    let (mut now, mut comm_total, mut compute_total) = (0.0f64, 0.0f64, 0.0f64);
    for t in 1..=steps {
        if t % h == 0 {
            now += per_round;
            comm_total += per_round;
        }
        now += compute;
        compute_total += compute;
        if extra > 0.0 {
            now += extra;
        }
    }
    assert_eq!(extra, 0.0, "4 workers must not be dataloader-bound");
    assert_eq!(r.clock.now_s().to_bits(), now.to_bits(), "virtual clock drifted");
    assert_eq!(
        r.clock.total(Charge::Communication).to_bits(),
        comm_total.to_bits()
    );
    assert_eq!(r.clock.total(Charge::Compute).to_bits(), compute_total.to_bits());

    // Bytes: exactly syncs × one 2-vector round — the scheduler's 2/H.
    let sched = SyncScheduler::new(SyncPeriod::Every(h));
    let (rounds, bytes) = r.recorder.comm();
    assert_eq!(rounds, sched.syncs_up_to(steps));
    assert_eq!(bytes, sched.syncs_up_to(steps) * net.sync_traffic_bytes(n, d_bytes, 2));
}

/// A drift policy that can never trigger (θ = ∞-ish) with `h_max = H`
/// produces the *same schedule* as the fixed policy — and therefore the
/// bitwise-identical model. The policy layer decides when, never what.
#[test]
fn degenerate_drift_schedule_matches_fixed_bitwise() {
    let fixed = run(cfg(4, 4, 48));
    let mut c = cfg(4, 4, 48);
    c.sync.policy = "drift".into();
    c.sync.drift_threshold = 1e30;
    c.sync.h_max = 4;
    let drift = run(c);

    assert_eq!(fixed.final_x, drift.final_x, "schedules agree but models diverged");
    assert_eq!(
        fixed.final_eval.unwrap().loss.to_bits(),
        drift.final_eval.unwrap().loss.to_bits()
    );
    assert_eq!(fixed.recorder.comm(), drift.recorder.comm());
    // Same gaps, different bookkeeping of why.
    assert_eq!(fixed.recorder.realized_h(), drift.recorder.realized_h());
    assert!(fixed.recorder.sync_events.iter().all(|e| e.reason == "period"));
    assert!(drift.recorder.sync_events.iter().all(|e| e.reason == "h_max"));
}

/// Every policy's recorded comm rounds equal the trainer's actual sync
/// count (one event per executed round), and the event gaps sum to at
/// most the step budget.
#[test]
fn rounds_equal_sync_events_for_every_policy() {
    let setups: Vec<(&str, ExperimentConfig)> = vec![
        ("fixed", cfg(4, 4, 60)),
        ("growing", {
            let mut c = cfg(4, 4, 60);
            c.sync.policy = "growing".into();
            c.sync.h_max = 16;
            c
        }),
        ("drift", {
            let mut c = cfg(4, 4, 60);
            c.sync.policy = "drift".into();
            c.sync.drift_threshold = 0.25;
            c.sync.h_max = 8;
            c
        }),
        ("time_budget", {
            let mut c = cfg(4, 4, 60);
            c.sync.policy = "time_budget".into();
            c.sync.target_comm_fraction = 0.02;
            c
        }),
    ];
    for (name, c) in setups {
        let h_max = c.sync.h_max;
        let adaptive = !c.sync.is_fixed();
        let r = run(c);
        let (rounds, bytes) = r.recorder.comm();
        assert_eq!(
            r.recorder.sync_events.len() as u64,
            rounds,
            "{name}: events != recorded rounds"
        );
        assert!(rounds > 0, "{name}: no rounds at all");
        assert!(bytes > 0, "{name}");
        let gaps = r.recorder.realized_h();
        assert!(gaps.iter().sum::<u64>() <= 60, "{name}: gaps overrun the budget");
        assert!(gaps.iter().all(|&g| g >= 1), "{name}");
        if adaptive {
            assert!(gaps.iter().all(|&g| g <= h_max), "{name}: h_max violated: {gaps:?}");
        }
        assert!(r.final_x.iter().all(|v| v.is_finite()), "{name}");
    }
}

/// Adaptive scheduling must not break run-to-run determinism: the
/// decisions are pure functions of deterministic observations.
#[test]
fn adaptive_runs_are_deterministic() {
    let make = || {
        let mut c = cfg(4, 4, 80);
        c.sync.policy = "drift".into();
        c.sync.drift_threshold = 0.5;
        c.sync.h_max = 12;
        c
    };
    let a = run(make());
    let b = run(make());
    assert_eq!(a.final_x, b.final_x);
    assert_eq!(a.recorder.realized_h(), b.recorder.realized_h());
    assert_eq!(a.recorder.comm(), b.recorder.comm());
}
