//! Steady-state zero-allocation pins (ISSUE 5 acceptance; DESIGN.md §7):
//! once warm, the training hot paths — per-worker optimizer steps driven
//! through the execution engine, leader-side aggregation, the sync-round
//! averaging kernels, and both compression codecs including the full
//! compressed sync round — must not touch the global allocator at all.
//!
//! Boundary: the lockstep *message* layer is exempt by design —
//! `std::sync::mpsc` allocates a queue node per send — so these pins
//! drive the compute/averaging/codec paths directly, exactly as the
//! engine executes them, rather than through the channel transport.
//!
//! The whole suite is one `#[test]` function: the allocation counter is
//! process-global, and a sibling test running concurrently would pollute
//! the steady-state windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use adaalter::comm::compress::{QsgdEncoded, QsgdQuantizer, SparseGrad, TopKSparsifier};
use adaalter::comm::{ChannelCollective, Collective, CompressedCollective, NetModel};
use adaalter::config::NetConfig;
use adaalter::coordinator::aggregate::Aggregator;
use adaalter::coordinator::Executor;
use adaalter::optim::{AdaGrad, LocalAdaAlterWorker, SyncOptimizer};
use adaalter::util::kernels;
use adaalter::util::pool::{ArcSlot, BufferPool};
use adaalter::util::rng::Rng;

/// Counts every allocator entry (alloc, alloc_zeroed, realloc) and
/// delegates to the system allocator.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Allocator entries observed while running `f` on this thread.
fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    ALLOCS.load(Ordering::Relaxed) - before
}

fn randn(d: usize, seed: u64) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    Rng::new(seed).fill_normal(&mut v, 1.0);
    v
}

#[test]
fn steady_state_hot_paths_allocate_zero() {
    let d = 4096usize;
    let n = 4usize;

    // --- engine-driven local steps (Alg. 4 lines 5–7) -------------------
    {
        let ex = Executor::serial();
        let mut workers: Vec<LocalAdaAlterWorker> =
            (0..n).map(|w| LocalAdaAlterWorker::new(randn(d, 10 + w as u64), 1.0, 1.0)).collect();
        let grads: Vec<Vec<f32>> = (0..n).map(|w| randn(d, 20 + w as u64)).collect();
        let mut out: Vec<Option<f64>> = vec![None; n];
        // Warm-up round, then the measured steady-state rounds.
        ex.map(&mut workers, &mut out, |w, st| st.local_step(&grads[w], 0.1));
        let got = allocs_during(|| {
            for _ in 0..5 {
                ex.map(&mut workers, &mut out, |w, st| st.local_step(&grads[w], 0.1));
            }
        });
        assert_eq!(got, 0, "engine local steps allocated");
    }

    // --- sync-round staging + averaging (Alg. 4 lines 11–12) ------------
    {
        let mut workers: Vec<LocalAdaAlterWorker> =
            (0..n).map(|w| LocalAdaAlterWorker::new(randn(d, 30 + w as u64), 1.0, 1.0)).collect();
        let grads: Vec<Vec<f32>> = (0..n).map(|w| randn(d, 40 + w as u64)).collect();
        let mut x_stage: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; d]).collect();
        let mut acc_stage: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; d]).collect();
        let mut avg_x = vec![0.0f32; d];
        let mut avg_acc = vec![0.0f32; d];
        let mut round = |workers: &mut Vec<LocalAdaAlterWorker>,
                         x_stage: &mut Vec<Vec<f32>>,
                         acc_stage: &mut Vec<Vec<f32>>,
                         avg_x: &mut Vec<f32>,
                         avg_acc: &mut Vec<f32>| {
            for (w, st) in workers.iter_mut().enumerate() {
                st.local_step(&grads[w], 0.1);
            }
            for (w, st) in workers.iter().enumerate() {
                x_stage[w].copy_from_slice(st.x());
                acc_stage[w].copy_from_slice(st.acc());
            }
            kernels::mean_into(&x_stage[..], avg_x);
            kernels::mean_into(&acc_stage[..], avg_acc);
            for st in workers.iter_mut() {
                st.apply_sync(avg_x, avg_acc);
            }
        };
        round(&mut workers, &mut x_stage, &mut acc_stage, &mut avg_x, &mut avg_acc);
        let got = allocs_during(|| {
            for _ in 0..3 {
                round(&mut workers, &mut x_stage, &mut acc_stage, &mut avg_x, &mut avg_acc);
            }
        });
        assert_eq!(got, 0, "sync-round staging/averaging allocated");
    }

    // --- leader-side aggregation + fully-synchronous optimizer step -----
    {
        let grads: Vec<Vec<f32>> = (0..n).map(|w| randn(d, 50 + w as u64)).collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let mut agg = Aggregator::new(d);
        let mut opt = AdaGrad::new(d, 1.0, 1.0);
        let mut x = randn(d, 60);
        agg.mean_grads_and_squares(&refs);
        opt.step(&mut x, &agg.avg_g, &agg.avg_gsq, 0.1);
        let got = allocs_during(|| {
            for _ in 0..5 {
                agg.mean_grads_and_squares(&refs);
                opt.step(&mut x, &agg.avg_g, &agg.avg_gsq, 0.1);
            }
        });
        assert_eq!(got, 0, "aggregation + optimizer step allocated");
    }

    // --- codec scratch paths ---------------------------------------------
    {
        let g = randn(d, 70);
        let q = QsgdQuantizer::new(15);
        let mut rng = Rng::new(7);
        let mut enc = QsgdEncoded { norm: 0.0, levels: Vec::new(), s: 15 };
        let mut out = vec![0.0f32; d];
        q.encode_to(&g, &mut rng, &mut enc);
        q.decode(&enc, &mut out);
        let got = allocs_during(|| {
            for _ in 0..5 {
                q.encode_to(&g, &mut rng, &mut enc);
                q.decode(&enc, &mut out);
            }
        });
        assert_eq!(got, 0, "qsgd scratch roundtrip allocated");

        let mut sp = TopKSparsifier::new(d, 0.01);
        let mut msg = SparseGrad { d, idx: Vec::new(), val: Vec::new() };
        sp.encode_into(&g, &mut msg);
        let got = allocs_during(|| {
            for _ in 0..5 {
                sp.encode_into(&g, &mut msg);
            }
        });
        assert_eq!(got, 0, "top-k scratch encode allocated");
    }

    // --- full compressed sync round (delta-coded, both codecs) ----------
    for codec in ["qsgd", "topk"] {
        let net = NetModel::from_config(&NetConfig::default());
        let mut c: CompressedCollective = match codec {
            "qsgd" => CompressedCollective::qsgd(ChannelCollective::new(n, d), net, 15, 3),
            _ => CompressedCollective::topk(ChannelCollective::new(n, d), net, 0.05),
        };
        let states: Vec<Vec<f32>> = (0..n).map(|w| randn(d, 80 + w as u64)).collect();
        let accs: Vec<Vec<f32>> = (0..n).map(|w| randn(d, 90 + w as u64)).collect();
        let xs: Vec<&[f32]> = states.iter().map(|v| v.as_slice()).collect();
        let acc_refs: Vec<&[f32]> = accs.iter().map(|v| v.as_slice()).collect();
        let mut avg_x = vec![0.0f32; d];
        let mut avg_acc = vec![0.0f32; d];
        // Two warm-up rounds populate the delta/staging/codec pools.
        for _ in 0..2 {
            c.sync_round(&xs, Some(&acc_refs), &mut avg_x, Some(&mut avg_acc)).unwrap();
        }
        let got = allocs_during(|| {
            for _ in 0..3 {
                c.sync_round(&xs, Some(&acc_refs), &mut avg_x, Some(&mut avg_acc)).unwrap();
            }
        });
        assert_eq!(got, 0, "{codec} compressed sync round allocated");
    }

    // --- pipelined wire staging: encode → frame → batch → vectored write -
    //
    // The `[comm] pipeline` writer path end to end as the coalescing
    // writer threads run it: take a pooled staging buffer, encode the
    // payload into it, wrap it in a frame, stage the frame's header into
    // the batch, submit everything with one vectored write, recycle the
    // payload buffers. After one warm-up round the cycle must be
    // allocation-free — the same handful of buffers circulates forever.
    {
        use adaalter::comm::wire::{Frame, FrameBatch, FrameKind, PayloadCodec};
        use adaalter::util::pool::BytePool;
        let src = randn(d, 110);
        let mut pool = BytePool::new();
        let mut batch = FrameBatch::new();
        let mut sink = std::io::sink();
        let mut round = |codec: &mut PayloadCodec,
                         pool: &mut BytePool,
                         batch: &mut FrameBatch,
                         sink: &mut std::io::Sink| {
            for w in 0..n as u32 {
                let mut payload = pool.take();
                codec.encode_vec(0, &src, &mut payload);
                batch.stage(Frame {
                    kind: FrameKind::SyncStep,
                    codec: codec.tag(),
                    flags: 0,
                    worker: w,
                    step: 1,
                    payload,
                });
            }
            batch.write_to(sink).unwrap();
            batch.recycle_into(pool);
        };
        for codec in [PayloadCodec::F32, PayloadCodec::Bf16] {
            let mut codec = codec;
            // Warm-up: grows the pool to the in-flight working set.
            round(&mut codec, &mut pool, &mut batch, &mut sink);
            let got = allocs_during(|| {
                for _ in 0..5 {
                    round(&mut codec, &mut pool, &mut batch, &mut sink);
                }
            });
            assert_eq!(got, 0, "pipelined wire staging allocated ({:?} tag)", codec.tag());
        }
    }

    // --- buffer pool and Arc recycling -----------------------------------
    {
        let mut pool = BufferPool::new();
        let b = pool.take(d);
        pool.put(b);
        let got = allocs_during(|| {
            for _ in 0..10 {
                let b = pool.take(d);
                pool.put(b);
            }
        });
        assert_eq!(got, 0, "buffer pool cycling allocated");

        let src = randn(d, 99);
        let mut slot = ArcSlot::new();
        drop(slot.fill(&src));
        let got = allocs_during(|| {
            for _ in 0..10 {
                drop(slot.fill(&src));
            }
        });
        assert_eq!(got, 0, "ArcSlot recycling allocated");
    }
}
