//! Shared test support for the `rust/tests/integration_*.rs` suites:
//! config builders for the synthetic and PJRT backends, factories, tiny
//! run drivers, temp dirs, and the bitwise trace-comparison assert the
//! transport/fault equivalence pins use.
//!
//! Each integration test is its own crate, so this module is included per
//! test file via `mod common;` — unused helpers in any one test binary
//! are expected.
#![allow(dead_code)]

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, RunResult, Trainer};
use adaalter::sim::SyntheticProblem;

/// Synthetic-backend experiment config with explicit problem size:
/// `workers` workers of `algo` for `steps` steps at sync period `h`
/// (forced to 1 for fully-synchronous algorithms), `rust_math` problem
/// dimension `dim`, warm-up `warmup`.
pub fn cfg_dim(
    algo: Algorithm,
    h: SyncPeriod,
    workers: usize,
    steps: u64,
    dim: usize,
    warmup: u64,
) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.train.workers = workers;
    c.train.steps = steps;
    c.train.sync_period = if algo.is_local() { h } else { SyncPeriod::Every(1) };
    c.train.backend = Backend::RustMath;
    c.train.rust_math_dim = dim;
    c.optim.algorithm = algo;
    c.optim.warmup_steps = warmup;
    c
}

/// The small fast shape most integration suites use: dimension 64,
/// warm-up 10, every step logged (so loss traces can be compared).
pub fn cfg(algo: Algorithm, h: SyncPeriod, workers: usize, steps: u64) -> ExperimentConfig {
    let mut c = cfg_dim(algo, h, workers, steps, 64, 10);
    c.train.log_every = 1;
    c
}

/// The artifact preset every PJRT integration test runs against — shared
/// so the trainer config and directly-constructed engines cannot drift.
pub const LM_PRESET: &str = "tiny";

/// PJRT language-model config (needs `make artifacts`): preset
/// [`LM_PRESET`], η = 0.5, warm-up 10, 2 eval batches.
pub fn lm_cfg(algo: Algorithm, h: SyncPeriod, workers: usize, steps: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.train.preset = LM_PRESET.into();
    c.train.backend = Backend::Pjrt;
    c.train.workers = workers;
    c.train.steps = steps;
    c.train.sync_period = if algo.is_local() { h } else { SyncPeriod::Every(1) };
    c.optim.algorithm = algo;
    c.optim.warmup_steps = 10;
    c.optim.eta = 0.5;
    c.train.log_every = 10;
    c.data.eval_batches = 2;
    c
}

/// Per-worker synthetic backends for `c` (non-IID least-squares problem
/// keyed by the config's dimension / worker count / seed).
pub fn factory(c: &ExperimentConfig) -> BackendFactory {
    let p = SyntheticProblem::new(c.train.rust_math_dim, c.train.workers, c.train.seed);
    Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>))
}

/// Train `c` on the synthetic backend; panics on error.
pub fn run(c: ExperimentConfig) -> RunResult {
    try_run(c).expect("training failed")
}

/// Train `c` on the synthetic backend, surfacing the error.
pub fn try_run(c: ExperimentConfig) -> adaalter::Result<RunResult> {
    let f = factory(&c);
    Trainer::new(c, f).run()
}

/// Fresh per-process temp directory for artifacts/checkpoints.
pub fn tmpdir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("adaalter_it_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d.to_str().unwrap().to_string()
}

/// The bitwise run-equivalence pin: identical final parameters, identical
/// loss-trace bits step for step, identical final-eval bits.
pub fn assert_bitwise_eq(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.final_x, b.final_x, "{what}: final x diverged");
    assert_eq!(
        a.recorder.steps.len(),
        b.recorder.steps.len(),
        "{what}: trace lengths differ"
    );
    for (pa, pb) in a.recorder.steps.iter().zip(&b.recorder.steps) {
        assert_eq!(pa.step, pb.step, "{what}: step ids diverged");
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{what}: loss trace diverged at step {}",
            pa.step
        );
    }
    match (&a.final_eval, &b.final_eval) {
        (Some(ea), Some(eb)) => assert_eq!(
            ea.loss.to_bits(),
            eb.loss.to_bits(),
            "{what}: final eval diverged"
        ),
        (None, None) => {}
        _ => panic!("{what}: final-eval presence differs"),
    }
}
