//! Shared test support for the `rust/tests/integration_*.rs` suites:
//! config builders for the synthetic and PJRT backends, factories, tiny
//! run drivers, temp dirs, and the bitwise trace-comparison assert the
//! transport/fault equivalence pins use.
//!
//! Each integration test is its own crate, so this module is included per
//! test file via `mod common;` — unused helpers in any one test binary
//! are expected.
#![allow(dead_code)]

use std::sync::Arc;

use adaalter::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
use adaalter::coordinator::{BackendFactory, RunResult, Trainer};
use adaalter::sim::SyntheticProblem;

/// Synthetic-backend experiment config with explicit problem size:
/// `workers` workers of `algo` for `steps` steps at sync period `h`
/// (forced to 1 for fully-synchronous algorithms), `rust_math` problem
/// dimension `dim`, warm-up `warmup`.
pub fn cfg_dim(
    algo: Algorithm,
    h: SyncPeriod,
    workers: usize,
    steps: u64,
    dim: usize,
    warmup: u64,
) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.train.workers = workers;
    c.train.steps = steps;
    c.train.sync_period = if algo.is_local() { h } else { SyncPeriod::Every(1) };
    c.train.backend = Backend::RustMath;
    c.train.rust_math_dim = dim;
    c.optim.algorithm = algo;
    c.optim.warmup_steps = warmup;
    c
}

/// The small fast shape most integration suites use: dimension 64,
/// warm-up 10, every step logged (so loss traces can be compared).
pub fn cfg(algo: Algorithm, h: SyncPeriod, workers: usize, steps: u64) -> ExperimentConfig {
    let mut c = cfg_dim(algo, h, workers, steps, 64, 10);
    c.train.log_every = 1;
    c
}

/// The artifact preset every PJRT integration test runs against — shared
/// so the trainer config and directly-constructed engines cannot drift.
pub const LM_PRESET: &str = "tiny";

/// PJRT language-model config (needs `make artifacts`): preset
/// [`LM_PRESET`], η = 0.5, warm-up 10, 2 eval batches.
pub fn lm_cfg(algo: Algorithm, h: SyncPeriod, workers: usize, steps: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.train.preset = LM_PRESET.into();
    c.train.backend = Backend::Pjrt;
    c.train.workers = workers;
    c.train.steps = steps;
    c.train.sync_period = if algo.is_local() { h } else { SyncPeriod::Every(1) };
    c.optim.algorithm = algo;
    c.optim.warmup_steps = 10;
    c.optim.eta = 0.5;
    c.train.log_every = 10;
    c.data.eval_batches = 2;
    c
}

/// Per-worker synthetic backends for `c` (non-IID least-squares problem
/// keyed by the config's dimension / worker count / seed).
pub fn factory(c: &ExperimentConfig) -> BackendFactory {
    let p = SyntheticProblem::new(c.train.rust_math_dim, c.train.workers, c.train.seed);
    Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>))
}

/// Train `c` on the synthetic backend; panics on error.
pub fn run(c: ExperimentConfig) -> RunResult {
    try_run(c).expect("training failed")
}

/// Train `c` on the synthetic backend, surfacing the error.
pub fn try_run(c: ExperimentConfig) -> adaalter::Result<RunResult> {
    let f = factory(&c);
    Trainer::new(c, f).run()
}

/// Fresh per-process temp directory for artifacts/checkpoints.
pub fn tmpdir(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("adaalter_it_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d.to_str().unwrap().to_string()
}

// ---------------------------------------------------------------------------
// Multi-process support (integration_net): spawn real leader/worker OS
// processes of the compiled `adaalter` binary over loopback sockets.
// ---------------------------------------------------------------------------

/// The compiled `adaalter` CLI binary under test.
pub fn adaalter_bin() -> &'static str {
    env!("CARGO_BIN_EXE_adaalter")
}

/// A spawned deployment process, killed on drop so a failed assertion
/// never leaves leader or worker processes running.
pub struct ChildGuard {
    /// Role tag for panic messages ("leader", "worker 2", …).
    pub label: String,
    /// The OS process.
    pub child: std::process::Child,
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl ChildGuard {
    /// Wait for exit, polling with a hard deadline so a protocol deadlock
    /// fails the test instead of hanging CI; kills the process on timeout.
    pub fn wait_within(&mut self, timeout: std::time::Duration) -> std::process::ExitStatus {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(status) = self.child.try_wait().expect("try_wait failed") {
                return status;
            }
            if std::time::Instant::now() > deadline {
                let _ = self.child.kill();
                let _ = self.child.wait();
                panic!("{} did not exit within {timeout:?}", self.label);
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
    }
}

/// Write `toml` under `dir` and return its path.
pub fn write_cfg(dir: &str, toml: &str) -> String {
    let path = format!("{dir}/cfg.toml");
    std::fs::write(&path, toml).expect("write config");
    path
}

/// Spawn the leader role: binds loopback with port 0 and publishes the
/// picked address to `<dir>/leader.addr` for [`spawn_worker`].
pub fn spawn_leader(cfg_path: &str, dir: &str) -> ChildGuard {
    // Stale discovery/report files from a previous run on this machine
    // would short-circuit the port-file polling (or the report assert).
    let _ = std::fs::remove_file(format!("{dir}/leader.addr"));
    let _ = std::fs::remove_file(format!("{dir}/net_report.json"));
    let child = std::process::Command::new(adaalter_bin())
        .args(["train", "--config", cfg_path, "--role", "leader"])
        .args(["--port-file", &format!("{dir}/leader.addr")])
        .args(["--out-dir", dir, "--quiet"])
        .stdout(std::process::Stdio::null())
        .spawn()
        .expect("spawn leader");
    ChildGuard { label: "leader".into(), child }
}

/// Spawn worker `w` against [`spawn_leader`]'s port file, with extra
/// environment variables (fault injection) applied.
pub fn spawn_worker(cfg_path: &str, dir: &str, w: usize, env: &[(String, String)]) -> ChildGuard {
    spawn_worker_with(cfg_path, dir, w, &[], env)
}

/// [`spawn_worker`] with extra CLI flags — e.g. `--rejoin` for a
/// relaunched worker id reconnecting to a live run (integration_elastic).
pub fn spawn_worker_with(
    cfg_path: &str,
    dir: &str,
    w: usize,
    extra_args: &[&str],
    env: &[(String, String)],
) -> ChildGuard {
    let mut cmd = std::process::Command::new(adaalter_bin());
    cmd.args(["train", "--config", cfg_path, "--role", "worker"])
        .args(["--worker-id", &w.to_string()])
        .args(["--port-file", &format!("{dir}/leader.addr")])
        .arg("--quiet")
        .args(extra_args)
        .stdout(std::process::Stdio::null());
    for (k, v) in env {
        cmd.env(k, v);
    }
    ChildGuard { label: format!("worker {w}"), child: cmd.spawn().expect("spawn worker") }
}

/// Everything one deployment run produced.
pub struct NetRun {
    /// Leader exit status.
    pub leader: std::process::ExitStatus,
    /// Worker exit statuses, by worker id.
    pub workers: Vec<std::process::ExitStatus>,
    /// The leader's output directory (`net_report.json` lives here).
    pub out_dir: String,
}

/// Run a full loopback deployment of `toml` with `workers` worker
/// processes; `worker_env` carries per-worker extra environment
/// (`(worker, key, value)`).
pub fn run_net(
    toml: &str,
    workers: usize,
    tag: &str,
    worker_env: &[(usize, String, String)],
) -> NetRun {
    let dir = tmpdir(tag);
    run_net_in(&dir, toml, workers, worker_env)
}

/// [`run_net`] in a caller-chosen directory (the Unix-socket scenario
/// needs the listen path inside the TOML to point there).
pub fn run_net_in(
    dir: &str,
    toml: &str,
    workers: usize,
    worker_env: &[(usize, String, String)],
) -> NetRun {
    let dir = dir.to_string();
    let cfg_path = write_cfg(&dir, toml);
    let mut leader = spawn_leader(&cfg_path, &dir);
    let mut kids: Vec<ChildGuard> = (0..workers)
        .map(|w| {
            let env: Vec<(String, String)> = worker_env
                .iter()
                .filter(|(i, _, _)| *i == w)
                .map(|(_, k, v)| (k.clone(), v.clone()))
                .collect();
            spawn_worker(&cfg_path, &dir, w, &env)
        })
        .collect();
    let limit = std::time::Duration::from_secs(120);
    let workers: Vec<std::process::ExitStatus> =
        kids.iter_mut().map(|g| g.wait_within(limit)).collect();
    let leader = leader.wait_within(limit);
    NetRun { leader, workers, out_dir: dir }
}

/// Parse the leader's `net_report.json` (written for networked runs).
pub fn net_report(out_dir: &str) -> adaalter::util::json::Json {
    let path = format!("{out_dir}/net_report.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    adaalter::util::json::Json::parse(&text).expect("net_report.json parses")
}

/// The bitwise run-equivalence pin: identical final parameters, identical
/// loss-trace bits step for step, identical final-eval bits.
pub fn assert_bitwise_eq(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(a.final_x, b.final_x, "{what}: final x diverged");
    assert_eq!(
        a.recorder.steps.len(),
        b.recorder.steps.len(),
        "{what}: trace lengths differ"
    );
    for (pa, pb) in a.recorder.steps.iter().zip(&b.recorder.steps) {
        assert_eq!(pa.step, pb.step, "{what}: step ids diverged");
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{what}: loss trace diverged at step {}",
            pa.step
        );
    }
    match (&a.final_eval, &b.final_eval) {
        (Some(ea), Some(eb)) => assert_eq!(
            ea.loss.to_bits(),
            eb.loss.to_bits(),
            "{what}: final eval diverged"
        ),
        (None, None) => {}
        _ => panic!("{what}: final-eval presence differs"),
    }
}
