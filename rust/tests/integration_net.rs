//! Multi-process networked-transport pins (DESIGN.md §4): real leader and
//! worker OS processes of the compiled `adaalter` binary over loopback
//! TCP / Unix-domain sockets, pinned **bit for bit** against the
//! in-process reference transports — identical final parameters, loss
//! traces and final-eval bits — with the real socket byte counters pinned
//! exactly equal to the booked (simulated α–β) accounting for every wire
//! codec. Failure paths: a worker process killed mid-run surfaces as a
//! crash tombstone (quorum runs continue, policy-free runs error cleanly,
//! nothing deadlocks), unreachable leaders produce the field-named
//! connect error, and a mismatched config fingerprint is rejected at
//! handshake without poisoning the run.
//!
//! CI runs this suite serialized (`--test-threads=1`) in release.

mod common;

use adaalter::config::{ExperimentConfig, TomlDoc};
use adaalter::coordinator::RunResult;
use adaalter::util::json::Json;

/// One deployment's experiment TOML: synthetic backend at d = 64, every
/// step logged (so the loss trace pins cover every iteration), generous
/// accept window for slow CI hosts.
fn net_toml(algo: &str, h: u64, workers: usize, steps: u64, codec: &str, listen: &str) -> String {
    let comm = match codec {
        "f32" => "[comm]\ntransport = \"tcp\"\n".to_string(),
        "bf16" => "[comm]\ntransport = \"tcp\"\n[precision]\nwire = \"bf16\"\n".to_string(),
        "qsgd" => {
            "[comm]\ntransport = \"tcp\"\ncompression = \"qsgd\"\nqsgd_levels = 15\n".to_string()
        }
        other => panic!("unknown codec {other}"),
    };
    format!(
        "[train]\n\
         workers = {workers}\n\
         sync_period = {h}\n\
         steps = {steps}\n\
         steps_per_epoch = 50\n\
         log_every = 1\n\
         backend = \"rust_math\"\n\
         rust_math_dim = 64\n\
         [optim]\n\
         algorithm = \"{algo}\"\n\
         warmup_steps = 10\n\
         {comm}\
         [net]\n\
         listen = \"{listen}\"\n\
         connect_timeout_s = 60.0\n"
    )
}

/// The in-process reference for a networked TOML: the identical
/// experiment over the equivalent in-process transport — `simulated` for
/// the dense f32 wire (same SimulatedCollective the networked leader
/// bills through), `channel` for the lossy codecs (CompressedCollective,
/// whose byte arithmetic WireCollective mirrors).
fn reference_run(toml: &str, codec: &str) -> RunResult {
    let swap = match codec {
        "f32" => "transport = \"simulated\"",
        _ => "transport = \"channel\"",
    };
    let ref_toml = toml.replace("transport = \"tcp\"", swap);
    let cfg = ExperimentConfig::from_doc(&TomlDoc::parse(&ref_toml).unwrap()).unwrap();
    common::run(cfg)
}

fn u64_field(rep: &Json, key: &str) -> u64 {
    rep.req(key).unwrap().num().unwrap() as u64
}

/// The tentpole pin: the deployment's `net_report.json` carries the same
/// bits as the in-process reference run, and the leader's real accounted
/// socket payload bytes equal the booked traffic exactly.
fn assert_report_matches(rep: &Json, r: &RunResult, what: &str) {
    let got: Vec<u32> = rep
        .req("final_x_bits")
        .unwrap()
        .arr()
        .unwrap()
        .iter()
        .map(|j| j.num().unwrap() as u32)
        .collect();
    let want: Vec<u32> = r.final_x.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got, want, "{what}: final x diverged");

    let steps = rep.req("steps").unwrap().arr().unwrap();
    assert_eq!(steps.len(), r.recorder.steps.len(), "{what}: trace lengths differ");
    for (row, p) in steps.iter().zip(&r.recorder.steps) {
        let row = row.arr().unwrap();
        assert_eq!(row[0].num().unwrap() as u64, p.step, "{what}: step ids diverged");
        assert_eq!(
            row[1].str().unwrap(),
            format!("{:016x}", p.train_loss.to_bits()),
            "{what}: loss trace diverged at step {}",
            p.step
        );
    }

    let eval = r.final_eval.as_ref().expect("reference has a final eval");
    assert_eq!(
        rep.req("final_eval_loss_bits").unwrap().str().unwrap(),
        format!("{:016x}", eval.loss.to_bits()),
        "{what}: final eval diverged"
    );

    let (syncs, booked) = r.recorder.comm();
    assert_eq!(u64_field(rep, "syncs"), syncs, "{what}: sync counts differ");
    assert_eq!(u64_field(rep, "booked_bytes"), booked, "{what}: booked bytes differ");
    // The real wire pin: the leader counted the actual codec payload
    // bytes that crossed its sockets — they must equal the simulated
    // accounting byte for byte, and the all-in frame traffic (headers,
    // handshake, control frames) is strictly larger.
    assert_eq!(
        u64_field(rep, "accounted_bytes"),
        booked,
        "{what}: real socket bytes != booked accounting"
    );
    assert!(
        u64_field(rep, "total_bytes") > u64_field(rep, "accounted_bytes"),
        "{what}: total wire traffic must exceed the accounted payloads"
    );
}

/// Run one deployment fault-free and pin it against the reference.
fn pin(algo: &str, h: u64, workers: usize, codec: &str, tag: &str) {
    let steps = 36;
    let toml = net_toml(algo, h, workers, steps, codec, "127.0.0.1:0");
    let run = common::run_net(&toml, workers, tag, &[]);
    for (w, st) in run.workers.iter().enumerate() {
        assert!(st.success(), "{tag}: worker {w} failed: {st}");
    }
    assert!(run.leader.success(), "{tag}: leader failed: {}", run.leader);
    let rep = common::net_report(&run.out_dir);
    let reference = reference_run(&toml, codec);
    assert_report_matches(&rep, &reference, tag);
}

// --- The equivalence matrix: algorithms × codecs × worker counts ----------

#[test]
fn tcp_f32_pins_bitwise_against_in_process() {
    pin("adagrad", 1, 2, "f32", "f32_adagrad_w2");
    pin("adagrad", 1, 4, "f32", "f32_adagrad_w4");
    pin("local_adaalter", 4, 2, "f32", "f32_laa_h4_w2");
    pin("local_adaalter", 4, 4, "f32", "f32_laa_h4_w4");
    pin("local_adaalter", 16, 4, "f32", "f32_laa_h16_w4");
}

#[test]
fn tcp_bf16_pins_bitwise_against_in_process() {
    pin("adagrad", 1, 2, "bf16", "bf16_adagrad_w2");
    pin("adagrad", 1, 4, "bf16", "bf16_adagrad_w4");
    pin("local_adaalter", 4, 2, "bf16", "bf16_laa_h4_w2");
    pin("local_adaalter", 4, 4, "bf16", "bf16_laa_h4_w4");
    pin("local_adaalter", 16, 4, "bf16", "bf16_laa_h16_w4");
}

#[test]
fn tcp_qsgd_pins_bitwise_against_in_process() {
    pin("adagrad", 1, 2, "qsgd", "qsgd_adagrad_w2");
    pin("adagrad", 1, 4, "qsgd", "qsgd_adagrad_w4");
    pin("local_adaalter", 4, 2, "qsgd", "qsgd_laa_h4_w2");
    pin("local_adaalter", 4, 4, "qsgd", "qsgd_laa_h4_w4");
    pin("local_adaalter", 16, 4, "qsgd", "qsgd_laa_h16_w4");
}

/// Unix-domain sockets run the identical protocol through the same
/// framing — one scenario pins the `uds` socket kind end to end.
#[test]
fn uds_f32_pins_bitwise_against_in_process() {
    let dir = common::tmpdir("uds_laa_h4");
    let toml = net_toml("local_adaalter", 4, 2, 36, "f32", &format!("{dir}/leader.sock"))
        .replace("transport = \"tcp\"", "transport = \"uds\"");
    let run = common::run_net_in(&dir, &toml, 2, &[]);
    for (w, st) in run.workers.iter().enumerate() {
        assert!(st.success(), "uds: worker {w} failed: {st}");
    }
    assert!(run.leader.success(), "uds: leader failed: {}", run.leader);
    let rep = common::net_report(&run.out_dir);
    let reference =
        reference_run(&toml.replace("transport = \"uds\"", "transport = \"tcp\""), "f32");
    assert_report_matches(&rep, &reference, "uds_laa_h4");
}

/// Sharded parameter server over the real wire: `comm.shards = 4` splits
/// every sync-round State/InstallState into shard-tagged frames, yet the
/// run pins bitwise against the in-process sharded reference and the
/// accounted socket payload bytes still equal the booked accounting
/// exactly (per-shard payload sums equal the dense totals).
#[test]
fn tcp_sharded_ps_pins_bitwise_against_in_process() {
    for (codec, tag) in [("f32", "shards_f32_laa_h4_w3"), ("bf16", "shards_bf16_laa_h4_w3")] {
        let toml = net_toml("local_adaalter", 4, 3, 36, codec, "127.0.0.1:0")
            .replace("transport = \"tcp\"\n", "transport = \"tcp\"\nshards = 4\n");
        let run = common::run_net(&toml, 3, tag, &[]);
        for (w, st) in run.workers.iter().enumerate() {
            assert!(st.success(), "{tag}: worker {w} failed: {st}");
        }
        assert!(run.leader.success(), "{tag}: leader failed: {}", run.leader);
        let rep = common::net_report(&run.out_dir);
        let reference = reference_run(&toml, codec);
        assert_report_matches(&rep, &reference, tag);
    }
}

// --- Failure paths --------------------------------------------------------

/// A leader that dies before publishing its address: the worker's
/// port-file poll is bounded by `net.connect_timeout_s` and reports the
/// field-named error (with the configured value) instead of hanging.
#[test]
fn missing_port_file_times_out_with_field_named_error() {
    let dir = common::tmpdir("portfile_timeout");
    let toml = net_toml("local_adaalter", 4, 2, 8, "f32", "127.0.0.1:0")
        .replace("connect_timeout_s = 60.0", "connect_timeout_s = 1.0");
    let cfg_path = common::write_cfg(&dir, &toml);
    let started = std::time::Instant::now();
    // No leader is ever spawned, so the port file never appears.
    let out = std::process::Command::new(common::adaalter_bin())
        .args(["train", "--config", &cfg_path, "--role", "worker"])
        .args(["--worker-id", "0", "--port-file", &format!("{dir}/never.addr")])
        .arg("--quiet")
        .output()
        .expect("spawn worker");
    let elapsed = started.elapsed();
    assert!(!out.status.success(), "worker must fail when the port file never appears");
    assert!(
        elapsed < std::time::Duration::from_secs(30),
        "port-file poll must respect net.connect_timeout_s, took {elapsed:?}"
    );
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("net.connect_timeout_s = 1"), "error must show the timeout: {err}");
    assert!(err.contains("never appeared"), "error must say what happened: {err}");
}

/// A worker process killed mid-run (process exit, not a cooperative
/// tombstone): under a quorum participation policy the leader absorbs the
/// EOF as a crash tombstone and finishes on the survivors.
#[test]
fn killed_worker_process_tombstones_under_quorum() {
    let mut toml = net_toml("local_adaalter", 4, 4, 36, "f32", "127.0.0.1:0");
    toml.push_str("[faults]\nquorum = 2\n");
    toml = toml.replace("[optim]", "fused = false\n[optim]");
    let env = vec![(3usize, adaalter::comm::net::EXIT_AT_STEP_ENV.to_string(), "7".to_string())];
    let run = common::run_net(&toml, 4, "kill_quorum", &env);
    assert_eq!(
        run.workers[3].code(),
        Some(3),
        "killed worker must exit through the kill hook: {}",
        run.workers[3]
    );
    for (w, st) in run.workers.iter().take(3).enumerate() {
        assert!(st.success(), "survivor {w} failed: {st}");
    }
    assert!(run.leader.success(), "leader must finish on the survivors: {}", run.leader);
    let rep = common::net_report(&run.out_dir);
    // Crash rounds ship frames the survivor accounting no longer books
    // (the dead worker's last SyncStep), so the exact-equality pin is a
    // fault-free property; here the counters just have to be sane.
    assert!(u64_field(&rep, "total_bytes") > u64_field(&rep, "accounted_bytes"));
    assert!(u64_field(&rep, "syncs") > 0);
}

/// The same kill without any participation policy: the leader reports a
/// clean typed protocol error (no deadlock, no corrupted state) and the
/// surviving workers exit via the shutdown Stop.
#[test]
fn killed_worker_process_fails_cleanly_without_quorum() {
    let toml = net_toml("local_adaalter", 4, 2, 36, "f32", "127.0.0.1:0");
    let env = vec![(1usize, adaalter::comm::net::EXIT_AT_STEP_ENV.to_string(), "7".to_string())];
    let run = common::run_net(&toml, 2, "kill_noquorum", &env);
    assert_eq!(run.workers[1].code(), Some(3), "killed worker: {}", run.workers[1]);
    assert!(
        !run.leader.success(),
        "leader must fail cleanly when a worker dies with no participation policy"
    );
    assert!(run.workers[0].success(), "survivor must exit via Stop: {}", run.workers[0]);
}

/// No leader anywhere: the worker's connect loop exhausts its retries and
/// reports the `net.connect`-field-named config error.
#[test]
fn unreachable_leader_yields_field_named_connect_error() {
    let dir = common::tmpdir("connect_err");
    let mut toml = net_toml("local_adaalter", 4, 2, 8, "f32", "");
    toml.push_str("connect_retries = 2\nretry_backoff_s = 0.01\n");
    let cfg_path = common::write_cfg(&dir, &toml);
    let out = std::process::Command::new(common::adaalter_bin())
        .args(["train", "--config", &cfg_path, "--role", "worker"])
        .args(["--worker-id", "0", "--connect", "127.0.0.1:9", "--quiet"])
        .output()
        .expect("spawn worker");
    assert!(!out.status.success(), "worker must fail with no leader listening");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("net.connect"), "error must name the config field: {err}");
    assert!(err.contains("net.connect_retries = 2"), "error must show the retry budget: {err}");
}

/// A worker started with a *different* experiment config is rejected at
/// handshake (config-fingerprint mismatch) — and the leader keeps
/// listening, so a correctly-configured fleet still completes bitwise.
#[test]
fn config_fingerprint_mismatch_rejected_at_handshake() {
    let dir = common::tmpdir("fp_mismatch");
    let toml = net_toml("local_adaalter", 4, 2, 36, "f32", "127.0.0.1:0");
    let cfg_path = common::write_cfg(&dir, &toml);
    let bad_toml = net_toml("local_adaalter", 8, 2, 36, "f32", "127.0.0.1:0");
    let bad_path = format!("{dir}/bad.toml");
    std::fs::write(&bad_path, &bad_toml).unwrap();

    let mut leader = common::spawn_leader(&cfg_path, &dir);
    let out = std::process::Command::new(common::adaalter_bin())
        .args(["train", "--config", &bad_path, "--role", "worker"])
        .args(["--worker-id", "0", "--port-file", &format!("{dir}/leader.addr")])
        .arg("--quiet")
        .output()
        .expect("spawn mismatched worker");
    assert!(!out.status.success(), "mismatched worker must be rejected");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("config mismatch"), "rejection must say why: {err}");

    // The leader is still accepting: the correct fleet completes, and the
    // run stays bitwise-identical to the in-process reference.
    let mut kids: Vec<common::ChildGuard> =
        (0..2).map(|w| common::spawn_worker(&cfg_path, &dir, w, &[])).collect();
    let limit = std::time::Duration::from_secs(120);
    for g in &mut kids {
        let st = g.wait_within(limit);
        assert!(st.success(), "{}: {st}", g.label);
    }
    let st = leader.wait_within(limit);
    assert!(st.success(), "leader: {st}");
    let rep = common::net_report(&dir);
    let reference = reference_run(&toml, "f32");
    assert_report_matches(&rep, &reference, "fp_mismatch");
}
