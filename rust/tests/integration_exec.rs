//! Execution-engine equivalence pins (ISSUE 5 tentpole; DESIGN.md §7):
//! the `[exec]` thread layout must never change a bit of the training
//! trajectory. Every scenario runs once under the serial reference
//! engine and once per threaded layout — the default one-host-per-worker
//! shape and pools of k ∈ {2, 4, 8} — asserting bit-identical final
//! parameters, per-step loss traces and final evaluations, across both
//! protocol families, compressed transports and a `[faults]` quorum
//! scenario.

mod common;

use adaalter::config::{Algorithm, ExperimentConfig, SyncPeriod};
use adaalter::sim::Charge;

/// `cfg` under the k-thread engine layout.
fn with_threads(mut c: ExperimentConfig, k: usize) -> ExperimentConfig {
    c.exec.parallelism = "threads".into();
    c.exec.threads = k;
    c
}

/// `cfg` under the serial reference engine (the default is one host per
/// worker, so the reference layout is opted into explicitly).
fn with_serial(mut c: ExperimentConfig) -> ExperimentConfig {
    c.exec.parallelism = "serial".into();
    c
}

#[test]
fn sync_adagrad_is_layout_invariant() {
    // Fully-synchronous AdaGrad (H = 1): every iteration barriers on all
    // 8 workers, so reply arrival order varies wildly across layouts —
    // the fixed-order gather must absorb all of it.
    let base = common::cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 8, 30);
    let serial = common::run(with_serial(base.clone()));
    // The default layout (one host per worker — the seed's thread shape)
    // is one of the layouts under test too.
    let default = common::run(base.clone());
    common::assert_bitwise_eq(&serial, &default, "adagrad default layout");
    for k in [2usize, 4, 8] {
        let r = common::run(with_threads(base.clone(), k));
        common::assert_bitwise_eq(&serial, &r, &format!("adagrad threads({k})"));
    }
}

#[test]
fn local_adaalter_is_layout_invariant() {
    // Local AdaAlter at H ∈ {4, 16}: local phases + paired averaging
    // rounds (Alg. 4 lines 11–12) — the survivor-mean arithmetic must be
    // bitwise-stable regardless of which host computed which replica.
    for h in [4u64, 16] {
        let base = common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), 8, 48);
        let serial = common::run(with_serial(base.clone()));
        let default = common::run(base.clone());
        common::assert_bitwise_eq(&serial, &default, &format!("local H={h} default layout"));
        for k in [2usize, 4, 8] {
            let r = common::run(with_threads(base.clone(), k));
            common::assert_bitwise_eq(&serial, &r, &format!("local H={h} threads({k})"));
        }
    }
}

#[test]
fn compressed_transports_are_layout_invariant() {
    // QSGD and top-k both hold leader-side codec state (RNG streams,
    // error-feedback residuals, delta bases) — none of it may observe the
    // worker thread layout.
    for compression in ["qsgd", "topk"] {
        let mut base = common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 32);
        base.comm.transport = "channel".into();
        base.comm.compression = compression.into();
        let serial = common::run(with_serial(base.clone()));
        assert!(serial.recorder.comm().1 > 0, "{compression}: no bytes recorded");
        for k in [2usize, 4] {
            let r = common::run(with_threads(base.clone(), k));
            common::assert_bitwise_eq(&serial, &r, &format!("{compression} threads({k})"));
            assert_eq!(
                serial.recorder.comm(),
                r.recorder.comm(),
                "{compression} threads({k}): wire accounting diverged"
            );
        }
    }
}

#[test]
fn quorum_fault_scenario_is_layout_invariant() {
    // The `[faults]` stack on top: one 4×-slow worker of 8, quorum-7
    // rounds dropping it. Fault streams are keyed by (seed, worker, step)
    // and the partial-round selection by arrival times — all of it must
    // be identical whichever host serves the slow worker.
    let mut base = common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 8, 40);
    base.train.fused = false;
    base.faults.slow_workers = 1;
    base.faults.slow_factor = 4.0;
    base.faults.quorum = 7;
    let serial = common::run(with_serial(base.clone()));
    assert!(!serial.recorder.fault_events.is_empty());
    for k in [2usize, 4, 8] {
        let r = common::run(with_threads(base.clone(), k));
        common::assert_bitwise_eq(&serial, &r, &format!("quorum threads({k})"));
        assert_eq!(
            serial.clock.total(Charge::Straggler).to_bits(),
            r.clock.total(Charge::Straggler).to_bits(),
            "quorum threads({k}): straggler accounting diverged"
        );
        assert_eq!(
            serial.recorder.fault_events.len(),
            r.recorder.fault_events.len(),
            "quorum threads({k}): fault-event traces diverged"
        );
    }
}

#[test]
fn default_layout_is_one_host_per_worker_and_matches_serial() {
    // The default — threads(0), one host per worker, exactly the thread
    // shape every run had before the engine existed — is
    // bitwise-identical to the serial reference, whether spelled as the
    // default, explicitly, or oversubscribed.
    let base = common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 24);
    let serial = common::run(with_serial(base.clone()));
    let default = common::run(base.clone());
    common::assert_bitwise_eq(&serial, &default, "default layout");
    let mut c = base.clone();
    c.exec.parallelism = "threads(0)".into();
    let r = common::run(c);
    common::assert_bitwise_eq(&serial, &r, "threads(0)");
    // And an oversubscribed pool (more threads than workers) clamps.
    let r = common::run(with_threads(base, 64));
    common::assert_bitwise_eq(&serial, &r, "threads(64)");
}

#[test]
fn exec_config_round_trips_through_toml() {
    use adaalter::config::TomlDoc;
    let doc = TomlDoc::parse(
        "[train]\nworkers = 4\nsteps = 8\nbackend = \"rust_math\"\nrust_math_dim = 32\n\
         [exec]\nparallelism = \"threads\"\nthreads = 2\n",
    )
    .unwrap();
    let cfg = ExperimentConfig::from_doc(&doc).unwrap();
    assert_eq!(cfg.exec.parallelism, "threads");
    assert_eq!(cfg.exec.threads, 2);
    let r = common::run(cfg);
    assert!(r.final_x.iter().all(|v| v.is_finite()));
}

#[test]
fn simd_dispatch_is_bitwise_invariant_end_to_end() {
    // The PR 6 tentpole contract (DESIGN.md §8): `exec.simd` is a pure
    // wall-clock knob — every kernel, including the fixed-tree
    // reductions, returns identical bits under either implementation, so
    // whole training runs agree bitwise across dispatch modes (and the
    // knob composes with every thread layout).
    for (algo, h) in [
        (Algorithm::AdaGrad, SyncPeriod::Every(1)),
        (Algorithm::LocalAdaAlter, SyncPeriod::Every(4)),
    ] {
        let base = common::cfg(algo, h, 4, 32);
        let mut off = base.clone();
        off.exec.simd = "off".into();
        let mut on = base.clone();
        on.exec.simd = "on".into();
        let r_off = common::run(off);
        let r_on = common::run(on);
        common::assert_bitwise_eq(&r_off, &r_on, &format!("{algo} simd on vs off"));
        let mut on_threads = with_threads(base, 2);
        on_threads.exec.simd = "on".into();
        let r = common::run(on_threads);
        common::assert_bitwise_eq(&r_off, &r, &format!("{algo} simd on + threads(2)"));
    }
    // Unknown spellings are a config error surfaced by the trainer.
    let mut bad = common::cfg(Algorithm::AdaGrad, SyncPeriod::Every(1), 2, 4);
    bad.exec.simd = "fast".into();
    let err = common::try_run(bad).unwrap_err();
    assert!(err.to_string().contains("exec.simd"), "{err}");
}

#[test]
fn bf16_state_runs_under_every_layout_and_stays_on_grid() {
    // `precision.state = "bf16"` composes with the execution engine: the
    // quantized-accumulator run is itself layout-invariant (quantization
    // happens inside the worker state machine, keyed by nothing but the
    // update stream).
    let mut base = common::cfg(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 4, 32);
    base.precision.state = "bf16".into();
    let serial = common::run(with_serial(base.clone()));
    assert!(serial.final_x.iter().all(|v| v.is_finite()));
    for k in [2usize, 4] {
        let r = common::run(with_threads(base.clone(), k));
        common::assert_bitwise_eq(&serial, &r, &format!("bf16 state threads({k})"));
    }
}
