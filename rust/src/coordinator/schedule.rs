//! Learning-rate schedule: the paper's warm-up (§6.2.1) and large-batch
//! scaling helper (§6.2.2).
//!
//! Warm-up: `η_t = η · min(1, t / warm_up_steps)` — AdaAlter's denominator
//! starts at `b₀²` (no accumulated history), so the first updates would be
//! oversized without it. The paper uses η = 0.5, warm_up_steps = 600.

/// Warm-up learning-rate schedule.
#[derive(Clone, Copy, Debug)]
pub struct WarmupSchedule {
    eta: f32,
    warmup_steps: u64,
}

impl WarmupSchedule {
    /// Base rate η and warm-up length (0 disables warm-up).
    pub fn new(eta: f32, warmup_steps: u64) -> Self {
        assert!(eta > 0.0 && eta.is_finite(), "eta must be positive");
        WarmupSchedule { eta, warmup_steps }
    }

    /// η_t for 1-based iteration t.
    pub fn lr(&self, t: u64) -> f32 {
        assert!(t >= 1, "iterations are 1-based");
        if self.warmup_steps == 0 || t >= self.warmup_steps {
            self.eta
        } else {
            self.eta * (t as f32 / self.warmup_steps as f32)
        }
    }

    /// Base rate.
    pub fn eta(&self) -> f32 {
        self.eta
    }
}

/// Batch-size learning-rate scaling rule (§6.2.2 / Goyal et al. 2017).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalingRule {
    /// η' = η · (B'/B).
    Linear,
    /// η' = η · sqrt(B'/B).
    Sqrt,
}

/// Re-scale a base learning rate tuned at `base_global_batch` for a run at
/// `new_global_batch`. The paper scales 0.2 @ 512 → [0.4, 0.8] @ 2048 and
/// settles on 0.5 (between sqrt and linear).
pub fn scale_lr(base_lr: f32, base_global_batch: u64, new_global_batch: u64,
                rule: ScalingRule) -> f32 {
    assert!(base_global_batch > 0 && new_global_batch > 0);
    let k = new_global_batch as f32 / base_global_batch as f32;
    match rule {
        ScalingRule::Linear => base_lr * k,
        ScalingRule::Sqrt => base_lr * k.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly_then_flattens() {
        let s = WarmupSchedule::new(0.5, 600);
        assert!((s.lr(1) - 0.5 / 600.0).abs() < 1e-9);
        assert!((s.lr(300) - 0.25).abs() < 1e-6);
        assert_eq!(s.lr(600), 0.5);
        assert_eq!(s.lr(10_000), 0.5);
    }

    #[test]
    fn warmup_monotone_nondecreasing() {
        let s = WarmupSchedule::new(0.5, 600);
        let mut prev = 0.0;
        for t in 1..=700 {
            let lr = s.lr(t);
            assert!(lr >= prev, "t={t}");
            prev = lr;
        }
    }

    #[test]
    fn zero_warmup_is_constant() {
        let s = WarmupSchedule::new(0.3, 0);
        assert_eq!(s.lr(1), 0.3);
        assert_eq!(s.lr(999), 0.3);
    }

    #[test]
    fn paper_scaling_example() {
        // 4 GPUs × 128 @ 0.2 → 8 GPUs × 256: linear gives 0.8, sqrt 0.4 —
        // the paper tunes within [0.4, 0.8].
        let linear = scale_lr(0.2, 4 * 128, 8 * 256, ScalingRule::Linear);
        let sqrt = scale_lr(0.2, 4 * 128, 8 * 256, ScalingRule::Sqrt);
        assert!((linear - 0.8).abs() < 1e-6);
        assert!((sqrt - 0.4).abs() < 1e-6);
        assert!(sqrt <= 0.5 && 0.5 <= linear, "paper's 0.5 sits in [sqrt, linear]");
    }

    #[test]
    #[should_panic(expected = "eta must be positive")]
    fn rejects_bad_eta() {
        let _ = WarmupSchedule::new(0.0, 600);
    }
}
