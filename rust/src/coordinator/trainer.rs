//! The leader: spawns workers, drives the global iteration loop, owns the
//! synchronization protocol, the virtual clock and the metrics.
//!
//! Two protocol families (dispatched on [`Algorithm::is_local`]):
//!
//! * **Fully synchronous** (SGD / AdaGrad / AdaAlter): the leader owns the
//!   model `x`. Every iteration it broadcasts `x`, gathers all worker
//!   gradients (the §2 barrier), aggregates (Alg. 1/3 line 5), and applies
//!   the [`crate::optim::SyncOptimizer`] update.
//! * **Local** (local SGD / local AdaAlter): workers own their replicas and
//!   step independently; when the configured [`SyncPolicy`] says so (every
//!   H-th iteration under the default fixed policy), the leader gathers
//!   `(y_{i,t}, A²_{i,t})`, averages both (Alg. 4 lines 11–12), and
//!   broadcasts the averages back. Each executed round's observation
//!   (modeled time, straggler spread, realized drift) feeds back into the
//!   policy (DESIGN.md §4).
//!
//! Communication is layered (DESIGN.md §3): the control plane (commands,
//! replies, barriers) runs over a [`ChannelTransport`], and every
//! data-plane exchange — gradient gather, model broadcast, the paired
//! parameter/denominator averaging round — goes through a pluggable
//! [`Collective`] selected by the `[comm]` config section. The collective
//! owns the cost model: each op returns a [`CommReport`] that the leader
//! books against the virtual clock and the traffic recorder, so swapping
//! "lockstep channels" for "α–β-charged parameter server" or "QSGD over a
//! ring" is a config choice, not a trainer change.
//!
//! Time: the virtual clock charges the paper-calibrated per-iteration
//! compute/dataload cost plus the collective-reported sync cost on
//! communication rounds (wall-clock on this box is meaningless for the
//! figures; real wall time is still recorded for host-throughput
//! reporting).

use std::sync::mpsc::channel;
use std::sync::Arc;

use crate::comm::{build_collective, ChannelTransport, Collective, CommReport};
use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::aggregate::{average_into, Aggregator};
use crate::coordinator::backend::{BackendFactory, EvalMetrics};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::schedule::WarmupSchedule;
use crate::coordinator::sync::{
    build_policy, StepObservation, SyncObservation, SyncPolicy, SyncReason,
};
use crate::coordinator::worker::{worker_loop, Cmd, Reply, WorkerSpec};
use crate::error::{Error, Result};
use crate::metrics::TrainRecorder;
use crate::optim;
use crate::sim::{Calibration, Charge, VirtualClock};

/// Result of a training run.
pub struct RunResult {
    /// Final (synchronized / averaged) parameters.
    pub final_x: Vec<f32>,
    /// Metrics (loss/eval curves, comm accounting, wall throughput).
    pub recorder: TrainRecorder,
    /// Virtual-time accounting.
    pub clock: VirtualClock,
    /// Final held-out evaluation.
    pub final_eval: Option<EvalMetrics>,
}

/// The leader/trainer.
pub struct Trainer {
    cfg: ExperimentConfig,
    factory: BackendFactory,
    /// Use the backend's fused local-step path when available.
    pub allow_fused: bool,
    /// Override the virtual-time calibration (default: paper V100).
    pub calibration: Calibration,
    /// Resume from a checkpoint (algorithm + dimensions must match).
    pub resume: Option<Checkpoint>,
}

impl Trainer {
    /// Build a trainer for `cfg`; `factory(worker)` constructs each
    /// worker's gradient backend on its own thread.
    pub fn new(cfg: ExperimentConfig, factory: BackendFactory) -> Self {
        Trainer {
            cfg,
            factory,
            allow_fused: true,
            calibration: Calibration::paper_v100(),
            resume: None,
        }
    }

    /// Run the full training loop.
    pub fn run(&self) -> Result<RunResult> {
        let cfg = &self.cfg;
        let n = cfg.train.workers;
        let algo = cfg.optim.algorithm;
        if self.resume.is_some() && cfg.comm.compression != "none" {
            // The delta-compression bases and error-feedback residuals are
            // not part of the checkpoint format; resuming would silently
            // quantize the full parameter vector on the first sync round.
            return Err(Error::Config(
                "resume is not supported over compressed transports \
                 (compressor state is not checkpointed)"
                    .into(),
            ));
        }
        if self.resume.is_some() && algo.is_local() && !cfg.sync.is_fixed() {
            // Adaptive scheduler state (drift accumulators, grown H) is
            // not part of the checkpoint format either.
            return Err(Error::Config(
                "resume requires sync.policy = \"fixed\" \
                 (adaptive scheduler state is not checkpointed)"
                    .into(),
            ));
        }
        if cfg.train.checkpoint_every > 0 && algo.is_local() && !cfg.sync.is_fixed() {
            // TOML-loaded configs are rejected by validate(); guard the
            // programmatically-built ones here too — snapshots require
            // sync boundaries known ahead of time.
            return Err(Error::Config(
                "checkpointing requires sync.policy = \"fixed\" \
                 (adaptive policies decide boundaries at runtime)"
                    .into(),
            ));
        }
        // The per-iteration sync decision is the policy's (DESIGN.md §4);
        // non-local algorithms always get FixedPeriod(1).
        let policy = build_policy(cfg)?;
        // Drift-triggered policies consume the per-step update norm, which
        // the fused device path cannot observe — fall back to the split
        // grad + rust-update path for those runs.
        let collect_update_sq = policy.needs_update_norms();
        let allow_fused = self.allow_fused && !collect_update_sq;
        let warmup = WarmupSchedule::new(cfg.optim.eta, cfg.optim.warmup_steps);

        // --- Spawn workers -------------------------------------------------
        // One probe backend determines d and initial params; workers build
        // their own backends thread-locally (PJRT clients are not Send).
        let probe = (self.factory)(0)?;
        let d = probe.dim();
        let mut start_step = 0u64;
        let mut resume_opt_state: Vec<Vec<f32>> = Vec::new();
        let mut resume_acc: Option<Arc<Vec<f32>>> = None;
        let init: Arc<Vec<f32>> = if let Some(ck) = &self.resume {
            ck.validate()?;
            if ck.algorithm != algo {
                return Err(Error::Protocol(format!(
                    "checkpoint is for {}, config asks for {algo}",
                    ck.algorithm
                )));
            }
            if ck.vectors[0].len() != d {
                return Err(Error::Protocol(format!(
                    "checkpoint d={} but backend d={d}",
                    ck.vectors[0].len()
                )));
            }
            start_step = ck.step;
            match algo {
                Algorithm::LocalAdaAlter => {
                    // vectors: [x, b2_sync, acc] — at a sync boundary
                    // b2_sync == acc == the averaged A²; install via an
                    // InstallState once workers are up.
                    resume_acc = Some(Arc::new(ck.vectors[2].clone()));
                }
                Algorithm::LocalSgd => {}
                _ => resume_opt_state = ck.vectors[1..].to_vec(),
            }
            Arc::new(ck.vectors[0].clone())
        } else {
            Arc::new(probe.init_params()?)
        };
        drop(probe);
        if init.len() != d {
            return Err(Error::Protocol(format!("init len {} != d {d}", init.len())));
        }

        let coll = build_collective(cfg, &self.calibration, d)?;
        let mut recorder = TrainRecorder::new(cfg.train.steps_per_epoch);
        recorder.set_transport(coll.label());
        recorder.set_sync_policy(policy.label());

        let (reply_tx, reply_rx) = channel::<Reply>();
        let mut txs = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for w in 0..n {
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            let spec = WorkerSpec {
                worker: w,
                algorithm: algo,
                epsilon: cfg.optim.epsilon,
                b0: cfg.optim.b0,
                init: Arc::clone(&init),
                allow_fused,
                collect_update_sq,
            };
            let factory = Arc::clone(&self.factory);
            let rtx = reply_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("adaalter-worker-{w}"))
                .spawn(move || worker_loop(spec, factory, cmd_rx, rtx))
                .map_err(Error::Io)?;
            txs.push(cmd_tx);
            joins.push(join);
        }
        drop(reply_tx);
        let transport = ChannelTransport::from_parts(txs, reply_rx, joins);

        let mut run = LeaderLoop {
            cfg,
            d,
            policy,
            last_sync_t: start_step,
            warmup,
            coll,
            calib: &self.calibration,
            transport,
            agg: Aggregator::new(d),
            recorder,
            clock: VirtualClock::new(),
            x: init.as_ref().clone(),
            opt: if algo.is_local() {
                None
            } else {
                let mut opt = optim::build_sync(&cfg.optim, d);
                if !resume_opt_state.is_empty() {
                    opt.restore_state(&resume_opt_state)?;
                }
                Some(opt)
            },
            start_step,
            resume_acc,
        };
        let out = run.drive();
        // Always attempt shutdown, even on error.
        run.shutdown();
        out.map(|(final_x, final_eval)| RunResult {
            final_x,
            recorder: run.recorder,
            clock: run.clock,
            final_eval,
        })
    }
}

/// A worker-reported failure — the one interception point for
/// `Reply::Err` across every gather/recv site.
fn worker_err(worker: usize, msg: String) -> Error {
    Error::Protocol(format!("worker {worker}: {msg}"))
}

/// Internal driver state (separated so shutdown can run after errors).
struct LeaderLoop<'a> {
    cfg: &'a ExperimentConfig,
    d: usize,
    /// The synchronization policy (config-selected; DESIGN.md §4).
    policy: Box<dyn SyncPolicy>,
    /// Iteration of the last executed sync round (realized-H tracking).
    last_sync_t: u64,
    warmup: WarmupSchedule,
    /// The data-plane collective (config-selected).
    coll: Box<dyn Collective>,
    calib: &'a Calibration,
    /// The control-plane message transport.
    transport: ChannelTransport<Cmd, Reply>,
    agg: Aggregator,
    recorder: TrainRecorder,
    clock: VirtualClock,
    /// Leader-owned model (sync algorithms); scratch for local averaging.
    x: Vec<f32>,
    opt: Option<Box<dyn optim::SyncOptimizer>>,
    /// First iteration is start_step + 1 (resume support).
    start_step: u64,
    /// Local-AdaAlter accumulator to install on resume.
    resume_acc: Option<Arc<Vec<f32>>>,
}

impl<'a> LeaderLoop<'a> {
    fn n(&self) -> usize {
        self.transport.n()
    }

    fn wait_ready(&self) -> Result<()> {
        self.transport
            .gather(|r| match r {
                Reply::Ready { worker } => Ok((worker, ())),
                Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
                _ => Err(Error::Protocol("expected Ready".into())),
            })
            .map(|_| ())
    }

    /// Charge one iteration's compute+dataload to the virtual clock.
    fn charge_iteration(&mut self) {
        let c = self.calib;
        let mut compute = c.t_compute_s;
        if matches!(
            self.cfg.optim.algorithm,
            Algorithm::AdaAlter | Algorithm::LocalAdaAlter
        ) {
            compute *= 1.0 + c.adaalter_compute_overhead;
        }
        self.clock.advance(Charge::Compute, compute);
        let extra = (c.dataload_s(self.n()) - compute).max(0.0);
        if extra > 0.0 {
            self.clock.advance(Charge::DataLoad, extra);
        }
    }

    /// Book a collective op's cost: virtual time to the clock, exact
    /// traffic and the full round count to the recorder (all bytes are
    /// booked on the first round's entry; extra rounds, should a future
    /// collective report them, count as zero-byte syncs so the recorder's
    /// sync counter always equals Σ rounds).
    fn apply_comm(&mut self, r: CommReport) {
        self.clock.advance(Charge::Communication, r.time_s);
        if r.rounds > 0 {
            self.recorder.sync(r.bytes);
            for _ in 1..r.rounds {
                self.recorder.sync(0);
            }
        }
    }

    /// The main loop; returns (final params, final eval).
    fn drive(&mut self) -> Result<(Vec<f32>, Option<EvalMetrics>)> {
        self.wait_ready()?;
        let algo = self.cfg.optim.algorithm;
        // Resuming a local run: install the checkpointed replica state.
        if self.start_step > 0 && algo.is_local() {
            let x = Arc::new(self.x.clone());
            let acc = self.resume_acc.clone();
            self.transport
                .broadcast(|_| Cmd::InstallState { x: Arc::clone(&x), acc: acc.clone() })?;
            self.wait_ready()?;
        }
        let steps = self.cfg.train.steps;
        let log_every = self.cfg.train.log_every.max(1);
        let eval_every = self.cfg.train.eval_every;

        for t in (self.start_step + 1)..=steps {
            let lr = self.warmup.lr(t);
            let mean_loss = if algo.is_local() {
                self.local_iteration(t, lr)?
            } else {
                self.sync_iteration(t, lr)?
            };
            self.charge_iteration();
            let log = t % log_every == 0 || t == steps || t == 1;
            self.recorder
                .step(t, mean_loss, lr, self.clock.now_s(), self.n() as u64, log);

            if eval_every > 0 && (t % eval_every == 0 || t == steps) {
                let m = self.evaluate(t)?;
                self.recorder
                    .eval(t, m.loss, m.ppl, self.clock.now_s());
            }

            let ck_every = self.cfg.train.checkpoint_every;
            if ck_every > 0 && t % ck_every == 0 {
                self.save_checkpoint(t)?;
            }
        }

        // Final consolidated model + eval.
        let final_x = self.consolidated_x()?;
        let final_eval = Some(self.eval_at(&final_x)?);
        Ok((final_x, final_eval))
    }

    /// One fully-synchronous iteration: broadcast x, gather grads, update.
    fn sync_iteration(&mut self, t: u64, lr: f32) -> Result<f64> {
        let x_arc = Arc::new(self.x.clone());
        let rep_b = self.coll.broadcast(&x_arc)?;
        self.transport
            .broadcast(|_| Cmd::SyncStep { t, x: Arc::clone(&x_arc) })?;
        let replies = self.transport.gather(|r| match r {
            Reply::Grad { worker, loss, grad } => Ok((worker, (loss, grad))),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected Grad".into())),
        })?;
        let mean_loss =
            replies.iter().map(|(l, _)| *l as f64).sum::<f64>() / replies.len() as f64;
        let mut grads: Vec<Vec<f32>> = replies.into_iter().map(|(_, g)| g).collect();
        // Gradient push/pull round: the collective transforms the payloads
        // (identity for lossless transports) and reports the round's cost.
        let rep_g = self.coll.gather_grads(&mut grads)?;
        self.apply_comm(rep_b.merge(rep_g));
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();

        let opt = self.opt.as_mut().expect("sync iteration without optimizer");
        match opt.algorithm() {
            Algorithm::AdaGrad => {
                // Alg. 1: accumulate the square of the AVERAGED gradient.
                self.agg.mean_grads(&grad_refs);
                self.agg.square_avg_grad();
            }
            _ => {
                // Alg. 3 (and momentum variance bookkeeping): average both
                // the gradients and their squares in one pass.
                self.agg.mean_grads_and_squares(&grad_refs);
            }
        }
        opt.step(&mut self.x, &self.agg.avg_g, &self.agg.avg_gsq, lr);
        Ok(mean_loss)
    }

    /// One local iteration; runs the sync round when the policy says so.
    fn local_iteration(&mut self, t: u64, lr: f32) -> Result<f64> {
        self.transport.broadcast(|_| Cmd::LocalStep { t, lr })?;
        let replies = self.transport.gather(|r| match r {
            Reply::StepDone { worker, loss, update_sq } => Ok((worker, (loss, update_sq))),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected StepDone".into())),
        })?;
        let n = replies.len() as f64;
        let mean_loss = replies.iter().map(|&(l, _)| l as f64).sum::<f64>() / n;
        let mean_update_sq = replies.iter().map(|&(_, u)| u).sum::<f64>() / n;

        let step = StepObservation { t, update_sq: mean_update_sq };
        if let Some(reason) = self.policy.decide(&step) {
            self.sync_round(t, reason)?;
        }
        Ok(mean_loss)
    }

    /// Gather worker states, with or without accumulators.
    fn collect_states(&self) -> Result<Vec<(Vec<f32>, Option<Vec<f32>>)>> {
        self.transport.broadcast(|_| Cmd::CollectState)?;
        self.transport.gather(|r| match r {
            Reply::State { worker, x, acc } => Ok((worker, (x, acc))),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected State".into())),
        })
    }

    /// Alg. 4 lines 11–12: the paired averaging round, executed by the
    /// configured collective (which may compress the exchange), then the
    /// averaged state is installed on every replica. The round's
    /// [`SyncObservation`] — assembled from the collective's report and
    /// the virtual clock — is recorded and fed back to the policy.
    fn sync_round(&mut self, t: u64, reason: SyncReason) -> Result<()> {
        let wants_acc = self.cfg.optim.algorithm.syncs_denominator();
        let states = self.collect_states()?;
        let xs: Vec<&[f32]> = states.iter().map(|(x, _)| x.as_slice()).collect();

        let (report, avg_acc) = if wants_acc {
            let accs: Vec<&[f32]> = states
                .iter()
                .map(|(_, a)| {
                    a.as_deref()
                        .ok_or_else(|| Error::Protocol("worker state missing accumulator".into()))
                })
                .collect::<Result<_>>()?;
            let mut acc = vec![0.0f32; self.d];
            let rep =
                self.coll
                    .sync_round(&xs, Some(&accs), &mut self.x, Some(&mut acc))?;
            (rep, Some(Arc::new(acc)))
        } else {
            let rep = self.coll.sync_round(&xs, None, &mut self.x, None)?;
            (rep, None)
        };

        let avg_x = Arc::new(self.x.clone());
        self.transport.broadcast(|_| Cmd::InstallState {
            x: Arc::clone(&avg_x),
            acc: avg_acc.clone(),
        })?;
        self.wait_ready()?;
        self.apply_comm(report);
        let (rounds, _) = self.recorder.comm();
        self.recorder.sync_event(
            t,
            t - self.last_sync_t,
            reason.as_str(),
            report.bytes,
            self.clock.now_s(),
        );
        self.last_sync_t = t;
        self.policy.observe(&SyncObservation {
            t,
            reason,
            rounds,
            round_bytes: report.bytes,
            round_time_s: report.time_s,
            straggler_s: report.straggler_s,
            drift_sq: report.drift_sq,
            virtual_now_s: self.clock.now_s(),
            total_comm_s: self.clock.total(Charge::Communication),
        });
        Ok(())
    }

    /// Checkpoint file path from the config.
    fn checkpoint_path(&self) -> String {
        if self.cfg.train.checkpoint_path.is_empty() {
            format!("{}/checkpoint.bin", self.cfg.out_dir)
        } else {
            self.cfg.train.checkpoint_path.clone()
        }
    }

    /// Snapshot training state at iteration `t` (for local algorithms the
    /// config validation guarantees `t` is a sync boundary, so replicas
    /// agree and worker 0's state is THE state).
    fn save_checkpoint(&mut self, t: u64) -> Result<()> {
        let algo = self.cfg.optim.algorithm;
        let vectors = if algo.is_local() {
            let states = self.collect_states()?;
            let (x0, acc0) = &states[0];
            match algo {
                Algorithm::LocalAdaAlter => {
                    let acc = acc0
                        .clone()
                        .ok_or_else(|| Error::Protocol("missing accumulator".into()))?;
                    vec![x0.clone(), acc.clone(), acc]
                }
                _ => vec![x0.clone()],
            }
        } else {
            let mut v = vec![self.x.clone()];
            v.extend(self.opt.as_ref().expect("sync opt").state_vectors());
            v
        };
        let ck = Checkpoint { step: t, algorithm: algo, vectors };
        ck.save(self.checkpoint_path())
    }

    /// Current consolidated model: leader's x for sync algorithms; the
    /// across-worker average x̄_t (the Theorem 2 sequence) for local ones.
    /// Observer-only — no wire traffic is booked (matches the paper, whose
    /// evaluation runs out-of-band).
    fn consolidated_x(&mut self) -> Result<Vec<f32>> {
        if !self.cfg.optim.algorithm.is_local() {
            return Ok(self.x.clone());
        }
        let states = self.collect_states()?;
        let xs: Vec<&[f32]> = states.iter().map(|(x, _)| x.as_slice()).collect();
        let mut out = vec![0.0f32; self.d];
        average_into(&xs, &mut out);
        Ok(out)
    }

    /// Mid-run evaluation at the consolidated model (on worker 0).
    fn evaluate(&mut self, _t: u64) -> Result<EvalMetrics> {
        let x = self.consolidated_x()?;
        self.eval_at(&x)
    }

    fn eval_at(&mut self, x: &[f32]) -> Result<EvalMetrics> {
        let x = Arc::new(x.to_vec());
        self.transport.send_to(0, Cmd::Eval { x: Some(x) })?;
        match self.transport.recv()? {
            Reply::Eval { metrics, .. } => Ok(metrics),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("unexpected reply during eval".into())),
        }
    }

    fn shutdown(&mut self) {
        self.transport.shutdown(|_| Cmd::Stop);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
    use crate::sim::SyntheticProblem;

    fn config(algo: Algorithm, h: SyncPeriod, steps: u64) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.train.workers = 4;
        c.train.steps = steps;
        c.train.sync_period = if algo.is_local() { h } else { SyncPeriod::Every(1) };
        c.train.backend = Backend::RustMath;
        c.train.rust_math_dim = 64;
        c.optim.algorithm = algo;
        c.optim.warmup_steps = 10;
        c.optim.eta = 0.5;
        c
    }

    fn synthetic_factory(cfg: &ExperimentConfig) -> BackendFactory {
        let p = SyntheticProblem::new(cfg.train.rust_math_dim, cfg.train.workers, cfg.train.seed);
        Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>))
    }

    fn run(algo: Algorithm, h: SyncPeriod, steps: u64) -> RunResult {
        let mut cfg = config(algo, h, steps);
        if matches!(algo, Algorithm::Sgd | Algorithm::LocalSgd) {
            // plain SGD needs lr < 2/L = 0.2 on the synthetic problem
            cfg.optim.eta = 0.1;
        }
        let f = synthetic_factory(&cfg);
        Trainer::new(cfg, f).run().unwrap()
    }

    #[test]
    fn all_algorithms_converge_to_the_noniid_optimum() {
        // The non-IID problem has an irreducible global loss F(x*) > 0
        // (workers' centres disagree); convergence = small SUBoptimality.
        let cfg0 = config(Algorithm::AdaGrad, SyncPeriod::Every(1), 1);
        let p = SyntheticProblem::new(cfg0.train.rust_math_dim, cfg0.train.workers, cfg0.train.seed);
        use crate::coordinator::backend::WorkerBackend as _;
        let init_loss = p.global_loss(&p.backend(0).init_params().unwrap());
        let opt_loss = p.global_loss(&p.optimum());
        assert!(init_loss > opt_loss + 100.0, "problem too easy");

        for algo in [
            Algorithm::Sgd,
            Algorithm::AdaGrad,
            Algorithm::AdaAlter,
            Algorithm::LocalSgd,
            Algorithm::LocalAdaAlter,
        ] {
            let r = run(algo, SyncPeriod::Every(4), 400);
            let subopt = r.final_eval.unwrap().loss - opt_loss;
            assert!(r.final_x.iter().all(|v| v.is_finite()), "{algo}: non-finite params");
            assert!(subopt < 1.0, "{algo}: suboptimality {subopt} (opt {opt_loss})");
        }
    }

    #[test]
    fn local_adaalter_h1_equals_sync_adaalter() {
        // THE equivalence anchor (paper §4.3): with H = 1, Algorithm 4
        // degenerates to Algorithm 3 exactly (up to f32 associativity).
        let a = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(1), 40);
        let b = run(Algorithm::AdaAlter, SyncPeriod::Every(1), 40);
        let max = crate::util::math::max_abs_diff(&a.final_x, &b.final_x);
        assert!(max < 5e-4, "H=1 local vs sync AdaAlter diverged: {max}");
    }

    #[test]
    fn sync_counts_match_scheduler() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(5), 63);
        let (syncs, bytes) = r.recorder.comm();
        assert_eq!(syncs, 63 / 5);
        assert!(bytes > 0);
        let r_inf = run(Algorithm::LocalAdaAlter, SyncPeriod::Infinite, 63);
        assert_eq!(r_inf.recorder.comm(), (0, 0));
    }

    #[test]
    fn sync_events_trace_fixed_policy() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(5), 63);
        assert_eq!(r.recorder.sync_events.len() as u64, r.recorder.comm().0);
        assert!(r
            .recorder
            .sync_events
            .iter()
            .all(|e| e.gap == 5 && e.reason == "period" && e.bytes > 0));
        assert_eq!(r.recorder.sync_policy(), "fixed(H=5)");
        // Fully-synchronous algorithms communicate every step by
        // construction — no policy events are recorded for them.
        let s = run(Algorithm::AdaGrad, SyncPeriod::Every(1), 10);
        assert!(s.recorder.sync_events.is_empty());
        assert_eq!(s.recorder.sync_policy(), "fixed(H=1)");
    }

    #[test]
    fn growing_policy_cuts_rounds_and_still_converges() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 400);
        cfg.sync.policy = "growing".into();
        cfg.sync.h_max = 16;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let (rounds, _) = r.recorder.comm();
        assert!(rounds < 400 / 4, "growing kept all {rounds} rounds");
        assert_eq!(r.recorder.sync_events.len() as u64, rounds);
        let gaps = r.recorder.realized_h();
        assert!(gaps.windows(2).all(|w| w[1] >= w[0]), "non-monotone: {gaps:?}");
        assert!(gaps.iter().all(|&g| g <= 16), "cap violated: {gaps:?}");
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn drift_policy_respects_h_max_through_the_trainer() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 200);
        cfg.sync.policy = "drift".into();
        cfg.sync.drift_threshold = 0.5;
        cfg.sync.h_max = 8;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let events = &r.recorder.sync_events;
        assert!(!events.is_empty());
        assert!(events.iter().all(|e| e.gap >= 1 && e.gap <= 8));
        assert!(events
            .iter()
            .all(|e| e.reason == "drift" || e.reason == "h_max"));
        assert_eq!(events.len() as u64, r.recorder.comm().0);
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn time_budget_policy_holds_comm_fraction() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 200);
        cfg.sync.policy = "time_budget".into();
        cfg.sync.target_comm_fraction = 0.02;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let events = &r.recorder.sync_events;
        assert!(events.len() >= 2);
        // After the first observed round the policy re-derives H from the
        // cost model; at 4 workers / 2% target it grows past the H₀ = 4.
        assert!(
            events.last().unwrap().gap > events.first().unwrap().gap,
            "H did not adapt: {:?}",
            r.recorder.realized_h()
        );
        let frac = r.clock.total(Charge::Communication) / r.clock.now_s();
        assert!(frac < 0.05, "comm fraction {frac} over budget");
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn adaptive_resume_rejected() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 8);
        cfg.sync.policy = "growing".into();
        let f = synthetic_factory(&cfg);
        let d = cfg.train.rust_math_dim;
        let mut t = Trainer::new(cfg, f);
        t.resume = Some(crate::coordinator::Checkpoint {
            step: 4,
            algorithm: Algorithm::LocalAdaAlter,
            vectors: vec![vec![0.0; d], vec![1.0; d], vec![1.0; d]],
        });
        let err = t.run().err().expect("must fail");
        assert!(err.to_string().contains("fixed"), "{err}");
    }

    #[test]
    fn fully_sync_communicates_every_step() {
        let r = run(Algorithm::AdaGrad, SyncPeriod::Every(1), 25);
        assert_eq!(r.recorder.comm().0, 25);
    }

    #[test]
    fn runs_are_deterministic() {
        let a = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 60);
        let b = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 60);
        assert_eq!(a.final_x, b.final_x, "training is not deterministic");
        assert_eq!(
            a.final_eval.unwrap().loss.to_bits(),
            b.final_eval.unwrap().loss.to_bits()
        );
    }

    #[test]
    fn virtual_clock_charges_components() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 40);
        assert!(r.clock.total(Charge::Compute) > 0.0);
        assert!(r.clock.total(Charge::Communication) > 0.0);
        // 4 workers: dataloader not binding in the paper calibration.
        assert_eq!(r.clock.total(Charge::DataLoad), 0.0);
        // comm < compute for H=4 (the whole point of the paper)
        assert!(r.clock.total(Charge::Communication) < r.clock.total(Charge::Compute));
    }

    #[test]
    fn transport_label_recorded() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 10);
        assert_eq!(r.recorder.transport(), "simulated(ps)");
    }

    #[test]
    fn single_worker_works() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 50);
        cfg.train.workers = 1;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        assert!(r.final_eval.unwrap().loss.is_finite());
    }
}
