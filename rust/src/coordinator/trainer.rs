//! The leader: spawns workers, drives the global iteration loop, owns the
//! synchronization protocol, the virtual clock and the metrics.
//!
//! Two protocol families (dispatched on [`Algorithm::is_local`]):
//!
//! * **Fully synchronous** (SGD / AdaGrad / AdaAlter): the leader owns the
//!   model `x`. Every iteration it broadcasts `x`, gathers all worker
//!   gradients (the §2 barrier), aggregates (Alg. 1/3 line 5), and applies
//!   the [`crate::optim::SyncOptimizer`] update.
//! * **Local** (local SGD / local AdaAlter): workers own their replicas and
//!   step independently; when the configured [`SyncPolicy`] says so (every
//!   H-th iteration under the default fixed policy), the leader gathers
//!   `(y_{i,t}, A²_{i,t})`, averages both (Alg. 4 lines 11–12), and
//!   broadcasts the averages back. Each executed round's observation
//!   (modeled time, straggler spread, realized drift) feeds back into the
//!   policy (DESIGN.md §5).
//!
//! Communication is layered (DESIGN.md §3): the control plane (commands,
//! replies, barriers) runs over a [`LeaderLink`] — in-process
//! [`crate::comm::ChannelTransport`] channels, or real TCP/Unix sockets
//! when `comm.transport` selects the networked deployment (DESIGN.md
//! §4) — and every
//! data-plane exchange — gradient gather, model broadcast, the paired
//! parameter/denominator averaging round — goes through a pluggable
//! [`Collective`] selected by the `[comm]` config section. The collective
//! owns the cost model: each op returns a [`CommReport`] that the leader
//! books against the virtual clock and the traffic recorder, so swapping
//! "lockstep channels" for "α–β-charged parameter server" or "QSGD over a
//! ring" is a config choice, not a trainer change.
//!
//! Time: the virtual clock charges the paper-calibrated per-iteration
//! compute/dataload cost plus the collective-reported sync cost on
//! communication rounds (wall-clock on this box is meaningless for the
//! figures; real wall time is still recorded for host-throughput
//! reporting).

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use crate::comm::net::{write_port_file, SocketKind, WireCollective, WireState};
use crate::comm::{
    build_collective, config_fingerprint, Collective, CommReport, LeaderLink, NetCounters,
    NetModel, TcpTransport,
};
use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::aggregate::{average_into, Aggregator};
use crate::coordinator::backend::{BackendFactory, EvalMetrics};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::executor::{spawn_worker_hosts, Parallelism};
use crate::coordinator::schedule::WarmupSchedule;
use crate::coordinator::sync::{
    build_policy, AutoscalePolicy, ScaleAction, StepObservation, SyncObservation, SyncPolicy,
    SyncReason,
};
use crate::coordinator::worker::{Cmd, Reply, WorkerSpec};
use crate::error::{Error, Result};
use crate::metrics::{FaultEvent, TrainRecorder};
use crate::optim;
use crate::sim::{Calibration, Charge, FaultPlan, VirtualClock};
use crate::util::pool::{ArcSlot, BufferPool};

/// Result of a training run.
pub struct RunResult {
    /// Final (synchronized / averaged) parameters.
    pub final_x: Vec<f32>,
    /// Metrics (loss/eval curves, comm accounting, wall throughput).
    pub recorder: TrainRecorder,
    /// Virtual-time accounting.
    pub clock: VirtualClock,
    /// Final held-out evaluation.
    pub final_eval: Option<EvalMetrics>,
    /// Real socket traffic `(accounted, total)` of a networked run
    /// (DESIGN.md §4): `accounted` is the billed codec payload bytes —
    /// pinned equal to the recorder's booked bytes for every codec —
    /// and `total` is every byte through the leader's sockets (headers
    /// and handshake included). `None` for in-process transports.
    pub net_bytes: Option<(u64, u64)>,
}

/// The leader/trainer.
pub struct Trainer {
    cfg: ExperimentConfig,
    factory: BackendFactory,
    /// Use the backend's fused local-step path when available.
    pub allow_fused: bool,
    /// Override the virtual-time calibration (default: paper V100).
    pub calibration: Calibration,
    /// Resume from a checkpoint (algorithm + dimensions must match).
    pub resume: Option<Checkpoint>,
    /// Override the fault scenario (default: compiled from the `[faults]`
    /// config section and `train.seed`; DESIGN.md §6).
    pub fault_plan: Option<FaultPlan>,
    /// Networked leader (DESIGN.md §4): publish the bound listen address
    /// to this file once the socket is up — how workers started with
    /// `--port-file` find a port-0 leader.
    pub port_file: Option<String>,
}

impl Trainer {
    /// Build a trainer for `cfg`; `factory(worker)` constructs each
    /// worker's gradient backend on its own thread.
    pub fn new(cfg: ExperimentConfig, factory: BackendFactory) -> Self {
        Trainer {
            cfg,
            factory,
            allow_fused: true,
            calibration: Calibration::paper_v100(),
            resume: None,
            fault_plan: None,
            port_file: None,
        }
    }

    /// Run the full training loop.
    pub fn run(&self) -> Result<RunResult> {
        let cfg = &self.cfg;
        let n = cfg.train.workers;
        let algo = cfg.optim.algorithm;
        // Install the `[exec]` SIMD dispatch mode process-wide. Pure
        // wall-clock knob: every kernel is bitwise mode-independent
        // (DESIGN.md §8), so concurrent runs with different configs
        // cannot perturb each other's results.
        crate::util::simd::set_mode(crate::util::simd::SimdMode::from_config(&cfg.exec)?);
        cfg.precision.validate()?;
        let bf16_state = cfg.precision.state_bf16();
        if self.resume.is_some() && cfg.comm.compression != "none" {
            // The delta-compression bases and error-feedback residuals are
            // not part of the checkpoint format; resuming would silently
            // quantize the full parameter vector on the first sync round.
            return Err(Error::Config(
                "resume is not supported over compressed transports \
                 (compressor state is not checkpointed)"
                    .into(),
            ));
        }
        if self.resume.is_some() && algo.is_local() && !cfg.sync.is_fixed() {
            // Adaptive scheduler state (drift accumulators, grown H) is
            // not part of the checkpoint format either.
            return Err(Error::Config(
                "resume requires sync.policy = \"fixed\" \
                 (adaptive scheduler state is not checkpointed)"
                    .into(),
            ));
        }
        if cfg.train.checkpoint_every > 0 && algo.is_local() && !cfg.sync.is_fixed() {
            // TOML-loaded configs are rejected by validate(); guard the
            // programmatically-built ones here too — snapshots require
            // sync boundaries known ahead of time.
            return Err(Error::Config(
                "checkpointing requires sync.policy = \"fixed\" \
                 (adaptive policies decide boundaries at runtime)"
                    .into(),
            ));
        }
        // The fault scenario (DESIGN.md §6): compiled from `[faults]` +
        // seed unless a programmatic plan was injected. An empty plan with
        // no participation policy keeps every fault code path disabled.
        let plan = match &self.fault_plan {
            Some(p) => {
                if p.n() != n {
                    return Err(Error::Config(format!(
                        "fault plan covers {} workers, config has {n}",
                        p.n()
                    )));
                }
                p.clone()
            }
            None => FaultPlan::from_config(cfg),
        };
        let faults_on = !plan.is_empty() || cfg.faults.partial() || cfg.faults.autoscale;
        if faults_on || cfg.faults.is_active() {
            // TOML-loaded configs already passed these rules; re-run them
            // for programmatically-built configs (field-named errors, not
            // mid-run panics). Checked whenever the *section* asks for
            // faults, not just when a plan compiled — an out-of-range
            // crash worker must error, not silently yield an empty plan.
            cfg.validate_faults()?;
        }
        // Checkpointing and resume compose with `[faults]` (DESIGN.md
        // §10): the plan is a pure function of `(seed, worker, step)`, so
        // a resumed run replays the exact same schedule from `start_step`
        // without any plan progress in the checkpoint. The one combination
        // still outside the format is the autoscaler: its patience
        // counters accumulate over telemetry history, which a checkpoint
        // does not carry.
        if self.resume.is_some() && cfg.faults.autoscale {
            return Err(Error::Config(
                "faults.autoscale is not supported with resume \
                 (autoscale patience counters are not checkpointed)"
                    .into(),
            ));
        }
        // The per-iteration sync decision is the policy's (DESIGN.md §5);
        // non-local algorithms always get FixedPeriod(1).
        let policy = build_policy(cfg)?;
        // Drift-triggered policies consume the per-step update norm, which
        // the fused device path cannot observe — fall back to the split
        // grad + rust-update path for those runs. `train.fused = false`
        // disables the device path outright (required for partial rounds).
        let collect_update_sq = policy.needs_update_norms();
        // bf16 accumulator state also disables fusion: the device graphs
        // know nothing about the quantize-after-update hook (same
        // fall-back precedent as collect_update_sq).
        let allow_fused = self.allow_fused && cfg.train.fused && !collect_update_sq && !bf16_state;
        let warmup = WarmupSchedule::new(cfg.optim.eta, cfg.optim.warmup_steps);

        // --- Spawn workers -------------------------------------------------
        // One probe backend determines d and initial params; workers build
        // their own backends thread-locally (PJRT clients are not Send).
        let probe = (self.factory)(0)?;
        let d = probe.dim();
        let mut start_step = 0u64;
        let mut resume_opt_state: Vec<Vec<f32>> = Vec::new();
        let mut resume_acc: Option<Arc<Vec<f32>>> = None;
        let init: Arc<Vec<f32>> = if let Some(ck) = &self.resume {
            ck.validate()?;
            if ck.algorithm != algo {
                return Err(Error::Protocol(format!(
                    "checkpoint is for {}, config asks for {algo}",
                    ck.algorithm
                )));
            }
            if ck.vectors[0].len() != d {
                return Err(Error::Protocol(format!(
                    "checkpoint d={} but backend d={d}",
                    ck.vectors[0].len()
                )));
            }
            start_step = ck.step;
            match algo {
                Algorithm::LocalAdaAlter => {
                    // vectors: [x, b2_sync, acc] — at a sync boundary
                    // b2_sync == acc == the averaged A²; install via an
                    // InstallState once workers are up.
                    resume_acc = Some(Arc::new(ck.vectors[2].clone()));
                }
                Algorithm::LocalSgd => {}
                _ => resume_opt_state = ck.vectors[1..].to_vec(),
            }
            Arc::new(ck.vectors[0].clone())
        } else {
            Arc::new(probe.init_params()?)
        };
        drop(probe);
        if init.len() != d {
            return Err(Error::Protocol(format!("init len {} != d {d}", init.len())));
        }

        // The execution engine (DESIGN.md §7): workers are hosted on the
        // `[exec]`-selected thread layout — one host per worker by
        // default (the pre-engine thread shape), k round-robin hosts or
        // one serial host on request. Every layout is bitwise-identical
        // (worker streams are pure functions of `(seed, worker, step)`;
        // all leader reductions are fixed-order).
        let par = Parallelism::from_config(&cfg.exec)?;
        let specs: Vec<WorkerSpec> = (0..n)
            .map(|w| WorkerSpec {
                worker: w,
                algorithm: algo,
                epsilon: cfg.optim.epsilon,
                b0: cfg.optim.b0,
                init: Arc::clone(&init),
                allow_fused,
                collect_update_sq,
                bf16_state,
                // A crash already behind the resume point never replays:
                // the plan's liveness windows (not the tombstone) decide
                // whether the worker is alive at `start_step`.
                crash_step: plan.crash_step(w).filter(|&c| c > start_step),
            })
            .collect();

        // The transport: in-process worker hosts, or real sockets when
        // `comm.transport` is "tcp"/"uds" (DESIGN.md §4). Lossy wires
        // over real sockets encode on the worker side, so their round
        // arithmetic runs in WireCollective against the leader's decoded
        // mirrors; the dense f32 wire ships exact bytes and keeps the
        // usual simulated α–β accounting.
        let (transport, coll, net_counters) = if cfg.comm.networked() {
            if self.resume.is_some() {
                return Err(Error::Config(
                    "resume is not supported over the networked transport \
                     (restart the run from step 0 instead)"
                        .into(),
                ));
            }
            let kind = SocketKind::from_transport(&cfg.comm.transport)
                .expect("networked() implies a tcp/uds transport");
            let bound = TcpTransport::listen(
                kind,
                &cfg.net.listen,
                Duration::from_secs_f64(cfg.net.connect_timeout_s),
            )?;
            if let Some(pf) = &self.port_file {
                write_port_file(pf, bound.local_addr())?;
            }
            let state = WireState::sharded(WireState::codec_for(cfg), n, d, cfg.comm.shards);
            let counters = NetCounters::new();
            let transport = bound.handshake(
                &specs,
                config_fingerprint(cfg),
                cfg.net.nodelay,
                Arc::clone(&state),
                Arc::clone(&counters),
                cfg.comm.pipeline,
            )?;
            let coll: Box<dyn Collective> = if cfg.comm.compression == "qsgd" {
                Box::new(
                    WireCollective::new(
                        state,
                        NetModel::from_config(&cfg.net).with_shards(cfg.comm.shards),
                        format!("qsgd(s={})", cfg.comm.qsgd_levels),
                    )
                    .with_pipeline(cfg.comm.pipeline),
                )
            } else if cfg.precision.wire_bf16() {
                Box::new(
                    WireCollective::new(
                        state,
                        NetModel::from_config(&cfg.net).with_shards(cfg.comm.shards),
                        "bf16".into(),
                    )
                    .with_pipeline(cfg.comm.pipeline),
                )
            } else {
                build_collective(cfg, &self.calibration, d)?
            };
            (LeaderLink::Net(Box::new(transport)), coll, Some(counters))
        } else {
            let (reply_tx, reply_rx) = channel::<Reply>();
            let transport =
                spawn_worker_hosts(par, specs, Arc::clone(&self.factory), reply_tx, reply_rx)?;
            let coll = build_collective(cfg, &self.calibration, d)?;
            (LeaderLink::Chan(transport), coll, None)
        };
        let mut recorder = TrainRecorder::new(cfg.train.steps_per_epoch);
        recorder.set_transport(coll.label());
        recorder.set_sync_policy(policy.label());

        let mut run = LeaderLoop {
            cfg,
            d,
            policy,
            last_sync_t: start_step,
            warmup,
            coll,
            calib: &self.calibration,
            transport,
            agg: Aggregator::new(d),
            recorder,
            clock: VirtualClock::new(),
            x: init.as_ref().clone(),
            opt: if algo.is_local() {
                None
            } else {
                let mut opt = optim::build_sync_precision(&cfg.optim, bf16_state, d);
                if !resume_opt_state.is_empty() {
                    opt.restore_state(&resume_opt_state)?;
                }
                Some(opt)
            },
            start_step,
            resume_acc,
            faults_on,
            // Membership starts from the plan's liveness windows at the
            // first iteration: spawn-scheduled workers and spares are not
            // addressed until admitted, and a resume inside a crash window
            // starts with that worker out (readmitted at its rejoin
            // boundary exactly as the uninterrupted run would).
            alive: (0..n).map(|w| plan.alive(w, start_step + 1)).collect(),
            left: vec![false; n],
            spares: (0..n).filter(|&w| plan.is_spare(w)).collect(),
            autoscale: if cfg.faults.autoscale {
                Some(AutoscalePolicy::new(
                    cfg.faults.autoscale_drift,
                    cfg.faults.autoscale_straggler_s,
                    cfg.faults.autoscale_patience,
                ))
            } else {
                None
            },
            round_crashes: 0,
            round_leaves: 0,
            round_joins: 0,
            plan,
            phase_s: vec![0.0; n],
            phase_nominal_s: 0.0,
            pool: BufferPool::new(),
            bcast_buf: vec![0.0; d],
            bcast_slot: ArcSlot::new(),
            install_slot: ArcSlot::new(),
            acc_slot: ArcSlot::new(),
            acc_scratch: vec![0.0; d],
        };
        let out = run.drive();
        // Always attempt shutdown, even on error. For the networked
        // transport this also joins the socket threads, so the traffic
        // counters read below are final.
        run.shutdown();
        // Surface the run's pool counters: leader f32 scratch merged with
        // the networked transport's wire byte pool (if any).
        let mut pool_stats = run.pool.stats();
        if let LeaderLink::Net(t) = &run.transport {
            pool_stats = pool_stats.merge(&t.pool_stats());
        }
        run.recorder.set_pool_stats(pool_stats);
        out.map(|(final_x, final_eval)| RunResult {
            final_x,
            recorder: run.recorder,
            clock: run.clock,
            final_eval,
            net_bytes: net_counters.map(|c| (c.accounted(), c.total())),
        })
    }
}

/// A worker-reported failure — the one interception point for
/// `Reply::Err` across every gather/recv site.
fn worker_err(worker: usize, msg: String) -> Error {
    Error::Protocol(format!("worker {worker}: {msg}"))
}

/// Per-worker outcome of a fault-aware gather: a payload, a crash
/// tombstone, or a voluntary departure (`Leave` — billed distinctly from
/// a crash; DESIGN.md §10).
enum Gathered<T> {
    Ok(T),
    Crashed,
    Left,
}

/// Internal driver state (separated so shutdown can run after errors).
struct LeaderLoop<'a> {
    cfg: &'a ExperimentConfig,
    d: usize,
    /// The synchronization policy (config-selected; DESIGN.md §5).
    policy: Box<dyn SyncPolicy>,
    /// Iteration of the last executed sync round (realized-H tracking).
    last_sync_t: u64,
    warmup: WarmupSchedule,
    /// The data-plane collective (config-selected).
    coll: Box<dyn Collective>,
    calib: &'a Calibration,
    /// The control-plane message transport: in-process channels, or the
    /// networked leader endpoint (DESIGN.md §4).
    transport: LeaderLink,
    agg: Aggregator,
    recorder: TrainRecorder,
    clock: VirtualClock,
    /// Leader-owned model (sync algorithms); scratch for local averaging.
    x: Vec<f32>,
    opt: Option<Box<dyn optim::SyncOptimizer>>,
    /// First iteration is start_step + 1 (resume support).
    start_step: u64,
    /// Local-AdaAlter accumulator to install on resume.
    resume_acc: Option<Arc<Vec<f32>>>,
    /// The fault scenario (DESIGN.md §6; empty in fault-free runs).
    plan: FaultPlan,
    /// Gate for every fault code path: false ⇒ the leader loop is the
    /// exact (bitwise) fault-free protocol.
    faults_on: bool,
    /// Per-worker liveness (false once a crash tombstone arrived, or
    /// before a spawn-scheduled worker's admission boundary).
    alive: Vec<bool>,
    /// Per-worker voluntary-departure flag (graceful `Leave` frame, or
    /// retired by the autoscaler): these workers are gone on purpose —
    /// never billed as crashes and never plan-readmitted (DESIGN.md §10).
    left: Vec<bool>,
    /// Spare workers (`faults.spawn_step = 0`) queued for autoscale
    /// admission, in id order.
    spares: Vec<usize>,
    /// Telemetry-driven elastic membership (`faults.autoscale`).
    autoscale: Option<AutoscalePolicy>,
    /// Crashes discovered since the last recorded fault event.
    round_crashes: u64,
    /// Voluntary departures since the last recorded fault event.
    round_leaves: u64,
    /// Admissions performed at the last round boundary.
    round_joins: u64,
    /// Per-worker virtual arrival time within the current local phase —
    /// the straggler signal partial rounds select on.
    phase_s: Vec<f64>,
    /// Lockstep-nominal virtual time of the current phase (what the
    /// per-iteration charges already booked for it).
    phase_nominal_s: f64,
    /// Recycled d-sized scratch buffers (DESIGN.md §7): gradient buffers
    /// ride `SyncStep` down and `Reply::Grad` back; state-snapshot
    /// buffers ride `CollectState` down and `Reply::State` back — after
    /// aggregation / averaging they are parked here, so steady-state
    /// steps and sync rounds reuse the same allocations.
    pool: BufferPool,
    /// Scratch the per-iteration broadcast payload is staged in so a
    /// lossy wire can transform it (bf16 rounding) before it is frozen
    /// into the broadcast `Arc`.
    bcast_buf: Vec<f32>,
    /// Recycled `Arc` payload for the per-iteration model broadcast.
    bcast_slot: ArcSlot,
    /// Recycled `Arc` payload for the sync-round state install.
    install_slot: ArcSlot,
    /// Recycled `Arc` payload for the averaged accumulator install.
    acc_slot: ArcSlot,
    /// Leader-side scratch the collective averages accumulators into.
    acc_scratch: Vec<f32>,
}

impl<'a> LeaderLoop<'a> {
    fn n(&self) -> usize {
        self.transport.n()
    }

    fn wait_ready(&mut self) -> Result<()> {
        self.transport
            .gather(|r| match r {
                Reply::Ready { worker } => Ok((worker, ())),
                Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
                _ => Err(Error::Protocol("expected Ready".into())),
            })
            .map(|_| ())
    }

    /// Algorithm-adjusted per-iteration compute cost (the Compute charge).
    fn compute_charge_s(&self) -> f64 {
        let c = self.calib;
        let mut compute = c.t_compute_s;
        if matches!(
            self.cfg.optim.algorithm,
            Algorithm::AdaAlter | Algorithm::LocalAdaAlter
        ) {
            compute *= 1.0 + c.adaalter_compute_overhead;
        }
        compute
    }

    /// Lockstep-nominal wall time of one iteration: compute, or the
    /// dataloader when it binds — exactly what [`Self::charge_iteration`]
    /// books per iteration.
    fn nominal_iter_s(&self) -> f64 {
        self.compute_charge_s().max(self.calib.dataload_s(self.n()))
    }

    /// Worker `w`'s modeled wall time for iteration `t` under the fault
    /// plan (slowdowns/stalls applied to compute; the shared dataloader
    /// still floors it). Equals [`Self::nominal_iter_s`] for un-faulted
    /// workers.
    fn worker_iter_s(&self, w: usize, t: u64) -> f64 {
        self.plan
            .step_time_s(w, t, self.compute_charge_s())
            .max(self.calib.dataload_s(self.n()))
    }

    /// Worker ids still alive (all of them in fault-free runs).
    fn alive_ids(&self) -> Vec<usize> {
        (0..self.n()).filter(|&w| self.alive[w]).collect()
    }

    /// Charge one iteration's compute+dataload to the virtual clock.
    fn charge_iteration(&mut self) {
        let compute = self.compute_charge_s();
        self.clock.advance(Charge::Compute, compute);
        let extra = (self.calib.dataload_s(self.n()) - compute).max(0.0);
        if extra > 0.0 {
            self.clock.advance(Charge::DataLoad, extra);
        }
    }

    /// Book a collective op's cost: virtual time to the clock, exact
    /// traffic and the full round count to the recorder (all bytes are
    /// booked on the first round's entry; extra rounds, should a future
    /// collective report them, count as zero-byte syncs so the recorder's
    /// sync counter always equals Σ rounds).
    fn apply_comm(&mut self, r: CommReport) {
        self.clock.advance(Charge::Communication, r.time_s);
        if r.rounds > 0 {
            self.recorder.sync(r.bytes);
            for _ in 1..r.rounds {
                self.recorder.sync(0);
            }
        }
    }

    /// The main loop; returns (final params, final eval).
    fn drive(&mut self) -> Result<(Vec<f32>, Option<EvalMetrics>)> {
        self.wait_ready()?;
        let algo = self.cfg.optim.algorithm;
        // Resuming a local run: install the checkpointed replica state.
        if self.start_step > 0 && algo.is_local() {
            let x = Arc::new(self.x.clone());
            let acc = self.resume_acc.clone();
            self.transport
                .broadcast(|_| Cmd::InstallState { x: Arc::clone(&x), acc: acc.clone() })?;
            self.wait_ready()?;
        }
        let steps = self.cfg.train.steps;
        let log_every = self.cfg.train.log_every.max(1);
        let eval_every = self.cfg.train.eval_every;

        for t in (self.start_step + 1)..=steps {
            let lr = self.warmup.lr(t);
            let mean_loss = if algo.is_local() {
                self.local_iteration(t, lr)?
            } else {
                self.sync_iteration(t, lr)?
            };
            self.charge_iteration();
            let log = t % log_every == 0 || t == steps || t == 1;
            // Throughput accounting: crashed workers stop drawing batches.
            let samples = if self.faults_on {
                self.alive.iter().filter(|&&a| a).count() as u64
            } else {
                self.n() as u64
            };
            self.recorder
                .step(t, mean_loss, lr, self.clock.now_s(), samples, log);

            if eval_every > 0 && (t % eval_every == 0 || t == steps) {
                let m = self.evaluate(t)?;
                self.recorder
                    .eval(t, m.loss, m.ppl, self.clock.now_s());
            }

            let ck_every = self.cfg.train.checkpoint_every;
            if ck_every > 0 && t % ck_every == 0 {
                self.save_checkpoint(t)?;
            }
        }

        // Final consolidated model + eval.
        let final_x = self.consolidated_x()?;
        let final_eval = Some(self.eval_at(&final_x)?);
        Ok((final_x, final_eval))
    }

    /// One fully-synchronous iteration: broadcast x, gather grads, update.
    fn sync_iteration(&mut self, t: u64, lr: f32) -> Result<f64> {
        if self.faults_on {
            return self.sync_iteration_faulted(t, lr);
        }
        // One shared payload per round (Arc clones, not vector clones),
        // recycled across rounds; gradient buffers ride the command down
        // and the reply back, so steady state allocates nothing here. The
        // broadcast runs on a scratch copy so a lossy wire can transform
        // the payload the workers actually receive.
        self.bcast_buf.copy_from_slice(&self.x);
        let rep_b = self.coll.broadcast(&mut self.bcast_buf)?;
        let x_arc = self.bcast_slot.fill(&self.bcast_buf);
        let (pool, d) = (&mut self.pool, self.d);
        self.transport.broadcast(|_| Cmd::SyncStep {
            t,
            x: Arc::clone(&x_arc),
            scratch: pool.take(d),
        })?;
        let replies = self.transport.gather(|r| match r {
            Reply::Grad { worker, loss, grad } => Ok((worker, (loss, grad))),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected Grad".into())),
        })?;
        let mean_loss =
            replies.iter().map(|(l, _)| *l as f64).sum::<f64>() / replies.len() as f64;
        let mut grads: Vec<Vec<f32>> = replies.into_iter().map(|(_, g)| g).collect();
        // Gradient push/pull round: the collective transforms the payloads
        // (identity for lossless transports) and reports the round's cost.
        let rep_g = self.coll.gather_grads(&mut grads)?;
        self.apply_comm(rep_b.merge(rep_g));
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();

        let opt = self.opt.as_mut().expect("sync iteration without optimizer");
        match opt.algorithm() {
            Algorithm::AdaGrad => {
                // Alg. 1: accumulate the square of the AVERAGED gradient.
                self.agg.mean_grads(&grad_refs);
                self.agg.square_avg_grad();
            }
            _ => {
                // Alg. 3 (and momentum variance bookkeeping): average both
                // the gradients and their squares in one pass.
                self.agg.mean_grads_and_squares(&grad_refs);
            }
        }
        opt.step(&mut self.x, &self.agg.avg_g, &self.agg.avg_gsq, lr);
        // Park the gradient buffers for the next iteration's SyncStep.
        for g in grads {
            self.pool.put(g);
        }
        Ok(mean_loss)
    }

    /// One local iteration; runs the sync round when the policy says so.
    fn local_iteration(&mut self, t: u64, lr: f32) -> Result<f64> {
        if self.faults_on {
            return self.local_iteration_faulted(t, lr);
        }
        self.transport.broadcast(|_| Cmd::LocalStep { t, lr })?;
        let replies = self.transport.gather(|r| match r {
            Reply::StepDone { worker, loss, update_sq } => Ok((worker, (loss, update_sq))),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected StepDone".into())),
        })?;
        let n = replies.len() as f64;
        let mean_loss = replies.iter().map(|&(l, _)| l as f64).sum::<f64>() / n;
        let mean_update_sq = replies.iter().map(|&(_, u)| u).sum::<f64>() / n;

        let step = StepObservation { t, update_sq: mean_update_sq };
        if let Some(reason) = self.policy.decide(&step) {
            self.sync_round(t, reason)?;
        }
        Ok(mean_loss)
    }

    /// Fault-aware fully-synchronous iteration (DESIGN.md §6): only live
    /// workers are addressed, crash tombstones shrink the gather, the
    /// per-iteration barrier is charged the spread between the slowest
    /// live worker and the lockstep-nominal cost, and the update averages
    /// the survivors' gradients.
    fn sync_iteration_faulted(&mut self, t: u64, lr: f32) -> Result<f64> {
        let targets = self.alive_ids();
        if targets.is_empty() {
            return Err(Error::Protocol(format!("all workers crashed before step {t}")));
        }
        self.bcast_buf.copy_from_slice(&self.x);
        let rep_b = self.coll.broadcast(&mut self.bcast_buf)?;
        let x_arc = self.bcast_slot.fill(&self.bcast_buf);
        let (pool, d) = (&mut self.pool, self.d);
        self.transport.broadcast_to(&targets, |_| Cmd::SyncStep {
            t,
            x: Arc::clone(&x_arc),
            scratch: pool.take(d),
        })?;
        let replies = self.transport.gather_from(&targets, |r| match r {
            Reply::Grad { worker, loss, grad } => Ok((worker, Gathered::Ok((loss, grad)))),
            Reply::Crashed { worker, .. } => Ok((worker, Gathered::Crashed)),
            Reply::Left { worker, .. } => Ok((worker, Gathered::Left)),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected Grad".into())),
        })?;
        let nominal = self.nominal_iter_s();
        let mut close = nominal;
        let mut losses: Vec<f64> = Vec::new();
        let mut grads: Vec<Vec<f32>> = Vec::new();
        for (&w, rep) in targets.iter().zip(replies) {
            match rep {
                Gathered::Ok((loss, grad)) => {
                    close = close.max(self.worker_iter_s(w, t));
                    losses.push(loss as f64);
                    grads.push(grad);
                }
                Gathered::Crashed => {
                    self.alive[w] = false;
                    self.round_crashes += 1;
                }
                Gathered::Left => {
                    self.alive[w] = false;
                    self.left[w] = true;
                    self.round_leaves += 1;
                }
            }
        }
        if grads.is_empty() {
            return Err(Error::Protocol(format!("all workers crashed at step {t}")));
        }
        let wait = close - nominal;
        if wait > 0.0 {
            self.clock.advance(Charge::Straggler, wait);
        }
        let mean_loss = losses.iter().sum::<f64>() / losses.len() as f64;
        let rep_g = self.coll.gather_grads(&mut grads)?;
        self.apply_comm(rep_b.merge(rep_g));
        // Every fully-synchronous iteration is a round: log its
        // participation too (here `dropped` counts workers whose departure
        // was discovered during this very round).
        self.recorder.fault_event(FaultEvent {
            step: t,
            alive: targets.len() as u64,
            participants: grads.len() as u64,
            dropped: (targets.len() - grads.len()) as u64,
            crashes: self.round_crashes,
            leaves: self.round_leaves,
            joins: self.round_joins,
            wait_s: wait,
            virtual_s: self.clock.now_s(),
        });
        self.round_crashes = 0;
        self.round_leaves = 0;
        self.round_joins = 0;
        let grad_refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();

        let opt = self.opt.as_mut().expect("sync iteration without optimizer");
        match opt.algorithm() {
            Algorithm::AdaGrad => {
                self.agg.mean_grads(&grad_refs);
                self.agg.square_avg_grad();
            }
            _ => {
                self.agg.mean_grads_and_squares(&grad_refs);
            }
        }
        opt.step(&mut self.x, &self.agg.avg_g, &self.agg.avg_gsq, lr);
        // Park the survivors' gradient buffers for the next iteration
        // (buffers sent to workers whose crash surfaced this round are
        // gone with them — the pool tracks the live population).
        for g in grads {
            self.pool.put(g);
        }
        Ok(mean_loss)
    }

    /// Fault-aware local iteration (DESIGN.md §6): live workers step and
    /// their per-worker virtual arrival times accumulate (slowdowns and
    /// stalls applied); crash tombstones mark workers dead; the policy's
    /// sync decision then runs the (possibly partial) round.
    fn local_iteration_faulted(&mut self, t: u64, lr: f32) -> Result<f64> {
        let targets = self.alive_ids();
        if targets.is_empty() {
            return Err(Error::Protocol(format!("all workers crashed before step {t}")));
        }
        self.transport.broadcast_to(&targets, |_| Cmd::LocalStep { t, lr })?;
        let replies = self.transport.gather_from(&targets, |r| match r {
            Reply::StepDone { worker, loss, update_sq } => {
                Ok((worker, Gathered::Ok((loss, update_sq))))
            }
            Reply::Crashed { worker, .. } => Ok((worker, Gathered::Crashed)),
            Reply::Left { worker, .. } => Ok((worker, Gathered::Left)),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected StepDone".into())),
        })?;
        self.phase_nominal_s += self.nominal_iter_s();
        let mut losses: Vec<f64> = Vec::new();
        let mut upds: Vec<f64> = Vec::new();
        for (&w, rep) in targets.iter().zip(&replies) {
            match rep {
                Gathered::Ok((loss, update_sq)) => {
                    let t_w = self.worker_iter_s(w, t);
                    self.phase_s[w] += t_w;
                    losses.push(*loss as f64);
                    upds.push(*update_sq);
                }
                Gathered::Crashed => {
                    self.alive[w] = false;
                    self.round_crashes += 1;
                }
                Gathered::Left => {
                    self.alive[w] = false;
                    self.left[w] = true;
                    self.round_leaves += 1;
                }
            }
        }
        if losses.is_empty() {
            return Err(Error::Protocol(format!("all workers crashed at step {t}")));
        }
        let n = losses.len() as f64;
        let mean_loss = losses.iter().sum::<f64>() / n;
        let mean_update_sq = upds.iter().sum::<f64>() / n;

        let step = StepObservation { t, update_sq: mean_update_sq };
        if let Some(reason) = self.policy.decide(&step) {
            self.sync_round(t, reason)?;
        }
        Ok(mean_loss)
    }

    /// Gather worker states, with or without accumulators. The snapshot
    /// buffers come out of (and, via [`Self::recycle_states`], return to)
    /// the leader's [`BufferPool`], so steady-state sync rounds reuse the
    /// same allocations.
    fn collect_states(&mut self, raw: bool) -> Result<Vec<(Vec<f32>, Option<Vec<f32>>)>> {
        let wants_acc = self.cfg.optim.algorithm.syncs_denominator();
        let (pool, d) = (&mut self.pool, self.d);
        self.transport.broadcast(|_| Cmd::CollectState {
            sx: pool.take(d),
            sa: if wants_acc { pool.take(d) } else { Vec::new() },
            raw,
        })?;
        self.transport.gather(|r| match r {
            Reply::State { worker, x, acc } => Ok((worker, (x, acc))),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected State".into())),
        })
    }

    /// [`Self::collect_states`] over a live subset (fault runs).
    fn collect_states_from(
        &mut self,
        targets: &[usize],
        raw: bool,
    ) -> Result<Vec<(Vec<f32>, Option<Vec<f32>>)>> {
        let wants_acc = self.cfg.optim.algorithm.syncs_denominator();
        let (pool, d) = (&mut self.pool, self.d);
        self.transport.broadcast_to(targets, |_| Cmd::CollectState {
            sx: pool.take(d),
            sa: if wants_acc { pool.take(d) } else { Vec::new() },
            raw,
        })?;
        self.transport.gather_from(targets, |r| match r {
            Reply::State { worker, x, acc } => Ok((worker, (x, acc))),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("expected State".into())),
        })
    }

    /// Park consumed state snapshots for the next round's
    /// [`Self::collect_states`].
    fn recycle_states(&mut self, states: Vec<(Vec<f32>, Option<Vec<f32>>)>) {
        for (x, acc) in states {
            self.pool.put(x);
            if let Some(a) = acc {
                self.pool.put(a);
            }
        }
    }

    /// [`Self::wait_ready`] over a live subset (fault runs).
    fn wait_ready_from(&mut self, targets: &[usize]) -> Result<()> {
        self.transport
            .gather_from(targets, |r| match r {
                Reply::Ready { worker } => Ok((worker, ())),
                Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
                _ => Err(Error::Protocol("expected Ready".into())),
            })
            .map(|_| ())
    }

    /// Alg. 4 lines 11–12: the paired averaging round, executed by the
    /// configured collective (which may compress the exchange), then the
    /// averaged state is installed on every replica. The round's
    /// [`SyncObservation`] — assembled from the collective's report and
    /// the virtual clock — is recorded and fed back to the policy.
    fn sync_round(&mut self, t: u64, reason: SyncReason) -> Result<()> {
        if self.faults_on {
            return self.sync_round_faulted(t, reason);
        }
        let wants_acc = self.cfg.optim.algorithm.syncs_denominator();
        let states = self.collect_states(false)?;
        let xs: Vec<&[f32]> = states.iter().map(|(x, _)| x.as_slice()).collect();

        let (report, avg_acc) = if wants_acc {
            let accs: Vec<&[f32]> = states
                .iter()
                .map(|(_, a)| {
                    a.as_deref()
                        .ok_or_else(|| Error::Protocol("worker state missing accumulator".into()))
                })
                .collect::<Result<_>>()?;
            let rep = self.coll.sync_round(
                &xs,
                Some(&accs),
                &mut self.x,
                Some(&mut self.acc_scratch),
            )?;
            (rep, Some(self.acc_slot.fill(&self.acc_scratch)))
        } else {
            let rep = self.coll.sync_round(&xs, None, &mut self.x, None)?;
            (rep, None)
        };

        let avg_x = self.install_slot.fill(&self.x);
        self.transport.broadcast(|_| Cmd::InstallState {
            x: Arc::clone(&avg_x),
            acc: avg_acc.clone(),
        })?;
        self.wait_ready()?;
        self.recycle_states(states);
        // Fault-free runs never configure the autoscaler (`faults_on`
        // routes them away from this path), so the decision is vacuous.
        let _ = self.record_round(t, reason, report, 0.0);
        Ok(())
    }

    /// Shared per-round bookkeeping tail of both sync-round paths: book
    /// the round's cost, log the sync event, and feed the policy its
    /// [`SyncObservation`]. `straggler_floor_s` lets the fault path raise
    /// the straggler observation to the barrier wait it actually measured
    /// (0 in the fault-free path — `report.straggler_s` is never negative,
    /// so the floor is then a no-op, bit for bit). The same observation
    /// feeds the autoscaler (when configured); its membership decision is
    /// returned to the fault path for execution at this boundary.
    fn record_round(
        &mut self,
        t: u64,
        reason: SyncReason,
        report: CommReport,
        straggler_floor_s: f64,
    ) -> Option<ScaleAction> {
        self.apply_comm(report);
        let (rounds, _) = self.recorder.comm();
        self.recorder.sync_event(
            t,
            t - self.last_sync_t,
            reason.as_str(),
            report.bytes,
            self.clock.now_s(),
        );
        self.last_sync_t = t;
        let obs = SyncObservation {
            t,
            reason,
            rounds,
            round_bytes: report.bytes,
            round_time_s: report.time_s,
            straggler_s: report.straggler_s.max(straggler_floor_s),
            drift_sq: report.drift_sq,
            virtual_now_s: self.clock.now_s(),
            total_comm_s: self.clock.total(Charge::Communication),
        };
        self.policy.observe(&obs);
        self.autoscale.as_mut().and_then(|a| a.observe(&obs))
    }

    /// Fault-aware sync round (DESIGN.md §6): live workers offer their
    /// states *and arrival times*; the collective's
    /// [`Collective::sync_round_partial`] closes the barrier per the
    /// configured participation policy (full barrier by default, quorum /
    /// backup-worker under `[faults]`), averaging only the participants.
    /// Every live worker — dropped stragglers included — then installs the
    /// averaged state (`InstallState` catch-up). The barrier's wait beyond
    /// the lockstep-nominal phase time is charged to
    /// [`Charge::Straggler`], and the round's participation is recorded as
    /// a [`crate::metrics::FaultEvent`].
    fn sync_round_faulted(&mut self, t: u64, reason: SyncReason) -> Result<()> {
        let wants_acc = self.cfg.optim.algorithm.syncs_denominator();
        let targets = self.alive_ids();
        if targets.is_empty() {
            return Err(Error::Protocol(format!("all workers crashed before round at {t}")));
        }
        let states = self.collect_states_from(&targets, false)?;
        let xs: Vec<&[f32]> = states.iter().map(|(x, _)| x.as_slice()).collect();
        let arrivals: Vec<f64> = targets.iter().map(|&w| self.phase_s[w]).collect();

        let (outcome, avg_acc) = if wants_acc {
            let accs: Vec<&[f32]> = states
                .iter()
                .map(|(_, a)| {
                    a.as_deref()
                        .ok_or_else(|| Error::Protocol("worker state missing accumulator".into()))
                })
                .collect::<Result<_>>()?;
            let oc = self.coll.sync_round_partial(
                &xs,
                Some(&accs),
                &arrivals,
                &mut self.x,
                Some(&mut self.acc_scratch),
            )?;
            (oc, Some(self.acc_slot.fill(&self.acc_scratch)))
        } else {
            let oc = self
                .coll
                .sync_round_partial(&xs, None, &arrivals, &mut self.x, None)?;
            (oc, None)
        };

        // Install the averaged state on every live worker — the dropped
        // stragglers abandon their stale phase and catch up here.
        let avg_x = self.install_slot.fill(&self.x);
        self.transport.broadcast_to(&targets, |_| Cmd::InstallState {
            x: Arc::clone(&avg_x),
            acc: avg_acc.clone(),
        })?;
        self.wait_ready_from(&targets)?;
        self.recycle_states(states);

        // The barrier's visible straggler penalty: how long the round's
        // close sat beyond what the per-iteration charges already booked.
        let wait_s = (outcome.close_s - self.phase_nominal_s).max(0.0);
        if wait_s > 0.0 {
            self.clock.advance(Charge::Straggler, wait_s);
        }
        let scale = self.record_round(t, reason, outcome.report, wait_s);
        // The membership boundary (DESIGN.md §10): every admission path —
        // wire rejoins, plan-scheduled rejoins/spawns, autoscale — runs
        // here, warm-starting newcomers from this round's averaged state,
        // so a worker admitted at `t` is indistinguishable from one that
        // installed the average like everyone else.
        self.membership_boundary(t, scale, &avg_x, &avg_acc)?;
        self.recorder.fault_event(FaultEvent {
            step: t,
            alive: targets.len() as u64,
            participants: outcome.participants.len() as u64,
            dropped: outcome.dropped.len() as u64,
            crashes: self.round_crashes,
            leaves: self.round_leaves,
            joins: self.round_joins,
            wait_s,
            virtual_s: self.clock.now_s(),
        });
        self.round_crashes = 0;
        self.round_leaves = 0;
        self.round_joins = 0;
        for &w in &targets {
            self.phase_s[w] = 0.0;
        }
        self.phase_nominal_s = 0.0;
        Ok(())
    }

    /// Execute this boundary's membership changes (DESIGN.md §10), in a
    /// deterministic order: wire rejoins first (late `Join` handshakes
    /// parked by the networked transport's accept loop), then
    /// plan-scheduled rejoins and spawns, then the autoscaler's decision.
    /// Every admitted worker is warm-started from the boundary's averaged
    /// `(x, A²)` via the ordinary `InstallState` catch-up and acks Ready
    /// before the next phase begins.
    fn membership_boundary(
        &mut self,
        t: u64,
        scale: Option<ScaleAction>,
        avg_x: &Arc<Vec<f32>>,
        avg_acc: &Option<Arc<Vec<f32>>>,
    ) -> Result<()> {
        for w in self.transport.poll_joins() {
            if self.alive[w] {
                // Stale or duplicate handshake for a live peer: ignore it
                // (the parked stream is dropped by the next admission).
                continue;
            }
            self.transport.admit_join(w)?;
            self.admit_worker(w, avg_x, avg_acc)?;
        }
        for w in 0..self.n() {
            if !self.alive[w]
                && !self.left[w]
                && !self.transport.peer_dead(w)
                && self.plan.readmit_step(w).is_some_and(|s| s <= t)
                && self.plan.alive(w, t + 1)
            {
                self.admit_worker(w, avg_x, avg_acc)?;
            }
        }
        match scale {
            Some(ScaleAction::Admit) => {
                let spare = self
                    .spares
                    .iter()
                    .copied()
                    .find(|&w| !self.alive[w] && !self.left[w] && !self.transport.peer_dead(w));
                if let Some(w) = spare {
                    self.admit_worker(w, avg_x, avg_acc)?;
                }
            }
            Some(ScaleAction::Drop) => {
                // Retire the slowest live worker — but never below the
                // participation floor the config promises.
                let floor = self.cfg.faults.quorum.max(1);
                let live = self.alive_ids();
                if live.len() > floor {
                    let slowest = live.into_iter().max_by(|&a, &b| {
                        self.phase_s[a]
                            .partial_cmp(&self.phase_s[b])
                            .expect("phase times are finite")
                            .then(a.cmp(&b))
                    });
                    if let Some(w) = slowest {
                        self.alive[w] = false;
                        self.left[w] = true;
                        self.round_leaves += 1;
                    }
                }
            }
            None => {}
        }
        Ok(())
    }

    /// Admit (or re-admit) worker `w` at a sync boundary: install the
    /// boundary's averaged state, wait for its Ready ack, and mark it
    /// live with a clean phase clock.
    fn admit_worker(
        &mut self,
        w: usize,
        avg_x: &Arc<Vec<f32>>,
        avg_acc: &Option<Arc<Vec<f32>>>,
    ) -> Result<()> {
        self.transport.send_to(
            w,
            Cmd::InstallState { x: Arc::clone(avg_x), acc: avg_acc.clone() },
        )?;
        self.wait_ready_from(&[w])?;
        self.alive[w] = true;
        self.left[w] = false;
        self.phase_s[w] = 0.0;
        self.round_joins += 1;
        Ok(())
    }

    /// Checkpoint file path from the config.
    fn checkpoint_path(&self) -> String {
        if self.cfg.train.checkpoint_path.is_empty() {
            format!("{}/checkpoint.bin", self.cfg.out_dir)
        } else {
            self.cfg.train.checkpoint_path.clone()
        }
    }

    /// Snapshot training state at iteration `t` (for local algorithms the
    /// config validation guarantees `t` is a sync boundary, so replicas
    /// agree and worker 0's state is THE state).
    fn save_checkpoint(&mut self, t: u64) -> Result<()> {
        let algo = self.cfg.optim.algorithm;
        let vectors = if algo.is_local() {
            // Raw snapshot: checkpoints are observer reads, not rounds —
            // they must carry exact f32 state even over a lossy wire.
            // Under `[faults]` only live workers are asked; `t` is a sync
            // boundary (validated), so every live replica holds the same
            // installed average and the lowest live id's state is THE
            // state.
            let states = if self.faults_on {
                let targets = self.alive_ids();
                if targets.is_empty() {
                    return Err(Error::Protocol(format!(
                        "all workers crashed before checkpoint at {t}"
                    )));
                }
                self.collect_states_from(&targets, true)?
            } else {
                self.collect_states(true)?
            };
            let (x0, acc0) = &states[0];
            let vectors = match algo {
                Algorithm::LocalAdaAlter => {
                    let acc = acc0
                        .clone()
                        .ok_or_else(|| Error::Protocol("missing accumulator".into()))?;
                    vec![x0.clone(), acc.clone(), acc]
                }
                _ => vec![x0.clone()],
            };
            self.recycle_states(states);
            vectors
        } else {
            let mut v = vec![self.x.clone()];
            v.extend(self.opt.as_ref().expect("sync opt").state_vectors());
            v
        };
        let ck = Checkpoint { step: t, algorithm: algo, vectors };
        ck.save(self.checkpoint_path())
    }

    /// Current consolidated model: leader's x for sync algorithms; the
    /// across-worker average x̄_t (the Theorem 2 sequence) for local ones.
    /// Observer-only — no wire traffic is booked (matches the paper, whose
    /// evaluation runs out-of-band).
    fn consolidated_x(&mut self) -> Result<Vec<f32>> {
        if !self.cfg.optim.algorithm.is_local() {
            return Ok(self.x.clone());
        }
        let states = if self.faults_on {
            let targets = self.alive_ids();
            if targets.is_empty() {
                return Err(Error::Protocol("all workers crashed".into()));
            }
            self.collect_states_from(&targets, true)?
        } else {
            self.collect_states(true)?
        };
        let xs: Vec<&[f32]> = states.iter().map(|(x, _)| x.as_slice()).collect();
        let mut out = vec![0.0f32; self.d];
        average_into(&xs, &mut out);
        self.recycle_states(states);
        Ok(out)
    }

    /// Mid-run evaluation at the consolidated model (on worker 0).
    fn evaluate(&mut self, _t: u64) -> Result<EvalMetrics> {
        let x = self.consolidated_x()?;
        self.eval_at(&x)
    }

    fn eval_at(&mut self, x: &[f32]) -> Result<EvalMetrics> {
        let x = Arc::new(x.to_vec());
        // Evaluation runs on the lowest-id live worker (worker 0 unless a
        // fault scenario killed it).
        let evaluator = self
            .alive
            .iter()
            .position(|&a| a)
            .ok_or_else(|| Error::Protocol("all workers crashed".into()))?;
        self.transport.send_to(evaluator, Cmd::Eval { x: Some(x) })?;
        match self.transport.recv()? {
            Reply::Eval { metrics, .. } => Ok(metrics),
            Reply::Err { worker, msg } => Err(worker_err(worker, msg)),
            _ => Err(Error::Protocol("unexpected reply during eval".into())),
        }
    }

    fn shutdown(&mut self) {
        self.transport.shutdown(|_| Cmd::Stop);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Backend, ExperimentConfig, SyncPeriod};
    use crate::sim::SyntheticProblem;

    fn config(algo: Algorithm, h: SyncPeriod, steps: u64) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.train.workers = 4;
        c.train.steps = steps;
        c.train.sync_period = if algo.is_local() { h } else { SyncPeriod::Every(1) };
        c.train.backend = Backend::RustMath;
        c.train.rust_math_dim = 64;
        c.optim.algorithm = algo;
        c.optim.warmup_steps = 10;
        c.optim.eta = 0.5;
        c
    }

    fn synthetic_factory(cfg: &ExperimentConfig) -> BackendFactory {
        let p = SyntheticProblem::new(cfg.train.rust_math_dim, cfg.train.workers, cfg.train.seed);
        Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>))
    }

    fn run(algo: Algorithm, h: SyncPeriod, steps: u64) -> RunResult {
        let mut cfg = config(algo, h, steps);
        if matches!(algo, Algorithm::Sgd | Algorithm::LocalSgd) {
            // plain SGD needs lr < 2/L = 0.2 on the synthetic problem
            cfg.optim.eta = 0.1;
        }
        let f = synthetic_factory(&cfg);
        Trainer::new(cfg, f).run().unwrap()
    }

    #[test]
    fn all_algorithms_converge_to_the_noniid_optimum() {
        // The non-IID problem has an irreducible global loss F(x*) > 0
        // (workers' centres disagree); convergence = small SUBoptimality.
        let cfg0 = config(Algorithm::AdaGrad, SyncPeriod::Every(1), 1);
        let p = SyntheticProblem::new(cfg0.train.rust_math_dim, cfg0.train.workers, cfg0.train.seed);
        use crate::coordinator::backend::WorkerBackend as _;
        let init_loss = p.global_loss(&p.backend(0).init_params().unwrap());
        let opt_loss = p.global_loss(&p.optimum());
        assert!(init_loss > opt_loss + 100.0, "problem too easy");

        for algo in [
            Algorithm::Sgd,
            Algorithm::AdaGrad,
            Algorithm::AdaAlter,
            Algorithm::LocalSgd,
            Algorithm::LocalAdaAlter,
        ] {
            let r = run(algo, SyncPeriod::Every(4), 400);
            let subopt = r.final_eval.unwrap().loss - opt_loss;
            assert!(r.final_x.iter().all(|v| v.is_finite()), "{algo}: non-finite params");
            assert!(subopt < 1.0, "{algo}: suboptimality {subopt} (opt {opt_loss})");
        }
    }

    #[test]
    fn local_adaalter_h1_equals_sync_adaalter() {
        // THE equivalence anchor (paper §4.3): with H = 1, Algorithm 4
        // degenerates to Algorithm 3 exactly (up to f32 associativity).
        let a = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(1), 40);
        let b = run(Algorithm::AdaAlter, SyncPeriod::Every(1), 40);
        let max = crate::util::math::max_abs_diff(&a.final_x, &b.final_x);
        assert!(max < 5e-4, "H=1 local vs sync AdaAlter diverged: {max}");
    }

    #[test]
    fn sync_counts_match_scheduler() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(5), 63);
        let (syncs, bytes) = r.recorder.comm();
        assert_eq!(syncs, 63 / 5);
        assert!(bytes > 0);
        let r_inf = run(Algorithm::LocalAdaAlter, SyncPeriod::Infinite, 63);
        assert_eq!(r_inf.recorder.comm(), (0, 0));
    }

    #[test]
    fn sync_events_trace_fixed_policy() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(5), 63);
        assert_eq!(r.recorder.sync_events.len() as u64, r.recorder.comm().0);
        assert!(r
            .recorder
            .sync_events
            .iter()
            .all(|e| e.gap == 5 && e.reason == "period" && e.bytes > 0));
        assert_eq!(r.recorder.sync_policy(), "fixed(H=5)");
        // Fully-synchronous algorithms communicate every step by
        // construction — no policy events are recorded for them.
        let s = run(Algorithm::AdaGrad, SyncPeriod::Every(1), 10);
        assert!(s.recorder.sync_events.is_empty());
        assert_eq!(s.recorder.sync_policy(), "fixed(H=1)");
    }

    #[test]
    fn growing_policy_cuts_rounds_and_still_converges() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 400);
        cfg.sync.policy = "growing".into();
        cfg.sync.h_max = 16;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let (rounds, _) = r.recorder.comm();
        assert!(rounds < 400 / 4, "growing kept all {rounds} rounds");
        assert_eq!(r.recorder.sync_events.len() as u64, rounds);
        let gaps = r.recorder.realized_h();
        assert!(gaps.windows(2).all(|w| w[1] >= w[0]), "non-monotone: {gaps:?}");
        assert!(gaps.iter().all(|&g| g <= 16), "cap violated: {gaps:?}");
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn drift_policy_respects_h_max_through_the_trainer() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 200);
        cfg.sync.policy = "drift".into();
        cfg.sync.drift_threshold = 0.5;
        cfg.sync.h_max = 8;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let events = &r.recorder.sync_events;
        assert!(!events.is_empty());
        assert!(events.iter().all(|e| e.gap >= 1 && e.gap <= 8));
        assert!(events
            .iter()
            .all(|e| e.reason == "drift" || e.reason == "h_max"));
        assert_eq!(events.len() as u64, r.recorder.comm().0);
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn time_budget_policy_holds_comm_fraction() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 200);
        cfg.sync.policy = "time_budget".into();
        cfg.sync.target_comm_fraction = 0.02;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let events = &r.recorder.sync_events;
        assert!(events.len() >= 2);
        // After the first observed round the policy re-derives H from the
        // cost model; at 4 workers / 2% target it grows past the H₀ = 4.
        assert!(
            events.last().unwrap().gap > events.first().unwrap().gap,
            "H did not adapt: {:?}",
            r.recorder.realized_h()
        );
        let frac = r.clock.total(Charge::Communication) / r.clock.now_s();
        assert!(frac < 0.05, "comm fraction {frac} over budget");
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn adaptive_resume_rejected() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 8);
        cfg.sync.policy = "growing".into();
        let f = synthetic_factory(&cfg);
        let d = cfg.train.rust_math_dim;
        let mut t = Trainer::new(cfg, f);
        t.resume = Some(crate::coordinator::Checkpoint {
            step: 4,
            algorithm: Algorithm::LocalAdaAlter,
            vectors: vec![vec![0.0; d], vec![1.0; d], vec![1.0; d]],
        });
        let err = t.run().err().expect("must fail");
        assert!(err.to_string().contains("fixed"), "{err}");
    }

    #[test]
    fn fully_sync_communicates_every_step() {
        let r = run(Algorithm::AdaGrad, SyncPeriod::Every(1), 25);
        assert_eq!(r.recorder.comm().0, 25);
    }

    #[test]
    fn runs_are_deterministic() {
        let a = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 60);
        let b = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 60);
        assert_eq!(a.final_x, b.final_x, "training is not deterministic");
        assert_eq!(
            a.final_eval.unwrap().loss.to_bits(),
            b.final_eval.unwrap().loss.to_bits()
        );
    }

    #[test]
    fn exec_layouts_are_bitwise_identical() {
        // The tentpole invariant in miniature (the full matrix lives in
        // rust/tests/integration_exec.rs): the default per-worker-host
        // layout, a serial host and a 2-thread pool produce the same
        // bits.
        let base = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 60);
        let default = {
            let f = synthetic_factory(&base);
            Trainer::new(base.clone(), f).run().unwrap()
        };
        let mut ser = base.clone();
        ser.exec.parallelism = "serial".into();
        let serial = {
            let f = synthetic_factory(&ser);
            Trainer::new(ser, f).run().unwrap()
        };
        let mut cfg = base.clone();
        cfg.exec.parallelism = "threads".into();
        cfg.exec.threads = 2;
        let threaded = {
            let f = synthetic_factory(&cfg);
            Trainer::new(cfg, f).run().unwrap()
        };
        assert_eq!(default.final_x, serial.final_x);
        assert_eq!(serial.final_x, threaded.final_x);
        assert_eq!(
            serial.final_eval.unwrap().loss.to_bits(),
            threaded.final_eval.unwrap().loss.to_bits()
        );
        // Unknown engine spellings are config errors, not panics.
        let mut bad = base;
        bad.exec.parallelism = "fibers".into();
        let f = synthetic_factory(&bad);
        let err = Trainer::new(bad, f).run().err().expect("must fail");
        assert!(err.to_string().contains("exec.parallelism"), "{err}");
    }

    #[test]
    fn virtual_clock_charges_components() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 40);
        assert!(r.clock.total(Charge::Compute) > 0.0);
        assert!(r.clock.total(Charge::Communication) > 0.0);
        // 4 workers: dataloader not binding in the paper calibration.
        assert_eq!(r.clock.total(Charge::DataLoad), 0.0);
        // comm < compute for H=4 (the whole point of the paper)
        assert!(r.clock.total(Charge::Communication) < r.clock.total(Charge::Compute));
    }

    #[test]
    fn transport_label_recorded() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 10);
        assert_eq!(r.recorder.transport(), "simulated(ps)");
    }

    #[test]
    fn single_worker_works() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 50);
        cfg.train.workers = 1;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn fault_free_runs_never_charge_straggler_time() {
        let r = run(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 40);
        assert_eq!(r.clock.total(Charge::Straggler), 0.0);
        assert!(r.recorder.fault_events.is_empty());
    }

    #[test]
    fn slow_worker_full_barrier_charges_closed_form_straggler_time() {
        // One 4×-slow worker of 4, H = 4, 40 steps, full barrier: every
        // round waits (f−1)·H·t_compute beyond nominal, so the total
        // straggler charge is steps · 3 · t_compute (dataloader not
        // binding at n = 4).
        let (steps, h, factor) = (40u64, 4u64, 4.0f64);
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), steps);
        cfg.faults.slow_workers = 1;
        cfg.faults.slow_factor = factor;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let calib = Calibration::paper_v100();
        let compute = calib.t_compute_s * (1.0 + calib.adaalter_compute_overhead);
        assert!(calib.dataload_s(4) < compute, "dataloader must not bind here");
        let want = steps as f64 * (factor - 1.0) * compute;
        let got = r.clock.total(Charge::Straggler);
        assert!(
            (got - want).abs() < 1e-9 * want,
            "straggler charge {got} != closed form {want}"
        );
        // One participation event per round, nobody dropped (full barrier).
        assert_eq!(r.recorder.fault_events.len() as u64, steps / h);
        assert!(r
            .recorder
            .fault_events
            .iter()
            .all(|e| e.alive == 4 && e.participants == 4 && e.dropped == 0 && e.wait_s > 0.0));
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn quorum_drops_the_slow_worker_and_eliminates_the_wait() {
        let (steps, h) = (40u64, 4u64);
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(h), steps);
        cfg.train.fused = false;
        cfg.faults.slow_workers = 1;
        cfg.faults.slow_factor = 4.0;
        cfg.faults.quorum = 3;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        // The three fast workers close every round at the nominal phase
        // time; the slow worker is dropped and the barrier never waits.
        assert_eq!(r.clock.total(Charge::Straggler), 0.0);
        assert_eq!(r.recorder.fault_events.len() as u64, steps / h);
        assert!(r
            .recorder
            .fault_events
            .iter()
            .all(|e| e.alive == 4 && e.participants == 3 && e.dropped == 1 && e.wait_s == 0.0));
        assert!(r.recorder.transport().starts_with("partial(q3"));
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn crashed_worker_is_excluded_and_training_continues() {
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 60);
        cfg.faults.crash_worker = 2;
        cfg.faults.crash_step = 9;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let events = &r.recorder.fault_events;
        assert_eq!(events.len(), 60 / 4);
        assert!(events.iter().take(2).all(|e| e.alive == 4), "pre-crash rounds");
        assert!(events.iter().skip(2).all(|e| e.alive == 3), "post-crash rounds");
        // Throughput accounting drops the dead worker: 8 steps × 4 live,
        // then 52 steps × 3 live.
        assert_eq!(r.recorder.samples(), 8 * 4 + 52 * 3);
        assert!(r.final_x.iter().all(|v| v.is_finite()));
        assert!(r.final_eval.unwrap().loss.is_finite());
    }

    #[test]
    fn fully_sync_fault_runs_log_per_iteration_events() {
        // AdaGrad barriers every step; with one 4×-slow worker of 4 each
        // iteration waits (f−1)·t_compute (no AdaAlter overhead, dataloader
        // not binding at n = 4), and each iteration logs one event.
        let (steps, factor) = (25u64, 4.0f64);
        let mut cfg = config(Algorithm::AdaGrad, SyncPeriod::Every(1), steps);
        cfg.faults.slow_workers = 1;
        cfg.faults.slow_factor = factor;
        let f = synthetic_factory(&cfg);
        let r = Trainer::new(cfg, f).run().unwrap();
        let calib = Calibration::paper_v100();
        let want = steps as f64 * (factor - 1.0) * calib.t_compute_s;
        let got = r.clock.total(Charge::Straggler);
        assert!(
            (got - want).abs() < 1e-9 * want,
            "straggler charge {got} != closed form {want}"
        );
        assert_eq!(r.recorder.fault_events.len() as u64, steps);
        assert!(r
            .recorder
            .fault_events
            .iter()
            .all(|e| e.alive == 4 && e.participants == 4 && e.dropped == 0 && e.wait_s > 0.0));
        assert_eq!(r.recorder.samples(), steps * 4);
        assert!(r.final_x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn trainer_rejects_bad_fault_configs_programmatically() {
        // quorum with the fused path on: field-named config error.
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 8);
        cfg.faults.quorum = 2;
        let f = synthetic_factory(&cfg);
        let err = Trainer::new(cfg, f).run().err().expect("must fail");
        assert!(err.to_string().contains("train.fused"), "{err}");

        // resume under the autoscaler: the one fault feature whose state
        // (patience counters) a checkpoint cannot reconstruct.
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 8);
        cfg.train.fused = false;
        cfg.faults.autoscale = true;
        let d = cfg.train.rust_math_dim;
        let f = synthetic_factory(&cfg);
        let mut t = Trainer::new(cfg, f);
        t.resume = Some(crate::coordinator::Checkpoint {
            step: 4,
            algorithm: Algorithm::LocalAdaAlter,
            vectors: vec![vec![0.0; d], vec![1.0; d], vec![1.0; d]],
        });
        let err = t.run().err().expect("must fail");
        assert!(err.to_string().contains("faults.autoscale"), "{err}");

        // ...but resume under a plain fault scenario is now supported: the
        // plan replays as a pure function of `(seed, worker, step)`.
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 8);
        cfg.faults.slow_workers = 1;
        let d = cfg.train.rust_math_dim;
        let f = synthetic_factory(&cfg);
        let mut t = Trainer::new(cfg, f);
        t.resume = Some(crate::coordinator::Checkpoint {
            step: 4,
            algorithm: Algorithm::LocalAdaAlter,
            vectors: vec![vec![0.0; d], vec![1.0; d], vec![1.0; d]],
        });
        t.run().expect("resume under [faults] must run");

        // plan/worker-count mismatch.
        let cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 8);
        let f = synthetic_factory(&cfg);
        let mut t = Trainer::new(cfg, f);
        t.fault_plan = Some(crate::sim::FaultPlan::none(2).with_slow(0, 2.0));
        let err = t.run().err().expect("must fail");
        assert!(err.to_string().contains("covers 2 workers"), "{err}");

        // Out-of-range crash worker in a programmatic config must error,
        // not silently compile to an empty (fault-free) plan.
        let mut cfg = config(Algorithm::LocalAdaAlter, SyncPeriod::Every(4), 8);
        cfg.faults.crash_worker = 7; // workers = 4
        cfg.faults.crash_step = 2;
        let f = synthetic_factory(&cfg);
        let err = Trainer::new(cfg, f).run().err().expect("must fail");
        assert!(err.to_string().contains("faults.crash_worker"), "{err}");
    }
}
