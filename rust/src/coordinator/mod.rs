//! The Layer-3 coordinator — the paper's system contribution.
//!
//! * [`sync`] — the synchronization subsystem: the fixed-H scheduler
//!   arithmetic (Alg. 4 lines 4/8) plus the pluggable [`SyncPolicy`]
//!   family deciding *when* to synchronize (DESIGN.md §5).
//! * [`schedule`] — warm-up learning rates (§6.2.1) and batch scaling.
//! * [`aggregate`] — gradient / parameter / denominator averaging.
//! * [`backend`] — the gradient-backend abstraction workers run on.
//! * [`worker`] — worker-cell protocol and execution bodies.
//! * [`executor`] — the execution engine: worker→thread layout
//!   (`[exec]`), bitwise-invariant across layouts (DESIGN.md §7).
//! * [`trainer`] — the leader: spawning, barriers, sync rounds, metrics.

pub mod aggregate;
pub mod backend;
pub mod checkpoint;
pub mod executor;
pub mod factory;
pub mod schedule;
pub mod sync;
pub mod trainer;
pub mod worker;

pub use checkpoint::Checkpoint;
pub use backend::{BackendFactory, EvalMetrics, WorkerBackend};
pub use executor::{Executor, Parallelism};
pub use schedule::{scale_lr, ScalingRule, WarmupSchedule};
pub use sync::{
    build_policy, DriftTriggered, FixedPeriod, GrowingPeriod, StepObservation, SyncObservation,
    SyncPolicy, SyncReason, SyncScheduler, TimeBudget,
};
pub use trainer::{RunResult, Trainer};
