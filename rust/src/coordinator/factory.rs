//! Backend-factory construction from an experiment config — the one place
//! that knows about both compute backends.

use std::sync::Arc;

use crate::config::{Backend, ExperimentConfig};
use crate::coordinator::backend::BackendFactory;
use crate::error::Result;
use crate::runtime::PjrtBackend;
use crate::sim::SyntheticProblem;

/// Build the per-worker backend factory named by the config.
pub fn make_factory(cfg: &ExperimentConfig) -> Result<BackendFactory> {
    match cfg.train.backend {
        Backend::RustMath => {
            let p = SyntheticProblem::new(
                cfg.train.rust_math_dim,
                cfg.train.workers,
                cfg.train.seed,
            );
            Ok(Arc::new(move |w| Ok(Box::new(p.backend(w)) as Box<_>)))
        }
        Backend::Pjrt => {
            let artifacts = cfg.artifacts_dir.clone();
            let preset = cfg.train.preset.clone();
            let workers = cfg.train.workers;
            let data = cfg.data.clone();
            let seed = cfg.train.seed;
            Ok(Arc::new(move |w| {
                Ok(Box::new(PjrtBackend::new(&artifacts, &preset, w, workers, &data, seed)?)
                    as Box<_>)
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn rust_math_factory_builds_workers() {
        let cfg = ExperimentConfig::default();
        let f = make_factory(&cfg).unwrap();
        let b0 = f(0).unwrap();
        let b1 = f(1).unwrap();
        assert_eq!(b0.dim(), cfg.train.rust_math_dim);
        assert_eq!(b1.dim(), b0.dim());
    }

    #[test]
    fn pjrt_factory_fails_cleanly_without_artifacts() {
        let mut cfg = ExperimentConfig::default();
        cfg.train.backend = Backend::Pjrt;
        cfg.artifacts_dir = "/nonexistent".into();
        let f = make_factory(&cfg).unwrap();
        let err = f(0).err().expect("should fail").to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
