//! Gradient-backend abstraction: how a worker turns (x, step) into a loss
//! and gradient.
//!
//! Two implementations:
//! * [`crate::sim::synthetic::SyntheticBackend`] — pure-rust non-IID
//!   least-squares (tests / comm benches, no artifacts needed);
//! * [`crate::runtime::backend::PjrtBackend`] — the real LM through the
//!   AOT-compiled HLO artifacts.
//!
//! Backends are constructed *inside* each worker thread (the PJRT client
//! is `Rc`-based and not `Send`), so the trainer receives a
//! [`BackendFactory`] rather than backends.

use crate::error::Result;

/// Held-out evaluation result.
#[derive(Clone, Copy, Debug)]
pub struct EvalMetrics {
    /// Mean held-out loss (per-token NLL for the LM backend).
    pub loss: f64,
    /// Perplexity `exp(sum_nll / tokens)` — the paper's §6.2 metric
    /// (LM backend only).
    pub ppl: Option<f64>,
}

/// Per-worker gradient computation.
pub trait WorkerBackend {
    /// Model dimension d.
    fn dim(&self) -> usize;

    /// Compute the local stochastic loss and gradient at `x` for global
    /// iteration `step`, writing the gradient into `out` (len d).
    /// Deterministic in (worker identity, step).
    fn loss_and_grad(&mut self, x: &[f32], step: u64, out: &mut [f32]) -> Result<f32>;

    /// Evaluate on the held-out set.
    fn eval(&mut self, x: &[f32]) -> Result<EvalMetrics>;

    /// Optional fused local-AdaAlter step (Alg. 4 lines 5–7 in one device
    /// dispatch): update `x` and `acc` in place given the synchronized
    /// denominator `b2_sync` and placeholder summand `denom_add = t'·ε²`.
    /// Returns `Ok(None)` when unsupported — the trainer then composes
    /// `loss_and_grad` with the rust-side update instead.
    fn fused_local_adaalter(
        &mut self,
        _x: &mut [f32],
        _b2_sync: &[f32],
        _acc: &mut [f32],
        _denom_add: f32,
        _lr: f32,
        _step: u64,
    ) -> Result<Option<f32>> {
        Ok(None)
    }

    /// Initial parameters (the PJRT backend loads the artifact init so all
    /// workers and the paper's warm-start agree; synthetic returns zeros).
    fn init_params(&self) -> Result<Vec<f32>>;
}

/// Thread-safe constructor: `factory(worker_id)` runs on the worker thread.
pub type BackendFactory =
    std::sync::Arc<dyn Fn(usize) -> Result<Box<dyn WorkerBackend>> + Send + Sync>;
