//! The execution engine (DESIGN.md §7): how worker computation is mapped
//! onto OS threads, selected by the `[exec]` config section and
//! bitwise-invariant across every layout.
//!
//! Two facilities:
//!
//! * [`spawn_worker_hosts`] — the trainer's persistent worker pool: the n
//!   protocol workers are partitioned round-robin across `k` host threads
//!   (`parallelism = "threads"`; the default `threads = 0` gives one host
//!   per worker — the thread shape every run had before the engine
//!   existed), or all placed on one host (`"serial"`, the reference
//!   layout). Hosts live for the whole run and serve the lockstep
//!   command protocol ([`crate::coordinator::worker`]).
//! * [`Executor`] — a fixed-order parallel-for over per-worker state for
//!   code that holds the state in hand (benches, the counting-allocator
//!   test, offline sweeps): `for_each`/`map` run `f(w, &mut state[w])`
//!   for every worker, serially in worker order or fanned out over a
//!   scoped thread pool, with results always delivered in worker order.
//!
//! Determinism argument (the tentpole invariant, pinned by
//! `rust/tests/integration_exec.rs`): every worker's gradient, RNG and
//! fault stream is a pure function of `(seed, worker, step)`, so cells
//! compute identical values wherever they are hosted; and every
//! leader-side reduction (`gather` slots by worker id, the averaging
//! kernels run in worker order) is **fixed-order**, so f32 sums are
//! performed in the same order regardless of reply arrival order. Thread
//! placement therefore cannot change a single bit of the training
//! trajectory.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::comm::ChannelTransport;
use crate::config::ExecConfig;
use crate::coordinator::backend::BackendFactory;
use crate::coordinator::worker::{host_loop, Cmd, Reply, WorkerSpec};
use crate::error::{Error, Result};

/// How worker computation maps onto OS threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// All workers execute on one host thread, in worker order — the
    /// reference order every other layout must match bitwise.
    Serial,
    /// Workers are spread round-robin across this many host threads
    /// (0 = one thread per worker; `Threads(0)` is the default layout).
    Threads(usize),
}

impl Parallelism {
    /// Parse the `[exec]` section (`parallelism = "serial" | "threads" |
    /// "threads(k)"`, with the separate `threads` key supplying k for the
    /// bare `"threads"` spelling).
    pub fn from_config(cfg: &ExecConfig) -> Result<Parallelism> {
        let s = cfg.parallelism.trim();
        if s == "serial" {
            return Ok(Parallelism::Serial);
        }
        if s == "threads" {
            return Ok(Parallelism::Threads(cfg.threads));
        }
        if let Some(inner) = s.strip_prefix("threads(").and_then(|r| r.strip_suffix(')')) {
            let k: usize = inner.trim().parse().map_err(|_| {
                Error::Config(format!("exec.parallelism: bad thread count in {s:?}"))
            })?;
            return Ok(Parallelism::Threads(k));
        }
        Err(Error::Config(format!(
            "exec.parallelism must be \"serial\", \"threads\" or \"threads(k)\", got {s:?}"
        )))
    }

    /// Number of host threads used for `n` workers.
    pub fn hosts(self, n: usize) -> usize {
        match self {
            Parallelism::Serial => 1,
            Parallelism::Threads(0) => n.max(1),
            Parallelism::Threads(k) => k.min(n).max(1),
        }
    }

    /// Human-readable label (metrics / bench tables).
    pub fn label(self) -> String {
        match self {
            Parallelism::Serial => "serial".into(),
            Parallelism::Threads(0) => "threads(n)".into(),
            Parallelism::Threads(k) => format!("threads({k})"),
        }
    }
}

/// Spawn the persistent worker pool for a training run: `specs[w]` becomes
/// worker `w`, hosted on thread `w mod hosts`. Returns the lockstep
/// transport addressing every worker by id (the leader cannot tell the
/// layouts apart). `reply_rx` must be the receive side of `reply_tx`.
pub fn spawn_worker_hosts(
    par: Parallelism,
    specs: Vec<WorkerSpec>,
    factory: BackendFactory,
    reply_tx: Sender<Reply>,
    reply_rx: Receiver<Reply>,
) -> Result<ChannelTransport<Cmd, Reply>> {
    let n = specs.len();
    let hosts = par.hosts(n);
    // Partition specs round-robin by worker id.
    let mut per_host: Vec<Vec<WorkerSpec>> = (0..hosts).map(|_| Vec::new()).collect();
    for spec in specs {
        per_host[spec.worker % hosts].push(spec);
    }
    let mut host_txs_unique: Vec<Sender<(usize, Cmd)>> = Vec::with_capacity(hosts);
    let mut joins = Vec::with_capacity(hosts);
    for (h, host_specs) in per_host.into_iter().enumerate() {
        let (cmd_tx, cmd_rx) = channel::<(usize, Cmd)>();
        let factory = std::sync::Arc::clone(&factory);
        let rtx = reply_tx.clone();
        let join = std::thread::Builder::new()
            .name(format!("adaalter-host-{h}"))
            .spawn(move || host_loop(host_specs, factory, cmd_rx, rtx))
            .map_err(Error::Io)?;
        host_txs_unique.push(cmd_tx);
        joins.push(join);
    }
    drop(reply_tx);
    let host_txs: Vec<Sender<(usize, Cmd)>> =
        (0..n).map(|w| host_txs_unique[w % hosts].clone()).collect();
    drop(host_txs_unique);
    Ok(ChannelTransport::from_hosts(host_txs, reply_rx, joins))
}

/// A fixed-order parallel-for over per-worker state, for callers that hold
/// the state in hand (benches, tests, offline sweeps). The trainer's
/// persistent pool is [`spawn_worker_hosts`]; this is the scoped fan-out
/// primitive sharing the same determinism contract.
#[derive(Clone, Copy, Debug)]
pub struct Executor {
    par: Parallelism,
}

impl Executor {
    /// Engine with the given thread layout.
    pub fn new(par: Parallelism) -> Self {
        Executor { par }
    }

    /// Serial reference engine.
    pub fn serial() -> Self {
        Executor { par: Parallelism::Serial }
    }

    /// Scoped pool of `k` threads (0 = one per item).
    pub fn threads(k: usize) -> Self {
        Executor { par: Parallelism::Threads(k) }
    }

    /// The configured layout.
    pub fn parallelism(&self) -> Parallelism {
        self.par
    }

    /// Host threads the engine would use for `n` items (1 collapses to
    /// the serial loop).
    fn fan_width(&self, n: usize) -> usize {
        match self.par {
            Parallelism::Serial => 1,
            Parallelism::Threads(_) => self.par.hosts(n),
        }
    }

    /// Run `f(w, &mut states[w])` for every `w`. The serial layout runs
    /// in worker order on the caller thread (and allocates nothing); the
    /// threaded layout fans contiguous state chunks out over a scoped
    /// pool. Either way `f` sees each state exactly once and results land
    /// nowhere — use [`Executor::map`] to collect outputs.
    pub fn for_each<S: Send>(&self, states: &mut [S], f: impl Fn(usize, &mut S) + Sync) {
        let hosts = self.fan_width(states.len());
        if hosts <= 1 || states.len() <= 1 {
            for (w, s) in states.iter_mut().enumerate() {
                f(w, s);
            }
            return;
        }
        let chunk = states.len().div_ceil(hosts);
        std::thread::scope(|scope| {
            for (c, block) in states.chunks_mut(chunk).enumerate() {
                let f = &f;
                let _ = scope.spawn(move || {
                    for (i, s) in block.iter_mut().enumerate() {
                        f(c * chunk + i, s);
                    }
                });
            }
        });
    }

    /// [`Executor::for_each`] collecting `f`'s output per worker into
    /// `out` (which must be `states.len()` long) — **fixed-order**: slot
    /// `w` always holds worker `w`'s result, whatever thread computed it,
    /// so downstream reductions are bitwise-stable.
    pub fn map<S: Send, T: Send>(
        &self,
        states: &mut [S],
        out: &mut [Option<T>],
        f: impl Fn(usize, &mut S) -> T + Sync,
    ) {
        assert_eq!(states.len(), out.len(), "Executor::map: out length mismatch");
        let hosts = self.fan_width(states.len());
        if hosts <= 1 || states.len() <= 1 {
            for (w, (s, o)) in states.iter_mut().zip(out.iter_mut()).enumerate() {
                *o = Some(f(w, s));
            }
            return;
        }
        let chunk = states.len().div_ceil(hosts);
        std::thread::scope(|scope| {
            for (c, (block, oblock)) in
                states.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate()
            {
                let f = &f;
                let _ = scope.spawn(move || {
                    for (i, (s, o)) in block.iter_mut().zip(oblock.iter_mut()).enumerate() {
                        *o = Some(f(c * chunk + i, s));
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_parses_all_spellings() {
        // The default: one host per worker (the pre-engine thread shape).
        let mut e = ExecConfig::default();
        assert_eq!(Parallelism::from_config(&e).unwrap(), Parallelism::Threads(0));
        e.parallelism = "serial".into();
        assert_eq!(Parallelism::from_config(&e).unwrap(), Parallelism::Serial);
        e.parallelism = "threads".into();
        e.threads = 4;
        assert_eq!(Parallelism::from_config(&e).unwrap(), Parallelism::Threads(4));
        e.parallelism = "threads(8)".into();
        assert_eq!(Parallelism::from_config(&e).unwrap(), Parallelism::Threads(8));
        e.parallelism = "gpu".into();
        assert!(Parallelism::from_config(&e).is_err());
        e.parallelism = "threads(x)".into();
        assert!(Parallelism::from_config(&e).is_err());
    }

    #[test]
    fn host_counts() {
        assert_eq!(Parallelism::Serial.hosts(8), 1);
        assert_eq!(Parallelism::Threads(0).hosts(8), 8);
        assert_eq!(Parallelism::Threads(3).hosts(8), 3);
        assert_eq!(Parallelism::Threads(16).hosts(8), 8);
        assert_eq!(Parallelism::Threads(2).hosts(0), 1);
        assert_eq!(Parallelism::Serial.label(), "serial");
        assert_eq!(Parallelism::Threads(0).label(), "threads(n)");
        assert_eq!(Parallelism::Threads(4).label(), "threads(4)");
    }

    #[test]
    fn executors_agree_bitwise_and_keep_order() {
        // Per-worker pseudo-computation whose result depends on the worker
        // id and its mutable state; every layout must produce identical
        // outputs in identical slots.
        let runs: Vec<Vec<Option<f64>>> = [
            Executor::serial(),
            Executor::threads(2),
            Executor::threads(3),
            Executor::threads(0),
            Executor::threads(64),
        ]
        .iter()
        .map(|ex| {
            let mut states: Vec<f64> = (0..7).map(|w| w as f64 * 0.25).collect();
            let mut out: Vec<Option<f64>> = vec![None; 7];
            for _ in 0..3 {
                ex.map(&mut states, &mut out, |w, s| {
                    *s = (*s + w as f64).sin();
                    *s * 2.0
                });
            }
            out
        })
        .collect();
        for other in &runs[1..] {
            assert_eq!(&runs[0], other);
        }
        for (w, o) in runs[0].iter().enumerate() {
            assert!(o.is_some(), "slot {w} empty");
        }
    }

    #[test]
    fn for_each_touches_every_state_once() {
        for ex in [Executor::serial(), Executor::threads(2), Executor::threads(5)] {
            let mut counts = vec![0u32; 9];
            ex.for_each(&mut counts, |_, c| *c += 1);
            assert!(counts.iter().all(|&c| c == 1), "{counts:?}");
        }
    }

    #[test]
    #[should_panic(expected = "out length mismatch")]
    fn map_rejects_mismatched_out() {
        let mut s = [0u8; 3];
        let mut out: Vec<Option<u8>> = vec![None; 2];
        Executor::serial().map(&mut s, &mut out, |_, v| *v);
    }

    #[test]
    fn properties_pipelined_completion_is_a_stage_preserving_permutation() {
        // The `[comm] pipeline` hazard model: shard i's internal stages
        // (gather → reduce → encode) must run in order, while distinct
        // shards may interleave and complete in any order. Pinned by
        // logging every (shard, stage) event across layouts and checking
        // (a) the completion sequence is a permutation of 0..k and (b)
        // each shard's own events appear in stage order — FIFO per shard,
        // free interleave across shards.
        use crate::util::prop;
        use std::sync::Mutex;
        const STAGES: usize = 3;
        prop::check("pipelined shard events: per-shard FIFO, global permutation", 30, |g| {
            let k = 1 + g.usize_in(0..12);
            let threads = 1 + g.usize_in(0..5);
            let log: Mutex<Vec<(usize, usize)>> = Mutex::new(Vec::new());
            let mut shards: Vec<usize> = (0..k).collect();
            Executor::threads(threads).for_each(&mut shards, |_, s| {
                for stage in 0..STAGES {
                    log.lock().unwrap().push((*s, stage));
                    // Jitter the interleave so schedules actually differ.
                    if (*s + stage) % 2 == 0 {
                        std::thread::yield_now();
                    }
                }
            });
            let events = log.into_inner().unwrap();
            prop::assert_that(events.len() == k * STAGES, "every stage logged once")?;
            // (a) completion order (each shard's final stage) is a
            // permutation of 0..k.
            let mut done: Vec<usize> =
                events.iter().filter(|(_, st)| *st == STAGES - 1).map(|(s, _)| *s).collect();
            done.sort_unstable();
            prop::assert_that(done == (0..k).collect::<Vec<_>>(), "completions form 0..k")?;
            // (b) per-shard internal order is preserved.
            for s in 0..k {
                let stages: Vec<usize> =
                    events.iter().filter(|(sh, _)| *sh == s).map(|(_, st)| *st).collect();
                prop::assert_that(
                    stages == (0..STAGES).collect::<Vec<_>>(),
                    format!("shard {s} stages out of order: {stages:?}"),
                )?;
            }
            Ok(())
        });
    }
}
