//! Checkpointing: durable save/restore of training state.
//!
//! A framework a team would deploy resumes 50-epoch runs after preemption.
//! Format (little-endian, single file, self-validating):
//!
//! ```text
//!   magic  "ADACKPT1"                    8 bytes
//!   step   u64                           global iteration t
//!   algo   u8 (Algorithm discriminant)   protocol family check on resume
//!   nvec   u8                            how many f32[d] sections follow
//!   d      u64
//!   <nvec sections of d f32 each>        x, then optional B²/A²/velocity
//!   crc    u32 (FNV-1a folded)           integrity of everything above
//! ```
//!
//! Sections by algorithm: SGD → [x]; momentum → [x, m]; AdaGrad/AdaAlter →
//! [x, B²]; Local AdaAlter → [x, B²_sync, A²] (a worker-consistent snapshot
//! is taken at a synchronization boundary, where all replicas agree).

use std::io::{Read, Write};
use std::path::Path;

use crate::config::Algorithm;
use crate::error::{Error, Result};

const MAGIC: &[u8; 8] = b"ADACKPT1";

/// In-memory training snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Global iteration the snapshot was taken after.
    pub step: u64,
    /// Algorithm the snapshot belongs to (resume must match).
    pub algorithm: Algorithm,
    /// State vectors, algorithm-dependent (see module docs). All length d.
    pub vectors: Vec<Vec<f32>>,
}

fn algo_tag(a: Algorithm) -> u8 {
    match a {
        Algorithm::Sgd => 0,
        Algorithm::LocalSgd => 1,
        Algorithm::AdaGrad => 2,
        Algorithm::AdaAlter => 3,
        Algorithm::LocalAdaAlter => 4,
    }
}

fn algo_from_tag(t: u8) -> Result<Algorithm> {
    Ok(match t {
        0 => Algorithm::Sgd,
        1 => Algorithm::LocalSgd,
        2 => Algorithm::AdaGrad,
        3 => Algorithm::AdaAlter,
        4 => Algorithm::LocalAdaAlter,
        other => return Err(Error::Data(format!("unknown algorithm tag {other}"))),
    })
}

/// Streaming FNV-1a over bytes (checkpoint integrity; not cryptographic).
#[derive(Clone)]
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }

    fn fold32(&self) -> u32 {
        (self.0 ^ (self.0 >> 32)) as u32
    }
}

impl Checkpoint {
    /// Number of state vectors the format expects for `algo`.
    pub fn expected_vectors(algo: Algorithm) -> usize {
        match algo {
            Algorithm::Sgd | Algorithm::LocalSgd => 1,
            Algorithm::AdaGrad | Algorithm::AdaAlter => 2,
            Algorithm::LocalAdaAlter => 3,
        }
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.vectors.is_empty() {
            return Err(Error::Data("checkpoint has no state vectors".into()));
        }
        let d = self.vectors[0].len();
        if self.vectors.iter().any(|v| v.len() != d) {
            return Err(Error::Data("checkpoint vectors have mixed lengths".into()));
        }
        if self.vectors.len() != Self::expected_vectors(self.algorithm) {
            return Err(Error::Data(format!(
                "{} expects {} vectors, checkpoint has {}",
                self.algorithm,
                Self::expected_vectors(self.algorithm),
                self.vectors.len()
            )));
        }
        Ok(())
    }

    /// Serialise to a file (atomic: write tmp + rename).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        self.validate()?;
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            let mut crc = Fnv::new();
            let put = |f: &mut dyn Write, crc: &mut Fnv, bytes: &[u8]| -> Result<()> {
                crc.update(bytes);
                f.write_all(bytes)?;
                Ok(())
            };
            put(&mut f, &mut crc, MAGIC)?;
            put(&mut f, &mut crc, &self.step.to_le_bytes())?;
            put(&mut f, &mut crc, &[algo_tag(self.algorithm)])?;
            put(&mut f, &mut crc, &[self.vectors.len() as u8])?;
            let d = self.vectors[0].len() as u64;
            put(&mut f, &mut crc, &d.to_le_bytes())?;
            for v in &self.vectors {
                // Bulk-cast the f32 slice; little-endian hosts only (checked
                // implicitly by the round-trip tests).
                let bytes = unsafe {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                };
                put(&mut f, &mut crc, bytes)?;
            }
            f.write_all(&crc.fold32().to_le_bytes())?;
            f.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load and verify a checkpoint file.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path.as_ref())?);
        let mut crc = Fnv::new();
        let take = |f: &mut dyn Read, crc: &mut Fnv, n: usize| -> Result<Vec<u8>> {
            let mut buf = vec![0u8; n];
            f.read_exact(&mut buf)
                .map_err(|e| Error::Data(format!("truncated checkpoint: {e}")))?;
            crc.update(&buf);
            Ok(buf)
        };
        let magic = take(&mut f, &mut crc, 8)?;
        if magic != MAGIC {
            return Err(Error::Data("not an adaalter checkpoint (bad magic)".into()));
        }
        let step = u64::from_le_bytes(take(&mut f, &mut crc, 8)?.try_into().unwrap());
        let algorithm = algo_from_tag(take(&mut f, &mut crc, 1)?[0])?;
        let nvec = take(&mut f, &mut crc, 1)?[0] as usize;
        let d = u64::from_le_bytes(take(&mut f, &mut crc, 8)?.try_into().unwrap()) as usize;
        if nvec == 0 || nvec > 8 || d == 0 {
            return Err(Error::Data(format!("implausible checkpoint header: nvec={nvec} d={d}")));
        }
        let mut vectors = Vec::with_capacity(nvec);
        for _ in 0..nvec {
            let bytes = take(&mut f, &mut crc, d * 4)?;
            let mut v = Vec::with_capacity(d);
            for c in bytes.chunks_exact(4) {
                v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            vectors.push(v);
        }
        let mut tail = [0u8; 4];
        f.read_exact(&mut tail)
            .map_err(|e| Error::Data(format!("missing checkpoint crc: {e}")))?;
        let want = u32::from_le_bytes(tail);
        if want != crc.fold32() {
            return Err(Error::Data("checkpoint crc mismatch (corrupted file)".into()));
        }
        let ck = Checkpoint { step, algorithm, vectors };
        ck.validate()?;
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("adaalter_ckpt_{}_{name}", std::process::id()))
    }

    fn sample(algo: Algorithm, d: usize) -> Checkpoint {
        let mut rng = Rng::new(9);
        let vectors = (0..Checkpoint::expected_vectors(algo))
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        Checkpoint { step: 12345, algorithm: algo, vectors }
    }

    #[test]
    fn round_trip_every_algorithm() {
        for algo in [
            Algorithm::Sgd,
            Algorithm::LocalSgd,
            Algorithm::AdaGrad,
            Algorithm::AdaAlter,
            Algorithm::LocalAdaAlter,
        ] {
            let path = tmp(algo.name());
            let ck = sample(algo, 1000);
            ck.save(&path).unwrap();
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(ck, back, "{algo}");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn corruption_detected() {
        let path = tmp("corrupt");
        sample(Algorithm::LocalAdaAlter, 256).save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncation_detected() {
        let path = tmp("trunc");
        sample(Algorithm::AdaGrad, 256).save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 100]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let path = tmp("magic");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn vector_arity_enforced() {
        let mut ck = sample(Algorithm::LocalAdaAlter, 64);
        ck.vectors.pop();
        assert!(ck.validate().is_err());
        let mut mixed = sample(Algorithm::AdaGrad, 64);
        mixed.vectors[1].pop();
        assert!(mixed.validate().is_err());
    }

    #[test]
    fn save_is_atomic_no_tmp_left() {
        let path = tmp("atomic");
        sample(Algorithm::Sgd, 64).save(&path).unwrap();
        assert!(path.exists());
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_file(&path).ok();
    }
}
