//! Synchronization scheduling — when does the cluster communicate?
//!
//! Two layers (DESIGN.md §5):
//!
//! * [`SyncScheduler`] — the pure fixed-H arithmetic of the paper
//!   (Alg. 4 line 8: `mod(t, H) == 0`, the local-step index
//!   `t' = mod(t−1, H) + 1` of line 4, and the `2/H` traffic accounting
//!   the benches report).
//! * [`SyncPolicy`] — the pluggable per-iteration *decision*: the trainer
//!   asks the policy whether iteration `t` ends with a synchronization
//!   ([`SyncPolicy::decide`]) and, after every executed round, feeds back a
//!   [`SyncObservation`] (modeled round time, straggler spread, measured
//!   replica drift, virtual-clock state) assembled from the collective
//!   layer's [`crate::comm::CommReport`]. Policies:
//!
//!   | config name   | type                | schedule                                  |
//!   |---------------|---------------------|-------------------------------------------|
//!   | `fixed`       | [`FixedPeriod`]     | the paper's `mod(t, H)` — default          |
//!   | `growing`     | [`GrowingPeriod`]   | H grows by a factor on a round schedule    |
//!   | `drift`       | [`DriftTriggered`]  | sync when accumulated drift ≥ threshold    |
//!   | `time_budget` | [`TimeBudget`]      | pick H to hit a target comm-time fraction  |
//!
//! [`FixedPeriod`] delegates to [`SyncScheduler`], so `policy = "fixed"`
//! is bitwise-identical to the pre-policy trainer (pinned by
//! `rust/tests/integration_sync_policy.rs`).
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the xla rpath link flags on
//! # // this image (libstdc++ from /opt/xla_extension), so compile-only.
//! use adaalter::config::SyncPeriod;
//! use adaalter::coordinator::sync::{FixedPeriod, StepObservation, SyncPolicy, SyncScheduler};
//!
//! // The fixed policy reproduces the paper's mod(t, H) == 0 schedule.
//! let mut policy = FixedPeriod::new(SyncPeriod::Every(4));
//! let sched = SyncScheduler::new(SyncPeriod::Every(4));
//! for t in 1..=12 {
//!     let step = StepObservation { t, update_sq: 0.0 };
//!     assert_eq!(policy.decide(&step).is_some(), sched.is_sync_step(t));
//! }
//! // H = 4 ships 2 vectors every 4th step: the paper's 2/H = 50% traffic.
//! assert_eq!(sched.comm_fraction(true), 0.5);
//! ```

use std::fmt;

use crate::config::{ExperimentConfig, SyncPeriod};
use crate::error::{Error, Result};

/// Pure-function scheduler over 1-based global iterations `t ∈ [1, T]`.
#[derive(Clone, Copy, Debug)]
pub struct SyncScheduler {
    period: SyncPeriod,
}

impl SyncScheduler {
    /// Scheduler for period H (or ∞ = never synchronize).
    pub fn new(period: SyncPeriod) -> Self {
        SyncScheduler { period }
    }

    /// The configured period.
    pub fn period(&self) -> SyncPeriod {
        self.period
    }

    /// Does iteration `t` (1-based) end with a synchronization?
    pub fn is_sync_step(&self, t: u64) -> bool {
        assert!(t >= 1, "iterations are 1-based");
        match self.period {
            SyncPeriod::Every(h) => t % h == 0,
            SyncPeriod::Infinite => false,
        }
    }

    /// Local-step index `t' = mod(t−1, H) + 1 ∈ [1, H]` (Alg. 4 line 4).
    /// For H = ∞ this simply counts steps since start.
    pub fn t_prime(&self, t: u64) -> u64 {
        assert!(t >= 1, "iterations are 1-based");
        match self.period {
            SyncPeriod::Every(h) => (t - 1) % h + 1,
            SyncPeriod::Infinite => t,
        }
    }

    /// Number of synchronization rounds in iterations `1..=t`.
    pub fn syncs_up_to(&self, t: u64) -> u64 {
        match self.period {
            SyncPeriod::Every(h) => t / h,
            SyncPeriod::Infinite => 0,
        }
    }

    /// Vectors shipped per worker per sync for the given algorithm family:
    /// 2 when the denominator synchronizes (local AdaAlter), 1 otherwise.
    pub fn vectors_per_sync(denominator_synced: bool) -> u64 {
        if denominator_synced {
            2
        } else {
            1
        }
    }

    /// Average per-iteration communication relative to fully-synchronous
    /// AdaGrad (1 vector per iteration): the paper's `2/H` (or `1/H`) claim.
    pub fn comm_fraction(&self, denominator_synced: bool) -> f64 {
        match self.period {
            SyncPeriod::Every(h) => {
                Self::vectors_per_sync(denominator_synced) as f64 / h as f64
            }
            SyncPeriod::Infinite => 0.0,
        }
    }

    /// Total vectors shipped per worker over iterations `1..=t` — the
    /// quantity the trainer's recorded traffic must be proportional to
    /// (integration tests pin recorded bytes against this).
    pub fn vectors_up_to(&self, t: u64, denominator_synced: bool) -> u64 {
        self.syncs_up_to(t) * Self::vectors_per_sync(denominator_synced)
    }
}

// ---------------------------------------------------------------------------
// The policy subsystem: per-iteration sync decisions from observations.
// ---------------------------------------------------------------------------

/// Why a policy triggered a synchronization round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncReason {
    /// The scheduled period elapsed (fixed / growing / time-budget H).
    Period,
    /// Accumulated local-update drift crossed the configured threshold.
    Drift,
    /// The hard `sync.h_max` cap forced a round before any trigger fired.
    HMax,
    /// A time-budget recomputation chose this round boundary.
    Budget,
}

impl SyncReason {
    /// Stable spelling used in metrics CSVs and bench tables.
    pub fn as_str(self) -> &'static str {
        match self {
            SyncReason::Period => "period",
            SyncReason::Drift => "drift",
            SyncReason::HMax => "h_max",
            SyncReason::Budget => "budget",
        }
    }
}

impl fmt::Display for SyncReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What a policy sees *every* iteration, before deciding whether to sync.
#[derive(Clone, Copy, Debug)]
pub struct StepObservation {
    /// The 1-based global iteration that just computed its local step.
    pub t: u64,
    /// Mean over workers of the squared L2 norm of this iteration's local
    /// parameter update `‖Δx‖²` — the per-step drift proxy (the sum of
    /// these over a period upper-bounds replica divergence, the quantity
    /// CADA-style triggers threshold). 0 when unavailable: on the fused
    /// device path, and on the local-SGD path unless the policy requested
    /// it — policies declare [`SyncPolicy::needs_update_norms`], which
    /// disables fusion and enables collection.
    pub update_sq: f64,
}

/// What a policy sees *after each executed synchronization round* —
/// assembled by the trainer from the collective layer's
/// [`crate::comm::CommReport`] and the virtual clock.
#[derive(Clone, Copy, Debug)]
pub struct SyncObservation {
    /// Iteration at which the round ran.
    pub t: u64,
    /// Why the policy triggered it.
    pub reason: SyncReason,
    /// Total sync rounds so far, including this one.
    pub rounds: u64,
    /// Exact bytes this round shipped cluster-wide.
    pub round_bytes: u64,
    /// Modeled wall time of this round, seconds.
    pub round_time_s: f64,
    /// Modeled spread between the first and last worker finishing the
    /// round (PS incast serialisation; 0 for ring all-reduce).
    pub straggler_s: f64,
    /// Measured mean squared L2 distance of worker replicas from their
    /// average at this round — the *realized* drift the paper's Theorem 2
    /// bounds.
    pub drift_sq: f64,
    /// Virtual-clock time after booking the round, seconds.
    pub virtual_now_s: f64,
    /// Cumulative virtual time attributed to communication, seconds.
    pub total_comm_s: f64,
}

/// A synchronization policy: decides, once per global iteration (called
/// in order, `t = start+1, start+2, …`), whether the iteration ends with
/// a sync round, and learns from each executed round's observation.
///
/// Contract: the trainer calls [`SyncPolicy::decide`] exactly once per
/// iteration; whenever it returns `Some(reason)`, a sync round runs and
/// [`SyncPolicy::observe`] is called with that round's observation before
/// the next `decide`.
pub trait SyncPolicy: Send {
    /// Human-readable label for metrics and bench tables,
    /// e.g. `"fixed(H=4)"` or `"drift(θ=2, H≤32)"`.
    fn label(&self) -> String;

    /// Does iteration `step.t` end with a synchronization round?
    fn decide(&mut self, step: &StepObservation) -> Option<SyncReason>;

    /// Feed back what the round the last `decide` triggered cost/observed.
    fn observe(&mut self, _obs: &SyncObservation) {}

    /// The policy's current effective period, when it has one (drift
    /// triggering has none — only the `h_max` cap).
    fn period_hint(&self) -> Option<u64> {
        None
    }

    /// Does the policy consume [`StepObservation::update_sq`]? When true
    /// the trainer disables the fused device step so the per-step update
    /// norm is measurable.
    fn needs_update_norms(&self) -> bool {
        false
    }
}

/// The paper's schedule: sync iff `mod(t, H) == 0`. Delegates to
/// [`SyncScheduler`], so it is bitwise-identical to the pre-policy
/// trainer. The default.
#[derive(Clone, Copy, Debug)]
pub struct FixedPeriod {
    sched: SyncScheduler,
}

impl FixedPeriod {
    /// Fixed period H (or ∞ = never synchronize).
    pub fn new(period: SyncPeriod) -> Self {
        FixedPeriod { sched: SyncScheduler::new(period) }
    }

    /// The underlying pure scheduler (benches share its accounting).
    pub fn scheduler(&self) -> SyncScheduler {
        self.sched
    }
}

impl SyncPolicy for FixedPeriod {
    fn label(&self) -> String {
        format!("fixed(H={})", self.sched.period())
    }

    fn decide(&mut self, step: &StepObservation) -> Option<SyncReason> {
        if self.sched.is_sync_step(step.t) {
            Some(SyncReason::Period)
        } else {
            None
        }
    }

    fn period_hint(&self) -> Option<u64> {
        self.sched.period().period()
    }
}

/// Stich-style growing period: start at H₀ and multiply H by
/// `sync.grow_factor` after every `sync.grow_every` sync rounds, capped
/// at `sync.h_max`. Motivated by Local SGD analyses: early training needs
/// tight coupling, stabilized training tolerates long local phases.
#[derive(Clone, Copy, Debug)]
pub struct GrowingPeriod {
    h0: u64,
    h: u64,
    factor: f64,
    every: u64,
    h_max: u64,
    since_sync: u64,
    rounds_at_h: u64,
}

impl GrowingPeriod {
    /// Start at `h0`, multiply by `factor` every `every` rounds, cap at
    /// `h_max`. Callers must guarantee `h0 ≥ 1`, `factor > 1`,
    /// `every ≥ 1`, `h_max ≥ h0` (config validation does).
    pub fn new(h0: u64, factor: f64, every: u64, h_max: u64) -> Self {
        GrowingPeriod { h0, h: h0, factor, every, h_max, since_sync: 0, rounds_at_h: 0 }
    }
}

impl SyncPolicy for GrowingPeriod {
    fn label(&self) -> String {
        format!(
            "growing(H₀={}, ×{} / {} rounds, H≤{})",
            self.h0, self.factor, self.every, self.h_max
        )
    }

    fn decide(&mut self, _step: &StepObservation) -> Option<SyncReason> {
        self.since_sync += 1;
        if self.since_sync >= self.h {
            Some(SyncReason::Period)
        } else {
            None
        }
    }

    fn observe(&mut self, _obs: &SyncObservation) {
        self.since_sync = 0;
        self.rounds_at_h += 1;
        if self.rounds_at_h >= self.every {
            self.rounds_at_h = 0;
            let grown = (self.h as f64 * self.factor).round() as u64;
            self.h = grown.max(self.h + 1);
            if self.h > self.h_max {
                self.h = self.h_max;
            }
        }
    }

    fn period_hint(&self) -> Option<u64> {
        Some(self.h)
    }
}

/// CADA-style drift trigger: accumulate the per-step update-norm proxy
/// `Σ ‖Δx‖²` and synchronize when it crosses `sync.drift_threshold` —
/// with a hard `sync.h_max` cap so a vanishing-gradient phase cannot
/// starve communication forever.
#[derive(Clone, Copy, Debug)]
pub struct DriftTriggered {
    threshold: f64,
    h_max: u64,
    since_sync: u64,
    accumulated: f64,
}

impl DriftTriggered {
    /// Trigger at accumulated proxy ≥ `threshold`, force a round after
    /// `h_max` local steps regardless.
    pub fn new(threshold: f64, h_max: u64) -> Self {
        DriftTriggered { threshold, h_max, since_sync: 0, accumulated: 0.0 }
    }

    /// Accumulated drift proxy since the last round (for diagnostics).
    pub fn accumulated(&self) -> f64 {
        self.accumulated
    }
}

impl SyncPolicy for DriftTriggered {
    fn label(&self) -> String {
        format!("drift(θ={}, H≤{})", self.threshold, self.h_max)
    }

    fn decide(&mut self, step: &StepObservation) -> Option<SyncReason> {
        self.since_sync += 1;
        self.accumulated += step.update_sq;
        if self.accumulated >= self.threshold {
            Some(SyncReason::Drift)
        } else if self.since_sync >= self.h_max {
            Some(SyncReason::HMax)
        } else {
            None
        }
    }

    fn observe(&mut self, _obs: &SyncObservation) {
        self.since_sync = 0;
        self.accumulated = 0.0;
    }

    fn needs_update_norms(&self) -> bool {
        true
    }
}

/// Pick H to hit a target communication fraction of modeled wall-clock:
/// with per-round comm time `t_round` and per-iteration compute time
/// `t_iter`, the comm share is `f = t_round / (t_round + H·t_iter)`, so
/// the policy sets `H = t_round·(1−f) / (f·t_iter)` after every round,
/// estimating `t_iter` from the virtual clock's non-communication charge.
/// Starts at H₀ until the first round is observed; clamped to
/// `[1, sync.h_max]`.
#[derive(Clone, Copy, Debug)]
pub struct TimeBudget {
    h: u64,
    target: f64,
    h_max: u64,
    since_sync: u64,
}

impl TimeBudget {
    /// Target comm fraction `target ∈ (0, 1)`; `h0` until first
    /// observation; cap `h_max`.
    pub fn new(h0: u64, target: f64, h_max: u64) -> Self {
        TimeBudget { h: h0, target, h_max, since_sync: 0 }
    }
}

impl SyncPolicy for TimeBudget {
    fn label(&self) -> String {
        format!("time_budget(f={}, H≤{})", self.target, self.h_max)
    }

    fn decide(&mut self, _step: &StepObservation) -> Option<SyncReason> {
        self.since_sync += 1;
        if self.since_sync >= self.h {
            Some(SyncReason::Budget)
        } else {
            None
        }
    }

    fn observe(&mut self, obs: &SyncObservation) {
        self.since_sync = 0;
        // Compute/dataload time per iteration, from the clock's
        // non-communication charge over the iterations completed so far
        // (the current iteration's compute is charged after the round, so
        // divide by t − 1; at t = 1 there is nothing to estimate from).
        let iters = obs.t.saturating_sub(1);
        let non_comm_s = obs.virtual_now_s - obs.total_comm_s;
        if iters == 0 || non_comm_s <= 0.0 || obs.round_time_s <= 0.0 {
            return;
        }
        let t_iter = non_comm_s / iters as f64;
        let want = obs.round_time_s * (1.0 - self.target) / (self.target * t_iter);
        self.h = (want.ceil() as u64).clamp(1, self.h_max);
    }

    fn period_hint(&self) -> Option<u64> {
        Some(self.h)
    }
}

// ---------------------------------------------------------------------------
// Autoscaling: telemetry-driven membership decisions (DESIGN.md §10).
// ---------------------------------------------------------------------------

/// A membership action the autoscaler asks the trainer to take at the
/// next sync-round boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    /// Admit one queued spare worker (warm-started via `InstallState`).
    Admit,
    /// Retire the slowest live worker (billed as a voluntary leave).
    Drop,
}

/// CADA-style elastic-membership policy (`[faults] autoscale`): consumes
/// the same per-round [`SyncObservation`] telemetry the sync policies do
/// and votes on membership instead of on the period. Deterministic — a
/// pure function of the observation stream, so two runs with identical
/// plans make identical scaling decisions.
///
/// Rules (evaluated once per executed sync round):
///
/// * straggler spread above `faults.autoscale_straggler_s` for
///   `faults.autoscale_patience` consecutive rounds → [`ScaleAction::Drop`]
///   (shed the persistent straggler; the trainer guards quorum).
/// * healthy rounds (spread under the threshold) with realized drift at or
///   above `faults.autoscale_drift` for `patience` consecutive rounds →
///   [`ScaleAction::Admit`] (more replicas to average down the variance,
///   if a spare is queued).
///
/// Both counters reset after an action fires, so decisions are paced at
/// least `patience` rounds apart.
#[derive(Clone, Copy, Debug)]
pub struct AutoscalePolicy {
    drift: f64,
    straggler_s: f64,
    patience: u64,
    healthy: u64,
    congested: u64,
}

impl AutoscalePolicy {
    /// Thresholds straight from the `[faults]` config keys; `patience ≥ 1`
    /// (config validation guarantees it).
    pub fn new(drift: f64, straggler_s: f64, patience: u64) -> Self {
        AutoscalePolicy { drift, straggler_s, patience, healthy: 0, congested: 0 }
    }

    /// Feed one executed round's telemetry; returns the action to take at
    /// this boundary, if any.
    pub fn observe(&mut self, obs: &SyncObservation) -> Option<ScaleAction> {
        if obs.straggler_s > self.straggler_s {
            self.congested += 1;
            self.healthy = 0;
        } else {
            self.congested = 0;
            if obs.drift_sq >= self.drift {
                self.healthy += 1;
            } else {
                self.healthy = 0;
            }
        }
        if self.congested >= self.patience {
            self.congested = 0;
            self.healthy = 0;
            return Some(ScaleAction::Drop);
        }
        if self.healthy >= self.patience {
            self.congested = 0;
            self.healthy = 0;
            return Some(ScaleAction::Admit);
        }
        None
    }
}

/// Build the policy the `[sync]` config section asks for (re-validating,
/// so programmatically-built configs hit the same rules TOML loads do).
/// Fully-synchronous algorithms always get `FixedPeriod(1)` — they
/// communicate every iteration by definition.
pub fn build_policy(cfg: &ExperimentConfig) -> Result<Box<dyn SyncPolicy>> {
    cfg.sync.validate()?;
    if !cfg.optim.algorithm.is_local() {
        return Ok(Box::new(FixedPeriod::new(SyncPeriod::Every(1))));
    }
    let s = &cfg.sync;
    let h0 = || -> Result<u64> {
        let h = cfg.train.sync_period.period().ok_or_else(|| {
            Error::Config(format!(
                "sync.policy = {:?} needs a finite train.sync_period as its initial H",
                s.policy
            ))
        })?;
        if h > s.h_max {
            return Err(Error::Config(format!(
                "train.sync_period ({h}) exceeds sync.h_max ({})",
                s.h_max
            )));
        }
        Ok(h)
    };
    match s.policy.as_str() {
        "fixed" => Ok(Box::new(FixedPeriod::new(cfg.train.sync_period))),
        "growing" => Ok(Box::new(GrowingPeriod::new(h0()?, s.grow_factor, s.grow_every, s.h_max))),
        "drift" => Ok(Box::new(DriftTriggered::new(s.drift_threshold, s.h_max))),
        "time_budget" => Ok(Box::new(TimeBudget::new(h0()?, s.target_comm_fraction, s.h_max))),
        other => Err(Error::Config(format!(
            "unknown sync.policy {other:?} (expected fixed, growing, drift or time_budget)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn h4_schedule_walkthrough() {
        let s = SyncScheduler::new(SyncPeriod::Every(4));
        let expect: [(u64, u64, bool); 8] = [
            (1, 1, false),
            (2, 2, false),
            (3, 3, false),
            (4, 4, true),
            (5, 1, false),
            (6, 2, false),
            (7, 3, false),
            (8, 4, true),
        ];
        for (t, tp, sync) in expect {
            assert_eq!(s.t_prime(t), tp, "t={t}");
            assert_eq!(s.is_sync_step(t), sync, "t={t}");
        }
        assert_eq!(s.syncs_up_to(8), 2);
        assert_eq!(s.syncs_up_to(7), 1);
    }

    #[test]
    fn h1_syncs_every_step() {
        let s = SyncScheduler::new(SyncPeriod::Every(1));
        for t in 1..=10 {
            assert!(s.is_sync_step(t));
            assert_eq!(s.t_prime(t), 1);
        }
        assert_eq!(s.syncs_up_to(10), 10);
    }

    #[test]
    fn infinite_never_syncs() {
        let s = SyncScheduler::new(SyncPeriod::Infinite);
        for t in 1..=100 {
            assert!(!s.is_sync_step(t));
            assert_eq!(s.t_prime(t), t);
        }
        assert_eq!(s.syncs_up_to(100), 0);
        assert_eq!(s.comm_fraction(true), 0.0);
    }

    #[test]
    fn vectors_up_to_counts_rounds_times_width() {
        let s = SyncScheduler::new(SyncPeriod::Every(4));
        assert_eq!(s.vectors_up_to(16, true), 8); // 4 rounds × 2 vectors
        assert_eq!(s.vectors_up_to(16, false), 4);
        assert_eq!(s.vectors_up_to(3, true), 0);
        let inf = SyncScheduler::new(SyncPeriod::Infinite);
        assert_eq!(inf.vectors_up_to(1000, true), 0);
    }

    #[test]
    fn comm_fraction_matches_paper() {
        // Paper §4.3: local AdaAlter reduces communication to 2/H.
        let s = SyncScheduler::new(SyncPeriod::Every(4));
        assert!((s.comm_fraction(true) - 0.5).abs() < 1e-12);
        assert!((s.comm_fraction(false) - 0.25).abs() < 1e-12);
        let s16 = SyncScheduler::new(SyncPeriod::Every(16));
        assert!((s16.comm_fraction(true) - 2.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn properties_hold_for_random_h() {
        prop::check("sync scheduler invariants", 200, |g| {
            let h = g.u64_in(1..64);
            let t = g.u64_in(1..10_000);
            let s = SyncScheduler::new(SyncPeriod::Every(h));
            let tp = s.t_prime(t);
            prop::assert_that((1..=h).contains(&tp), format!("t'={tp} outside [1,{h}]"))?;
            // sync exactly when t' == H
            prop::assert_that(
                s.is_sync_step(t) == (tp == h),
                format!("sync/t' disagree at t={t}, H={h}"),
            )?;
            // exactly floor(T/H) syncs in [1, T]
            let count = (1..=t).filter(|&u| s.is_sync_step(u)).count() as u64;
            prop::assert_that(
                count == t / h && count == s.syncs_up_to(t),
                format!("sync count {count} != {}", t / h),
            )
        });
    }

    #[test]
    #[should_panic(expected = "1-based")]
    fn zero_iteration_rejected() {
        SyncScheduler::new(SyncPeriod::Every(4)).t_prime(0);
    }

    // -- policy subsystem ---------------------------------------------------

    /// Dummy observation for driving policies outside the trainer.
    fn obs(t: u64, reason: SyncReason, rounds: u64) -> SyncObservation {
        SyncObservation {
            t,
            reason,
            rounds,
            round_bytes: 0,
            round_time_s: 0.0,
            straggler_s: 0.0,
            drift_sq: 0.0,
            virtual_now_s: 0.0,
            total_comm_s: 0.0,
        }
    }

    /// Drive a policy for `steps` iterations with a constant per-step
    /// update proxy; return the gaps between consecutive sync rounds.
    fn gaps(policy: &mut dyn SyncPolicy, steps: u64, update_sq: f64) -> Vec<u64> {
        let mut out = Vec::new();
        let mut last = 0u64;
        let mut rounds = 0u64;
        for t in 1..=steps {
            let step = StepObservation { t, update_sq };
            if let Some(reason) = policy.decide(&step) {
                rounds += 1;
                out.push(t - last);
                last = t;
                policy.observe(&obs(t, reason, rounds));
            }
        }
        out
    }

    #[test]
    fn fixed_policy_matches_mod_arithmetic() {
        // The ISSUE's pin: FixedPeriod == the old mod(t, H) for
        // H ∈ {1, 4, 16, ∞}.
        for period in [
            SyncPeriod::Every(1),
            SyncPeriod::Every(4),
            SyncPeriod::Every(16),
            SyncPeriod::Infinite,
        ] {
            let mut p = FixedPeriod::new(period);
            let s = SyncScheduler::new(period);
            let mut rounds = 0u64;
            for t in 1..=512 {
                let got = p.decide(&StepObservation { t, update_sq: 9.9 });
                assert_eq!(got.is_some(), s.is_sync_step(t), "{period}: t={t}");
                if let Some(r) = got {
                    assert_eq!(r, SyncReason::Period);
                    rounds += 1;
                    p.observe(&obs(t, r, rounds));
                }
            }
            assert_eq!(rounds, s.syncs_up_to(512), "{period}");
        }
    }

    #[test]
    fn fixed_policy_matches_scheduler_for_random_h() {
        prop::check("fixed policy == scheduler", 100, |g| {
            let h = g.u64_in(1..64);
            let steps = g.u64_in(1..500);
            let mut p = FixedPeriod::new(SyncPeriod::Every(h));
            let s = SyncScheduler::new(SyncPeriod::Every(h));
            for t in 1..=steps {
                let got = p.decide(&StepObservation { t, update_sq: 0.0 }).is_some();
                prop::assert_that(
                    got == s.is_sync_step(t),
                    format!("H={h}: policy and scheduler disagree at t={t}"),
                )?;
                if got {
                    p.observe(&obs(t, SyncReason::Period, 1));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn growing_period_doubles_on_schedule() {
        // H₀ = 4, ×2 every 2 rounds, capped at 16:
        // gaps 4, 4, 8, 8, 16, 16, 16, …
        let mut p = GrowingPeriod::new(4, 2.0, 2, 16);
        let g = gaps(&mut p, 200, 0.0);
        assert_eq!(&g[..6], &[4, 4, 8, 8, 16, 16]);
        assert!(g[6..].iter().all(|&x| x == 16), "cap violated: {g:?}");
        assert_eq!(p.period_hint(), Some(16));
    }

    #[test]
    fn growing_period_fractional_factor_still_grows() {
        // factor 1.1 rounds H=1 to 1; the max(h+1) guard must still grow.
        let mut p = GrowingPeriod::new(1, 1.1, 1, 8);
        let g = gaps(&mut p, 64, 0.0);
        assert_eq!(&g[..4], &[1, 2, 3, 4], "{g:?}");
    }

    #[test]
    fn drift_triggers_at_threshold() {
        // Constant proxy 1.0, threshold 4: sync every 4th step, reason
        // Drift (threshold reached exactly at the 4th accumulation).
        let mut p = DriftTriggered::new(4.0, 64);
        let mut reasons = Vec::new();
        let mut rounds = 0;
        for t in 1..=12 {
            if let Some(r) = p.decide(&StepObservation { t, update_sq: 1.0 }) {
                rounds += 1;
                reasons.push((t, r));
                p.observe(&obs(t, r, rounds));
            }
        }
        assert_eq!(
            reasons,
            vec![
                (4, SyncReason::Drift),
                (8, SyncReason::Drift),
                (12, SyncReason::Drift)
            ]
        );
        assert!(p.needs_update_norms());
        assert_eq!(p.period_hint(), None);
    }

    #[test]
    fn drift_respects_h_max_for_random_streams() {
        prop::check("drift gap <= h_max", 100, |g| {
            let h_max = g.u64_in(1..32);
            let threshold = g.f64_in(0.1..100.0);
            let mut p = DriftTriggered::new(threshold, h_max);
            let mut last = 0u64;
            let mut rounds = 0u64;
            for t in 1..=400u64 {
                let upd = g.f64_in(0.0..2.0);
                if let Some(r) = p.decide(&StepObservation { t, update_sq: upd }) {
                    rounds += 1;
                    prop::assert_that(
                        t - last <= h_max,
                        format!("gap {} > h_max {h_max} at t={t}", t - last),
                    )?;
                    // The cap reason only fires at exactly the cap.
                    if r == SyncReason::HMax {
                        prop::assert_that(
                            t - last == h_max,
                            format!("HMax at gap {} != {h_max}", t - last),
                        )?;
                    }
                    last = t;
                    p.observe(&obs(t, r, rounds));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn drift_quiet_stream_falls_back_to_h_max() {
        // No drift at all: every gap is exactly h_max, reason HMax.
        let mut p = DriftTriggered::new(1.0, 8);
        let g = gaps(&mut p, 64, 0.0);
        assert_eq!(g, vec![8; 8]);
    }

    #[test]
    fn time_budget_solves_for_target_fraction() {
        // t_round = 0.07 s, t_iter = 0.2333… s, target f = 0.05:
        // H = 0.07·0.95/(0.05·t_iter) ≈ 5.7 → ceil 6.
        let mut p = TimeBudget::new(4, 0.05, 64);
        assert_eq!(p.period_hint(), Some(4));
        let mut o = obs(4, SyncReason::Budget, 1);
        o.round_time_s = 0.07;
        o.virtual_now_s = 0.77; // 0.7 non-comm over 3 completed iterations
        o.total_comm_s = 0.07;
        p.observe(&o);
        let t_iter = (0.77 - 0.07) / 3.0;
        let want = (0.07 * 0.95 / (0.05 * t_iter)).ceil() as u64;
        assert_eq!(p.period_hint(), Some(want));
        // And the next gap uses the new H.
        let g = gaps(&mut p, want + 1, 0.0);
        assert_eq!(g, vec![want]);
    }

    #[test]
    fn time_budget_clamps_to_h_max_and_one() {
        let mut p = TimeBudget::new(4, 0.5, 8);
        // Enormous round cost → unclamped H would explode; cap at 8.
        let mut o = obs(4, SyncReason::Budget, 1);
        o.round_time_s = 1e6;
        o.virtual_now_s = 1e6 + 0.3;
        o.total_comm_s = 1e6;
        p.observe(&o);
        assert_eq!(p.period_hint(), Some(8));
        // Tiny round cost → H floors at 1.
        let mut o = obs(4, SyncReason::Budget, 2);
        o.round_time_s = 1e-9;
        o.virtual_now_s = 0.3;
        o.total_comm_s = 0.0;
        p.observe(&o);
        assert_eq!(p.period_hint(), Some(1));
    }

    #[test]
    fn autoscale_drops_persistent_stragglers_and_admits_when_healthy() {
        // spread threshold 0.05 s, drift threshold 1.0, patience 2.
        let mut p = AutoscalePolicy::new(1.0, 0.05, 2);
        let mut o = obs(4, SyncReason::Period, 1);
        // Two congested rounds in a row → Drop, counters reset.
        o.straggler_s = 0.2;
        assert_eq!(p.observe(&o), None);
        assert_eq!(p.observe(&o), Some(ScaleAction::Drop));
        assert_eq!(p.observe(&o), None, "counters must reset after an action");
        // Healthy + drifty rounds → Admit after `patience` rounds.
        o.straggler_s = 0.0;
        o.drift_sq = 3.0;
        assert_eq!(p.observe(&o), None);
        assert_eq!(p.observe(&o), Some(ScaleAction::Admit));
        // A congested round resets the healthy streak.
        assert_eq!(p.observe(&o), None);
        o.straggler_s = 0.2;
        assert_eq!(p.observe(&o), None);
        o.straggler_s = 0.0;
        assert_eq!(p.observe(&o), None, "healthy streak restarted");
        assert_eq!(p.observe(&o), Some(ScaleAction::Admit));
        // Healthy but low-drift rounds trigger nothing, ever.
        o.drift_sq = 0.0;
        for _ in 0..16 {
            assert_eq!(p.observe(&o), None);
        }
    }

    #[test]
    fn autoscale_is_deterministic_over_replayed_telemetry() {
        prop::check("autoscale replays identically", 50, |g| {
            let patience = g.u64_in(1..4);
            let thr = g.f64_in(0.01..0.2);
            let stream: Vec<(f64, f64)> =
                (0..40).map(|_| (g.f64_in(0.0..0.3), g.f64_in(0.0..2.0))).collect();
            let run = |stream: &[(f64, f64)]| -> Vec<Option<ScaleAction>> {
                let mut p = AutoscalePolicy::new(1.0, thr, patience);
                stream
                    .iter()
                    .map(|&(sp, dr)| {
                        let mut o = obs(1, SyncReason::Period, 1);
                        o.straggler_s = sp;
                        o.drift_sq = dr;
                        p.observe(&o)
                    })
                    .collect()
            };
            prop::assert_that(run(&stream) == run(&stream), "replay diverged")
        });
    }

    #[test]
    fn build_policy_dispatches_on_config() {
        use crate::config::ExperimentConfig;
        let mut cfg = ExperimentConfig::default();
        assert!(build_policy(&cfg).unwrap().label().starts_with("fixed(H=4"));
        cfg.sync.policy = "growing".into();
        assert!(build_policy(&cfg).unwrap().label().starts_with("growing"));
        cfg.sync.policy = "drift".into();
        let p = build_policy(&cfg).unwrap();
        assert!(p.label().starts_with("drift"));
        assert!(p.needs_update_norms());
        cfg.sync.policy = "time_budget".into();
        assert!(build_policy(&cfg).unwrap().label().starts_with("time_budget"));
        cfg.sync.policy = "oracle".into();
        assert!(build_policy(&cfg).is_err());
        // Non-local algorithms always get the every-step fixed policy.
        let mut sync_cfg = ExperimentConfig::default();
        sync_cfg.optim.algorithm = crate::config::Algorithm::AdaGrad;
        assert_eq!(build_policy(&sync_cfg).unwrap().label(), "fixed(H=1)");
        // Adaptive initial H needs a finite sync_period.
        let mut inf = ExperimentConfig::default();
        inf.train.sync_period = SyncPeriod::Infinite;
        inf.sync.policy = "growing".into();
        assert!(build_policy(&inf).is_err());
        // …within the h_max cap, even for programmatically-built configs
        // that never pass through ExperimentConfig::validate.
        let mut cap = ExperimentConfig::default();
        cap.train.sync_period = SyncPeriod::Every(128); // default h_max = 64
        cap.sync.policy = "growing".into();
        let err = build_policy(&cap).unwrap_err();
        assert!(err.to_string().contains("h_max"), "{err}");
    }
}
