//! Synchronization scheduler — decides, per global iteration, whether the
//! cluster communicates (Alg. 4 line 8: `mod(t, H) == 0`) and tracks the
//! local-step index `t' = mod(t−1, H) + 1` (line 4) that scales the
//! placeholder denominator.
//!
//! Also accounts communication rounds/bytes so benches can report the
//! paper's `2/H` reduction factor directly.

use crate::config::SyncPeriod;

/// Pure-function scheduler over 1-based global iterations `t ∈ [1, T]`.
#[derive(Clone, Copy, Debug)]
pub struct SyncScheduler {
    period: SyncPeriod,
}

impl SyncScheduler {
    /// Scheduler for period H (or ∞ = never synchronize).
    pub fn new(period: SyncPeriod) -> Self {
        SyncScheduler { period }
    }

    /// The configured period.
    pub fn period(&self) -> SyncPeriod {
        self.period
    }

    /// Does iteration `t` (1-based) end with a synchronization?
    pub fn is_sync_step(&self, t: u64) -> bool {
        assert!(t >= 1, "iterations are 1-based");
        match self.period {
            SyncPeriod::Every(h) => t % h == 0,
            SyncPeriod::Infinite => false,
        }
    }

    /// Local-step index `t' = mod(t−1, H) + 1 ∈ [1, H]` (Alg. 4 line 4).
    /// For H = ∞ this simply counts steps since start.
    pub fn t_prime(&self, t: u64) -> u64 {
        assert!(t >= 1, "iterations are 1-based");
        match self.period {
            SyncPeriod::Every(h) => (t - 1) % h + 1,
            SyncPeriod::Infinite => t,
        }
    }

    /// Number of synchronization rounds in iterations `1..=t`.
    pub fn syncs_up_to(&self, t: u64) -> u64 {
        match self.period {
            SyncPeriod::Every(h) => t / h,
            SyncPeriod::Infinite => 0,
        }
    }

    /// Vectors shipped per worker per sync for the given algorithm family:
    /// 2 when the denominator synchronizes (local AdaAlter), 1 otherwise.
    pub fn vectors_per_sync(denominator_synced: bool) -> u64 {
        if denominator_synced {
            2
        } else {
            1
        }
    }

    /// Average per-iteration communication relative to fully-synchronous
    /// AdaGrad (1 vector per iteration): the paper's `2/H` (or `1/H`) claim.
    pub fn comm_fraction(&self, denominator_synced: bool) -> f64 {
        match self.period {
            SyncPeriod::Every(h) => {
                Self::vectors_per_sync(denominator_synced) as f64 / h as f64
            }
            SyncPeriod::Infinite => 0.0,
        }
    }

    /// Total vectors shipped per worker over iterations `1..=t` — the
    /// quantity the trainer's recorded traffic must be proportional to
    /// (integration tests pin recorded bytes against this).
    pub fn vectors_up_to(&self, t: u64, denominator_synced: bool) -> u64 {
        self.syncs_up_to(t) * Self::vectors_per_sync(denominator_synced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn h4_schedule_walkthrough() {
        let s = SyncScheduler::new(SyncPeriod::Every(4));
        let expect: [(u64, u64, bool); 8] = [
            (1, 1, false),
            (2, 2, false),
            (3, 3, false),
            (4, 4, true),
            (5, 1, false),
            (6, 2, false),
            (7, 3, false),
            (8, 4, true),
        ];
        for (t, tp, sync) in expect {
            assert_eq!(s.t_prime(t), tp, "t={t}");
            assert_eq!(s.is_sync_step(t), sync, "t={t}");
        }
        assert_eq!(s.syncs_up_to(8), 2);
        assert_eq!(s.syncs_up_to(7), 1);
    }

    #[test]
    fn h1_syncs_every_step() {
        let s = SyncScheduler::new(SyncPeriod::Every(1));
        for t in 1..=10 {
            assert!(s.is_sync_step(t));
            assert_eq!(s.t_prime(t), 1);
        }
        assert_eq!(s.syncs_up_to(10), 10);
    }

    #[test]
    fn infinite_never_syncs() {
        let s = SyncScheduler::new(SyncPeriod::Infinite);
        for t in 1..=100 {
            assert!(!s.is_sync_step(t));
            assert_eq!(s.t_prime(t), t);
        }
        assert_eq!(s.syncs_up_to(100), 0);
        assert_eq!(s.comm_fraction(true), 0.0);
    }

    #[test]
    fn vectors_up_to_counts_rounds_times_width() {
        let s = SyncScheduler::new(SyncPeriod::Every(4));
        assert_eq!(s.vectors_up_to(16, true), 8); // 4 rounds × 2 vectors
        assert_eq!(s.vectors_up_to(16, false), 4);
        assert_eq!(s.vectors_up_to(3, true), 0);
        let inf = SyncScheduler::new(SyncPeriod::Infinite);
        assert_eq!(inf.vectors_up_to(1000, true), 0);
    }

    #[test]
    fn comm_fraction_matches_paper() {
        // Paper §4.3: local AdaAlter reduces communication to 2/H.
        let s = SyncScheduler::new(SyncPeriod::Every(4));
        assert!((s.comm_fraction(true) - 0.5).abs() < 1e-12);
        assert!((s.comm_fraction(false) - 0.25).abs() < 1e-12);
        let s16 = SyncScheduler::new(SyncPeriod::Every(16));
        assert!((s16.comm_fraction(true) - 2.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn properties_hold_for_random_h() {
        prop::check("sync scheduler invariants", 200, |g| {
            let h = g.u64_in(1..64);
            let t = g.u64_in(1..10_000);
            let s = SyncScheduler::new(SyncPeriod::Every(h));
            let tp = s.t_prime(t);
            prop::assert_that((1..=h).contains(&tp), format!("t'={tp} outside [1,{h}]"))?;
            // sync exactly when t' == H
            prop::assert_that(
                s.is_sync_step(t) == (tp == h),
                format!("sync/t' disagree at t={t}, H={h}"),
            )?;
            // exactly floor(T/H) syncs in [1, T]
            let count = (1..=t).filter(|&u| s.is_sync_step(u)).count() as u64;
            prop::assert_that(
                count == t / h && count == s.syncs_up_to(t),
                format!("sync count {count} != {}", t / h),
            )
        });
    }

    #[test]
    #[should_panic(expected = "1-based")]
    fn zero_iteration_rejected() {
        SyncScheduler::new(SyncPeriod::Every(4)).t_prime(0);
    }
}
