//! Aggregation — the leader-side averaging of gradients (sync algorithms,
//! Alg. 1/3 line 5). The wire-crossing parameter/denominator averaging of
//! Alg. 4 lines 11–12 runs inside the configured
//! [`crate::comm::Collective`] (same [`crate::util::math::mean_into`]
//! kernel); [`average_into`] remains for observer-side consolidation that
//! ships no bytes (final/eval model materialization).
//!
//! Hot path: n ≤ 8 vectors of d up to 1e8; every routine is a streaming
//! pass with reused scratch buffers (no per-sync allocation — see
//! EXPERIMENTS.md §Perf).

use crate::util::math;

/// Reusable aggregation scratch space for a d-dimensional model.
pub struct Aggregator {
    /// Averaged gradient (valid after `mean_grads`).
    pub avg_g: Vec<f32>,
    /// Averaged squared gradients (valid after `mean_grads_and_squares`).
    pub avg_gsq: Vec<f32>,
}

impl Aggregator {
    /// Allocate scratch for dimension `d`.
    pub fn new(d: usize) -> Self {
        Aggregator { avg_g: vec![0.0; d], avg_gsq: vec![0.0; d] }
    }

    /// Dimension.
    pub fn dim(&self) -> usize {
        self.avg_g.len()
    }

    /// `avg_g = (1/n) Σ_i grads[i]` — Alg. 1/3 line 5.
    pub fn mean_grads(&mut self, grads: &[&[f32]]) -> &[f32] {
        math::mean_into(grads, &mut self.avg_g);
        &self.avg_g
    }

    /// Simultaneously `avg_g = (1/n) Σ_i g_i` and
    /// `avg_gsq = (1/n) Σ_i g_i ∘ g_i` — one pass over the inputs, both
    /// outputs written per cache line (Alg. 3 needs both: line 5 + line 7).
    /// Delegates to the shared cache-blocked kernel
    /// ([`crate::util::kernels::mean_and_squares_into`]).
    pub fn mean_grads_and_squares(&mut self, grads: &[&[f32]]) -> (&[f32], &[f32]) {
        crate::util::kernels::mean_and_squares_into(grads, &mut self.avg_g, &mut self.avg_gsq);
        (&self.avg_g, &self.avg_gsq)
    }

    /// Square the already-averaged gradient into `avg_gsq` — AdaGrad's
    /// Alg. 1 line 6 accumulates `G_t ∘ G_t` of the *averaged* gradient.
    pub fn square_avg_grad(&mut self) -> &[f32] {
        let (g, gsq) = (&self.avg_g, &mut self.avg_gsq);
        crate::util::kernels::square_into(g, gsq);
        &self.avg_gsq
    }
}

/// Average `sources` into `out` (sync of parameters or denominators).
/// Free function (not on `Aggregator`) because the destination is usually a
/// worker-owned buffer, not scratch.
pub fn average_into(sources: &[&[f32]], out: &mut [f32]) {
    math::mean_into(sources, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn mean_grads_basic() {
        let mut agg = Aggregator::new(3);
        let a = [1.0f32, 2.0, 3.0];
        let b = [3.0f32, 2.0, 1.0];
        assert_eq!(agg.mean_grads(&[&a, &b]), &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn joint_mean_matches_separate_passes() {
        let mut rng = Rng::new(1);
        let d = 1000;
        let grads: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();

        let mut agg = Aggregator::new(d);
        let (g, gsq) = agg.mean_grads_and_squares(&refs);
        let (g, gsq) = (g.to_vec(), gsq.to_vec());

        // Separate oracle computation.
        let mut eg = vec![0.0f32; d];
        let mut egsq = vec![0.0f32; d];
        for v in &grads {
            for i in 0..d {
                eg[i] += v[i] / 4.0;
                egsq[i] += v[i] * v[i] / 4.0;
            }
        }
        for i in 0..d {
            assert!((g[i] - eg[i]).abs() < 1e-5, "g[{i}]");
            assert!((gsq[i] - egsq[i]).abs() < 1e-4, "gsq[{i}]");
        }
    }

    #[test]
    fn square_avg_grad_is_elementwise_square() {
        let mut agg = Aggregator::new(2);
        let a = [3.0f32, -2.0];
        agg.mean_grads(&[&a]);
        assert_eq!(agg.square_avg_grad(), &[9.0, 4.0]);
    }

    #[test]
    fn avg_gsq_ge_avg_g_squared() {
        // Jensen: mean of squares >= square of mean — distinguishes the
        // AdaAlter accumulator (line 7) from AdaGrad's (line 6).
        prop::check("jensen on aggregation", 100, |g| {
            let d = g.usize_in(1..64);
            let n = g.usize_in(1..8);
            let grads: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_f32(d..d + 1, -5.0..5.0)).collect();
            let refs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
            let mut agg = Aggregator::new(d);
            let (avg_g, avg_gsq) = agg.mean_grads_and_squares(&refs);
            for i in 0..d {
                if avg_gsq[i] + 1e-5 < avg_g[i] * avg_g[i] {
                    return Err(format!(
                        "jensen violated at {i}: {} < {}",
                        avg_gsq[i],
                        avg_g[i] * avg_g[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn average_into_identical_replicas_is_identity() {
        prop::check("sync fixed point", 50, |g| {
            let v = g.vec_normal(1..128, 2.0);
            let sources: Vec<&[f32]> = (0..4).map(|_| v.as_slice()).collect();
            let mut out = vec![0.0f32; v.len()];
            average_into(&sources, &mut out);
            prop::assert_close(&out, &v, 1e-6, "identical-replica average")
        });
    }

    #[test]
    fn average_preserves_linearity() {
        // mean(a+c, b+c) == mean(a,b) + c
        prop::check("aggregation linearity", 50, |g| {
            let d = g.usize_in(1..100);
            let a = g.vec_f32(d..d + 1, -3.0..3.0);
            let b = g.vec_f32(d..d + 1, -3.0..3.0);
            let c = g.f32_in(-2.0..2.0);
            let ac: Vec<f32> = a.iter().map(|v| v + c).collect();
            let bc: Vec<f32> = b.iter().map(|v| v + c).collect();
            let mut m1 = vec![0.0f32; d];
            let mut m2 = vec![0.0f32; d];
            average_into(&[&a, &b], &mut m1);
            average_into(&[&ac, &bc], &mut m2);
            let m1c: Vec<f32> = m1.iter().map(|v| v + c).collect();
            prop::assert_close(&m2, &m1c, 1e-5, "linearity")
        });
    }
}
