//! Worker thread: owns a gradient backend (and, for local algorithms, the
//! local replica + AdaAlter accumulator) and executes leader commands.
//!
//! The protocol is a strict request/reply lockstep per iteration — the
//! synchronous-training barrier of the paper (§2: "synchronous training …
//! blocks the global update until all the workers respond"). The leader
//! side of the channel plumbing lives in
//! [`crate::comm::transport::ChannelTransport`]; this module owns the
//! command/reply vocabulary and the worker thread body. Determinism:
//! every gradient is keyed by `(worker, step)`, so thread scheduling cannot
//! change results.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::config::Algorithm;
use crate::coordinator::backend::{BackendFactory, EvalMetrics};
use crate::optim::{LocalAdaAlterWorker, Sgd};

/// Leader → worker commands.
pub enum Cmd {
    /// Fully-synchronous step: compute the gradient at the broadcast `x`
    /// and return it (Alg. 1/3 line 4).
    SyncStep { t: u64, x: Arc<Vec<f32>> },
    /// Local step (Alg. 2 line 5 / Alg. 4 lines 5–7) on the local replica.
    LocalStep { t: u64, lr: f32 },
    /// Send the local replica (and accumulator) for averaging (Alg. 4
    /// lines 11–12 push).
    CollectState,
    /// Install the averaged state (pull side of the sync round).
    InstallState { x: Arc<Vec<f32>>, acc: Option<Arc<Vec<f32>>> },
    /// Evaluate on the held-out set: at `x` if given, else at the local
    /// replica.
    Eval { x: Option<Arc<Vec<f32>>> },
    /// Shut down.
    Stop,
}

/// Worker → leader replies.
pub enum Reply {
    /// Gradient for a `SyncStep` (loss is the local mini-batch loss).
    Grad { worker: usize, loss: f32, grad: Vec<f32> },
    /// A `LocalStep` finished. `update_sq` is the squared L2 norm of this
    /// step's local parameter update `‖Δx‖²` — the drift proxy adaptive
    /// sync policies consume (DESIGN.md §4); 0 when the fused device path
    /// applied the update (the norm is not observable without an extra
    /// device read, so the trainer disables fusion for policies that need
    /// it).
    StepDone { worker: usize, loss: f32, update_sq: f64 },
    /// Local state snapshot for averaging.
    State { worker: usize, x: Vec<f32>, acc: Option<Vec<f32>> },
    /// Evaluation result.
    Eval { worker: usize, metrics: EvalMetrics },
    /// Ready after start-up / state install.
    Ready { worker: usize },
    /// The worker's fault schedule killed it at `step` (DESIGN.md §5).
    /// The tombstone reply stands in for a vanished process so the
    /// lockstep protocol observes the death instead of deadlocking; the
    /// leader marks the worker dead and stops addressing it.
    Crashed { worker: usize, step: u64 },
    /// Fatal worker error.
    Err { worker: usize, msg: String },
}

/// Everything a worker thread needs at spawn time.
pub struct WorkerSpec {
    /// This worker's 0-based id.
    pub worker: usize,
    /// The algorithm the cluster runs (decides the local state held).
    pub algorithm: Algorithm,
    /// ε for local AdaAlter.
    pub epsilon: f32,
    /// b₀ for local AdaAlter.
    pub b0: f32,
    /// Initial parameters (identical across workers, Alg. 2/4 line 1).
    pub init: Arc<Vec<f32>>,
    /// Use the backend's fused local-step path when available.
    pub allow_fused: bool,
    /// Measure the per-step `‖Δx‖²` drift proxy (set when the sync policy
    /// consumes it). Gates the local-SGD path's extra pass over the
    /// gradient; the AdaAlter path folds the norm into its existing fused
    /// update loop, so it always reports it.
    pub collect_update_sq: bool,
    /// Fault injection (DESIGN.md §5): the worker dies permanently at this
    /// step — it executes steps `t < crash_step` and answers everything
    /// from `crash_step` on with [`Reply::Crashed`].
    pub crash_step: Option<u64>,
}

/// Local-algorithm replica state.
enum LocalState {
    None,
    Sgd { x: Vec<f32> },
    AdaAlter(LocalAdaAlterWorker),
}

/// The worker thread body. Runs until `Stop` (or channel close / error).
pub fn worker_loop(
    spec: WorkerSpec,
    factory: BackendFactory,
    rx: Receiver<Cmd>,
    tx: Sender<Reply>,
) {
    let worker = spec.worker;
    let fail = |tx: &Sender<Reply>, msg: String| {
        let _ = tx.send(Reply::Err { worker, msg });
    };

    let mut backend = match factory(worker) {
        Ok(b) => b,
        Err(e) => return fail(&tx, format!("backend init: {e}")),
    };
    let d = backend.dim();
    if spec.init.len() != d {
        return fail(&tx, format!("init len {} != backend dim {d}", spec.init.len()));
    }

    let mut local = match spec.algorithm {
        Algorithm::LocalSgd => LocalState::Sgd { x: spec.init.as_ref().clone() },
        Algorithm::LocalAdaAlter => LocalState::AdaAlter(LocalAdaAlterWorker::new(
            spec.init.as_ref().clone(),
            spec.b0,
            spec.epsilon,
        )),
        _ => LocalState::None,
    };
    let mut grad_buf = vec![0.0f32; d];
    let eps2 = spec.epsilon * spec.epsilon;

    if tx.send(Reply::Ready { worker }).is_err() {
        return;
    }

    let crash_at = spec.crash_step;
    let mut dead = false;

    while let Ok(cmd) = rx.recv() {
        // Fault injection: the schedule kills this worker at its crash
        // step; from then on every command except Stop is answered with
        // the tombstone so the lockstep protocol observes the death
        // instead of blocking on a reply that would never come.
        if !dead {
            let step = match &cmd {
                Cmd::SyncStep { t, .. } | Cmd::LocalStep { t, .. } => Some(*t),
                _ => None,
            };
            if let (Some(c), Some(t)) = (crash_at, step) {
                if t >= c {
                    dead = true;
                }
            }
        }
        if dead {
            if matches!(cmd, Cmd::Stop) {
                break;
            }
            let _ = tx.send(Reply::Crashed { worker, step: crash_at.unwrap_or(0) });
            continue;
        }
        match cmd {
            Cmd::SyncStep { t, x } => {
                match backend.loss_and_grad(&x, t, &mut grad_buf) {
                    Ok(loss) => {
                        let _ = tx.send(Reply::Grad { worker, loss, grad: grad_buf.clone() });
                    }
                    Err(e) => return fail(&tx, format!("grad at t={t}: {e}")),
                }
            }
            Cmd::LocalStep { t, lr } => {
                let (loss, update_sq) = match &mut local {
                    LocalState::Sgd { x } => match backend.loss_and_grad(x, t, &mut grad_buf) {
                        Ok(loss) => {
                            // Δx = −lr·g, so ‖Δx‖² is computable before the
                            // update without touching its arithmetic. Only
                            // paid when a policy consumes it.
                            let update_sq: f64 = if spec.collect_update_sq {
                                grad_buf
                                    .iter()
                                    .map(|&gv| {
                                        let u = (lr * gv) as f64;
                                        u * u
                                    })
                                    .sum()
                            } else {
                                0.0
                            };
                            Sgd::apply(x, &grad_buf, lr);
                            (loss, update_sq)
                        }
                        Err(e) => return fail(&tx, format!("grad at t={t}: {e}")),
                    },
                    LocalState::AdaAlter(w) => {
                        // Try the fused device path first (Alg. 4 lines 5–7
                        // in one dispatch); fall back to grad + rust update.
                        let denom_add = (w.t_prime() + 1) as f32 * eps2;
                        let fused = if spec.allow_fused {
                            backend.fused_local_adaalter_split(w, denom_add, lr, t)
                        } else {
                            Ok(None)
                        };
                        match fused {
                            // Fused path: update norm not observable.
                            Ok(Some(loss)) => (loss, 0.0),
                            Ok(None) => match backend.loss_and_grad(w.x(), t, &mut grad_buf) {
                                Ok(loss) => {
                                    let update_sq = w.local_step(&grad_buf, lr);
                                    (loss, update_sq)
                                }
                                Err(e) => return fail(&tx, format!("grad at t={t}: {e}")),
                            },
                            Err(e) => return fail(&tx, format!("fused step at t={t}: {e}")),
                        }
                    }
                    LocalState::None => {
                        return fail(&tx, "LocalStep sent to a sync-algorithm worker".into())
                    }
                };
                let _ = tx.send(Reply::StepDone { worker, loss, update_sq });
            }
            Cmd::CollectState => match &local {
                LocalState::Sgd { x } => {
                    let _ = tx.send(Reply::State { worker, x: x.clone(), acc: None });
                }
                LocalState::AdaAlter(w) => {
                    let _ = tx.send(Reply::State {
                        worker,
                        x: w.x().to_vec(),
                        acc: Some(w.acc().to_vec()),
                    });
                }
                LocalState::None => {
                    return fail(&tx, "CollectState sent to a sync-algorithm worker".into())
                }
            },
            Cmd::InstallState { x, acc } => {
                match &mut local {
                    LocalState::Sgd { x: lx } => lx.copy_from_slice(&x),
                    LocalState::AdaAlter(w) => {
                        let Some(acc) = acc.as_deref() else {
                            return fail(&tx, "InstallState without accumulator".into());
                        };
                        w.apply_sync(&x, acc);
                    }
                    LocalState::None => {
                        return fail(&tx, "InstallState sent to a sync-algorithm worker".into())
                    }
                }
                let _ = tx.send(Reply::Ready { worker });
            }
            Cmd::Eval { x } => {
                let point = match (&x, &local) {
                    (Some(x), _) => backend.eval(x),
                    (None, LocalState::Sgd { x }) => backend.eval(x),
                    (None, LocalState::AdaAlter(w)) => backend.eval(w.x()),
                    (None, LocalState::None) => {
                        return fail(&tx, "Eval{None} on a sync-algorithm worker".into())
                    }
                };
                match point {
                    Ok(metrics) => {
                        let _ = tx.send(Reply::Eval { worker, metrics });
                    }
                    Err(e) => return fail(&tx, format!("eval: {e}")),
                }
            }
            Cmd::Stop => break,
        }
    }
}

/// Extension: run the backend's fused path against a [`LocalAdaAlterWorker`]
/// whose x/acc it mutates in place.
trait FusedSplit {
    fn fused_local_adaalter_split(
        &mut self,
        w: &mut LocalAdaAlterWorker,
        denom_add: f32,
        lr: f32,
        step: u64,
    ) -> crate::error::Result<Option<f32>>;
}

impl FusedSplit for Box<dyn crate::coordinator::backend::WorkerBackend> {
    fn fused_local_adaalter_split(
        &mut self,
        w: &mut LocalAdaAlterWorker,
        denom_add: f32,
        lr: f32,
        step: u64,
    ) -> crate::error::Result<Option<f32>> {
        let (x, b2, acc) = w.split_mut();
        let r = self.fused_local_adaalter(x, b2, acc, denom_add, lr, step)?;
        if r.is_some() {
            w.note_external_step();
        }
        Ok(r)
    }
}
