//! Worker cells: each owns a gradient backend (and, for local algorithms,
//! the local replica + AdaAlter accumulator) and executes leader commands.
//!
//! The protocol is a strict request/reply lockstep per iteration — the
//! synchronous-training barrier of the paper (§2: "synchronous training …
//! blocks the global update until all the workers respond"). The leader
//! side of the channel plumbing lives in
//! [`crate::comm::transport::ChannelTransport`]; this module owns the
//! command/reply vocabulary and the worker execution bodies. Determinism:
//! every gradient is keyed by `(worker, step)`, so thread scheduling and
//! host placement cannot change results.
//!
//! Hosting (DESIGN.md §7): a worker cell runs either on its own thread
//! ([`worker_loop`], commands on a dedicated channel) or multiplexed with
//! siblings on a shared host thread ([`host_loop`], commands tagged with
//! the worker id). The execution engine
//! ([`crate::coordinator::executor`]) picks the layout from the `[exec]`
//! config section; all layouts are bitwise-equivalent because each cell's
//! state is a pure function of `(seed, worker, step)`.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::config::Algorithm;
use crate::coordinator::backend::{BackendFactory, EvalMetrics, WorkerBackend};
use crate::optim::{LocalAdaAlterWorker, Sgd};
use crate::util::kernels;

/// Leader → worker commands.
pub enum Cmd {
    /// Fully-synchronous step: compute the gradient at the broadcast `x`
    /// and return it (Alg. 1/3 line 4).
    SyncStep {
        /// Iteration number (keys the gradient stream).
        t: u64,
        /// Shared model payload (one allocation per round, Arc-cloned).
        x: Arc<Vec<f32>>,
        /// Recycled gradient buffer from the leader's pool: the cell
        /// computes into it and ships it back as [`Reply::Grad`], so the
        /// steady-state sync step allocates nothing (empty on the first
        /// iteration; the cell resizes it to `d` once).
        scratch: Vec<f32>,
    },
    /// Local step (Alg. 2 line 5 / Alg. 4 lines 5–7) on the local replica.
    LocalStep {
        /// Iteration number.
        t: u64,
        /// Warm-up-scheduled learning rate for this iteration.
        lr: f32,
    },
    /// Send the local replica (and accumulator) for averaging (Alg. 4
    /// lines 11–12 push).
    CollectState {
        /// Recycled buffer the cell copies its parameters into (ships
        /// back as [`Reply::State`]; empty on the first round).
        sx: Vec<f32>,
        /// Recycled buffer for the accumulator (the leader sends an empty
        /// vector for algorithms that don't sync denominators; dropped
        /// then).
        sa: Vec<f32>,
        /// Observer collect: the snapshot is for checkpointing/eval, not a
        /// billed sync round. In-process cells ignore it; the networked
        /// transport ships raw (exact, unbilled) payloads for these
        /// (DESIGN.md §4).
        raw: bool,
    },
    /// Install the averaged state (pull side of the sync round).
    InstallState {
        /// Averaged parameters to install.
        x: Arc<Vec<f32>>,
        /// Averaged accumulator (local AdaAlter only).
        acc: Option<Arc<Vec<f32>>>,
    },
    /// Evaluate on the held-out set: at `x` if given, else at the local
    /// replica.
    Eval {
        /// Evaluation point (None = the local replica).
        x: Option<Arc<Vec<f32>>>,
    },
    /// Shut down.
    Stop,
}

/// Worker → leader replies.
pub enum Reply {
    /// Gradient for a `SyncStep` (loss is the local mini-batch loss).
    Grad {
        /// Replying worker id.
        worker: usize,
        /// Local mini-batch loss.
        loss: f32,
        /// The gradient, in the leader's recycled scratch buffer.
        grad: Vec<f32>,
    },
    /// A `LocalStep` finished. `update_sq` is the squared L2 norm of this
    /// step's local parameter update `‖Δx‖²` — the drift proxy adaptive
    /// sync policies consume (DESIGN.md §5); 0 when the fused device path
    /// applied the update (the norm is not observable without an extra
    /// device read, so the trainer disables fusion for policies that need
    /// it).
    StepDone {
        /// Replying worker id.
        worker: usize,
        /// Local mini-batch loss.
        loss: f32,
        /// `‖Δx‖²` of the applied update (0 on the fused path).
        update_sq: f64,
    },
    /// Local state snapshot for averaging.
    State {
        /// Replying worker id.
        worker: usize,
        /// Local replica parameters.
        x: Vec<f32>,
        /// Local accumulator (local AdaAlter only).
        acc: Option<Vec<f32>>,
    },
    /// Evaluation result.
    Eval {
        /// Replying worker id.
        worker: usize,
        /// Held-out metrics.
        metrics: EvalMetrics,
    },
    /// Ready after start-up / state install.
    Ready {
        /// Replying worker id.
        worker: usize,
    },
    /// The worker's fault schedule killed it at `step` (DESIGN.md §6).
    /// The tombstone reply stands in for a vanished process so the
    /// lockstep protocol observes the death instead of deadlocking; the
    /// leader marks the worker dead and stops addressing it.
    Crashed {
        /// Replying worker id.
        worker: usize,
        /// The 1-based iteration the schedule killed it at.
        step: u64,
    },
    /// The worker departed voluntarily at `step` (graceful leave,
    /// DESIGN.md §10). Unlike [`Reply::Crashed`] this is not billed as a
    /// failure: the leader retires the worker from the live set without
    /// counting it against the crash telemetry.
    Left {
        /// Replying worker id.
        worker: usize,
        /// The 1-based iteration the worker left at.
        step: u64,
    },
    /// Fatal worker error.
    Err {
        /// Replying worker id.
        worker: usize,
        /// Error description.
        msg: String,
    },
}

/// Everything a worker cell needs at spawn time.
pub struct WorkerSpec {
    /// This worker's 0-based id.
    pub worker: usize,
    /// The algorithm the cluster runs (decides the local state held).
    pub algorithm: Algorithm,
    /// ε for local AdaAlter.
    pub epsilon: f32,
    /// b₀ for local AdaAlter.
    pub b0: f32,
    /// Initial parameters (identical across workers, Alg. 2/4 line 1).
    pub init: Arc<Vec<f32>>,
    /// Use the backend's fused local-step path when available.
    pub allow_fused: bool,
    /// Measure the per-step `‖Δx‖²` drift proxy (set when the sync policy
    /// consumes it). Gates the local-SGD path's extra pass over the
    /// gradient; the AdaAlter path folds the norm into its existing fused
    /// update loop, so it always reports it.
    pub collect_update_sq: bool,
    /// Keep the local accumulator state on the bf16 grid
    /// (`precision.state = "bf16"`; DESIGN.md §8). The trainer disables
    /// the fused device path for these runs.
    pub bf16_state: bool,
    /// Fault injection (DESIGN.md §6): the worker dies permanently at this
    /// step — it executes steps `t < crash_step` and answers everything
    /// from `crash_step` on with [`Reply::Crashed`].
    pub crash_step: Option<u64>,
}

/// Local-algorithm replica state.
enum LocalState {
    None,
    Sgd { x: Vec<f32> },
    AdaAlter(LocalAdaAlterWorker),
}

/// What a cell's command handler asks its host to do next.
enum CellFlow {
    /// Keep serving commands.
    Continue,
    /// This cell received `Stop`.
    Stopped,
    /// Fatal error already reported via `Reply::Err` — abandon the host.
    Failed,
}

/// Report a fatal cell error.
fn send_fail(tx: &Sender<Reply>, worker: usize, msg: String) -> CellFlow {
    let _ = tx.send(Reply::Err { worker, msg });
    CellFlow::Failed
}

/// One hosted worker: backend + replica state + fault schedule.
struct WorkerCell {
    worker: usize,
    d: usize,
    allow_fused: bool,
    collect_update_sq: bool,
    crash_at: Option<u64>,
    dead: bool,
    eps2: f32,
    backend: Box<dyn WorkerBackend>,
    local: LocalState,
    /// Local-algorithm gradient scratch (empty for sync-algorithm cells,
    /// whose gradients land in the leader's recycled `SyncStep` buffer).
    grad_buf: Vec<f32>,
}

impl WorkerCell {
    /// Build the cell on the current (host) thread — backends are
    /// constructed thread-locally because PJRT clients are not `Send`.
    fn build(spec: WorkerSpec, factory: &BackendFactory) -> Result<WorkerCell, String> {
        let backend = (factory.as_ref())(spec.worker).map_err(|e| format!("backend init: {e}"))?;
        let d = backend.dim();
        if spec.init.len() != d {
            return Err(format!("init len {} != backend dim {d}", spec.init.len()));
        }
        let local = match spec.algorithm {
            Algorithm::LocalSgd => LocalState::Sgd { x: spec.init.as_ref().clone() },
            Algorithm::LocalAdaAlter => LocalState::AdaAlter(
                LocalAdaAlterWorker::new(spec.init.as_ref().clone(), spec.b0, spec.epsilon)
                    .with_bf16_state(spec.bf16_state),
            ),
            _ => LocalState::None,
        };
        let grad_buf = if matches!(local, LocalState::None) {
            Vec::new()
        } else {
            vec![0.0f32; d]
        };
        Ok(WorkerCell {
            worker: spec.worker,
            d,
            allow_fused: spec.allow_fused,
            collect_update_sq: spec.collect_update_sq,
            crash_at: spec.crash_step,
            dead: false,
            eps2: spec.epsilon * spec.epsilon,
            backend,
            local,
            grad_buf,
        })
    }

    /// Execute one leader command, replying on `tx`.
    fn handle(&mut self, cmd: Cmd, tx: &Sender<Reply>) -> CellFlow {
        let worker = self.worker;
        // Fault injection: the schedule kills this worker at its crash
        // step; from then on every command except Stop is answered with
        // the tombstone so the lockstep protocol observes the death
        // instead of blocking on a reply that would never come.
        if !self.dead {
            let step = match &cmd {
                Cmd::SyncStep { t, .. } | Cmd::LocalStep { t, .. } => Some(*t),
                _ => None,
            };
            if let (Some(c), Some(t)) = (self.crash_at, step) {
                if t >= c {
                    self.dead = true;
                }
            }
        }
        if self.dead {
            match &cmd {
                Cmd::Stop => return CellFlow::Stopped,
                // Elastic membership (DESIGN.md §10): the leader re-admits
                // a crashed local-algorithm worker at a sync-round boundary
                // by re-broadcasting the averaged state. The install revives
                // the cell — warm-started at the boundary, it is bitwise
                // indistinguishable from a worker that never left. The
                // crash schedule is one-shot, so it is cleared on revival.
                Cmd::InstallState { .. } if !matches!(self.local, LocalState::None) => {
                    self.dead = false;
                    self.crash_at = None;
                }
                _ => {
                    // Release any payload the command carried before
                    // replying (the leader recycles broadcast Arcs once all
                    // handles drop).
                    let step = self.crash_at.unwrap_or(0);
                    drop(cmd);
                    let _ = tx.send(Reply::Crashed { worker, step });
                    return CellFlow::Continue;
                }
            }
        }
        match cmd {
            Cmd::SyncStep { t, x, mut scratch } => {
                scratch.resize(self.d, 0.0);
                match self.backend.loss_and_grad(&x, t, &mut scratch) {
                    Ok(loss) => {
                        // Release the shared payload BEFORE replying so the
                        // leader's ArcSlot can recycle the allocation next
                        // round.
                        drop(x);
                        let _ = tx.send(Reply::Grad { worker, loss, grad: scratch });
                        CellFlow::Continue
                    }
                    Err(e) => send_fail(tx, worker, format!("grad at t={t}: {e}")),
                }
            }
            Cmd::LocalStep { t, lr } => {
                let collect = self.collect_update_sq;
                let (loss, update_sq) = match &mut self.local {
                    LocalState::Sgd { x } => {
                        match self.backend.loss_and_grad(x, t, &mut self.grad_buf) {
                            Ok(loss) => {
                                // Δx = −lr·g, so ‖Δx‖² is computable before
                                // the update without touching its
                                // arithmetic. Only paid when a policy
                                // consumes it.
                                let update_sq: f64 = if collect {
                                    kernels::sgd_update_sq(&self.grad_buf, lr)
                                } else {
                                    0.0
                                };
                                Sgd::apply(x, &self.grad_buf, lr);
                                (loss, update_sq)
                            }
                            Err(e) => {
                                return send_fail(tx, worker, format!("grad at t={t}: {e}"))
                            }
                        }
                    }
                    LocalState::AdaAlter(w) => {
                        // Try the fused device path first (Alg. 4 lines 5–7
                        // in one dispatch); fall back to grad + rust update.
                        let denom_add = (w.t_prime() + 1) as f32 * self.eps2;
                        let fused = if self.allow_fused {
                            self.backend.fused_local_adaalter_split(w, denom_add, lr, t)
                        } else {
                            Ok(None)
                        };
                        match fused {
                            // Fused path: update norm not observable.
                            Ok(Some(loss)) => (loss, 0.0),
                            Ok(None) => {
                                match self.backend.loss_and_grad(w.x(), t, &mut self.grad_buf) {
                                    Ok(loss) => {
                                        let update_sq = w.local_step(&self.grad_buf, lr);
                                        (loss, update_sq)
                                    }
                                    Err(e) => {
                                        return send_fail(
                                            tx,
                                            worker,
                                            format!("grad at t={t}: {e}"),
                                        )
                                    }
                                }
                            }
                            Err(e) => {
                                return send_fail(tx, worker, format!("fused step at t={t}: {e}"))
                            }
                        }
                    }
                    LocalState::None => {
                        return send_fail(
                            tx,
                            worker,
                            "LocalStep sent to a sync-algorithm worker".into(),
                        )
                    }
                };
                let _ = tx.send(Reply::StepDone { worker, loss, update_sq });
                CellFlow::Continue
            }
            Cmd::CollectState { mut sx, mut sa, raw: _ } => match &self.local {
                LocalState::Sgd { x } => {
                    sx.resize(x.len(), 0.0);
                    sx.copy_from_slice(x);
                    drop(sa);
                    let _ = tx.send(Reply::State { worker, x: sx, acc: None });
                    CellFlow::Continue
                }
                LocalState::AdaAlter(w) => {
                    sx.resize(w.x().len(), 0.0);
                    sx.copy_from_slice(w.x());
                    sa.resize(w.acc().len(), 0.0);
                    sa.copy_from_slice(w.acc());
                    let _ = tx.send(Reply::State { worker, x: sx, acc: Some(sa) });
                    CellFlow::Continue
                }
                LocalState::None => {
                    send_fail(tx, worker, "CollectState sent to a sync-algorithm worker".into())
                }
            },
            Cmd::InstallState { x, acc } => {
                match &mut self.local {
                    LocalState::Sgd { x: lx } => lx.copy_from_slice(&x),
                    LocalState::AdaAlter(w) => {
                        let Some(a) = acc.as_deref() else {
                            return send_fail(tx, worker, "InstallState without accumulator".into());
                        };
                        w.apply_sync(&x, a);
                    }
                    LocalState::None => {
                        return send_fail(
                            tx,
                            worker,
                            "InstallState sent to a sync-algorithm worker".into(),
                        )
                    }
                }
                // Release the shared payloads before replying (ArcSlot
                // recycling, as in SyncStep).
                drop(x);
                drop(acc);
                let _ = tx.send(Reply::Ready { worker });
                CellFlow::Continue
            }
            Cmd::Eval { x } => {
                let point = match (&x, &self.local) {
                    (Some(x), _) => self.backend.eval(x),
                    (None, LocalState::Sgd { x }) => self.backend.eval(x),
                    (None, LocalState::AdaAlter(w)) => self.backend.eval(w.x()),
                    (None, LocalState::None) => {
                        return send_fail(tx, worker, "Eval{None} on a sync-algorithm worker".into())
                    }
                };
                match point {
                    Ok(metrics) => {
                        let _ = tx.send(Reply::Eval { worker, metrics });
                        CellFlow::Continue
                    }
                    Err(e) => send_fail(tx, worker, format!("eval: {e}")),
                }
            }
            Cmd::Stop => CellFlow::Stopped,
        }
    }
}

/// The single-worker thread body: one cell on a dedicated channel. Runs
/// until `Stop` (or channel close / error).
pub fn worker_loop(
    spec: WorkerSpec,
    factory: BackendFactory,
    rx: Receiver<Cmd>,
    tx: Sender<Reply>,
) {
    let worker = spec.worker;
    let mut cell = match WorkerCell::build(spec, &factory) {
        Ok(c) => c,
        Err(msg) => {
            let _ = tx.send(Reply::Err { worker, msg });
            return;
        }
    };
    if tx.send(Reply::Ready { worker }).is_err() {
        return;
    }
    while let Ok(cmd) = rx.recv() {
        match cell.handle(cmd, &tx) {
            CellFlow::Continue => {}
            CellFlow::Stopped | CellFlow::Failed => break,
        }
    }
}

/// The host thread body (DESIGN.md §7): several worker cells multiplexed
/// on one shared channel, commands tagged `(worker, cmd)`. Cells are built
/// in the given order, each announcing `Ready`; the loop exits once every
/// hosted cell received `Stop` (or on a fatal cell error / channel close).
pub fn host_loop(
    specs: Vec<WorkerSpec>,
    factory: BackendFactory,
    rx: Receiver<(usize, Cmd)>,
    tx: Sender<Reply>,
) {
    let mut cells: Vec<WorkerCell> = Vec::with_capacity(specs.len());
    for spec in specs {
        let worker = spec.worker;
        match WorkerCell::build(spec, &factory) {
            Ok(c) => cells.push(c),
            Err(msg) => {
                let _ = tx.send(Reply::Err { worker, msg });
                return;
            }
        }
        if tx.send(Reply::Ready { worker }).is_err() {
            return;
        }
    }
    let mut live = cells.len();
    while live > 0 {
        let Ok((w, cmd)) = rx.recv() else { return };
        let Some(cell) = cells.iter_mut().find(|c| c.worker == w) else {
            let _ = tx.send(Reply::Err {
                worker: w,
                msg: "command routed to a host not owning this worker".into(),
            });
            return;
        };
        match cell.handle(cmd, &tx) {
            CellFlow::Continue => {}
            CellFlow::Stopped => live -= 1,
            CellFlow::Failed => return,
        }
    }
}

/// Extension: run the backend's fused path against a [`LocalAdaAlterWorker`]
/// whose x/acc it mutates in place.
trait FusedSplit {
    fn fused_local_adaalter_split(
        &mut self,
        w: &mut LocalAdaAlterWorker,
        denom_add: f32,
        lr: f32,
        step: u64,
    ) -> crate::error::Result<Option<f32>>;
}

impl FusedSplit for Box<dyn crate::coordinator::backend::WorkerBackend> {
    fn fused_local_adaalter_split(
        &mut self,
        w: &mut LocalAdaAlterWorker,
        denom_add: f32,
        lr: f32,
        step: u64,
    ) -> crate::error::Result<Option<f32>> {
        let (x, b2, acc) = w.split_mut();
        let r = self.fused_local_adaalter(x, b2, acc, denom_add, lr, step)?;
        if r.is_some() {
            w.note_external_step();
        }
        Ok(r)
    }
}
