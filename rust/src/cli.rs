//! Minimal CLI argument parser (the offline image has no `clap`).
//!
//! Grammar: `adaalter <command> [--flag value]… [--switch]…`. Flags that
//! take values are declared up front so `--set a=b --set c=d` can repeat
//! and typos fail loudly.

use std::collections::{BTreeMap, BTreeSet};

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// The subcommand (first non-flag token).
    pub command: String,
    /// Value flags (`--key value`), in order per key.
    values: BTreeMap<String, Vec<String>>,
    /// Boolean switches (`--quiet`).
    switches: BTreeSet<String>,
}

impl Args {
    /// Parse `argv[1..]`. `value_flags` take an argument; `switch_flags`
    /// do not; anything else errors.
    pub fn parse(
        argv: &[String],
        value_flags: &[&str],
        switch_flags: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // Support --key=value in one token.
                if let Some((k, v)) = name.split_once('=') {
                    if !value_flags.contains(&k) {
                        return Err(Error::Config(format!("unknown flag --{k}")));
                    }
                    out.values.entry(k.to_string()).or_default().push(v.to_string());
                } else if value_flags.contains(&name) {
                    let v = it.next().ok_or_else(|| {
                        Error::Config(format!("flag --{name} needs a value"))
                    })?;
                    out.values.entry(name.to_string()).or_default().push(v.clone());
                } else if switch_flags.contains(&name) {
                    out.switches.insert(name.to_string());
                } else {
                    return Err(Error::Config(format!("unknown flag --{name}")));
                }
            } else if out.command.is_empty() {
                out.command = tok.clone();
            } else {
                return Err(Error::Config(format!("unexpected argument {tok:?}")));
            }
        }
        Ok(out)
    }

    /// Last value of a flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    /// All values of a repeatable flag.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.values.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Is a switch present?
    pub fn has(&self, key: &str) -> bool {
        self.switches.contains(key)
    }

    /// Value with default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = Args::parse(
            &argv("train --experiment paper-default --set a=1 --set b=2 --quiet"),
            &["experiment", "set"],
            &["quiet"],
        )
        .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("experiment"), Some("paper-default"));
        assert_eq!(a.get_all("set"), &["a=1".to_string(), "b=2".to_string()]);
        assert!(a.has("quiet"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&argv("run --steps=50"), &["steps"], &[]).unwrap();
        assert_eq!(a.get("steps"), Some("50"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(Args::parse(&argv("x --bogus 1"), &["real"], &[]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&argv("x --experiment"), &["experiment"], &[]).is_err());
    }

    #[test]
    fn stray_positional_rejected() {
        assert!(Args::parse(&argv("x y"), &[], &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("t"), &["k"], &[]).unwrap();
        assert_eq!(a.get_or("k", "fallback"), "fallback");
        assert!(a.get_all("k").is_empty());
    }
}
