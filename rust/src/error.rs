//! Crate-wide error type.
//!
//! Library modules return [`Result`]; binaries convert to
//! `Box<dyn std::error::Error>` at the edge (the image is dependency-free,
//! so no `anyhow`). Variants are grouped by subsystem so callers can match
//! on the failing layer (config vs artifact vs runtime vs protocol).

use std::fmt;

/// Unified error for the adaalter crate.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / CLI parse or validation failures.
    Config(String),
    /// TOML / JSON syntax errors with location info.
    Parse { what: &'static str, line: usize, msg: String },
    /// `artifacts/` problems: missing files, manifest mismatch, bad shapes.
    Artifact(String),
    /// PJRT / XLA runtime failures.
    Runtime(String),
    /// Training-protocol invariant violations (e.g. state-size mismatch).
    Protocol(String),
    /// Data-pipeline failures.
    Data(String),
    /// Underlying I/O.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Parse { what, line, msg } => {
                write!(f, "{what} parse error at line {line}: {msg}")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for runtime-layer errors from the xla crate (whose error type
    /// we do not want in our public API).
    pub fn runtime(e: impl fmt::Display) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_subsystem() {
        assert!(Error::Config("x".into()).to_string().starts_with("config"));
        assert!(Error::Artifact("x".into()).to_string().starts_with("artifact"));
        let e = Error::Parse { what: "toml", line: 3, msg: "bad".into() };
        assert_eq!(e.to_string(), "toml parse error at line 3: bad");
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("gone"));
    }
}
