//! Batch assembly: token panels for the PJRT training/eval artifacts.
//!
//! A training batch is an `i32[batch, seq+1]` panel (inputs `[:, :-1]`,
//! targets `[:, 1:]` — the split happens inside the lowered graph). The
//! loader is stateless: `(worker, step)` fully determines a batch, which is
//! what makes threaded training runs bit-reproducible and lets tests replay
//! any worker's stream.

use crate::config::DataConfig;

use super::corpus::SyntheticCorpus;

/// Stateless, deterministic batch loader over a [`SyntheticCorpus`].
pub struct BatchLoader {
    corpus: SyntheticCorpus,
    batch: usize,
    eval_batch: usize,
    seq: usize,
}

impl BatchLoader {
    /// Loader for `workers` shards of batches `[batch, seq+1]`.
    pub fn new(
        vocab: usize,
        workers: usize,
        batch: usize,
        eval_batch: usize,
        seq: usize,
        cfg: &DataConfig,
        seed: u64,
    ) -> Self {
        assert!(batch >= 1 && seq >= 2);
        BatchLoader {
            corpus: SyntheticCorpus::new(vocab, workers, cfg, seed),
            batch,
            eval_batch,
            seq,
        }
    }

    /// Tokens per training batch row (seq + 1).
    pub fn row_len(&self) -> usize {
        self.seq + 1
    }

    /// Flattened `[batch, seq+1]` training panel for `(worker, step)`.
    pub fn train_batch(&self, worker: usize, step: u64) -> Vec<i32> {
        let row = self.row_len();
        let mut tokens = vec![0u32; self.batch * row];
        // One contiguous stream per (worker, step), chunked into rows: rows
        // of a batch are consecutive windows of the same stream, which
        // preserves the Markov structure within each row.
        self.corpus.fill_stream(worker, step, &mut tokens);
        tokens.into_iter().map(|t| t as i32).collect()
    }

    /// Flattened `[eval_batch, seq+1]` held-out panel for eval batch `k`.
    pub fn eval_batch(&self, k: u64) -> Vec<i32> {
        let row = self.row_len();
        let mut tokens = vec![0u32; self.eval_batch * row];
        self.corpus.fill_eval_stream(k, &mut tokens);
        tokens.into_iter().map(|t| t as i32).collect()
    }

    /// Training batch shape.
    pub fn train_shape(&self) -> [usize; 2] {
        [self.batch, self.row_len()]
    }

    /// Eval batch shape.
    pub fn eval_shape(&self) -> [usize; 2] {
        [self.eval_batch, self.row_len()]
    }

    /// Samples (rows) per training batch.
    pub fn samples_per_batch(&self) -> usize {
        self.batch
    }

    /// Underlying corpus (diagnostics).
    pub fn corpus(&self) -> &SyntheticCorpus {
        &self.corpus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loader() -> BatchLoader {
        BatchLoader::new(256, 4, 3, 5, 16, &DataConfig::default(), 11)
    }

    #[test]
    fn shapes_and_determinism() {
        let l = loader();
        assert_eq!(l.train_shape(), [3, 17]);
        assert_eq!(l.eval_shape(), [5, 17]);
        let a = l.train_batch(1, 7);
        assert_eq!(a.len(), 3 * 17);
        assert_eq!(a, l.train_batch(1, 7));
        assert_ne!(a, l.train_batch(1, 8));
        assert_ne!(a, l.train_batch(2, 7));
        let e = l.eval_batch(0);
        assert_eq!(e.len(), 5 * 17);
        assert_eq!(e, l.eval_batch(0));
        assert_ne!(e, l.eval_batch(1));
    }

    #[test]
    fn tokens_are_valid_ids() {
        let l = loader();
        for w in 0..4 {
            for s in [0u64, 5, 99] {
                assert!(l.train_batch(w, s).iter().all(|&t| (0..256).contains(&t)));
            }
        }
        assert!(l.eval_batch(3).iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn eval_differs_from_train_streams() {
        let l = loader();
        let e: Vec<i32> = l.eval_batch(0)[..17].to_vec();
        for w in 0..4 {
            let t: Vec<i32> = l.train_batch(w, 0)[..17].to_vec();
            assert_ne!(e, t, "worker {w} train stream equals eval stream");
        }
    }
}
