//! Synthetic language-modelling corpus — the 1B-Word-Benchmark stand-in.
//!
//! The paper trains on 0.8B words with a 793k vocabulary; the optimizer
//! protocol only sees the gradient stream, so any corpus with (a) a heavy-
//! tailed unigram distribution, (b) learnable sequential structure and
//! (c) controllable non-IID sharding exercises the same code paths
//! (DESIGN.md §3). The generative model per worker `w`:
//!
//! ```text
//!   next = permute(prev)                 with prob. `markov`   (shared,
//!                                        learnable order-1 structure)
//!   next = zipf_sample() rotated by      otherwise             (worker-
//!          round(noniid · w · V / n)                            specific
//!                                                               unigrams)
//! ```
//!
//! `noniid = 0` gives IID shards (every worker samples the same law);
//! `noniid = 1` gives maximally rotated (disjoint-mode) unigram
//! distributions — the paper's `D_i ≠ D_j` setting. The Markov permutation
//! is shared so there is a common signal for the model to learn, which is
//! what makes the PPL-vs-epoch curves (Fig. 3) meaningful.

use crate::config::DataConfig;
use crate::util::rng::{Rng, ZipfTable};

/// Deterministic synthetic corpus over `vocab` tokens for `n` workers.
pub struct SyntheticCorpus {
    vocab: u64,
    workers: usize,
    markov: f64,
    noniid: f64,
    zipf: ZipfTable,
    seed: u64,
    /// Multiplier of the shared learnable permutation `next = (a·prev + b) % V`.
    perm_a: u64,
    perm_b: u64,
}

impl SyntheticCorpus {
    /// Build the corpus model (tables only; streams are generated on demand).
    pub fn new(vocab: usize, workers: usize, cfg: &DataConfig, seed: u64) -> Self {
        assert!(vocab >= 4, "vocab too small");
        assert!(workers >= 1);
        // `a` must be coprime with V for the map to be a permutation; V is
        // a power of two in our presets, so any odd multiplier works. Pick
        // a,b from the seed so different experiments learn different maps.
        let mut r = Rng::derive(seed, &[0xC0FFEE]);
        let perm_a = (r.below(vocab as u64 / 2) * 2 + 3) % vocab as u64 | 1;
        let perm_b = r.below(vocab as u64);
        SyntheticCorpus {
            vocab: vocab as u64,
            workers,
            markov: cfg.markov,
            noniid: cfg.noniid,
            zipf: ZipfTable::new(vocab, cfg.zipf_s),
            seed,
            perm_a,
            perm_b,
        }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.vocab as usize
    }

    /// The shared learnable next-token map.
    #[inline]
    fn permute(&self, prev: u32) -> u32 {
        ((self.perm_a.wrapping_mul(prev as u64).wrapping_add(self.perm_b)) % self.vocab) as u32
    }

    /// Unigram rotation offset for worker `w` (the non-IID knob).
    fn rotation(&self, worker: usize) -> u64 {
        if self.workers <= 1 {
            return 0;
        }
        let span = self.vocab as f64 / self.workers as f64;
        (self.noniid * worker as f64 * span).round() as u64 % self.vocab
    }

    /// Fill `out` with a token stream for `(worker, stream_key)`.
    ///
    /// `stream_key` distinguishes independent draws (e.g. the step number);
    /// the same key always regenerates the same stream.
    pub fn fill_stream(&self, worker: usize, stream_key: u64, out: &mut [u32]) {
        let mut rng = Rng::derive(self.seed, &[1, worker as u64, stream_key]);
        let rot = self.rotation(worker);
        let mut prev: u32 = self.rotated_zipf(&mut rng, rot);
        for slot in out.iter_mut() {
            prev = if rng.bernoulli(self.markov) {
                self.permute(prev)
            } else {
                self.rotated_zipf(&mut rng, rot)
            };
            *slot = prev;
        }
    }

    #[inline]
    fn rotated_zipf(&self, rng: &mut Rng, rot: u64) -> u32 {
        let rank = self.zipf.sample(rng) as u64;
        ((rank + rot) % self.vocab) as u32
    }

    /// Held-out evaluation stream: a uniform mixture over all workers'
    /// distributions (the shared "test set" of §6.2), keyed separately
    /// from every training stream.
    pub fn fill_eval_stream(&self, batch_key: u64, out: &mut [u32]) {
        let mut rng = Rng::derive(self.seed, &[2, batch_key]);
        let mut prev: u32 = 0;
        for slot in out.iter_mut() {
            // Rotate through worker distributions token-block-wise so eval
            // covers every shard's modes.
            let w = rng.below(self.workers as u64) as usize;
            let rot = self.rotation(w);
            prev = if rng.bernoulli(self.markov) {
                self.permute(prev)
            } else {
                self.rotated_zipf(&mut rng, rot)
            };
            *slot = prev;
        }
    }

    /// Empirical unigram histogram over a generated stream (test helper /
    /// corpus diagnostics).
    pub fn unigram_histogram(&self, worker: usize, samples: usize) -> Vec<u64> {
        let mut stream = vec![0u32; samples];
        self.fill_stream(worker, 0xEDA, &mut stream);
        let mut hist = vec![0u64; self.vocab as usize];
        for t in stream {
            hist[t as usize] += 1;
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;

    fn corpus(noniid: f64, workers: usize) -> SyntheticCorpus {
        let cfg = DataConfig { noniid, ..Default::default() };
        SyntheticCorpus::new(256, workers, &cfg, 7)
    }

    #[test]
    fn streams_are_deterministic() {
        let c = corpus(0.5, 4);
        let mut a = vec![0u32; 512];
        let mut b = vec![0u32; 512];
        c.fill_stream(2, 9, &mut a);
        c.fill_stream(2, 9, &mut b);
        assert_eq!(a, b);
        c.fill_stream(2, 10, &mut b);
        assert_ne!(a, b);
        c.fill_stream(3, 9, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = corpus(1.0, 8);
        let mut s = vec![0u32; 4096];
        for w in 0..8 {
            c.fill_stream(w, 1, &mut s);
            assert!(s.iter().all(|&t| (t as usize) < c.vocab()));
        }
        c.fill_eval_stream(0, &mut s);
        assert!(s.iter().all(|&t| (t as usize) < c.vocab()));
    }

    #[test]
    fn markov_structure_is_learnable() {
        // With markov=0.85, the successor of token t should very often be
        // permute(t): measure the hit rate.
        let c = corpus(0.0, 1);
        let mut s = vec![0u32; 20_000];
        c.fill_stream(0, 3, &mut s);
        let hits = s.windows(2).filter(|w| w[1] == c.permute(w[0])).count();
        let rate = hits as f64 / (s.len() - 1) as f64;
        assert!(rate > 0.8, "markov hit rate {rate}");
    }

    #[test]
    fn zipf_head_dominates() {
        let c = corpus(0.0, 1);
        let hist = c.unigram_histogram(0, 50_000);
        let total: u64 = hist.iter().sum();
        let mut sorted = hist.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top16: u64 = sorted[..16].iter().sum();
        assert!(
            top16 as f64 / total as f64 > 0.35,
            "head mass {}",
            top16 as f64 / total as f64
        );
    }

    #[test]
    fn noniid_rotates_unigrams() {
        // At noniid=1 the dominant tokens of worker 0 and worker 4 (of 8)
        // must be (near-)disjoint; at noniid=0 they must coincide.
        let top_tokens = |c: &SyntheticCorpus, w: usize| -> Vec<usize> {
            let hist = c.unigram_histogram(w, 30_000);
            let mut idx: Vec<usize> = (0..hist.len()).collect();
            idx.sort_unstable_by_key(|&i| std::cmp::Reverse(hist[i]));
            idx.truncate(8);
            idx
        };
        let iid = corpus(0.0, 8);
        let t0 = top_tokens(&iid, 0);
        let t4 = top_tokens(&iid, 4);
        let overlap_iid = t0.iter().filter(|t| t4.contains(t)).count();
        assert!(overlap_iid >= 6, "iid overlap {overlap_iid}");

        let skew = corpus(1.0, 8);
        let s0 = top_tokens(&skew, 0);
        let s4 = top_tokens(&skew, 4);
        let overlap_skew = s0.iter().filter(|t| s4.contains(t)).count();
        assert!(overlap_skew <= 3, "noniid overlap {overlap_skew}");
    }

    #[test]
    fn rotation_bounds() {
        let c = corpus(1.0, 8);
        for w in 0..8 {
            assert!(c.rotation(w) < 256);
        }
        let single = corpus(1.0, 1);
        assert_eq!(single.rotation(0), 0);
    }

    #[test]
    fn permutation_is_bijective() {
        let c = corpus(0.0, 1);
        let mut seen = vec![false; c.vocab()];
        for t in 0..c.vocab() as u32 {
            let n = c.permute(t) as usize;
            assert!(!seen[n], "collision at {t} -> {n}");
            seen[n] = true;
        }
    }
}
