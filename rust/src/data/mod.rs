//! Data pipeline: synthetic Zipf+Markov corpus (the 1B-word stand-in),
//! deterministic non-IID sharded batch loading.

pub mod corpus;
pub mod loader;

pub use corpus::SyntheticCorpus;
pub use loader::BatchLoader;
