//! Training metrics: loss curves, throughput, PPL, virtual-time axes, and
//! CSV emission for the figure-regeneration benches.

pub mod recorder;

pub use recorder::{EvalPoint, FaultEvent, StepPoint, TrainRecorder};
