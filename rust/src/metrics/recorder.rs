//! Metric recording for a training run.
//!
//! The recorder owns the loss/PPL curves (the Fig. 3 series) and the
//! throughput counters (Fig. 2), on both axes the paper uses: epochs and
//! (virtual) wall-clock time.

use std::time::Instant;

use crate::error::Result;
use crate::util::csv::CsvWriter;

/// One logged training step (averaged over workers).
#[derive(Clone, Copy, Debug)]
pub struct StepPoint {
    pub step: u64,
    pub epoch: f64,
    pub train_loss: f64,
    pub lr: f32,
    pub virtual_s: f64,
    pub wall_s: f64,
}

/// One held-out evaluation.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub step: u64,
    pub epoch: f64,
    pub loss: f64,
    pub ppl: Option<f64>,
    pub virtual_s: f64,
    pub wall_s: f64,
}

/// Accumulates metrics over a run.
pub struct TrainRecorder {
    steps_per_epoch: u64,
    started: Instant,
    ema_loss: Option<f64>,
    ema_beta: f64,
    pub steps: Vec<StepPoint>,
    pub evals: Vec<EvalPoint>,
    samples_processed: u64,
    comm_bytes: u64,
    syncs: u64,
    /// Label of the collective transport that shipped the traffic
    /// (e.g. "simulated(ps)", "qsgd(s=15)") — set by the trainer so bench
    /// tables can attribute bytes to the transport that produced them.
    transport: String,
}

impl TrainRecorder {
    /// Recorder; `steps_per_epoch` defines the epoch axis.
    pub fn new(steps_per_epoch: u64) -> Self {
        assert!(steps_per_epoch >= 1);
        TrainRecorder {
            steps_per_epoch,
            started: Instant::now(),
            ema_loss: None,
            ema_beta: 0.98,
            steps: Vec::new(),
            evals: Vec::new(),
            samples_processed: 0,
            comm_bytes: 0,
            syncs: 0,
            transport: String::new(),
        }
    }

    /// Record which collective transport this run communicates through.
    pub fn set_transport(&mut self, label: String) {
        self.transport = label;
    }

    /// The collective transport label ("" if never set).
    pub fn transport(&self) -> &str {
        &self.transport
    }

    /// Epoch coordinate of a step.
    pub fn epoch_of(&self, step: u64) -> f64 {
        step as f64 / self.steps_per_epoch as f64
    }

    /// Record a training step (call every step; point storage only happens
    /// when `log` is true so long runs stay cheap).
    pub fn step(&mut self, step: u64, loss: f64, lr: f32, virtual_s: f64,
                samples: u64, log: bool) {
        self.samples_processed += samples;
        self.ema_loss = Some(match self.ema_loss {
            None => loss,
            Some(e) => self.ema_beta * e + (1.0 - self.ema_beta) * loss,
        });
        if log {
            self.steps.push(StepPoint {
                step,
                epoch: self.epoch_of(step),
                train_loss: loss,
                lr,
                virtual_s,
                wall_s: self.started.elapsed().as_secs_f64(),
            });
        }
    }

    /// Record one sync round's traffic.
    pub fn sync(&mut self, bytes: u64) {
        self.syncs += 1;
        self.comm_bytes += bytes;
    }

    /// Record a held-out evaluation.
    pub fn eval(&mut self, step: u64, loss: f64, ppl: Option<f64>, virtual_s: f64) {
        self.evals.push(EvalPoint {
            step,
            epoch: self.epoch_of(step),
            loss,
            ppl,
            virtual_s,
            wall_s: self.started.elapsed().as_secs_f64(),
        });
    }

    /// Smoothed training loss.
    pub fn ema_loss(&self) -> Option<f64> {
        self.ema_loss
    }

    /// Total samples processed.
    pub fn samples(&self) -> u64 {
        self.samples_processed
    }

    /// Sync rounds and total bytes shipped.
    pub fn comm(&self) -> (u64, u64) {
        (self.syncs, self.comm_bytes)
    }

    /// Real-time throughput, samples/s (wall-clock).
    pub fn wall_throughput(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt > 0.0 {
            self.samples_processed as f64 / dt
        } else {
            0.0
        }
    }

    /// Write the step curve as CSV.
    pub fn write_steps_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["step", "epoch", "train_loss", "lr", "virtual_s", "wall_s"],
        )?;
        for p in &self.steps {
            w.row(&[
                p.step.to_string(),
                format!("{:.4}", p.epoch),
                format!("{:.6}", p.train_loss),
                format!("{:.6}", p.lr),
                format!("{:.3}", p.virtual_s),
                format!("{:.3}", p.wall_s),
            ])?;
        }
        w.flush()
    }

    /// Write the eval curve as CSV.
    pub fn write_evals_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["step", "epoch", "eval_loss", "ppl", "virtual_s", "wall_s"],
        )?;
        for p in &self.evals {
            w.row(&[
                p.step.to_string(),
                format!("{:.4}", p.epoch),
                format!("{:.6}", p.loss),
                p.ppl.map_or(String::new(), |v| format!("{v:.4}")),
                format!("{:.3}", p.virtual_s),
                format!("{:.3}", p.wall_s),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_converges_to_constant_stream() {
        let mut r = TrainRecorder::new(10);
        for s in 1..=500 {
            r.step(s, 2.0, 0.1, 0.0, 4, false);
        }
        assert!((r.ema_loss().unwrap() - 2.0).abs() < 1e-6);
        assert_eq!(r.samples(), 2000);
        assert!(r.steps.is_empty(), "log=false stores nothing");
    }

    #[test]
    fn epoch_axis() {
        let r = TrainRecorder::new(100);
        assert_eq!(r.epoch_of(250), 2.5);
    }

    #[test]
    fn sync_accounting() {
        let mut r = TrainRecorder::new(10);
        r.sync(1024);
        r.sync(1024);
        assert_eq!(r.comm(), (2, 2048));
    }

    #[test]
    fn transport_label_roundtrip() {
        let mut r = TrainRecorder::new(10);
        assert_eq!(r.transport(), "");
        r.set_transport("qsgd(s=15)".into());
        assert_eq!(r.transport(), "qsgd(s=15)");
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("adaalter_rec_test");
        let sp = dir.join("steps.csv");
        let ep = dir.join("evals.csv");
        let mut r = TrainRecorder::new(10);
        r.step(1, 3.5, 0.1, 0.5, 4, true);
        r.eval(1, 3.4, Some(30.0), 0.5);
        r.eval(2, 3.3, None, 1.0);
        r.write_steps_csv(sp.to_str().unwrap()).unwrap();
        r.write_evals_csv(ep.to_str().unwrap()).unwrap();
        let steps = std::fs::read_to_string(&sp).unwrap();
        assert!(steps.lines().count() == 2 && steps.contains("3.500000"));
        let evals = std::fs::read_to_string(&ep).unwrap();
        assert!(evals.contains("30.0000"));
        // ppl column empty when None
        assert!(evals.lines().nth(2).unwrap().contains(",,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
