//! Metric recording for a training run.
//!
//! The recorder owns the loss/PPL curves (the Fig. 3 series), the
//! throughput counters (Fig. 2), and the synchronization-event log (the
//! realized-H trajectory of adaptive sync policies, DESIGN.md §5), on
//! both axes the paper uses: epochs and (virtual) wall-clock time.

use std::time::Instant;

use crate::error::Result;
use crate::util::csv::CsvWriter;
use crate::util::pool::PoolStats;

/// One logged training step (averaged over workers).
#[derive(Clone, Copy, Debug)]
pub struct StepPoint {
    /// Global iteration t (1-based).
    pub step: u64,
    /// Epoch coordinate `t / steps_per_epoch`.
    pub epoch: f64,
    /// Mean worker training loss at this step.
    pub train_loss: f64,
    /// Learning rate in effect.
    pub lr: f32,
    /// Virtual-clock time, seconds.
    pub virtual_s: f64,
    /// Real wall-clock since the recorder started, seconds.
    pub wall_s: f64,
}

/// One held-out evaluation.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    /// Global iteration t the evaluation ran at.
    pub step: u64,
    /// Epoch coordinate `t / steps_per_epoch`.
    pub epoch: f64,
    /// Held-out loss.
    pub loss: f64,
    /// Held-out perplexity (None for non-LM workloads).
    pub ppl: Option<f64>,
    /// Virtual-clock time, seconds.
    pub virtual_s: f64,
    /// Real wall-clock since the recorder started, seconds.
    pub wall_s: f64,
}

/// One executed synchronization round — together these trace the
/// *realized* H trajectory (and trigger reasons) of the sync policy that
/// drove the run.
#[derive(Clone, Copy, Debug)]
pub struct SyncEvent {
    /// Global iteration the round ran at.
    pub step: u64,
    /// Local steps since the previous round — the realized H.
    pub gap: u64,
    /// Why the policy triggered it
    /// ([`crate::coordinator::sync::SyncReason::as_str`]).
    pub reason: &'static str,
    /// Bytes this round shipped cluster-wide.
    pub bytes: u64,
    /// Virtual-clock time after the round, seconds.
    pub virtual_s: f64,
}

/// One executed synchronization round's participation accounting under an
/// active `[faults]` scenario (DESIGN.md §6): who was alive, who made the
/// round, who was dropped as a straggler, and how long the barrier waited
/// beyond the lockstep-nominal phase time. One row per round; exported as
/// `faults_<tag>.csv` and pinned bitwise-reproducible by
/// `rust/tests/integration_faults.rs`.
#[derive(Clone, Copy, Debug)]
pub struct FaultEvent {
    /// Global iteration the round ran at.
    pub step: u64,
    /// Workers still alive when the round started.
    pub alive: u64,
    /// Workers whose states made the average.
    pub participants: u64,
    /// Workers excluded from the average: straggler drops at partial
    /// rounds; for fully-synchronous rounds, crashes discovered during
    /// the round itself.
    pub dropped: u64,
    /// Workers that crashed (involuntarily) during this round's phase.
    pub crashes: u64,
    /// Workers that departed voluntarily (graceful `Leave`, or retired by
    /// the autoscaler) during this round's phase — billed distinctly from
    /// crashes (DESIGN.md §10).
    pub leaves: u64,
    /// Workers admitted or re-admitted at this round's boundary (plan
    /// rejoins/spawns, wire rejoins, autoscale admissions).
    pub joins: u64,
    /// Barrier wait beyond the nominal phase time, virtual seconds
    /// (charged to [`crate::sim::Charge::Straggler`]).
    pub wait_s: f64,
    /// Virtual-clock time after the round, seconds.
    pub virtual_s: f64,
}

/// Accumulates metrics over a run.
pub struct TrainRecorder {
    steps_per_epoch: u64,
    started: Instant,
    ema_loss: Option<f64>,
    ema_beta: f64,
    /// Logged step curve (the Fig. 3 training-loss series).
    pub steps: Vec<StepPoint>,
    /// Held-out evaluation curve (the Fig. 3 PPL series).
    pub evals: Vec<EvalPoint>,
    /// Executed sync rounds: the realized-H trajectory + trigger reasons.
    pub sync_events: Vec<SyncEvent>,
    /// Per-round participation accounting (empty unless a `[faults]`
    /// scenario is active — one entry per executed sync round then).
    pub fault_events: Vec<FaultEvent>,
    samples_processed: u64,
    comm_bytes: u64,
    syncs: u64,
    /// Label of the collective transport that shipped the traffic
    /// (e.g. "simulated(ps)", "qsgd(s=15)") — set by the trainer so bench
    /// tables can attribute bytes to the transport that produced them.
    transport: String,
    /// Label of the sync policy that scheduled the rounds
    /// (e.g. "fixed(H=4)", "drift(θ=1, H≤64)").
    sync_policy: String,
    /// Buffer-pool counters at run end (leader f32 scratch pool merged
    /// with the wire byte pool) — set by the trainer so runs can check
    /// the zero-steady-state-allocation pools actually warmed up.
    pool_stats: PoolStats,
}

impl TrainRecorder {
    /// Recorder; `steps_per_epoch` defines the epoch axis.
    pub fn new(steps_per_epoch: u64) -> Self {
        assert!(steps_per_epoch >= 1);
        TrainRecorder {
            steps_per_epoch,
            started: Instant::now(),
            ema_loss: None,
            ema_beta: 0.98,
            steps: Vec::new(),
            evals: Vec::new(),
            sync_events: Vec::new(),
            fault_events: Vec::new(),
            samples_processed: 0,
            comm_bytes: 0,
            syncs: 0,
            transport: String::new(),
            sync_policy: String::new(),
            pool_stats: PoolStats::default(),
        }
    }

    /// Record which collective transport this run communicates through.
    pub fn set_transport(&mut self, label: String) {
        self.transport = label;
    }

    /// The collective transport label ("" if never set).
    pub fn transport(&self) -> &str {
        &self.transport
    }

    /// Record which sync policy schedules this run's rounds.
    pub fn set_sync_policy(&mut self, label: String) {
        self.sync_policy = label;
    }

    /// The sync-policy label ("" if never set).
    pub fn sync_policy(&self) -> &str {
        &self.sync_policy
    }

    /// Record the run's final buffer-pool counters (hit/miss/drop).
    pub fn set_pool_stats(&mut self, stats: PoolStats) {
        self.pool_stats = stats;
    }

    /// The recorded buffer-pool counters (all-zero if never set).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool_stats
    }

    /// Epoch coordinate of a step.
    pub fn epoch_of(&self, step: u64) -> f64 {
        step as f64 / self.steps_per_epoch as f64
    }

    /// Record a training step (call every step; point storage only happens
    /// when `log` is true so long runs stay cheap).
    pub fn step(&mut self, step: u64, loss: f64, lr: f32, virtual_s: f64,
                samples: u64, log: bool) {
        self.samples_processed += samples;
        self.ema_loss = Some(match self.ema_loss {
            None => loss,
            Some(e) => self.ema_beta * e + (1.0 - self.ema_beta) * loss,
        });
        if log {
            self.steps.push(StepPoint {
                step,
                epoch: self.epoch_of(step),
                train_loss: loss,
                lr,
                virtual_s,
                wall_s: self.started.elapsed().as_secs_f64(),
            });
        }
    }

    /// Record one sync round's traffic.
    pub fn sync(&mut self, bytes: u64) {
        self.syncs += 1;
        self.comm_bytes += bytes;
    }

    /// Record one executed synchronization *event* — the realized gap
    /// (local steps since the previous round) and the policy's trigger
    /// reason. Kept separate from [`TrainRecorder::sync`]: `sync` counts
    /// accounting rounds (driven by the collective's `CommReport`), events
    /// trace the scheduler's decisions.
    pub fn sync_event(
        &mut self,
        step: u64,
        gap: u64,
        reason: &'static str,
        bytes: u64,
        virtual_s: f64,
    ) {
        self.sync_events.push(SyncEvent { step, gap, reason, bytes, virtual_s });
    }

    /// The realized local-update periods, in order — one gap per executed
    /// round (all equal to H under the fixed policy).
    pub fn realized_h(&self) -> Vec<u64> {
        self.sync_events.iter().map(|e| e.gap).collect()
    }

    /// Record one executed round's participation accounting (fault runs
    /// only — one event per sync round, DESIGN.md §6/§10).
    pub fn fault_event(&mut self, event: FaultEvent) {
        self.fault_events.push(event);
    }

    /// Record a held-out evaluation.
    pub fn eval(&mut self, step: u64, loss: f64, ppl: Option<f64>, virtual_s: f64) {
        self.evals.push(EvalPoint {
            step,
            epoch: self.epoch_of(step),
            loss,
            ppl,
            virtual_s,
            wall_s: self.started.elapsed().as_secs_f64(),
        });
    }

    /// Smoothed training loss.
    pub fn ema_loss(&self) -> Option<f64> {
        self.ema_loss
    }

    /// Total samples processed.
    pub fn samples(&self) -> u64 {
        self.samples_processed
    }

    /// Sync rounds and total bytes shipped.
    pub fn comm(&self) -> (u64, u64) {
        (self.syncs, self.comm_bytes)
    }

    /// Real-time throughput, samples/s (wall-clock).
    pub fn wall_throughput(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt > 0.0 {
            self.samples_processed as f64 / dt
        } else {
            0.0
        }
    }

    /// Write the step curve as CSV.
    pub fn write_steps_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["step", "epoch", "train_loss", "lr", "virtual_s", "wall_s"],
        )?;
        for p in &self.steps {
            w.row(&[
                p.step.to_string(),
                format!("{:.4}", p.epoch),
                format!("{:.6}", p.train_loss),
                format!("{:.6}", p.lr),
                format!("{:.3}", p.virtual_s),
                format!("{:.3}", p.wall_s),
            ])?;
        }
        w.flush()
    }

    /// Write the sync-event log (the realized-H trajectory) as CSV.
    pub fn write_sync_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["step", "gap", "reason", "bytes", "virtual_s"],
        )?;
        for e in &self.sync_events {
            w.row(&[
                e.step.to_string(),
                e.gap.to_string(),
                e.reason.to_string(),
                e.bytes.to_string(),
                format!("{:.3}", e.virtual_s),
            ])?;
        }
        w.flush()
    }

    /// Write the per-round participation log (`faults_<tag>.csv`) — the
    /// fault scenario's observable trace. Deterministic: the same config
    /// seed reproduces the identical file byte-for-byte.
    pub fn write_faults_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "step",
                "alive",
                "participants",
                "dropped",
                "crashes",
                "leaves",
                "joins",
                "wait_s",
                "virtual_s",
            ],
        )?;
        for e in &self.fault_events {
            w.row(&[
                e.step.to_string(),
                e.alive.to_string(),
                e.participants.to_string(),
                e.dropped.to_string(),
                e.crashes.to_string(),
                e.leaves.to_string(),
                e.joins.to_string(),
                format!("{:.6}", e.wait_s),
                format!("{:.3}", e.virtual_s),
            ])?;
        }
        w.flush()
    }

    /// Write the eval curve as CSV.
    pub fn write_evals_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["step", "epoch", "eval_loss", "ppl", "virtual_s", "wall_s"],
        )?;
        for p in &self.evals {
            w.row(&[
                p.step.to_string(),
                format!("{:.4}", p.epoch),
                format!("{:.6}", p.loss),
                p.ppl.map_or(String::new(), |v| format!("{v:.4}")),
                format!("{:.3}", p.virtual_s),
                format!("{:.3}", p.wall_s),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_converges_to_constant_stream() {
        let mut r = TrainRecorder::new(10);
        for s in 1..=500 {
            r.step(s, 2.0, 0.1, 0.0, 4, false);
        }
        assert!((r.ema_loss().unwrap() - 2.0).abs() < 1e-6);
        assert_eq!(r.samples(), 2000);
        assert!(r.steps.is_empty(), "log=false stores nothing");
    }

    #[test]
    fn epoch_axis() {
        let r = TrainRecorder::new(100);
        assert_eq!(r.epoch_of(250), 2.5);
    }

    #[test]
    fn sync_accounting() {
        let mut r = TrainRecorder::new(10);
        r.sync(1024);
        r.sync(1024);
        assert_eq!(r.comm(), (2, 2048));
    }

    #[test]
    fn transport_label_roundtrip() {
        let mut r = TrainRecorder::new(10);
        assert_eq!(r.transport(), "");
        r.set_transport("qsgd(s=15)".into());
        assert_eq!(r.transport(), "qsgd(s=15)");
        assert_eq!(r.sync_policy(), "");
        r.set_sync_policy("fixed(H=4)".into());
        assert_eq!(r.sync_policy(), "fixed(H=4)");
    }

    #[test]
    fn pool_stats_roundtrip() {
        let mut r = TrainRecorder::new(10);
        assert_eq!(r.pool_stats(), PoolStats::default());
        let s = PoolStats { hits: 7, misses: 2, dropped: 1 };
        r.set_pool_stats(s);
        assert_eq!(r.pool_stats(), s);
    }

    #[test]
    fn sync_events_trace_realized_h() {
        let mut r = TrainRecorder::new(10);
        r.sync_event(4, 4, "period", 1024, 1.0);
        r.sync_event(8, 4, "period", 1024, 2.0);
        r.sync_event(11, 3, "drift", 1024, 3.0);
        assert_eq!(r.realized_h(), vec![4, 4, 3]);
        assert_eq!(r.sync_events.len(), 3);
        assert_eq!(r.sync_events[2].reason, "drift");
        // Events don't touch the traffic accounting.
        assert_eq!(r.comm(), (0, 0));
    }

    #[test]
    fn sync_csv_roundtrip() {
        let dir = std::env::temp_dir().join("adaalter_sync_csv_test");
        let p = dir.join("sync.csv");
        let mut r = TrainRecorder::new(10);
        r.sync_event(4, 4, "period", 2048, 1.5);
        r.sync_event(12, 8, "h_max", 2048, 3.0);
        r.write_sync_csv(p.to_str().unwrap()).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s.lines().count(), 3);
        assert!(s.lines().next().unwrap().contains("gap"));
        assert!(s.contains("h_max") && s.contains("2048"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_events_accumulate_and_roundtrip_csv() {
        let dir = std::env::temp_dir().join("adaalter_faults_csv_test");
        let p = dir.join("faults.csv");
        let mut r = TrainRecorder::new(10);
        assert!(r.fault_events.is_empty());
        r.fault_event(FaultEvent {
            step: 4,
            alive: 8,
            participants: 7,
            dropped: 1,
            crashes: 1,
            leaves: 0,
            joins: 0,
            wait_s: 0.551250,
            virtual_s: 1.5,
        });
        r.fault_event(FaultEvent {
            step: 8,
            alive: 8,
            participants: 8,
            dropped: 0,
            crashes: 0,
            leaves: 1,
            joins: 2,
            wait_s: 0.0,
            virtual_s: 3.0,
        });
        assert_eq!(r.fault_events.len(), 2);
        assert_eq!(r.fault_events[0].dropped, 1);
        assert_eq!(r.fault_events[1].joins, 2);
        // Events don't touch the traffic accounting.
        assert_eq!(r.comm(), (0, 0));
        r.write_faults_csv(p.to_str().unwrap()).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s.lines().count(), 3);
        let header = s.lines().next().unwrap();
        assert!(header.contains("participants"));
        assert!(header.contains("crashes") && header.contains("leaves"));
        assert!(header.contains("joins"));
        assert!(s.contains("0.551250"));
        // Row 2: leave and join columns land in the right cells.
        assert!(s.lines().nth(2).unwrap().contains("8,8,8,0,0,1,2,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("adaalter_rec_test");
        let sp = dir.join("steps.csv");
        let ep = dir.join("evals.csv");
        let mut r = TrainRecorder::new(10);
        r.step(1, 3.5, 0.1, 0.5, 4, true);
        r.eval(1, 3.4, Some(30.0), 0.5);
        r.eval(2, 3.3, None, 1.0);
        r.write_steps_csv(sp.to_str().unwrap()).unwrap();
        r.write_evals_csv(ep.to_str().unwrap()).unwrap();
        let steps = std::fs::read_to_string(&sp).unwrap();
        assert!(steps.lines().count() == 2 && steps.contains("3.500000"));
        let evals = std::fs::read_to_string(&ep).unwrap();
        assert!(evals.contains("30.0000"));
        // ppl column empty when None
        assert!(evals.lines().nth(2).unwrap().contains(",,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
