//! Typed experiment configuration (the framework's "config system").
//!
//! A config describes one training experiment end-to-end: which algorithm
//! (paper Alg. 1–4), the cluster shape, the synchronization period H, the
//! compute backend, the network model, the data pipeline, and output paths.
//! Configs load from the TOML subset in [`super::toml`], can be overridden
//! from the CLI (`--set key=value`), and validate eagerly.

use std::fmt;

use crate::error::{Error, Result};

use super::toml::{TomlDoc, TomlValue};

/// The training algorithms of the paper (plus plain SGD for completeness).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Fully-synchronous SGD (gradient averaging every step).
    Sgd,
    /// Algorithm 2: local SGD, parameter averaging every H steps.
    LocalSgd,
    /// Algorithm 1: distributed AdaGrad (baseline).
    AdaGrad,
    /// Algorithm 3: fully-synchronous AdaAlter.
    AdaAlter,
    /// Algorithm 4: local AdaAlter — the paper's contribution.
    LocalAdaAlter,
}

impl Algorithm {
    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s {
            "sgd" => Algorithm::Sgd,
            "local_sgd" => Algorithm::LocalSgd,
            "adagrad" => Algorithm::AdaGrad,
            "adaalter" => Algorithm::AdaAlter,
            "local_adaalter" => Algorithm::LocalAdaAlter,
            other => {
                return Err(Error::Config(format!(
                    "unknown algorithm {other:?} (expected one of sgd, \
                     local_sgd, adagrad, adaalter, local_adaalter)"
                )))
            }
        })
    }

    /// Config-file spelling.
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Sgd => "sgd",
            Algorithm::LocalSgd => "local_sgd",
            Algorithm::AdaGrad => "adagrad",
            Algorithm::AdaAlter => "adaalter",
            Algorithm::LocalAdaAlter => "local_adaalter",
        }
    }

    /// Does the algorithm skip synchronization rounds (H > 1 meaningful)?
    pub fn is_local(self) -> bool {
        matches!(self, Algorithm::LocalSgd | Algorithm::LocalAdaAlter)
    }

    /// Does the algorithm synchronize optimizer state (denominators) too?
    /// Local AdaAlter ships 2 vectors per sync (the paper's 2/H factor);
    /// local SGD ships 1.
    pub fn syncs_denominator(self) -> bool {
        matches!(self, Algorithm::LocalAdaAlter)
    }
}

impl fmt::Display for Algorithm {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Synchronization period H. `Infinite` reproduces the paper's
/// "Local AdaAlter, H = +∞" baseline (communication removed entirely).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncPeriod {
    /// Synchronize every H-th iteration (H ≥ 1).
    Every(u64),
    /// Never synchronize (the paper's communication-free baseline).
    Infinite,
}

impl SyncPeriod {
    /// From a float (TOML `inf` maps to `Infinite`).
    pub fn from_f64(v: f64) -> Result<SyncPeriod> {
        if v.is_infinite() && v > 0.0 {
            Ok(SyncPeriod::Infinite)
        } else if v >= 1.0 && v.fract() == 0.0 && v <= u64::MAX as f64 {
            Ok(SyncPeriod::Every(v as u64))
        } else {
            Err(Error::Config(format!("sync period H must be >=1 integer or inf, got {v}")))
        }
    }

    /// Steps between syncs, or `None` for never.
    pub fn period(self) -> Option<u64> {
        match self {
            SyncPeriod::Every(h) => Some(h),
            SyncPeriod::Infinite => None,
        }
    }
}

impl fmt::Display for SyncPeriod {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SyncPeriod::Every(h) => write!(f, "{h}"),
            SyncPeriod::Infinite => write!(f, "inf"),
        }
    }
}

/// Compute backend for worker gradients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Load `artifacts/*.hlo.txt` and run the real LM through PJRT.
    Pjrt,
    /// Pure-rust synthetic workload (non-IID least-squares); no artifacts
    /// needed. Used by unit/property tests and the comm-only benches.
    RustMath,
}

impl Backend {
    /// Parse config spelling.
    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "pjrt" => Backend::Pjrt,
            "rust_math" => Backend::RustMath,
            other => {
                return Err(Error::Config(format!(
                    "unknown backend {other:?} (expected pjrt or rust_math)"
                )))
            }
        })
    }
}

/// Optimizer hyperparameters (paper §6.2–6.3 defaults).
#[derive(Clone, Debug)]
pub struct OptimConfig {
    /// Which of the paper's algorithms (Alg. 1–4, plus plain SGD) to run.
    pub algorithm: Algorithm,
    /// Base learning rate η (paper: 0.5 for 8×256).
    pub eta: f32,
    /// ε — numerical stability / local placeholder constant (paper: 1).
    pub epsilon: f32,
    /// b₀ — accumulator initialisation (paper: 1).
    pub b0: f32,
    /// Warm-up steps (paper §6.2.1: 600; 0 disables).
    pub warmup_steps: u64,
    /// Momentum for the SGD baselines (0 = vanilla).
    pub momentum: f32,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig {
            algorithm: Algorithm::LocalAdaAlter,
            eta: crate::paper::ETA,
            epsilon: crate::paper::EPSILON,
            b0: crate::paper::B0,
            warmup_steps: crate::paper::WARM_UP_STEPS,
            momentum: 0.0,
        }
    }
}

/// Cluster / schedule parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model preset name (must exist in the artifact manifest for PJRT).
    pub preset: String,
    /// Number of workers n.
    pub workers: usize,
    /// Synchronization period H.
    pub sync_period: SyncPeriod,
    /// Total training steps T (per worker).
    pub steps: u64,
    /// Steps per "epoch" for reporting (paper: 20,000).
    pub steps_per_epoch: u64,
    /// Evaluate test PPL every this many steps (0 = only at end).
    pub eval_every: u64,
    /// Log training metrics every this many steps.
    pub log_every: u64,
    /// Experiment seed (controls data, init noise, everything).
    pub seed: u64,
    /// Gradient backend.
    pub backend: Backend,
    /// Problem dimension for the rust_math backend.
    pub rust_math_dim: usize,
    /// Save a checkpoint every this many steps (0 = off). For local
    /// algorithms this must be a multiple of H — snapshots are taken at
    /// synchronization boundaries, where every replica agrees.
    pub checkpoint_every: u64,
    /// Checkpoint file path ("" = `<out_dir>/checkpoint.bin`).
    pub checkpoint_path: String,
    /// Use the backend's fused local-step device path when available
    /// (the trainer may still disable it at runtime for sync policies
    /// that need per-step observations). Partial-participation rounds
    /// (`faults.quorum` / `faults.drop_slowest`) require `false`.
    pub fused: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "tiny".into(),
            workers: 8,
            sync_period: SyncPeriod::Every(4),
            steps: 400,
            steps_per_epoch: 100,
            eval_every: 0,
            log_every: 20,
            seed: 42,
            backend: Backend::RustMath,
            rust_math_dim: 4096,
            checkpoint_every: 0,
            checkpoint_path: String::new(),
            fused: true,
        }
    }
}

/// Data-pipeline parameters (synthetic corpus; DESIGN.md §12).
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// Zipf exponent of the unigram distribution.
    pub zipf_s: f64,
    /// Markov order-1 mixing weight (0 = iid unigrams, 1 = deterministic).
    pub markov: f64,
    /// Non-IID skew across workers in [0,1]: 0 = IID shards, 1 = fully
    /// disjoint topic per worker (the paper's D_i ≠ D_j setting).
    pub noniid: f64,
    /// Held-out evaluation batches.
    pub eval_batches: usize,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig { zipf_s: 1.1, markov: 0.85, noniid: 0.5, eval_batches: 8 }
    }
}

/// Network-simulation parameters (DESIGN.md §3; calibrated in sim::calib).
///
/// Defaults match the paper-fitted V100/NVLink parameter-server constants
/// (132 GB/s ≈ 1056 Gbit/s aggregate, 50 µs latency) so `train` runs charge
/// the same virtual time the Fig. 1/2 analytic model uses. Override for
/// commodity-network studies (e.g. `net.bandwidth_gbps = 10`).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Topology: "ps" (paper's parameter-server), "allreduce" (ring) or
    /// "tree" (hierarchical reduce+broadcast over a fan-out-f tree).
    pub topology: String,
    /// Tree topology: children per node (fan-out f ≥ 2); depth is
    /// ⌈log_f n⌉. Ignored by "ps" / "allreduce".
    pub tree_fanout: usize,
    /// Per-message latency α (microseconds).
    pub latency_us: f64,
    /// Per-link bandwidth β (Gbit/s).
    pub bandwidth_gbps: f64,
    /// Server ingress bandwidth shared by concurrent senders (PS incast).
    pub server_bandwidth_gbps: f64,
    /// Data-loading capacity of the host, samples/s (paper §6.4 bottleneck);
    /// 0 disables the dataloader model.
    pub dataloader_samples_per_s: f64,
    /// Networked transport (DESIGN.md §4), leader side: the address to
    /// bind — "host:port" for `comm.transport = "tcp"` ("…:0" picks a free
    /// port, published via `--port-file`), a socket path for "uds".
    pub listen: String,
    /// Networked transport, worker side: the leader address to dial
    /// (same forms as `listen`; `--connect` / `--port-file` override).
    pub connect: String,
    /// Budget for a worker reaching the leader (connect retries plus
    /// port-file polling) and for the leader's accept loop, seconds.
    pub connect_timeout_s: f64,
    /// Connection attempts a worker makes before giving up.
    pub connect_retries: u32,
    /// Linear backoff between connection attempts, seconds (attempt k
    /// waits k × this).
    pub retry_backoff_s: f64,
    /// Set TCP_NODELAY on connections (no-op for "uds"). The lockstep
    /// protocol is latency-bound, so this defaults on.
    pub nodelay: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            topology: "ps".into(),
            tree_fanout: 2,
            latency_us: 50.0,
            bandwidth_gbps: 1056.0,
            server_bandwidth_gbps: 1056.0,
            dataloader_samples_per_s: 8830.0,
            listen: String::new(),
            connect: String::new(),
            connect_timeout_s: 30.0,
            connect_retries: 10,
            retry_backoff_s: 0.05,
            nodelay: true,
        }
    }
}

/// Collective-communication transport selection (DESIGN.md §3).
///
/// * `transport = "simulated"` (default) routes the lockstep channel ops
///   through the α–β cost model: virtual time and traffic are charged per
///   collective op exactly as the paper's parameter-server / ring
///   all-reduce would cost them.
/// * `transport = "channel"` is the bare in-process lockstep: identical
///   data path, zero modeled cost (for equivalence tests and wire-exact
///   compressed accounting).
/// * `transport = "tcp" | "uds"` runs the same lockstep protocol over
///   real sockets between OS processes (DESIGN.md §4) — the leader is
///   started with `--role leader`, workers with `--role worker`, and the
///   `[net]` addresses wire them together. Bitwise-identical to the
///   in-process run; billed bytes are the actual socket payloads.
/// * `compression = "qsgd" | "topk"` decorates the transport with QSGD
///   stochastic quantization / top-k sparsification with error feedback;
///   recorded bytes are then the *exact* encoded wire sizes.
#[derive(Clone, Debug)]
pub struct CommConfig {
    /// "simulated" (α–β-charged, default) or "channel" (bare lockstep).
    pub transport: String,
    /// "none" (default), "qsgd" or "topk".
    pub compression: String,
    /// Leader shards k (range partition of the parameter vector across k
    /// parallel shard servers, DESIGN.md §3). 1 (default) is the single
    /// leader, bitwise-identical to the pre-sharding runs; k > 1 requires
    /// `net.topology = "ps"` and an elementwise codec
    /// (`comm.compression = "none"`; f32/bf16 wire both compose).
    pub shards: usize,
    /// Sync-round software-pipeline depth (DESIGN.md §"Pipelined sync
    /// rounds"). 0 (default) keeps today's strictly-serial round; depth
    /// d ≥ 1 lets up to d shards be in flight at once — shard *i*
    /// reducing on the leader while shard *i+1* is still arriving and
    /// shard *i−1* is being encoded and written out — and turns on frame
    /// coalescing + vectored submission in the socket writer threads.
    /// Pure scheduling: pipelined runs are bitwise-identical to
    /// `pipeline = 0` (per-shard reduction order is unchanged), so this
    /// knob is excluded from the config fingerprint like `[exec]`.
    pub pipeline: usize,
    /// QSGD quantization levels s (1..=127). Default 15 → 2s+1 = 31
    /// symbols → 5-bit codes per coordinate on the wire.
    pub qsgd_levels: u8,
    /// Fraction of coordinates top-k keeps per message (0, 1].
    pub topk_keep: f64,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig {
            transport: "simulated".into(),
            compression: "none".into(),
            shards: 1,
            pipeline: 0,
            qsgd_levels: 15,
            topk_keep: 0.01,
        }
    }
}

impl CommConfig {
    /// The `[comm]` consistency rules — the single copy shared by
    /// [`ExperimentConfig::validate`] and
    /// [`crate::comm::collective::build_collective`] (which guards
    /// programmatically-built configs that never pass through TOML
    /// validation).
    pub fn validate(&self) -> Result<()> {
        match self.transport.as_str() {
            "simulated" | "channel" | "tcp" | "uds" => {}
            other => {
                return Err(Error::Config(format!(
                    "comm.transport must be \"simulated\", \"channel\", \"tcp\" or \"uds\", \
                     got {other:?}"
                )))
            }
        }
        match self.compression.as_str() {
            "none" => {}
            "qsgd" => {
                if self.transport == "simulated" {
                    return Err(Error::Config(
                        "compressed transports measure exact wire bytes; \
                         set comm.transport = \"channel\" (or \"tcp\"/\"uds\" — the \
                         simulated α–β charge assumes dense vectors)"
                            .into(),
                    ));
                }
            }
            "topk" => {
                if self.transport != "channel" {
                    return Err(Error::Config(
                        "comm.compression = \"topk\" measures exact wire bytes over \
                         the in-process lockstep; set comm.transport = \"channel\" \
                         (the sparse index sets are not delta-coded for the \
                         networked wire)"
                            .into(),
                    ));
                }
            }
            other => {
                return Err(Error::Config(format!(
                    "comm.compression must be \"none\", \"qsgd\" or \"topk\", got {other:?}"
                )))
            }
        }
        if !(1..=64).contains(&self.shards) {
            // The wire tags shard indices in the 7 free frame-flag bits;
            // 64 leaves headroom and is far past the useful range.
            return Err(Error::Config(format!(
                "comm.shards must be in 1..=64, got {}",
                self.shards
            )));
        }
        if self.shards > 1 && self.compression != "none" {
            // QSGD normalizes by the whole-vector norm and top-k selects
            // globally: neither commutes with a range partition, so the
            // sharded result would not be bitwise-equal to the dense run.
            return Err(Error::Config(format!(
                "comm.shards > 1 requires comm.compression = \"none\" \
                 (got {:?}; qsgd/topk quantize against whole-vector state \
                 and do not commute with a range partition)",
                self.compression
            )));
        }
        if self.pipeline > 16 {
            // Each in-flight shard pins a staging buffer on the leader
            // and every writer thread; past the shard count extra depth
            // buys nothing, and 16 is already past any useful k.
            return Err(Error::Config(format!(
                "comm.pipeline must be in 0..=16, got {}",
                self.pipeline
            )));
        }
        if !(1..=127).contains(&self.qsgd_levels) {
            return Err(Error::Config(format!(
                "comm.qsgd_levels must be in 1..=127, got {}",
                self.qsgd_levels
            )));
        }
        if !(self.topk_keep > 0.0 && self.topk_keep <= 1.0) {
            return Err(Error::Config(format!(
                "comm.topk_keep must be in (0, 1], got {}",
                self.topk_keep
            )));
        }
        Ok(())
    }

    /// Is a real multi-process socket transport selected (DESIGN.md §4)?
    pub fn networked(&self) -> bool {
        matches!(self.transport.as_str(), "tcp" | "uds")
    }
}

/// Synchronization-policy selection (DESIGN.md §5).
///
/// The `[sync]` section picks *when* local algorithms communicate —
/// `[train].sync_period` stays the (initial) H:
///
/// * `policy = "fixed"` (default) — the paper's `mod(t, H)` schedule,
///   bitwise-identical to the pre-policy trainer.
/// * `policy = "growing"` — H multiplies by `grow_factor` after every
///   `grow_every` sync rounds, capped at `h_max` (Stich-style).
/// * `policy = "drift"` — CADA-style: sync when the accumulated
///   local-update drift proxy crosses `drift_threshold`, hard-capped at
///   `h_max` local steps.
/// * `policy = "time_budget"` — re-derive H after every round so modeled
///   communication stays at `target_comm_fraction` of virtual wall-clock.
#[derive(Clone, Debug)]
pub struct SyncConfig {
    /// "fixed" (default), "growing", "drift" or "time_budget".
    pub policy: String,
    /// Hard cap on the local-update period for adaptive policies.
    pub h_max: u64,
    /// Growing policy: multiply H by this per growth step (> 1).
    pub grow_factor: f64,
    /// Growing policy: grow after this many sync rounds (≥ 1).
    pub grow_every: u64,
    /// Drift policy: accumulated `Σ‖Δx‖²` that triggers a round (> 0).
    pub drift_threshold: f64,
    /// Time-budget policy: target comm share of wall-clock, in (0, 1).
    pub target_comm_fraction: f64,
}

impl Default for SyncConfig {
    fn default() -> Self {
        SyncConfig {
            policy: "fixed".into(),
            h_max: 64,
            grow_factor: 2.0,
            grow_every: 1,
            drift_threshold: 1.0,
            target_comm_fraction: 0.05,
        }
    }
}

impl SyncConfig {
    /// The `[sync]` self-contained bounds — shared by
    /// [`ExperimentConfig::validate`] and
    /// [`crate::coordinator::sync::build_policy`] (which guards
    /// programmatically-built configs that never pass through TOML
    /// validation), mirroring the [`CommConfig::validate`] pattern.
    pub fn validate(&self) -> Result<()> {
        match self.policy.as_str() {
            "fixed" | "growing" | "drift" | "time_budget" => {}
            other => {
                return Err(Error::Config(format!(
                    "sync.policy must be \"fixed\", \"growing\", \"drift\" or \
                     \"time_budget\", got {other:?}"
                )))
            }
        }
        if self.h_max < 1 {
            return Err(Error::Config("sync.h_max must be >= 1".into()));
        }
        if !(self.grow_factor > 1.0 && self.grow_factor.is_finite()) {
            return Err(Error::Config(format!(
                "sync.grow_factor must be a finite value > 1, got {}",
                self.grow_factor
            )));
        }
        if self.grow_every < 1 {
            return Err(Error::Config("sync.grow_every must be >= 1".into()));
        }
        if !(self.drift_threshold > 0.0 && self.drift_threshold.is_finite()) {
            return Err(Error::Config(format!(
                "sync.drift_threshold must be a finite value > 0, got {}",
                self.drift_threshold
            )));
        }
        if !(self.target_comm_fraction > 0.0 && self.target_comm_fraction < 1.0) {
            return Err(Error::Config(format!(
                "sync.target_comm_fraction must be in (0, 1), got {}",
                self.target_comm_fraction
            )));
        }
        Ok(())
    }

    /// Is this the (default) fixed-period schedule?
    pub fn is_fixed(&self) -> bool {
        self.policy == "fixed"
    }
}

/// Execution-engine selection (DESIGN.md §7): how worker computation maps
/// onto OS threads. Purely a wall-clock knob — every layout is
/// bitwise-identical (worker streams are pure functions of
/// `(seed, worker, step)` and all leader-side reductions are fixed-order),
/// which `rust/tests/integration_exec.rs` pins.
///
/// * `parallelism = "threads"` — workers spread round-robin across
///   `threads` host threads. The default, with `threads = 0` (one host
///   thread per worker): exactly the thread shape every run had before
///   the engine existed, so configs without an `[exec]` section keep
///   both their results (bitwise) and their parallelism.
/// * `parallelism = "threads(k)"` — shorthand carrying the count.
/// * `parallelism = "serial"` — all workers hosted on one engine thread,
///   stepping in worker order (the reference layout the equivalence
///   tests compare against).
#[derive(Clone, Debug)]
pub struct ExecConfig {
    /// "threads" (default), "threads(k)" or "serial".
    pub parallelism: String,
    /// Host-thread count for `parallelism = "threads"` (0 = one per
    /// worker, the default).
    pub threads: usize,
    /// Kernel dispatch: "auto" (default; `ADAALTER_SIMD` env decides,
    /// on when unset), "on" or "off". Pure wall-clock knob — the SIMD
    /// and serial kernels are bitwise-identical (DESIGN.md §8).
    pub simd: String,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig { parallelism: "threads".into(), threads: 0, simd: "auto".into() }
    }
}

impl ExecConfig {
    /// The `[exec]` consistency rules — the spellings must resolve to a
    /// thread layout and a SIMD dispatch mode. One copy shared by
    /// [`ExperimentConfig::validate`] and the trainer (which re-resolves
    /// for programmatically-built configs), mirroring the
    /// [`CommConfig::validate`] pattern.
    pub fn validate(&self) -> Result<()> {
        crate::coordinator::executor::Parallelism::from_config(self).map(|_| ())?;
        crate::util::simd::SimdMode::from_config(self).map(|_| ())
    }
}

/// Mixed-precision selection (`[precision]`, DESIGN.md §8). With the
/// section absent both knobs default to `"f32"` and every code path is
/// bitwise-identical to the seed.
///
/// * `wire = "bf16"` — sync-round / gather payloads travel as bf16
///   (round-to-nearest-even), exactly halving recorded wire bytes;
///   composes with the delta coding of the compressed collective.
///   Requires `comm.transport = "channel"` (or the networked `"tcp"` /
///   `"uds"`) with `comm.compression = "none"` — like QSGD/top-k, the
///   bf16 codec measures exact wire bytes, and stacking two lossy codecs
///   would double-quantize.
/// * `state = "bf16"` — optimizer accumulator state (`b2` / `acc`) is
///   rounded through bf16 after every update while the weights stay f32
///   (master weights). Value-exact emulation: storage remains f32, but
///   every stored value is exactly bf16-representable.
#[derive(Clone, Debug)]
pub struct PrecisionConfig {
    /// Sync-payload wire format: "f32" (default) or "bf16".
    pub wire: String,
    /// Optimizer accumulator state: "f32" (default) or "bf16".
    pub state: String,
}

impl Default for PrecisionConfig {
    fn default() -> Self {
        PrecisionConfig { wire: "f32".into(), state: "f32".into() }
    }
}

impl PrecisionConfig {
    /// Self-contained `[precision]` spellings check.
    pub fn validate(&self) -> Result<()> {
        for (key, v) in [("precision.wire", &self.wire), ("precision.state", &self.state)] {
            if v != "f32" && v != "bf16" {
                return Err(Error::Config(format!(
                    "{key} must be \"f32\" or \"bf16\", got {v:?}"
                )));
            }
        }
        Ok(())
    }

    /// Is the bf16 wire format selected?
    pub fn wire_bf16(&self) -> bool {
        self.wire == "bf16"
    }

    /// Is the bf16 optimizer state selected?
    pub fn state_bf16(&self) -> bool {
        self.state == "bf16"
    }

    /// The `[precision]` × `[comm]` cross-rule (single copy — also re-run
    /// by `build_collective` for programmatically-built configs): the bf16
    /// wire, like QSGD/top-k, measures exact bytes over the bare channel;
    /// the simulated α–β charge assumes dense f32 vectors, and stacking
    /// bf16 under another lossy codec would double-quantize.
    pub fn validate_with_comm(&self, comm: &CommConfig) -> Result<()> {
        if self.wire_bf16()
            && ((comm.transport != "channel" && !comm.networked())
                || comm.compression != "none")
        {
            return Err(Error::Config(
                "precision.wire = \"bf16\" measures exact wire bytes; set \
                 comm.transport = \"channel\" (or \"tcp\"/\"uds\") with \
                 comm.compression = \"none\""
                    .into(),
            ));
        }
        Ok(())
    }
}

/// Deterministic fault/straggler scenario + partial-participation policy
/// (DESIGN.md §6). With the section absent (all defaults) every fault
/// code path is disabled and the trainer is bitwise-identical to the
/// fault-free leader loop.
///
/// Scenario (compiled into a seeded [`crate::sim::FaultPlan`]):
///
/// * `slow_workers` / `slow_factor` — the N *highest* worker ids run
///   their compute `slow_factor`× slower, permanently.
/// * `stall_prob` / `stall_s` — per `(worker, step)`, with probability
///   `stall_prob`, a transient stall of `stall_s` virtual seconds
///   (seeded by `train.seed`, keyed like the gradient streams).
/// * `crash_worker` / `crash_step` — worker `crash_worker` (−1 = none)
///   dies permanently at iteration `crash_step`.
///
/// Elastic membership (DESIGN.md "Elastic membership & recovery"):
///
/// * `rejoin_step` — the crashed worker comes back at this step,
///   re-admitted at the next sync boundary via `InstallState` (0 = never).
/// * `spawn_workers` / `spawn_step` — the N *highest* worker ids start
///   absent and join at `spawn_step` (`spawn_step = 0` queues them as
///   autoscale spares).
/// * `autoscale` + `autoscale_patience` / `autoscale_straggler_s` /
///   `autoscale_drift` — telemetry-driven membership: admit queued spares
///   on persistently healthy high-drift rounds, drop the slowest worker
///   after persistently congested rounds.
///
/// Participation policy for synchronization rounds (local algorithms):
///
/// * `quorum` — close a round once this many live workers arrived, then
///   wait at most `timeout_s` more (virtual) before dropping the rest;
///   stragglers skip the average but still receive the installed state
///   (catch-up). 0 = full barrier.
/// * `drop_slowest` — backup-worker policy: always drop the k slowest
///   arrivals of each round. Mutually exclusive with `quorum`.
#[derive(Clone, Debug)]
pub struct FaultsConfig {
    /// How many of the highest worker ids are permanently slowed (0 = none).
    pub slow_workers: usize,
    /// Compute-time multiplier for slowed workers (≥ 1).
    pub slow_factor: f64,
    /// Per-(worker, step) transient-stall probability, in [0, 1).
    pub stall_prob: f64,
    /// Virtual seconds one transient stall costs (> 0 when `stall_prob` > 0).
    pub stall_s: f64,
    /// Worker id to crash permanently (−1 = none).
    pub crash_worker: i64,
    /// Iteration (1-based) at which `crash_worker` dies.
    pub crash_step: u64,
    /// Minimum live workers that close a sync round (0 = full barrier).
    pub quorum: usize,
    /// Extra virtual seconds to wait after the quorum arrives before
    /// dropping stragglers from the round.
    pub timeout_s: f64,
    /// Backup-worker policy: drop the k slowest arrivals each round (0 = off).
    pub drop_slowest: usize,
    /// Step (1-based, > `crash_step`) at which the crashed worker rejoins
    /// the live set — re-admitted at the next sync boundary and
    /// warm-started via `InstallState`. 0 = the crash is permanent.
    pub rejoin_step: u64,
    /// How many of the *highest* worker ids start absent and join later
    /// (scheduled scale-up, or queued autoscale spares). 0 = none.
    pub spawn_workers: usize,
    /// Step (1-based) at which spawned workers join. 0 queues them as
    /// spares that only the autoscale policy admits (requires
    /// `autoscale = true`).
    pub spawn_step: u64,
    /// Telemetry-driven elastic membership: consume the per-round
    /// drift/straggler observations to admit queued spares and drop
    /// persistent stragglers at sync boundaries.
    pub autoscale: bool,
    /// Consecutive rounds a trigger condition must persist before the
    /// autoscale policy acts (≥ 1).
    pub autoscale_patience: u64,
    /// Straggler-spread threshold, virtual seconds: rounds whose barrier
    /// wait exceeds this count toward dropping the slowest worker.
    pub autoscale_straggler_s: f64,
    /// Drift threshold (accumulated Σ‖Δx‖² per round): healthy rounds at
    /// or above it count toward admitting a queued spare.
    pub autoscale_drift: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            slow_workers: 0,
            slow_factor: 4.0,
            stall_prob: 0.0,
            stall_s: 0.05,
            crash_worker: -1,
            crash_step: 0,
            quorum: 0,
            timeout_s: 0.0,
            drop_slowest: 0,
            rejoin_step: 0,
            spawn_workers: 0,
            spawn_step: 0,
            autoscale: false,
            autoscale_patience: 2,
            autoscale_straggler_s: 0.05,
            autoscale_drift: 0.0,
        }
    }
}

impl FaultsConfig {
    /// Does the section schedule any fault or engage partial participation?
    pub fn is_active(&self) -> bool {
        self.slow_workers > 0
            || self.stall_prob > 0.0
            || self.crash_worker >= 0
            || self.partial()
            || self.has_churn()
    }

    /// Is a partial-participation policy (quorum / backup-worker) selected?
    pub fn partial(&self) -> bool {
        self.quorum > 0 || self.drop_slowest > 0
    }

    /// Does the section schedule elastic membership — a rejoin, spawned
    /// workers, or the telemetry-driven autoscale policy?
    pub fn has_churn(&self) -> bool {
        self.rejoin_step > 0 || self.spawn_workers > 0 || self.autoscale
    }

    /// The `[faults]` self-contained bounds — shared by
    /// [`ExperimentConfig::validate`] and the trainer's programmatic-config
    /// guard, mirroring the [`CommConfig::validate`] pattern. Cross-field
    /// rules (worker counts, algorithm family, fused path, checkpointing)
    /// live in [`ExperimentConfig::validate_faults`].
    pub fn validate(&self) -> Result<()> {
        if !(self.slow_factor >= 1.0 && self.slow_factor.is_finite()) {
            return Err(Error::Config(format!(
                "faults.slow_factor must be a finite value >= 1, got {}",
                self.slow_factor
            )));
        }
        if !(0.0..1.0).contains(&self.stall_prob) {
            return Err(Error::Config(format!(
                "faults.stall_prob must be in [0, 1), got {}",
                self.stall_prob
            )));
        }
        if !(self.stall_s >= 0.0 && self.stall_s.is_finite()) {
            return Err(Error::Config(format!(
                "faults.stall_s must be a finite value >= 0, got {}",
                self.stall_s
            )));
        }
        if self.stall_prob > 0.0 && self.stall_s <= 0.0 {
            return Err(Error::Config(
                "faults.stall_s must be > 0 when faults.stall_prob > 0".into(),
            ));
        }
        if self.crash_worker < -1 {
            return Err(Error::Config(format!(
                "faults.crash_worker must be -1 (none) or a worker id, got {}",
                self.crash_worker
            )));
        }
        if self.crash_worker >= 0 && self.crash_step < 1 {
            return Err(Error::Config(
                "faults.crash_step must be >= 1 when faults.crash_worker is set".into(),
            ));
        }
        if !(self.timeout_s >= 0.0 && self.timeout_s.is_finite()) {
            return Err(Error::Config(format!(
                "faults.timeout_s must be a finite value >= 0, got {}",
                self.timeout_s
            )));
        }
        if self.quorum > 0 && self.drop_slowest > 0 {
            return Err(Error::Config(
                "faults.quorum and faults.drop_slowest are mutually exclusive \
                 participation policies (set one of them to 0)"
                    .into(),
            ));
        }
        if self.rejoin_step > 0 {
            if self.crash_worker < 0 {
                return Err(Error::Config(
                    "faults.rejoin_step requires faults.crash_worker \
                     (only a crashed worker can rejoin)"
                        .into(),
                ));
            }
            if self.rejoin_step <= self.crash_step {
                return Err(Error::Config(format!(
                    "faults.rejoin_step ({}) must be > faults.crash_step ({})",
                    self.rejoin_step, self.crash_step
                )));
            }
        }
        if self.spawn_workers > 0 && self.spawn_step == 0 && !self.autoscale {
            return Err(Error::Config(
                "faults.spawn_step must be >= 1 when faults.spawn_workers is \
                 set (or faults.autoscale = true to queue them as spares)"
                    .into(),
            ));
        }
        if self.autoscale && self.autoscale_patience < 1 {
            return Err(Error::Config(
                "faults.autoscale_patience must be >= 1".into(),
            ));
        }
        if !(self.autoscale_straggler_s >= 0.0 && self.autoscale_straggler_s.is_finite()) {
            return Err(Error::Config(format!(
                "faults.autoscale_straggler_s must be a finite value >= 0, got {}",
                self.autoscale_straggler_s
            )));
        }
        if !(self.autoscale_drift >= 0.0 && self.autoscale_drift.is_finite()) {
            return Err(Error::Config(format!(
                "faults.autoscale_drift must be a finite value >= 0, got {}",
                self.autoscale_drift
            )));
        }
        Ok(())
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Cluster shape / schedule (`[train]`).
    pub train: TrainConfig,
    /// Optimizer hyperparameters (`[optim]`).
    pub optim: OptimConfig,
    /// Synthetic data pipeline (`[data]`).
    pub data: DataConfig,
    /// Network cost model (`[net]`).
    pub net: NetConfig,
    /// Collective-transport selection (`[comm]`).
    pub comm: CommConfig,
    /// Synchronization-policy selection (`[sync]`).
    pub sync: SyncConfig,
    /// Fault scenario + partial-participation policy (`[faults]`).
    pub faults: FaultsConfig,
    /// Execution-engine thread layout (`[exec]`).
    pub exec: ExecConfig,
    /// Mixed-precision selection (`[precision]`).
    pub precision: PrecisionConfig,
    /// Directory for CSV/JSONL outputs.
    pub out_dir: String,
    /// Artifact directory (PJRT backend).
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            train: TrainConfig::default(),
            optim: OptimConfig::default(),
            data: DataConfig::default(),
            net: NetConfig::default(),
            comm: CommConfig::default(),
            sync: SyncConfig::default(),
            faults: FaultsConfig::default(),
            exec: ExecConfig::default(),
            precision: PrecisionConfig::default(),
            out_dir: "results".into(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

/// All dotted keys the config system accepts — `ensure_known_keys` guard.
pub const KNOWN_KEYS: &[&str] = &[
    "out_dir",
    "artifacts_dir",
    "train.preset",
    "train.workers",
    "train.sync_period",
    "train.steps",
    "train.steps_per_epoch",
    "train.eval_every",
    "train.log_every",
    "train.seed",
    "train.backend",
    "train.rust_math_dim",
    "train.checkpoint_every",
    "train.checkpoint_path",
    "train.fused",
    "optim.algorithm",
    "optim.eta",
    "optim.epsilon",
    "optim.b0",
    "optim.warmup_steps",
    "optim.momentum",
    "data.zipf_s",
    "data.markov",
    "data.noniid",
    "data.eval_batches",
    "net.topology",
    "net.tree_fanout",
    "net.latency_us",
    "net.bandwidth_gbps",
    "net.server_bandwidth_gbps",
    "net.dataloader_samples_per_s",
    "net.listen",
    "net.connect",
    "net.connect_timeout_s",
    "net.connect_retries",
    "net.retry_backoff_s",
    "net.nodelay",
    "comm.transport",
    "comm.compression",
    "comm.shards",
    "comm.pipeline",
    "comm.qsgd_levels",
    "comm.topk_keep",
    "sync.policy",
    "sync.h_max",
    "sync.grow_factor",
    "sync.grow_every",
    "sync.drift_threshold",
    "sync.target_comm_fraction",
    "faults.slow_workers",
    "faults.slow_factor",
    "faults.stall_prob",
    "faults.stall_s",
    "faults.crash_worker",
    "faults.crash_step",
    "faults.quorum",
    "faults.timeout_s",
    "faults.drop_slowest",
    "faults.rejoin_step",
    "faults.spawn_workers",
    "faults.spawn_step",
    "faults.autoscale",
    "faults.autoscale_patience",
    "faults.autoscale_straggler_s",
    "faults.autoscale_drift",
    "exec.parallelism",
    "exec.threads",
    "exec.simd",
    "precision.wire",
    "precision.state",
];

impl ExperimentConfig {
    /// Build from a parsed TOML document (defaults fill gaps; unknown keys
    /// rejected; then validated).
    pub fn from_doc(doc: &TomlDoc) -> Result<ExperimentConfig> {
        doc.ensure_known_keys(KNOWN_KEYS)?;
        let mut c = ExperimentConfig {
            out_dir: doc.str_or("out_dir", "results")?,
            artifacts_dir: doc.str_or("artifacts_dir", "artifacts")?,
            ..Default::default()
        };

        c.train.preset = doc.str_or("train.preset", &c.train.preset)?;
        c.train.workers = doc.int_or("train.workers", c.train.workers as i64)? as usize;
        if let Some(v) = doc.get("train.sync_period") {
            c.train.sync_period = SyncPeriod::from_f64(v.float()?)?;
        }
        c.train.steps = doc.int_or("train.steps", c.train.steps as i64)? as u64;
        c.train.steps_per_epoch =
            doc.int_or("train.steps_per_epoch", c.train.steps_per_epoch as i64)? as u64;
        c.train.eval_every = doc.int_or("train.eval_every", c.train.eval_every as i64)? as u64;
        c.train.log_every = doc.int_or("train.log_every", c.train.log_every as i64)? as u64;
        c.train.seed = doc.int_or("train.seed", c.train.seed as i64)? as u64;
        c.train.backend = Backend::parse(&doc.str_or("train.backend", "rust_math")?)?;
        c.train.rust_math_dim =
            doc.int_or("train.rust_math_dim", c.train.rust_math_dim as i64)? as usize;
        c.train.checkpoint_every =
            doc.int_or("train.checkpoint_every", c.train.checkpoint_every as i64)? as u64;
        c.train.checkpoint_path =
            doc.str_or("train.checkpoint_path", &c.train.checkpoint_path)?;
        c.train.fused = doc.bool_or("train.fused", c.train.fused)?;

        if let Some(v) = doc.get("optim.algorithm") {
            c.optim.algorithm = Algorithm::parse(v.str()?)?;
        }
        c.optim.eta = doc.float_or("optim.eta", c.optim.eta as f64)? as f32;
        c.optim.epsilon = doc.float_or("optim.epsilon", c.optim.epsilon as f64)? as f32;
        c.optim.b0 = doc.float_or("optim.b0", c.optim.b0 as f64)? as f32;
        c.optim.warmup_steps =
            doc.int_or("optim.warmup_steps", c.optim.warmup_steps as i64)? as u64;
        c.optim.momentum = doc.float_or("optim.momentum", c.optim.momentum as f64)? as f32;

        c.data.zipf_s = doc.float_or("data.zipf_s", c.data.zipf_s)?;
        c.data.markov = doc.float_or("data.markov", c.data.markov)?;
        c.data.noniid = doc.float_or("data.noniid", c.data.noniid)?;
        c.data.eval_batches =
            doc.int_or("data.eval_batches", c.data.eval_batches as i64)? as usize;

        c.net.topology = doc.str_or("net.topology", &c.net.topology)?;
        let fanout = doc.int_or("net.tree_fanout", c.net.tree_fanout as i64)?;
        if fanout < 2 {
            return Err(Error::Config(format!(
                "net.tree_fanout must be >= 2, got {fanout}"
            )));
        }
        c.net.tree_fanout = fanout as usize;
        c.net.latency_us = doc.float_or("net.latency_us", c.net.latency_us)?;
        c.net.bandwidth_gbps = doc.float_or("net.bandwidth_gbps", c.net.bandwidth_gbps)?;
        c.net.server_bandwidth_gbps =
            doc.float_or("net.server_bandwidth_gbps", c.net.server_bandwidth_gbps)?;
        c.net.dataloader_samples_per_s =
            doc.float_or("net.dataloader_samples_per_s", c.net.dataloader_samples_per_s)?;
        c.net.listen = doc.str_or("net.listen", &c.net.listen)?;
        c.net.connect = doc.str_or("net.connect", &c.net.connect)?;
        c.net.connect_timeout_s =
            doc.float_or("net.connect_timeout_s", c.net.connect_timeout_s)?;
        let retries = doc.int_or("net.connect_retries", c.net.connect_retries as i64)?;
        if !(0..=u32::MAX as i64).contains(&retries) {
            return Err(Error::Config(format!(
                "net.connect_retries must be >= 0, got {retries}"
            )));
        }
        c.net.connect_retries = retries as u32;
        c.net.retry_backoff_s = doc.float_or("net.retry_backoff_s", c.net.retry_backoff_s)?;
        c.net.nodelay = doc.bool_or("net.nodelay", c.net.nodelay)?;

        c.comm.transport = doc.str_or("comm.transport", &c.comm.transport)?;
        c.comm.compression = doc.str_or("comm.compression", &c.comm.compression)?;
        let shards = doc.int_or("comm.shards", c.comm.shards as i64)?;
        if !(1..=64).contains(&shards) {
            return Err(Error::Config(format!(
                "comm.shards must be in 1..=64, got {shards}"
            )));
        }
        c.comm.shards = shards as usize;
        let pipeline = doc.int_or("comm.pipeline", c.comm.pipeline as i64)?;
        if !(0..=16).contains(&pipeline) {
            return Err(Error::Config(format!(
                "comm.pipeline must be in 0..=16, got {pipeline}"
            )));
        }
        c.comm.pipeline = pipeline as usize;
        let levels = doc.int_or("comm.qsgd_levels", c.comm.qsgd_levels as i64)?;
        if !(1..=127).contains(&levels) {
            return Err(Error::Config(format!(
                "comm.qsgd_levels must be in 1..=127, got {levels}"
            )));
        }
        c.comm.qsgd_levels = levels as u8;
        c.comm.topk_keep = doc.float_or("comm.topk_keep", c.comm.topk_keep)?;

        c.sync.policy = doc.str_or("sync.policy", &c.sync.policy)?;
        c.sync.h_max = doc.int_or("sync.h_max", c.sync.h_max as i64)? as u64;
        c.sync.grow_factor = doc.float_or("sync.grow_factor", c.sync.grow_factor)?;
        c.sync.grow_every = doc.int_or("sync.grow_every", c.sync.grow_every as i64)? as u64;
        c.sync.drift_threshold =
            doc.float_or("sync.drift_threshold", c.sync.drift_threshold)?;
        c.sync.target_comm_fraction =
            doc.float_or("sync.target_comm_fraction", c.sync.target_comm_fraction)?;

        c.faults.slow_workers =
            doc.int_or("faults.slow_workers", c.faults.slow_workers as i64)? as usize;
        c.faults.slow_factor = doc.float_or("faults.slow_factor", c.faults.slow_factor)?;
        c.faults.stall_prob = doc.float_or("faults.stall_prob", c.faults.stall_prob)?;
        c.faults.stall_s = doc.float_or("faults.stall_s", c.faults.stall_s)?;
        c.faults.crash_worker = doc.int_or("faults.crash_worker", c.faults.crash_worker)?;
        let crash_step = doc.int_or("faults.crash_step", c.faults.crash_step as i64)?;
        if crash_step < 0 {
            // Don't let a negative wrap into a huge u64 that silently
            // schedules the crash past the end of the run.
            return Err(Error::Config(format!(
                "faults.crash_step must be >= 0, got {crash_step}"
            )));
        }
        c.faults.crash_step = crash_step as u64;
        c.faults.quorum = doc.int_or("faults.quorum", c.faults.quorum as i64)? as usize;
        c.faults.timeout_s = doc.float_or("faults.timeout_s", c.faults.timeout_s)?;
        c.faults.drop_slowest =
            doc.int_or("faults.drop_slowest", c.faults.drop_slowest as i64)? as usize;
        let rejoin_step = doc.int_or("faults.rejoin_step", c.faults.rejoin_step as i64)?;
        if rejoin_step < 0 {
            return Err(Error::Config(format!(
                "faults.rejoin_step must be >= 0, got {rejoin_step}"
            )));
        }
        c.faults.rejoin_step = rejoin_step as u64;
        c.faults.spawn_workers =
            doc.int_or("faults.spawn_workers", c.faults.spawn_workers as i64)? as usize;
        let spawn_step = doc.int_or("faults.spawn_step", c.faults.spawn_step as i64)?;
        if spawn_step < 0 {
            return Err(Error::Config(format!(
                "faults.spawn_step must be >= 0, got {spawn_step}"
            )));
        }
        c.faults.spawn_step = spawn_step as u64;
        c.faults.autoscale = doc.bool_or("faults.autoscale", c.faults.autoscale)?;
        let patience =
            doc.int_or("faults.autoscale_patience", c.faults.autoscale_patience as i64)?;
        if patience < 0 {
            return Err(Error::Config(format!(
                "faults.autoscale_patience must be >= 0, got {patience}"
            )));
        }
        c.faults.autoscale_patience = patience as u64;
        c.faults.autoscale_straggler_s = doc
            .float_or("faults.autoscale_straggler_s", c.faults.autoscale_straggler_s)?;
        c.faults.autoscale_drift =
            doc.float_or("faults.autoscale_drift", c.faults.autoscale_drift)?;

        c.exec.parallelism = doc.str_or("exec.parallelism", &c.exec.parallelism)?;
        let exec_threads = doc.int_or("exec.threads", c.exec.threads as i64)?;
        if exec_threads < 0 {
            return Err(Error::Config(format!(
                "exec.threads must be >= 0, got {exec_threads}"
            )));
        }
        c.exec.threads = exec_threads as usize;
        c.exec.simd = doc.str_or("exec.simd", &c.exec.simd)?;

        c.precision.wire = doc.str_or("precision.wire", &c.precision.wire)?;
        c.precision.state = doc.str_or("precision.state", &c.precision.state)?;

        c.validate()?;
        Ok(c)
    }

    /// Load + parse + validate from a path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<ExperimentConfig> {
        ExperimentConfig::from_doc(&TomlDoc::load(path)?)
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<()> {
        let t = &self.train;
        if t.workers == 0 {
            return Err(Error::Config("train.workers must be >= 1".into()));
        }
        if t.steps == 0 {
            return Err(Error::Config("train.steps must be >= 1".into()));
        }
        if self.optim.eta <= 0.0 || !self.optim.eta.is_finite() {
            return Err(Error::Config(format!("optim.eta must be positive, got {}", self.optim.eta)));
        }
        if self.optim.epsilon <= 0.0 {
            return Err(Error::Config("optim.epsilon must be positive (paper Thm 1: arbitrary ε > 0)".into()));
        }
        if self.optim.b0 < 1.0 {
            return Err(Error::Config("optim.b0 must be >= 1 (paper Thm 1/2 assumption b₀ ≥ 1)".into()));
        }
        if !(0.0..1.0).contains(&(self.optim.momentum as f64)) {
            return Err(Error::Config("optim.momentum must be in [0, 1)".into()));
        }
        if !self.optim.algorithm.is_local() && self.train.sync_period != SyncPeriod::Every(1) {
            // Fully-synchronous algorithms sync every step by definition;
            // accept only the default H so configs stay honest.
            if let SyncPeriod::Every(h) = self.train.sync_period {
                if h != 1 {
                    return Err(Error::Config(format!(
                        "algorithm {} is fully synchronous; train.sync_period must be 1 (got {h})",
                        self.optim.algorithm
                    )));
                }
            } else {
                return Err(Error::Config(format!(
                    "algorithm {} is fully synchronous; train.sync_period must be 1 (got inf)",
                    self.optim.algorithm
                )));
            }
        }
        if !(0.0..=1.0).contains(&self.data.noniid) {
            return Err(Error::Config("data.noniid must be in [0, 1]".into()));
        }
        if !(0.0..=1.0).contains(&self.data.markov) {
            return Err(Error::Config("data.markov must be in [0, 1]".into()));
        }
        if self.train.checkpoint_every > 0 && self.optim.algorithm.is_local() {
            if let SyncPeriod::Every(h) = self.train.sync_period {
                if self.train.checkpoint_every % h != 0 {
                    return Err(Error::Config(format!(
                        "train.checkpoint_every ({}) must be a multiple of H ({h}) \
                         for local algorithms (snapshots happen at sync boundaries)",
                        self.train.checkpoint_every
                    )));
                }
            } else {
                return Err(Error::Config(
                    "checkpointing requires finite H for local algorithms".into(),
                ));
            }
        }
        match self.net.topology.as_str() {
            "ps" | "allreduce" | "tree" => {}
            other => {
                return Err(Error::Config(format!(
                    "net.topology must be \"ps\", \"allreduce\" or \"tree\", got {other:?}"
                )))
            }
        }
        if self.net.tree_fanout < 2 {
            return Err(Error::Config(format!(
                "net.tree_fanout must be >= 2, got {}",
                self.net.tree_fanout
            )));
        }
        if self.comm.shards > 1 && self.net.topology != "ps" {
            // Sharding splits the *server*: only the parameter-server
            // topology has one. Ring/tree reductions have no incast to
            // shard away.
            return Err(Error::Config(format!(
                "comm.shards > 1 shards the parameter server; net.topology \
                 must be \"ps\", got {:?}",
                self.net.topology
            )));
        }
        if self.net.latency_us < 0.0 || self.net.bandwidth_gbps <= 0.0 {
            return Err(Error::Config("net latency/bandwidth out of range".into()));
        }
        if !(self.net.connect_timeout_s > 0.0 && self.net.connect_timeout_s.is_finite()) {
            return Err(Error::Config(format!(
                "net.connect_timeout_s must be a finite value > 0, got {}",
                self.net.connect_timeout_s
            )));
        }
        if !(self.net.retry_backoff_s >= 0.0 && self.net.retry_backoff_s.is_finite()) {
            return Err(Error::Config(format!(
                "net.retry_backoff_s must be a finite value >= 0, got {}",
                self.net.retry_backoff_s
            )));
        }
        self.comm.validate()?;
        if self.comm.networked() {
            // The networked deployment (DESIGN.md §4) is the paper's
            // parameter-server shape: one leader process, ≥ 2 workers.
            if self.net.topology != "ps" {
                return Err(Error::Config(format!(
                    "comm.transport = {:?} runs the leader↔worker protocol; \
                     net.topology must be \"ps\", got {:?}",
                    self.comm.transport, self.net.topology
                )));
            }
            if t.workers < 2 {
                return Err(Error::Config(format!(
                    "comm.transport = {:?} needs train.workers >= 2 (the in-process \
                     codecs bill single-worker clusters as free, which a real socket \
                     cannot reproduce)",
                    self.comm.transport
                )));
            }
            if self.faults.is_active()
                && (self.comm.compression != "none" || self.precision.wire_bf16())
            {
                // The lossy codecs key their streams by participant count;
                // a mid-round process death would desynchronize the
                // leader's and workers' RNG use counters.
                return Err(Error::Config(format!(
                    "[faults] over comm.transport = {:?} requires the dense f32 wire \
                     (comm.compression = \"none\", precision.wire = \"f32\")",
                    self.comm.transport
                )));
            }
        }
        self.sync.validate()?;
        if !self.sync.is_fixed() {
            if !self.optim.algorithm.is_local() {
                return Err(Error::Config(format!(
                    "sync.policy = {:?} requires a local algorithm \
                     (fully-synchronous algorithms communicate every step)",
                    self.sync.policy
                )));
            }
            let h0 = match self.train.sync_period {
                SyncPeriod::Every(h) => h,
                SyncPeriod::Infinite => {
                    return Err(Error::Config(format!(
                        "sync.policy = {:?} needs a finite train.sync_period \
                         as its initial H (got inf)",
                        self.sync.policy
                    )))
                }
            };
            if h0 > self.sync.h_max {
                return Err(Error::Config(format!(
                    "train.sync_period ({h0}) exceeds sync.h_max ({})",
                    self.sync.h_max
                )));
            }
            if self.train.checkpoint_every > 0 {
                // Snapshots happen at sync boundaries, which adaptive
                // policies only know at runtime.
                return Err(Error::Config(format!(
                    "train.checkpoint_every requires sync.policy = \"fixed\" \
                     (adaptive policy {:?} decides boundaries at runtime)",
                    self.sync.policy
                )));
            }
        }
        self.validate_faults()?;
        self.exec.validate()?;
        self.precision.validate()?;
        self.precision.validate_with_comm(&self.comm)?;
        Ok(())
    }

    /// The `[faults]` rules, self-contained bounds plus the cross-field
    /// consistency checks — one copy shared by [`ExperimentConfig::validate`]
    /// and the trainer (which re-runs it for programmatically-built configs
    /// whenever a fault scenario is active).
    pub fn validate_faults(&self) -> Result<()> {
        self.faults.validate()?;
        let f = &self.faults;
        let workers = self.train.workers;
        if f.slow_workers > workers {
            return Err(Error::Config(format!(
                "faults.slow_workers ({}) exceeds train.workers ({workers})",
                f.slow_workers
            )));
        }
        if f.crash_worker >= 0 {
            if f.crash_worker as usize >= workers {
                return Err(Error::Config(format!(
                    "faults.crash_worker ({}) out of range (train.workers = {workers})",
                    f.crash_worker
                )));
            }
            if workers == 1 {
                return Err(Error::Config(
                    "faults.crash_worker would crash the only worker (train.workers = 1)"
                        .into(),
                ));
            }
        }
        if f.quorum > workers {
            return Err(Error::Config(format!(
                "faults.quorum ({}) exceeds train.workers ({workers})",
                f.quorum
            )));
        }
        if f.crash_worker >= 0 && f.quorum > workers.saturating_sub(1) {
            return Err(Error::Config(format!(
                "faults.quorum ({}) is unreachable once faults.crash_worker dies \
                 (at most {} workers stay alive)",
                f.quorum,
                workers - 1
            )));
        }
        if f.drop_slowest > 0 && f.drop_slowest >= workers {
            return Err(Error::Config(format!(
                "faults.drop_slowest ({}) must leave at least one participant \
                 (train.workers = {workers})",
                f.drop_slowest
            )));
        }
        if f.crash_worker >= 0 && self.comm.compression != "none" {
            // A crash shrinks the gather, and the compressor's per-worker
            // error-feedback/delta streams are keyed by gather position —
            // survivors would silently inherit the dead worker's residuals.
            return Err(Error::Config(
                "faults.crash_worker requires comm.compression = \"none\" \
                 (compressor error-feedback streams are keyed by gather \
                 position, which a crash would shift)"
                    .into(),
            ));
        }
        if f.partial() {
            if !self.optim.algorithm.is_local() {
                return Err(Error::Config(format!(
                    "faults.quorum/drop_slowest require a local algorithm \
                     ({} barriers on every worker each step by definition)",
                    self.optim.algorithm
                )));
            }
            if self.comm.compression != "none" {
                return Err(Error::Config(
                    "faults.quorum/drop_slowest require comm.compression = \"none\" \
                     (delta-compression bases assume full participation)"
                        .into(),
                ));
            }
            if self.train.fused {
                return Err(Error::Config(
                    "faults.quorum/drop_slowest require train.fused = false \
                     (partial rounds use the split grad + rust-update path)"
                        .into(),
                ));
            }
        }
        if f.has_churn() {
            // Elastic membership warm-starts (re)admitted workers through
            // the local algorithms' InstallState catch-up path at a sync
            // boundary — there is no such boundary for fully-synchronous
            // algorithms, and the fused/compressed paths assume a fixed
            // participant set.
            if !self.optim.algorithm.is_local() {
                return Err(Error::Config(format!(
                    "faults.rejoin_step/spawn_workers/autoscale require a local \
                     algorithm ({} has no sync boundary to warm-start at)",
                    self.optim.algorithm
                )));
            }
            if self.comm.compression != "none" {
                return Err(Error::Config(
                    "faults.rejoin_step/spawn_workers/autoscale require \
                     comm.compression = \"none\" (delta/error-feedback streams \
                     are keyed by a fixed participant set)"
                        .into(),
                ));
            }
            if self.train.fused {
                return Err(Error::Config(
                    "faults.rejoin_step/spawn_workers/autoscale require \
                     train.fused = false (elastic rounds use the split \
                     grad + rust-update path)"
                        .into(),
                ));
            }
            if f.spawn_workers >= workers {
                return Err(Error::Config(format!(
                    "faults.spawn_workers ({}) must leave at least one initial \
                     worker (train.workers = {workers})",
                    f.spawn_workers
                )));
            }
            if f.quorum > workers - f.spawn_workers {
                return Err(Error::Config(format!(
                    "faults.quorum ({}) is unreachable before the {} spawned \
                     workers join ({} workers start live)",
                    f.quorum,
                    f.spawn_workers,
                    workers - f.spawn_workers
                )));
            }
        }
        // Checkpointing under an active scenario is well-defined since the
        // fault plan is a pure function of `(seed, worker, step)`: snapshots
        // happen at sync boundaries (checkpoint_every % H == 0) where every
        // live replica holds the installed average, and a resume
        // reconstructs the membership table from the replayed plan. The
        // still-forbidden combination — checkpointing under an *adaptive*
        // sync policy — is rejected by [`ExperimentConfig::validate`].
        Ok(())
    }

    /// Apply a `key=value` CLI override (string values need no quotes).
    pub fn override_from_doc(doc: &mut TomlDoc, spec: &str) -> Result<()> {
        let (key, val) = spec.split_once('=').ok_or_else(|| {
            Error::Config(format!("--set expects key=value, got {spec:?}"))
        })?;
        let key = key.trim();
        let val = val.trim();
        // Try int, float, bool, then string.
        let value = if let Ok(i) = val.parse::<i64>() {
            TomlValue::Int(i)
        } else if val == "inf" {
            TomlValue::Float(f64::INFINITY)
        } else if let Ok(f) = val.parse::<f64>() {
            TomlValue::Float(f)
        } else if val == "true" || val == "false" {
            TomlValue::Bool(val == "true")
        } else {
            TomlValue::Str(val.to_string())
        };
        doc.set(key, value);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_constants() {
        let c = ExperimentConfig::default();
        assert_eq!(c.optim.eta, 0.5);
        assert_eq!(c.optim.epsilon, 1.0);
        assert_eq!(c.optim.b0, 1.0);
        assert_eq!(c.optim.warmup_steps, 600);
        assert_eq!(c.optim.algorithm, Algorithm::LocalAdaAlter);
    }

    #[test]
    fn roundtrip_from_toml() {
        let doc = TomlDoc::parse(
            "[train]\nworkers = 4\nsync_period = 8\nbackend = \"rust_math\"\n\
             [optim]\nalgorithm = \"local_adaalter\"\neta = 0.25\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.train.workers, 4);
        assert_eq!(c.train.sync_period, SyncPeriod::Every(8));
        assert_eq!(c.optim.eta, 0.25);
    }

    #[test]
    fn h_infinity() {
        let doc = TomlDoc::parse("[train]\nsync_period = inf\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.train.sync_period, SyncPeriod::Infinite);
        assert_eq!(c.train.sync_period.period(), None);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = TomlDoc::parse("[train]\nworkerz = 4\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn sync_algorithm_with_h_rejected() {
        let doc = TomlDoc::parse(
            "[train]\nsync_period = 4\n[optim]\nalgorithm = \"adagrad\"\n",
        )
        .unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("fully synchronous"));
    }

    #[test]
    fn validation_bounds() {
        let mut c = ExperimentConfig::default();
        c.optim.b0 = 0.5;
        assert!(c.validate().is_err());
        c.optim.b0 = 1.0;
        c.train.workers = 0;
        assert!(c.validate().is_err());
        c.train.workers = 2;
        c.net.topology = "mesh".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn algorithm_parse_and_props() {
        for a in ["sgd", "local_sgd", "adagrad", "adaalter", "local_adaalter"] {
            assert_eq!(Algorithm::parse(a).unwrap().name(), a);
        }
        assert!(Algorithm::parse("adam").is_err());
        assert!(Algorithm::LocalAdaAlter.is_local());
        assert!(Algorithm::LocalAdaAlter.syncs_denominator());
        assert!(Algorithm::LocalSgd.is_local());
        assert!(!Algorithm::LocalSgd.syncs_denominator());
        assert!(!Algorithm::AdaGrad.is_local());
    }

    #[test]
    fn comm_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[comm]\ntransport = \"channel\"\ncompression = \"qsgd\"\nqsgd_levels = 7\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.comm.transport, "channel");
        assert_eq!(c.comm.compression, "qsgd");
        assert_eq!(c.comm.qsgd_levels, 7);

        // Defaults: simulated transport, no compression.
        let d = ExperimentConfig::default();
        assert_eq!(d.comm.transport, "simulated");
        assert_eq!(d.comm.compression, "none");
        d.validate().unwrap();

        // Compression over the simulated transport is ambiguous accounting.
        let doc = TomlDoc::parse("[comm]\ncompression = \"topk\"\n").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("channel"), "{err}");

        // Bounds.
        let doc = TomlDoc::parse("[comm]\nqsgd_levels = 200\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        let mut c = ExperimentConfig::default();
        c.comm.topk_keep = 0.0;
        assert!(c.validate().is_err());
        c.comm.topk_keep = 0.5;
        c.comm.transport = "carrier-pigeon".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn shards_and_tree_topology_parse_and_validate() {
        let doc = TomlDoc::parse("[comm]\nshards = 4\n[net]\ntopology = \"ps\"\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.comm.shards, 4);

        let doc = TomlDoc::parse("[net]\ntopology = \"tree\"\ntree_fanout = 4\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.net.topology, "tree");
        assert_eq!(c.net.tree_fanout, 4);

        // Bounds.
        for bad in ["shards = 0", "shards = 65"] {
            let doc = TomlDoc::parse(&format!("[comm]\n{bad}\n")).unwrap();
            assert!(ExperimentConfig::from_doc(&doc).is_err(), "{bad}");
        }
        let doc = TomlDoc::parse("[net]\ntree_fanout = 1\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());

        // Sharding splits the PS; other topologies have no server.
        let mut c = ExperimentConfig::default();
        c.comm.shards = 2;
        c.net.topology = "allreduce".into();
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("comm.shards"), "{err}");

        // Lossy codecs don't commute with a range partition.
        let mut c = ExperimentConfig::default();
        c.comm.transport = "channel".into();
        c.comm.compression = "qsgd".into();
        c.comm.shards = 2;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("comm.shards"), "{err}");
    }

    #[test]
    fn pipeline_knob_parses_and_validates() {
        // Default off ≡ today's strictly-serial round.
        assert_eq!(ExperimentConfig::default().comm.pipeline, 0);
        let doc = TomlDoc::parse("[comm]\nshards = 8\npipeline = 4\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.comm.pipeline, 4);
        c.validate().unwrap();
        // Bounds: 0..=16 at parse AND validate time.
        let doc = TomlDoc::parse("[comm]\npipeline = 17\n").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("comm.pipeline"), "{err}");
        let mut c = ExperimentConfig::default();
        c.comm.pipeline = 17;
        let err = c.validate().unwrap_err();
        assert!(err.to_string().contains("comm.pipeline"), "{err}");
        // The knob composes with every transport — depth on a dense plan
        // simply collapses to the serial executor.
        let mut c = ExperimentConfig::default();
        c.comm.pipeline = 2;
        c.validate().unwrap();
    }

    #[test]
    fn sync_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[sync]\npolicy = \"drift\"\ndrift_threshold = 2.5\nh_max = 32\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.sync.policy, "drift");
        assert_eq!(c.sync.drift_threshold, 2.5);
        assert_eq!(c.sync.h_max, 32);
        assert!(!c.sync.is_fixed());

        // Defaults: fixed policy, bitwise-compatible with the seed.
        let d = ExperimentConfig::default();
        assert!(d.sync.is_fixed());
        assert_eq!(d.sync.h_max, 64);
        d.validate().unwrap();

        // Unknown policy name.
        let doc = TomlDoc::parse("[sync]\npolicy = \"oracle\"\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());

        // Adaptive policies require a local algorithm…
        let doc = TomlDoc::parse(
            "[train]\nsync_period = 1\n[optim]\nalgorithm = \"adagrad\"\n\
             [sync]\npolicy = \"growing\"\n",
        )
        .unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("local"), "{err}");

        // …a finite initial H…
        let doc = TomlDoc::parse("[train]\nsync_period = inf\n[sync]\npolicy = \"growing\"\n")
            .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());

        // …an initial H within the cap…
        let doc =
            TomlDoc::parse("[train]\nsync_period = 128\n[sync]\npolicy = \"growing\"\n")
                .unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("h_max"), "{err}");

        // …and no checkpointing (boundaries are only known at runtime).
        let doc = TomlDoc::parse(
            "[train]\ncheckpoint_every = 8\n[sync]\npolicy = \"drift\"\n",
        )
        .unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("fixed"), "{err}");

        // Bounds.
        let mut c = ExperimentConfig::default();
        c.sync.grow_factor = 1.0;
        assert!(c.validate().is_err());
        c.sync.grow_factor = 2.0;
        c.sync.drift_threshold = 0.0;
        assert!(c.validate().is_err());
        c.sync.drift_threshold = 1.0;
        c.sync.target_comm_fraction = 1.0;
        assert!(c.validate().is_err());
        c.sync.target_comm_fraction = 0.05;
        c.sync.h_max = 0;
        assert!(c.validate().is_err());
        c.sync.h_max = 64;
        c.sync.grow_every = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn faults_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[train]\nfused = false\n[faults]\nslow_workers = 1\nslow_factor = 4.0\n\
             quorum = 7\ntimeout_s = 0.25\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.faults.slow_workers, 1);
        assert_eq!(c.faults.slow_factor, 4.0);
        assert_eq!(c.faults.quorum, 7);
        assert_eq!(c.faults.timeout_s, 0.25);
        assert!(!c.train.fused);
        assert!(c.faults.is_active() && c.faults.partial());

        // Defaults: inactive section, fused path on, full barrier.
        let d = ExperimentConfig::default();
        assert!(!d.faults.is_active());
        assert!(!d.faults.partial());
        assert!(d.train.fused);
        d.validate().unwrap();

        // An explicitly-zeroed section is still inactive.
        let doc = TomlDoc::parse("[faults]\nslow_workers = 0\nquorum = 0\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(!c.faults.is_active());
    }

    #[test]
    fn elastic_membership_keys_parse_and_validate() {
        let doc = TomlDoc::parse(
            "[train]\nfused = false\n\
             [faults]\ncrash_worker = 2\ncrash_step = 8\nrejoin_step = 13\n\
             spawn_workers = 1\nspawn_step = 0\nautoscale = true\n\
             autoscale_patience = 3\nautoscale_straggler_s = 0.1\n\
             autoscale_drift = 2.0\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.faults.rejoin_step, 13);
        assert_eq!(c.faults.spawn_workers, 1);
        assert_eq!(c.faults.spawn_step, 0);
        assert!(c.faults.autoscale);
        assert_eq!(c.faults.autoscale_patience, 3);
        assert_eq!(c.faults.autoscale_straggler_s, 0.1);
        assert_eq!(c.faults.autoscale_drift, 2.0);
        assert!(c.faults.has_churn() && c.faults.is_active());
        // A churn-free section has no membership schedule.
        assert!(!ExperimentConfig::default().faults.has_churn());
    }

    #[test]
    fn checkpointing_now_composes_with_faults_under_fixed_policy() {
        // Lifted ban: boundary snapshots under an active scenario are
        // well-defined (the plan replays from the seed on resume).
        let doc = TomlDoc::parse(
            "[train]\nfused = false\ncheckpoint_every = 4\n\
             [faults]\ncrash_worker = 1\ncrash_step = 8\nquorum = 2\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(c.faults.is_active() && c.train.checkpoint_every == 4);
        // Still forbidden, by field name: checkpointing under an adaptive
        // policy (boundaries only known at runtime).
        let doc = TomlDoc::parse(
            "[train]\ncheckpoint_every = 4\n[sync]\npolicy = \"drift\"\n",
        )
        .unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("train.checkpoint_every"), "{err}");
        assert!(err.contains("fixed"), "{err}");
    }

    #[test]
    fn faults_negative_paths_name_the_field() {
        // Every invalid combination must come back as Err with a message
        // naming the offending field — never a panic mid-run.
        let cases: &[(&str, &str)] = &[
            // quorum larger than the cluster
            ("[train]\nfused = false\n[faults]\nquorum = 9\n", "faults.quorum"),
            // quorum with the fused device path
            ("[faults]\nquorum = 4\n", "train.fused"),
            // quorum needs a local algorithm
            (
                "[train]\nsync_period = 1\nfused = false\n\
                 [optim]\nalgorithm = \"adagrad\"\n[faults]\nquorum = 2\n",
                "local",
            ),
            // quorum over a compressed transport
            (
                "[train]\nfused = false\n[comm]\ntransport = \"channel\"\n\
                 compression = \"qsgd\"\n[faults]\nquorum = 4\n",
                "comm.compression",
            ),
            // rejoin without a crash to rejoin from
            ("[train]\nfused = false\n[faults]\nrejoin_step = 8\n",
             "faults.rejoin_step"),
            // rejoin not after the crash
            (
                "[train]\nfused = false\n\
                 [faults]\ncrash_worker = 1\ncrash_step = 8\nrejoin_step = 8\n",
                "faults.rejoin_step",
            ),
            // spawned workers with neither a spawn step nor autoscale
            ("[train]\nfused = false\n[faults]\nspawn_workers = 1\n",
             "faults.spawn_step"),
            // everyone spawned: no initial worker
            (
                "[train]\nworkers = 2\nfused = false\n\
                 [faults]\nspawn_workers = 2\nspawn_step = 4\n",
                "faults.spawn_workers",
            ),
            // quorum unreachable before the spawned workers join
            (
                "[train]\nworkers = 4\nfused = false\n\
                 [faults]\nquorum = 4\nspawn_workers = 1\nspawn_step = 8\n",
                "faults.quorum",
            ),
            // churn over the fused device path
            (
                "[faults]\ncrash_worker = 1\ncrash_step = 4\nrejoin_step = 9\n",
                "train.fused",
            ),
            // churn needs a local algorithm (no boundary to warm-start at)
            (
                "[train]\nsync_period = 1\nfused = false\n\
                 [optim]\nalgorithm = \"adagrad\"\n\
                 [faults]\nautoscale = true\n",
                "local",
            ),
            // zero patience can never trigger
            (
                "[train]\nfused = false\n\
                 [faults]\nautoscale = true\nautoscale_patience = 0\n",
                "faults.autoscale_patience",
            ),
            // crash without a crash step
            ("[faults]\ncrash_worker = 1\n", "faults.crash_step"),
            // negative crash step must not wrap into "never"
            ("[faults]\ncrash_worker = 1\ncrash_step = -3\n", "faults.crash_step"),
            // crash worker out of range
            ("[train]\nworkers = 2\n[faults]\ncrash_worker = 5\ncrash_step = 2\n",
             "faults.crash_worker"),
            // crash makes the quorum unreachable
            (
                "[train]\nworkers = 4\nfused = false\n\
                 [faults]\nquorum = 4\ncrash_worker = 0\ncrash_step = 2\n",
                "unreachable",
            ),
            // crash over a compressed transport (position-keyed residuals)
            (
                "[comm]\ntransport = \"channel\"\ncompression = \"topk\"\n\
                 [faults]\ncrash_worker = 1\ncrash_step = 2\n",
                "comm.compression",
            ),
            // slowdown below 1 is a speed-up, not a fault
            ("[faults]\nslow_workers = 1\nslow_factor = 0.5\n", "faults.slow_factor"),
            // stall probability out of range
            ("[faults]\nstall_prob = 1.5\n", "faults.stall_prob"),
            // stalls that cost nothing
            ("[faults]\nstall_prob = 0.1\nstall_s = 0.0\n", "faults.stall_s"),
            // both participation policies at once
            ("[train]\nfused = false\n[faults]\nquorum = 2\ndrop_slowest = 1\n",
             "mutually exclusive"),
            // backup policy dropping everyone
            ("[train]\nworkers = 4\nfused = false\n[faults]\ndrop_slowest = 4\n",
             "faults.drop_slowest"),
            // negative timeout
            ("[train]\nfused = false\n[faults]\nquorum = 2\ntimeout_s = -1.0\n",
             "faults.timeout_s"),
        ];
        for (toml, needle) in cases {
            let doc = TomlDoc::parse(toml).unwrap_or_else(|e| panic!("{toml}: {e}"));
            let err = ExperimentConfig::from_doc(&doc)
                .err()
                .unwrap_or_else(|| panic!("expected Err for:\n{toml}"))
                .to_string();
            assert!(err.contains(needle), "{toml}\nerror {err:?} lacks {needle:?}");
        }
    }

    #[test]
    fn exec_section_parses_and_validates() {
        // Defaults: one host thread per worker — the pre-engine thread
        // shape, so `[exec]`-less configs keep their parallelism.
        let d = ExperimentConfig::default();
        assert_eq!(d.exec.parallelism, "threads");
        assert_eq!(d.exec.threads, 0);
        assert_eq!(d.exec.simd, "auto");
        d.validate().unwrap();

        let doc = TomlDoc::parse("[exec]\nparallelism = \"threads\"\nthreads = 4\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.exec.parallelism, "threads");
        assert_eq!(c.exec.threads, 4);

        // The shorthand spelling carries its own count.
        let doc = TomlDoc::parse("[exec]\nparallelism = \"threads(8)\"\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.exec.parallelism, "threads(8)");

        // Unknown spellings and negative counts are rejected.
        let doc = TomlDoc::parse("[exec]\nparallelism = \"gpu\"\n").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
        let doc = TomlDoc::parse("[exec]\nthreads = -2\n").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("exec.threads"), "{err}");
        let mut c = ExperimentConfig::default();
        c.exec.parallelism = "threads(no)".into();
        assert!(c.validate().is_err());

        // The simd knob parses and rejects unknown spellings by name.
        let doc = TomlDoc::parse("[exec]\nsimd = \"on\"\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.exec.simd, "on");
        let doc = TomlDoc::parse("[exec]\nsimd = \"fast\"\n").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("exec.simd"), "{err}");
    }

    #[test]
    fn precision_section_parses_and_validates() {
        // Defaults: full f32 everywhere — the bitwise-seed configuration.
        let d = ExperimentConfig::default();
        assert_eq!(d.precision.wire, "f32");
        assert_eq!(d.precision.state, "f32");
        assert!(!d.precision.wire_bf16() && !d.precision.state_bf16());
        d.validate().unwrap();

        // bf16 wire needs the exact-bytes channel transport.
        let doc = TomlDoc::parse(
            "[comm]\ntransport = \"channel\"\n[precision]\nwire = \"bf16\"\nstate = \"bf16\"\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(c.precision.wire_bf16() && c.precision.state_bf16());

        // bf16 state alone works over any transport.
        let doc = TomlDoc::parse("[precision]\nstate = \"bf16\"\n").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(c.precision.state_bf16() && !c.precision.wire_bf16());

        // bf16 wire over the simulated transport is ambiguous accounting…
        let doc = TomlDoc::parse("[precision]\nwire = \"bf16\"\n").unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("channel"), "{err}");

        // …and stacking it under another lossy codec double-quantizes.
        let doc = TomlDoc::parse(
            "[comm]\ntransport = \"channel\"\ncompression = \"qsgd\"\n\
             [precision]\nwire = \"bf16\"\n",
        )
        .unwrap();
        let err = ExperimentConfig::from_doc(&doc).unwrap_err();
        assert!(err.to_string().contains("compression"), "{err}");

        // Unknown spellings are rejected by field name.
        for (toml, needle) in [
            ("[precision]\nwire = \"fp8\"\n", "precision.wire"),
            ("[precision]\nstate = \"f16\"\n", "precision.state"),
        ] {
            let doc = TomlDoc::parse(toml).unwrap();
            let err = ExperimentConfig::from_doc(&doc).unwrap_err();
            assert!(err.to_string().contains(needle), "{err}");
        }
    }

    #[test]
    fn cli_override() {
        let mut doc = TomlDoc::parse("[train]\nworkers = 2\n").unwrap();
        ExperimentConfig::override_from_doc(&mut doc, "train.workers=6").unwrap();
        ExperimentConfig::override_from_doc(&mut doc, "optim.eta=0.125").unwrap();
        ExperimentConfig::override_from_doc(&mut doc, "train.sync_period=inf").unwrap();
        // fully-sync default algorithm is local_adaalter so inf is OK
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.train.workers, 6);
        assert_eq!(c.optim.eta, 0.125);
        assert_eq!(c.train.sync_period, SyncPeriod::Infinite);
        assert!(ExperimentConfig::override_from_doc(&mut doc, "nonsense").is_err());
    }

    #[test]
    fn sync_period_from_f64_bounds() {
        assert!(SyncPeriod::from_f64(0.0).is_err());
        assert!(SyncPeriod::from_f64(2.5).is_err());
        assert!(SyncPeriod::from_f64(-1.0).is_err());
        assert_eq!(SyncPeriod::from_f64(4.0).unwrap(), SyncPeriod::Every(4));
        assert_eq!(SyncPeriod::from_f64(f64::INFINITY).unwrap(), SyncPeriod::Infinite);
    }
}
