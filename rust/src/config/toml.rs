//! TOML-subset parser for experiment config files.
//!
//! The offline image has no `toml`/`serde`, so the framework owns a parser
//! for the subset its configs use: `[section]` and `[section.sub]` headers,
//! `key = value` with strings, integers, floats, booleans, and homogeneous
//! inline arrays, plus `#` comments. Unknown syntax is an error, never
//! silently ignored — configs drive experiments and must not rot.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal (`inf` maps here as `f64::INFINITY`).
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Homogeneous inline array.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// As i64.
    pub fn int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(v) => Ok(*v),
            _ => Err(Error::Config(format!("expected integer, got {self:?}"))),
        }
    }

    /// As f64 (integers widen).
    pub fn float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(v) => Ok(*v),
            TomlValue::Int(v) => Ok(*v as f64),
            _ => Err(Error::Config(format!("expected float, got {self:?}"))),
        }
    }

    /// As str.
    pub fn str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => Err(Error::Config(format!("expected string, got {self:?}"))),
        }
    }

    /// As bool.
    pub fn bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => Err(Error::Config(format!("expected bool, got {self:?}"))),
        }
    }

    /// As array.
    pub fn array(&self) -> Result<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Ok(v),
            _ => Err(Error::Config(format!("expected array, got {self:?}"))),
        }
    }
}

/// Parsed document: dotted-path key → value (`"train.workers"`).
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let errline = lineno + 1;
            if let Some(inner) = line.strip_prefix('[') {
                let inner = inner.strip_suffix(']').ok_or(Error::Parse {
                    what: "toml",
                    line: errline,
                    msg: "unterminated section header".into(),
                })?;
                let name = inner.trim();
                if name.is_empty() || !name.split('.').all(is_bare_key) {
                    return Err(Error::Parse {
                        what: "toml",
                        line: errline,
                        msg: format!("invalid section name {name:?}"),
                    });
                }
                section = name.to_string();
            } else if let Some(eq) = find_top_level_eq(line) {
                let key = line[..eq].trim();
                if !is_bare_key(key) {
                    return Err(Error::Parse {
                        what: "toml",
                        line: errline,
                        msg: format!("invalid key {key:?}"),
                    });
                }
                let value = parse_value(line[eq + 1..].trim(), errline)?;
                let full = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                if doc.entries.insert(full.clone(), value).is_some() {
                    return Err(Error::Parse {
                        what: "toml",
                        line: errline,
                        msg: format!("duplicate key {full:?}"),
                    });
                }
            } else {
                return Err(Error::Parse {
                    what: "toml",
                    line: errline,
                    msg: format!("cannot parse line {line:?}"),
                });
            }
        }
        Ok(doc)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<TomlDoc> {
        TomlDoc::parse(&std::fs::read_to_string(path)?)
    }

    /// Look up a dotted key.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    /// All keys (dotted), sorted.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    /// Typed accessors with defaults — the schema layer's workhorses.
    pub fn int_or(&self, key: &str, default: i64) -> Result<i64> {
        self.get(key).map_or(Ok(default), TomlValue::int)
    }

    /// f64 with default.
    pub fn float_or(&self, key: &str, default: f64) -> Result<f64> {
        self.get(key).map_or(Ok(default), TomlValue::float)
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        self.get(key).map_or(Ok(default.to_string()), |v| v.str().map(String::from))
    }

    /// bool with default.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        self.get(key).map_or(Ok(default), TomlValue::bool)
    }

    /// Set (used by CLI `--set key=value` overrides).
    pub fn set(&mut self, key: &str, value: TomlValue) {
        self.entries.insert(key.to_string(), value);
    }

    /// Reject any key outside the allowed set — typo protection.
    pub fn ensure_known_keys(&self, allowed: &[&str]) -> Result<()> {
        for k in self.keys() {
            if !allowed.contains(&k) {
                return Err(Error::Config(format!(
                    "unknown config key {k:?} (allowed: {allowed:?})"
                )));
            }
        }
        Ok(())
    }
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Find the first `=` outside any string (key/value split).
fn find_top_level_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

/// Parse a scalar or inline-array value.
fn parse_value(text: &str, line: usize) -> Result<TomlValue> {
    let err = |msg: String| Error::Parse { what: "toml", line, msg };
    let t = text.trim();
    if t.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(inner) = t.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| err("unterminated array".into()))?;
        let mut vals = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_array_items(inner) {
                vals.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(TomlValue::Array(vals));
    }
    if let Some(inner) = t.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| err("unterminated string".into()))?;
        if inner.contains('"') {
            return Err(err("embedded quote in string (escapes unsupported)".into()));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match t {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    // "inf" for H = ∞ configs.
    if t == "inf" {
        return Ok(TomlValue::Float(f64::INFINITY));
    }
    if !t.contains(['.', 'e', 'E']) {
        if let Ok(v) = t.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(v));
        }
    }
    if let Ok(v) = t.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(err(format!("cannot parse value {t:?}")))
}

/// Split inline-array items on top-level commas (strings may hold commas).
fn split_array_items(inner: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if !inner[start..].trim().is_empty() {
        items.push(&inner[start..]);
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
preset = "tiny"

[train]
workers = 8
sync_period = 4       # H
lr = 0.5
warmup = 600
algorithms = ["adagrad", "local_adaalter"]
use_pjrt = true

[net]
latency_us = 25.0
bandwidth_gbps = 10
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("preset").unwrap().str().unwrap(), "tiny");
        assert_eq!(doc.get("train.workers").unwrap().int().unwrap(), 8);
        assert_eq!(doc.get("train.lr").unwrap().float().unwrap(), 0.5);
        assert!(doc.get("train.use_pjrt").unwrap().bool().unwrap());
        let algos = doc.get("train.algorithms").unwrap().array().unwrap();
        assert_eq!(algos.len(), 2);
        assert_eq!(algos[1].str().unwrap(), "local_adaalter");
        // ints widen to float on demand
        assert_eq!(doc.get("net.bandwidth_gbps").unwrap().float().unwrap(), 10.0);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = TomlDoc::parse("# only a comment\n\na = 1 # trailing\n").unwrap();
        assert_eq!(doc.get("a").unwrap().int().unwrap(), 1);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("s").unwrap().str().unwrap(), "a#b");
    }

    #[test]
    fn inf_parses_for_h_infinity() {
        let doc = TomlDoc::parse("h = inf\n").unwrap();
        assert!(doc.get("h").unwrap().float().unwrap().is_infinite());
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(TomlDoc::parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let err = TomlDoc::parse("a = 1\nnot a kv\n").unwrap_err();
        match err {
            Error::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("wrong error: {other:?}"),
        }
    }

    #[test]
    fn unterminated_constructs_rejected() {
        assert!(TomlDoc::parse("[sec\n").is_err());
        assert!(TomlDoc::parse("a = \"oops\n").is_err());
        assert!(TomlDoc::parse("a = [1, 2\n").is_err());
        assert!(TomlDoc::parse("a =\n").is_err());
    }

    #[test]
    fn defaults_and_overrides() {
        let mut doc = TomlDoc::parse("a = 1\n").unwrap();
        assert_eq!(doc.int_or("a", 9).unwrap(), 1);
        assert_eq!(doc.int_or("missing", 9).unwrap(), 9);
        doc.set("b.c", TomlValue::Str("x".into()));
        assert_eq!(doc.get("b.c").unwrap().str().unwrap(), "x");
    }

    #[test]
    fn unknown_key_guard() {
        let doc = TomlDoc::parse("a = 1\nb = 2\n").unwrap();
        assert!(doc.ensure_known_keys(&["a", "b"]).is_ok());
        assert!(doc.ensure_known_keys(&["a"]).is_err());
    }

    #[test]
    fn underscored_ints() {
        let doc = TomlDoc::parse("n = 1_000_000\n").unwrap();
        assert_eq!(doc.get("n").unwrap().int().unwrap(), 1_000_000);
    }

    #[test]
    fn nested_sections() {
        let doc = TomlDoc::parse("[a.b]\nc = 3\n").unwrap();
        assert_eq!(doc.get("a.b.c").unwrap().int().unwrap(), 3);
    }

    #[test]
    fn empty_array() {
        let doc = TomlDoc::parse("xs = []\n").unwrap();
        assert!(doc.get("xs").unwrap().array().unwrap().is_empty());
    }
}
