//! Configuration system: TOML-subset parser, typed schema, named presets.
//!
//! Load order: preset or file → CLI `--set key=value` overrides → validate.

pub mod presets;
pub mod schema;
pub mod toml;

pub use presets::{load_preset, preset_doc, PRESETS};
pub use schema::{
    Algorithm, Backend, CommConfig, DataConfig, ExecConfig, ExperimentConfig, FaultsConfig,
    NetConfig, OptimConfig, PrecisionConfig, SyncPeriod, TrainConfig,
};
pub use toml::{TomlDoc, TomlValue};
