//! Named experiment presets — the paper's evaluation settings, runnable as
//! `adaalter train --experiment <name>` without writing a config file.
//!
//! Each preset is expressed as a TOML snippet so the same parsing/validation
//! path is exercised whether a config comes from disk, CLI or a preset.
//!
//! # The `[comm]` section
//!
//! Every preset (and config file) may select its collective transport
//! (DESIGN.md §3) without touching code:
//!
//! ```toml
//! [comm]
//! transport = "simulated"   # default: lockstep data path + α–β cost model
//! # transport = "channel"   # bare lockstep, zero modeled cost
//! compression = "none"      # or "qsgd" / "topk" (require transport = "channel")
//! qsgd_levels = 15          # QSGD levels s (31 symbols → 5-bit codes at s = 15)
//! topk_keep = 0.01          # top-k keep fraction (1% sparsification)
//! shards = 1                # k > 1 range-partitions the PS across k shard
//!                           # servers (requires compression = "none",
//!                           # topology = "ps"; bitwise ≡ shards = 1)
//! ```
//!
//! Pair with `net.topology = "ps" | "allreduce" | "tree"` (tree takes
//! `net.tree_fanout`) to move the same run between a parameter server, a
//! ring and a reduction tree — the `compressed-qsgd`, `ring-allreduce`,
//! `sharded-ps` and `tree-allreduce` presets below are the canonical
//! examples, and `benches/comm_reduction.rs` sweeps the transports while
//! `benches/topology_scaling.rs` sweeps topologies and shard counts.
//!
//! # The `[sync]` section
//!
//! Every preset (and config file) may also select its synchronization
//! policy (DESIGN.md §5) — *when* local algorithms communicate, with
//! `train.sync_period` as the (initial) H:
//!
//! ```toml
//! [sync]
//! policy = "fixed"            # default: the paper's mod(t, H) schedule
//! # policy = "growing"        # H ×= grow_factor every grow_every rounds
//! # policy = "drift"          # sync when accumulated Σ‖Δx‖² ≥ threshold
//! # policy = "time_budget"    # pick H for a target comm-time fraction
//! h_max = 64                  # hard cap on H for adaptive policies
//! grow_factor = 2.0           # growing: growth multiplier (> 1)
//! grow_every = 1              # growing: rounds between growth steps
//! drift_threshold = 1.0       # drift: accumulated ‖Δx‖² trigger
//! target_comm_fraction = 0.05 # time_budget: comm share of wall-clock
//! ```
//!
//! The `adaptive-drift` and `time-budget` presets below are the canonical
//! examples; `benches/adaptive_sync.rs` sweeps fixed vs. adaptive
//! policies over the fig-3 convergence setup.
//!
//! # The `[faults]` section
//!
//! Every preset (and config file) may also run a deterministic fault
//! scenario with partial-participation sync rounds (DESIGN.md §6):
//!
//! ```toml
//! [train]
//! fused = false        # required by quorum / drop_slowest rounds
//! [faults]
//! slow_workers = 1     # the 1 highest worker id runs 4× slower…
//! slow_factor = 4.0
//! stall_prob = 0.0     # per-(worker, step) transient-stall probability
//! stall_s = 0.05       # virtual seconds per stall
//! crash_worker = -1    # worker id to kill permanently (-1 = none)
//! crash_step = 0       # 1-based iteration it dies at
//! quorum = 7           # close each sync round with 7 of 8 workers…
//! timeout_s = 0.0      # …waiting this long past the quorum before dropping
//! drop_slowest = 0     # or: always drop the k slowest (backup workers)
//! ```
//!
//! The `straggler-quorum` preset below is the canonical example;
//! `benches/straggler_recovery.rs` sweeps full-barrier vs. quorum vs.
//! backup-worker sync under one slow worker of eight.
//!
//! Elastic membership (DESIGN.md §10) rides on the same section — churn
//! that *recovers* instead of only shrinking:
//!
//! ```toml
//! [faults]
//! rejoin_step = 570    # the crashed worker comes back at this step…
//!                      # (re-admitted at the next sync boundary)
//! spawn_workers = 1    # the 1 highest worker id starts absent…
//! spawn_step = 0       # …joining at this step (0 = queued spare, only
//!                      # admitted by the autoscaler)
//! autoscale = true     # telemetry-driven membership: admit spares on
//!                      # sustained drift, retire persistent stragglers
//! autoscale_patience = 4      # consecutive rounds before acting
//! autoscale_drift = 0.5       # drift_sq >= this counts as "drifty"
//! autoscale_straggler_s = 0.05 # barrier wait above this is "congested"
//! ```
//!
//! The `elastic-spot` preset below is the canonical example;
//! `benches/elastic_churn.rs` measures recovery-time-to-parity and
//! `tests/integration_elastic.rs` pins the membership machine, including
//! kill/relaunch `--rejoin` over real sockets.
//!
//! # The `[exec]` section
//!
//! Every preset (and config file) may also pick the execution engine's
//! thread layout (DESIGN.md §7) — a pure wall-clock knob, bitwise-
//! identical across all values:
//!
//! ```toml
//! [exec]
//! parallelism = "threads"  # default; with threads = 0 (one host per
//!                          # worker) this is the pre-engine thread shape
//! # parallelism = "threads(8)"  # shorthand carrying the count
//! # parallelism = "serial"      # one host thread, worker order
//! threads = 0              # host threads for "threads" (0 = one/worker)
//! ```
//!
//! The `parallel-hosts` preset below is the canonical example;
//! `benches/micro_hot_paths.rs` measures the worker-step scaling.
//!
//! # The `[precision]` section and `exec.simd`
//!
//! Every preset (and config file) may also pick the mixed-precision
//! surface and the SIMD kernel dispatch (DESIGN.md §8):
//!
//! ```toml
//! [exec]
//! simd = "auto"        # default; "on" / "off" force the dispatch
//!                      # (bitwise-identical either way — wall-clock only)
//! [precision]
//! wire = "f32"         # or "bf16": sync payloads ship as bf16, exactly
//!                      # halving recorded wire bytes (needs
//!                      # comm.transport = "channel", compression = "none")
//! state = "f32"        # or "bf16": optimizer accumulators rounded
//!                      # through bf16 each step; weights stay f32 masters
//! ```
//!
//! The `mixed-precision` preset below is the canonical example;
//! `benches/comm_reduction.rs` compares f32 / bf16 / bf16+delta wire
//! bytes and `benches/micro_hot_paths.rs` the serial-vs-SIMD kernels.
//!
//! # The networked transport (`[net]` sockets)
//!
//! `comm.transport = "tcp"` (or `"uds"`) moves the same lockstep protocol
//! onto real sockets: one leader process, one OS process per worker
//! (DESIGN.md §4):
//!
//! ```toml
//! [comm]
//! transport = "tcp"    # or "uds" (Unix-domain socket path)
//! [net]
//! listen = "127.0.0.1:0"   # leader bind; ":0" picks a free port, which
//!                          # --port-file publishes for the workers
//! connect = ""             # worker side: leader address (or --connect)
//! connect_timeout_s = 30.0
//! connect_retries = 10     # linear backoff between dial attempts
//! retry_backoff_s = 0.05
//! nodelay = true
//! ```
//!
//! The `tcp-loopback` preset below is the canonical example;
//! `tests/integration_net.rs` pins multi-process runs bitwise against the
//! in-process reference and `benches/net_loopback.rs` records the real
//! frame traffic.

use crate::error::{Error, Result};

use super::schema::ExperimentConfig;
use super::toml::TomlDoc;

/// A named, documented experiment preset.
pub struct Preset {
    /// CLI spelling (`--experiment <name>`).
    pub name: &'static str,
    /// One-line description shown by `adaalter presets`.
    pub summary: &'static str,
    /// The preset as a TOML snippet (parsed through the normal path).
    pub toml: &'static str,
}

/// All built-in presets.
pub const PRESETS: &[Preset] = &[
    Preset {
        name: "paper-default",
        summary: "Paper §6.2 default: 8 workers, local AdaAlter H=4, η=0.5, warm-up 600",
        toml: r#"
[train]
workers = 8
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
"#,
    },
    Preset {
        name: "adagrad-baseline",
        summary: "Fully-synchronous distributed AdaGrad (Alg. 1), 8 workers",
        toml: r#"
[train]
workers = 8
sync_period = 1
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "adagrad"
"#,
    },
    Preset {
        name: "adaalter-sync",
        summary: "Fully-synchronous AdaAlter (Alg. 3), 8 workers",
        toml: r#"
[train]
workers = 8
sync_period = 1
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "adaalter"
"#,
    },
    Preset {
        name: "tiny-lm",
        summary: "PJRT tiny transformer LM, 4 workers, local AdaAlter H=4",
        toml: r#"
[train]
preset = "tiny"
workers = 4
sync_period = 4
steps = 200
steps_per_epoch = 50
log_every = 10
backend = "pjrt"
[optim]
algorithm = "local_adaalter"
warmup_steps = 50
"#,
    },
    Preset {
        name: "small-lm",
        summary: "PJRT small (~0.9M param) LM, 8 workers, local AdaAlter H=4 — the e2e driver",
        toml: r#"
[train]
preset = "small"
workers = 8
sync_period = 4
steps = 300
steps_per_epoch = 100
log_every = 10
eval_every = 50
backend = "pjrt"
[optim]
algorithm = "local_adaalter"
warmup_steps = 60
"#,
    },
    Preset {
        name: "compressed-qsgd",
        summary: "Local AdaAlter H=4 over QSGD-compressed wire (s=15), exact byte accounting",
        toml: r#"
[train]
workers = 4
sync_period = 4
steps = 800
steps_per_epoch = 200
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[comm]
transport = "channel"
compression = "qsgd"
qsgd_levels = 15
"#,
    },
    Preset {
        name: "ring-allreduce",
        summary: "Local AdaAlter H=4 over a simulated ring all-reduce instead of the paper's PS",
        toml: r#"
[train]
workers = 8
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[net]
topology = "allreduce"
[comm]
transport = "simulated"
"#,
    },
    Preset {
        name: "sharded-ps",
        summary: "Local AdaAlter H=4 over a 4-shard parameter server (incast split 4 ways)",
        toml: r#"
[train]
workers = 8
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[comm]
transport = "simulated"
shards = 4
"#,
    },
    Preset {
        name: "tree-allreduce",
        summary: "Local AdaAlter H=4 over a fan-out-4 tree reduction (depth ⌈log₄ n⌉)",
        toml: r#"
[train]
workers = 8
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[net]
topology = "tree"
tree_fanout = 4
[comm]
transport = "simulated"
"#,
    },
    Preset {
        name: "adaptive-drift",
        summary: "Local AdaAlter with CADA-style drift-triggered syncs (θ=4, H≤32)",
        toml: r#"
[train]
workers = 8
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[sync]
policy = "drift"
drift_threshold = 4.0
h_max = 32
"#,
    },
    Preset {
        name: "time-budget",
        summary: "Local AdaAlter with H re-derived each round to hold comm at 5% of wall-clock",
        toml: r#"
[train]
workers = 8
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[sync]
policy = "time_budget"
target_comm_fraction = 0.05
h_max = 64
"#,
    },
    Preset {
        name: "straggler-quorum",
        summary: "1 of 8 workers 4× slow; quorum-7 sync rounds drop it instead of waiting",
        toml: r#"
[train]
workers = 8
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
fused = false
[optim]
algorithm = "local_adaalter"
[faults]
slow_workers = 1
slow_factor = 4.0
quorum = 7
"#,
    },
    Preset {
        name: "elastic-spot",
        summary: "Spot-fleet churn: 1 of 6 workers dies and rejoins under quorum-3; autoscaler admits a queued spare on sustained drift",
        toml: r#"
[train]
workers = 6
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
fused = false
[optim]
algorithm = "local_adaalter"
[faults]
quorum = 3
crash_worker = 4
crash_step = 400
rejoin_step = 570
spawn_workers = 1
spawn_step = 0
autoscale = true
autoscale_patience = 4
autoscale_drift = 0.5
autoscale_straggler_s = 0.05
"#,
    },
    Preset {
        name: "parallel-hosts",
        summary: "Paper default on the threaded execution engine (8 workers over 4 host threads)",
        toml: r#"
[train]
workers = 8
sync_period = 4
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[exec]
parallelism = "threads"
threads = 4
"#,
    },
    Preset {
        name: "mixed-precision",
        summary: "Local AdaAlter H=4 with bf16 wire + bf16 optimizer state, SIMD forced on",
        toml: r#"
[train]
workers = 4
sync_period = 4
steps = 800
steps_per_epoch = 200
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[comm]
transport = "channel"
[exec]
simd = "on"
[precision]
wire = "bf16"
state = "bf16"
"#,
    },
    Preset {
        name: "tcp-loopback",
        summary: "Local AdaAlter H=4 over real loopback TCP: leader + 4 worker processes",
        toml: r#"
[train]
workers = 4
sync_period = 4
steps = 200
steps_per_epoch = 50
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[comm]
transport = "tcp"
[net]
listen = "127.0.0.1:0"
"#,
    },
    Preset {
        name: "noniid-stress",
        summary: "Fully non-IID shards (D_i disjoint), local AdaAlter H=8",
        toml: r#"
[train]
workers = 8
sync_period = 8
steps = 2000
steps_per_epoch = 500
backend = "rust_math"
[optim]
algorithm = "local_adaalter"
[data]
noniid = 1.0
"#,
    },
];

/// Resolve a preset by name into a validated config.
pub fn load_preset(name: &str) -> Result<ExperimentConfig> {
    let p = PRESETS
        .iter()
        .find(|p| p.name == name)
        .ok_or_else(|| {
            let names: Vec<_> = PRESETS.iter().map(|p| p.name).collect();
            Error::Config(format!("unknown experiment preset {name:?}; available: {names:?}"))
        })?;
    ExperimentConfig::from_doc(&TomlDoc::parse(p.toml)?)
}

/// Resolve a preset into its TOML doc (so CLI --set overrides can stack).
pub fn preset_doc(name: &str) -> Result<TomlDoc> {
    let p = PRESETS
        .iter()
        .find(|p| p.name == name)
        .ok_or_else(|| Error::Config(format!("unknown experiment preset {name:?}")))?;
    TomlDoc::parse(p.toml)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::{Algorithm, SyncPeriod};

    #[test]
    fn all_presets_parse_and_validate() {
        for p in PRESETS {
            let c = load_preset(p.name)
                .unwrap_or_else(|e| panic!("preset {} invalid: {e}", p.name));
            assert!(c.train.workers >= 1, "{}", p.name);
        }
    }

    #[test]
    fn paper_default_matches_paper() {
        let c = load_preset("paper-default").unwrap();
        assert_eq!(c.train.workers, 8);
        assert_eq!(c.train.sync_period, SyncPeriod::Every(4));
        assert_eq!(c.optim.algorithm, Algorithm::LocalAdaAlter);
        assert_eq!(c.optim.eta, 0.5);
        assert_eq!(c.optim.warmup_steps, 600);
    }

    #[test]
    fn unknown_preset_lists_options() {
        let err = load_preset("nope").unwrap_err().to_string();
        assert!(err.contains("paper-default"), "{err}");
    }

    #[test]
    fn noniid_preset_is_fully_disjoint() {
        let c = load_preset("noniid-stress").unwrap();
        assert_eq!(c.data.noniid, 1.0);
    }

    #[test]
    fn exec_preset_selects_threaded_engine() {
        let c = load_preset("parallel-hosts").unwrap();
        assert_eq!(c.exec.parallelism, "threads");
        assert_eq!(c.exec.threads, 4);
        // Every other preset keeps the default layout (one host per
        // worker — the pre-engine thread shape).
        for p in PRESETS.iter().filter(|p| p.name != "parallel-hosts") {
            let e = load_preset(p.name).unwrap().exec;
            assert_eq!((e.parallelism.as_str(), e.threads), ("threads", 0), "{}", p.name);
        }
    }

    #[test]
    fn sync_presets_select_policies() {
        let c = load_preset("adaptive-drift").unwrap();
        assert_eq!(c.sync.policy, "drift");
        assert_eq!(c.sync.drift_threshold, 4.0);
        assert_eq!(c.sync.h_max, 32);
        let t = load_preset("time-budget").unwrap();
        assert_eq!(t.sync.policy, "time_budget");
        assert_eq!(t.sync.target_comm_fraction, 0.05);
        // All other presets keep the bitwise-identical fixed schedule.
        let d = load_preset("paper-default").unwrap();
        assert!(d.sync.is_fixed());
    }

    #[test]
    fn faults_preset_selects_quorum_scenario() {
        let c = load_preset("straggler-quorum").unwrap();
        assert_eq!(c.faults.slow_workers, 1);
        assert_eq!(c.faults.slow_factor, 4.0);
        assert_eq!(c.faults.quorum, 7);
        assert!(!c.train.fused);
        assert!(c.faults.is_active() && c.faults.partial());
        // Every other preset keeps the fault-free (bitwise-seed) trainer —
        // except the elastic-membership scenario, which churns by design.
        let churny = ["straggler-quorum", "elastic-spot"];
        for p in PRESETS.iter().filter(|p| !churny.contains(&p.name)) {
            assert!(!load_preset(p.name).unwrap().faults.is_active(), "{}", p.name);
        }
    }

    #[test]
    fn elastic_preset_selects_churn_and_autoscale() {
        let c = load_preset("elastic-spot").unwrap();
        assert_eq!(c.faults.crash_worker, 4);
        assert_eq!(c.faults.rejoin_step, 570);
        assert_eq!((c.faults.spawn_workers, c.faults.spawn_step), (1, 0));
        assert!(c.faults.autoscale && c.faults.has_churn());
        assert_eq!(c.faults.autoscale_patience, 4);
        assert_eq!(c.faults.quorum, 3);
        assert!(!c.train.fused);
    }

    #[test]
    fn precision_preset_selects_bf16_and_simd() {
        let c = load_preset("mixed-precision").unwrap();
        assert!(c.precision.wire_bf16() && c.precision.state_bf16());
        assert_eq!(c.exec.simd, "on");
        assert_eq!(c.comm.transport, "channel");
        assert_eq!(c.comm.compression, "none");
        // Every other preset stays full-f32 with auto dispatch — the
        // bitwise-seed precision surface.
        for p in PRESETS.iter().filter(|p| p.name != "mixed-precision") {
            let c = load_preset(p.name).unwrap();
            assert!(!c.precision.wire_bf16() && !c.precision.state_bf16(), "{}", p.name);
            assert_eq!(c.exec.simd, "auto", "{}", p.name);
        }
    }

    #[test]
    fn tcp_loopback_preset_selects_the_networked_transport() {
        let c = load_preset("tcp-loopback").unwrap();
        assert!(c.comm.networked());
        assert_eq!(c.comm.transport, "tcp");
        assert_eq!(c.net.listen, "127.0.0.1:0");
        assert_eq!(c.net.topology, "ps");
        // Every other preset stays in-process.
        for p in PRESETS.iter().filter(|p| p.name != "tcp-loopback") {
            assert!(!load_preset(p.name).unwrap().comm.networked(), "{}", p.name);
        }
    }

    #[test]
    fn topology_presets_select_shards_and_tree() {
        let s = load_preset("sharded-ps").unwrap();
        assert_eq!(s.comm.shards, 4);
        assert_eq!(s.comm.transport, "simulated");
        assert_eq!(s.net.topology, "ps");
        let t = load_preset("tree-allreduce").unwrap();
        assert_eq!(t.net.topology, "tree");
        assert_eq!(t.net.tree_fanout, 4);
        assert_eq!(t.comm.shards, 1);
        // Every other preset keeps the unsharded single-leader PS (or its
        // explicitly chosen ring) — the bitwise-seed comm shape.
        for p in PRESETS.iter().filter(|p| p.name != "sharded-ps") {
            assert_eq!(load_preset(p.name).unwrap().comm.shards, 1, "{}", p.name);
        }
    }

    #[test]
    fn comm_presets_select_transports() {
        let c = load_preset("compressed-qsgd").unwrap();
        assert_eq!(c.comm.transport, "channel");
        assert_eq!(c.comm.compression, "qsgd");
        assert_eq!(c.comm.qsgd_levels, 15);
        let r = load_preset("ring-allreduce").unwrap();
        assert_eq!(r.net.topology, "allreduce");
        assert_eq!(r.comm.transport, "simulated");
        assert_eq!(r.comm.compression, "none");
    }
}
