//! # adaalter — Local AdaAlter, reproduced as a deployable training framework
//!
//! Rust implementation of *Local AdaAlter: Communication-Efficient Stochastic
//! Gradient Descent with Adaptive Learning Rates* (Xie, Koyejo, Gupta, Lin;
//! 2019), built as the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: worker
//!   threads, the synchronization subsystem with the paper's `t'·ε²`
//!   placeholder denominator (a pluggable [`coordinator::SyncPolicy`]
//!   family — fixed H, growing H, drift-triggered, time-budget — fed
//!   per-round observations from the collective layer),
//!   parameter/denominator averaging, a pluggable
//!   collective-communication layer ([`comm::Collective`]: in-process
//!   lockstep, α–β-charged parameter-server / ring-allreduce simulation,
//!   QSGD / top-k compressed transports with exact wire-byte accounting),
//!   a deterministic fault & straggler scenario engine with
//!   partial-participation sync rounds ([`sim::FaultPlan`] +
//!   [`comm::PartialCollective`]: seeded slowdowns/stalls/crashes, quorum
//!   and backup-worker barriers), a bitwise-deterministic execution
//!   engine ([`coordinator::executor`]: `[exec]`-selected worker→thread
//!   layouts over shared hot-path kernels ([`util::kernels`]) with
//!   zero-allocation steady state ([`util::pool`])), warm-up
//!   learning-rate schedule, data pipeline, metrics, CLI.
//! * **L2 (python/compile, build time only)** — a JAX transformer language
//!   model lowered once to HLO-text artifacts (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels for the fused
//!   optimizer updates, lowered inside the L2 graphs.
//!
//! At runtime only this crate runs: artifacts are loaded through the PJRT C
//! API ([`runtime`]) and Python never sits on the training path.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every figure/table of the paper to a bench target.
#![warn(missing_docs)]

pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod metrics;
pub mod optim;
pub mod runtime;
pub mod sim;
pub mod util;

pub use error::{Error, Result};

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// The paper's protocol constants (§6.3: "in all the experiments, we take
/// ε = 1, b₀ = 1"; §6.2.1: η = 0.5, warm_up_steps = 600).
pub mod paper {
    /// Numerical-stability / placeholder constant ε.
    pub const EPSILON: f32 = 1.0;
    /// Accumulator initialisation b₀ (B₀² = b₀²·1).
    pub const B0: f32 = 1.0;
    /// Tuned base learning rate η for the 8×256 configuration.
    pub const ETA: f32 = 0.5;
    /// Warm-up steps for AdaAlter's small-denominator start.
    pub const WARM_UP_STEPS: u64 = 600;
    /// Synchronization periods evaluated in Fig. 1/2/3 and Table 2.
    pub const H_SWEEP: [u64; 4] = [4, 8, 12, 16];
    /// Iterations per epoch in the paper's setup (each epoch processes
    /// 20,000 × 8 × 256 samples).
    pub const STEPS_PER_EPOCH: u64 = 20_000;
}
