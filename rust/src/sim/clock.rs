//! Virtual clock — deterministic simulated-time accounting.
//!
//! Real wall-clock on this 1-core box says nothing about an 8×V100
//! cluster; every time-axis in the reproduced figures is *virtual*: the
//! trainer charges each iteration with modeled compute/dataload/sync costs
//! (from [`super::calib`]) and the clock integrates them. Charges are
//! labelled so benches can report the time composition (compute vs
//! communication vs data loading — exactly Fig. 1's decomposition).

use std::collections::BTreeMap;

/// What a time charge pays for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Charge {
    /// Forward/backward + optimizer computation.
    Compute,
    /// Host data loading (the §6.4 bottleneck).
    DataLoad,
    /// Synchronization (PS push/pull or all-reduce).
    Communication,
    /// Barrier time spent waiting for slow / stalled workers beyond the
    /// lockstep-nominal iteration cost — the fault model's visible penalty
    /// (DESIGN.md §6; zero unless a `[faults]` scenario is active).
    Straggler,
    /// Anything else (checkpointing, eval…).
    Other,
}

/// Accumulating virtual clock.
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    now_s: f64,
    by_charge: BTreeMap<Charge, f64>,
}

impl VirtualClock {
    /// Fresh clock at t = 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance by `dt` seconds, attributed to `charge`.
    pub fn advance(&mut self, charge: Charge, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad time charge {dt}");
        self.now_s += dt;
        *self.by_charge.entry(charge).or_insert(0.0) += dt;
    }

    /// Current virtual time, seconds.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Total attributed to one charge class.
    pub fn total(&self, charge: Charge) -> f64 {
        self.by_charge.get(&charge).copied().unwrap_or(0.0)
    }

    /// (charge, seconds) breakdown, sorted by charge.
    pub fn breakdown(&self) -> Vec<(Charge, f64)> {
        self.by_charge.iter().map(|(&c, &t)| (c, t)).collect()
    }

    /// Fraction of total time in `charge` (0 if clock never advanced).
    pub fn fraction(&self, charge: Charge) -> f64 {
        if self.now_s == 0.0 {
            0.0
        } else {
            self.total(charge) / self.now_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_and_attributes() {
        let mut c = VirtualClock::new();
        c.advance(Charge::Compute, 1.5);
        c.advance(Charge::Communication, 0.5);
        c.advance(Charge::Compute, 0.5);
        assert_eq!(c.now_s(), 2.5);
        assert_eq!(c.total(Charge::Compute), 2.0);
        assert_eq!(c.total(Charge::Communication), 0.5);
        assert_eq!(c.total(Charge::DataLoad), 0.0);
        assert!((c.fraction(Charge::Compute) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn breakdown_sums_to_now() {
        let mut c = VirtualClock::new();
        c.advance(Charge::Compute, 1.0);
        c.advance(Charge::DataLoad, 2.0);
        c.advance(Charge::Other, 3.0);
        let sum: f64 = c.breakdown().iter().map(|(_, t)| t).sum();
        assert_eq!(sum, c.now_s());
    }

    #[test]
    #[should_panic(expected = "bad time charge")]
    fn rejects_negative_time() {
        VirtualClock::new().advance(Charge::Compute, -1.0);
    }

    #[test]
    fn empty_clock_fraction_zero() {
        assert_eq!(VirtualClock::new().fraction(Charge::Compute), 0.0);
    }
}
