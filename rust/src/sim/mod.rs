//! Cluster simulation: virtual clock, paper-calibrated V100 cost model,
//! analytic epoch/throughput model (Fig. 1/2), deterministic fault &
//! straggler scenarios (DESIGN.md §6), and the synthetic non-IID
//! optimization workload for the rust-native backend.

pub mod calib;
pub mod clock;
pub mod epoch_model;
pub mod faults;
pub mod synthetic;

pub use calib::Calibration;
pub use clock::{Charge, VirtualClock};
pub use epoch_model::{EpochModel, IterCost, SimAlgo};
pub use faults::FaultPlan;
pub use synthetic::{SyntheticBackend, SyntheticProblem};
