//! Deterministic fault & straggler scenarios (DESIGN.md §6).
//!
//! The paper's §2 premise is that the synchronous barrier "blocks the
//! global update until all the workers respond" — so the dominant
//! production failure class is a worker that is *slow, stalled, or dead*.
//! This module makes that class runnable: a [`FaultPlan`] is a seeded,
//! per-worker schedule of
//!
//! * **permanent slowdowns** — worker `w`'s modeled compute time is
//!   multiplied by a factor ≥ 1 on every iteration;
//! * **transient stalls** — with probability `p` per `(worker, step)`,
//!   worker `w` loses a fixed number of virtual seconds at step `t`;
//! * **permanent crashes** — worker `w` executes steps `t < crash_step`
//!   and is dead from `crash_step` on (the worker thread answers further
//!   step commands with a tombstone reply instead of a gradient);
//! * **rejoins** — a crashed worker comes back at `rejoin_step`: the
//!   leader's membership table re-admits it at the next sync-round
//!   boundary and warm-starts it through the `InstallState` catch-up
//!   path (DESIGN.md "Elastic membership & recovery");
//! * **spawns** — worker `w` is absent at startup and only joins the
//!   live set at `spawn_step` (`Some(0)` marks a *queued spare* that
//!   only the telemetry-driven autoscale policy may admit).
//!
//! Everything is a pure function of `(config seed, worker, step)` — the
//! same keying discipline the gradient streams use — so a scenario
//! replays bit-for-bit across runs and worker-thread interleavings, and
//! the whole scenario space is property-testable. An empty plan disables
//! every fault code path in the trainer, which then stays bitwise
//! identical to the fault-free leader loop.
//!
//! Plans are normally built from the `[faults]` config section
//! ([`FaultPlan::from_config`]); tests and benches can also compose them
//! programmatically with the builder methods.

use crate::config::ExperimentConfig;
use crate::util::rng::Rng;

/// Domain-separation tag for the stall stream (keeps fault randomness
/// independent of the gradient/data streams derived from the same seed).
const STALL_TAG: u64 = 0x00FA_0175;

/// A deterministic per-worker fault schedule (see module docs).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Experiment seed the stall stream derives from.
    seed: u64,
    /// Per-worker permanent compute-time multiplier (1.0 = nominal).
    slow: Vec<f64>,
    /// Per-(worker, step) transient-stall probability.
    stall_prob: f64,
    /// Virtual seconds one stall costs.
    stall_dur_s: f64,
    /// Per-worker crash step (the worker executes steps `t < crash`).
    crash: Vec<Option<u64>>,
    /// Per-worker rejoin step: a crashed worker is scheduled live again
    /// for `t >= rejoin` (requires a crash step, and `rejoin > crash`).
    rejoin: Vec<Option<u64>>,
    /// Per-worker spawn step: the worker is absent before `spawn`.
    /// `Some(0)` marks a queued spare only the autoscale policy admits.
    spawn: Vec<Option<u64>>,
}

impl FaultPlan {
    /// The empty (fault-free) plan for `n` workers.
    pub fn none(n: usize) -> Self {
        FaultPlan {
            seed: 0,
            slow: vec![1.0; n],
            stall_prob: 0.0,
            stall_dur_s: 0.0,
            crash: vec![None; n],
            rejoin: vec![None; n],
            spawn: vec![None; n],
        }
    }

    /// Build the plan the `[faults]` config section describes: the
    /// `faults.slow_workers` *highest* worker ids are permanently slowed
    /// by `faults.slow_factor` (worker 0 stays fast — it is also the eval
    /// worker), stalls are seeded from `train.seed`, and
    /// `faults.crash_worker` dies at `faults.crash_step`.
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        let n = cfg.train.workers;
        let f = &cfg.faults;
        let mut plan = FaultPlan::none(n);
        plan.seed = cfg.train.seed;
        for w in n.saturating_sub(f.slow_workers)..n {
            plan.slow[w] = f.slow_factor;
        }
        if f.stall_prob > 0.0 {
            plan.stall_prob = f.stall_prob;
            plan.stall_dur_s = f.stall_s;
        }
        if f.crash_worker >= 0 && (f.crash_worker as usize) < n {
            plan.crash[f.crash_worker as usize] = Some(f.crash_step);
            if f.rejoin_step > 0 {
                plan.rejoin[f.crash_worker as usize] = Some(f.rejoin_step);
            }
        }
        // Spawned workers (scheduled scale-up / autoscale spares) take the
        // *highest* ids, like `slow_workers` — worker 0 stays present (it
        // is also the eval worker).
        for w in n.saturating_sub(f.spawn_workers)..n {
            plan.spawn[w] = Some(f.spawn_step);
        }
        plan
    }

    /// Number of workers the plan covers.
    pub fn n(&self) -> usize {
        self.slow.len()
    }

    /// True when the plan schedules no fault at all — the trainer then
    /// takes the exact fault-free code paths.
    pub fn is_empty(&self) -> bool {
        self.slow.iter().all(|&f| f == 1.0)
            && self.stall_prob == 0.0
            && self.crash.iter().all(Option::is_none)
            && !self.has_churn()
    }

    /// Does the plan schedule any membership change beyond a permanent
    /// crash — a rejoin or a spawned/spare worker?
    pub fn has_churn(&self) -> bool {
        self.rejoin.iter().any(Option::is_some)
            || self.spawn.iter().any(Option::is_some)
    }

    /// Builder: re-seed the stall stream.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder: permanently slow worker `w` by `factor` (≥ 1).
    pub fn with_slow(mut self, w: usize, factor: f64) -> Self {
        assert!(factor >= 1.0 && factor.is_finite(), "slow factor must be >= 1");
        self.slow[w] = factor;
        self
    }

    /// Builder: crash worker `w` at step `step` (≥ 1; the worker executes
    /// steps `t < step`).
    pub fn with_crash(mut self, w: usize, step: u64) -> Self {
        assert!(step >= 1, "crash step is 1-based");
        self.crash[w] = Some(step);
        self
    }

    /// Builder: schedule crashed worker `w` to rejoin at `step` (strictly
    /// after its crash step; re-admitted at the next sync boundary ≥ step).
    pub fn with_rejoin(mut self, w: usize, step: u64) -> Self {
        let crash = self.crash[w].expect("rejoin requires a crash step");
        assert!(step > crash, "rejoin step must be > crash step");
        self.rejoin[w] = Some(step);
        self
    }

    /// Builder: worker `w` is absent until `step` (admitted at the first
    /// sync boundary ≥ step). `step = 0` queues it as an autoscale spare.
    pub fn with_spawn(mut self, w: usize, step: u64) -> Self {
        self.spawn[w] = Some(step);
        self
    }

    /// Builder: transient stalls of `dur_s` virtual seconds with
    /// per-(worker, step) probability `prob`.
    pub fn with_stalls(mut self, prob: f64, dur_s: f64) -> Self {
        assert!((0.0..1.0).contains(&prob), "stall probability in [0, 1)");
        assert!(dur_s >= 0.0 && dur_s.is_finite(), "stall duration >= 0");
        self.stall_prob = prob;
        self.stall_dur_s = dur_s;
        self
    }

    /// Worker `w`'s permanent compute-time multiplier.
    pub fn slow_factor(&self, w: usize) -> f64 {
        self.slow[w]
    }

    /// Worker `w`'s crash step, if it ever crashes.
    pub fn crash_step(&self, w: usize) -> Option<u64> {
        self.crash[w]
    }

    /// Worker `w`'s scheduled rejoin step, if its crash is temporary.
    pub fn rejoin_step(&self, w: usize) -> Option<u64> {
        self.rejoin[w]
    }

    /// Worker `w`'s spawn step, if it starts absent (`Some(0)` = spare).
    pub fn spawn_step(&self, w: usize) -> Option<u64> {
        self.spawn[w]
    }

    /// Is worker `w` a queued spare — absent until the autoscale policy
    /// admits it?
    pub fn is_spare(&self, w: usize) -> bool {
        self.spawn[w] == Some(0)
    }

    /// The step at which an absent worker `w` becomes schedulable again
    /// (the leader admits it at the first sync boundary ≥ this step):
    /// the spawn step for spawned workers, the rejoin step for temporary
    /// crashes. `None` for permanent crashes and queued spares.
    pub fn readmit_step(&self, w: usize) -> Option<u64> {
        if let Some(s) = self.spawn[w] {
            return if s > 0 { Some(s) } else { None };
        }
        if self.crash[w].is_some() {
            self.rejoin[w]
        } else {
            None
        }
    }

    /// Is worker `w` scheduled live at iteration `t` (1-based)? Absent
    /// before its spawn step, dead in the `[crash, rejoin)` window (or
    /// from `crash` on when no rejoin is scheduled).
    pub fn alive(&self, w: usize, t: u64) -> bool {
        if let Some(s) = self.spawn[w] {
            if s == 0 || t < s {
                return false;
            }
        }
        match self.crash[w] {
            None => true,
            Some(c) => t < c || self.rejoin[w].is_some_and(|r| t >= r),
        }
    }

    /// The stall worker `w` suffers at step `t`, in virtual seconds — a
    /// pure function of `(seed, worker, step)`, so identical across runs
    /// and thread interleavings.
    pub fn stall_s(&self, w: usize, t: u64) -> f64 {
        if self.stall_prob <= 0.0 {
            return 0.0;
        }
        let mut rng = Rng::derive(self.seed, &[STALL_TAG, w as u64, t]);
        if rng.bernoulli(self.stall_prob) {
            self.stall_dur_s
        } else {
            0.0
        }
    }

    /// Worker `w`'s modeled wall time for iteration `t`, given the
    /// lockstep-nominal compute cost `base_s`:
    /// `base · slow_factor(w) + stall(w, t)`.
    pub fn step_time_s(&self, w: usize, t: u64, base_s: f64) -> f64 {
        base_s * self.slow[w] + self.stall_s(w, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::util::prop;

    #[test]
    fn empty_plan_is_empty_and_free() {
        let p = FaultPlan::none(4);
        assert!(p.is_empty());
        assert_eq!(p.n(), 4);
        for w in 0..4 {
            assert_eq!(p.slow_factor(w), 1.0);
            assert_eq!(p.crash_step(w), None);
            for t in 1..50 {
                assert!(p.alive(w, t));
                assert_eq!(p.stall_s(w, t), 0.0);
                assert_eq!(p.step_time_s(w, t, 0.25), 0.25);
            }
        }
    }

    #[test]
    fn from_config_slows_highest_ids_and_crashes_the_named_worker() {
        let mut cfg = ExperimentConfig::default();
        cfg.train.workers = 4;
        cfg.faults.slow_workers = 2;
        cfg.faults.slow_factor = 4.0;
        cfg.faults.crash_worker = 1;
        cfg.faults.crash_step = 7;
        let p = FaultPlan::from_config(&cfg);
        assert!(!p.is_empty());
        assert_eq!(p.slow_factor(0), 1.0);
        assert_eq!(p.slow_factor(1), 1.0);
        assert_eq!(p.slow_factor(2), 4.0);
        assert_eq!(p.slow_factor(3), 4.0);
        assert_eq!(p.crash_step(1), Some(7));
        assert!(p.alive(1, 6));
        assert!(!p.alive(1, 7));
        assert!(!p.alive(1, 700));
        assert_eq!(p.step_time_s(3, 1, 0.2), 0.8);
    }

    #[test]
    fn default_config_yields_empty_plan() {
        let p = FaultPlan::from_config(&ExperimentConfig::default());
        assert!(p.is_empty());
    }

    #[test]
    fn stalls_are_deterministic_and_roughly_calibrated() {
        let p = FaultPlan::none(2).with_seed(42).with_stalls(0.25, 0.05);
        let q = FaultPlan::none(2).with_seed(42).with_stalls(0.25, 0.05);
        let mut hits = 0u64;
        let total = 4000u64;
        for t in 1..=total {
            let a = p.stall_s(1, t);
            assert_eq!(a, q.stall_s(1, t), "stall stream not deterministic at t={t}");
            assert!(a == 0.0 || a == 0.05);
            if a > 0.0 {
                hits += 1;
            }
        }
        let frac = hits as f64 / total as f64;
        assert!((frac - 0.25).abs() < 0.05, "stall fraction {frac}");
        // Different seed ⇒ different stream (astronomically likely).
        let r = FaultPlan::none(2).with_seed(43).with_stalls(0.25, 0.05);
        let diverges = (1..=200u64).any(|t| r.stall_s(1, t) != p.stall_s(1, t));
        assert!(diverges, "seed must matter");
        // Worker id separates streams too.
        let diverges = (1..=200u64).any(|t| p.stall_s(0, t) != p.stall_s(1, t));
        assert!(diverges, "worker id must matter");
    }

    #[test]
    fn properties_step_time_and_liveness() {
        prop::check("fault plan invariants", 200, |g| {
            let n = g.usize_in(1..8);
            let mut plan = FaultPlan::none(n).with_seed(g.u64_in(0..1 << 20));
            let w = g.usize_in(0..n);
            let factor = g.f64_in(1.0..8.0);
            plan = plan.with_slow(w, factor);
            if g.bool() {
                plan = plan.with_stalls(g.f64_in(0.0..0.9), g.f64_in(0.0..0.2));
            }
            let crash = g.u64_in(1..100);
            plan = plan.with_crash(w, crash);
            let base = g.f64_in(0.01..1.0);
            for t in 1..=64u64 {
                let tw = plan.step_time_s(w, t, base);
                prop::assert_that(
                    tw >= base * factor - 1e-12,
                    format!("step time {tw} below slowed base"),
                )?;
                // Once dead, dead forever.
                if !plan.alive(w, t) {
                    prop::assert_that(!plan.alive(w, t + 1), "resurrection")?;
                }
            }
            prop::assert_that(!plan.alive(w, crash), "alive at crash step")?;
            prop::assert_that(crash == 1 || plan.alive(w, crash - 1), "dead too early")
        });
    }

    #[test]
    #[should_panic(expected = "slow factor")]
    fn builder_rejects_speedups() {
        let _ = FaultPlan::none(2).with_slow(0, 0.5);
    }

    #[test]
    #[should_panic(expected = "rejoin step")]
    fn builder_rejects_rejoin_before_crash() {
        let _ = FaultPlan::none(2).with_crash(1, 10).with_rejoin(1, 10);
    }

    #[test]
    fn churn_schedule_windows_liveness() {
        let p = FaultPlan::none(4)
            .with_crash(1, 8)
            .with_rejoin(1, 13)
            .with_spawn(3, 5);
        assert!(p.has_churn() && !p.is_empty());
        // Crash window [8, 13): dead inside, alive either side.
        assert!(p.alive(1, 7) && !p.alive(1, 8) && !p.alive(1, 12));
        assert!(p.alive(1, 13) && p.alive(1, 500));
        assert_eq!(p.readmit_step(1), Some(13));
        // Spawned worker: absent before 5, present after.
        assert!(!p.alive(3, 1) && !p.alive(3, 4) && p.alive(3, 5));
        assert_eq!(p.readmit_step(3), Some(5));
        assert!(!p.is_spare(3));
        // A queued spare is never plan-alive and has no readmit step.
        let q = FaultPlan::none(2).with_spawn(1, 0);
        assert!(q.is_spare(1) && q.has_churn());
        assert!((1..100).all(|t| !q.alive(1, t)));
        assert_eq!(q.readmit_step(1), None);
        // Permanent crashes keep the pre-churn contract.
        let perm = FaultPlan::none(2).with_crash(0, 3);
        assert!(!perm.has_churn());
        assert_eq!(perm.readmit_step(0), None);
        assert!((3..100).all(|t| !perm.alive(0, t)));
    }

    #[test]
    fn from_config_builds_rejoin_and_spawn_schedules() {
        let mut cfg = ExperimentConfig::default();
        cfg.train.workers = 4;
        cfg.faults.crash_worker = 1;
        cfg.faults.crash_step = 6;
        cfg.faults.rejoin_step = 11;
        cfg.faults.spawn_workers = 1;
        cfg.faults.spawn_step = 9;
        let p = FaultPlan::from_config(&cfg);
        assert_eq!(p.rejoin_step(1), Some(11));
        assert_eq!(p.spawn_step(3), Some(9));
        assert!(p.has_churn());
        // Replay: the schedule is a pure function of the config.
        let q = FaultPlan::from_config(&cfg);
        for w in 0..4 {
            for t in 1..64 {
                assert_eq!(p.alive(w, t), q.alive(w, t), "w={w} t={t}");
            }
        }
    }
}
