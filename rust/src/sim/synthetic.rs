//! Synthetic non-IID optimization workload — the rust-native backend.
//!
//! An ill-conditioned least-squares problem that satisfies the paper's
//! Assumptions 1–2 exactly and exposes the effects the theory predicts:
//!
//! ```text
//!   F_i(x)  = ½ Σ_j a_j (x_j − c_{i,j})²          (worker i's local loss)
//!   ∇f_i(x) = a ∘ (x − c_i) + ξ,   ξ ~ N(0, σ²)   (stochastic gradient)
//! ```
//!
//! * `a_j` log-spaced over three decades ⇒ per-coordinate curvature spread,
//!   the regime where adaptive (AdaGrad-family) methods beat plain SGD —
//!   the reason the paper wants adaptive learning rates at all;
//! * worker centres `c_i = skew · δ_i` with `‖δ_i‖` controlled by the
//!   non-IID knob ⇒ `∇F_i ≠ ∇F_j` (the paper's `D_i ≠ D_j` setting);
//! * the global optimum is `x* = mean_i c_i`, so the exact suboptimality
//!   `F(x) − F(x*)` is available in closed form for convergence plots.
//!
//! L-smoothness holds with `L = max_j a_j`; bounded-gradient (Assumption 2)
//! holds on any bounded iterate region, matching the theory's setting.

use crate::coordinator::backend::{EvalMetrics, WorkerBackend};
use crate::error::Result;
use crate::util::rng::Rng;

/// Configuration of the synthetic problem.
#[derive(Clone, Debug)]
pub struct SyntheticProblem {
    /// Problem dimension d.
    pub dim: usize,
    /// Number of workers n (each gets its own local objective).
    pub workers: usize,
    /// Gradient noise σ.
    pub noise: f32,
    /// Non-IID skew of worker centres (0 = identical local objectives).
    pub skew: f32,
    /// Experiment seed.
    pub seed: u64,
}

impl SyntheticProblem {
    /// Paper-shaped default: moderate noise, non-IID workers.
    pub fn new(dim: usize, workers: usize, seed: u64) -> Self {
        SyntheticProblem { dim, workers, noise: 0.1, skew: 1.0, seed }
    }

    /// Per-coordinate curvatures `a_j`, log-spaced in [1e-2, 1e1].
    pub fn curvatures(&self) -> Vec<f32> {
        let d = self.dim;
        (0..d)
            .map(|j| {
                let t = if d > 1 { j as f64 / (d - 1) as f64 } else { 0.0 };
                10f64.powf(-2.0 + 3.0 * t) as f32
            })
            .collect()
    }

    /// Worker i's centre `c_i`.
    pub fn center(&self, worker: usize) -> Vec<f32> {
        let mut rng = Rng::derive(self.seed, &[10, worker as u64]);
        let mut c = vec![0.0f32; self.dim];
        rng.fill_normal(&mut c, self.skew);
        c
    }

    /// The global optimum `x* = mean_i c_i`.
    pub fn optimum(&self) -> Vec<f32> {
        let mut opt = vec![0.0f32; self.dim];
        for w in 0..self.workers {
            let c = self.center(w);
            for j in 0..self.dim {
                opt[j] += c[j] / self.workers as f32;
            }
        }
        opt
    }

    /// Exact global loss `F(x) = (1/n) Σ_i F_i(x)`.
    pub fn global_loss(&self, x: &[f32]) -> f64 {
        let a = self.curvatures();
        let mut total = 0.0f64;
        for w in 0..self.workers {
            let c = self.center(w);
            let mut li = 0.0f64;
            for j in 0..self.dim {
                let r = (x[j] - c[j]) as f64;
                li += 0.5 * a[j] as f64 * r * r;
            }
            total += li;
        }
        total / self.workers as f64
    }

    /// Build the worker-`w` backend.
    pub fn backend(&self, worker: usize) -> SyntheticBackend {
        SyntheticBackend {
            problem: self.clone(),
            worker,
            a: self.curvatures(),
            c: self.center(worker),
        }
    }
}

/// Worker-side backend for the synthetic problem.
pub struct SyntheticBackend {
    problem: SyntheticProblem,
    worker: usize,
    a: Vec<f32>,
    c: Vec<f32>,
}

impl WorkerBackend for SyntheticBackend {
    fn dim(&self) -> usize {
        self.problem.dim
    }

    fn loss_and_grad(&mut self, x: &[f32], step: u64, out: &mut [f32]) -> Result<f32> {
        assert_eq!(x.len(), self.problem.dim);
        assert_eq!(out.len(), self.problem.dim);
        let mut rng = Rng::derive(self.problem.seed, &[20, self.worker as u64, step]);
        let sigma = self.problem.noise;
        let mut loss = 0.0f64;
        for j in 0..x.len() {
            let r = x[j] - self.c[j];
            loss += 0.5 * (self.a[j] * r * r) as f64;
            out[j] = self.a[j] * r + sigma * rng.normal_f32();
        }
        Ok(loss as f32)
    }

    fn eval(&mut self, x: &[f32]) -> Result<EvalMetrics> {
        Ok(EvalMetrics { loss: self.problem.global_loss(x), ppl: None })
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        // Far-from-optimum deterministic start shared by all workers.
        let mut rng = Rng::derive(self.problem.seed, &[30]);
        let mut x = vec![0.0f32; self.problem.dim];
        rng.fill_normal(&mut x, 3.0);
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_matches_finite_difference() {
        let p = SyntheticProblem { noise: 0.0, ..SyntheticProblem::new(16, 2, 3) };
        let mut b = p.backend(1);
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut g = vec![0.0f32; 16];
        let loss = b.loss_and_grad(&x, 5, &mut g).unwrap();
        assert!(loss > 0.0);
        let h = 1e-3f32;
        for j in [0usize, 7, 15] {
            let mut xp = x.clone();
            xp[j] += h;
            let mut xm = x.clone();
            xm[j] -= h;
            let mut scratch = vec![0.0f32; 16];
            let lp = b.loss_and_grad(&xp, 5, &mut scratch).unwrap();
            let lm = b.loss_and_grad(&xm, 5, &mut scratch).unwrap();
            let fd = (lp - lm) / (2.0 * h);
            assert!((fd - g[j]).abs() < 1e-2 * g[j].abs().max(1.0), "j={j}: {fd} vs {}", g[j]);
        }
    }

    #[test]
    fn optimum_minimises_global_loss() {
        let p = SyntheticProblem::new(32, 4, 9);
        let opt = p.optimum();
        let l_opt = p.global_loss(&opt);
        // Perturbations only increase the loss.
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let mut x = opt.clone();
            for v in x.iter_mut() {
                *v += 0.1 * rng.normal_f32();
            }
            assert!(p.global_loss(&x) > l_opt);
        }
    }

    #[test]
    fn noniid_workers_have_different_gradients() {
        let p = SyntheticProblem::new(64, 4, 5);
        let x = vec![0.0f32; 64];
        let mut g0 = vec![0.0f32; 64];
        let mut g1 = vec![0.0f32; 64];
        p.backend(0).loss_and_grad(&x, 1, &mut g0).unwrap();
        p.backend(1).loss_and_grad(&x, 1, &mut g1).unwrap();
        let diff: f32 = g0.iter().zip(&g1).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1.0, "gradients identical across non-IID workers");
    }

    #[test]
    fn zero_skew_makes_workers_iid() {
        let p = SyntheticProblem { skew: 0.0, noise: 0.0, ..SyntheticProblem::new(16, 3, 5) };
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let mut g0 = vec![0.0f32; 16];
        let mut g1 = vec![0.0f32; 16];
        p.backend(0).loss_and_grad(&x, 1, &mut g0).unwrap();
        p.backend(2).loss_and_grad(&x, 1, &mut g1).unwrap();
        assert_eq!(g0, g1);
    }

    #[test]
    fn gradients_deterministic_per_step() {
        let p = SyntheticProblem::new(16, 2, 5);
        let x = vec![1.0f32; 16];
        let mut a = vec![0.0f32; 16];
        let mut b = vec![0.0f32; 16];
        p.backend(0).loss_and_grad(&x, 7, &mut a).unwrap();
        p.backend(0).loss_and_grad(&x, 7, &mut b).unwrap();
        assert_eq!(a, b);
        p.backend(0).loss_and_grad(&x, 8, &mut b).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn curvature_spread_is_three_decades() {
        let p = SyntheticProblem::new(128, 1, 0);
        let a = p.curvatures();
        assert!((a[0] - 0.01).abs() < 1e-6);
        assert!((a[127] - 10.0).abs() < 1e-4);
    }

    #[test]
    fn eval_reports_global_loss() {
        let p = SyntheticProblem::new(8, 2, 4);
        let mut b = p.backend(0);
        let opt = p.optimum();
        let m = b.eval(&opt).unwrap();
        assert!(m.ppl.is_none());
        assert!((m.loss - p.global_loss(&opt)).abs() < 1e-12);
    }
}
