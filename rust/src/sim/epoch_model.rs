//! Analytic epoch-time / throughput model — the engine behind the Fig. 1
//! and Fig. 2 reproductions.
//!
//! For a calibrated cluster ([`super::calib::Calibration`]) and an
//! algorithm, the per-iteration time decomposes (DESIGN.md §11) as
//!
//! ```text
//!   t_iter = max(t_compute, t_dataload(n))  +  t_sync_visible(n, v) / H
//! ```
//!
//! with `v` vectors per sync (1 for gradient sync / parameter averaging,
//! 2 for local AdaAlter's params + denominators) and `H` the
//! synchronization period (H=1 for fully-sync, H=∞ ⇒ no comm term). The
//! paper's epoch is a fixed 20,000 × 8 × 256 samples regardless of n, so
//! `iters_per_epoch(n) = 20,000 · 8 / n` at batch 256.

use crate::config::SyncPeriod;
use crate::sim::calib::Calibration;

/// Algorithm variants as evaluated in Fig. 1/2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimAlgo {
    /// Fully-synchronous distributed AdaGrad (Alg. 1).
    AdaGrad,
    /// Fully-synchronous AdaAlter (Alg. 3) — tiny compute overhead.
    AdaAlter,
    /// Local AdaAlter (Alg. 4) with period H (or H=∞: comm removed).
    LocalAdaAlter(SyncPeriod),
    /// Local SGD (Alg. 2) with period H — ships 1 vector per sync.
    LocalSgd(SyncPeriod),
    /// The paper's "ideal computation-only overhead" baseline: no comm,
    /// no data loading (dummy batches).
    IdealComputeOnly,
}

impl SimAlgo {
    /// Display label (matches the paper's legends).
    pub fn label(&self) -> String {
        match self {
            SimAlgo::AdaGrad => "AdaGrad".into(),
            SimAlgo::AdaAlter => "AdaAlter".into(),
            SimAlgo::LocalAdaAlter(SyncPeriod::Every(h)) => format!("Local AdaAlter, H={h}"),
            SimAlgo::LocalAdaAlter(SyncPeriod::Infinite) => "Local AdaAlter, H=inf".into(),
            SimAlgo::LocalSgd(SyncPeriod::Every(h)) => format!("Local SGD, H={h}"),
            SimAlgo::LocalSgd(SyncPeriod::Infinite) => "Local SGD, H=inf".into(),
            SimAlgo::IdealComputeOnly => "Ideal computation-only overhead".into(),
        }
    }
}

/// Per-iteration time decomposition (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterCost {
    /// GPU compute (fwd/bwd + optimizer).
    pub compute_s: f64,
    /// Extra time the shared dataloader adds beyond compute (0 if hidden).
    pub dataload_extra_s: f64,
    /// Amortised visible communication.
    pub comm_s: f64,
}

impl IterCost {
    /// Total per-iteration seconds.
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.dataload_extra_s + self.comm_s
    }
}

/// The analytic model.
pub struct EpochModel {
    /// The calibrated cluster constants the model evaluates.
    pub calib: Calibration,
    /// Samples processed per epoch (paper: 20,000 × 8 × 256).
    pub samples_per_epoch: u64,
}

impl EpochModel {
    /// Model with the paper's epoch definition.
    pub fn paper() -> Self {
        EpochModel {
            calib: Calibration::paper_v100(),
            samples_per_epoch: 20_000 * 8 * 256,
        }
    }

    /// Global iterations per epoch with n workers.
    pub fn iters_per_epoch(&self, n: usize) -> f64 {
        self.samples_per_epoch as f64 / (n as f64 * self.calib.batch_per_worker as f64)
    }

    /// Per-iteration cost decomposition for `algo` on n workers.
    pub fn iter_cost(&self, algo: SimAlgo, n: usize) -> IterCost {
        let c = &self.calib;
        // AdaAlter's swapped update adds ~0.4% to the serial path (Table 2:
        // 98.47 h vs 98.05 h) — applied after the compute/dataload max so it
        // survives even when loading binds.
        let overhead = if matches!(algo, SimAlgo::AdaAlter | SimAlgo::LocalAdaAlter(_)) {
            1.0 + c.adaalter_compute_overhead
        } else {
            1.0
        };
        if matches!(algo, SimAlgo::IdealComputeOnly) {
            return IterCost { compute_s: c.t_compute_s, ..Default::default() };
        }
        let base = c.t_compute_s.max(c.dataload_s(n)) * overhead;
        let compute = c.t_compute_s * overhead;
        let dataload_extra = base - compute;
        let comm = match algo {
            // PS: the server sees every worker's gradient, so AdaAlter's
            // squared-average accumulation costs no extra traffic.
            SimAlgo::AdaGrad | SimAlgo::AdaAlter => c.visible_sync_s(n, 1),
            SimAlgo::LocalAdaAlter(p) => match p.period() {
                Some(h) => c.visible_periodic_sync_s(n, 2) / h as f64,
                None => 0.0,
            },
            SimAlgo::LocalSgd(p) => match p.period() {
                Some(h) => c.visible_periodic_sync_s(n, 1) / h as f64,
                None => 0.0,
            },
            SimAlgo::IdealComputeOnly => unreachable!(),
        };
        IterCost { compute_s: compute, dataload_extra_s: dataload_extra, comm_s: comm }
    }

    /// Seconds per epoch — the Fig. 1 quantity.
    pub fn epoch_time_s(&self, algo: SimAlgo, n: usize) -> f64 {
        self.iters_per_epoch(n) * self.iter_cost(algo, n).total_s()
    }

    /// Samples/second — the Fig. 2 quantity.
    pub fn throughput(&self, algo: SimAlgo, n: usize) -> f64 {
        let t = self.iter_cost(algo, n).total_s();
        n as f64 * self.calib.batch_per_worker as f64 / t
    }

    /// End-of-training hours for `epochs` epochs — the Table 2 time column.
    pub fn training_hours(&self, algo: SimAlgo, n: usize, epochs: u64) -> f64 {
        epochs as f64 * self.epoch_time_s(algo, n) / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SyncPeriod::{Every, Infinite};

    fn model() -> EpochModel {
        EpochModel::paper()
    }

    /// The headline Table 2 reproduction: every time lands within 5% of
    /// the paper's measured hours.
    #[test]
    fn table2_times_within_tolerance() {
        let m = model();
        let cases: &[(SimAlgo, f64)] = &[
            (SimAlgo::AdaGrad, 98.05),
            (SimAlgo::AdaAlter, 98.47),
            (SimAlgo::LocalAdaAlter(Every(4)), 69.17),
            (SimAlgo::LocalAdaAlter(Every(8)), 67.41),
            (SimAlgo::LocalAdaAlter(Every(12)), 65.49),
            (SimAlgo::LocalAdaAlter(Every(16)), 64.22),
        ];
        for &(algo, want) in cases {
            let got = m.training_hours(algo, 8, 50);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.05, "{}: {got:.2} h vs paper {want} h ({:.1}%)",
                    algo.label(), rel * 100.0);
        }
    }

    /// Paper §6.3.2: "local AdaAlter can reduce almost 30% of the training
    /// time" (H=4 vs fully-sync AdaGrad).
    #[test]
    fn thirty_percent_reduction_at_h4() {
        let m = model();
        let sync = m.epoch_time_s(SimAlgo::AdaGrad, 8);
        let h4 = m.epoch_time_s(SimAlgo::LocalAdaAlter(Every(4)), 8);
        let reduction = 1.0 - h4 / sync;
        assert!((0.25..0.35).contains(&reduction), "reduction {reduction}");
    }

    #[test]
    fn ordering_matches_fig1() {
        // ideal < H=inf < H=16 < … < H=4 < fully-sync, at every n.
        let m = model();
        for n in [1usize, 2, 4, 8] {
            let ideal = m.epoch_time_s(SimAlgo::IdealComputeOnly, n);
            let hinf = m.epoch_time_s(SimAlgo::LocalAdaAlter(Infinite), n);
            let mut prev = hinf;
            assert!(ideal <= hinf + 1e-9, "n={n}");
            for h in [16u64, 12, 8, 4] {
                let t = m.epoch_time_s(SimAlgo::LocalAdaAlter(Every(h)), n);
                assert!(t >= prev - 1e-12, "n={n} H={h}");
                prev = t;
            }
            let sync = m.epoch_time_s(SimAlgo::AdaAlter, n);
            assert!(sync >= prev, "n={n} sync");
            if n >= 2 {
                assert!(m.epoch_time_s(SimAlgo::AdaGrad, n) >= prev, "n={n} adagrad");
            }
        }
    }

    #[test]
    fn throughput_and_epoch_time_consistent() {
        let m = model();
        for n in [1usize, 2, 4, 8] {
            let tp = m.throughput(SimAlgo::AdaGrad, n);
            let et = m.epoch_time_s(SimAlgo::AdaGrad, n);
            let implied = m.samples_per_epoch as f64 / et;
            assert!((tp - implied).abs() / tp < 1e-9, "n={n}");
        }
    }

    #[test]
    fn sublinear_scaling_from_4_to_8() {
        // §6.4: "almost all the algorithms do not scale well when changing
        // the number of workers from 4 to 8" — throughput ratio << 2.
        let m = model();
        for algo in [
            SimAlgo::AdaGrad,
            SimAlgo::LocalAdaAlter(Every(4)),
            SimAlgo::LocalAdaAlter(Infinite),
        ] {
            let r = m.throughput(algo, 8) / m.throughput(algo, 4);
            assert!(r < 1.7, "{}: ratio {r}", algo.label());
        }
        // …but the ideal baseline scales perfectly by construction.
        let r = m.throughput(SimAlgo::IdealComputeOnly, 8)
            / m.throughput(SimAlgo::IdealComputeOnly, 4);
        assert!((r - 2.0).abs() < 1e-9);
    }

    #[test]
    fn h_inf_equals_no_comm() {
        let m = model();
        let c = m.iter_cost(SimAlgo::LocalAdaAlter(Infinite), 8);
        assert_eq!(c.comm_s, 0.0);
        // H=inf differs from ideal only by the dataloader bottleneck.
        let ideal = m.iter_cost(SimAlgo::IdealComputeOnly, 8);
        assert!(c.total_s() > ideal.total_s());
    }

    #[test]
    fn local_sgd_ships_half_of_local_adaalter() {
        let m = model();
        let aa = m.iter_cost(SimAlgo::LocalAdaAlter(Every(4)), 8).comm_s;
        let sgd = m.iter_cost(SimAlgo::LocalSgd(Every(4)), 8).comm_s;
        let ratio = aa / sgd;
        assert!((ratio - 2.0).abs() < 0.01, "ratio {ratio}");
    }
}
