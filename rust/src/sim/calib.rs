//! Calibration of the cluster simulator against the paper's own numbers.
//!
//! The paper's testbed (one machine, 8× V100-16GB, Big-LSTM on 1B-word,
//! batch 256/GPU) is reproduced as an analytic cost model whose constants
//! are **fit to Table 2 and §6.4 of the paper itself**:
//!
//! Measured by the paper (50 epochs, 20,000 global iterations/epoch):
//! * AdaGrad (fully sync):      98.05 h  →  0.3530 s/iter
//! * Local AdaAlter H=4:        69.17 h  →  0.2490 s/iter
//! * Local AdaAlter H=8:        67.41 h  →  0.2427 s/iter
//! * Local AdaAlter H=12:       65.49 h  →  0.2358 s/iter
//! * Local AdaAlter H=16:       64.22 h  →  0.2312 s/iter
//!
//! Fitting `t_iter(H) = t_base + t_sync2 · overlap / H` to the four local
//! rows gives `t_base ≈ 0.232 s` and an *effective* (non-overlapped)
//! 2-vector sync cost ≈ 0.072 s. The paper's MXNet parameter server
//! overlaps communication with computation (layer-bucketed push/pull), so
//! we model a raw α–β sync cost with an overlap discount `γ`:
//! `t_sync_visible = (1 − γ) · t_sync_raw`.
//!
//! Components:
//! * `t_compute` = 0.195 s/iter — the paper's "ideal computation-only"
//!   bound at batch 256 (Fig. 1's lowest baseline).
//! * dataloader capacity C = 8 · 256 / 0.232 ≈ 8,830 samples/s — chosen so
//!   data loading binds exactly at 8 workers (`§6.4`: "when there are too
//!   many workers, the data-loading also becomes a bottleneck"; the gap
//!   between H=∞ and ideal-compute in Fig. 1).
//! * payload = 4·d bytes with d = 0.83e9 (Big LSTM, §6.1 / Józefowicz et
//!   al.), server aggregate bandwidth 132 GB/s and γ = 0.7, which lands
//!   the fully-sync visible cost at `(1−γ)·2·n·4d/β ≈ 0.121 s` so that
//!   AdaGrad@8 totals 0.353 s/iter — the Table 2 value.

use crate::comm::netmodel::{NetModel, Topology};

/// Paper-calibrated V100 cluster constants.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Pure computation time per iteration at the reference batch (s).
    pub t_compute_s: f64,
    /// Host data-loading capacity, samples/s (shared across workers).
    pub dataloader_samples_per_s: f64,
    /// Per-GPU batch size the constants were fit at.
    pub batch_per_worker: u64,
    /// Model dimension d (parameters) of the simulated Big LSTM.
    pub model_params: u64,
    /// Fraction of the raw per-iteration gradient-sync time hidden by
    /// compute overlap (γ₁ — layer-bucketed push/pull pipelined with
    /// backprop).
    pub overlap: f64,
    /// Fraction of the raw periodic bulk state sync (local algorithms)
    /// hidden by overlap (γ₂). Bulk transfers pipeline far better than the
    /// per-iteration fine-grained KVStore sync: fitted to the paper's
    /// Table 2 local rows (visible cost ≈ 0.072 s per 2-vector round).
    pub periodic_overlap: f64,
    /// The α–β network model (PS topology, paper's setting).
    pub net: NetModel,
    /// Relative extra compute of AdaAlter vs AdaGrad (Table 2: +0.4%).
    pub adaalter_compute_overhead: f64,
}

impl Calibration {
    /// The paper's 8×V100 testbed.
    pub fn paper_v100() -> Self {
        Calibration {
            t_compute_s: 0.195,
            dataloader_samples_per_s: 8830.0,
            batch_per_worker: 256,
            model_params: 830_000_000,
            overlap: 0.70,
            periodic_overlap: 0.91,
            net: NetModel {
                topology: Topology::ParameterServer,
                alpha_s: 50e-6,
                beta_bytes_per_s: 132e9,
                server_beta_bytes_per_s: 132e9,
            },
            adaalter_compute_overhead: 0.004,
        }
    }

    /// Bytes of one synchronized vector (f32 flat model).
    pub fn vector_bytes(&self) -> u64 {
        4 * self.model_params
    }

    /// Visible (non-overlapped) per-iteration gradient sync time.
    pub fn visible_sync_s(&self, n: usize, vectors: u64) -> f64 {
        (1.0 - self.overlap) * self.net.sync_time(n, self.vector_bytes(), vectors)
    }

    /// Visible time of one periodic bulk state sync (local algorithms).
    pub fn visible_periodic_sync_s(&self, n: usize, vectors: u64) -> f64 {
        (1.0 - self.periodic_overlap) * self.net.sync_time(n, self.vector_bytes(), vectors)
    }

    /// Host data-loading time per iteration with n workers drawing
    /// `batch_per_worker` samples each from the shared loader.
    pub fn dataload_s(&self, n: usize) -> f64 {
        n as f64 * self.batch_per_worker as f64 / self.dataloader_samples_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_sync_iteration_matches_table2() {
        // AdaGrad @ 8 workers must land on ~0.353 s/iter (98.05 h / 50
        // epochs / 20k iters).
        let c = Calibration::paper_v100();
        let t = c.t_compute_s.max(c.dataload_s(8)) + c.visible_sync_s(8, 1);
        assert!((t - 0.353).abs() < 0.012, "t_iter = {t}");
    }

    #[test]
    fn local_h4_lands_near_paper() {
        let c = Calibration::paper_v100();
        let t = c.t_compute_s.max(c.dataload_s(8)) + c.visible_periodic_sync_s(8, 2) / 4.0;
        assert!((t - 0.249).abs() < 0.015, "t_iter = {t}");
    }

    #[test]
    fn dataloader_binds_only_at_eight_workers() {
        // §6.4: scaling stalls going 4 → 8 because loading becomes the
        // bottleneck.
        let c = Calibration::paper_v100();
        assert!(c.dataload_s(4) < c.t_compute_s);
        assert!(c.dataload_s(8) > c.t_compute_s);
    }

    #[test]
    fn overlap_discount_applied() {
        let c = Calibration::paper_v100();
        let raw = c.net.sync_time(8, c.vector_bytes(), 1);
        assert!((c.visible_sync_s(8, 1) - 0.3 * raw).abs() < 1e-9);
    }
}
