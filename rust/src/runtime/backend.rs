//! PJRT-backed [`WorkerBackend`]: the real transformer LM through the AOT
//! artifacts.
//!
//! Graphs used:
//! * `train_step`  (flat, tokens) → (loss, grad)           — generic path
//! * `local_step_adaalter` (flat, b2, acc, tokens, t'ε², η) → (y, acc', loss)
//!   — the fused Alg. 4 hot path: one dispatch per local iteration and the
//!   gradient never surfaces to the host (EXPERIMENTS.md §Perf).
//! * `eval_step`   (flat, tokens) → (Σ nll, count)          — test PPL.

use crate::config::DataConfig;
use crate::coordinator::backend::{EvalMetrics, WorkerBackend};
use crate::data::BatchLoader;
use crate::error::{Error, Result};

use super::engine::{read_f32_into, read_scalar_f32, Arg, Engine, LoadedGraph};

/// PJRT worker backend for one preset.
pub struct PjrtBackend {
    engine: Engine,
    train_step: LoadedGraph,
    local_step: Option<LoadedGraph>,
    eval_step: LoadedGraph,
    loader: BatchLoader,
    worker: usize,
    d: usize,
    eval_batches: usize,
}

impl PjrtBackend {
    /// Build the backend for `worker` (call on the worker's own thread).
    pub fn new(
        artifacts_dir: &str,
        preset: &str,
        worker: usize,
        workers: usize,
        data_cfg: &DataConfig,
        seed: u64,
    ) -> Result<PjrtBackend> {
        let engine = Engine::new(artifacts_dir, preset)?;
        let p = engine.preset();
        let loader = BatchLoader::new(
            p.vocab,
            workers,
            p.batch,
            p.eval_batch,
            p.seq,
            data_cfg,
            seed,
        );
        let train_step = engine.load_graph("train_step")?;
        // The fused graph is optional in the manifest (older artifact sets).
        let local_step = engine.load_graph("local_step_adaalter").ok();
        let eval_step = engine.load_graph("eval_step")?;
        let d = p.d;
        Ok(PjrtBackend {
            engine,
            train_step,
            local_step,
            eval_step,
            loader,
            worker,
            d,
            eval_batches: data_cfg.eval_batches.max(1),
        })
    }

    /// Tokens per training batch (rows × row-length) — samples/step for
    /// throughput accounting.
    pub fn samples_per_step(&self) -> usize {
        self.loader.samples_per_batch()
    }
}

impl WorkerBackend for PjrtBackend {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss_and_grad(&mut self, x: &[f32], step: u64, out: &mut [f32]) -> Result<f32> {
        let tokens = self.loader.train_batch(self.worker, step);
        let outs = self.train_step.run(&[Arg::F32(x), Arg::I32(&tokens)])?;
        let loss = read_scalar_f32(&outs[0])?;
        read_f32_into(&outs[1], out)?;
        Ok(loss)
    }

    fn fused_local_adaalter(
        &mut self,
        x: &mut [f32],
        b2_sync: &[f32],
        acc: &mut [f32],
        denom_add: f32,
        lr: f32,
        step: u64,
    ) -> Result<Option<f32>> {
        let Some(graph) = &self.local_step else {
            return Ok(None);
        };
        let tokens = self.loader.train_batch(self.worker, step);
        let da = [denom_add];
        let lr_arr = [lr];
        let outs = graph.run(&[
            Arg::F32(x),
            Arg::F32(b2_sync),
            Arg::F32(acc),
            Arg::I32(&tokens),
            Arg::F32(&da),
            Arg::F32(&lr_arr),
        ])?;
        read_f32_into(&outs[0], x)?;
        read_f32_into(&outs[1], acc)?;
        let loss = read_scalar_f32(&outs[2])?;
        Ok(Some(loss))
    }

    fn eval(&mut self, x: &[f32]) -> Result<EvalMetrics> {
        let mut sum_nll = 0.0f64;
        let mut count = 0.0f64;
        for k in 0..self.eval_batches {
            let tokens = self.loader.eval_batch(k as u64);
            let outs = self.eval_step.run(&[Arg::F32(x), Arg::I32(&tokens)])?;
            sum_nll += read_scalar_f32(&outs[0])? as f64;
            count += read_scalar_f32(&outs[1])? as f64;
        }
        if count == 0.0 {
            return Err(Error::Runtime("eval produced zero tokens".into()));
        }
        let mean = sum_nll / count;
        Ok(EvalMetrics { loss: mean, ppl: Some(mean.exp()) })
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        self.engine.init_params()
    }
}
