//! Artifact manifest: the typed view of `artifacts/manifest.json` that
//! `python/compile/aot.py` emits at build time.
//!
//! The manifest is the only contract between the build-time Python layers
//! and the runtime: per preset it records the flat dimension `d`, batch
//! geometry, every lowered graph's file + input/output shapes/dtypes, the
//! parameter layout (name/shape/offset), and the initial-parameter blob.
//! Everything is validated on load so shape bugs surface at startup, not
//! mid-training.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Element type of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float ("float32").
    F32,
    /// 32-bit signed integer ("int32").
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => Err(Error::Artifact(format!("unsupported dtype {other:?}"))),
        }
    }
}

/// Shape + dtype of one graph input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .req("shape")?
            .arr()?
            .iter()
            .map(|v| v.usize())
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(j.req("dtype")?.str()?)?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One lowered graph.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// HLO-text file name, relative to the manifest directory.
    pub file: String,
    /// Declared graph inputs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Declared graph outputs, in tuple order.
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> Result<ArtifactEntry> {
        let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.req(key)?.arr()?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(ArtifactEntry {
            file: j.req("file")?.str()?.to_string(),
            inputs: parse_specs("inputs")?,
            outputs: parse_specs("outputs")?,
        })
    }
}

/// One named parameter tensor inside the flat vector.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    /// Parameter name (JAX pytree path).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Start offset inside the flat parameter vector.
    pub offset: usize,
    /// Element count.
    pub size: usize,
}

/// A preset's full manifest subtree.
#[derive(Clone, Debug)]
pub struct PresetManifest {
    /// Preset name ("tiny", "small", …).
    pub name: String,
    /// Flat model dimension.
    pub d: usize,
    /// Training batch size per worker.
    pub batch: usize,
    /// Evaluation batch size.
    pub eval_batch: usize,
    /// Sequence length.
    pub seq: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// File holding the warm-start flat parameter vector.
    pub init_params_file: String,
    /// Lowered graphs by logical name ("train_step", "eval_step", …).
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    /// Layout of the flat parameter vector.
    pub param_spec: Vec<ParamEntry>,
}

impl PresetManifest {
    /// Look up a graph by logical name ("train_step", "eval_step", …).
    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "preset {:?} has no artifact {name:?} (have: {:?})",
                self.name,
                self.artifacts.keys().collect::<Vec<_>>()
            ))
        })
    }

    fn validate(&self) -> Result<()> {
        // Param spec must tile [0, d) exactly.
        let mut off = 0;
        for p in &self.param_spec {
            if p.offset != off || p.size != p.shape.iter().product::<usize>() {
                return Err(Error::Artifact(format!(
                    "param {:?}: bad offset/size (offset {} expected {off})",
                    p.name, p.offset
                )));
            }
            off += p.size;
        }
        if off != self.d {
            return Err(Error::Artifact(format!(
                "param spec covers {off} of d={}",
                self.d
            )));
        }
        // Spot-check the core graphs' shapes.
        let ts = self.artifact("train_step")?;
        if ts.inputs.first().map(|t| t.shape.as_slice()) != Some(&[self.d][..]) {
            return Err(Error::Artifact("train_step input 0 is not f32[d]".into()));
        }
        if ts.inputs.get(1).map(|t| t.shape.as_slice())
            != Some(&[self.batch, self.seq + 1][..])
        {
            return Err(Error::Artifact("train_step input 1 is not [batch, seq+1]".into()));
        }
        Ok(())
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Manifest schema version.
    pub version: usize,
    /// Presets by name.
    pub presets: BTreeMap<String, PresetManifest>,
    /// Directory the manifest was loaded from (artifact files live here).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let version = j.req("version")?.usize()?;
        if version != 2 {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported (want 2); re-run `make artifacts`"
            )));
        }
        let mut presets = BTreeMap::new();
        for (name, pj) in j.req("presets")?.obj()? {
            let artifacts = pj
                .req("artifacts")?
                .obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), ArtifactEntry::from_json(v)?)))
                .collect::<Result<BTreeMap<_, _>>>()?;
            let param_spec = pj
                .req("param_spec")?
                .arr()?
                .iter()
                .map(|e| {
                    Ok(ParamEntry {
                        name: e.req("name")?.str()?.to_string(),
                        shape: e
                            .req("shape")?
                            .arr()?
                            .iter()
                            .map(|v| v.usize())
                            .collect::<Result<_>>()?,
                        offset: e.req("offset")?.usize()?,
                        size: e.req("size")?.usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let pm = PresetManifest {
                name: name.clone(),
                d: pj.req("d")?.usize()?,
                batch: pj.req("batch")?.usize()?,
                eval_batch: pj.req("eval_batch")?.usize()?,
                seq: pj.req("seq")?.usize()?,
                vocab: pj.req("vocab")?.usize()?,
                init_params_file: pj.req("init_params")?.str()?.to_string(),
                artifacts,
                param_spec,
            };
            pm.validate()?;
            presets.insert(name.clone(), pm);
        }
        Ok(Manifest { version, presets, dir })
    }

    /// Get a preset or a helpful error.
    pub fn preset(&self, name: &str) -> Result<&PresetManifest> {
        self.presets.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "preset {name:?} not in manifest (have: {:?}); \
                 run `make artifacts` or `python -m compile.aot --presets {name}`",
                self.presets.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// Load a preset's initial parameters (raw little-endian f32).
    pub fn load_init_params(&self, preset: &str) -> Result<Vec<f32>> {
        let p = self.preset(preset)?;
        let path = self.dir.join(&p.init_params_file);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::Artifact(format!("read {}: {e}", path.display())))?;
        if bytes.len() != 4 * p.d {
            return Err(Error::Artifact(format!(
                "{}: {} bytes, expected {}",
                path.display(),
                bytes.len(),
                4 * p.d
            )));
        }
        let mut out = Vec::with_capacity(p.d);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    /// Absolute path of an artifact file.
    pub fn artifact_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

/// True if artifacts exist at `dir` (used by tests to skip PJRT suites on
/// fresh checkouts).
pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Write a minimal-but-valid manifest to a temp dir.
    fn fake_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("adaalter_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let d = 8usize;
        let manifest = format!(
            r#"{{
  "version": 2,
  "presets": {{
    "fake": {{
      "d": {d}, "batch": 2, "eval_batch": 2, "seq": 3, "vocab": 16,
      "init_params": "fake_init.f32bin",
      "param_spec": [
        {{"name": "a", "shape": [2, 2], "offset": 0, "size": 4}},
        {{"name": "b", "shape": [4], "offset": 4, "size": 4}}
      ],
      "artifacts": {{
        "train_step": {{
          "file": "fake_train_step.hlo.txt",
          "inputs": [
            {{"shape": [{d}], "dtype": "float32"}},
            {{"shape": [2, 4], "dtype": "int32"}}
          ],
          "outputs": [
            {{"shape": [], "dtype": "float32"}},
            {{"shape": [{d}], "dtype": "float32"}}
          ]
        }}
      }}
    }}
  }}
}}"#
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let init: Vec<u8> = (0..d).flat_map(|i| (i as f32).to_le_bytes()).collect();
        std::fs::write(dir.join("fake_init.f32bin"), init).unwrap();
        dir
    }

    #[test]
    fn loads_and_validates() {
        let dir = fake_dir();
        let m = Manifest::load(&dir).unwrap();
        let p = m.preset("fake").unwrap();
        assert_eq!(p.d, 8);
        assert_eq!(p.artifact("train_step").unwrap().inputs[1].dtype, Dtype::I32);
        assert!(p.artifact("missing").is_err());
        let init = m.load_init_params("fake").unwrap();
        assert_eq!(init, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_mentions_make_artifacts() {
        let err = Manifest::load("/nonexistent/path").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { shape: vec![4, 33], dtype: Dtype::I32 };
        assert_eq!(t.elements(), 132);
        let scalar = TensorSpec { shape: vec![], dtype: Dtype::F32 };
        assert_eq!(scalar.elements(), 1);
    }

    #[test]
    fn real_manifest_if_built() {
        // Deep-validate the real artifacts when present.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !artifacts_available(&dir) {
            return; // fresh checkout
        }
        let m = Manifest::load(&dir).unwrap();
        for (name, p) in &m.presets {
            assert!(p.d > 0, "{name}");
            assert!(m.load_init_params(name).unwrap().len() == p.d);
            for (aname, a) in &p.artifacts {
                let path = m.artifact_path(a);
                assert!(path.exists(), "{name}/{aname}: missing {}", path.display());
            }
        }
    }
}
