//! Runtime: PJRT loading and execution of the AOT HLO-text artifacts.
//!
//! * [`artifact`] — typed manifest (`artifacts/manifest.json`).
//! * [`engine`] — PJRT client + graph compile/execute with shape checks.
//! * [`backend`] — the [`crate::coordinator::WorkerBackend`] over the LM.

pub mod artifact;
pub mod backend;
pub mod engine;

pub use artifact::{artifacts_available, Manifest, PresetManifest};
pub use backend::PjrtBackend;
pub use engine::{Arg, Engine, LoadedGraph};
