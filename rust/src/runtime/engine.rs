//! PJRT engine: load the AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, xla_extension 0.5.1):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. The interchange format is HLO *text* —
//! jax ≥ 0.5 serialized protos carry 64-bit instruction ids the 0.5.1
//! parser rejects; the text parser reassigns ids (aot.py docstring,
//! /opt/xla-example/README.md).
//!
//! Thread model: `PjRtClient` is `Rc`-based (not `Send`), so each worker
//! thread owns its own `Engine` — the factory pattern in
//! [`crate::coordinator::backend`]. This also mirrors the real topology
//! (one PJRT device per worker).
//!
//! Every lowered graph returns a tuple; PJRT hands it back as a single
//! tuple buffer which [`LoadedGraph::run`] decomposes into per-output
//! literals.

use crate::error::{Error, Result};

use super::artifact::{ArtifactEntry, Dtype, Manifest, PresetManifest, TensorSpec};

/// Host-side argument for a graph invocation.
pub enum Arg<'a> {
    /// f32 tensor with the artifact-declared shape.
    F32(&'a [f32]),
    /// i32 tensor with the artifact-declared shape.
    I32(&'a [i32]),
}

impl Arg<'_> {
    fn dtype(&self) -> Dtype {
        match self {
            Arg::F32(_) => Dtype::F32,
            Arg::I32(_) => Dtype::I32,
        }
    }

    fn len(&self) -> usize {
        match self {
            Arg::F32(v) => v.len(),
            Arg::I32(v) => v.len(),
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        let bytes: &[u8] = match self {
            Arg::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            Arg::I32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        };
        let ty = match self {
            Arg::F32(_) => xla::ElementType::F32,
            Arg::I32(_) => xla::ElementType::S32,
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &spec.shape, bytes)
            .map_err(Error::runtime)
    }
}

/// A compiled, ready-to-run graph.
pub struct LoadedGraph {
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
    name: String,
}

impl LoadedGraph {
    /// Declared output specs.
    pub fn outputs(&self) -> &[TensorSpec] {
        &self.entry.outputs
    }

    /// Execute with shape/dtype-checked host arguments; returns one
    /// decomposed literal per declared output.
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.entry.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} args, graph takes {}",
                self.name,
                args.len(),
                self.entry.inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (arg, spec)) in args.iter().zip(&self.entry.inputs).enumerate() {
            if arg.dtype() != spec.dtype || arg.len() != spec.elements() {
                return Err(Error::Runtime(format!(
                    "{}: arg {i} is {:?}×{}, graph wants {:?}×{}",
                    self.name,
                    arg.dtype(),
                    arg.len(),
                    spec.dtype,
                    spec.elements()
                )));
            }
            literals.push(arg.to_literal(spec)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(Error::runtime)?;
        let tuple = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime(format!("{}: empty result", self.name)))?
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let parts = tuple.to_tuple().map_err(Error::runtime)?;
        if parts.len() != self.entry.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: {} outputs, manifest declares {}",
                self.name,
                parts.len(),
                self.entry.outputs.len()
            )));
        }
        Ok(parts)
    }
}

/// Copy an f32 output literal into a slice.
pub fn read_f32_into(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_raw_to::<f32>(out).map_err(Error::runtime)
}

/// Read a scalar f32 output.
pub fn read_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(Error::runtime)
}

/// Per-thread PJRT engine for one preset.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    preset: String,
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>, preset: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.preset(preset)?; // validate early
        let client = xla::PjRtClient::cpu().map_err(Error::runtime)?;
        Ok(Engine { client, manifest, preset: preset.to_string() })
    }

    /// The preset manifest.
    pub fn preset(&self) -> &PresetManifest {
        self.manifest.preset(&self.preset).expect("validated in new()")
    }

    /// The whole manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Initial parameters for this preset.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        self.manifest.load_init_params(&self.preset)
    }

    /// Load + compile one graph by logical name.
    pub fn load_graph(&self, name: &str) -> Result<LoadedGraph> {
        let entry = self.preset().artifact(name)?.clone();
        let path = self.manifest.artifact_path(&entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {}", path.display())))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", path.display())))?;
        Ok(LoadedGraph { exe, entry, name: name.to_string() })
    }
}
