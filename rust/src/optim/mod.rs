//! Optimizer implementations — rust-native mirrors of the paper's
//! Algorithms 1–4.
//!
//! Two execution paths exist for every update:
//!
//! 1. the **PJRT path**: the fused Pallas kernels (L1) lowered into
//!    `artifacts/{preset}_opt_*.hlo.txt` / the fused local-step graphs,
//!    executed by [`crate::runtime`];
//! 2. the **rust path** (this module): identical recurrences as fused
//!    single-pass loops over the flat `f32[d]` state.
//!
//! The rust path serves three roles: the coordinator-side update when the
//! leader owns the state (sync algorithms average gradients, then update
//! once), the reference the integration tests pin the PJRT path against,
//! and the backend for the pure-rust synthetic workload benches.
//!
//! All implementations are *exact* transcriptions — update-then-accumulate
//! for AdaAlter (Alg. 3 lines 6–7), accumulate-then-update for AdaGrad
//! (Alg. 1 lines 6–7), and the `t'·ε²` placeholder for local AdaAlter
//! (Alg. 4 line 6).

pub mod adaalter;
pub mod adagrad;
pub mod local_adaalter;
pub mod sgd;
pub mod theory;

pub use adaalter::AdaAlter;
pub use adagrad::AdaGrad;
pub use local_adaalter::LocalAdaAlterWorker;
pub use sgd::{MomentumSgd, Sgd};
pub use theory::BoundParams;

use crate::config::{Algorithm, OptimConfig};

/// A fully-synchronous optimizer: the leader averages worker gradients each
/// step and applies one global update (Algorithms 1 and 3, plus SGD).
pub trait SyncOptimizer: Send {
    /// Apply one step.
    ///
    /// * `x` — global model, updated in place.
    /// * `g` — averaged gradient `(1/n) Σ_i G_{i,t}`.
    /// * `gsq` — averaged squared gradients `(1/n) Σ_i G_{i,t} ∘ G_{i,t}`
    ///   (AdaGrad per Alg. 1 accumulates `G_t ∘ G_t` of the *averaged*
    ///   gradient and receives `g ∘ g` here; AdaAlter per Alg. 3 line 7
    ///   receives the worker-averaged squares — the trainer passes the
    ///   right one for each algorithm).
    /// * `lr` — warmed-up learning rate η_t.
    fn step(&mut self, x: &mut [f32], g: &[f32], gsq: &[f32], lr: f32);

    /// Algorithm identifier (for logs and metric labels).
    fn algorithm(&self) -> Algorithm;

    /// Read access to the accumulator state, if the algorithm has one
    /// (used by tests and checkpointing).
    fn denominator(&self) -> Option<&[f32]> {
        None
    }

    /// Optimizer state vectors for checkpointing (excluding x, which the
    /// leader owns). Default: stateless.
    fn state_vectors(&self) -> Vec<Vec<f32>> {
        Vec::new()
    }

    /// Restore state saved by [`Self::state_vectors`].
    fn restore_state(&mut self, vectors: &[Vec<f32>]) -> crate::error::Result<()> {
        if vectors.is_empty() {
            Ok(())
        } else {
            Err(crate::error::Error::Protocol(format!(
                "{} is stateless but checkpoint carries {} optimizer vectors",
                self.algorithm(),
                vectors.len()
            )))
        }
    }
}

/// Build the sync optimizer named by the config (dimension `d`).
///
/// Panics if asked for a local algorithm — local state machines live on the
/// workers ([`LocalAdaAlterWorker`]), not behind this trait.
pub fn build_sync(cfg: &OptimConfig, d: usize) -> Box<dyn SyncOptimizer> {
    build_sync_precision(cfg, false, d)
}

/// [`build_sync`] with an explicit accumulator precision: when `bf16_state`
/// is set (`precision.state = "bf16"`) the adaptive optimizers keep their
/// denominator on the bf16 grid (DESIGN.md §8). SGD and momentum-SGD carry
/// no accumulator, so the flag is a no-op for them.
pub fn build_sync_precision(
    cfg: &OptimConfig,
    bf16_state: bool,
    d: usize,
) -> Box<dyn SyncOptimizer> {
    match cfg.algorithm {
        Algorithm::Sgd => {
            if cfg.momentum > 0.0 {
                Box::new(MomentumSgd::new(d, cfg.momentum))
            } else {
                Box::new(Sgd::new())
            }
        }
        Algorithm::AdaGrad => {
            Box::new(AdaGrad::new(d, cfg.b0, cfg.epsilon).with_bf16_state(bf16_state))
        }
        Algorithm::AdaAlter => {
            Box::new(AdaAlter::new(d, cfg.b0, cfg.epsilon).with_bf16_state(bf16_state))
        }
        Algorithm::LocalSgd | Algorithm::LocalAdaAlter => {
            panic!("{} is a local algorithm; use the worker-side state machine", cfg.algorithm)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OptimConfig;

    #[test]
    fn build_sync_dispatches() {
        let mut cfg = OptimConfig { algorithm: Algorithm::AdaGrad, ..Default::default() };
        assert_eq!(build_sync(&cfg, 4).algorithm(), Algorithm::AdaGrad);
        cfg.algorithm = Algorithm::AdaAlter;
        assert_eq!(build_sync(&cfg, 4).algorithm(), Algorithm::AdaAlter);
        cfg.algorithm = Algorithm::Sgd;
        assert_eq!(build_sync(&cfg, 4).algorithm(), Algorithm::Sgd);
        cfg.momentum = 0.9;
        assert_eq!(build_sync(&cfg, 4).algorithm(), Algorithm::Sgd);
    }

    #[test]
    fn build_sync_precision_lands_state_on_bf16_grid() {
        let cfg = OptimConfig { algorithm: Algorithm::AdaGrad, ..Default::default() };
        let mut opt = build_sync_precision(&cfg, true, 4);
        let mut x = vec![0.0f32; 4];
        let g = vec![0.3f32, -0.7, 0.11, 2.5];
        let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
        opt.step(&mut x, &g, &gsq, 0.1);
        for &v in opt.denominator().unwrap() {
            assert_eq!(v.to_bits(), crate::util::half::round_f32(v).to_bits());
        }
        // SGD has no accumulator; the flag must be accepted silently.
        let cfg = OptimConfig { algorithm: Algorithm::Sgd, ..Default::default() };
        assert_eq!(build_sync_precision(&cfg, true, 4).algorithm(), Algorithm::Sgd);
    }

    #[test]
    #[should_panic(expected = "local algorithm")]
    fn build_sync_rejects_local() {
        let cfg = OptimConfig { algorithm: Algorithm::LocalAdaAlter, ..Default::default() };
        let _ = build_sync(&cfg, 4);
    }
}
