//! Local AdaAlter worker state machine — Algorithm 4, the paper's headline
//! contribution.
//!
//! Each worker holds three `f32[d]` vectors:
//!
//! * `x`        — the local model replica `x_{i,t}`;
//! * `b2_sync`  — the last *synchronized* denominator `B²_{i,t-t'}`
//!   (identical on every worker between syncs — the property the proof of
//!   Theorem 2 leans on);
//! * `acc`      — the running accumulator `A²_{i,t} = B²_{i,t-t'} +
//!   Σ_s G_{i,s} ∘ G_{i,s}` over the local steps since the last sync.
//!
//! During the `H−1` communication-free steps, the *placeholder denominator*
//! `B²_{i,t-t'} + t'·ε²·1` (line 6) stands in for the not-yet-averaged
//! squares: each local step contributes exactly one `ε²` per coordinate.
//! At a synchronization round both the parameters `y_{i,t}` and the
//! accumulators `A²_{i,t}` are averaged (lines 11–12) — communication is
//! `2/H` of fully-synchronous AdaGrad per step on average.
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the xla rpath link flags on
//! # // this image (libstdc++ from /opt/xla_extension), so compile-only.
//! use adaalter::optim::LocalAdaAlterWorker;
//!
//! // d = 1, b₀ = 1, ε = 1: the first local step divides by √(b₀² + 1·ε²).
//! let mut w = LocalAdaAlterWorker::new(vec![0.0], 1.0, 1.0);
//! let update_sq = w.local_step(&[2.0], 0.5); // x ← 0 − 0.5·2/√2
//! assert!((w.x()[0] + 1.0 / 2.0f32.sqrt()).abs() < 1e-6);
//! assert!((update_sq - 0.5).abs() < 1e-6); // ‖Δx‖² = (1/√2)²
//! assert_eq!(w.acc(), &[5.0]);             // b₀² + g² = 1 + 4
//! assert_eq!(w.t_prime(), 1);
//!
//! // A sync round installs the cluster averages and resets t'.
//! w.apply_sync(&[0.25], &[3.0]);
//! assert_eq!((w.x(), w.b2_sync(), w.t_prime()), (&[0.25][..], &[3.0][..], 0));
//! ```

use crate::util::{kernels, math};

/// Per-worker Local AdaAlter state.
pub struct LocalAdaAlterWorker {
    x: Vec<f32>,
    b2_sync: Vec<f32>,
    acc: Vec<f32>,
    eps2: f32,
    /// Local steps since the last synchronization (t' after a step is in
    /// `1..=H`; 0 means "just synced / fresh").
    t_prime: u64,
    /// Total local steps taken (for diagnostics).
    steps: u64,
    bf16_state: bool,
}

impl LocalAdaAlterWorker {
    /// Fresh worker: `x = init`, `B² = A² = b0²·1` (Alg. 4 line 1).
    pub fn new(init: Vec<f32>, b0: f32, epsilon: f32) -> Self {
        let d = init.len();
        LocalAdaAlterWorker {
            x: init,
            b2_sync: vec![b0 * b0; d],
            acc: vec![b0 * b0; d],
            eps2: epsilon * epsilon,
            t_prime: 0,
            steps: 0,
            bf16_state: false,
        }
    }

    /// Enable bf16 accumulator state (`precision.state = "bf16"`): `acc`
    /// and `b2_sync` are rounded through bf16 after every update while `x`
    /// stays a full f32 master weight (see [`crate::util::half`]). The
    /// `acc ≥ b2_sync` invariant survives exactly: `b2_sync` is itself a
    /// bf16 grid point, and round-to-nearest-even of any `v ≥ p` for a
    /// grid point `p` is `≥ p`.
    pub fn with_bf16_state(mut self, on: bool) -> Self {
        self.bf16_state = on;
        if on {
            crate::util::half::quantize_assign(&mut self.acc);
            crate::util::half::quantize_assign(&mut self.b2_sync);
        }
        self
    }

    /// Dimension d.
    pub fn dim(&self) -> usize {
        self.x.len()
    }

    /// One local iteration (Alg. 4 lines 4–9, non-sync branch):
    ///
    /// t' ← t'+1;
    /// `x ← x − η · g / sqrt(b2_sync + t'·ε²)`;  `acc ← acc + g∘g`.
    ///
    /// Returns `‖Δx‖²`, the squared L2 norm of the applied update — the
    /// per-step drift proxy adaptive sync policies accumulate
    /// (DESIGN.md §5). The update arithmetic is unchanged: the same
    /// quotient is computed once and both applied and squared.
    pub fn local_step(&mut self, g: &[f32], lr: f32) -> f64 {
        assert_eq!(g.len(), self.x.len(), "LocalAdaAlterWorker: g dim");
        self.t_prime += 1;
        self.steps += 1;
        let add = self.t_prime as f32 * self.eps2;
        // Fused single pass over the three streams (shared kernel).
        let update_sq =
            kernels::local_adaalter_step(&mut self.x, &self.b2_sync, &mut self.acc, g, lr, add);
        if self.bf16_state {
            crate::util::half::quantize_assign(&mut self.acc);
        }
        update_sq
    }

    /// Apply a synchronization result (Alg. 4 lines 11–12): install the
    /// averaged parameters and averaged accumulators, reset t'.
    pub fn apply_sync(&mut self, avg_x: &[f32], avg_acc: &[f32]) {
        assert_eq!(avg_x.len(), self.x.len(), "apply_sync: x dim");
        assert_eq!(avg_acc.len(), self.acc.len(), "apply_sync: acc dim");
        self.x.copy_from_slice(avg_x);
        self.acc.copy_from_slice(avg_acc);
        self.b2_sync.copy_from_slice(avg_acc);
        if self.bf16_state {
            // Quantizing both copies of the same vector keeps them equal,
            // so the post-sync `acc == b2_sync` identity is preserved.
            crate::util::half::quantize_assign(&mut self.acc);
            crate::util::half::quantize_assign(&mut self.b2_sync);
        }
        self.t_prime = 0;
    }

    /// The parameters to contribute to the sync average (`y_{i,t}`).
    pub fn x(&self) -> &[f32] {
        &self.x
    }

    /// The accumulator to contribute to the sync average (`A²_{i,t}`).
    pub fn acc(&self) -> &[f32] {
        &self.acc
    }

    /// The synchronized denominator `B²_{i,t-t'}` (equal across workers).
    pub fn b2_sync(&self) -> &[f32] {
        &self.b2_sync
    }

    /// Local steps since last sync.
    pub fn t_prime(&self) -> u64 {
        self.t_prime
    }

    /// Total local steps taken.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Split mutable access for the fused device path: the backend updates
    /// `x` and `acc` itself (one PJRT dispatch) while reading `b2_sync`;
    /// the caller must then call [`Self::note_external_step`].
    pub fn split_mut(&mut self) -> (&mut [f32], &[f32], &mut [f32]) {
        (&mut self.x, &self.b2_sync, &mut self.acc)
    }

    /// Record that one local step was applied externally (fused path):
    /// advances `t'` and the step counter without touching the vectors.
    pub fn note_external_step(&mut self) {
        self.t_prime += 1;
        self.steps += 1;
    }

    /// The placeholder denominator the *next* local step would divide by
    /// (before sqrt): `b2_sync + (t'+1)·ε²` — exposed for invariant tests.
    pub fn next_placeholder(&self) -> Vec<f32> {
        let add = (self.t_prime + 1) as f32 * self.eps2;
        self.b2_sync.iter().map(|&b| b + add).collect()
    }

    /// Invariant check (debug / property tests): the accumulator equals
    /// `b2_sync + Σ g∘g ≥ b2_sync`, and both are finite.
    pub fn check_invariants(&self) -> Result<(), String> {
        if !math::all_finite(&self.x) {
            return Err("x contains non-finite values".into());
        }
        if !math::all_finite(&self.acc) {
            return Err("acc contains non-finite values".into());
        }
        for (i, (&a, &b)) in self.acc.iter().zip(&self.b2_sync).enumerate() {
            if a < b - 1e-6 {
                return Err(format!("acc[{i}]={a} < b2_sync[{i}]={b}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_hand_computed() {
        // d=1, b0=1, eps=1, x=0, g=2, lr=0.5.
        // t'=1: denom = sqrt(1 + 1*1) = sqrt2; x = -0.5*2/sqrt2 = -1/sqrt2.
        let mut w = LocalAdaAlterWorker::new(vec![0.0], 1.0, 1.0);
        w.local_step(&[2.0], 0.5);
        assert!((w.x()[0] + 1.0 / 2.0f32.sqrt()).abs() < 1e-6);
        assert_eq!(w.acc(), &[5.0]); // 1 + 4
        assert_eq!(w.b2_sync(), &[1.0]); // unchanged until sync
        assert_eq!(w.t_prime(), 1);
    }

    #[test]
    fn placeholder_grows_per_local_step() {
        // Second local step must divide by sqrt(b2_sync + 2*eps²), NOT by
        // sqrt(acc) — the paper's lazy-denominator trick.
        let mut w = LocalAdaAlterWorker::new(vec![0.0], 1.0, 1.0);
        w.local_step(&[100.0], 0.0); // huge gsq into acc, but lr=0 so x fixed
        assert_eq!(w.x(), &[0.0]);
        assert_eq!(w.acc(), &[10_001.0]);
        // Next step uses b2_sync + 2*eps² = 3, not acc.
        w.local_step(&[1.0], 1.0);
        assert!((w.x()[0] + 1.0 / 3.0f32.sqrt()).abs() < 1e-6, "x={}", w.x()[0]);
    }

    #[test]
    fn local_step_reports_update_norm() {
        // d=2, b0=1, eps=1, lr=0.5, g=(2, -2): each coordinate moves by
        // 0.5·2/√2 = 1/√2, so ‖Δx‖² = 2·(1/2) = 1.
        let mut w = LocalAdaAlterWorker::new(vec![0.0, 0.0], 1.0, 1.0);
        let upd = w.local_step(&[2.0, -2.0], 0.5);
        assert!((upd - 1.0).abs() < 1e-6, "upd={upd}");
        // lr = 0 moves nothing.
        let upd = w.local_step(&[100.0, 100.0], 0.0);
        assert_eq!(upd, 0.0);
    }

    #[test]
    fn sync_installs_averages_and_resets() {
        let mut w = LocalAdaAlterWorker::new(vec![1.0, 2.0], 1.0, 1.0);
        w.local_step(&[1.0, -1.0], 0.5);
        assert_eq!(w.t_prime(), 1);
        w.apply_sync(&[10.0, 20.0], &[7.0, 8.0]);
        assert_eq!(w.x(), &[10.0, 20.0]);
        assert_eq!(w.acc(), &[7.0, 8.0]);
        assert_eq!(w.b2_sync(), &[7.0, 8.0]);
        assert_eq!(w.t_prime(), 0);
        // t' restarts at 1 after sync.
        w.local_step(&[0.0, 0.0], 0.5);
        assert_eq!(w.t_prime(), 1);
    }

    #[test]
    fn matches_python_ref_recurrence() {
        // Mirror of ref.local_adaalter_round_ref with H=3, d=4 — values
        // generated by the same arithmetic, here recomputed longhand.
        let d = 4;
        let x0: Vec<f32> = vec![0.1, -0.2, 0.3, -0.4];
        let b0 = 1.0;
        let eps = 1.0;
        let lr = 0.5;
        let grads: [[f32; 4]; 3] = [
            [1.0, -0.5, 0.25, 2.0],
            [-0.3, 0.7, -1.1, 0.9],
            [0.05, -0.15, 0.6, -2.0],
        ];
        let mut w = LocalAdaAlterWorker::new(x0.clone(), b0, eps);
        for g in &grads {
            w.local_step(g, lr);
        }
        // Longhand expected values.
        let mut x = x0.clone();
        let b2 = vec![1.0f32; d];
        let mut acc = b2.clone();
        for (s, g) in grads.iter().enumerate() {
            let add = (s + 1) as f32;
            for i in 0..d {
                x[i] -= lr * g[i] / (b2[i] + add).sqrt();
                acc[i] += g[i] * g[i];
            }
        }
        for i in 0..d {
            assert!((w.x()[i] - x[i]).abs() < 1e-6);
            assert!((w.acc()[i] - acc[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn invariants_hold_over_many_steps() {
        let mut w = LocalAdaAlterWorker::new(vec![0.5; 64], 1.0, 1.0);
        for s in 0..50 {
            let g: Vec<f32> = (0..64).map(|i| ((i + s) as f32 * 0.17).sin()).collect();
            w.local_step(&g, 0.5);
            w.check_invariants().unwrap();
            if s % 8 == 7 {
                let avg_x = w.x().to_vec();
                let avg_acc = w.acc().to_vec();
                w.apply_sync(&avg_x, &avg_acc);
                w.check_invariants().unwrap();
            }
        }
        assert_eq!(w.steps(), 50);
    }

    #[test]
    fn bf16_state_keeps_invariants_exact() {
        use crate::util::half;
        let mut w = LocalAdaAlterWorker::new(vec![0.5; 33], 1.0, 1.0).with_bf16_state(true);
        for s in 0..50 {
            let g: Vec<f32> = (0..33).map(|i| ((i + s) as f32 * 0.17).sin()).collect();
            w.local_step(&g, 0.5);
            w.check_invariants().unwrap();
            // Quantized invariant is exact, not just within tolerance.
            for (&a, &b) in w.acc().iter().zip(w.b2_sync()) {
                assert!(a >= b, "acc {a} < b2_sync {b}");
            }
            // All accumulator state sits on the bf16 grid; x stays f32.
            for &v in w.acc().iter().chain(w.b2_sync()) {
                assert_eq!(v.to_bits(), half::round_f32(v).to_bits());
            }
            if s % 8 == 7 {
                let avg_x = w.x().to_vec();
                // Feed an off-grid average: apply_sync must land it on-grid
                // for BOTH copies so acc == b2_sync holds exactly.
                let avg_acc: Vec<f32> = w.acc().iter().map(|&a| a + 1e-3).collect();
                w.apply_sync(&avg_x, &avg_acc);
                assert_eq!(w.acc(), w.b2_sync());
                w.check_invariants().unwrap();
            }
        }
    }

    #[test]
    fn invariant_checker_catches_corruption() {
        let mut w = LocalAdaAlterWorker::new(vec![0.0; 4], 1.0, 1.0);
        w.local_step(&[1.0; 4], 0.5);
        // Corrupt: acc below b2_sync.
        w.acc[0] = 0.0;
        assert!(w.check_invariants().is_err());
    }
}
