//! SGD baselines: plain (Algorithm 2's local step) and heavy-ball momentum.

use crate::config::Algorithm;

use super::SyncOptimizer;

/// Stateless vanilla SGD: `x ← x − η·g`.
pub struct Sgd;

impl Sgd {
    /// Construct (no state).
    pub fn new() -> Self {
        Sgd
    }

    /// The local step shared by sync-SGD and local-SGD workers
    /// ([`crate::util::kernels::sgd_step`]).
    pub fn apply(x: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(x.len(), g.len(), "Sgd: dim mismatch");
        crate::util::kernels::sgd_step(x, g, lr);
    }
}

impl Default for Sgd {
    fn default() -> Self {
        Sgd::new()
    }
}

impl SyncOptimizer for Sgd {
    fn step(&mut self, x: &mut [f32], g: &[f32], _gsq: &[f32], lr: f32) {
        Sgd::apply(x, g, lr);
    }

    fn algorithm(&self) -> Algorithm {
        Algorithm::Sgd
    }
}

/// Heavy-ball momentum: `m ← μ·m + g; x ← x − η·m`.
pub struct MomentumSgd {
    m: Vec<f32>,
    mu: f32,
}

impl MomentumSgd {
    /// `d`-dimensional velocity, momentum coefficient `mu ∈ [0,1)`.
    pub fn new(d: usize, mu: f32) -> Self {
        assert!((0.0..1.0).contains(&mu), "momentum must be in [0,1)");
        MomentumSgd { m: vec![0.0; d], mu }
    }

    /// Borrow the velocity (tests).
    pub fn velocity(&self) -> &[f32] {
        &self.m
    }
}

impl SyncOptimizer for MomentumSgd {
    fn step(&mut self, x: &mut [f32], g: &[f32], _gsq: &[f32], lr: f32) {
        let d = self.m.len();
        assert_eq!(x.len(), d, "MomentumSgd: x dim");
        assert_eq!(g.len(), d, "MomentumSgd: g dim");
        crate::util::kernels::momentum_step(x, &mut self.m, g, self.mu, lr);
    }

    fn algorithm(&self) -> Algorithm {
        Algorithm::Sgd
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_basic() {
        let mut x = vec![1.0f32, 2.0];
        Sgd::apply(&mut x, &[0.5, -1.0], 0.1);
        assert_eq!(x, vec![0.95, 2.1]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = MomentumSgd::new(1, 0.5);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1.0], &[1.0], 1.0);
        assert_eq!(opt.velocity(), &[1.0]);
        assert_eq!(x, vec![-1.0]);
        opt.step(&mut x, &[1.0], &[1.0], 1.0);
        // v = 0.5*1 + 1 = 1.5; x = -1 - 1.5 = -2.5
        assert_eq!(opt.velocity(), &[1.5]);
        assert_eq!(x, vec![-2.5]);
    }

    #[test]
    fn zero_momentum_equals_sgd() {
        let mut mom = MomentumSgd::new(3, 0.0);
        let mut xa = vec![1.0f32, 2.0, 3.0];
        let mut xb = xa.clone();
        let g = [0.3f32, -0.2, 0.9];
        mom.step(&mut xa, &g, &g, 0.25);
        Sgd::apply(&mut xb, &g, 0.25);
        assert_eq!(xa, xb);
    }

    #[test]
    #[should_panic(expected = "momentum must be in [0,1)")]
    fn invalid_momentum_rejected() {
        let _ = MomentumSgd::new(1, 1.0);
    }
}
