//! Distributed AdaGrad — Algorithm 1 of the paper (the baseline).
//!
//! Per step (lines 6–7): `B²_t ← B²_{t-1} + G_t ∘ G_t` then
//! `x_t ← x_{t-1} − η · G_t / sqrt(B²_t + ε²·1)` — accumulate FIRST,
//! update with the fresh denominator.

use crate::config::Algorithm;

use super::SyncOptimizer;

/// AdaGrad state: the accumulated squared-gradient denominator.
pub struct AdaGrad {
    b2: Vec<f32>,
    eps2: f32,
}

impl AdaGrad {
    /// `d`-dimensional state, `B₀² = b0²·1`.
    pub fn new(d: usize, b0: f32, epsilon: f32) -> Self {
        AdaGrad { b2: vec![b0 * b0; d], eps2: epsilon * epsilon }
    }

    /// Borrow the denominator (tests / checkpoints).
    pub fn b2(&self) -> &[f32] {
        &self.b2
    }
}

impl SyncOptimizer for AdaGrad {
    fn step(&mut self, x: &mut [f32], g: &[f32], gsq: &[f32], lr: f32) {
        let d = self.b2.len();
        assert_eq!(x.len(), d, "AdaGrad: x dim");
        assert_eq!(g.len(), d, "AdaGrad: g dim");
        assert_eq!(gsq.len(), d, "AdaGrad: gsq dim");
        // Fused single pass (shared kernel): accumulate, then update with
        // the new value.
        crate::util::kernels::adagrad_step(x, &mut self.b2, g, gsq, lr, self.eps2);
    }

    fn algorithm(&self) -> Algorithm {
        Algorithm::AdaGrad
    }

    fn denominator(&self) -> Option<&[f32]> {
        Some(&self.b2)
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        vec![self.b2.clone()]
    }

    fn restore_state(&mut self, vectors: &[Vec<f32>]) -> crate::error::Result<()> {
        if vectors.len() != 1 || vectors[0].len() != self.b2.len() {
            return Err(crate::error::Error::Protocol(
                "checkpoint state does not match optimizer".into(),
            ));
        }
        self.b2.copy_from_slice(&vectors[0]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-computed two-step recurrence.
    #[test]
    fn matches_hand_computation() {
        let mut opt = AdaGrad::new(2, 1.0, 1.0); // b2 = [1,1], eps2 = 1
        let mut x = vec![1.0f32, -2.0];
        let g = vec![2.0f32, 0.5];
        let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
        opt.step(&mut x, &g, &gsq, 0.5);
        // b2 = [1+4, 1+0.25] = [5, 1.25]; denom = sqrt(b2+1) = [sqrt6, sqrt2.25=1.5]
        // x = [1 - 0.5*2/sqrt6, -2 - 0.5*0.5/1.5]
        let e0 = 1.0 - 1.0 / 6.0f32.sqrt();
        let e1 = -2.0 - 0.25 / 1.5;
        assert!((x[0] - e0).abs() < 1e-6, "{} vs {e0}", x[0]);
        assert!((x[1] - e1).abs() < 1e-6, "{} vs {e1}", x[1]);
        assert_eq!(opt.b2(), &[5.0, 1.25]);

        // second step accumulates on top
        opt.step(&mut x, &g, &gsq, 0.5);
        assert_eq!(opt.b2(), &[9.0, 1.5]);
    }

    #[test]
    fn uses_fresh_denominator() {
        // With a huge gsq, the very first update must already be damped —
        // that is the accumulate-first order.
        let mut opt = AdaGrad::new(1, 1.0, 1.0);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1.0], &[1_000_000.0], 1.0);
        assert!(x[0].abs() < 1.1e-3, "update {} not damped", x[0]);
    }

    #[test]
    fn denominator_monotone() {
        let mut opt = AdaGrad::new(8, 1.0, 0.5);
        let mut x = vec![0.0f32; 8];
        let mut prev = opt.b2().to_vec();
        for s in 0..20 {
            let g: Vec<f32> = (0..8).map(|i| ((i + s) as f32 * 0.3).sin()).collect();
            let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
            opt.step(&mut x, &g, &gsq, 0.1);
            for (p, n) in prev.iter().zip(opt.b2()) {
                assert!(n >= p);
            }
            prev = opt.b2().to_vec();
        }
    }

    #[test]
    #[should_panic(expected = "x dim")]
    fn dimension_mismatch_panics() {
        let mut opt = AdaGrad::new(2, 1.0, 1.0);
        let mut x = vec![0.0f32; 3];
        opt.step(&mut x, &[0.0; 3], &[0.0; 3], 0.1);
    }
}
