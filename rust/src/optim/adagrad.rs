//! Distributed AdaGrad — Algorithm 1 of the paper (the baseline).
//!
//! Per step (lines 6–7): `B²_t ← B²_{t-1} + G_t ∘ G_t` then
//! `x_t ← x_{t-1} − η · G_t / sqrt(B²_t + ε²·1)` — accumulate FIRST,
//! update with the fresh denominator.

use crate::config::Algorithm;

use super::SyncOptimizer;

/// AdaGrad state: the accumulated squared-gradient denominator.
pub struct AdaGrad {
    b2: Vec<f32>,
    eps2: f32,
    bf16_state: bool,
}

impl AdaGrad {
    /// `d`-dimensional state, `B₀² = b0²·1`.
    pub fn new(d: usize, b0: f32, epsilon: f32) -> Self {
        AdaGrad { b2: vec![b0 * b0; d], eps2: epsilon * epsilon, bf16_state: false }
    }

    /// Enable bf16 accumulator state (`precision.state = "bf16"`): `b2`
    /// is rounded through bf16 after every update while `x` stays a full
    /// f32 master. Value-exact emulation — storage remains f32, but every
    /// stored value is exactly bf16-representable.
    pub fn with_bf16_state(mut self, on: bool) -> Self {
        self.bf16_state = on;
        if on {
            crate::util::half::quantize_assign(&mut self.b2);
        }
        self
    }

    /// Borrow the denominator (tests / checkpoints).
    pub fn b2(&self) -> &[f32] {
        &self.b2
    }
}

impl SyncOptimizer for AdaGrad {
    fn step(&mut self, x: &mut [f32], g: &[f32], gsq: &[f32], lr: f32) {
        let d = self.b2.len();
        assert_eq!(x.len(), d, "AdaGrad: x dim");
        assert_eq!(g.len(), d, "AdaGrad: g dim");
        assert_eq!(gsq.len(), d, "AdaGrad: gsq dim");
        // Fused single pass (shared kernel): accumulate, then update with
        // the new value.
        crate::util::kernels::adagrad_step(x, &mut self.b2, g, gsq, lr, self.eps2);
        if self.bf16_state {
            crate::util::half::quantize_assign(&mut self.b2);
        }
    }

    fn algorithm(&self) -> Algorithm {
        Algorithm::AdaGrad
    }

    fn denominator(&self) -> Option<&[f32]> {
        Some(&self.b2)
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        vec![self.b2.clone()]
    }

    fn restore_state(&mut self, vectors: &[Vec<f32>]) -> crate::error::Result<()> {
        if vectors.len() != 1 || vectors[0].len() != self.b2.len() {
            return Err(crate::error::Error::Protocol(
                "checkpoint state does not match optimizer".into(),
            ));
        }
        self.b2.copy_from_slice(&vectors[0]);
        if self.bf16_state {
            // Idempotent for checkpoints written under bf16 state; makes
            // f32-written checkpoints land on the bf16 grid.
            crate::util::half::quantize_assign(&mut self.b2);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-computed two-step recurrence.
    #[test]
    fn matches_hand_computation() {
        let mut opt = AdaGrad::new(2, 1.0, 1.0); // b2 = [1,1], eps2 = 1
        let mut x = vec![1.0f32, -2.0];
        let g = vec![2.0f32, 0.5];
        let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
        opt.step(&mut x, &g, &gsq, 0.5);
        // b2 = [1+4, 1+0.25] = [5, 1.25]; denom = sqrt(b2+1) = [sqrt6, sqrt2.25=1.5]
        // x = [1 - 0.5*2/sqrt6, -2 - 0.5*0.5/1.5]
        let e0 = 1.0 - 1.0 / 6.0f32.sqrt();
        let e1 = -2.0 - 0.25 / 1.5;
        assert!((x[0] - e0).abs() < 1e-6, "{} vs {e0}", x[0]);
        assert!((x[1] - e1).abs() < 1e-6, "{} vs {e1}", x[1]);
        assert_eq!(opt.b2(), &[5.0, 1.25]);

        // second step accumulates on top
        opt.step(&mut x, &g, &gsq, 0.5);
        assert_eq!(opt.b2(), &[9.0, 1.5]);
    }

    #[test]
    fn uses_fresh_denominator() {
        // With a huge gsq, the very first update must already be damped —
        // that is the accumulate-first order.
        let mut opt = AdaGrad::new(1, 1.0, 1.0);
        let mut x = vec![0.0f32];
        opt.step(&mut x, &[1.0], &[1_000_000.0], 1.0);
        assert!(x[0].abs() < 1.1e-3, "update {} not damped", x[0]);
    }

    #[test]
    fn denominator_monotone() {
        let mut opt = AdaGrad::new(8, 1.0, 0.5);
        let mut x = vec![0.0f32; 8];
        let mut prev = opt.b2().to_vec();
        for s in 0..20 {
            let g: Vec<f32> = (0..8).map(|i| ((i + s) as f32 * 0.3).sin()).collect();
            let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
            opt.step(&mut x, &g, &gsq, 0.1);
            for (p, n) in prev.iter().zip(opt.b2()) {
                assert!(n >= p);
            }
            prev = opt.b2().to_vec();
        }
    }

    #[test]
    fn bf16_state_stays_on_grid_and_monotone() {
        use crate::util::half;
        let mut opt = AdaGrad::new(8, 1.0, 0.5).with_bf16_state(true);
        let mut x = vec![0.0f32; 8];
        let mut prev = opt.b2().to_vec();
        for s in 0..20 {
            let g: Vec<f32> = (0..8).map(|i| ((i + s) as f32 * 0.3).sin()).collect();
            let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
            opt.step(&mut x, &g, &gsq, 0.1);
            for (i, (&p, &n)) in prev.iter().zip(opt.b2()).enumerate() {
                // Every stored value is exactly bf16-representable and the
                // denominator stays monotone (RNE of v ≥ grid point p is ≥ p).
                assert_eq!(n.to_bits(), half::round_f32(n).to_bits(), "off-grid at {i}");
                assert!(n >= p, "not monotone at {i}: {n} < {p}");
            }
            prev = opt.b2().to_vec();
        }
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bf16_restore_quantizes_f32_checkpoints() {
        use crate::optim::SyncOptimizer as _;
        let mut opt = AdaGrad::new(2, 1.0, 1.0).with_bf16_state(true);
        opt.restore_state(&[vec![1.2345678f32, 3.3333333]]).unwrap();
        for &v in opt.b2() {
            assert_eq!(v.to_bits(), crate::util::half::round_f32(v).to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "x dim")]
    fn dimension_mismatch_panics() {
        let mut opt = AdaGrad::new(2, 1.0, 1.0);
        let mut x = vec![0.0f32; 3];
        opt.step(&mut x, &[0.0; 3], &[0.0; 3], 0.1);
    }
}
