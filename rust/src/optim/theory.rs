//! Theorem 1 / Theorem 2 convergence bounds, computable — so experiments
//! can check measured gradient norms against what the paper guarantees.
//!
//! Theorem 1 (AdaAlter, Alg. 3):
//! ```text
//!   (1/T) Σ ‖∇F(x_{t-1})‖² ≤ 2(b₀ + √T·ε/p)·ΔF/(ηT)
//!                           + d·L·η·(b₀ + √T·ε/p)·log(b₀² + Tρ²)/(n·p²·T)
//! ```
//! Theorem 2 (local AdaAlter, Alg. 4) adds the `4η²L²H²` drift term:
//! ```text
//!   … ≤ 2√(b₀² + Tε²/p²)·ΔF/(ηT)
//!     + [4η²L²H² + Lη/n]·d·log(b₀² + Tρ²)·√(b₀² + Tε²/p²)/(T·p²)
//! ```
//! with `p = min(ε/ρ, 1)`, `ΔF = F(x₀) − F*`, under L-smoothness and
//! `‖∇f‖∞ ≤ ρ`. On the synthetic problem every constant is known exactly
//! (`L = max a_j`, closed-form optimum), so the bounds are testable — see
//! the tests and `benches/theory_bounds.rs`-style usage in examples.

/// Problem/algorithm constants the bounds need.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// Smoothness constant L.
    pub l_smooth: f64,
    /// Coordinate gradient bound ρ (Assumption 2).
    pub rho: f64,
    /// Initial suboptimality ΔF = F(x₀) − F(x_T) (upper bound: F(x₀) − F*).
    pub delta_f: f64,
    /// Dimension d.
    pub d: usize,
    /// Workers n.
    pub n: usize,
    /// Learning rate η (must be ≤ 1/L for the theorems).
    pub eta: f64,
    /// ε — the placeholder constant (paper default: 1).
    pub epsilon: f64,
    /// b₀ — the accumulator initialisation (paper default: 1).
    pub b0: f64,
}

impl BoundParams {
    /// `p = min(ε/ρ, 1)`.
    pub fn p(&self) -> f64 {
        (self.epsilon / self.rho).min(1.0)
    }

    /// Validity check: the theorems assume η ≤ 1/L and b₀ ≥ 1.
    pub fn assumptions_hold(&self) -> bool {
        self.eta <= 1.0 / self.l_smooth + 1e-12 && self.b0 >= 1.0 && self.epsilon > 0.0
    }

    /// Theorem 1 RHS: bound on the T-averaged squared gradient norm for
    /// fully-synchronous AdaAlter.
    pub fn theorem1_bound(&self, t_steps: u64) -> f64 {
        let t = t_steps as f64;
        let p = self.p();
        let coeff = self.b0 + t.sqrt() * self.epsilon / p;
        let log_term = (self.b0 * self.b0 + t * self.rho * self.rho).ln();
        2.0 * coeff * self.delta_f / (self.eta * t)
            + self.d as f64 * self.l_smooth * self.eta * coeff * log_term
                / (self.n as f64 * p * p * t)
    }

    /// Theorem 2 RHS: bound for local AdaAlter with period H.
    pub fn theorem2_bound(&self, t_steps: u64, h: u64) -> f64 {
        let t = t_steps as f64;
        let p = self.p();
        let root = (self.b0 * self.b0 + t * self.epsilon * self.epsilon / (p * p)).sqrt();
        let log_term = (self.b0 * self.b0 + t * self.rho * self.rho).ln();
        let drift = 4.0 * self.eta * self.eta * self.l_smooth * self.l_smooth
            * (h as f64) * (h as f64)
            + self.l_smooth * self.eta / self.n as f64;
        2.0 * root * self.delta_f / (self.eta * t)
            + drift * self.d as f64 * log_term * root / (t * p * p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> BoundParams {
        BoundParams {
            l_smooth: 10.0,
            rho: 5.0,
            delta_f: 600.0,
            d: 512,
            n: 4,
            eta: 0.1,
            epsilon: 1.0,
            b0: 1.0,
        }
    }

    #[test]
    fn bounds_decay_in_t() {
        let p = params();
        let b_1k = p.theorem1_bound(1_000);
        let b_100k = p.theorem1_bound(100_000);
        let b_10m = p.theorem1_bound(10_000_000);
        assert!(b_100k < b_1k);
        assert!(b_10m < b_100k);
        // O(log T / sqrt T): ratio over 100x steps ≈ 1/10 (up to logs).
        assert!(b_10m < b_100k / 5.0);
    }

    #[test]
    fn theorem2_penalises_h_quadratically() {
        let p = params();
        let t = 100_000;
        let b1 = p.theorem2_bound(t, 1);
        let b4 = p.theorem2_bound(t, 4);
        let b16 = p.theorem2_bound(t, 16);
        assert!(b4 > b1);
        assert!(b16 > b4);
        // The H² term dominates at large H: quadrupling H ≈ 16x that term.
        let drift4 = b4 - b1;
        let drift16 = b16 - b1;
        let ratio = drift16 / drift4;
        assert!((10.0..22.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn more_workers_tighten_theorem1_variance_term() {
        let mut p = params();
        let t = 10_000;
        let b4 = p.theorem1_bound(t);
        p.n = 64;
        let b64 = p.theorem1_bound(t);
        assert!(b64 < b4);
    }

    #[test]
    fn h1_theorem2_same_rate_as_theorem1() {
        // At H=1 both bounds decay as O(log T / sqrt T); Theorem 2 carries
        // a larger constant (its drift term keeps 4η²L² even at H=1), so we
        // check the *rate*: the ratio is a stable constant across T, not a
        // growing gap.
        let p = params();
        let r_small = p.theorem2_bound(10_000, 1) / p.theorem1_bound(10_000);
        let r_large = p.theorem2_bound(10_000_000, 1) / p.theorem1_bound(10_000_000);
        assert!(r_small > 1.0 && r_small < 100.0, "r_small {r_small}");
        assert!(
            (r_large / r_small - 1.0).abs() < 0.25,
            "ratio drifts with T: {r_small} -> {r_large}"
        );
    }

    #[test]
    fn assumption_gate() {
        let mut p = params();
        assert!(p.assumptions_hold());
        p.eta = 0.2; // > 1/L = 0.1
        assert!(!p.assumptions_hold());
        p.eta = 0.05;
        p.b0 = 0.5;
        assert!(!p.assumptions_hold());
    }

    #[test]
    fn p_is_min_eps_over_rho_and_one() {
        let mut p = params();
        assert_eq!(p.p(), 1.0 / 5.0);
        p.rho = 0.5;
        assert_eq!(p.p(), 1.0);
    }
}
