//! Fully-synchronous AdaAlter — Algorithm 3, the paper's first contribution.
//!
//! Per step (lines 6–7):
//!   `x_t ← x_{t-1} − η · G_t / sqrt(B²_{t-1} + ε²·1)`   (update FIRST …)
//!   `B²_t ← B²_{t-1} + (1/n) Σ_i G_{i,t} ∘ G_{i,t}`     (… accumulate AFTER)
//!
//! The one-line swap relative to AdaGrad is what makes the denominator
//! lazily computable in the local variant (Alg. 4): during local steps the
//! not-yet-averaged `G ∘ G` contributions are stood in for by `t'·ε²`.

use crate::config::Algorithm;

use super::SyncOptimizer;

/// AdaAlter state: the accumulated denominator (updated *after* each step).
pub struct AdaAlter {
    b2: Vec<f32>,
    eps2: f32,
    bf16_state: bool,
}

impl AdaAlter {
    /// `d`-dimensional state, `B₀² = b0²·1`.
    pub fn new(d: usize, b0: f32, epsilon: f32) -> Self {
        AdaAlter { b2: vec![b0 * b0; d], eps2: epsilon * epsilon, bf16_state: false }
    }

    /// Enable bf16 accumulator state (`precision.state = "bf16"`): `b2`
    /// is rounded through bf16 after every update while `x` stays a full
    /// f32 master (see [`crate::util::half`]).
    pub fn with_bf16_state(mut self, on: bool) -> Self {
        self.bf16_state = on;
        if on {
            crate::util::half::quantize_assign(&mut self.b2);
        }
        self
    }

    /// Borrow the denominator.
    pub fn b2(&self) -> &[f32] {
        &self.b2
    }
}

impl SyncOptimizer for AdaAlter {
    fn step(&mut self, x: &mut [f32], g: &[f32], gsq: &[f32], lr: f32) {
        let d = self.b2.len();
        assert_eq!(x.len(), d, "AdaAlter: x dim");
        assert_eq!(g.len(), d, "AdaAlter: g dim");
        assert_eq!(gsq.len(), d, "AdaAlter: gsq dim");
        // Fused single pass (shared kernel): update with the STALE
        // denominator, then fold the fresh squares in.
        crate::util::kernels::adaalter_step(x, &mut self.b2, g, gsq, lr, self.eps2);
        if self.bf16_state {
            crate::util::half::quantize_assign(&mut self.b2);
        }
    }

    fn algorithm(&self) -> Algorithm {
        Algorithm::AdaAlter
    }

    fn denominator(&self) -> Option<&[f32]> {
        Some(&self.b2)
    }

    fn state_vectors(&self) -> Vec<Vec<f32>> {
        vec![self.b2.clone()]
    }

    fn restore_state(&mut self, vectors: &[Vec<f32>]) -> crate::error::Result<()> {
        if vectors.len() != 1 || vectors[0].len() != self.b2.len() {
            return Err(crate::error::Error::Protocol(
                "checkpoint state does not match optimizer".into(),
            ));
        }
        self.b2.copy_from_slice(&vectors[0]);
        if self.bf16_state {
            // Idempotent for bf16-written checkpoints; quantizes
            // f32-written ones onto the grid.
            crate::util::half::quantize_assign(&mut self.b2);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adagrad::AdaGrad;

    #[test]
    fn matches_hand_computation() {
        let mut opt = AdaAlter::new(2, 1.0, 1.0); // b2 = [1,1], eps2 = 1
        let mut x = vec![1.0f32, -2.0];
        let g = vec![2.0f32, 0.5];
        let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
        opt.step(&mut x, &g, &gsq, 0.5);
        // update uses STALE b2=1: denom = sqrt(1+1) = sqrt2
        // x = [1 - 0.5*2/sqrt2, -2 - 0.5*0.5/sqrt2]
        let s2 = 2.0f32.sqrt();
        assert!((x[0] - (1.0 - 1.0 / s2)).abs() < 1e-6);
        assert!((x[1] - (-2.0 - 0.25 / s2)).abs() < 1e-6);
        // accumulate AFTER: b2 = [5, 1.25]
        assert_eq!(opt.b2(), &[5.0, 1.25]);
    }

    #[test]
    fn update_ignores_fresh_squares() {
        // The defining property: the update must not see this step's gsq.
        let mut a = AdaAlter::new(1, 1.0, 1.0);
        let mut b = AdaAlter::new(1, 1.0, 1.0);
        let mut xa = vec![0.0f32];
        let mut xb = vec![0.0f32];
        a.step(&mut xa, &[1.0], &[1.0], 0.5);
        b.step(&mut xb, &[1.0], &[1e9], 0.5);
        assert_eq!(xa[0], xb[0]);
        assert_ne!(a.b2()[0], b.b2()[0]);
    }

    #[test]
    fn first_step_differs_from_adagrad_then_converges_in_shape() {
        // With identical inputs, AdaAlter's first update is LARGER (stale
        // denominator is smaller) — the reason the paper adds warm-up.
        let mut aa = AdaAlter::new(1, 1.0, 1.0);
        let mut ag = AdaGrad::new(1, 1.0, 1.0);
        use crate::optim::SyncOptimizer as _;
        let mut xa = vec![0.0f32];
        let mut xg = vec![0.0f32];
        aa.step(&mut xa, &[3.0], &[9.0], 1.0);
        ag.step(&mut xg, &[3.0], &[9.0], 1.0);
        assert!(xa[0].abs() > xg[0].abs());
        // After the step both hold the same accumulated squares.
        assert_eq!(aa.b2(), ag.b2());
    }

    #[test]
    fn adaalter_denominator_lags_adagrad_by_one_step() {
        // B²(AdaAlter, after t steps) == B²(AdaGrad, after t steps); the
        // *used* denominator differs by exactly one step's gsq.
        let mut aa = AdaAlter::new(4, 1.0, 1.0);
        let mut ag = AdaGrad::new(4, 1.0, 1.0);
        use crate::optim::SyncOptimizer as _;
        let mut xa = vec![0.0f32; 4];
        let mut xg = vec![0.0f32; 4];
        for s in 0..10 {
            let g: Vec<f32> = (0..4).map(|i| ((i * 7 + s) as f32 * 0.41).cos()).collect();
            let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
            aa.step(&mut xa, &g, &gsq, 0.3);
            ag.step(&mut xg, &g, &gsq, 0.3);
            for i in 0..4 {
                assert!((aa.b2()[i] - ag.b2()[i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn zero_gradient_fixed_point() {
        let mut opt = AdaAlter::new(3, 1.0, 1.0);
        let mut x = vec![1.0f32, 2.0, 3.0];
        let before = x.clone();
        opt.step(&mut x, &[0.0; 3], &[0.0; 3], 0.5);
        assert_eq!(x, before);
        assert_eq!(opt.b2(), &[1.0; 3]);
    }

    #[test]
    fn bf16_state_preserves_defining_property() {
        use crate::util::half;
        // The stale-denominator property must survive quantized state:
        // this step's gsq cannot leak into this step's update.
        let mut a = AdaAlter::new(1, 1.0, 1.0).with_bf16_state(true);
        let mut b = AdaAlter::new(1, 1.0, 1.0).with_bf16_state(true);
        let (mut xa, mut xb) = (vec![0.0f32], vec![0.0f32]);
        a.step(&mut xa, &[1.0], &[1.0], 0.5);
        b.step(&mut xb, &[1.0], &[1e9], 0.5);
        assert_eq!(xa[0], xb[0]);
        // 1.0 is bf16-exact, so the zero-gradient fixed point holds
        // exactly under quantized state too.
        let mut opt = AdaAlter::new(3, 1.0, 1.0).with_bf16_state(true);
        let mut x = vec![1.0f32, 2.0, 3.0];
        opt.step(&mut x, &[0.0; 3], &[0.0; 3], 0.5);
        assert_eq!(opt.b2(), &[1.0; 3]);
        // And every stored denominator value sits on the bf16 grid.
        let mut opt = AdaAlter::new(4, 1.0, 0.5).with_bf16_state(true);
        let mut x = vec![0.0f32; 4];
        for s in 0..30 {
            let g: Vec<f32> = (0..4).map(|i| ((i * 3 + s) as f32 * 0.7).cos()).collect();
            let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
            opt.step(&mut x, &g, &gsq, 0.2);
            for &v in opt.b2() {
                assert_eq!(v.to_bits(), half::round_f32(v).to_bits());
            }
        }
    }
}
