//! Shared hot-path kernels — the one home for every per-element loop the
//! training hot paths execute (DESIGN.md §6).
//!
//! Before this module, each call site owned a private copy of its loop:
//! the optimizer steps in [`crate::optim`], the leader-side averaging in
//! [`crate::coordinator::aggregate`], and the delta coding of
//! [`crate::comm`]'s compressed transports. Centralising them buys three
//! things:
//!
//! * **One bitwise-pinned implementation.** The equivalence tests pin the
//!   exact f32 op order; with a single copy, an optimisation (or a bug)
//!   cannot drift one caller away from the others.
//! * **Autovectorizer-friendly shape.** Every kernel operates on
//!   pre-narrowed contiguous slices with bounds checks hoisted out of the
//!   hot body, and the multi-input reductions are cache-blocked
//!   ([`MEAN_CHUNK`]) so accumulator chunks stay in L1 across the n input
//!   passes.
//! * **Zero-allocation discipline.** Kernels never allocate; callers bring
//!   every buffer (see [`crate::util::pool::BufferPool`]), which is what
//!   the counting-allocator test leans on.
//!
//! Bitwise contract: each kernel performs *exactly* the arithmetic, in
//! exactly the per-element order, of the loop it replaced. Cache blocking
//! only regroups loop iterations; it never reassociates a single
//! element's operations, so results are bit-identical to the unblocked
//! form.

/// Panic-with-context helper for length mismatches (protocol invariant).
#[inline]
fn check_len(a: usize, b: usize, what: &str) {
    assert_eq!(a, b, "length mismatch in {what}: {a} vs {b}");
}

/// Cache-blocking chunk for multi-input reductions: 4 KiB of f32 keeps the
/// accumulator chunk resident in L1 across the n input passes, turning the
/// n-way mean from (n reads + n read-modify-writes of `out`) into
/// (n reads + 1 write) of DRAM traffic. EXPERIMENTS.md §Perf.
pub const MEAN_CHUNK: usize = 1024;

/// `out[i] = mean_k inputs[k][i]` — the Alg. 4 lines 11–12 synchronization
/// average. `inputs` must be non-empty and same-length. Generic over the
/// row type so both `&[&[f32]]` (leader gathers) and `&[Vec<f32>]`
/// (pooled staging buffers) average without building a borrow vector.
pub fn mean_into<S: AsRef<[f32]>>(inputs: &[S], out: &mut [f32]) {
    assert!(!inputs.is_empty(), "mean_into: no inputs");
    let d = out.len();
    for v in inputs {
        check_len(v.as_ref().len(), d, "mean_into");
    }
    let scale = 1.0 / inputs.len() as f32;
    let mut start = 0;
    while start < d {
        let end = (start + MEAN_CHUNK).min(d);
        let out_c = &mut out[start..end];
        out_c.copy_from_slice(&inputs[0].as_ref()[start..end]);
        for v in &inputs[1..] {
            let v = &v.as_ref()[start..end];
            for (o, &x) in out_c.iter_mut().zip(v) {
                *o += x;
            }
        }
        for o in out_c.iter_mut() {
            *o *= scale;
        }
        start = end;
    }
}

/// Simultaneously `avg_g = (1/n) Σ_i g_i` and `avg_gsq = (1/n) Σ_i g_i∘g_i`
/// — one pass over the inputs, both outputs written per cache line
/// (Alg. 3 needs both: line 5 + line 7).
pub fn mean_and_squares_into<S: AsRef<[f32]>>(
    inputs: &[S],
    avg_g: &mut [f32],
    avg_gsq: &mut [f32],
) {
    assert!(!inputs.is_empty(), "mean_and_squares_into: no inputs");
    let d = avg_g.len();
    check_len(avg_gsq.len(), d, "mean_and_squares_into");
    for g in inputs {
        check_len(g.as_ref().len(), d, "mean_and_squares_into");
    }
    let scale = 1.0 / inputs.len() as f32;
    let mut start = 0;
    while start < d {
        let end = (start + MEAN_CHUNK).min(d);
        let (gc, qc) = (&mut avg_g[start..end], &mut avg_gsq[start..end]);
        let first = &inputs[0].as_ref()[start..end];
        for i in 0..gc.len() {
            let v = first[i];
            gc[i] = v;
            qc[i] = v * v;
        }
        for g in &inputs[1..] {
            let g = &g.as_ref()[start..end];
            for i in 0..gc.len() {
                let v = g[i];
                gc[i] += v;
                qc[i] += v * v;
            }
        }
        for i in 0..gc.len() {
            gc[i] *= scale;
            qc[i] *= scale;
        }
        start = end;
    }
}

/// `out[i] = x[i]²` — AdaGrad's Alg. 1 line 6 squares the *averaged*
/// gradient.
pub fn square_into(x: &[f32], out: &mut [f32]) {
    check_len(x.len(), out.len(), "square_into");
    let d = out.len();
    let x = &x[..d];
    for i in 0..d {
        out[i] = x[i] * x[i];
    }
}

/// In-place `acc += x`.
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    check_len(acc.len(), x.len(), "add_assign");
    let d = acc.len();
    let x = &x[..d];
    for i in 0..d {
        acc[i] += x[i];
    }
}

/// In-place `acc *= s` (scaled accumulate's epilogue).
pub fn scale_assign(acc: &mut [f32], s: f32) {
    for v in acc.iter_mut() {
        *v *= s;
    }
}

/// In-place `acc += s * x` (axpy).
pub fn axpy(acc: &mut [f32], s: f32, x: &[f32]) {
    check_len(acc.len(), x.len(), "axpy");
    let d = acc.len();
    let x = &x[..d];
    for i in 0..d {
        acc[i] += s * x[i];
    }
}

/// In-place `acc += g ∘ g` (squared-gradient accumulate, Alg. 1/3 line 6/7
/// building block).
pub fn sq_accumulate(acc: &mut [f32], g: &[f32]) {
    check_len(acc.len(), g.len(), "sq_accumulate");
    let d = acc.len();
    let g = &g[..d];
    for i in 0..d {
        acc[i] += g[i] * g[i];
    }
}

/// Plain SGD update: `x ← x − lr·g`.
pub fn sgd_step(x: &mut [f32], g: &[f32], lr: f32) {
    check_len(x.len(), g.len(), "sgd_step");
    let d = x.len();
    let g = &g[..d];
    for i in 0..d {
        x[i] -= lr * g[i];
    }
}

/// `‖lr·g‖²` in f64 — the SGD drift proxy, computed exactly as the local
/// step would apply it (`Δx = −lr·g`), without touching the update.
pub fn sgd_update_sq(g: &[f32], lr: f32) -> f64 {
    g.iter()
        .map(|&gv| {
            let u = (lr * gv) as f64;
            u * u
        })
        .sum()
}

/// Heavy-ball momentum update: `m ← μ·m + g; x ← x − lr·m`, fused.
pub fn momentum_step(x: &mut [f32], m: &mut [f32], g: &[f32], mu: f32, lr: f32) {
    let d = m.len();
    check_len(x.len(), d, "momentum_step");
    check_len(g.len(), d, "momentum_step");
    let x = &mut x[..d];
    let g = &g[..d];
    for i in 0..d {
        let v = mu * m[i] + g[i];
        m[i] = v;
        x[i] -= lr * v;
    }
}

/// AdaGrad step (Alg. 1 lines 6–7), fused single pass: accumulate the
/// squared averaged gradient FIRST, update with the fresh denominator.
pub fn adagrad_step(x: &mut [f32], b2: &mut [f32], g: &[f32], gsq: &[f32], lr: f32, eps2: f32) {
    let d = b2.len();
    check_len(x.len(), d, "adagrad_step");
    check_len(g.len(), d, "adagrad_step");
    check_len(gsq.len(), d, "adagrad_step");
    let x = &mut x[..d];
    let g = &g[..d];
    let gsq = &gsq[..d];
    for i in 0..d {
        let b2i = b2[i] + gsq[i];
        b2[i] = b2i;
        x[i] -= lr * g[i] / (b2i + eps2).sqrt();
    }
}

/// AdaAlter step (Alg. 3 lines 6–7), fused single pass: update with the
/// STALE denominator, then fold the fresh squares in.
pub fn adaalter_step(x: &mut [f32], b2: &mut [f32], g: &[f32], gsq: &[f32], lr: f32, eps2: f32) {
    let d = b2.len();
    check_len(x.len(), d, "adaalter_step");
    check_len(g.len(), d, "adaalter_step");
    check_len(gsq.len(), d, "adaalter_step");
    let x = &mut x[..d];
    let g = &g[..d];
    let gsq = &gsq[..d];
    for i in 0..d {
        let stale = b2[i];
        x[i] -= lr * g[i] / (stale + eps2).sqrt();
        b2[i] = stale + gsq[i];
    }
}

/// Local AdaAlter step (Alg. 4 lines 5–7), fused single pass over the
/// three streams: `x ← x − lr·g/√(b2_sync + denom_add)`, `acc += g∘g`.
/// Returns `‖Δx‖²` (f64), the drift proxy adaptive sync policies consume.
pub fn local_adaalter_step(
    x: &mut [f32],
    b2_sync: &[f32],
    acc: &mut [f32],
    g: &[f32],
    lr: f32,
    denom_add: f32,
) -> f64 {
    let d = x.len();
    check_len(b2_sync.len(), d, "local_adaalter_step");
    check_len(acc.len(), d, "local_adaalter_step");
    check_len(g.len(), d, "local_adaalter_step");
    let b2 = &b2_sync[..d];
    let acc = &mut acc[..d];
    let g = &g[..d];
    let mut update_sq = 0.0f64;
    for i in 0..d {
        let gi = g[i];
        let du = lr * gi / (b2[i] + denom_add).sqrt();
        x[i] -= du;
        acc[i] += gi * gi;
        update_sq += du as f64 * du as f64;
    }
    update_sq
}

/// Delta encode: `out[i] = src[i] − base[i]` (the quantity compressed
/// local-SGD actually ships; DESIGN.md §3).
pub fn delta_encode(src: &[f32], base: &[f32], out: &mut [f32]) {
    let d = out.len();
    check_len(src.len(), d, "delta_encode");
    check_len(base.len(), d, "delta_encode");
    let src = &src[..d];
    let base = &base[..d];
    for i in 0..d {
        out[i] = src[i] - base[i];
    }
}

/// Delta decode: `out[i] = base[i] + delta[i]`.
pub fn delta_decode(base: &[f32], delta: &[f32], out: &mut [f32]) {
    let d = out.len();
    check_len(base.len(), d, "delta_decode");
    check_len(delta.len(), d, "delta_decode");
    let base = &base[..d];
    let delta = &delta[..d];
    for i in 0..d {
        out[i] = base[i] + delta[i];
    }
}

/// Delta decode clamped at zero: `out[i] = max(base[i] + delta[i], 0)` —
/// the denominator install after a lossy roundtrip (the `t'·ε²`
/// placeholder keeps the installed denominator strictly positive, so
/// training stays finite).
pub fn delta_decode_clamped(base: &[f32], delta: &[f32], out: &mut [f32]) {
    let d = out.len();
    check_len(base.len(), d, "delta_decode_clamped");
    check_len(delta.len(), d, "delta_decode_clamped");
    let base = &base[..d];
    let delta = &delta[..d];
    for i in 0..d {
        out[i] = (base[i] + delta[i]).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn randv(seed: u64, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        Rng::new(seed).fill_normal(&mut v, 1.0);
        v
    }

    /// The bitwise contract: the chunked mean equals the naive
    /// sum-then-scale per-element recurrence EXACTLY (same op order).
    #[test]
    fn mean_into_bitwise_matches_naive() {
        prop::check("mean_into bitwise", 40, |g| {
            let d = g.usize_in(1..3000);
            let n = g.usize_in(1..6);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(d..d + 1, -3.0..3.0)).collect();
            let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
            let mut out = vec![0.0f32; d];
            mean_into(&refs, &mut out);
            // Naive: out = in0; out += in_k; out *= 1/n — element-wise.
            let scale = 1.0 / n as f32;
            for i in 0..d {
                let mut acc = rows[0][i];
                for row in &rows[1..] {
                    acc += row[i];
                }
                acc *= scale;
                prop::assert_that(
                    out[i].to_bits() == acc.to_bits(),
                    format!("mean_into[{i}] not bitwise: {} vs {acc}", out[i]),
                )?;
            }
            // The Vec-row overload runs the same kernel.
            let mut out2 = vec![0.0f32; d];
            mean_into(&rows, &mut out2);
            prop::assert_that(out == out2, "Vec-row overload diverged")
        });
    }

    #[test]
    fn mean_and_squares_bitwise_matches_naive() {
        prop::check("mean_and_squares bitwise", 30, |g| {
            let d = g.usize_in(1..2500);
            let n = g.usize_in(1..6);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(d..d + 1, -3.0..3.0)).collect();
            let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
            let mut avg_g = vec![0.0f32; d];
            let mut avg_gsq = vec![0.0f32; d];
            mean_and_squares_into(&refs, &mut avg_g, &mut avg_gsq);
            let scale = 1.0 / n as f32;
            for i in 0..d {
                let mut sg = rows[0][i];
                let mut sq = rows[0][i] * rows[0][i];
                for row in &rows[1..] {
                    let v = row[i];
                    sg += v;
                    sq += v * v;
                }
                sg *= scale;
                sq *= scale;
                prop::assert_that(
                    avg_g[i].to_bits() == sg.to_bits() && avg_gsq[i].to_bits() == sq.to_bits(),
                    format!("joint mean[{i}] not bitwise"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn elementwise_kernels_match_hand_loops() {
        let d = 37;
        let g = randv(1, d);
        let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();

        // adagrad_step vs the original fused loop.
        let mut x = randv(2, d);
        let mut b2 = vec![1.0f32; d];
        let (mut xe, mut b2e) = (x.clone(), b2.clone());
        adagrad_step(&mut x, &mut b2, &g, &gsq, 0.3, 1.0);
        for i in 0..d {
            let b2i = b2e[i] + gsq[i];
            b2e[i] = b2i;
            xe[i] -= 0.3 * g[i] / (b2i + 1.0).sqrt();
        }
        assert_eq!(x, xe);
        assert_eq!(b2, b2e);

        // adaalter_step vs the original fused loop.
        let mut x = randv(3, d);
        let mut b2 = vec![1.0f32; d];
        let (mut xe, mut b2e) = (x.clone(), b2.clone());
        adaalter_step(&mut x, &mut b2, &g, &gsq, 0.3, 1.0);
        for i in 0..d {
            let stale = b2e[i];
            xe[i] -= 0.3 * g[i] / (stale + 1.0).sqrt();
            b2e[i] = stale + gsq[i];
        }
        assert_eq!(x, xe);
        assert_eq!(b2, b2e);

        // local_adaalter_step vs the original three-stream loop.
        let mut x = randv(4, d);
        let b2s = vec![1.0f32; d];
        let mut acc = vec![1.0f32; d];
        let (mut xe, mut acce) = (x.clone(), acc.clone());
        let upd = local_adaalter_step(&mut x, &b2s, &mut acc, &g, 0.5, 2.0);
        let mut upde = 0.0f64;
        for i in 0..d {
            let du = 0.5 * g[i] / (b2s[i] + 2.0).sqrt();
            xe[i] -= du;
            acce[i] += g[i] * g[i];
            upde += du as f64 * du as f64;
        }
        assert_eq!(x, xe);
        assert_eq!(acc, acce);
        assert_eq!(upd.to_bits(), upde.to_bits());

        // sgd_step + sgd_update_sq.
        let mut x = randv(5, d);
        let mut xe = x.clone();
        let upd = sgd_update_sq(&g, 0.1);
        sgd_step(&mut x, &g, 0.1);
        let mut upde = 0.0f64;
        for i in 0..d {
            let u = (0.1 * g[i]) as f64;
            upde += u * u;
            xe[i] -= 0.1 * g[i];
        }
        assert_eq!(x, xe);
        assert_eq!(upd.to_bits(), upde.to_bits());
    }

    #[test]
    fn delta_roundtrip_and_clamp() {
        let base = randv(7, 64);
        let src = randv(8, 64);
        let mut delta = vec![0.0f32; 64];
        let mut back = vec![0.0f32; 64];
        delta_encode(&src, &base, &mut delta);
        delta_decode(&base, &delta, &mut back);
        for i in 0..64 {
            // f32 subtract-then-add is not exact in general; exact when
            // magnitudes are comparable — just check the identity used.
            assert_eq!(back[i].to_bits(), (base[i] + (src[i] - base[i])).to_bits());
        }
        let base = [1.0f32, 0.5, 0.0];
        let delta = [-2.0f32, 0.25, -0.5];
        let mut out = [9.0f32; 3];
        delta_decode_clamped(&base, &delta, &mut out);
        assert_eq!(out, [0.0, 0.75, 0.0]);
    }

    #[test]
    fn accumulate_kernels() {
        let mut acc = vec![1.0f32; 4];
        axpy(&mut acc, 2.0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(acc, vec![3.0, 5.0, 7.0, 9.0]);
        add_assign(&mut acc, &[1.0; 4]);
        assert_eq!(acc, vec![4.0, 6.0, 8.0, 10.0]);
        scale_assign(&mut acc, 0.5);
        assert_eq!(acc, vec![2.0, 3.0, 4.0, 5.0]);
        let mut sq = vec![1.0f32; 3];
        sq_accumulate(&mut sq, &[2.0, -3.0, 0.0]);
        assert_eq!(sq, vec![5.0, 10.0, 1.0]);
        let mut out = vec![0.0f32; 2];
        square_into(&[3.0, -2.0], &mut out);
        assert_eq!(out, vec![9.0, 4.0]);
    }

    #[test]
    fn momentum_kernel_matches_hand_loop() {
        let mut x = vec![0.0f32; 2];
        let mut m = vec![0.0f32; 2];
        momentum_step(&mut x, &mut m, &[1.0, -1.0], 0.5, 1.0);
        assert_eq!(m, vec![1.0, -1.0]);
        assert_eq!(x, vec![-1.0, 1.0]);
        momentum_step(&mut x, &mut m, &[1.0, -1.0], 0.5, 1.0);
        assert_eq!(m, vec![1.5, -1.5]);
        assert_eq!(x, vec![-2.5, 2.5]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn ragged_inputs_rejected() {
        let mut out = vec![0.0f32; 3];
        mean_into(&[&[1.0f32, 2.0][..]], &mut out);
    }
}
