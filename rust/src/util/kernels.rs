//! Shared hot-path kernels — the one home for every per-element loop the
//! training hot paths execute (DESIGN.md §7, §8).
//!
//! Before this module, each call site owned a private copy of its loop:
//! the optimizer steps in [`crate::optim`], the leader-side averaging in
//! [`crate::coordinator::aggregate`], and the delta coding of
//! [`crate::comm`]'s compressed transports. Centralising them buys three
//! things:
//!
//! * **One bitwise-pinned implementation.** The equivalence tests pin the
//!   exact f32 op order; with a single copy, an optimisation (or a bug)
//!   cannot drift one caller away from the others.
//! * **Explicit SIMD with a scalar oracle.** Every public kernel here is
//!   a thin dispatcher: [`serial`] holds the scalar reference loops, and
//!   [`crate::util::simd`] holds lane-structured versions selected by the
//!   `[exec] simd` knob. The two are bit-identical for every kernel —
//!   elementwise ops run the same per-element arithmetic in the same
//!   order, and the two reductions ([`sgd_update_sq`],
//!   [`local_adaalter_step`]'s `‖Δx‖²`) accumulate into the same fixed
//!   8-lane f64 tree (element `i` → lane `i mod 8`,
//!   [`crate::util::simd::fold_tree`] fold) in both implementations — so
//!   the dispatch decision is a pure wall-clock knob. The property pins
//!   below assert serial ≡ simd for all widths including every remainder
//!   length.
//! * **Zero-allocation discipline.** Kernels never allocate; callers bring
//!   every buffer (see [`crate::util::pool::BufferPool`]), which is what
//!   the counting-allocator test leans on.
//!
//! Bitwise contract: each elementwise kernel performs *exactly* the
//! arithmetic, in exactly the per-element order, of the loop it replaced.
//! Cache blocking ([`MEAN_CHUNK`]) and lane chunking only regroup loop
//! iterations; they never reassociate a single element's operations. The
//! f64 drift reductions use the fixed lane tree in *both* modes (the one
//! deliberate reassociation, chosen so serial ≡ simd bitwise; the scalar
//! value differs from a left-to-right sum only by f64 rounding, and no
//! consumer pins that sum — drift policies and reports are pinned
//! run-vs-run).

use crate::util::simd;

/// Panic-with-context helper for length mismatches (protocol invariant).
#[inline]
pub(crate) fn check_len(a: usize, b: usize, what: &str) {
    assert_eq!(a, b, "length mismatch in {what}: {a} vs {b}");
}

/// Cache-blocking chunk for multi-input reductions: 4 KiB of f32 keeps the
/// accumulator chunk resident in L1 across the n input passes, turning the
/// n-way mean from (n reads + n read-modify-writes of `out`) into
/// (n reads + 1 write) of DRAM traffic. EXPERIMENTS.md §Perf.
pub const MEAN_CHUNK: usize = 1024;

/// Scalar reference kernels — the bitwise oracle the SIMD forms in
/// [`crate::util::simd`] are pinned against.
///
/// These are the seed's original loops, unchanged except that the two f64
/// drift reductions accumulate into the shared fixed 8-lane tree (see the
/// module doc). Call sites use the dispatching wrappers in the parent
/// module; benches and property tests call these directly to compare the
/// implementations without touching the process-global mode.
pub mod serial {
    use super::{check_len, MEAN_CHUNK};
    use crate::util::simd::{fold_tree, LANES};

    /// Scalar reference for [`super::mean_into`]: chunked copy / add /
    /// scale passes.
    pub fn mean_into<S: AsRef<[f32]>>(inputs: &[S], out: &mut [f32]) {
        assert!(!inputs.is_empty(), "mean_into: no inputs");
        let d = out.len();
        for v in inputs {
            check_len(v.as_ref().len(), d, "mean_into");
        }
        let scale = 1.0 / inputs.len() as f32;
        let mut start = 0;
        while start < d {
            let end = (start + MEAN_CHUNK).min(d);
            let out_c = &mut out[start..end];
            out_c.copy_from_slice(&inputs[0].as_ref()[start..end]);
            for v in &inputs[1..] {
                let v = &v.as_ref()[start..end];
                for (o, &x) in out_c.iter_mut().zip(v) {
                    *o += x;
                }
            }
            for o in out_c.iter_mut() {
                *o *= scale;
            }
            start = end;
        }
    }

    /// Scalar reference for [`super::mean_and_squares_into`].
    pub fn mean_and_squares_into<S: AsRef<[f32]>>(
        inputs: &[S],
        avg_g: &mut [f32],
        avg_gsq: &mut [f32],
    ) {
        assert!(!inputs.is_empty(), "mean_and_squares_into: no inputs");
        let d = avg_g.len();
        check_len(avg_gsq.len(), d, "mean_and_squares_into");
        for g in inputs {
            check_len(g.as_ref().len(), d, "mean_and_squares_into");
        }
        let scale = 1.0 / inputs.len() as f32;
        let mut start = 0;
        while start < d {
            let end = (start + MEAN_CHUNK).min(d);
            let (gc, qc) = (&mut avg_g[start..end], &mut avg_gsq[start..end]);
            let first = &inputs[0].as_ref()[start..end];
            for i in 0..gc.len() {
                let v = first[i];
                gc[i] = v;
                qc[i] = v * v;
            }
            for g in &inputs[1..] {
                let g = &g.as_ref()[start..end];
                for i in 0..gc.len() {
                    let v = g[i];
                    gc[i] += v;
                    qc[i] += v * v;
                }
            }
            for i in 0..gc.len() {
                gc[i] *= scale;
                qc[i] *= scale;
            }
            start = end;
        }
    }

    /// Scalar reference for [`super::square_into`].
    pub fn square_into(x: &[f32], out: &mut [f32]) {
        check_len(x.len(), out.len(), "square_into");
        let d = out.len();
        let x = &x[..d];
        for i in 0..d {
            out[i] = x[i] * x[i];
        }
    }

    /// Scalar reference for [`super::add_assign`].
    pub fn add_assign(acc: &mut [f32], x: &[f32]) {
        check_len(acc.len(), x.len(), "add_assign");
        let d = acc.len();
        let x = &x[..d];
        for i in 0..d {
            acc[i] += x[i];
        }
    }

    /// Scalar reference for [`super::scale_assign`].
    pub fn scale_assign(acc: &mut [f32], s: f32) {
        for v in acc.iter_mut() {
            *v *= s;
        }
    }

    /// Scalar reference for [`super::axpy`].
    pub fn axpy(acc: &mut [f32], s: f32, x: &[f32]) {
        check_len(acc.len(), x.len(), "axpy");
        let d = acc.len();
        let x = &x[..d];
        for i in 0..d {
            acc[i] += s * x[i];
        }
    }

    /// Scalar reference for [`super::sq_accumulate`].
    pub fn sq_accumulate(acc: &mut [f32], g: &[f32]) {
        check_len(acc.len(), g.len(), "sq_accumulate");
        let d = acc.len();
        let g = &g[..d];
        for i in 0..d {
            acc[i] += g[i] * g[i];
        }
    }

    /// Scalar reference for [`super::sgd_step`].
    pub fn sgd_step(x: &mut [f32], g: &[f32], lr: f32) {
        check_len(x.len(), g.len(), "sgd_step");
        let d = x.len();
        let g = &g[..d];
        for i in 0..d {
            x[i] -= lr * g[i];
        }
    }

    /// Scalar reference for [`super::sgd_update_sq`] — the scalar form of
    /// the fixed 8-lane tree (element `i` feeds lane `i mod 8`).
    pub fn sgd_update_sq(g: &[f32], lr: f32) -> f64 {
        let mut lanes = [0.0f64; LANES];
        for (i, &gv) in g.iter().enumerate() {
            let u = (lr * gv) as f64;
            lanes[i % LANES] += u * u;
        }
        fold_tree(&lanes)
    }

    /// Scalar reference for [`super::momentum_step`].
    pub fn momentum_step(x: &mut [f32], m: &mut [f32], g: &[f32], mu: f32, lr: f32) {
        let d = m.len();
        check_len(x.len(), d, "momentum_step");
        check_len(g.len(), d, "momentum_step");
        let x = &mut x[..d];
        let g = &g[..d];
        for i in 0..d {
            let v = mu * m[i] + g[i];
            m[i] = v;
            x[i] -= lr * v;
        }
    }

    /// Scalar reference for [`super::adagrad_step`].
    pub fn adagrad_step(x: &mut [f32], b2: &mut [f32], g: &[f32], gsq: &[f32], lr: f32, eps2: f32) {
        let d = b2.len();
        check_len(x.len(), d, "adagrad_step");
        check_len(g.len(), d, "adagrad_step");
        check_len(gsq.len(), d, "adagrad_step");
        let x = &mut x[..d];
        let g = &g[..d];
        let gsq = &gsq[..d];
        for i in 0..d {
            let b2i = b2[i] + gsq[i];
            b2[i] = b2i;
            x[i] -= lr * g[i] / (b2i + eps2).sqrt();
        }
    }

    /// Scalar reference for [`super::adaalter_step`].
    pub fn adaalter_step(
        x: &mut [f32],
        b2: &mut [f32],
        g: &[f32],
        gsq: &[f32],
        lr: f32,
        eps2: f32,
    ) {
        let d = b2.len();
        check_len(x.len(), d, "adaalter_step");
        check_len(g.len(), d, "adaalter_step");
        check_len(gsq.len(), d, "adaalter_step");
        let x = &mut x[..d];
        let g = &g[..d];
        let gsq = &gsq[..d];
        for i in 0..d {
            let stale = b2[i];
            x[i] -= lr * g[i] / (stale + eps2).sqrt();
            b2[i] = stale + gsq[i];
        }
    }

    /// Scalar reference for [`super::local_adaalter_step`] — elementwise
    /// streams as in the seed; `‖Δx‖²` via the scalar fixed 8-lane tree.
    pub fn local_adaalter_step(
        x: &mut [f32],
        b2_sync: &[f32],
        acc: &mut [f32],
        g: &[f32],
        lr: f32,
        denom_add: f32,
    ) -> f64 {
        let d = x.len();
        check_len(b2_sync.len(), d, "local_adaalter_step");
        check_len(acc.len(), d, "local_adaalter_step");
        check_len(g.len(), d, "local_adaalter_step");
        let b2 = &b2_sync[..d];
        let acc = &mut acc[..d];
        let g = &g[..d];
        let mut lanes = [0.0f64; LANES];
        for i in 0..d {
            let gi = g[i];
            let du = lr * gi / (b2[i] + denom_add).sqrt();
            x[i] -= du;
            acc[i] += gi * gi;
            lanes[i % LANES] += du as f64 * du as f64;
        }
        fold_tree(&lanes)
    }

    /// Scalar reference for [`super::delta_encode`].
    pub fn delta_encode(src: &[f32], base: &[f32], out: &mut [f32]) {
        let d = out.len();
        check_len(src.len(), d, "delta_encode");
        check_len(base.len(), d, "delta_encode");
        let src = &src[..d];
        let base = &base[..d];
        for i in 0..d {
            out[i] = src[i] - base[i];
        }
    }

    /// Scalar reference for [`super::delta_decode`].
    pub fn delta_decode(base: &[f32], delta: &[f32], out: &mut [f32]) {
        let d = out.len();
        check_len(base.len(), d, "delta_decode");
        check_len(delta.len(), d, "delta_decode");
        let base = &base[..d];
        let delta = &delta[..d];
        for i in 0..d {
            out[i] = base[i] + delta[i];
        }
    }

    /// Scalar reference for [`super::delta_decode_clamped`].
    pub fn delta_decode_clamped(base: &[f32], delta: &[f32], out: &mut [f32]) {
        let d = out.len();
        check_len(base.len(), d, "delta_decode_clamped");
        check_len(delta.len(), d, "delta_decode_clamped");
        let base = &base[..d];
        let delta = &delta[..d];
        for i in 0..d {
            out[i] = (base[i] + delta[i]).max(0.0);
        }
    }
}

/// `out[i] = mean_k inputs[k][i]` — the Alg. 4 lines 11–12 synchronization
/// average. `inputs` must be non-empty and same-length. Generic over the
/// row type so both `&[&[f32]]` (leader gathers) and `&[Vec<f32>]`
/// (pooled staging buffers) average without building a borrow vector.
pub fn mean_into<S: AsRef<[f32]>>(inputs: &[S], out: &mut [f32]) {
    if simd::enabled() {
        simd::mean_into(inputs, out)
    } else {
        serial::mean_into(inputs, out)
    }
}

/// Simultaneously `avg_g = (1/n) Σ_i g_i` and `avg_gsq = (1/n) Σ_i g_i∘g_i`
/// — one pass over the inputs, both outputs written per cache line
/// (Alg. 3 needs both: line 5 + line 7).
pub fn mean_and_squares_into<S: AsRef<[f32]>>(
    inputs: &[S],
    avg_g: &mut [f32],
    avg_gsq: &mut [f32],
) {
    if simd::enabled() {
        simd::mean_and_squares_into(inputs, avg_g, avg_gsq)
    } else {
        serial::mean_and_squares_into(inputs, avg_g, avg_gsq)
    }
}

/// `out[i] = x[i]²` — AdaGrad's Alg. 1 line 6 squares the *averaged*
/// gradient.
pub fn square_into(x: &[f32], out: &mut [f32]) {
    if simd::enabled() {
        simd::square_into(x, out)
    } else {
        serial::square_into(x, out)
    }
}

/// In-place `acc += x`.
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    if simd::enabled() {
        simd::add_assign(acc, x)
    } else {
        serial::add_assign(acc, x)
    }
}

/// In-place `acc *= s` (scaled accumulate's epilogue).
pub fn scale_assign(acc: &mut [f32], s: f32) {
    if simd::enabled() {
        simd::scale_assign(acc, s)
    } else {
        serial::scale_assign(acc, s)
    }
}

/// In-place `acc += s * x` (axpy).
pub fn axpy(acc: &mut [f32], s: f32, x: &[f32]) {
    if simd::enabled() {
        simd::axpy(acc, s, x)
    } else {
        serial::axpy(acc, s, x)
    }
}

/// In-place `acc += g ∘ g` (squared-gradient accumulate, Alg. 1/3 line 6/7
/// building block).
pub fn sq_accumulate(acc: &mut [f32], g: &[f32]) {
    if simd::enabled() {
        simd::sq_accumulate(acc, g)
    } else {
        serial::sq_accumulate(acc, g)
    }
}

/// Plain SGD update: `x ← x − lr·g`.
pub fn sgd_step(x: &mut [f32], g: &[f32], lr: f32) {
    if simd::enabled() {
        simd::sgd_step(x, g, lr)
    } else {
        serial::sgd_step(x, g, lr)
    }
}

/// `‖lr·g‖²` in f64 — the SGD drift proxy, computed exactly as the local
/// step would apply it (`Δx = −lr·g`), without touching the update.
/// Accumulated via the fixed 8-lane tree (mode-independent bits).
pub fn sgd_update_sq(g: &[f32], lr: f32) -> f64 {
    if simd::enabled() {
        simd::sgd_update_sq(g, lr)
    } else {
        serial::sgd_update_sq(g, lr)
    }
}

/// Heavy-ball momentum update: `m ← μ·m + g; x ← x − lr·m`, fused.
pub fn momentum_step(x: &mut [f32], m: &mut [f32], g: &[f32], mu: f32, lr: f32) {
    if simd::enabled() {
        simd::momentum_step(x, m, g, mu, lr)
    } else {
        serial::momentum_step(x, m, g, mu, lr)
    }
}

/// AdaGrad step (Alg. 1 lines 6–7), fused single pass: accumulate the
/// squared averaged gradient FIRST, update with the fresh denominator.
pub fn adagrad_step(x: &mut [f32], b2: &mut [f32], g: &[f32], gsq: &[f32], lr: f32, eps2: f32) {
    if simd::enabled() {
        simd::adagrad_step(x, b2, g, gsq, lr, eps2)
    } else {
        serial::adagrad_step(x, b2, g, gsq, lr, eps2)
    }
}

/// AdaAlter step (Alg. 3 lines 6–7), fused single pass: update with the
/// STALE denominator, then fold the fresh squares in.
pub fn adaalter_step(x: &mut [f32], b2: &mut [f32], g: &[f32], gsq: &[f32], lr: f32, eps2: f32) {
    if simd::enabled() {
        simd::adaalter_step(x, b2, g, gsq, lr, eps2)
    } else {
        serial::adaalter_step(x, b2, g, gsq, lr, eps2)
    }
}

/// Local AdaAlter step (Alg. 4 lines 5–7), fused single pass over the
/// three streams: `x ← x − lr·g/√(b2_sync + denom_add)`, `acc += g∘g`.
/// Returns `‖Δx‖²` (f64), the drift proxy adaptive sync policies consume,
/// accumulated via the fixed 8-lane tree (mode-independent bits).
pub fn local_adaalter_step(
    x: &mut [f32],
    b2_sync: &[f32],
    acc: &mut [f32],
    g: &[f32],
    lr: f32,
    denom_add: f32,
) -> f64 {
    if simd::enabled() {
        simd::local_adaalter_step(x, b2_sync, acc, g, lr, denom_add)
    } else {
        serial::local_adaalter_step(x, b2_sync, acc, g, lr, denom_add)
    }
}

/// Delta encode: `out[i] = src[i] − base[i]` (the quantity compressed
/// local-SGD actually ships; DESIGN.md §3).
pub fn delta_encode(src: &[f32], base: &[f32], out: &mut [f32]) {
    if simd::enabled() {
        simd::delta_encode(src, base, out)
    } else {
        serial::delta_encode(src, base, out)
    }
}

/// Delta decode: `out[i] = base[i] + delta[i]`.
pub fn delta_decode(base: &[f32], delta: &[f32], out: &mut [f32]) {
    if simd::enabled() {
        simd::delta_decode(base, delta, out)
    } else {
        serial::delta_decode(base, delta, out)
    }
}

/// Delta decode clamped at zero: `out[i] = max(base[i] + delta[i], 0)` —
/// the denominator install after a lossy roundtrip (the `t'·ε²`
/// placeholder keeps the installed denominator strictly positive, so
/// training stays finite).
pub fn delta_decode_clamped(base: &[f32], delta: &[f32], out: &mut [f32]) {
    if simd::enabled() {
        simd::delta_decode_clamped(base, delta, out)
    } else {
        serial::delta_decode_clamped(base, delta, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::util::simd::{fold_tree, LANES};

    fn randv(seed: u64, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        Rng::new(seed).fill_normal(&mut v, 1.0);
        v
    }

    /// The bitwise contract: the chunked mean equals the naive
    /// sum-then-scale per-element recurrence EXACTLY (same op order).
    #[test]
    fn mean_into_bitwise_matches_naive() {
        prop::check("mean_into bitwise", 40, |g| {
            let d = g.usize_in(1..3000);
            let n = g.usize_in(1..6);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(d..d + 1, -3.0..3.0)).collect();
            let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
            let mut out = vec![0.0f32; d];
            mean_into(&refs, &mut out);
            // Naive: out = in0; out += in_k; out *= 1/n — element-wise.
            let scale = 1.0 / n as f32;
            for i in 0..d {
                let mut acc = rows[0][i];
                for row in &rows[1..] {
                    acc += row[i];
                }
                acc *= scale;
                prop::assert_that(
                    out[i].to_bits() == acc.to_bits(),
                    format!("mean_into[{i}] not bitwise: {} vs {acc}", out[i]),
                )?;
            }
            // The Vec-row overload runs the same kernel.
            let mut out2 = vec![0.0f32; d];
            mean_into(&rows, &mut out2);
            prop::assert_that(out == out2, "Vec-row overload diverged")
        });
    }

    #[test]
    fn mean_and_squares_bitwise_matches_naive() {
        prop::check("mean_and_squares bitwise", 30, |g| {
            let d = g.usize_in(1..2500);
            let n = g.usize_in(1..6);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(d..d + 1, -3.0..3.0)).collect();
            let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
            let mut avg_g = vec![0.0f32; d];
            let mut avg_gsq = vec![0.0f32; d];
            mean_and_squares_into(&refs, &mut avg_g, &mut avg_gsq);
            let scale = 1.0 / n as f32;
            for i in 0..d {
                let mut sg = rows[0][i];
                let mut sq = rows[0][i] * rows[0][i];
                for row in &rows[1..] {
                    let v = row[i];
                    sg += v;
                    sq += v * v;
                }
                sg *= scale;
                sq *= scale;
                prop::assert_that(
                    avg_g[i].to_bits() == sg.to_bits() && avg_gsq[i].to_bits() == sq.to_bits(),
                    format!("joint mean[{i}] not bitwise"),
                )?;
            }
            Ok(())
        });
    }

    /// The fixed-tree reference for the drift reductions, written as an
    /// independent loop (the hand oracle both implementations must hit).
    fn tree_sum(terms: impl Iterator<Item = f64>) -> f64 {
        let mut lanes = [0.0f64; LANES];
        for (i, t) in terms.enumerate() {
            lanes[i % LANES] += t;
        }
        fold_tree(&lanes)
    }

    #[test]
    fn elementwise_kernels_match_hand_loops() {
        let d = 37;
        let g = randv(1, d);
        let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();

        // adagrad_step vs the original fused loop.
        let mut x = randv(2, d);
        let mut b2 = vec![1.0f32; d];
        let (mut xe, mut b2e) = (x.clone(), b2.clone());
        adagrad_step(&mut x, &mut b2, &g, &gsq, 0.3, 1.0);
        for i in 0..d {
            let b2i = b2e[i] + gsq[i];
            b2e[i] = b2i;
            xe[i] -= 0.3 * g[i] / (b2i + 1.0).sqrt();
        }
        assert_eq!(x, xe);
        assert_eq!(b2, b2e);

        // adaalter_step vs the original fused loop.
        let mut x = randv(3, d);
        let mut b2 = vec![1.0f32; d];
        let (mut xe, mut b2e) = (x.clone(), b2.clone());
        adaalter_step(&mut x, &mut b2, &g, &gsq, 0.3, 1.0);
        for i in 0..d {
            let stale = b2e[i];
            xe[i] -= 0.3 * g[i] / (stale + 1.0).sqrt();
            b2e[i] = stale + gsq[i];
        }
        assert_eq!(x, xe);
        assert_eq!(b2, b2e);

        // local_adaalter_step vs the original three-stream loop; the f64
        // drift reduction vs the fixed-tree hand oracle.
        let mut x = randv(4, d);
        let b2s = vec![1.0f32; d];
        let mut acc = vec![1.0f32; d];
        let (mut xe, mut acce) = (x.clone(), acc.clone());
        let upd = local_adaalter_step(&mut x, &b2s, &mut acc, &g, 0.5, 2.0);
        for i in 0..d {
            let du = 0.5 * g[i] / (b2s[i] + 2.0).sqrt();
            xe[i] -= du;
            acce[i] += g[i] * g[i];
        }
        let upde = tree_sum((0..d).map(|i| {
            let du = 0.5 * g[i] / (b2s[i] + 2.0).sqrt();
            du as f64 * du as f64
        }));
        assert_eq!(x, xe);
        assert_eq!(acc, acce);
        assert_eq!(upd.to_bits(), upde.to_bits());

        // sgd_step + sgd_update_sq (same tree oracle).
        let mut x = randv(5, d);
        let mut xe = x.clone();
        let upd = sgd_update_sq(&g, 0.1);
        sgd_step(&mut x, &g, 0.1);
        for i in 0..d {
            xe[i] -= 0.1 * g[i];
        }
        let upde = tree_sum(g.iter().map(|&gv| {
            let u = (0.1 * gv) as f64;
            u * u
        }));
        assert_eq!(x, xe);
        assert_eq!(upd.to_bits(), upde.to_bits());
    }

    /// The tentpole pin: serial and SIMD implementations are bit-identical
    /// for EVERY kernel at every width — each remainder length 0..LANES,
    /// the lane boundary itself, and widths straddling the MEAN_CHUNK
    /// cache-block edge.
    #[test]
    fn serial_and_simd_agree_bitwise_for_all_widths() {
        let mut widths: Vec<usize> = (0..2 * LANES + 1).collect();
        widths.extend([
            61,
            64,
            500,
            MEAN_CHUNK - 1,
            MEAN_CHUNK,
            MEAN_CHUNK + 1,
            MEAN_CHUNK + 7,
            2 * MEAN_CHUNK + 3,
        ]);
        for &d in &widths {
            let g = randv(d as u64 + 11, d);
            let base = randv(d as u64 + 12, d);
            let src = randv(d as u64 + 13, d);
            let gsq: Vec<f32> = g.iter().map(|v| v * v).collect();
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

            // mean_into / mean_and_squares_into over 3 rows.
            if d > 0 {
                let rows = [g.clone(), base.clone(), src.clone()];
                let (mut a, mut b) = (vec![0.0f32; d], vec![0.0f32; d]);
                serial::mean_into(&rows, &mut a);
                crate::util::simd::mean_into(&rows, &mut b);
                assert_eq!(bits(&a), bits(&b), "mean_into d={d}");
                let (mut ag, mut aq) = (vec![0.0f32; d], vec![0.0f32; d]);
                let (mut bg, mut bq) = (vec![0.0f32; d], vec![0.0f32; d]);
                serial::mean_and_squares_into(&rows, &mut ag, &mut aq);
                crate::util::simd::mean_and_squares_into(&rows, &mut bg, &mut bq);
                assert_eq!(bits(&ag), bits(&bg), "mean_and_squares g d={d}");
                assert_eq!(bits(&aq), bits(&bq), "mean_and_squares gsq d={d}");
            }

            // Unary / binary elementwise.
            let (mut a, mut b) = (vec![0.0f32; d], vec![0.0f32; d]);
            serial::square_into(&g, &mut a);
            crate::util::simd::square_into(&g, &mut b);
            assert_eq!(bits(&a), bits(&b), "square_into d={d}");

            let (mut a, mut b) = (base.clone(), base.clone());
            serial::add_assign(&mut a, &g);
            crate::util::simd::add_assign(&mut b, &g);
            assert_eq!(bits(&a), bits(&b), "add_assign d={d}");
            serial::scale_assign(&mut a, 0.37);
            crate::util::simd::scale_assign(&mut b, 0.37);
            assert_eq!(bits(&a), bits(&b), "scale_assign d={d}");
            serial::axpy(&mut a, -1.25, &g);
            crate::util::simd::axpy(&mut b, -1.25, &g);
            assert_eq!(bits(&a), bits(&b), "axpy d={d}");
            serial::sq_accumulate(&mut a, &g);
            crate::util::simd::sq_accumulate(&mut b, &g);
            assert_eq!(bits(&a), bits(&b), "sq_accumulate d={d}");
            serial::sgd_step(&mut a, &g, 0.15);
            crate::util::simd::sgd_step(&mut b, &g, 0.15);
            assert_eq!(bits(&a), bits(&b), "sgd_step d={d}");

            // Reductions: identical bits including the lane tree.
            assert_eq!(
                serial::sgd_update_sq(&g, 0.15).to_bits(),
                crate::util::simd::sgd_update_sq(&g, 0.15).to_bits(),
                "sgd_update_sq d={d}"
            );

            // Optimizer steps.
            let (mut xa, mut xb) = (src.clone(), src.clone());
            let (mut ma, mut mb) = (base.clone(), base.clone());
            serial::momentum_step(&mut xa, &mut ma, &g, 0.9, 0.2);
            crate::util::simd::momentum_step(&mut xb, &mut mb, &g, 0.9, 0.2);
            assert_eq!(bits(&xa), bits(&xb), "momentum x d={d}");
            assert_eq!(bits(&ma), bits(&mb), "momentum m d={d}");

            let (mut xa, mut xb) = (src.clone(), src.clone());
            let (mut ba, mut bb) = (vec![1.0f32; d], vec![1.0f32; d]);
            serial::adagrad_step(&mut xa, &mut ba, &g, &gsq, 0.3, 1.0);
            crate::util::simd::adagrad_step(&mut xb, &mut bb, &g, &gsq, 0.3, 1.0);
            assert_eq!(bits(&xa), bits(&xb), "adagrad x d={d}");
            assert_eq!(bits(&ba), bits(&bb), "adagrad b2 d={d}");

            let (mut xa, mut xb) = (src.clone(), src.clone());
            let (mut ba, mut bb) = (vec![1.0f32; d], vec![1.0f32; d]);
            serial::adaalter_step(&mut xa, &mut ba, &g, &gsq, 0.3, 1.0);
            crate::util::simd::adaalter_step(&mut xb, &mut bb, &g, &gsq, 0.3, 1.0);
            assert_eq!(bits(&xa), bits(&xb), "adaalter x d={d}");
            assert_eq!(bits(&ba), bits(&bb), "adaalter b2 d={d}");

            let (mut xa, mut xb) = (src.clone(), src.clone());
            let b2s = vec![1.0f32; d];
            let (mut aa, mut ab) = (vec![1.0f32; d], vec![1.0f32; d]);
            let ua = serial::local_adaalter_step(&mut xa, &b2s, &mut aa, &g, 0.5, 2.0);
            let ub = crate::util::simd::local_adaalter_step(&mut xb, &b2s, &mut ab, &g, 0.5, 2.0);
            assert_eq!(bits(&xa), bits(&xb), "local_adaalter x d={d}");
            assert_eq!(bits(&aa), bits(&ab), "local_adaalter acc d={d}");
            assert_eq!(ua.to_bits(), ub.to_bits(), "local_adaalter upd d={d}");

            // Delta coding.
            let (mut a, mut b) = (vec![0.0f32; d], vec![0.0f32; d]);
            serial::delta_encode(&src, &base, &mut a);
            crate::util::simd::delta_encode(&src, &base, &mut b);
            assert_eq!(bits(&a), bits(&b), "delta_encode d={d}");
            serial::delta_decode(&base, &g, &mut a);
            crate::util::simd::delta_decode(&base, &g, &mut b);
            assert_eq!(bits(&a), bits(&b), "delta_decode d={d}");
            serial::delta_decode_clamped(&base, &g, &mut a);
            crate::util::simd::delta_decode_clamped(&base, &g, &mut b);
            assert_eq!(bits(&a), bits(&b), "delta_decode_clamped d={d}");
        }
    }

    /// Random-shape property pin over the same serial ≡ simd contract
    /// (widths and values the fixed list above doesn't enumerate).
    #[test]
    fn serial_and_simd_agree_bitwise_random_shapes() {
        prop::check("serial ≡ simd bitwise", 60, |gen| {
            let d = gen.usize_in(1..4100);
            let g = gen.vec_f32(d..d + 1, -4.0..4.0);
            let lr = gen.f32_in(0.001..1.5);
            let ua = serial::sgd_update_sq(&g, lr);
            let ub = crate::util::simd::sgd_update_sq(&g, lr);
            prop::assert_that(
                ua.to_bits() == ub.to_bits(),
                format!("sgd_update_sq d={d}: {ua} vs {ub}"),
            )?;
            let b2s = gen.vec_f32(d..d + 1, 0.1..5.0);
            let (mut xa, mut xb) = (g.clone(), g.clone());
            let (mut aa, mut ab) = (b2s.clone(), b2s.clone());
            let ua = serial::local_adaalter_step(&mut xa, &b2s, &mut aa, &g, lr, 0.5);
            let ub = crate::util::simd::local_adaalter_step(&mut xb, &b2s, &mut ab, &g, lr, 0.5);
            prop::assert_that(
                ua.to_bits() == ub.to_bits(),
                format!("local_adaalter upd d={d}"),
            )?;
            for i in 0..d {
                prop::assert_that(
                    xa[i].to_bits() == xb[i].to_bits() && aa[i].to_bits() == ab[i].to_bits(),
                    format!("local_adaalter streams d={d} i={i}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn delta_roundtrip_and_clamp() {
        let base = randv(7, 64);
        let src = randv(8, 64);
        let mut delta = vec![0.0f32; 64];
        let mut back = vec![0.0f32; 64];
        delta_encode(&src, &base, &mut delta);
        delta_decode(&base, &delta, &mut back);
        for i in 0..64 {
            // f32 subtract-then-add is not exact in general; exact when
            // magnitudes are comparable — just check the identity used.
            assert_eq!(back[i].to_bits(), (base[i] + (src[i] - base[i])).to_bits());
        }
        let base = [1.0f32, 0.5, 0.0];
        let delta = [-2.0f32, 0.25, -0.5];
        let mut out = [9.0f32; 3];
        delta_decode_clamped(&base, &delta, &mut out);
        assert_eq!(out, [0.0, 0.75, 0.0]);
    }

    #[test]
    fn accumulate_kernels() {
        let mut acc = vec![1.0f32; 4];
        axpy(&mut acc, 2.0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(acc, vec![3.0, 5.0, 7.0, 9.0]);
        add_assign(&mut acc, &[1.0; 4]);
        assert_eq!(acc, vec![4.0, 6.0, 8.0, 10.0]);
        scale_assign(&mut acc, 0.5);
        assert_eq!(acc, vec![2.0, 3.0, 4.0, 5.0]);
        let mut sq = vec![1.0f32; 3];
        sq_accumulate(&mut sq, &[2.0, -3.0, 0.0]);
        assert_eq!(sq, vec![5.0, 10.0, 1.0]);
        let mut out = vec![0.0f32; 2];
        square_into(&[3.0, -2.0], &mut out);
        assert_eq!(out, vec![9.0, 4.0]);
    }

    #[test]
    fn momentum_kernel_matches_hand_loop() {
        let mut x = vec![0.0f32; 2];
        let mut m = vec![0.0f32; 2];
        momentum_step(&mut x, &mut m, &[1.0, -1.0], 0.5, 1.0);
        assert_eq!(m, vec![1.0, -1.0]);
        assert_eq!(x, vec![-1.0, 1.0]);
        momentum_step(&mut x, &mut m, &[1.0, -1.0], 0.5, 1.0);
        assert_eq!(m, vec![1.5, -1.5]);
        assert_eq!(x, vec![-2.5, 2.5]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn ragged_inputs_rejected() {
        let mut out = vec![0.0f32; 3];
        mean_into(&[&[1.0f32, 2.0][..]], &mut out);
    }
}
