//! Small self-contained substrates the offline build image forces us to own:
//! PRNG (no `rand`), property-testing harness (no `proptest`), JSON reader
//! (no `serde`), CSV writer, and the SIMD-friendly vector math the hot paths
//! use.

pub mod csv;
pub mod json;
pub mod math;
pub mod prop;
pub mod rng;
pub mod timing;

pub use rng::Rng;
