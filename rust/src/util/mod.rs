//! Small self-contained substrates the offline build image forces us to own:
//! PRNG (no `rand`), property-testing harness (no `proptest`), JSON
//! reader/writer (no `serde`), CSV writer, the shared hot-path kernels and
//! buffer pool (DESIGN.md §7), the explicit SIMD kernel forms and dispatch
//! knob, and the bf16 mixed-precision conversions (DESIGN.md §8).

pub mod csv;
pub mod half;
pub mod json;
pub mod kernels;
pub mod math;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod timing;

pub use rng::Rng;
