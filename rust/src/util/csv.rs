//! Tiny CSV writer for experiment outputs (`results/*.csv`).
//!
//! Benches and examples emit the paper's figures/tables as CSV series; this
//! keeps quoting rules in one place. Reading is not needed (downstream
//! plotting happens outside the repo).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::Result;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

/// Quote a field if it contains a comma, quote or newline.
fn quote(field: &str) -> String {
    if field.contains([',', '"', '\n']) {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(
            out,
            "{}",
            header.iter().map(|h| quote(h)).collect::<Vec<_>>().join(",")
        )?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    /// Write one row of stringified fields. Panics if the column count does
    /// not match the header (catching experiment-harness bugs early).
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        assert_eq!(
            fields.len(),
            self.cols,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.cols
        );
        writeln!(
            self.out,
            "{}",
            fields.iter().map(|f| quote(f)).collect::<Vec<_>>().join(",")
        )?;
        Ok(())
    }

    /// Flush to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Convenience macro: stringify heterogeneous row fields.
#[macro_export]
macro_rules! csv_row {
    ($w:expr, $($field:expr),+ $(,)?) => {
        $w.row(&[$(format!("{}", $field)),+])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("adaalter_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x,y".into()]).unwrap();
            csv_row!(w, 2.5, "plain").unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n2.5,plain\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "csv row has 1 fields")]
    fn wrong_arity_panics() {
        let dir = std::env::temp_dir().join("adaalter_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }

    #[test]
    fn quoting_rules() {
        assert_eq!(quote("plain"), "plain");
        assert_eq!(quote("a,b"), "\"a,b\"");
        assert_eq!(quote("say \"hi\""), "\"say \"\"hi\"\"\"");
    }
}
