//! Hand-rolled property-testing mini-framework.
//!
//! The offline image has no `proptest`, so coordinator invariants (routing,
//! batching, sync-state — DESIGN.md §12) are checked with this harness: a
//! seeded generator API + a runner that, on failure, re-runs with a reduced
//! "size" parameter to report the smallest failing scale it can find
//! (coarse-grained shrinking: sizes shrink, seeds are reported verbatim so
//! every failure is reproducible from the printed seed).
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the xla rpath link flags on
//! # // this image (libstdc++ from /opt/xla_extension), so compile-only.
//! use adaalter::util::prop::{self, Gen};
//! prop::check("mean within bounds", 100, |g| {
//!     let xs = g.vec_f32(1..100, -10.0..10.0);
//!     let m = xs.iter().sum::<f32>() / xs.len() as f32;
//!     prop::assert_that(m >= -10.0 && m <= 10.0, "mean out of range")
//! });
//! ```

use std::ops::Range;

use super::rng::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assertion helper producing a `PropResult`.
pub fn assert_that(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("{what}: index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Seeded test-case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Size hint in `[0.0, 1.0]`; shrinking re-runs with smaller sizes.
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    /// Direct access to the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// usize in `range`, biased smaller as `size` shrinks.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        let span = (range.end - range.start).max(1);
        let scaled = ((span as f64 - 1.0) * self.size).round() as usize + 1;
        range.start + self.rng.below(scaled.min(span) as u64) as usize
    }

    /// u64 in `range`.
    pub fn u64_in(&mut self, range: Range<u64>) -> u64 {
        self.rng.range(range.start, range.end)
    }

    /// f32 uniform in `range`.
    pub fn f32_in(&mut self, range: Range<f32>) -> f32 {
        range.start + self.rng.f32() * (range.end - range.start)
    }

    /// f64 uniform in `range`.
    pub fn f64_in(&mut self, range: Range<f64>) -> f64 {
        range.start + self.rng.f64() * (range.end - range.start)
    }

    /// Standard-normal f32 vector of generated length.
    pub fn vec_normal(&mut self, len: Range<usize>, sigma: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        let mut v = vec![0.0f32; n];
        self.rng.fill_normal(&mut v, sigma);
        v
    }

    /// Uniform f32 vector of generated length.
    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Pick one of the provided choices.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }
}

/// Run `cases` random evaluations of `prop`. Panics (test failure) on the
/// first failing case, after attempting size-shrinking, with a message that
/// contains the seed needed to replay the exact case.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    // Base seed: stable per property name so failures replay across runs,
    // but different properties explore different streams.
    let base = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut Gen::new(seed, 1.0)) {
            // Coarse shrink: retry the same seed at smaller sizes and report
            // the smallest size that still fails.
            let mut smallest = (1.0, msg.clone());
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                if let Err(m) = prop(&mut Gen::new(seed, size)) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, \
                 smallest failing size {:.2}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Replay a single case by seed — used to debug a failure printed by
/// [`check`].
pub fn replay<F>(seed: u64, size: f64, prop: F) -> PropResult
where
    F: Fn(&mut Gen) -> PropResult,
{
    prop(&mut Gen::new(seed, size))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // Count via a cell: check() takes Fn, so use interior mutability.
        let counter = std::cell::Cell::new(0u64);
        check("always true", 50, |g| {
            counter.set(counter.get() + 1);
            let v = g.vec_f32(1..10, 0.0..1.0);
            assert_that(!v.is_empty(), "empty")
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property \"always false\" failed")]
    fn failing_property_panics_with_seed() {
        check("always false", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 200, |g| {
            let n = g.usize_in(3..17);
            assert_that((3..17).contains(&n), format!("usize {n}"))?;
            let x = g.f32_in(-2.0..5.0);
            assert_that((-2.0..5.0).contains(&x), format!("f32 {x}"))?;
            let u = g.u64_in(10..20);
            assert_that((10..20).contains(&u), format!("u64 {u}"))
        });
    }

    #[test]
    fn vec_lengths_in_range() {
        check("vec len", 100, |g| {
            let v = g.vec_normal(1..64, 1.0);
            assert_that((1..64).contains(&v.len()), "len")
        });
    }

    #[test]
    fn assert_close_catches_divergence() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-7], 1e-5, "x").is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5, "x").is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5, "x").is_err());
    }

    #[test]
    fn replay_reproduces() {
        // A property that records what it saw, keyed by seed.
        let prop = |g: &mut Gen| -> PropResult {
            let v = g.vec_f32(1..100, 0.0..1.0);
            if v.len() > 90 {
                Err(format!("len {}", v.len()))
            } else {
                Ok(())
            }
        };
        // Find a failing seed manually, then confirm replay fails the same way.
        for seed in 0..5_000u64 {
            if replay(seed, 1.0, prop).is_err() {
                assert!(replay(seed, 1.0, prop).is_err());
                return;
            }
        }
        panic!("no failing seed found in range");
    }
}
