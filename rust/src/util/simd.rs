//! Explicit SIMD hot-path kernels + the `[exec] simd` dispatch knob
//! (DESIGN.md §8).
//!
//! Every kernel in [`crate::util::kernels`] has two implementations: the
//! scalar reference in `kernels::serial` (the bitwise oracle) and the
//! lane-structured version here. The public `kernels::*` entry points
//! dispatch between them via [`enabled`]. The vector forms process fixed
//! [`LANES`]-wide chunks (`chunks_exact` — the shape every autovectorizer
//! turns into packed vector instructions without `unsafe` or
//! target-feature gates) plus a scalar remainder loop.
//!
//! **Bitwise contract.** Elementwise kernels perform the identical
//! per-element arithmetic in the identical per-element order, so chunking
//! only regroups loop iterations: serial ≡ simd bit-for-bit. Reductions
//! (`sgd_update_sq`, `local_adaalter_step`'s `‖Δx‖²`) accumulate into a
//! fixed 8-lane f64 tree — element `i` feeds lane `i mod 8`, the
//! remainder tail continues the same mapping, and the lanes fold in one
//! fixed bracketing ([`fold_tree`]). The serial oracle computes the *same
//! scalar tree*, so reductions are also bit-identical across modes, and
//! every kernel output is independent of the dispatch decision. The
//! kernel property pins assert serial ≡ simd for all widths including
//! every remainder length.
//!
//! Why the vector forms are faster even with identical arithmetic: the
//! reduction oracle in the seed carried one sequential f64 accumulator —
//! a loop-carried dependence that bounds throughput at one element per
//! add latency. Eight independent lanes break the chain (8-way ILP /
//! one vector accumulator), and the fixed-width inner loops give the
//! compiler exact trip counts to unroll. See
//! `benches/micro_hot_paths.rs` serial-vs-simd rows.
//!
//! **Dispatch mode** is process-global: `[exec] simd = "auto" | "on" |
//! "off"`, installed by the trainer at run start ([`set_mode`] —
//! last-trainer-wins, like thread-pool sizing). `auto` resolves once per
//! process from the `ADAALTER_SIMD` environment variable (`off`/`0`/
//! `false` disable; anything else, including unset, enables — CI uses
//! this to force both modes). Because serial ≡ simd bitwise, the mode is
//! a pure wall-clock knob: flipping it can never change a result.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::config::ExecConfig;
use crate::error::{Error, Result};
use crate::util::kernels::{check_len, MEAN_CHUNK};

/// Lanes per vector chunk: 8 × f32 = 256 bits (one AVX2 register; two
/// NEON registers), and 8 × f64 accumulator lanes for the reductions.
pub const LANES: usize = 8;

/// The `[exec] simd` dispatch mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Resolve from the `ADAALTER_SIMD` environment variable (default on).
    Auto,
    /// Always take the lane-structured kernels.
    On,
    /// Always take the scalar serial kernels.
    Off,
}

impl SimdMode {
    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> Option<SimdMode> {
        match s {
            "auto" => Some(SimdMode::Auto),
            "on" => Some(SimdMode::On),
            "off" => Some(SimdMode::Off),
            _ => None,
        }
    }

    /// Resolve from an `[exec]` section, with the config-error wording
    /// shared by [`ExecConfig::validate`] and the trainer.
    pub fn from_config(cfg: &ExecConfig) -> Result<SimdMode> {
        SimdMode::parse(&cfg.simd).ok_or_else(|| {
            Error::Config(format!(
                "exec.simd must be one of \"auto\", \"on\", \"off\", got {:?}",
                cfg.simd
            ))
        })
    }

    /// Config-file spelling.
    pub fn label(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::On => "on",
            SimdMode::Off => "off",
        }
    }
}

const MODE_AUTO: u8 = 0;
const MODE_ON: u8 = 1;
const MODE_OFF: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_AUTO);
static AUTO_DEFAULT: OnceLock<bool> = OnceLock::new();

fn auto_enabled() -> bool {
    *AUTO_DEFAULT.get_or_init(|| {
        !matches!(
            std::env::var("ADAALTER_SIMD").as_deref(),
            Ok("off") | Ok("0") | Ok("false")
        )
    })
}

/// Install the process-global dispatch mode (trainer start; last wins).
pub fn set_mode(mode: SimdMode) {
    let v = match mode {
        SimdMode::Auto => MODE_AUTO,
        SimdMode::On => MODE_ON,
        SimdMode::Off => MODE_OFF,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// The currently-installed dispatch mode.
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        MODE_ON => SimdMode::On,
        MODE_OFF => SimdMode::Off,
        _ => SimdMode::Auto,
    }
}

/// Should [`crate::util::kernels`] dispatch to the lane kernels?
#[inline]
pub fn enabled() -> bool {
    match MODE.load(Ordering::Relaxed) {
        MODE_ON => true,
        MODE_OFF => false,
        _ => auto_enabled(),
    }
}

/// The fixed reduction fold: lanes pair across the half-stride first
/// (`0+4`, `2+6`, `1+5`, `3+7`), then brackets combine — one immutable
/// bracketing shared by the serial oracle and the lane kernels.
#[inline]
pub fn fold_tree(l: &[f64; LANES]) -> f64 {
    ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]))
}

// ---------------------------------------------------------------------------
// Lane-structured kernels. Signatures and per-element arithmetic mirror
// `kernels::serial` exactly; see the module doc for the bitwise contract.
// ---------------------------------------------------------------------------

/// Lane form of [`crate::util::kernels::serial::mean_into`].
pub fn mean_into<S: AsRef<[f32]>>(inputs: &[S], out: &mut [f32]) {
    assert!(!inputs.is_empty(), "mean_into: no inputs");
    let d = out.len();
    for v in inputs {
        check_len(v.as_ref().len(), d, "mean_into");
    }
    let scale = 1.0 / inputs.len() as f32;
    let mut start = 0;
    while start < d {
        let end = (start + MEAN_CHUNK).min(d);
        let out_c = &mut out[start..end];
        out_c.copy_from_slice(&inputs[0].as_ref()[start..end]);
        for v in &inputs[1..] {
            add_assign(out_c, &v.as_ref()[start..end]);
        }
        scale_assign(out_c, scale);
        start = end;
    }
}

/// Lane form of [`crate::util::kernels::serial::mean_and_squares_into`].
pub fn mean_and_squares_into<S: AsRef<[f32]>>(
    inputs: &[S],
    avg_g: &mut [f32],
    avg_gsq: &mut [f32],
) {
    assert!(!inputs.is_empty(), "mean_and_squares_into: no inputs");
    let d = avg_g.len();
    check_len(avg_gsq.len(), d, "mean_and_squares_into");
    for g in inputs {
        check_len(g.as_ref().len(), d, "mean_and_squares_into");
    }
    let scale = 1.0 / inputs.len() as f32;
    let mut start = 0;
    while start < d {
        let end = (start + MEAN_CHUNK).min(d);
        let (gc, qc) = (&mut avg_g[start..end], &mut avg_gsq[start..end]);
        let first = &inputs[0].as_ref()[start..end];
        {
            let mut gi = gc.chunks_exact_mut(LANES);
            let mut qi = qc.chunks_exact_mut(LANES);
            let mut fi = first.chunks_exact(LANES);
            for ((gv, qv), fv) in (&mut gi).zip(&mut qi).zip(&mut fi) {
                for j in 0..LANES {
                    let v = fv[j];
                    gv[j] = v;
                    qv[j] = v * v;
                }
            }
            for ((gv, qv), &v) in
                gi.into_remainder().iter_mut().zip(qi.into_remainder()).zip(fi.remainder())
            {
                *gv = v;
                *qv = v * v;
            }
        }
        for g in &inputs[1..] {
            let g = &g.as_ref()[start..end];
            let mut gi = gc.chunks_exact_mut(LANES);
            let mut qi = qc.chunks_exact_mut(LANES);
            let mut vi = g.chunks_exact(LANES);
            for ((gv, qv), vv) in (&mut gi).zip(&mut qi).zip(&mut vi) {
                for j in 0..LANES {
                    let v = vv[j];
                    gv[j] += v;
                    qv[j] += v * v;
                }
            }
            for ((gv, qv), &v) in
                gi.into_remainder().iter_mut().zip(qi.into_remainder()).zip(vi.remainder())
            {
                *gv += v;
                *qv += v * v;
            }
        }
        scale_assign(gc, scale);
        scale_assign(qc, scale);
        start = end;
    }
}

/// Lane form of [`crate::util::kernels::serial::square_into`].
pub fn square_into(x: &[f32], out: &mut [f32]) {
    check_len(x.len(), out.len(), "square_into");
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (o, xv) in (&mut oc).zip(&mut xc) {
        for j in 0..LANES {
            o[j] = xv[j] * xv[j];
        }
    }
    for (o, &xv) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o = xv * xv;
    }
}

/// Lane form of [`crate::util::kernels::serial::add_assign`].
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    check_len(acc.len(), x.len(), "add_assign");
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (a, xv) in (&mut ac).zip(&mut xc) {
        for j in 0..LANES {
            a[j] += xv[j];
        }
    }
    for (a, &xv) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += xv;
    }
}

/// Lane form of [`crate::util::kernels::serial::scale_assign`].
pub fn scale_assign(acc: &mut [f32], s: f32) {
    let mut ac = acc.chunks_exact_mut(LANES);
    for a in &mut ac {
        for v in a.iter_mut() {
            *v *= s;
        }
    }
    for a in ac.into_remainder() {
        *a *= s;
    }
}

/// Lane form of [`crate::util::kernels::serial::axpy`].
pub fn axpy(acc: &mut [f32], s: f32, x: &[f32]) {
    check_len(acc.len(), x.len(), "axpy");
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (a, xv) in (&mut ac).zip(&mut xc) {
        for j in 0..LANES {
            a[j] += s * xv[j];
        }
    }
    for (a, &xv) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += s * xv;
    }
}

/// Lane form of [`crate::util::kernels::serial::sq_accumulate`].
pub fn sq_accumulate(acc: &mut [f32], g: &[f32]) {
    check_len(acc.len(), g.len(), "sq_accumulate");
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut gc = g.chunks_exact(LANES);
    for (a, gv) in (&mut ac).zip(&mut gc) {
        for j in 0..LANES {
            a[j] += gv[j] * gv[j];
        }
    }
    for (a, &gv) in ac.into_remainder().iter_mut().zip(gc.remainder()) {
        *a += gv * gv;
    }
}

/// Lane form of [`crate::util::kernels::serial::sgd_step`].
pub fn sgd_step(x: &mut [f32], g: &[f32], lr: f32) {
    check_len(x.len(), g.len(), "sgd_step");
    let mut xc = x.chunks_exact_mut(LANES);
    let mut gc = g.chunks_exact(LANES);
    for (xv, gv) in (&mut xc).zip(&mut gc) {
        for j in 0..LANES {
            xv[j] -= lr * gv[j];
        }
    }
    for (xv, &gv) in xc.into_remainder().iter_mut().zip(gc.remainder()) {
        *xv -= lr * gv;
    }
}

/// Lane form of [`crate::util::kernels::serial::sgd_update_sq`]: eight
/// independent f64 accumulator lanes (element `i` → lane `i mod 8`),
/// folded by [`fold_tree`]. Bit-identical to the serial scalar tree.
pub fn sgd_update_sq(g: &[f32], lr: f32) -> f64 {
    let mut lanes = [0.0f64; LANES];
    let mut gc = g.chunks_exact(LANES);
    for gv in &mut gc {
        for j in 0..LANES {
            let u = (lr * gv[j]) as f64;
            lanes[j] += u * u;
        }
    }
    for (j, &gv) in gc.remainder().iter().enumerate() {
        let u = (lr * gv) as f64;
        lanes[j] += u * u;
    }
    fold_tree(&lanes)
}

/// Lane form of [`crate::util::kernels::serial::momentum_step`].
pub fn momentum_step(x: &mut [f32], m: &mut [f32], g: &[f32], mu: f32, lr: f32) {
    let d = m.len();
    check_len(x.len(), d, "momentum_step");
    check_len(g.len(), d, "momentum_step");
    let mut xc = x[..d].chunks_exact_mut(LANES);
    let mut mc = m.chunks_exact_mut(LANES);
    let mut gc = g[..d].chunks_exact(LANES);
    for ((xv, mv), gv) in (&mut xc).zip(&mut mc).zip(&mut gc) {
        for j in 0..LANES {
            let v = mu * mv[j] + gv[j];
            mv[j] = v;
            xv[j] -= lr * v;
        }
    }
    for ((xv, mv), &gv) in
        xc.into_remainder().iter_mut().zip(mc.into_remainder()).zip(gc.remainder())
    {
        let v = mu * *mv + gv;
        *mv = v;
        *xv -= lr * v;
    }
}

/// Lane form of [`crate::util::kernels::serial::adagrad_step`].
pub fn adagrad_step(x: &mut [f32], b2: &mut [f32], g: &[f32], gsq: &[f32], lr: f32, eps2: f32) {
    let d = b2.len();
    check_len(x.len(), d, "adagrad_step");
    check_len(g.len(), d, "adagrad_step");
    check_len(gsq.len(), d, "adagrad_step");
    let mut xc = x[..d].chunks_exact_mut(LANES);
    let mut bc = b2.chunks_exact_mut(LANES);
    let mut gc = g[..d].chunks_exact(LANES);
    let mut qc = gsq[..d].chunks_exact(LANES);
    for (((xv, bv), gv), qv) in (&mut xc).zip(&mut bc).zip(&mut gc).zip(&mut qc) {
        for j in 0..LANES {
            let b2i = bv[j] + qv[j];
            bv[j] = b2i;
            xv[j] -= lr * gv[j] / (b2i + eps2).sqrt();
        }
    }
    for (((xv, bv), &gv), &qv) in xc
        .into_remainder()
        .iter_mut()
        .zip(bc.into_remainder())
        .zip(gc.remainder())
        .zip(qc.remainder())
    {
        let b2i = *bv + qv;
        *bv = b2i;
        *xv -= lr * gv / (b2i + eps2).sqrt();
    }
}

/// Lane form of [`crate::util::kernels::serial::adaalter_step`].
pub fn adaalter_step(x: &mut [f32], b2: &mut [f32], g: &[f32], gsq: &[f32], lr: f32, eps2: f32) {
    let d = b2.len();
    check_len(x.len(), d, "adaalter_step");
    check_len(g.len(), d, "adaalter_step");
    check_len(gsq.len(), d, "adaalter_step");
    let mut xc = x[..d].chunks_exact_mut(LANES);
    let mut bc = b2.chunks_exact_mut(LANES);
    let mut gc = g[..d].chunks_exact(LANES);
    let mut qc = gsq[..d].chunks_exact(LANES);
    for (((xv, bv), gv), qv) in (&mut xc).zip(&mut bc).zip(&mut gc).zip(&mut qc) {
        for j in 0..LANES {
            let stale = bv[j];
            xv[j] -= lr * gv[j] / (stale + eps2).sqrt();
            bv[j] = stale + qv[j];
        }
    }
    for (((xv, bv), &gv), &qv) in xc
        .into_remainder()
        .iter_mut()
        .zip(bc.into_remainder())
        .zip(gc.remainder())
        .zip(qc.remainder())
    {
        let stale = *bv;
        *xv -= lr * gv / (stale + eps2).sqrt();
        *bv = stale + qv;
    }
}

/// Lane form of [`crate::util::kernels::serial::local_adaalter_step`]:
/// elementwise streams identical; `‖Δx‖²` accumulates into the fixed
/// 8-lane f64 tree (element `i` → lane `i mod 8`, [`fold_tree`] fold).
pub fn local_adaalter_step(
    x: &mut [f32],
    b2_sync: &[f32],
    acc: &mut [f32],
    g: &[f32],
    lr: f32,
    denom_add: f32,
) -> f64 {
    let d = x.len();
    check_len(b2_sync.len(), d, "local_adaalter_step");
    check_len(acc.len(), d, "local_adaalter_step");
    check_len(g.len(), d, "local_adaalter_step");
    let mut lanes = [0.0f64; LANES];
    let mut xc = x.chunks_exact_mut(LANES);
    let mut bc = b2_sync[..d].chunks_exact(LANES);
    let mut ac = acc[..d].chunks_exact_mut(LANES);
    let mut gc = g[..d].chunks_exact(LANES);
    for (((xv, bv), av), gv) in (&mut xc).zip(&mut bc).zip(&mut ac).zip(&mut gc) {
        for j in 0..LANES {
            let gi = gv[j];
            let du = lr * gi / (bv[j] + denom_add).sqrt();
            xv[j] -= du;
            av[j] += gi * gi;
            lanes[j] += du as f64 * du as f64;
        }
    }
    let (xr, br, ar, gr) =
        (xc.into_remainder(), bc.remainder(), ac.into_remainder(), gc.remainder());
    for j in 0..gr.len() {
        let gi = gr[j];
        let du = lr * gi / (br[j] + denom_add).sqrt();
        xr[j] -= du;
        ar[j] += gi * gi;
        lanes[j] += du as f64 * du as f64;
    }
    fold_tree(&lanes)
}

/// Lane form of [`crate::util::kernels::serial::delta_encode`].
pub fn delta_encode(src: &[f32], base: &[f32], out: &mut [f32]) {
    let d = out.len();
    check_len(src.len(), d, "delta_encode");
    check_len(base.len(), d, "delta_encode");
    let mut oc = out.chunks_exact_mut(LANES);
    let mut sc = src[..d].chunks_exact(LANES);
    let mut bc = base[..d].chunks_exact(LANES);
    for ((o, sv), bv) in (&mut oc).zip(&mut sc).zip(&mut bc) {
        for j in 0..LANES {
            o[j] = sv[j] - bv[j];
        }
    }
    for ((o, &sv), &bv) in oc.into_remainder().iter_mut().zip(sc.remainder()).zip(bc.remainder()) {
        *o = sv - bv;
    }
}

/// Lane form of [`crate::util::kernels::serial::delta_decode`].
pub fn delta_decode(base: &[f32], delta: &[f32], out: &mut [f32]) {
    let d = out.len();
    check_len(base.len(), d, "delta_decode");
    check_len(delta.len(), d, "delta_decode");
    let mut oc = out.chunks_exact_mut(LANES);
    let mut bc = base[..d].chunks_exact(LANES);
    let mut dc = delta[..d].chunks_exact(LANES);
    for ((o, bv), dv) in (&mut oc).zip(&mut bc).zip(&mut dc) {
        for j in 0..LANES {
            o[j] = bv[j] + dv[j];
        }
    }
    for ((o, &bv), &dv) in oc.into_remainder().iter_mut().zip(bc.remainder()).zip(dc.remainder()) {
        *o = bv + dv;
    }
}

/// Lane form of [`crate::util::kernels::serial::delta_decode_clamped`].
pub fn delta_decode_clamped(base: &[f32], delta: &[f32], out: &mut [f32]) {
    let d = out.len();
    check_len(base.len(), d, "delta_decode_clamped");
    check_len(delta.len(), d, "delta_decode_clamped");
    let mut oc = out.chunks_exact_mut(LANES);
    let mut bc = base[..d].chunks_exact(LANES);
    let mut dc = delta[..d].chunks_exact(LANES);
    for ((o, bv), dv) in (&mut oc).zip(&mut bc).zip(&mut dc) {
        for j in 0..LANES {
            o[j] = (bv[j] + dv[j]).max(0.0);
        }
    }
    for ((o, &bv), &dv) in oc.into_remainder().iter_mut().zip(bc.remainder()).zip(dc.remainder()) {
        *o = (bv + dv).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing_and_labels() {
        assert_eq!(SimdMode::parse("auto"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse("on"), Some(SimdMode::On));
        assert_eq!(SimdMode::parse("off"), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("ON"), None);
        assert_eq!(SimdMode::parse(""), None);
        for m in [SimdMode::Auto, SimdMode::On, SimdMode::Off] {
            assert_eq!(SimdMode::parse(m.label()), Some(m));
        }
    }

    #[test]
    fn from_config_rejects_unknown_spelling() {
        let mut cfg = ExecConfig::default();
        assert_eq!(SimdMode::from_config(&cfg).unwrap(), SimdMode::Auto);
        cfg.simd = "fast".into();
        let err = SimdMode::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("exec.simd"), "{err}");
    }

    #[test]
    fn mode_global_roundtrip() {
        // Safe to toggle even under the parallel test harness: every
        // kernel is bitwise mode-independent, so concurrent dispatch
        // reads cannot change any other test's results.
        let before = mode();
        set_mode(SimdMode::On);
        assert!(enabled());
        assert_eq!(mode(), SimdMode::On);
        set_mode(SimdMode::Off);
        assert!(!enabled());
        set_mode(SimdMode::Auto);
        assert_eq!(mode(), SimdMode::Auto);
        set_mode(before);
    }

    #[test]
    fn fold_tree_is_fixed_bracketing() {
        let l = [1e16, 1.0, -1e16, 2.0, 3.0, 4.0, 5.0, 6.0];
        let expect = ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]));
        assert_eq!(fold_tree(&l).to_bits(), expect.to_bits());
    }
}
