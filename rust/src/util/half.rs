//! bf16 (bfloat16) conversion — the mixed-precision substrate (DESIGN.md
//! §7).
//!
//! bf16 is the upper 16 bits of an IEEE-754 f32: same 8-bit exponent,
//! mantissa truncated from 23 to 7 bits. That makes conversion pure bit
//! arithmetic (no tables, no rescaling), preserves the full f32 dynamic
//! range (unlike IEEE f16), and keeps every conversion branch-free enough
//! for the SIMD-batched helpers below — which is why it is the standard
//! mixed-precision wire/state format for distributed training.
//!
//! Three conversion flavors:
//!
//! * [`bf16_from_f32`] — round-to-nearest-even (RNE), the default. NaNs
//!   are quieted (payload truncation may otherwise produce an infinity
//!   bit pattern); ±Inf, ±0 and subnormals fall out of the bit shift
//!   naturally.
//! * [`bf16_from_f32_stochastic`] — stochastic rounding: add 16 uniform
//!   random bits before truncating. Rounds up with probability equal to
//!   the discarded fraction, so the *expected* decoded value equals the
//!   input (in bit space exactly; in value space up to binade-boundary
//!   curvature) — the property that keeps long accumulations unbiased.
//! * [`f32_from_bf16`] — exact widening (every bf16 value is an f32).
//!
//! Batched forms ([`encode_into`], [`decode_into`], [`quantize_assign`])
//! process fixed 8-lane chunks plus a scalar remainder — the same shape as
//! [`crate::util::simd`] — and allocate nothing beyond the caller's
//! buffers. `quantize_assign` is the optimizer-state hook: bf16 optimizer
//! state is *emulated value-exactly* by keeping f32 storage and rounding
//! it through bf16 after every update, so accessors, checkpoints and the
//! zero-allocation discipline are untouched while every stored value is
//! exactly representable in 16 bits.

/// Lanes per batched-conversion chunk (mirrors [`crate::util::simd::LANES`]).
const LANES: usize = 8;

/// Convert one f32 to bf16 with round-to-nearest-even.
///
/// NaN inputs are quieted: the truncated payload is OR-ed with the quiet
/// bit so a signalling-NaN payload that truncates to all-zero mantissa
/// cannot turn into an infinity.
#[inline]
pub fn bf16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE: add 0x7FFF plus the LSB of the kept part, then truncate —
    // ties (discarded half exactly 0x8000) round to the even mantissa.
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// Convert one f32 to bf16 with stochastic rounding: `r` supplies 16
/// uniform random bits; the value rounds up with probability equal to the
/// discarded fraction. NaNs are quieted as in [`bf16_from_f32`].
#[inline]
pub fn bf16_from_f32_stochastic(x: f32, r: u16) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    ((bits + r as u32) >> 16) as u16
}

/// Widen one bf16 to f32 (exact — bf16 values are a subset of f32).
#[inline]
pub fn f32_from_bf16(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Round an f32 through bf16 and back (RNE) — the value-exact emulation
/// primitive: the result is the f32 nearest-bf16 representation of `x`.
#[inline]
pub fn round_f32(x: f32) -> f32 {
    f32_from_bf16(bf16_from_f32(x))
}

/// Batched RNE encode: `out` is resized to `src.len()` and filled with
/// the bf16 encodings. 8-lane chunks + scalar remainder.
pub fn encode_into(src: &[f32], out: &mut Vec<u16>) {
    out.clear();
    out.resize(src.len(), 0);
    let mut s = src.chunks_exact(LANES);
    let mut o = out.chunks_exact_mut(LANES);
    for (sc, oc) in (&mut s).zip(&mut o) {
        for j in 0..LANES {
            oc[j] = bf16_from_f32(sc[j]);
        }
    }
    for (ov, &sv) in o.into_remainder().iter_mut().zip(s.remainder()) {
        *ov = bf16_from_f32(sv);
    }
}

/// Batched decode: `out[i] = f32_from_bf16(src[i])`. Lengths must match.
pub fn decode_into(src: &[u16], out: &mut [f32]) {
    assert_eq!(src.len(), out.len(), "length mismatch in bf16 decode_into");
    let mut s = src.chunks_exact(LANES);
    let mut o = out.chunks_exact_mut(LANES);
    for (sc, oc) in (&mut s).zip(&mut o) {
        for j in 0..LANES {
            oc[j] = f32_from_bf16(sc[j]);
        }
    }
    for (ov, &sv) in o.into_remainder().iter_mut().zip(s.remainder()) {
        *ov = f32_from_bf16(sv);
    }
}

/// In-place RNE roundtrip: every element becomes its nearest
/// bf16-representable f32. The bf16 wire codec and the bf16 optimizer
/// state both reduce to this one kernel; zero allocations.
pub fn quantize_assign(xs: &mut [f32]) {
    let mut c = xs.chunks_exact_mut(LANES);
    for chunk in &mut c {
        for v in chunk.iter_mut() {
            *v = round_f32(*v);
        }
    }
    for v in c.into_remainder() {
        *v = round_f32(*v);
    }
}

/// Wire bytes of a bf16-encoded vector of dimension `d`.
pub fn wire_bytes(d: usize) -> u64 {
    2 * d as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// The two bf16 neighbours of a finite f32 (by sign-magnitude
    /// truncation): the rounded result must be one of them.
    fn neighbours(x: f32) -> (f32, f32) {
        let bits = x.to_bits();
        let lo = bits & 0xFFFF_0000;
        // Next representable in magnitude (may overflow to ±Inf — that is
        // the correct upper neighbour for values above bf16 MAX).
        let hi = lo.wrapping_add(0x0001_0000);
        (f32::from_bits(lo), f32::from_bits(hi))
    }

    #[test]
    fn exact_values_roundtrip_identically() {
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1.5, -3.25, 256.0, 1.0e30, -1.0e-30] {
            // All chosen values have ≤7 mantissa bits ⇒ bf16-exact.
            assert_eq!(round_f32(v).to_bits(), v.to_bits(), "{v}");
        }
        assert_eq!(f32_from_bf16(bf16_from_f32(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f32_from_bf16(bf16_from_f32(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn rounds_to_one_of_the_two_bf16_neighbours() {
        // "Within 1 ulp-of-bf16": the RNE result is the truncation or the
        // next magnitude step, never further.
        prop::check("bf16 rounds to a neighbour", 300, |g| {
            // Mix wide-range uniform with raw bit patterns (covers
            // subnormals and extreme exponents).
            let x = if g.bool() {
                g.f32_in(-1.0e20..1.0e20)
            } else {
                f32::from_bits(g.rng().next_u64() as u32)
            };
            if x.is_nan() {
                return Ok(());
            }
            let r = round_f32(x);
            let (lo, hi) = neighbours(x);
            prop::assert_that(
                r.to_bits() == lo.to_bits() || r.to_bits() == hi.to_bits(),
                format!("{x} ({:#x}) rounded to {r}, neighbours {lo}/{hi}", x.to_bits()),
            )?;
            // And of the two, RNE picks the nearer (ties go even, which is
            // still "not further than the other neighbour").
            if r.is_finite() && lo.is_finite() && hi.is_finite() {
                let (dr, dlo, dhi) =
                    ((r - x).abs() as f64, (lo - x).abs() as f64, (hi - x).abs() as f64);
                prop::assert_that(
                    dr <= dlo.max(dhi) && dr <= dlo.min(dhi) + (hi - lo).abs() as f64 / 2.0,
                    format!("{x}: |err| {dr} vs neighbours {dlo}/{dhi}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn rounding_is_monotone() {
        prop::check("bf16 rounding monotone", 200, |g| {
            let a = g.f32_in(-1.0e10..1.0e10);
            let b = g.f32_in(-1.0e10..1.0e10);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            prop::assert_that(
                round_f32(lo) <= round_f32(hi),
                format!("round({lo}) > round({hi})"),
            )
        });
    }

    #[test]
    fn nan_inf_and_subnormals() {
        // NaN stays NaN (quieted, never an infinity).
        let q = f32_from_bf16(bf16_from_f32(f32::NAN));
        assert!(q.is_nan());
        // A signalling-style payload whose top bits truncate to zero must
        // not collapse to Inf.
        let snan = f32::from_bits(0x7F80_0001);
        assert!(snan.is_nan());
        assert!(f32_from_bf16(bf16_from_f32(snan)).is_nan());
        assert!(f32_from_bf16(bf16_from_f32_stochastic(snan, 0xFFFF)).is_nan());
        // Infinities are fixed points, f32::MAX overflows to Inf (nearest).
        assert_eq!(round_f32(f32::MAX), f32::INFINITY);
        assert_eq!(round_f32(-f32::MAX), f32::NEG_INFINITY);
        // f32 subnormals round to bf16-grid subnormals or zero, exactly.
        let sub = f32::from_bits(0x0001_2345);
        let r = round_f32(sub);
        assert!(r == 0.0 || r.to_bits() & 0xFFFF == 0, "{:#x}", r.to_bits());
        // Signed zero is preserved.
        assert_eq!(round_f32(-0.0).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        // E[decode(sr(x))] ≈ x: the mean over many uniform draws lands
        // within a small fraction of one bf16 ulp.
        let mut rng = Rng::new(42);
        for &x in &[1.234567f32, -0.007813, 3.9999, 1000.5, -1.0e-8] {
            let trials = 40_000;
            let mut mean = 0.0f64;
            for _ in 0..trials {
                let r = (rng.next_u64() & 0xFFFF) as u16;
                mean += f32_from_bf16(bf16_from_f32_stochastic(x, r)) as f64 / trials as f64;
            }
            let (lo, hi) = neighbours(x);
            let ulp = (hi - lo).abs() as f64;
            assert!(
                (mean - x as f64).abs() < 0.05 * ulp + 1e-12,
                "x={x}: mean {mean}, ulp {ulp}"
            );
        }
    }

    #[test]
    fn stochastic_extremes_match_truncation_bounds() {
        // r = 0 truncates toward zero in magnitude; r = 0xFFFF reaches at
        // most the next magnitude step.
        prop::check("bf16 stochastic bounds", 200, |g| {
            let x = g.f32_in(-1.0e10..1.0e10);
            let (lo, hi) = neighbours(x);
            let down = f32_from_bf16(bf16_from_f32_stochastic(x, 0));
            let up = f32_from_bf16(bf16_from_f32_stochastic(x, 0xFFFF));
            prop::assert_that(down.to_bits() == lo.to_bits(), format!("down {down} vs {lo}"))?;
            prop::assert_that(
                up.to_bits() == lo.to_bits() || up.to_bits() == hi.to_bits(),
                format!("up {up} vs {lo}/{hi}"),
            )
        });
    }

    #[test]
    fn batched_forms_match_scalar_for_all_widths() {
        // Every width 0..40 exercises both the 8-lane chunks and each
        // possible remainder length.
        for d in 0..40usize {
            let mut src = vec![0.0f32; d];
            Rng::new(d as u64 + 1).fill_normal(&mut src, 3.0);
            if d > 2 {
                src[0] = f32::NAN;
                src[1] = f32::INFINITY;
                src[2] = f32::from_bits(0x0000_0777); // subnormal
            }
            let mut enc = Vec::new();
            encode_into(&src, &mut enc);
            assert_eq!(enc.len(), d);
            let mut dec = vec![0.0f32; d];
            decode_into(&enc, &mut dec);
            let mut q = src.clone();
            quantize_assign(&mut q);
            for i in 0..d {
                assert_eq!(enc[i], bf16_from_f32(src[i]), "enc[{i}] d={d}");
                assert_eq!(dec[i].to_bits(), round_f32(src[i]).to_bits(), "dec[{i}] d={d}");
                assert_eq!(q[i].to_bits(), round_f32(src[i]).to_bits(), "q[{i}] d={d}");
            }
        }
    }

    #[test]
    fn quantize_is_idempotent() {
        prop::check("bf16 quantize idempotent", 100, |g| {
            let mut v = g.vec_normal(1..200, 10.0);
            quantize_assign(&mut v);
            let once = v.clone();
            quantize_assign(&mut v);
            for (i, (&a, &b)) in once.iter().zip(&v).enumerate() {
                prop::assert_that(a.to_bits() == b.to_bits(), format!("idx {i}: {a} vs {b}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn wire_bytes_is_half_of_f32() {
        assert_eq!(wire_bytes(0), 0);
        assert_eq!(wire_bytes(1024), 2048);
        assert_eq!(wire_bytes(1 << 20), 4 * (1 << 20) / 2);
    }
}
