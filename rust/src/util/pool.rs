//! Step-scoped buffer reuse for the training hot paths (DESIGN.md §7).
//!
//! Two small tools with one goal: steady-state training should not touch
//! the allocator.
//!
//! * [`BufferPool`] — a free-list of `f32` scratch vectors. The leader
//!   owns one: gradient buffers ride `Cmd::SyncStep` down to the workers
//!   and come back inside `Reply::Grad`; state-collection buffers ride
//!   `Cmd::CollectState` and come back inside `Reply::State` — in both
//!   cases the leader parks the returned vectors here and hands the same
//!   allocations out on the next round. (Codec scratch — QSGD level
//!   buffers, top-k select indices, delta staging — is owned by the codec
//!   and collective structs directly, since its shapes are fixed.)
//! * [`ArcSlot`] — a recycler for `Arc<Vec<f32>>` broadcast payloads: the
//!   leader ships one shared payload per round ([`std::sync::Arc`] clones,
//!   not vector clones), and once every worker has dropped its handle the
//!   same allocation is refilled for the next round instead of
//!   reallocated.
//!
//! The counting-allocator test (`rust/tests/integration_alloc.rs`) pins
//! the zero-steady-state-allocation property of the paths built on these.

use std::sync::Arc;

/// A free-list of reusable `f32` scratch vectors.
///
/// [`BufferPool::take`]`(len)` returns a vector resized to `len`
/// (contents unspecified — callers must overwrite); [`BufferPool::put`]
/// returns it for reuse. Taking from an empty pool allocates, so steady
/// state is allocation-free once the pool has warmed up to the working
/// set.
#[derive(Default)]
pub struct BufferPool {
    free: Vec<Vec<f32>>,
}

impl BufferPool {
    /// Empty pool.
    pub fn new() -> Self {
        BufferPool::default()
    }

    /// Take a buffer of length `len` (zero-filled only on fresh
    /// allocation; reused buffers keep stale contents).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer for reuse.
    pub fn put(&mut self, v: Vec<f32>) {
        self.free.push(v);
    }

    /// Buffers currently parked in the pool (diagnostics / tests).
    pub fn parked(&self) -> usize {
        self.free.len()
    }
}

/// Recycler for a leader-broadcast `Arc<Vec<f32>>` payload.
///
/// The lockstep protocol guarantees every worker drops its handle before
/// the leader's next broadcast (workers release the payload before
/// replying), so by the time [`ArcSlot::fill`] runs again the slot's
/// allocation is unique and can be overwritten in place. If a handle is
/// still live (e.g. a crashed cell that released late), `fill` falls back
/// to a fresh allocation — correctness never depends on the recycle.
#[derive(Default)]
pub struct ArcSlot {
    slot: Option<Arc<Vec<f32>>>,
}

impl ArcSlot {
    /// Empty slot.
    pub fn new() -> Self {
        ArcSlot::default()
    }

    /// Return a shared payload holding a copy of `src`, reusing the
    /// previous round's allocation when it is no longer shared.
    pub fn fill(&mut self, src: &[f32]) -> Arc<Vec<f32>> {
        let arc = match self.slot.take() {
            Some(mut a) => match Arc::get_mut(&mut a) {
                Some(buf) if buf.len() == src.len() => {
                    buf.copy_from_slice(src);
                    a
                }
                _ => Arc::new(src.to_vec()),
            },
            None => Arc::new(src.to_vec()),
        };
        self.slot = Some(Arc::clone(&arc));
        arc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_buffers() {
        let mut p = BufferPool::new();
        let a = p.take(16);
        assert_eq!(a.len(), 16);
        let ptr = a.as_ptr();
        p.put(a);
        assert_eq!(p.parked(), 1);
        // Shrinking reuse keeps the allocation — no new allocation.
        let b = p.take(8);
        assert_eq!(b.len(), 8);
        assert_eq!(b.as_ptr(), ptr);
        assert_eq!(p.parked(), 0);
    }

    #[test]
    fn empty_pool_allocates_fresh_zeroed() {
        let mut p = BufferPool::new();
        let v = p.take(4);
        assert_eq!(v, vec![0.0f32; 4]);
    }

    #[test]
    fn arc_slot_recycles_when_unique() {
        let mut s = ArcSlot::new();
        let a = s.fill(&[1.0, 2.0]);
        assert_eq!(a.as_slice(), &[1.0, 2.0]);
        let ptr = Arc::as_ptr(&a);
        drop(a); // all external handles gone → next fill reuses
        let b = s.fill(&[3.0, 4.0]);
        assert_eq!(b.as_slice(), &[3.0, 4.0]);
        assert_eq!(Arc::as_ptr(&b), ptr);
    }

    #[test]
    fn arc_slot_falls_back_when_shared_or_resized() {
        let mut s = ArcSlot::new();
        let a = s.fill(&[1.0, 2.0]);
        // `a` still live → the slot is shared and must not be overwritten.
        let b = s.fill(&[5.0, 6.0]);
        assert_eq!(a.as_slice(), &[1.0, 2.0]);
        assert_eq!(b.as_slice(), &[5.0, 6.0]);
        drop((a, b));
        // Length change → fresh allocation of the right size.
        let c = s.fill(&[7.0]);
        assert_eq!(c.as_slice(), &[7.0]);
    }
}
