//! Step-scoped buffer reuse for the training hot paths (DESIGN.md §7).
//!
//! Three small tools with one goal: steady-state training should not
//! touch the allocator.
//!
//! * [`BufferPool`] — a free-list of `f32` scratch vectors. The leader
//!   owns one: gradient buffers ride `Cmd::SyncStep` down to the workers
//!   and come back inside `Reply::Grad`; state-collection buffers ride
//!   `Cmd::CollectState` and come back inside `Reply::State` — in both
//!   cases the leader parks the returned vectors here and hands the same
//!   allocations out on the next round. (Codec scratch — QSGD level
//!   buffers, top-k select indices, delta staging — is owned by the codec
//!   and collective structs directly, since its shapes are fixed.)
//! * [`BytePool`] — the same free-list idea for `u8` wire buffers: the
//!   pipelined socket path ([`crate::comm::net`]) stages encoded frames
//!   in pooled byte buffers so encode → frame → queue is copy-free and
//!   allocation-free at steady state, with multiple buffers in flight
//!   when `[comm] pipeline` overlaps shards.
//! * [`ArcSlot`] — a recycler for `Arc<Vec<f32>>` broadcast payloads: the
//!   leader ships one shared payload per round ([`std::sync::Arc`] clones,
//!   not vector clones), and once every worker has dropped its handle the
//!   same allocation is refilled for the next round instead of
//!   reallocated.
//!
//! Both pools are capped: `put` beyond the high-water mark drops the
//! buffer instead of parking it, so a deep `[comm] pipeline` (many
//! in-flight shard buffers) cannot silently hoard memory. Hit/miss
//! counters are surfaced through `metrics/recorder.rs` for runs that
//! want to check the pool actually warmed up.
//!
//! The counting-allocator test (`rust/tests/integration_alloc.rs`) pins
//! the zero-steady-state-allocation property of the paths built on these.

use std::sync::Arc;

/// Default high-water mark for pooled buffers: the leader's working set
/// is O(workers + pipeline depth) buffers per family, and 64 covers the
/// validated maxima (64 workers / depth 16) with room to spare.
pub const DEFAULT_POOL_CAP: usize = 64;

/// Cumulative take/put statistics for a pool ([`BufferPool::stats`],
/// [`BytePool::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` calls served from the free-list (no allocation).
    pub hits: u64,
    /// `take` calls that had to allocate (empty free-list).
    pub misses: u64,
    /// `put` calls dropped because the pool was at its cap.
    pub dropped: u64,
}

impl PoolStats {
    /// Sum with another pool's counters (for aggregating the f32 and
    /// byte pools into one recorder line).
    pub fn merge(&self, other: &PoolStats) -> PoolStats {
        PoolStats {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            dropped: self.dropped + other.dropped,
        }
    }
}

/// A free-list of reusable `f32` scratch vectors.
///
/// [`BufferPool::take`]`(len)` returns a vector resized to `len`
/// (contents unspecified — callers must overwrite); [`BufferPool::put`]
/// returns it for reuse. Taking from an empty pool allocates, so steady
/// state is allocation-free once the pool has warmed up to the working
/// set. The free-list is capped at a high-water mark ([`DEFAULT_POOL_CAP`]
/// unless [`BufferPool::with_cap`] chose otherwise): returns beyond the
/// cap drop the buffer.
pub struct BufferPool {
    free: Vec<Vec<f32>>,
    cap: usize,
    stats: PoolStats,
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::with_cap(DEFAULT_POOL_CAP)
    }
}

impl BufferPool {
    /// Empty pool with the default cap.
    pub fn new() -> Self {
        BufferPool::default()
    }

    /// Empty pool that parks at most `cap` buffers.
    pub fn with_cap(cap: usize) -> Self {
        BufferPool { free: Vec::new(), cap, stats: PoolStats::default() }
    }

    /// Take a buffer of length `len` (zero-filled only on fresh
    /// allocation; reused buffers keep stale contents).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        match self.free.pop() {
            Some(mut v) => {
                self.stats.hits += 1;
                v.resize(len, 0.0);
                v
            }
            None => {
                self.stats.misses += 1;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer for reuse; dropped if the pool is at its cap.
    pub fn put(&mut self, v: Vec<f32>) {
        if self.free.len() < self.cap {
            self.free.push(v);
        } else {
            self.stats.dropped += 1;
        }
    }

    /// Buffers currently parked in the pool (diagnostics / tests).
    pub fn parked(&self) -> usize {
        self.free.len()
    }

    /// Cumulative hit/miss/drop counters.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }
}

/// A free-list of reusable `u8` wire-staging buffers.
///
/// Same contract as [`BufferPool`] but for encoded payload bytes:
/// [`BytePool::take`] hands back a *cleared* buffer (`len == 0`,
/// capacity retained) ready for `encode_into`-style appends, and
/// [`BytePool::put`] parks it again up to the cap. The networked
/// transport keeps one per staging site so a pipelined round recycles
/// the same handful of allocations no matter how many frames it
/// coalesces.
pub struct BytePool {
    free: Vec<Vec<u8>>,
    cap: usize,
    stats: PoolStats,
}

impl Default for BytePool {
    fn default() -> Self {
        BytePool::with_cap(DEFAULT_POOL_CAP)
    }
}

impl BytePool {
    /// Empty pool with the default cap.
    pub fn new() -> Self {
        BytePool::default()
    }

    /// Empty pool that parks at most `cap` buffers.
    pub fn with_cap(cap: usize) -> Self {
        BytePool { free: Vec::new(), cap, stats: PoolStats::default() }
    }

    /// Take an empty buffer (capacity reused from a parked buffer when
    /// one is available).
    pub fn take(&mut self) -> Vec<u8> {
        match self.free.pop() {
            Some(mut v) => {
                self.stats.hits += 1;
                v.clear();
                v
            }
            None => {
                self.stats.misses += 1;
                Vec::new()
            }
        }
    }

    /// Return a buffer for reuse; dropped if the pool is at its cap.
    pub fn put(&mut self, v: Vec<u8>) {
        if self.free.len() < self.cap {
            self.free.push(v);
        } else {
            self.stats.dropped += 1;
        }
    }

    /// Buffers currently parked in the pool (diagnostics / tests).
    pub fn parked(&self) -> usize {
        self.free.len()
    }

    /// Cumulative hit/miss/drop counters.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }
}

/// Recycler for a leader-broadcast `Arc<Vec<f32>>` payload.
///
/// The lockstep protocol guarantees every worker drops its handle before
/// the leader's next broadcast (workers release the payload before
/// replying), so by the time [`ArcSlot::fill`] runs again the slot's
/// allocation is unique and can be overwritten in place. If a handle is
/// still live (e.g. a crashed cell that released late), `fill` falls back
/// to a fresh allocation — correctness never depends on the recycle.
#[derive(Default)]
pub struct ArcSlot {
    slot: Option<Arc<Vec<f32>>>,
}

impl ArcSlot {
    /// Empty slot.
    pub fn new() -> Self {
        ArcSlot::default()
    }

    /// Return a shared payload holding a copy of `src`, reusing the
    /// previous round's allocation when it is no longer shared.
    pub fn fill(&mut self, src: &[f32]) -> Arc<Vec<f32>> {
        let arc = match self.slot.take() {
            Some(mut a) => match Arc::get_mut(&mut a) {
                Some(buf) if buf.len() == src.len() => {
                    buf.copy_from_slice(src);
                    a
                }
                _ => Arc::new(src.to_vec()),
            },
            None => Arc::new(src.to_vec()),
        };
        self.slot = Some(Arc::clone(&arc));
        arc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_buffers() {
        let mut p = BufferPool::new();
        let a = p.take(16);
        assert_eq!(a.len(), 16);
        let ptr = a.as_ptr();
        p.put(a);
        assert_eq!(p.parked(), 1);
        // Shrinking reuse keeps the allocation — no new allocation.
        let b = p.take(8);
        assert_eq!(b.len(), 8);
        assert_eq!(b.as_ptr(), ptr);
        assert_eq!(p.parked(), 0);
    }

    #[test]
    fn empty_pool_allocates_fresh_zeroed() {
        let mut p = BufferPool::new();
        let v = p.take(4);
        assert_eq!(v, vec![0.0f32; 4]);
    }

    #[test]
    fn pool_counts_hits_and_misses() {
        let mut p = BufferPool::new();
        let a = p.take(8); // miss: empty pool
        p.put(a);
        let b = p.take(8); // hit: recycled
        p.put(b);
        assert_eq!(p.stats(), PoolStats { hits: 1, misses: 1, dropped: 0 });
    }

    #[test]
    fn pool_cap_drops_beyond_high_water() {
        let mut p = BufferPool::with_cap(2);
        for _ in 0..4 {
            let v = p.take(8);
            // Hold nothing back: every put past the cap must be dropped,
            // not parked.
            p.put(v);
        }
        let extra_a = p.take(8);
        let extra_b = p.take(8);
        let extra_c = p.take(8);
        p.put(extra_a);
        p.put(extra_b);
        p.put(extra_c);
        assert_eq!(p.parked(), 2, "cap = 2 must bound the free-list");
        assert_eq!(p.stats().dropped, 1);
        // The cap never affects take: it still serves from the list.
        let v = p.take(4);
        assert_eq!(v.len(), 4);
        assert_eq!(p.parked(), 1);
    }

    #[test]
    fn byte_pool_recycles_cleared() {
        let mut p = BytePool::with_cap(2);
        let mut a = p.take();
        a.extend_from_slice(&[1, 2, 3, 4]);
        let ptr = a.as_ptr();
        p.put(a);
        let b = p.take();
        assert!(b.is_empty(), "recycled byte buffers come back cleared");
        assert_eq!(b.as_ptr(), ptr, "capacity is reused, not reallocated");
        p.put(b);
        p.put(vec![9; 8]);
        p.put(vec![9; 8]); // past cap = 2 → dropped
        assert_eq!(p.parked(), 2);
        assert_eq!(p.stats().dropped, 1);
    }

    #[test]
    fn pool_stats_merge_sums() {
        let a = PoolStats { hits: 1, misses: 2, dropped: 3 };
        let b = PoolStats { hits: 10, misses: 20, dropped: 30 };
        assert_eq!(a.merge(&b), PoolStats { hits: 11, misses: 22, dropped: 33 });
    }

    #[test]
    fn arc_slot_recycles_when_unique() {
        let mut s = ArcSlot::new();
        let a = s.fill(&[1.0, 2.0]);
        assert_eq!(a.as_slice(), &[1.0, 2.0]);
        let ptr = Arc::as_ptr(&a);
        drop(a); // all external handles gone → next fill reuses
        let b = s.fill(&[3.0, 4.0]);
        assert_eq!(b.as_slice(), &[3.0, 4.0]);
        assert_eq!(Arc::as_ptr(&b), ptr);
    }

    #[test]
    fn arc_slot_falls_back_when_shared_or_resized() {
        let mut s = ArcSlot::new();
        let a = s.fill(&[1.0, 2.0]);
        // `a` still live → the slot is shared and must not be overwritten.
        let b = s.fill(&[5.0, 6.0]);
        assert_eq!(a.as_slice(), &[1.0, 2.0]);
        assert_eq!(b.as_slice(), &[5.0, 6.0]);
        drop((a, b));
        // Length change → fresh allocation of the right size.
        let c = s.fill(&[7.0]);
        assert_eq!(c.as_slice(), &[7.0]);
    }
}
