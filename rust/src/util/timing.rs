//! Micro-benchmark timing harness (the offline image has no `criterion`).
//!
//! Used by the `benches/` targets (`harness = false`): warmup + repeated
//! timed batches, reporting median/mean/min over batches. Deliberately
//! simple — the figure-level benches care about model-derived numbers, and
//! the hot-path benches about order-of-magnitude and before/after deltas
//! (EXPERIMENTS.md §Perf).

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    /// Iterations per timed batch.
    pub iters_per_batch: u64,
    /// Number of timed batches.
    pub batches: usize,
    /// Nanoseconds per iteration, median over batches.
    pub median_ns: f64,
    /// Nanoseconds per iteration, mean over batches.
    pub mean_ns: f64,
    /// Nanoseconds per iteration, fastest batch.
    pub min_ns: f64,
}

impl BenchStats {
    /// Iterations per second at the median.
    pub fn per_second(&self) -> f64 {
        1e9 / self.median_ns
    }

    /// Bytes/s given bytes touched per iteration.
    pub fn bandwidth_gbs(&self, bytes_per_iter: u64) -> f64 {
        bytes_per_iter as f64 * self.per_second() / 1e9
    }
}

/// Time `f` with `iters` calls per batch over `batches` batches (after one
/// warmup batch). The closure should include its own black-box sinks.
pub fn bench(iters: u64, batches: usize, mut f: impl FnMut()) -> BenchStats {
    assert!(iters > 0 && batches > 0);
    // Warmup.
    for _ in 0..iters {
        f();
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(batches);
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    per_iter.sort_by(|a, b| a.total_cmp(b));
    let median_ns = per_iter[per_iter.len() / 2];
    let mean_ns = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    BenchStats {
        iters_per_batch: iters,
        batches,
        median_ns,
        mean_ns,
        min_ns: per_iter[0],
    }
}

/// Prevent the optimiser from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty-print one bench row (aligned for the bench logs).
pub fn report(name: &str, stats: &BenchStats, extra: &str) {
    println!(
        "{name:<44} {:>12.0} ns/iter  {:>14.0} iter/s  {extra}",
        stats.median_ns,
        stats.per_second()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut acc = 0u64;
        let s = bench(100, 5, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.per_second() > 0.0);
    }

    #[test]
    fn bandwidth_math() {
        let s = BenchStats {
            iters_per_batch: 1,
            batches: 1,
            median_ns: 1000.0, // 1 µs/iter
            mean_ns: 1000.0,
            min_ns: 1000.0,
        };
        // 1 MiB per µs ≈ 1048 GB/s
        let gbs = s.bandwidth_gbs(1 << 20);
        assert!((gbs - 1.048576e3).abs() < 1e-6, "{gbs}");
    }
}
