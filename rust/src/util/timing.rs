//! Micro-benchmark timing harness (the offline image has no `criterion`).
//!
//! Used by the `benches/` targets (`harness = false`): warmup + repeated
//! timed batches, reporting median/mean/min over batches. Deliberately
//! simple — the figure-level benches care about model-derived numbers, and
//! the hot-path benches about order-of-magnitude and before/after deltas
//! (EXPERIMENTS.md §Perf).
//!
//! Besides the human-readable [`report`] rows, every bench records its
//! numbers into a [`BenchSink`] and writes a machine-readable
//! `BENCH_<bench>.json` next to the working directory, so the perf
//! trajectory is tracked in-repo from PR 4 onward instead of scrolling by
//! on stdout.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;

/// Result of one timed benchmark.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    /// Iterations per timed batch.
    pub iters_per_batch: u64,
    /// Number of timed batches.
    pub batches: usize,
    /// Nanoseconds per iteration, median over batches.
    pub median_ns: f64,
    /// Nanoseconds per iteration, mean over batches.
    pub mean_ns: f64,
    /// Nanoseconds per iteration, fastest batch.
    pub min_ns: f64,
}

impl BenchStats {
    /// Iterations per second at the median.
    pub fn per_second(&self) -> f64 {
        1e9 / self.median_ns
    }

    /// Bytes/s given bytes touched per iteration.
    pub fn bandwidth_gbs(&self, bytes_per_iter: u64) -> f64 {
        bytes_per_iter as f64 * self.per_second() / 1e9
    }
}

/// Time `f` with `iters` calls per batch over `batches` batches (after one
/// warmup batch). The closure should include its own black-box sinks.
pub fn bench(iters: u64, batches: usize, mut f: impl FnMut()) -> BenchStats {
    assert!(iters > 0 && batches > 0);
    // Warmup.
    for _ in 0..iters {
        f();
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(batches);
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    per_iter.sort_by(|a, b| a.total_cmp(b));
    let median_ns = per_iter[per_iter.len() / 2];
    let mean_ns = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    BenchStats {
        iters_per_batch: iters,
        batches,
        median_ns,
        mean_ns,
        min_ns: per_iter[0],
    }
}

/// Prevent the optimiser from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty-print one bench row (aligned for the bench logs).
pub fn report(name: &str, stats: &BenchStats, extra: &str) {
    println!(
        "{name:<44} {:>12.0} ns/iter  {:>14.0} iter/s  {extra}",
        stats.median_ns,
        stats.per_second()
    );
}

/// Machine-readable bench result sink: collects named rows (timed stats
/// and/or free-form metric values) and writes them as one
/// `BENCH_<bench>.json` document — `{"bench": ..., "rows": [...]}`, each
/// row `{"name", "metrics": {...}}` plus `median_ns`/`mean_ns`/`min_ns`/
/// `iters_per_batch`/`batches`/`per_second` when the row was timed.
pub struct BenchSink {
    bench: String,
    rows: Vec<Json>,
}

fn metrics_obj(metrics: &[(&str, f64)]) -> Json {
    let mut m = BTreeMap::new();
    for &(k, v) in metrics {
        m.insert(k.to_string(), Json::Num(v));
    }
    Json::Obj(m)
}

impl BenchSink {
    /// Sink for bench target `bench` (used in the output file name).
    pub fn new(bench: &str) -> Self {
        BenchSink { bench: bench.to_string(), rows: Vec::new() }
    }

    /// Record one timed row with optional derived metrics
    /// (bytes-per-iteration, GB/s, steps/s, …).
    pub fn timed(&mut self, name: &str, stats: &BenchStats, metrics: &[(&str, f64)]) {
        let mut row = BTreeMap::new();
        row.insert("name".into(), Json::Str(name.to_string()));
        row.insert("median_ns".into(), Json::Num(stats.median_ns));
        row.insert("mean_ns".into(), Json::Num(stats.mean_ns));
        row.insert("min_ns".into(), Json::Num(stats.min_ns));
        row.insert("iters_per_batch".into(), Json::Num(stats.iters_per_batch as f64));
        row.insert("batches".into(), Json::Num(stats.batches as f64));
        row.insert("per_second".into(), Json::Num(stats.per_second()));
        row.insert("metrics".into(), metrics_obj(metrics));
        self.rows.push(Json::Obj(row));
    }

    /// Record one untimed row — model-derived numbers (throughputs,
    /// speedup ratios, byte counts) that have no ns/iter reading.
    pub fn value(&mut self, name: &str, metrics: &[(&str, f64)]) {
        let mut row = BTreeMap::new();
        row.insert("name".into(), Json::Str(name.to_string()));
        row.insert("metrics".into(), metrics_obj(metrics));
        self.rows.push(Json::Obj(row));
    }

    /// The collected document.
    pub fn to_json(&self) -> Json {
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str(self.bench.clone()));
        doc.insert("rows".into(), Json::Arr(self.rows.clone()));
        Json::Obj(doc)
    }

    /// Rows recorded so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows were recorded.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Write `BENCH_<bench>.json` into `dir` (the repo root when invoked
    /// via `cargo bench`); returns the path written.
    pub fn write_in(&self, dir: &str) -> std::io::Result<String> {
        let path = if dir.is_empty() {
            format!("BENCH_{}.json", self.bench)
        } else {
            format!("{dir}/BENCH_{}.json", self.bench)
        };
        std::fs::write(&path, self.to_json().dump() + "\n")?;
        Ok(path)
    }

    /// [`BenchSink::write_in`] the current directory, printing the path —
    /// the one-line epilogue every bench target calls.
    pub fn finish(&self) {
        match self.write_in("") {
            Ok(path) => println!("\nwrote {path} ({} rows)", self.rows.len()),
            Err(e) => eprintln!("\nfailed to write BENCH_{}.json: {e}", self.bench),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut acc = 0u64;
        let s = bench(100, 5, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.per_second() > 0.0);
    }

    #[test]
    fn sink_collects_and_serializes_rows() {
        let mut sink = BenchSink::new("unit_test");
        assert!(sink.is_empty());
        let s = BenchStats {
            iters_per_batch: 4,
            batches: 2,
            median_ns: 500.0,
            mean_ns: 510.0,
            min_ns: 490.0,
        };
        sink.timed("kernel_a", &s, &[("bytes_per_iter", 1024.0)]);
        sink.value("speedup", &[("threads8_vs_serial", 3.5)]);
        assert_eq!(sink.len(), 2);
        let doc = sink.to_json();
        assert_eq!(doc.req("bench").unwrap().str().unwrap(), "unit_test");
        let rows = doc.req("rows").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req("name").unwrap().str().unwrap(), "kernel_a");
        assert_eq!(rows[0].req("median_ns").unwrap().num().unwrap(), 500.0);
        assert_eq!(
            rows[0].req("metrics").unwrap().req("bytes_per_iter").unwrap().num().unwrap(),
            1024.0
        );
        assert!(rows[1].get("median_ns").is_none());
        // The dump parses back to the same document.
        let text = doc.dump();
        assert_eq!(crate::util::json::Json::parse(&text).unwrap(), doc);
        // And survives a disk roundtrip in a temp dir.
        let dir = std::env::temp_dir().join(format!("adaalter_sink_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = sink.write_in(dir.to_str().unwrap()).unwrap();
        let read = std::fs::read_to_string(&path).unwrap();
        assert_eq!(crate::util::json::Json::parse(read.trim()).unwrap(), doc);
    }

    #[test]
    fn bandwidth_math() {
        let s = BenchStats {
            iters_per_batch: 1,
            batches: 1,
            median_ns: 1000.0, // 1 µs/iter
            mean_ns: 1000.0,
            min_ns: 1000.0,
        };
        // 1 MiB per µs ≈ 1048 GB/s
        let gbs = s.bandwidth_gbs(1 << 20);
        assert!((gbs - 1.048576e3).abs() < 1e-6, "{gbs}");
    }
}
