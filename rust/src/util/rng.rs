//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! The offline image has no `rand` crate, so the framework owns its PRNG.
//! Determinism is load-bearing: every experiment (data sharding, synthetic
//! corpus, gradient noise in the rust-math backend) is keyed by
//! `(experiment seed, worker id, step)` so runs reproduce bit-for-bit across
//! invocations and worker-thread schedules.
//!
//! Algorithms: Blackman & Vigna, <https://prng.di.unimi.it/> (public domain
//! reference implementations; test vectors below pin ours to them).

/// xoshiro256** generator, seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 (SplitMix64-expanded, per Vigna's guidance).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream for `(worker, step)` style sub-keys.
    ///
    /// Mixes the parts through SplitMix64 so nearby keys decorrelate.
    pub fn derive(seed: u64, parts: &[u64]) -> Self {
        let mut sm = seed;
        let mut acc = splitmix64(&mut sm);
        for &p in parts {
            let mut k = acc ^ p.wrapping_mul(0xA24BAED4963EE407);
            acc = splitmix64(&mut k);
        }
        Rng::new(acc)
    }

    /// Next raw u64 (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// non-cryptographic needs: modulo bias < 2^-32 for n < 2^32).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (pairs cached would complicate state;
    /// the single-call form is plenty for our volumes).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma) noise.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Sample from Zipf(s) over `{0, .., n-1}` using inverse-CDF on a
    /// precomputed table — see [`ZipfTable`] for the table-based fast path.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        // Fisher–Yates.
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Precomputed inverse-CDF table for a Zipf distribution over `n` items.
///
/// The synthetic corpus (DESIGN.md §12) approximates the 1B-word benchmark's
/// heavy-tailed unigram distribution with Zipf(s≈1.1); sampling must be O(1)
/// amortised, so we binary-search a cumulative table.
#[derive(Clone)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    /// Build the table for `n` ranks with exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "ZipfTable needs at least one item");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        ZipfTable { cdf }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True if the table is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Sample a rank in `[0, n)`; rank 0 is the most frequent.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // partition_point: first index with cdf[i] >= u.
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vector from Vigna's xoshiro256** C code seeded with
    /// s = [1, 2, 3, 4].
    #[test]
    fn xoshiro_reference_vector() {
        let mut r = Rng { s: [1, 2, 3, 4] };
        let expected: [u64; 5] = [
            11520,
            0,
            1509978240,
            1215971899390074240,
            1216172134540287360,
        ];
        for &e in &expected {
            assert_eq!(r.next_u64(), e);
        }
    }

    #[test]
    fn splitmix_seeding_is_deterministic_and_sensitive() {
        let a: Vec<u64> = (0..4).map(|_| Rng::new(7).next_u64()).collect();
        let b: Vec<u64> = (0..4).map(|_| Rng::new(7).next_u64()).collect();
        assert_eq!(a, b);
        assert_ne!(Rng::new(7).next_u64(), Rng::new(8).next_u64());
    }

    #[test]
    fn derive_streams_are_independent() {
        let mut a = Rng::derive(1, &[0, 5]);
        let mut b = Rng::derive(1, &[0, 6]);
        let mut c = Rng::derive(1, &[1, 5]);
        let (x, y, z) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_ne!(x, y);
        assert_ne!(x, z);
        assert_ne!(y, z);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(123);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(99);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_rank0_most_frequent() {
        let t = ZipfTable::new(1000, 1.1);
        let mut r = Rng::new(3);
        let mut c0 = 0;
        let mut c_other = 0;
        for _ in 0..50_000 {
            match t.sample(&mut r) {
                0 => c0 += 1,
                500.. => c_other += 1,
                _ => {}
            }
        }
        assert!(c0 > c_other, "rank0 {c0} vs tail {c_other}");
    }

    #[test]
    fn zipf_sample_in_range() {
        let t = ZipfTable::new(17, 1.0);
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            assert!(t.sample(&mut r) < 17);
        }
    }
}
