//! Flat-vector math used on the coordinator hot paths.
//!
//! Everything operates on contiguous `&[f32]` / `&mut [f32]` so LLVM can
//! auto-vectorise. The per-element hot loops themselves live in
//! [`crate::util::kernels`] (one bitwise-pinned copy shared by the
//! optimizers, the aggregator and the compressed transports; DESIGN.md
//! §6); this module re-exposes the aggregation entry points the rest of
//! the crate historically imported from here, plus the norm/diff
//! primitives that have no other home.

use crate::util::kernels;

/// Panic-with-context helper for length mismatches (protocol invariant).
#[inline]
fn check_len(a: usize, b: usize, what: &str) {
    assert_eq!(a, b, "length mismatch in {what}: {a} vs {b}");
}

/// `out[i] = mean_k inputs[k][i]` — the Alg. 4 lines 11–12 synchronization
/// average. `inputs` must be non-empty and same-length. Delegates to the
/// shared cache-blocked kernel ([`kernels::mean_into`]).
pub fn mean_into(inputs: &[&[f32]], out: &mut [f32]) {
    kernels::mean_into(inputs, out);
}

/// In-place `acc += x` ([`kernels::add_assign`]).
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    kernels::add_assign(acc, x);
}

/// In-place `acc *= s` ([`kernels::scale_assign`]).
pub fn scale_assign(acc: &mut [f32], s: f32) {
    kernels::scale_assign(acc, s);
}

/// In-place `acc += s * x` ([`kernels::axpy`]).
pub fn axpy(acc: &mut [f32], s: f32, x: &[f32]) {
    kernels::axpy(acc, s, x);
}

/// Euclidean norm.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Max |x_i| (the paper's Assumption 2 bound ρ is on the ∞-norm).
pub fn linf_norm(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// Dot product in f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    check_len(a.len(), b.len(), "dot");
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// `max_i |a_i - b_i|` — the equivalence metric used by the H=1 ≡ sync test.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    check_len(a.len(), b.len(), "max_abs_diff");
    a.iter().zip(b).fold(0.0f32, |m, (&x, &y)| m.max((x - y).abs()))
}

/// True if every element is finite (NaN/Inf tripwire after each sync round).
pub fn all_finite(x: &[f32]) -> bool {
    x.iter().all(|v| v.is_finite())
}

/// Mean of a slice (f64 accumulation).
pub fn mean(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_into_basic() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [3.0f32, 4.0, 5.0];
        let mut out = [0.0f32; 3];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 3.0, 4.0]);
    }

    #[test]
    fn mean_into_single_input_is_copy() {
        let a = [1.5f32, -2.5];
        let mut out = [0.0f32; 2];
        mean_into(&[&a], &mut out);
        assert_eq!(out, a);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mean_into_rejects_ragged() {
        let a = [1.0f32; 3];
        let b = [1.0f32; 2];
        let mut out = [0.0f32; 3];
        mean_into(&[&a, &b], &mut out);
    }

    #[test]
    fn axpy_and_add() {
        let mut acc = vec![1.0f32; 4];
        axpy(&mut acc, 2.0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(acc, vec![3.0, 5.0, 7.0, 9.0]);
        add_assign(&mut acc, &[1.0; 4]);
        assert_eq!(acc, vec![4.0, 6.0, 8.0, 10.0]);
        scale_assign(&mut acc, 0.5);
        assert_eq!(acc, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn norms_and_dot() {
        let v = [3.0f32, 4.0];
        assert!((l2_norm(&v) - 5.0).abs() < 1e-12);
        assert_eq!(linf_norm(&[-7.0, 2.0]), 7.0);
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn diff_and_finite() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
        assert!(all_finite(&[1.0, -2.0]));
        assert!(!all_finite(&[1.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
