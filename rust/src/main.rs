//! `adaalter` — the Local AdaAlter training framework CLI (leader entry).
//!
//! ```text
//! adaalter train      --experiment <preset> | --config <file> [--set k=v]…
//!                     [--role leader --listen addr | --role worker
//!                      --worker-id i --connect addr] [--port-file path]
//! adaalter presets                       list experiment presets
//! adaalter inspect    [--artifacts dir]  summarise the AOT artifacts
//! adaalter epoch-model [--workers n]     print the Fig. 1/2 analytic rows
//! adaalter version
//! ```
//!
//! With `comm.transport = "tcp"` / `"uds"` the same binary is both halves
//! of the networked deployment (DESIGN.md §4): the leader binds
//! `--listen` (or `net.listen`), each worker process dials `--connect`
//! (or polls `--port-file` for a port-0 leader's published address).

use std::sync::Arc;

use adaalter::cli::Args;
use adaalter::config::{self, ExperimentConfig, SyncPeriod, TomlDoc};
use adaalter::coordinator::factory::make_factory;
use adaalter::coordinator::Trainer;
use adaalter::error::Result;
use adaalter::runtime::Manifest;
use adaalter::sim::{Charge, EpochModel, SimAlgo};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            "experiment", "config", "set", "artifacts", "workers", "out-dir", "resume",
            "role", "listen", "connect", "worker-id", "port-file",
        ],
        &["no-fused", "quiet", "help", "rejoin"],
    )?;
    match args.command.as_str() {
        "" | "help" => {
            print_help();
            Ok(())
        }
        "version" => {
            println!("adaalter {}", adaalter::version());
            Ok(())
        }
        "presets" => cmd_presets(),
        "train" => cmd_train(&args),
        "inspect" => cmd_inspect(&args),
        "epoch-model" => cmd_epoch_model(&args),
        other => Err(adaalter::Error::Config(format!(
            "unknown command {other:?} (try `adaalter help`)"
        ))),
    }
}

fn print_help() {
    println!(
        "adaalter {} — Local AdaAlter (Xie et al. 2019) training framework

USAGE:
  adaalter train --experiment <name> [--set key=value]... [--no-fused]
  adaalter train --config <file.toml> [--set key=value]...
  adaalter train ... --resume <checkpoint.bin>
  adaalter train ... --role leader --listen 127.0.0.1:0 --port-file <p>
  adaalter train ... --role worker --worker-id <i> --connect <addr>
  adaalter train ... --role worker --worker-id <i> --connect <addr> --rejoin
  adaalter presets
  adaalter inspect [--artifacts <dir>]
  adaalter epoch-model
  adaalter version",
        adaalter::version()
    );
}

fn cmd_presets() -> Result<()> {
    println!("{:<20} summary", "name");
    for p in config::PRESETS {
        println!("{:<20} {}", p.name, p.summary);
    }
    Ok(())
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut doc = if let Some(path) = args.get("config") {
        TomlDoc::load(path)?
    } else {
        let name = args.get_or("experiment", "paper-default");
        config::preset_doc(name)?
    };
    for spec in args.get_all("set") {
        ExperimentConfig::override_from_doc(&mut doc, spec)?;
    }
    let mut cfg = ExperimentConfig::from_doc(&doc)?;
    if let Some(dir) = args.get("out-dir") {
        cfg.out_dir = dir.to_string();
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    let quiet = args.has("quiet");
    match args.get_or("role", "leader") {
        "leader" => {
            if let Some(listen) = args.get("listen") {
                cfg.net.listen = listen.to_string();
            }
        }
        // A worker process of the networked deployment (DESIGN.md §4):
        // dial the leader, handshake, serve the lockstep protocol until
        // Stop. No leader-side reporting happens here.
        "worker" => {
            let w: usize = args
                .get("worker-id")
                .ok_or_else(|| {
                    adaalter::Error::Config("--role worker requires --worker-id".into())
                })?
                .parse()
                .map_err(|_| {
                    adaalter::Error::Config(
                        "--worker-id must be a non-negative integer".into(),
                    )
                })?;
            return adaalter::comm::run_worker(
                &cfg,
                w,
                args.get_or("connect", ""),
                args.get("port-file"),
                args.has("rejoin"),
            );
        }
        other => {
            return Err(adaalter::Error::Config(format!(
                "--role must be \"leader\" or \"worker\", got {other:?}"
            )))
        }
    }
    if !quiet {
        println!(
            "training: algo={} workers={} H={} steps={} backend={:?} preset={}",
            cfg.optim.algorithm,
            cfg.train.workers,
            cfg.train.sync_period,
            cfg.train.steps,
            cfg.train.backend,
            cfg.train.preset
        );
    }
    let factory = make_factory(&cfg)?;
    let mut trainer = Trainer::new(cfg.clone(), factory);
    trainer.allow_fused = !args.has("no-fused");
    trainer.port_file = args.get("port-file").map(String::from);
    if let Some(path) = args.get("resume") {
        let ck = adaalter::coordinator::Checkpoint::load(path)?;
        if !quiet {
            println!("resuming from {path} at step {}", ck.step);
        }
        trainer.resume = Some(ck);
    }
    let result = trainer.run()?;

    let (syncs, bytes) = result.recorder.comm();
    if !quiet {
        for p in &result.recorder.steps {
            println!(
                "step {:>6}  epoch {:>7.3}  loss {:>9.5}  lr {:>7.5}  vtime {:>9.1}s",
                p.step, p.epoch, p.train_loss, p.lr, p.virtual_s
            );
        }
    }
    if let Some(ev) = result.final_eval {
        match ev.ppl {
            Some(ppl) => println!("final: eval_loss {:.5}  test PPL {:.3}", ev.loss, ppl),
            None => println!("final: global loss {:.6}", ev.loss),
        }
    }
    println!(
        "virtual time {:.1}s (compute {:.1}s, dataload {:.1}s, comm {:.1}s, \
         straggler {:.1}s); \
         {syncs} syncs, {:.1} MiB shipped; wall {:.1}s, {:.0} samples/s host",
        result.clock.now_s(),
        result.clock.total(Charge::Compute),
        result.clock.total(Charge::DataLoad),
        result.clock.total(Charge::Communication),
        result.clock.total(Charge::Straggler),
        bytes as f64 / (1 << 20) as f64,
        result.recorder.steps.last().map(|p| p.wall_s).unwrap_or(0.0),
        result.recorder.wall_throughput(),
    );

    std::fs::create_dir_all(&cfg.out_dir)?;
    let tag = format!(
        "{}_w{}_h{}",
        cfg.optim.algorithm,
        cfg.train.workers,
        cfg.train.sync_period
    );
    let steps_csv = format!("{}/train_{tag}.csv", cfg.out_dir);
    let evals_csv = format!("{}/eval_{tag}.csv", cfg.out_dir);
    result.recorder.write_steps_csv(&steps_csv)?;
    result.recorder.write_evals_csv(&evals_csv)?;
    if !quiet {
        println!("wrote {steps_csv} and {evals_csv}");
    }
    // Local runs: the realized-H trajectory (one row per sync round).
    if !result.recorder.sync_events.is_empty() {
        let sync_csv = format!("{}/sync_{tag}.csv", cfg.out_dir);
        result.recorder.write_sync_csv(&sync_csv)?;
        if !quiet {
            println!(
                "wrote {sync_csv} ({} rounds, policy {})",
                result.recorder.sync_events.len(),
                result.recorder.sync_policy()
            );
        }
    }
    // Networked runs: a machine-readable report of everything the
    // equivalence tests pin bitwise against the in-process reference —
    // final params and per-step losses as exact bit patterns, the booked
    // traffic, and the real socket byte counters (DESIGN.md §4).
    if let Some((accounted, total)) = result.net_bytes {
        use adaalter::util::json::Json;
        use std::collections::BTreeMap;
        let mut doc: BTreeMap<String, Json> = BTreeMap::new();
        doc.insert(
            "final_x_bits".into(),
            Json::Arr(result.final_x.iter().map(|v| Json::Num(v.to_bits() as f64)).collect()),
        );
        doc.insert(
            "steps".into(),
            Json::Arr(
                result
                    .recorder
                    .steps
                    .iter()
                    .map(|p| {
                        Json::Arr(vec![
                            Json::Num(p.step as f64),
                            Json::Str(format!("{:016x}", p.train_loss.to_bits())),
                        ])
                    })
                    .collect(),
            ),
        );
        doc.insert(
            "final_eval_loss_bits".into(),
            match &result.final_eval {
                Some(ev) => Json::Str(format!("{:016x}", ev.loss.to_bits())),
                None => Json::Null,
            },
        );
        doc.insert("syncs".into(), Json::Num(syncs as f64));
        doc.insert("booked_bytes".into(), Json::Num(bytes as f64));
        doc.insert("accounted_bytes".into(), Json::Num(accounted as f64));
        doc.insert("total_bytes".into(), Json::Num(total as f64));
        let path = format!("{}/net_report.json", cfg.out_dir);
        std::fs::write(&path, Json::Obj(doc).dump())?;
        if !quiet {
            println!(
                "wrote {path} (accounted {accounted} B == booked {bytes} B? {}; \
                 total on the wire {total} B)",
                accounted == bytes
            );
        }
    }
    // Fault runs: the per-round participation log (who made each round,
    // who was dropped, how long the barrier waited).
    if !result.recorder.fault_events.is_empty() {
        let faults_csv = format!("{}/faults_{tag}.csv", cfg.out_dir);
        result.recorder.write_faults_csv(&faults_csv)?;
        if !quiet {
            let waited: f64 =
                result.recorder.fault_events.iter().map(|e| e.wait_s).sum();
            println!(
                "wrote {faults_csv} ({} rounds, straggler wait {waited:.2}s)",
                result.recorder.fault_events.len()
            );
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let m = Manifest::load(dir)?;
    println!("manifest v{} at {}/", m.version, dir);
    for (name, p) in &m.presets {
        println!(
            "  preset {name}: d={} ({:.2}M params), batch={}, seq={}, vocab={}",
            p.d,
            p.d as f64 / 1e6,
            p.batch,
            p.seq,
            p.vocab
        );
        for (aname, a) in &p.artifacts {
            let ins: Vec<String> = a.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
            println!("    {aname:<22} {} inputs {}", a.file, ins.join(" "));
        }
    }
    Ok(())
}

fn cmd_epoch_model(_args: &Args) -> Result<()> {
    let m = EpochModel::paper();
    let algos: Vec<SimAlgo> = vec![
        SimAlgo::AdaGrad,
        SimAlgo::AdaAlter,
        SimAlgo::LocalAdaAlter(SyncPeriod::Every(4)),
        SimAlgo::LocalAdaAlter(SyncPeriod::Every(8)),
        SimAlgo::LocalAdaAlter(SyncPeriod::Every(12)),
        SimAlgo::LocalAdaAlter(SyncPeriod::Every(16)),
        SimAlgo::LocalAdaAlter(SyncPeriod::Infinite),
        SimAlgo::IdealComputeOnly,
    ];
    println!("{:<34} {:>10} {:>10} {:>10} {:>10}", "algorithm \\ epoch seconds", "n=1", "n=2", "n=4", "n=8");
    for a in &algos {
        let row: Vec<String> = [1usize, 2, 4, 8]
            .iter()
            .map(|&n| format!("{:>10.0}", m.epoch_time_s(*a, n)))
            .collect();
        println!("{:<34} {}", a.label(), row.join(" "));
    }
    Ok(())
}

// The Arc import is used by make_factory's signature indirectly; keep the
// compiler honest if the signature changes.
#[allow(unused)]
fn _assert_factory_shape(f: adaalter::coordinator::BackendFactory) -> Arc<dyn Fn(usize) -> Result<Box<dyn adaalter::coordinator::WorkerBackend>> + Send + Sync> {
    f
}
