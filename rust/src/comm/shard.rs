//! Range partition of the parameter vector across `k` leader shards
//! (DESIGN.md §3).
//!
//! `comm.shards = k` splits `[0, d)` into `k` contiguous index ranges —
//! the first `d mod k` ranges get `⌈d/k⌉` coordinates, the rest `⌊d/k⌋` —
//! so every coordinate belongs to exactly one shard and the partition is
//! a pure function of `(d, k)` that leader and workers compute
//! independently (no shard map on the wire; frames carry only the shard
//! index in the free flag bits, DESIGN.md §4).
//!
//! Because every aggregation kernel in [`crate::util::kernels`] is
//! per-coordinate with a fixed operation order, averaging each range
//! separately is **bitwise-identical** to averaging the dense vector —
//! the foundation of the `shards = k ≡ shards = 1` equivalence pin.

use std::ops::Range;

use crate::coordinator::executor::Executor;

/// The range partition for a `d`-dimensional vector over `k` shards.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    d: usize,
    k: usize,
}

impl ShardPlan {
    /// Partition `[0, d)` into `k` contiguous ranges (k clamped to ≥ 1;
    /// shards beyond `d` come out empty).
    pub fn new(d: usize, k: usize) -> ShardPlan {
        ShardPlan { d, k: k.max(1) }
    }

    /// A single shard covering the whole vector — the unsharded plan.
    pub fn dense(d: usize) -> ShardPlan {
        ShardPlan::new(d, 1)
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.k
    }

    /// Vector dimension the plan partitions.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Is this the trivial single-shard plan?
    pub fn is_dense(&self) -> bool {
        self.k == 1
    }

    /// The index range owned by shard `s` (first `d mod k` shards carry
    /// the extra coordinate).
    pub fn range(&self, s: usize) -> Range<usize> {
        debug_assert!(s < self.k);
        let base = self.d / self.k;
        let extra = self.d % self.k;
        let start = s * base + s.min(extra);
        let len = base + usize::from(s < extra);
        start..start + len
    }

    /// All shard ranges in index order (adjacent, disjoint, covering
    /// `[0, d)`).
    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.k).map(|s| self.range(s))
    }

    /// The shard owning coordinate `i`.
    pub fn shard_of(&self, i: usize) -> usize {
        debug_assert!(i < self.d);
        let base = self.d / self.k;
        let extra = self.d % self.k;
        let split = extra * (base + 1);
        if i < split {
            i / (base + 1)
        } else {
            extra + (i - split) / base.max(1)
        }
    }
}

/// Shard-partitioned mean: average each shard's range independently —
/// the dataflow the k shard servers execute in parallel. Bitwise-identical
/// to the dense [`crate::util::math::mean_into`] (per-coordinate kernels,
/// fixed operation order; pinned by a property test below), so
/// `shards = k` runs reproduce `shards = 1` exactly.
pub fn mean_into_sharded(plan: &ShardPlan, inputs: &[&[f32]], out: &mut [f32]) {
    if plan.is_dense() {
        // Keep the unsharded path literally the pre-sharding call (and
        // allocation-free, DESIGN.md §7).
        crate::util::math::mean_into(inputs, out);
        return;
    }
    let mut subs: Vec<&[f32]> = Vec::with_capacity(inputs.len());
    for r in plan.ranges() {
        if r.is_empty() {
            continue;
        }
        subs.clear();
        subs.extend(inputs.iter().map(|v| &v[r.clone()]));
        crate::util::math::mean_into(&subs, &mut out[r]);
    }
}

/// [`mean_into_sharded`] fanned over an [`Executor`] — the pipelined
/// leader's parallel reduction stage (`[comm] pipeline`, DESIGN.md
/// §"Pipelined sync rounds"). Each shard's range is reduced by exactly
/// the same per-range [`crate::util::math::mean_into`] call the serial
/// path makes, on a disjoint `&mut` slice of `out`, so the result is
/// **bitwise-identical** to the serial (and dense) mean no matter how
/// the executor schedules the shards — only wall-clock changes.
pub fn mean_into_sharded_exec(
    plan: &ShardPlan,
    exec: &Executor,
    inputs: &[&[f32]],
    out: &mut [f32],
) {
    use crate::coordinator::executor::Parallelism;
    if plan.is_dense() || matches!(exec.parallelism(), Parallelism::Serial) {
        mean_into_sharded(plan, inputs, out);
        return;
    }
    // Carve `out` into the plan's disjoint per-shard windows so each
    // parallel task owns its slice exclusively.
    let mut parts: Vec<(Range<usize>, &mut [f32])> = Vec::with_capacity(plan.shards());
    let mut rest = out;
    for r in plan.ranges() {
        let (head, tail) = rest.split_at_mut(r.len());
        rest = tail;
        if !r.is_empty() {
            parts.push((r, head));
        }
    }
    exec.for_each(&mut parts, |_, (r, window)| {
        let subs: Vec<&[f32]> = inputs.iter().map(|v| &v[r.clone()]).collect();
        crate::util::math::mean_into(&subs, window);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn dense_plan_is_identity() {
        let p = ShardPlan::dense(10);
        assert!(p.is_dense());
        assert_eq!(p.range(0), 0..10);
        assert_eq!(p.ranges().count(), 1);
    }

    #[test]
    fn uneven_split_front_loads_the_remainder() {
        // d = 10, k = 4 → 3 | 3 | 2 | 2.
        let p = ShardPlan::new(10, 4);
        let r: Vec<_> = p.ranges().collect();
        assert_eq!(r, vec![0..3, 3..6, 6..8, 8..10]);
    }

    #[test]
    fn more_shards_than_coordinates_leaves_empty_tails() {
        let p = ShardPlan::new(3, 5);
        let lens: Vec<_> = p.ranges().map(|r| r.len()).collect();
        assert_eq!(lens, vec![1, 1, 1, 0, 0]);
    }

    #[test]
    fn properties_partition_laws() {
        prop::check("shard ranges partition [0, d)", 300, |g| {
            // Exercise d not divisible by k heavily (the boundary case the
            // sharded collectives must get right).
            let d = g.usize_in(0..4096);
            let k = 1 + g.usize_in(0..64);
            let p = ShardPlan::new(d, k);
            let mut expected_start = 0usize;
            let mut max_len = 0usize;
            let mut min_len = usize::MAX;
            for r in p.ranges() {
                prop::assert_that(r.start == expected_start, "adjacent and ordered")?;
                expected_start = r.end;
                max_len = max_len.max(r.len());
                min_len = min_len.min(r.len());
            }
            prop::assert_that(expected_start == d, "covers [0, d)")?;
            prop::assert_that(max_len - min_len <= 1, "balanced within one")?;
            prop::assert_that(
                max_len == d.div_ceil(k) && (d == 0 || min_len == d / k),
                "sizes are ⌈d/k⌉ / ⌊d/k⌋",
            )?;
            // shard_of inverts the ranges.
            if d > 0 {
                let i = g.usize_in(0..d);
                let s = p.shard_of(i);
                prop::assert_that(p.range(s).contains(&i), "shard_of lands in its range")?;
            }
            Ok(())
        });
    }

    #[test]
    fn properties_exec_parallel_mean_is_bitwise_serial() {
        prop::check("executor-fanned shard mean ≡ serial, bitwise", 60, |g| {
            let d = 1 + g.usize_in(0..400);
            let k = 1 + g.usize_in(0..10);
            let n = 1 + g.usize_in(0..5);
            let threads = 1 + g.usize_in(0..4);
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| g.f32_in(-4.0..4.0)).collect())
                .collect();
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let plan = ShardPlan::new(d, k);
            let mut serial = vec![0.0f32; d];
            mean_into_sharded(&plan, &refs, &mut serial);
            let mut parallel = vec![0.0f32; d];
            mean_into_sharded_exec(&plan, &Executor::threads(threads), &refs, &mut parallel);
            prop::assert_that(
                serial.iter().zip(&parallel).all(|(a, b)| a.to_bits() == b.to_bits()),
                "bitwise equal",
            )
        });
    }

    #[test]
    fn properties_sharded_mean_is_bitwise_dense() {
        use crate::util::kernels;
        prop::check("per-shard mean ≡ dense mean, bitwise", 100, |g| {
            let d = 1 + g.usize_in(0..300);
            let k = 1 + g.usize_in(0..8);
            let n = 1 + g.usize_in(0..5);
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| g.f32_in(-4.0..4.0)).collect())
                .collect();
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let mut dense = vec![0.0f32; d];
            kernels::mean_into(&refs, &mut dense);
            let mut sharded = vec![0.0f32; d];
            mean_into_sharded(&ShardPlan::new(d, k), &refs, &mut sharded);
            prop::assert_that(
                dense.iter().zip(&sharded).all(|(a, b)| a.to_bits() == b.to_bits()),
                "bitwise equal",
            )
        });
    }
}
